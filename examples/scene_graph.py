"""Scene graphs end to end: arbitrary Bayesian networks compiled to the packed
stochastic substrate (the generalisation of the Fig S8 motif scripts).

One declarative spec replaces the per-motif wiring: the compiler lowers any
DAG -- binary or cardinality-k categorical -- to counter-entropy SNEs +
parent-gathered DAC CDFs + CORDIV, the enumeration oracle bounds it, and the
frame driver batches streaming evidence.

Run:  PYTHONPATH=src python examples/scene_graph.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.bayesnet import (
    FrameDriver, NetworkSpec, Node, by_name, compile_network,
    make_posterior_fn, sample_evidence,
)
from repro.core import graph

key = jax.random.PRNGKey(0)

# 1. A Fig S8 motif is just a three-node spec ---------------------------------
cpt = ((0.10, 0.60), (0.35, 0.90))
motif = NetworkSpec(
    name="fig-s8b",
    nodes=(
        Node("a1", (), (0.30,)),
        Node("a2", (), (0.70,)),
        Node("b", ("a1", "a2"), cpt[0] + cpt[1]),
    ),
    evidence=("b",), queries=("a1",),
)
net = compile_network(motif, n_bits=1 << 14)
post, acc = net.run(key, jnp.array([[1]]))
expect = float(graph.analytic_two_parent(0.30, 0.70, jnp.asarray(cpt)))
print(f"1. Fig S8b as a spec: P(A1|B=1) = {float(post[0, 0]):.3f} "
      f"(analytic {expect:.3f}, {int(acc[0])} accepted bits)")

# 2. An 8-node scenario network, 2048 evidence frames, one jit launch ---------
# The default lowering is the fused net_sweep: every frame draws an
# INDEPENDENT joint sample (what the memristor array provides for free),
# generated in-register -- no entropy tensor ever reaches HBM.
spec = by_name("pedestrian-night")
net = compile_network(spec, n_bits=4096)
ev = sample_evidence(spec, jax.random.PRNGKey(1), 2048)
post, acc = net.run(key, ev)                     # warm-up + compile
jax.block_until_ready(post)
t0 = time.perf_counter()
post, acc = net.run(key, ev)
jax.block_until_ready(post)
dt = time.perf_counter() - t0
shared = compile_network(spec, n_bits=4096, share_entropy=True)
sp, _ = shared.run(key, ev)
jax.block_until_ready(sp)
t0 = time.perf_counter()
sp, _ = shared.run(key, ev)
jax.block_until_ready(sp)
dt_shared = time.perf_counter() - t0
print(f"2. {spec.name}: {spec.n_nodes} nodes, queries {net.queries}, "
      f"{ev.shape[0]} frames in {dt * 1e3:.2f} ms "
      f"({ev.shape[0] / dt:,.0f} frames/s on {jax.default_backend()}, "
      f"independent joint sample per frame; error-correlated shared-entropy "
      f"launch took {dt_shared / dt:.2f}x as long)")

# 3. Exact enumeration oracle bounds the stochastic backend -------------------
exact, _ = make_posterior_fn(spec, dac_quantize=True)(ev)
err = np.abs(np.asarray(post) - np.asarray(exact))
keep = np.asarray(acc) > 50
print(f"3. vs enumeration oracle: mean |err| {err[keep].mean():.4f}, "
      f"max {err[keep].max():.4f} over {int(keep.sum())} frames "
      f"(stochastic floor ~{1 / np.sqrt(np.median(np.asarray(acc))):.4f})")

# 4. Streaming frames through serve-style continuous batching -----------------
drv = FrameDriver(net, max_batch=512, base_key=jax.random.PRNGKey(2))
night_frame = np.array([1, 0, 1])                # night, no RGB, thermal fires
day_frame = np.array([0, 1, 1])                  # day, both detectors fire
drv.submit(night_frame); drv.submit(day_frame)
out = drv.drain()                                # driver sequences launch keys
q = net.queries.index("pedestrian")
print(f"4. streamed frames: P(pedestrian | night, thermal-only) = {out[0][0][q]:.3f}, "
      f"P(pedestrian | day, both) = {out[1][0][q]:.3f}")
print("   (thermal alone at night is already decisive -- the Fig 4 rescue, "
      "now produced by a compiled network instead of hand-wired operators)")

# 5. Categorical nodes are first-class: 4-way obstacle classification ---------
# A cardinality-k node is one spec line -- no towers of booleans.  The
# compiler lowers it to ceil(log2 k) packed value bit-planes sampled from one
# entropy byte against the CPT row's 8-bit DAC CDF; queries come back as
# normalised length-k posterior vectors, and `decide` argmaxes the count
# slots in-register inside the same fused sweep launch (posterior + MAP
# decision, one kernel).
spec = by_name("obstacle-class")
net = compile_network(spec, n_bits=4096)
ev = sample_evidence(spec, jax.random.PRNGKey(3), 2048)
post, acc = net.run(key, ev)                     # warm-up + compile
jax.block_until_ready(post)
t0 = time.perf_counter()
post, acc = net.run(key, ev)
jax.block_until_ready(post)
dt = time.perf_counter() - t0
exact, _ = make_posterior_fn(spec, dac_quantize=True)(ev)
keep = np.asarray(acc) > 50
err = np.abs(np.asarray(post) - np.asarray(exact))[keep]
classes = ("none", "pedestrian", "vehicle", "cyclist")
print(f"5. {spec.name}: obstacle is ONE cardinality-4 node "
      f"({net.query_cards[0]}-vector posterior), {ev.shape[0]} frames in "
      f"{dt * 1e3:.2f} ms ({ev.shape[0] / dt:,.0f} frames/s), "
      f"mean |err| vs oracle {err.mean():.4f}")
# a thermal large-warm signature + strong echo on a dark road: classify
frame = np.array([1, 0, 2, 2])                   # night, rgb=none, th=large, radar=strong
post, dec, _ = net.decide(jax.random.PRNGKey(5), np.stack([frame]))
vec = ", ".join(f"{c}={float(p):.3f}" for c, p in zip(classes, np.asarray(post)[0, 0]))
print(f"   P(obstacle | night, thermal-large, radar-strong) = [{vec}] "
      f"-> decide: {classes[int(np.asarray(dec)[0, 0])].upper()}")
