"""Serve a small model with batched requests and the paper's timely-reliable
Bayes decision gate (fused posteriors + confidence threshold).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import api
from repro.serve import EngineConfig, Request, ServeEngine

cfg = get_smoke_config("qwen2-72b")
params = api.init(cfg, jax.random.PRNGKey(0))

engine = ServeEngine(
    cfg, params,
    EngineConfig(max_batch=4, t_cache=128, bayes_gate=True,
                 confidence_threshold=0.5),
)

rng = np.random.default_rng(0)
requests = [
    Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=6 + i).astype(np.int32),
            max_new_tokens=12)
    for i in range(4)
]
engine.run(jax.random.PRNGKey(1), requests)

print("=== batched serving with Bayes-gated emission ===")
for r in requests:
    gated = sum(c >= 0.5 for c in r.confidences)
    print(f"request {r.rid}: generated {len(r.out_tokens)} tokens | "
          f"{gated}/{len(r.out_tokens)} emissions cleared the reliability gate | "
          f"mean fused confidence {np.mean(r.confidences):.2f}")
print("\n(a rejected emission is the LM analogue of the paper's 'keep lane' "
      "branch: the decision is withheld until belief clears the threshold)")
