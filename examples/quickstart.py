"""Quickstart: the paper's probabilistic-computing stack in five steps.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import (
    Corr, bayes_fusion, bayes_inference, bitops, cordiv, latency, logic, sne,
)

key = jax.random.PRNGKey(0)
N = 1024  # stochastic-number length (paper demos use 100; longer = more precise)

# 1. Stochastic number encoding (the memristor SNE, Fig 2a) -------------------
p = 0.72
stream = sne.encode_uncorrelated(key, p, N)
print(f"1. SNE: encoded p={p} -> measured {float(bitops.decode(stream, N)):.3f} "
      f"({N} bits packed into {stream.shape[-1]} uint32 words)")

# 2. Probabilistic logic: AND as a one-gate multiplier (Fig 2d/e) -------------
_, est, _ = logic.prob_and(key, 0.8, 0.6, N, Corr.UNCORRELATED)
print(f"2. AND(0.8, 0.6) uncorrelated = {float(est):.3f}  (expect 0.48)")
_, est_min, _ = logic.prob_and(key, 0.8, 0.6, N, Corr.POSITIVE)
print(f"   AND(0.8, 0.6) positively correlated = {float(est_min):.3f}  (expect min=0.6)")

# 3. CORDIV division (Fig S7's divider) ---------------------------------------
kd, ke = jax.random.split(key)
d = sne.encode_uncorrelated(kd, 0.8, N)
n_sub = d & sne.encode_uncorrelated(ke, 0.5, N)       # n subset-of d
_, q = cordiv.cordiv_scan(n_sub, d, N)
print(f"3. CORDIV: P(n)/P(d) = {float(q):.3f}  (expect 0.5)")

# 4. Bayesian inference operator (Fig 3, eq 1) --------------------------------
tr = bayes_inference(key, p_a=0.57, p_b_given_a=0.72, p_b_given_nota=0.6, n_bits=N)
print(f"4. Bayes inference: P(A)=0.57 -> P(A|B)={float(tr.posterior_ratio):.3f} "
      f"(theory {float(tr.posterior_analytic):.3f}; paper's route-planning case)")

# 5. Bayesian fusion operator (Fig 4, eq 5) + the timeliness claim ------------
p_modal = jnp.array([[0.55, 0.45],     # RGB says: weak obstacle evidence
                     [0.95, 0.05]])    # thermal says: strong obstacle evidence
ftr = bayes_fusion(key, p_modal, n_bits=N)
rep = latency.memristor_latency(n_bits=100)
print(f"5. Bayes fusion: fused P(obstacle)={float(ftr.fused_ratio[0]):.3f} "
      f"(analytic {float(ftr.fused_analytic[0]):.3f}); "
      f"memristor latency model: {rep.frame_latency_s*1e3:.1f} ms/frame "
      f"= {rep.fps:.0f} fps (paper: <0.4 ms, 2500 fps)")
