"""Route planning for self-driving with the hardware Bayesian inference
operator (paper Fig 3): a vehicle decides whether to cut into the target lane.

Run:  PYTHONPATH=src python examples/route_planning.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bayes_inference, correlation, latency

key = jax.random.PRNGKey(2024)

# Scenario (Fig 3a): prior belief that cutting in is safe, evidence about the
# incoming (blue) vehicle on the target lane.
P_A = 0.57           # prior belief to cut in (traffic rules, road structure...)
P_B_GIVEN_A = 0.72   # chance of seeing this lane state if cutting in is safe
P_B_GIVEN_NOT_A = 0.60

print("=== timely reliable route planning (memristor Bayes operator) ===")
for trial in range(5):
    tr = bayes_inference(jax.random.fold_in(key, trial), P_A, P_B_GIVEN_A,
                         P_B_GIVEN_NOT_A, n_bits=100)
    post = float(tr.posterior_ratio)
    decision = "CUT IN (belief increased)" if post > P_A else "KEEP LANE"
    print(f"frame {trial}: P(A|B) = {post:.2f}  (theory "
          f"{float(tr.posterior_analytic):.2f})  -> {decision}")

# the paper's timing argument: decision latency vs human reaction / ADAS
rep = latency.memristor_latency(n_bits=100, n_sne=5)
print(f"\noperator latency @100 bits: {rep.frame_latency_s*1e3:.2f} ms/frame "
      f"({rep.fps:.0f} fps) -- paper claims <0.4 ms / 2,500 fps: "
      f"{'OK' if rep.meets_paper_claim() else 'MISS'}")
print(f"reference: human driver brake reaction {latency.HUMAN_REACTION_S}, "
      f"ADAS {latency.ADAS_FPS} fps")

# correlation audit (Fig 3c/3d): the circuit works in the designed correlations
tr = bayes_inference(key, P_A, P_B_GIVEN_A, P_B_GIVEN_NOT_A, n_bits=1 << 14)
rho = correlation.correlation_matrix(tr.streams, tr.n_bits, "pearson")
names = list(tr.streams)
print("\nPearson correlation matrix (stream order: " + ", ".join(names) + ")")
print(np.array2string(np.asarray(rho), precision=2, suppress_small=True))
