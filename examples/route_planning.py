"""Route planning for self-driving with the hardware Bayesian inference
operator (paper Fig 3): a vehicle decides whether to cut into the target lane.

Ported off the legacy hand-wired ``core.bayes_inference`` pipeline onto the
bayesnet compiler: the two-node prior/likelihood motif is now a declarative
spec, frames stream through the serve-style ``FrameDriver`` (the compiled
fused sweep underneath), and the analytic reference comes from the
enumeration oracle instead of the motif-specific closed form.

Run:  PYTHONPATH=src python examples/route_planning.py
"""

import jax
import numpy as np

from repro.bayesnet import (
    FrameDriver, NetworkSpec, Node, compile_network, make_posterior_fn,
)
from repro.bayesnet.compile import lower_streams
from repro.core import correlation, latency

# Scenario (Fig 3a): prior belief that cutting in is safe, evidence about the
# incoming (blue) vehicle on the target lane.
P_A = 0.57           # prior belief to cut in (traffic rules, road structure...)
P_B_GIVEN_A = 0.72   # chance of seeing this lane state if cutting in is safe
P_B_GIVEN_NOT_A = 0.60

N_BITS = 96          # the paper's ~100-bit frames, word-aligned for packing

spec = NetworkSpec(
    name="route-planning",
    nodes=(
        Node("cut_in", (), (P_A,)),
        Node("lane_state", ("cut_in",), (P_B_GIVEN_NOT_A, P_B_GIVEN_A)),
    ),
    evidence=("lane_state",),
    queries=("cut_in",),
)
net = compile_network(spec, n_bits=N_BITS)
theory = float(make_posterior_fn(spec)(np.asarray([[1]]))[0][0, 0])

print("=== timely reliable route planning (memristor Bayes operator) ===")
driver = FrameDriver(net, max_batch=8, base_key=jax.random.PRNGKey(2024), salt=0)
driver.submit(np.ones((5, 1), np.int32))      # five frames of B = 1 evidence
for trial, (post_vec, accepted) in sorted(driver.drain().items()):
    post = float(post_vec[0])
    decision = "CUT IN (belief increased)" if post > P_A else "KEEP LANE"
    print(f"frame {trial}: P(A|B) = {post:.2f}  (theory "
          f"{theory:.2f})  -> {decision}")

# the paper's timing argument: decision latency vs human reaction / ADAS
rep = latency.memristor_latency(n_bits=100, n_sne=5)
print(f"\noperator latency @100 bits: {rep.frame_latency_s*1e3:.2f} ms/frame "
      f"({rep.fps:.0f} fps) -- paper claims <0.4 ms / 2,500 fps: "
      f"{'OK' if rep.meets_paper_claim() else 'MISS'}")
print(f"reference: human driver brake reaction {latency.HUMAN_REACTION_S}, "
      f"ADAS {latency.ADAS_FPS} fps")

# correlation audit (Fig 3c/3d): the compiled node streams carry the designed
# correlations -- lane_state is driven by cut_in through the gathered CPT, so
# the pair correlates; fresh counter entropy keeps everything else clean.
streams = lower_streams(spec, jax.random.PRNGKey(2024), 1 << 14)
names = list(spec.topo_order())
rho = correlation.correlation_matrix(
    {name: streams[name][0] for name in names}, 1 << 14, "pearson"
)
print("\nPearson correlation matrix (stream order: " + ", ".join(names) + ")")
print(np.array2string(np.asarray(rho), precision=2, suppress_small=True))
