"""Obstacle detection via RGB+thermal Bayesian fusion (paper Fig 4 / Movie S1)
on synthetic FLIR-like scenes, through the packed Pallas kernel pipeline.

Run:  PYTHONPATH=src python examples/obstacle_fusion.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import detection
from repro.kernels.bayes_decide.ops import bayes_decide
from repro.kernels.fusion_map.ops import fusion_map

key = jax.random.PRNGKey(0)
cfg = detection.SceneConfig(height=64, width=64, night_fraction=1.0)  # night!

gt, p_rgb, p_th, night = detection.make_scene(key, cfg)
print(f"night scene: {int(gt.sum())} obstacle pixels")

# single-modal decisions (what the pre-trained edge networks would output)
for name, p in (("RGB", p_rgb), ("thermal", p_th)):
    tp, fp, conf = detection.detection_metrics(gt, p)
    print(f"  {name:8s}: detection {float(tp)*100:5.1f}%  conf {float(conf):.2f}")

# analytic fusion (eq 5) through the fusion_map kernel
p_modal = jnp.stack([
    jnp.stack([p_rgb, 1 - p_rgb], -1).reshape(-1, 2),
    jnp.stack([p_th, 1 - p_th], -1).reshape(-1, 2),
])
fused = fusion_map(p_modal)[:, 0].reshape(gt.shape)
tp, fp, conf = detection.detection_metrics(gt, fused)
print(f"  fused   : detection {float(tp)*100:5.1f}%  conf {float(conf):.2f}"
      f"   <- recovers targets both modalities are unsure about")

# stochastic-circuit path on a tile, one fused kernel launch:
# encode -> AND -> popcount -> argmax without leaving VMEM
tile = p_modal[:, :4096, :]                       # (2, pixels, 2)
decisions, counts = bayes_decide(jax.random.PRNGKey(1), tile, 256)
counts = counts.astype(jnp.float32)                        # (pix, 2)
stoch = counts[:, 0] / jnp.maximum(counts.sum(-1), 1.0)
err = float(jnp.mean(jnp.abs(stoch - fused.reshape(-1)[:4096])))
agree = float(jnp.mean((decisions == (fused.reshape(-1)[:4096] < 0.5)).astype(jnp.float32)))
print(f"\nfused stochastic circuit (256-bit streams) vs analytic fusion: "
      f"mean abs err {err:.3f}, decision agreement {agree*100:.1f}%")
print("(the hardware operator is this pipeline with memristor entropy; "
      "<0.4 ms/frame at 100-bit on the paper's substrate)")
