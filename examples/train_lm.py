"""End-to-end training driver: train a ~100M-param qwen2-family model for a few
hundred steps on the synthetic pipeline (CPU-feasible scale by default).

Run:    PYTHONPATH=src python examples/train_lm.py            (fast, ~30M)
        PYTHONPATH=src python examples/train_lm.py --full     (~100M, slower)

Demonstrates the production loop surface: deterministic resumable data, AdamW
with fp32 master, checkpointing + restart, straggler/spike guards.
"""

import argparse
import dataclasses

import jax

from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig
from repro.optim import adamw
from repro.train.loop import TrainConfig, TrainLoop

ap = argparse.ArgumentParser()
ap.add_argument("--full", action="store_true", help="~100M params, 200 steps")
ap.add_argument("--steps", type=int, default=0)
args = ap.parse_args()

base = get_smoke_config("qwen2-72b")
if args.full:
    cfg = dataclasses.replace(
        base, name="qwen2-100m", num_layers=8, d_model=512, num_heads=8,
        num_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32_000,
    )
    steps, batch, seq = args.steps or 200, 8, 256
else:
    cfg = dataclasses.replace(
        base, name="qwen2-30m", num_layers=4, d_model=256, num_heads=4,
        num_kv_heads=2, head_dim=64, d_ff=1024, vocab_size=8_192,
    )
    steps, batch, seq = args.steps or 60, 8, 128

n_params = sum(
    x.size for x in jax.tree.leaves(
        jax.eval_shape(lambda: __import__("repro.models.api", fromlist=["api"])
                       .init(cfg, jax.random.PRNGKey(0)))
    )
)
print(f"training {cfg.name}: {n_params/1e6:.1f}M params, {steps} steps, "
      f"batch {batch} x seq {seq}")

loop = TrainLoop(
    cfg,
    DataConfig(seed=0, global_batch=batch, seq_len=seq, vocab_size=cfg.vocab_size),
    TrainConfig(steps=steps, ckpt_every=max(steps // 4, 1),
                ckpt_dir="/tmp/repro_train_lm"),
    adamw.AdamWConfig(lr=1e-3, warmup_steps=max(steps // 10, 1), total_steps=steps),
)
params, _, history = loop.run(jax.random.PRNGKey(0))
losses = [h["loss"] for h in history]
print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
      f"({'DECREASED' if losses[-1] < losses[0] else 'no progress'})")
print(f"checkpoints committed at: {loop.ckpt.available_steps()}")
