"""Scale-out demo: one launch, many devices, zero reproducibility tax.

Run with forced host devices to see frame sharding on a CPU box:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/sharded_sweep.py

The compiled network's fused sweep is embarrassingly parallel over frames and
its entropy is a pure function of the global (node, frame, word) counter, so
``compile_network(devices=8)`` shards the frame axis with ``shard_map`` and
every shard reproduces exactly the bits the single-device launch would have
produced for its slice -- verified below, then raced.  The FrameDriver's
async mode then pipelines launches: dispatch never waits for device work,
``harvest()`` is the only synchronisation point.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.bayesnet import (
    FrameDriver, by_name, compile_network, sample_evidence,
)

n_dev = len(jax.devices())
print(f"devices: {n_dev} ({jax.default_backend()})")

spec = by_name("obstacle-class")
ev = np.asarray(sample_evidence(spec, jax.random.PRNGKey(1), 2048))
key = jax.random.PRNGKey(0)

# 1. bit-identity: the sharded launch IS the single-device launch -------------
single = compile_network(spec, n_bits=4096)
sharded = compile_network(spec, n_bits=4096, devices=n_dev)
p1, a1 = single.run(key, ev)
pn, an = sharded.run(key, ev)
np.testing.assert_array_equal(np.asarray(p1), np.asarray(pn))
np.testing.assert_array_equal(np.asarray(a1), np.asarray(an))
print(f"1. sharded ({sharded.n_shards} shards) == single-device: "
      f"bit-identical posteriors over {ev.shape[0]} frames")


def bench(net, reps=5):
    jax.block_until_ready(net.run(key, ev))
    best = min(
        (lambda t0: (jax.block_until_ready(net.run(key, ev)),
                     time.perf_counter() - t0)[1])(time.perf_counter())
        for _ in range(reps)
    )
    return ev.shape[0] / best


f1, fn = bench(single), bench(sharded)
print(f"2. throughput: single {f1:,.0f} frames/s, sharded {fn:,.0f} frames/s "
      f"({fn / f1:.2f}x on this host -- approaches {n_dev}x with real cores)")

# 3. the whole sense->classify->act path in the same launch -------------------
post, dec, acc = sharded.decide(key, ev[:4])
classes = ("none", "pedestrian", "vehicle", "cyclist")
qi = sharded.queries.index("obstacle")
print("3. fused decide (posterior + argmax, one launch):")
for i in range(4):
    print(f"   frame {i}: P = {np.round(np.asarray(post)[i, qi], 3)} "
          f"-> {classes[int(np.asarray(dec)[i, qi])]}")

# 4. async driver: pipeline the queue, block once -----------------------------
warm = FrameDriver(sharded, max_batch=512, salt=0)
warm.submit(ev[:512])
warm.drain()                       # compile the 512-lane bucket once, untimed
drv = FrameDriver(sharded, max_batch=512, salt=0)
drv.submit(ev)
t0 = time.perf_counter()
out = drv.drain_async()            # dispatches 4 launches, one harvest
dt = time.perf_counter() - t0
print(f"4. FrameDriver.drain_async: {len(out)} frames through "
      f"{ev.shape[0] // 512} pipelined launches in {dt * 1e3:.1f} ms "
      f"({len(out) / dt:,.0f} frames/s)")
