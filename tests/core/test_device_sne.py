"""Device model (Fig 1/S2/S4) and SNE transfer curves (Fig 2b/2c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitops, device, sne


def test_ou_stationary_stats():
    params = device.DEFAULT_PARAMS
    path = device.sample_ou_path(jax.random.PRNGKey(0), 20000, params)
    x = np.asarray(path)[1000:]
    assert abs(x.mean() - params.vth_mu) < 0.02
    assert abs(x.std() - params.vth_sigma) < 0.03


def test_ou_fit_recovers_params():
    params = device.DEFAULT_PARAMS
    path = np.asarray(device.sample_ou_path(jax.random.PRNGKey(1), 50000, params))
    theta, mu, sigma_w = device.fit_ou(path)
    assert abs(theta - params.ou_theta) < 0.05
    assert abs(mu - params.vth_mu) < 0.02
    assert abs(sigma_w - params.ou_sigma_w) < 0.02


def test_device_to_device_cv():
    mus = np.asarray(device.sample_devices(jax.random.PRNGKey(2), 2000))
    cv = mus.std() / mus.mean()
    assert abs(cv - device.DEFAULT_PARAMS.d2d_cv) < 0.015  # paper: ~8 %


def test_endurance_states_separated():
    hrs, lrs = device.endurance_trace(jax.random.PRNGKey(3), 5000)
    assert float(jnp.min(hrs) / jnp.max(lrs)) > 1e3  # ratio stays large (Fig 1e)


def test_sigmoid_curves_and_inverses():
    v = jnp.linspace(1.0, 3.5, 11)
    p = sne.p_from_vin(v)
    np.testing.assert_allclose(np.asarray(sne.vin_from_p(p)), np.asarray(v), atol=1e-3)
    # paper anchor points: P_unc(2.24) = 0.5
    assert abs(float(sne.p_from_vin(2.24)) - 0.5) < 1e-6
    vr = jnp.linspace(0.2, 1.0, 9)
    pc = sne.p_from_vref(vr)
    np.testing.assert_allclose(np.asarray(sne.vref_from_p(pc)), np.asarray(vr), atol=1e-3)
    assert abs(float(sne.p_from_vref(0.57)) - 0.5) < 1e-6
    # monotonicity: P_unc increases with V_in, P_corr decreases with V_ref (Fig 2b/c)
    assert bool(jnp.all(jnp.diff(p) > 0))
    assert bool(jnp.all(jnp.diff(pc) < 0))


@pytest.mark.parametrize("p", [0.1, 0.5, 0.72, 0.9])
def test_encoders_hit_target_probability(p):
    n = 1 << 14
    est_u = float(
        bitops.decode(sne.encode_uncorrelated(jax.random.PRNGKey(1), p, n), n)
    )
    assert abs(est_u - p) < 0.02


@pytest.mark.parametrize("p", [0.3, 0.6])
def test_device_driven_encoder_statistically_equivalent(p):
    """encode_via_device (OU memristor entropy) matches the PRNG encoder."""
    n = 1 << 13
    est = float(bitops.decode(sne.encode_via_device(jax.random.PRNGKey(4), p, n), n))
    # OU autocorrelation widens the estimator variance; allow 4x tolerance.
    assert abs(est - p) < 0.08


def test_switching_event_probability():
    # V_in at the stationary mean -> switch probability ~0.5
    bits = device.switching_event(jax.random.PRNGKey(5), 2.08, 20000)
    assert abs(float(bits.mean()) - 0.5) < 0.05


def test_latency_model_reproduces_paper_claim():
    from repro.core import latency

    rep = latency.memristor_latency(n_bits=100)
    assert rep.meets_paper_claim()
    assert rep.frame_latency_s == pytest.approx(0.4e-3, rel=1e-6)
    assert rep.fps == pytest.approx(2500.0, rel=1e-6)
    # TPU mapping is orders of magnitude faster per decision
    assert latency.tpu_throughput_model(100) > 1e8
