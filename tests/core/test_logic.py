"""Table S1: all logic x correlation cells, statistical vs analytic."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st  # optional-hypothesis shim (tests/hypcompat.py)

from repro.core import bitops, correlation, logic
from repro.core.logic import Corr

N_BITS = 1 << 14  # 16384 bits -> stochastic std <= 0.5/128 ~ 0.004
TOL = 0.03        # ~7 sigma


PROBS = [(0.2, 0.7), (0.5, 0.5), (0.9, 0.3), (0.05, 0.95)]
MODES = [Corr.UNCORRELATED, Corr.POSITIVE, Corr.NEGATIVE]


@pytest.mark.parametrize("pa,pb", PROBS)
@pytest.mark.parametrize("mode", MODES)
def test_and_all_modes(pa, pb, mode):
    key = jax.random.PRNGKey(hash((pa, pb, mode.value)) % (2**31))
    _, est, _ = logic.prob_and(key, pa, pb, N_BITS, mode)
    expect = float(logic.expected_and(pa, pb, mode))
    assert abs(float(est) - expect) < TOL


@pytest.mark.parametrize("pa,pb", PROBS)
@pytest.mark.parametrize("mode", MODES)
def test_or_all_modes(pa, pb, mode):
    key = jax.random.PRNGKey(hash(("or", pa, pb, mode.value)) % (2**31))
    _, est, _ = logic.prob_or(key, pa, pb, N_BITS, mode)
    assert abs(float(est) - float(logic.expected_or(pa, pb, mode))) < TOL


@pytest.mark.parametrize("pa,pb", PROBS)
@pytest.mark.parametrize("mode", MODES)
def test_xor_all_modes(pa, pb, mode):
    key = jax.random.PRNGKey(hash(("xor", pa, pb, mode.value)) % (2**31))
    _, est, _ = logic.prob_xor(key, pa, pb, N_BITS, mode)
    assert abs(float(est) - float(logic.expected_xor(pa, pb, mode))) < TOL


@pytest.mark.parametrize("ps,pa,pb", [(0.5, 0.2, 0.8), (0.3, 0.9, 0.1), (0.72, 0.57, 0.4)])
@pytest.mark.parametrize("mode_inputs", MODES)
def test_mux_weighted_addition(ps, pa, pb, mode_inputs):
    key = jax.random.PRNGKey(hash(("mux", ps, pa, pb, mode_inputs.value)) % (2**31))
    _, est, _ = logic.prob_mux(key, ps, pa, pb, N_BITS, mode_inputs)
    assert abs(float(est) - float(logic.expected_mux(ps, pa, pb))) < TOL


def test_mux_corrupted_by_correlated_select():
    """Fig S6b counter-example: select positively correlated with input b."""
    from repro.core import sne

    key = jax.random.PRNGKey(42)
    ps, pa, pb = 0.5, 0.1, 0.5
    ka, kc = jax.random.split(key)
    # select shares entropy with b -> corrupted
    both = sne.encode_correlated(kc, jnp.array([ps, pb]), N_BITS)
    s, b = both[0], both[1]
    a = sne.encode_uncorrelated(ka, jnp.float32(pa), N_BITS)
    est = float(bitops.decode(bitops.bmux(s, a, b), N_BITS))
    good = float(logic.expected_mux(ps, pa, pb))
    assert abs(est - good) > 0.1  # visibly corrupted


@given(
    pa=st.floats(0.02, 0.98),
    pb=st.floats(0.02, 0.98),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=20, deadline=None)
def test_property_and_uncorrelated(pa, pb, seed):
    key = jax.random.PRNGKey(seed)
    _, est, (a, b) = logic.prob_and(key, pa, pb, N_BITS, Corr.UNCORRELATED)
    assert abs(float(est) - pa * pb) < 0.05
    # streams decode to their programmed probabilities
    assert abs(float(bitops.decode(a, N_BITS)) - pa) < 0.05
    assert abs(float(bitops.decode(b, N_BITS)) - pb) < 0.05


def test_correlation_modes_measured():
    """Encoded pairs exhibit the designed Pearson/SCC signs (Fig 3c/3d style)."""
    key = jax.random.PRNGKey(7)
    pa, pb = 0.6, 0.6
    a, b = logic.encode_pair(key, pa, pb, N_BITS, Corr.POSITIVE)
    assert float(correlation.scc(a, b, N_BITS)) > 0.9
    a, b = logic.encode_pair(key, pa, pb, N_BITS, Corr.NEGATIVE)
    assert float(correlation.scc(a, b, N_BITS)) < -0.9
    a, b = logic.encode_pair(key, pa, pb, N_BITS, Corr.UNCORRELATED)
    assert abs(float(correlation.pearson(a, b, N_BITS))) < 0.05


def test_mux_tree_mean():
    key = jax.random.PRNGKey(3)
    from repro.core import sne

    ps = jnp.array([0.1, 0.5, 0.9])
    streams = sne.encode_uncorrelated(key, ps, N_BITS)
    out, k_pad = logic.mux_tree(jax.random.PRNGKey(4), streams, N_BITS)
    assert k_pad == 4
    est = float(bitops.decode(out, N_BITS))
    assert abs(est - float(ps.sum()) / 4) < TOL
