"""Device-model calibration surface: the derived read statistics, custom
parameter sets flowing through every simulator entry point, and the
degenerate inputs :func:`repro.core.device.fit_ou` must survive.

Complements test_device_sne.py (which checks DEFAULT_PARAMS statistics);
the crossbar :class:`~repro.bayesnet.noise.NoiseModel` tie itself is pinned
in tests/bayesnet/test_noise.py.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.core import device


def test_reads_per_bit_and_read_cv_formulas():
    p = device.DEFAULT_PARAMS
    assert p.reads_per_bit == pytest.approx(p.t_bit / p.t_switch) == pytest.approx(80.0)
    assert p.read_cv == pytest.approx(
        (p.vth_sigma / p.vth_mu) / np.sqrt(p.reads_per_bit)
    )
    # integration over ~80 cycles attenuates well below the per-cycle CV
    assert p.read_cv < p.vth_sigma / p.vth_mu / 8
    # derived quantities track the base constants
    fast = dataclasses.replace(p, t_bit=p.t_switch)
    assert fast.reads_per_bit == pytest.approx(1.0)
    assert fast.read_cv == pytest.approx(p.vth_sigma / p.vth_mu)


def test_sample_devices_custom_params():
    custom = dataclasses.replace(device.DEFAULT_PARAMS, vth_mu=1.5, d2d_cv=0.2)
    mus = np.asarray(device.sample_devices(jax.random.PRNGKey(0), 4000, custom))
    assert abs(mus.mean() - 1.5) < 0.02
    assert abs(mus.std() / mus.mean() - 0.2) < 0.02


def test_fit_ou_custom_theta_roundtrip():
    custom = dataclasses.replace(device.DEFAULT_PARAMS, ou_theta=0.6)
    path = np.asarray(device.sample_ou_path(jax.random.PRNGKey(1), 50000, custom))
    theta, mu, sigma_w = device.fit_ou(path)
    assert abs(theta - 0.6) < 0.05
    assert abs(mu - custom.vth_mu) < 0.02
    assert abs(sigma_w - custom.ou_sigma_w) < 0.02


def test_fit_ou_random_walk_falls_back_to_sample_mean():
    # theta ~ 0 (pure random walk): the mu = a / theta division is guarded.
    rng = np.random.default_rng(0)
    path = np.cumsum(rng.normal(0.0, 1e-3, 10000)) + 2.0
    theta, mu, sigma_w = device.fit_ou(path)
    assert abs(theta) < 0.05
    assert np.isfinite(mu) and np.isfinite(sigma_w)


def test_endurance_trace_shapes_and_ratio():
    custom = dataclasses.replace(device.DEFAULT_PARAMS, switching_ratio=1e4)
    hrs, lrs = device.endurance_trace(jax.random.PRNGKey(2), 512, custom)
    assert hrs.shape == lrs.shape == (512,)
    assert np.all(np.asarray(lrs) > 0)
    ratio = float(np.asarray(hrs).mean() / np.asarray(lrs).mean())
    assert 3e3 < ratio < 3e4                     # tracks the custom ratio


def test_switching_event_saturates():
    ones = np.asarray(device.switching_event(jax.random.PRNGKey(3), 10.0, 256))
    zeros = np.asarray(device.switching_event(jax.random.PRNGKey(3), 0.0, 256))
    assert ones.dtype == np.uint8 and ones.shape == (256,)
    assert ones.all() and not zeros.any()
