"""Packed-domain fast paths vs their oracles.

* word-parallel CORDIV (`cordiv_fill`) must equal the bit-serial circuit
  (`cordiv_scan`) bit-for-bit -- on the subset-correlated pairs the operators
  produce, and on arbitrary uncorrelated pairs (the fill is exact circuit
  semantics, not an approximation).
* the counter-based SNE must match the float-uniform reference encoder's
  mean and correlation statistics within the O(1/sqrt(n_bits)) band, in all
  correlation modes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitops, cordiv, correlation, logic, rng, sne
from repro.core.logic import Corr


# --- word-parallel CORDIV == serial circuit, bit for bit --------------------------

@pytest.mark.parametrize("n_bits", [32, 100, 128, 129, 1000, 1 << 14])
@pytest.mark.parametrize("shape", [(), (3,), (2, 4)])
def test_cordiv_fill_equals_scan_on_subsets(n_bits, shape):
    key = jax.random.PRNGKey(n_bits * 31 + len(shape))
    k1, k2 = jax.random.split(key)
    d = sne.encode_uncorrelated(k1, jnp.full(shape, 0.7), n_bits)
    n = d & sne.encode_uncorrelated(k2, jnp.full(shape, 0.6), n_bits)
    q_scan, est_scan = cordiv.cordiv_scan(n, d, n_bits)
    q_fill, est_fill = cordiv.cordiv_fill(n, d, n_bits)
    np.testing.assert_array_equal(np.asarray(q_scan), np.asarray(q_fill))
    np.testing.assert_allclose(np.asarray(est_scan), np.asarray(est_fill))


@pytest.mark.parametrize("seed", range(8))
def test_cordiv_fill_equals_scan_on_arbitrary_pairs(seed):
    """The fill is exact D-flip-flop semantics even without subset correlation."""
    n_bits = [96, 100, 512, 1 << 13][seed % 4]
    key = jax.random.PRNGKey(seed)
    k1, k2, kp = jax.random.split(key, 3)
    pa, pb = jax.random.uniform(kp, (2,))
    a = sne.encode_uncorrelated(k1, jnp.full((5,), pa), n_bits)
    b = sne.encode_uncorrelated(k2, jnp.full((5,), pb), n_bits)
    q_scan, _ = cordiv.cordiv_scan(a, b, n_bits)
    q_fill, _ = cordiv.cordiv_fill(a, b, n_bits)
    np.testing.assert_array_equal(np.asarray(q_scan), np.asarray(q_fill))


def test_cordiv_fill_superset_completion_pairs():
    """The make_superset construction (marginal-P(B) inference) stays bit-exact."""
    n_bits = 1 << 12
    key = jax.random.PRNGKey(77)
    k1, k2 = jax.random.split(key)
    n = sne.encode_uncorrelated(k1, 0.3, n_bits)
    d = cordiv.make_superset(k2, n, 0.3, 0.8, n_bits)
    q_scan, _ = cordiv.cordiv_scan(n, d, n_bits)
    q_fill, _ = cordiv.cordiv_fill(n, d, n_bits)
    np.testing.assert_array_equal(np.asarray(q_scan), np.asarray(q_fill))


def test_cordiv_fill_pad_bits_stay_zero():
    n_bits = 100
    d = sne.encode_uncorrelated(jax.random.PRNGKey(1), 0.9, n_bits)
    q, _ = cordiv.cordiv_fill(d, d, n_bits)
    assert int(bitops.popcount(q & ~bitops.pad_mask(n_bits))) == 0


def test_cordiv_fill_empty_inputs_bounded():
    zeros = jnp.zeros((4,), jnp.uint32)
    q, est = cordiv.cordiv_fill(zeros, zeros, 128)
    assert int(bitops.popcount(q)) == 0
    assert float(est) == 0.0


# --- counter-based SNE vs float-uniform reference statistics ----------------------

N = 1 << 14
SIGMA = 0.5 / np.sqrt(N)  # worst-case Bernoulli std at p=0.5


@pytest.mark.parametrize("p", [0.05, 0.3, 0.5, 0.72, 0.95])
def test_counter_sne_mean_matches_float_reference(p):
    k1, k2 = jax.random.split(jax.random.PRNGKey(int(p * 1000)))
    est_ctr = float(bitops.decode(sne.encode_uncorrelated(k1, p, N), N))
    est_flt = float(bitops.decode(sne.encode_float_reference(k2, p, N), N))
    # both unbiased up to the 8-bit DAC quantisation (<= 1/512); 6-sigma band
    assert abs(est_ctr - p) < 1.0 / 512 + 6 * SIGMA
    assert abs(est_ctr - est_flt) < 1.0 / 512 + 8 * SIGMA


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_counter_sne_positive_correlation_stats(seed):
    key = jax.random.PRNGKey(seed)
    pa, pb = 0.6, 0.35
    a, b = logic.encode_pair(key, pa, pb, N, Corr.POSITIVE)
    # Table S1 positive mode: AND -> min, and SCC -> +1
    est_and = float(bitops.decode(a & b, N))
    assert abs(est_and - min(pa, pb)) < 0.02
    assert float(correlation.scc(a, b, N)) > 0.95


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_counter_sne_negative_correlation_stats(seed):
    key = jax.random.PRNGKey(100 + seed)
    pa, pb = 0.6, 0.55
    a, b = logic.encode_pair(key, pa, pb, N, Corr.NEGATIVE)
    # Table S1 negative mode: AND -> max(pa+pb-1, 0), SCC -> -1
    est_and = float(bitops.decode(a & b, N))
    assert abs(est_and - max(pa + pb - 1.0, 0.0)) < 0.02
    assert float(correlation.scc(a, b, N)) < -0.95


def test_counter_sne_uncorrelated_streams_independent():
    k1, k2 = jax.random.split(jax.random.PRNGKey(9))
    a = sne.encode_uncorrelated(k1, 0.5, N)
    b = sne.encode_uncorrelated(k2, 0.5, N)
    assert abs(float(correlation.pearson(a, b, N))) < 6 * SIGMA * 2


def test_counter_sne_entropy_traffic():
    """The packed encoder consumes 8 entropy bits per stream bit (vs 32 float)."""
    assert rng.n_rand_words(128) == 32          # 32 u32 words for 128 stream bits
    assert rng.n_rand_words(100) == 32          # word-padded
    w = rng.random_words(jax.random.PRNGKey(0), (3,), 128)
    assert w.shape == (3, 32) and w.dtype == jnp.uint32


def test_counter_hash_generator_statistics():
    """The lowbias32 counter generator is statistically clean: byte means,
    pairwise stream correlation, and lag-1 autocorrelation all within
    binomial noise at 2^14 bits."""
    n_rand = N // 4
    w = rng.counter_hash_words(jax.random.PRNGKey(3), (8,), n_rand)
    shifts = jnp.arange(4, dtype=jnp.uint32) * 8
    by = (w[..., None] >> shifts) & jnp.uint32(0xFF)
    bits = np.asarray((by < jnp.uint32(128)).astype(jnp.float32).reshape(8, -1))
    assert np.abs(bits.mean(-1) - 0.5).max() < 6 * SIGMA
    c = np.corrcoef(bits)
    np.fill_diagonal(c, 0)
    assert np.abs(c).max() < 6 * SIGMA
    flat = bits.reshape(-1)
    assert abs(np.corrcoef(flat[:-1], flat[1:])[0, 1]) < 6 * 0.5 / np.sqrt(flat.size)


def test_counter_hash_deterministic_and_keyed():
    a = rng.counter_hash_words(jax.random.PRNGKey(1), (4,), 16)
    b = rng.counter_hash_words(jax.random.PRNGKey(1), (4,), 16)
    c = rng.counter_hash_words(jax.random.PRNGKey(2), (4,), 16)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert not np.array_equal(np.asarray(a), np.asarray(c))


def test_threefry_impl_available():
    w = rng.random_words(jax.random.PRNGKey(0), (2,), 128, impl="threefry")
    assert w.shape == (2, 32) and w.dtype == jnp.uint32


def test_counter_iota_matches_flat_arange():
    """Broadcasted-iota counters equal the flat row-major arange, with offset."""
    got = np.asarray(rng.counter_iota((3, 5, 4)))
    np.testing.assert_array_equal(got, np.arange(60, dtype=np.uint32).reshape(3, 5, 4))
    shifted = np.asarray(rng.counter_iota((2, 4), offset=100))
    np.testing.assert_array_equal(shifted, 100 + np.arange(8, dtype=np.uint32).reshape(2, 4))
    # counter_hash_words with offset draws a contiguous slice of the same space
    k = jax.random.PRNGKey(7)
    whole = np.asarray(rng.counter_hash_words(k, (4,), 8)).reshape(-1)
    part = np.asarray(rng.counter_hash_words(k, (2,), 4, offset=8)).reshape(-1)
    np.testing.assert_array_equal(part, whole[8:16])


def test_fair_bits_threefry_end_to_end():
    """fair_bits(impl='threefry') draws exactly jax.random.bits words, so the
    threefry mode is reproducible against other JAX code (it used to fall
    through to the counter-hash generator silently)."""
    k = jax.random.PRNGKey(11)
    got = rng.fair_bits(k, (3,), 128, impl="threefry")
    want = jax.random.bits(k, (3, 4), jnp.uint32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    fast = rng.fair_bits(k, (3,), 128)
    assert not np.array_equal(np.asarray(got), np.asarray(fast))
    # pad bits stay zero through the threefry path too
    s100 = rng.fair_bits(jax.random.PRNGKey(5), (), 100, impl="threefry")
    assert int(bitops.popcount(s100 & ~bitops.pad_mask(100))) == 0


def test_plane_entropy_statistics():
    """The fused sweep's bit-plane generator (shared first round + salted
    second round) yields clean comparator bytes: per-threshold hit rates,
    cross-plane correlation, and adjacent-word correlation all within
    binomial noise."""
    n_words = 1 << 12
    kd = rng.seed_words(jax.random.PRNGKey(21))
    base = rng.plane_base(rng.counter_iota((n_words,)), kd[0])
    planes = np.stack(
        [np.asarray(rng.plane_word(base, kd[1], k)) for k in range(8)]
    )                                                     # (8, n_words) u32
    bits = ((planes[:, :, None] >> np.arange(32)) & 1).reshape(8, -1)
    n = bits.shape[1]
    sig = 0.5 / np.sqrt(n)
    # each plane is a fair coin
    assert np.abs(bits.mean(axis=1) - 0.5).max() < 6 * sig
    # planes are pairwise uncorrelated (byte bits must be jointly uniform)
    c = np.corrcoef(bits)
    np.fill_diagonal(c, 0)
    assert np.abs(c).max() < 6 / np.sqrt(n)
    # reconstructed bytes hit Bernoulli(t / 256) across the threshold range
    byte = np.zeros(n, np.uint32)
    for k in range(8):
        byte |= bits[k].astype(np.uint32) << k
    for t in (1, 37, 128, 200, 255):
        p = byte < t
        assert abs(p.mean() - t / 256) < 6 * np.sqrt(t / 256 * (1 - t / 256) / n)
    # lag-1 autocorrelation along the stream
    flat = (byte < 128).astype(np.float64)
    assert abs(np.corrcoef(flat[:-1], flat[1:])[0, 1]) < 6 / np.sqrt(n)


def test_fair_bits_is_half():
    s = rng.fair_bits(jax.random.PRNGKey(4), (), N)
    assert abs(float(bitops.decode(s, N)) - 0.5) < 6 * SIGMA
    # pad bits zero on non-aligned lengths
    s100 = rng.fair_bits(jax.random.PRNGKey(5), (), 100)
    assert int(bitops.popcount(s100 & ~bitops.pad_mask(100))) == 0
