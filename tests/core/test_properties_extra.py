"""Extra algebraic property tests on packed stochastic numbers (hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypcompat import given, settings, st  # optional-hypothesis shim (tests/hypcompat.py)

from repro.core import bitops, sne
from repro.core.fusion import fuse_analytic

N = 1 << 12


@given(seed=st.integers(0, 2**31 - 1), p=st.floats(0.05, 0.95), q=st.floats(0.05, 0.95))
@settings(max_examples=15, deadline=None)
def test_de_morgan_on_streams(seed, p, q):
    """NOT(a AND b) == NOT(a) OR NOT(b) bitwise on packed streams."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    a = sne.encode_uncorrelated(k1, p, N)
    b = sne.encode_uncorrelated(k2, q, N)
    lhs = bitops.bnot(bitops.band(a, b), N)
    rhs = bitops.bor(bitops.bnot(a, N), bitops.bnot(b, N))
    np.testing.assert_array_equal(np.asarray(lhs), np.asarray(rhs))


@given(seed=st.integers(0, 2**31 - 1), p=st.floats(0.02, 0.98))
@settings(max_examples=15, deadline=None)
def test_xor_with_self_and_complement(seed, p):
    """a XOR a == 0; a XOR NOT(a) == all ones (on valid bits)."""
    a = sne.encode_uncorrelated(jax.random.PRNGKey(seed), p, N)
    assert int(bitops.popcount(bitops.bxor(a, a))) == 0
    x = bitops.bxor(a, bitops.bnot(a, N))
    assert int(bitops.popcount(x)) == N


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_mux_select_partition(seed):
    """MUX output bits partition between inputs: popcounts add up exactly."""
    ks, ka, kb = jax.random.split(jax.random.PRNGKey(seed), 3)
    s = sne.encode_uncorrelated(ks, 0.5, N)
    a = sne.encode_uncorrelated(ka, 0.7, N)
    b = sne.encode_uncorrelated(kb, 0.3, N)
    out = bitops.bmux(s, a, b)
    take_b = bitops.popcount(s & b)
    take_a = bitops.popcount(bitops.bnot(s, N) & a)
    assert int(bitops.popcount(out)) == int(take_a) + int(take_b)


@given(
    seed=st.integers(0, 2**31 - 1),
    m=st.integers(2, 4),
    k=st.integers(2, 6),
)
@settings(max_examples=15, deadline=None)
def test_fusion_analytic_invariants(seed, m, k):
    """eq (5): permutation-equivariant over modalities; sharper than any input
    on the argmax class when all modalities agree."""
    key = jax.random.PRNGKey(seed)
    p = jax.nn.softmax(jax.random.normal(key, (m, k)), -1)
    out = fuse_analytic(p)                                # (m, k) -> (k,)
    out_perm = fuse_analytic(p[::-1])
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_perm), rtol=1e-5)
    assert abs(float(out.sum()) - 1.0) < 1e-5
    # agreement sharpening: fuse identical posteriors -> argmax prob increases
    same = jnp.stack([p[0]] * m)
    fused_same = fuse_analytic(same)
    assert float(fused_same.max()) >= float(p[0].max()) - 1e-6


def test_cordiv_range_bounded():
    """CORDIV estimates stay in [0, 1] even on adversarial (empty) inputs."""
    from repro.core import cordiv

    zeros = jnp.zeros((4,), jnp.uint32)
    est = cordiv.cordiv_ratio(zeros, zeros)
    assert float(est) == 0.0
    _, est_scan = cordiv.cordiv_scan(zeros, zeros, 128)
    assert 0.0 <= float(est_scan) <= 1.0
