"""Bayesian inference & fusion operators vs the paper's equations."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st  # optional-hypothesis shim (tests/hypcompat.py)

from repro.core import bitops, cordiv, correlation, fusion, graph, inference

N_BITS = 1 << 14
TOL = 0.03


def test_cordiv_scan_equals_ratio_on_subset():
    key = jax.random.PRNGKey(0)
    from repro.core import sne

    kd, extra = jax.random.split(key)
    d = sne.encode_uncorrelated(kd, 0.7, N_BITS)
    n = d & sne.encode_uncorrelated(extra, 0.6, N_BITS)  # n subset of d
    _, est_scan = cordiv.cordiv_scan(n, d, N_BITS)
    est_ratio = cordiv.cordiv_ratio(n, d)
    assert abs(float(est_scan) - float(est_ratio)) < TOL
    assert abs(float(est_ratio) - 0.6) < TOL


def test_make_superset():
    key = jax.random.PRNGKey(5)
    from repro.core import sne

    k1, k2 = jax.random.split(key)
    n = sne.encode_uncorrelated(k1, 0.3, N_BITS)
    d = cordiv.make_superset(k2, n, 0.3, 0.8, N_BITS)
    assert int(bitops.popcount(n & ~d)) == 0  # subset holds bitwise
    assert abs(float(bitops.decode(d, N_BITS)) - 0.8) < TOL


@pytest.mark.parametrize(
    "pa,pba,pbn",
    [(0.57, 0.72, 0.6), (0.2, 0.9, 0.1), (0.8, 0.5, 0.5), (0.5, 0.99, 0.01)],
)
def test_inference_operator_matches_eq1(pa, pba, pbn):
    key = jax.random.PRNGKey(hash((pa, pba, pbn)) % (2**31))
    tr = inference.bayes_inference(key, pa, pba, pbn, n_bits=N_BITS)
    expect = float(inference.analytic_posterior(pa, pba, pbn))
    assert abs(float(tr.posterior_ratio) - expect) < TOL
    assert abs(float(tr.posterior_scan) - expect) < 2 * TOL
    # numerator is a bitwise subset of the denominator (CORDIV requirement)
    assert int(bitops.popcount(tr.streams["numer"] & ~tr.streams["denom"])) == 0


def test_route_planning_case_paper_band():
    """Fig 3b: P(A)=57%, evidence ~72% -> posterior in the paper's 61-63% band."""
    key = jax.random.PRNGKey(2024)
    tr = inference.bayes_inference(key, 0.57, 0.72, 0.6, n_bits=N_BITS)
    assert 0.58 < float(tr.posterior_ratio) < 0.66
    assert float(tr.posterior_ratio) > 0.57  # belief increased -> cut in


def test_inference_marginal_variant():
    key = jax.random.PRNGKey(11)
    tr = inference.bayes_inference_marginal(key, 0.57, 0.78, 0.72, n_bits=N_BITS)
    expect = 0.57 * 0.78 / 0.72
    assert abs(float(tr.posterior_ratio) - expect) < TOL


def test_operator_correlation_design():
    """Fig 3c/3d: the SNE streams feeding AND/MUX are mutually uncorrelated."""
    key = jax.random.PRNGKey(9)
    tr = inference.bayes_inference(key, 0.57, 0.72, 0.6, n_bits=N_BITS)
    s = tr.streams
    for x, y in [("A", "B|A"), ("A", "B|!A"), ("B|A", "B|!A")]:
        assert abs(float(correlation.pearson(s[x], s[y], N_BITS))) < 0.05
    # numerator strongly positively correlated with denominator (shared SNEs)
    assert float(correlation.scc(s["numer"], s["denom"], N_BITS)) > 0.9


@given(
    pa1=st.floats(0.05, 0.95),
    pa2=st.floats(0.05, 0.95),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=10, deadline=None)
def test_two_parent_property(pa1, pa2, seed):
    cpt = jnp.array([[0.1, 0.4], [0.6, 0.9]])
    post_scan, post_ratio, analytic = graph.two_parent_one_child(
        jax.random.PRNGKey(seed), pa1, pa2, cpt, n_bits=N_BITS
    )
    assert abs(float(post_ratio) - float(analytic)) < 0.06
    assert 0.0 <= float(post_ratio) <= 1.0


def test_one_parent_two_child():
    post_scan, post_ratio, analytic = graph.one_parent_two_child(
        jax.random.PRNGKey(1), 0.5, (0.9, 0.2), (0.8, 0.3), n_bits=N_BITS
    )
    assert abs(float(post_ratio) - float(analytic)) < TOL
    assert abs(float(post_scan) - float(analytic)) < 2 * TOL


# ---- fusion ----------------------------------------------------------------------

def test_fusion_matches_eq5_binary():
    key = jax.random.PRNGKey(3)
    p_modal = jnp.array([[0.8, 0.2], [0.7, 0.3]])  # (M=2, K=2)
    tr = fusion.bayes_fusion(key, p_modal, n_bits=N_BITS)
    np.testing.assert_allclose(
        np.asarray(tr.fused_ratio), np.asarray(tr.fused_analytic), atol=0.04
    )
    np.testing.assert_allclose(
        np.asarray(tr.fused_scan), np.asarray(tr.fused_analytic), atol=0.08
    )


@given(
    m=st.integers(2, 4),
    k=st.integers(2, 5),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=10, deadline=None)
def test_fusion_property(m, k, seed):
    key = jax.random.PRNGKey(seed)
    kp, kf = jax.random.split(key)
    logits = jax.random.normal(kp, (m, k))
    p_modal = jax.nn.softmax(logits, axis=-1) * 0.9 + 0.05  # keep away from 0/1
    p_modal = p_modal / p_modal.sum(-1, keepdims=True)
    tr = fusion.bayes_fusion(kf, p_modal, n_bits=N_BITS)
    # normalized outputs sum to 1 and match eq (5); the AND-count estimator
    # variance grows with M (products of M probabilities get tiny), so the
    # stochastic tolerance scales with the modality count
    assert abs(float(tr.fused_ratio.sum()) - 1.0) < 1e-5
    np.testing.assert_allclose(
        np.asarray(tr.fused_ratio), np.asarray(tr.fused_analytic), atol=0.04 * m
    )


def test_fusion_recovers_missed_target():
    """Fig 4b behaviour: one weak + one confident modality -> confident fusion."""
    key = jax.random.PRNGKey(8)
    fused = fusion.detection_fusion(key, jnp.array([0.55, 0.95]), n_bits=N_BITS)
    assert float(fused) > 0.9  # more confident than either alone... (0.95 check below)
    analytic = fusion.fuse_analytic(
        jnp.array([[0.55, 0.45], [0.95, 0.05]])
    )[0]
    assert abs(float(fused) - float(analytic)) < 0.05


def test_fusion_m_greater_than_2():
    p_modal = jnp.array([[0.7, 0.3], [0.8, 0.2], [0.6, 0.4]])
    out = fusion.fuse_analytic(p_modal)
    # eq (5): q_c  prop  prod p_ic / prior^(M-1)
    q = np.prod(np.asarray(p_modal), axis=0) / (0.5 ** 2)
    np.testing.assert_allclose(np.asarray(out), q / q.sum(), rtol=1e-5)
