import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st  # optional-hypothesis shim (tests/hypcompat.py)

from repro.core import bitops


@pytest.mark.parametrize("n", [1, 31, 32, 33, 100, 256, 1000])
def test_pack_unpack_roundtrip(n):
    key = jax.random.PRNGKey(n)
    bits = jax.random.bernoulli(key, 0.5, (3, n)).astype(jnp.uint8)
    words = bitops.pack_bits(bits)
    assert words.shape == (3, bitops.n_words(n))
    out = bitops.unpack_bits(words, n)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(bits))


@pytest.mark.parametrize("n", [1, 32, 100, 513])
def test_popcount_matches_sum(n):
    key = jax.random.PRNGKey(n + 7)
    bits = jax.random.bernoulli(key, 0.3, (5, n)).astype(jnp.uint8)
    words = bitops.pack_bits(bits)
    np.testing.assert_array_equal(
        np.asarray(bitops.popcount(words)), np.asarray(bits.sum(-1, dtype=jnp.int32))
    )


def test_decode_range():
    words = bitops.pack_bits(jnp.ones((100,), jnp.uint8))
    assert float(bitops.decode(words, 100)) == 1.0
    words0 = bitops.pack_bits(jnp.zeros((100,), jnp.uint8))
    assert float(bitops.decode(words0, 100)) == 0.0


@given(n=st.integers(1, 300), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_bnot_property(n, seed):
    key = jax.random.PRNGKey(seed)
    bits = jax.random.bernoulli(key, 0.5, (n,)).astype(jnp.uint8)
    w = bitops.pack_bits(bits)
    nw = bitops.bnot(w, n)
    # NOT flips exactly the valid bits, padding stays zero.
    assert int(bitops.popcount(nw)) == n - int(bitops.popcount(w))
    np.testing.assert_array_equal(
        np.asarray(bitops.unpack_bits(nw, n)), 1 - np.asarray(bits)
    )


def test_mux_bit_semantics():
    n = 64
    key = jax.random.PRNGKey(0)
    ks, ka, kb = jax.random.split(key, 3)
    s = jax.random.bernoulli(ks, 0.5, (n,)).astype(jnp.uint8)
    a = jax.random.bernoulli(ka, 0.5, (n,)).astype(jnp.uint8)
    b = jax.random.bernoulli(kb, 0.5, (n,)).astype(jnp.uint8)
    out = bitops.bmux(bitops.pack_bits(s), bitops.pack_bits(a), bitops.pack_bits(b))
    expect = np.where(np.asarray(s) == 1, np.asarray(b), np.asarray(a))
    np.testing.assert_array_equal(np.asarray(bitops.unpack_bits(out, n)), expect)
