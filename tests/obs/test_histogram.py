"""LatencyHistogram + percentile: exactness, binning, and fallback honesty."""

import math

import numpy as np
import pytest

from repro.obs.histogram import PAPER_BUDGET_MS, LatencyHistogram, percentile


class TestPercentile:
    def test_matches_numpy_linear_exactly(self):
        rng = np.random.default_rng(0)
        for n in (1, 2, 3, 7, 100, 1001):
            xs = rng.lognormal(mean=-1.0, sigma=2.0, size=n).tolist()
            for q in (0.0, 1.0, 25.0, 50.0, 90.0, 99.0, 99.9, 100.0):
                # same formula as numpy's linear method; tolerance covers
                # a 1-ulp difference in floating-point evaluation order
                assert percentile(xs, q) == pytest.approx(
                    float(np.percentile(xs, q)), rel=1e-14
                ), (n, q)

    def test_order_independent(self):
        xs = [5.0, 1.0, 3.0, 2.0, 4.0]
        assert percentile(xs, 50) == 3.0
        assert percentile(sorted(xs, reverse=True), 50) == 3.0

    def test_rejects_bad_inputs(self):
        with pytest.raises(ValueError, match="no samples"):
            percentile([], 50)
        with pytest.raises(ValueError, match=r"\[0, 100\]"):
            percentile([1.0], 101)


class TestHistogram:
    def test_percentiles_exact_while_retained(self):
        h = LatencyHistogram()
        rng = np.random.default_rng(1)
        xs = rng.lognormal(mean=0.0, sigma=1.5, size=500)
        for x in xs:
            h.observe(float(x))
        assert h.exact
        for q in (50, 90, 99):
            assert h.percentile(q) == pytest.approx(float(np.percentile(xs, q)))
        assert h.n == 500
        assert h.min_ms == pytest.approx(xs.min())
        assert h.max_ms == pytest.approx(xs.max())
        assert h.mean_ms == pytest.approx(xs.mean())

    def test_observe_many_equals_observe_loop(self):
        rng = np.random.default_rng(2)
        xs = rng.lognormal(size=300).tolist()
        a, b = LatencyHistogram(budget_ms=1.0), LatencyHistogram(budget_ms=1.0)
        for x in xs:
            a.observe(x)
        b.observe_many(xs)
        assert a.counts == b.counts
        assert a.n == b.n
        assert a.under_budget == b.under_budget
        assert a.total_ms == pytest.approx(b.total_ms)
        assert a.p99 == pytest.approx(b.p99)
        assert a.rows() == b.rows()

    def test_bin_fallback_is_flagged_and_bounded(self):
        # past the retention cap percentiles degrade to bin interpolation:
        # still monotone and inside [min, max], and `exact` says so
        h = LatencyHistogram(max_samples=10)
        rng = np.random.default_rng(3)
        xs = rng.lognormal(sigma=2.0, size=1000)
        h.observe_many(xs.tolist())
        assert not h.exact
        last = -math.inf
        for q in (0, 10, 50, 90, 99, 100):
            p = h.percentile(q)
            assert h.min_ms <= p <= h.max_ms
            assert p >= last
            last = p
        # coarse agreement with the true percentiles (log bins, 8/decade)
        assert h.p50 == pytest.approx(float(np.percentile(xs, 50)), rel=0.5)

    def test_under_and_overflow_bins(self):
        h = LatencyHistogram(lo_ms=1.0, hi_ms=100.0, bins_per_decade=2)
        h.observe(0.01)    # underflow
        h.observe(5000.0)  # overflow
        rows = h.rows()
        assert rows[0][0] == 0.0 and rows[0][2] == 1
        assert rows[-1][1] == math.inf and rows[-1][2] == 1
        assert sum(c for _, _, c in rows) == h.n == 2

    def test_budget_annotation(self):
        h = LatencyHistogram(budget_ms=PAPER_BUDGET_MS)
        h.observe_many([0.1, 0.2, 0.3, 0.9])
        assert h.budget_fraction() == pytest.approx(0.75)
        s = h.summary()
        assert s["budget_ms"] == PAPER_BUDGET_MS
        assert s["budget_fraction"] == pytest.approx(0.75)
        assert s["exact"] is True

    def test_no_budget_means_nan_fraction(self):
        h = LatencyHistogram()
        h.observe(1.0)
        assert math.isnan(h.budget_fraction())
        assert "budget_ms" not in h.summary()

    def test_empty_histogram_raises_on_percentile(self):
        with pytest.raises(ValueError, match="empty"):
            LatencyHistogram().percentile(50)

    def test_rejects_bad_config(self):
        with pytest.raises(ValueError):
            LatencyHistogram(lo_ms=10.0, hi_ms=1.0)
        with pytest.raises(ValueError):
            LatencyHistogram(bins_per_decade=0)
