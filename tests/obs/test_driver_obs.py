"""Instrumented FrameDriver / compile_network: bit-identity, span structure,
counters, retry-span parentage, and the <=5% overhead bound.

The telemetry contract is "gated, not assumed": a traced driver must run the
exact same jax computation as an untraced one (tracing never touches keys or
entropy), and the host-side bookkeeping it adds must stay within noise of a
launch.  Both properties are regression-tested here rather than trusted.
"""

import time

import jax
import numpy as np
import pytest

from repro.bayesnet import SCENARIOS, by_name, compile_network, sample_evidence
from repro.bayesnet.driver import FrameDriver
from repro.bayesnet.reliability import RetryPolicy
from repro.obs import MetricsRegistry, Tracer

N_BITS = 256
N_FRAMES = 8


def _drivers(net, trace=None, **kw):
    """Same (base_key, salt) with and without telemetry."""
    return (
        FrameDriver(net, salt=7, **kw),
        FrameDriver(net, salt=7, trace=trace or Tracer(),
                    metrics=MetricsRegistry(), **kw),
    )


@pytest.fixture(scope="module")
def pn_net():
    return compile_network(by_name("pedestrian-night"), n_bits=N_BITS)


@pytest.fixture(scope="module")
def pn_net_lowbit():
    # 32-bit streams: decision margins stay small, so Phi(z) confidence never
    # saturates to float 1.0 and min_confidence=1.0 retries every frame
    return compile_network(by_name("pedestrian-night"), n_bits=32)


@pytest.fixture(scope="module")
def pn_ev():
    spec = by_name("pedestrian-night")
    return np.asarray(sample_evidence(spec, jax.random.PRNGKey(1), N_FRAMES))


class TestBitIdentity:
    @pytest.mark.parametrize("name", sorted(SCENARIOS))
    def test_traced_equals_untraced_every_scenario(self, name):
        spec = by_name(name)
        net = compile_network(spec, n_bits=N_BITS)
        ev = sample_evidence(spec, jax.random.PRNGKey(1), N_FRAMES)
        plain, traced = _drivers(net, max_batch=4)
        plain.submit(ev)
        traced.submit(ev)
        a, b = plain.drain(), traced.drain()
        assert a.keys() == b.keys()
        for rid in a:
            np.testing.assert_array_equal(a[rid][0], b[rid][0])
            assert a[rid][1] == b[rid][1]

    def test_traced_async_equals_untraced_sync(self, pn_net, pn_ev):
        plain, traced = _drivers(pn_net, max_batch=4)
        plain.submit(pn_ev)
        traced.submit(pn_ev)
        a, b = plain.drain(), traced.drain_async()
        for rid in a:
            np.testing.assert_array_equal(a[rid][0], b[rid][0])


class TestSpanStructure:
    def test_launch_span_tree(self, pn_net, pn_ev):
        tr = Tracer()
        drv = FrameDriver(pn_net, max_batch=8, salt=0, trace=tr)
        drv.submit(pn_ev)
        drv.drain()
        (launch,) = tr.named("launch[")
        children = {s.name for s in tr.spans if s.parent_id == launch.span_id}
        assert children == {"pack", "dispatch", "device", "harvest"}
        assert all(s.done for s in tr.spans)
        # the device span closes inside harvest: completion was only observed
        # when the host blocked on the arrays
        dev = tr.named("device")[0]
        harvest = tr.named("harvest")[0]
        assert harvest.t_start <= dev.t_end <= harvest.t_end

    def test_async_device_spans_overlap(self, pn_net, pn_ev):
        tr = Tracer()
        drv = FrameDriver(pn_net, max_batch=2, salt=0, trace=tr)
        drv.submit(pn_ev)  # 8 frames / 2 lanes = 4 pipelined launches
        drv.drain_async()
        devs = tr.named("device")
        assert len(devs) == 4
        # every dispatch happened before the first harvest blocked: all
        # device spans were open simultaneously at some point
        assert max(d.t_start for d in devs) < min(d.t_end for d in devs)

    def test_sync_and_async_traverse_the_same_spans(self, pn_net, pn_ev):
        tra, trb = Tracer(), Tracer()
        da = FrameDriver(pn_net, max_batch=4, salt=3, trace=tra)
        db = FrameDriver(pn_net, max_batch=4, salt=3, trace=trb)
        da.submit(pn_ev)
        db.submit(pn_ev)
        da.drain()
        db.drain_async()
        # same workload, same launches -- only the wall-clock schedule
        # differs; "step" counts differ structurally (async re-steps an
        # empty queue while draining in-flight work)
        ca, cb = tra.span_counts(), trb.span_counts()
        ca.pop("step"), cb.pop("step")
        assert ca == cb

    def test_retry_span_nests_under_flagging_launch(self, pn_net_lowbit, pn_ev):
        tr = Tracer()
        # at 32 bits confidence can't reach 1.0, so min_confidence=1.0
        # retries every frame until the budget is spent
        drv = FrameDriver(
            pn_net_lowbit, max_batch=8, salt=0, trace=tr,
            retry=RetryPolicy(min_confidence=1.0, max_retries=1),
        )
        drv.submit(pn_ev)
        out = drv.drain()
        assert len(out) == N_FRAMES
        retries = tr.named("retry[")
        assert len(retries) == N_FRAMES
        launch0 = tr.named("launch[0]")[0]
        for sp in retries:
            assert sp.parent_id == launch0.span_id  # flagged by launch 0
            assert sp.done and sp.attrs["attempt"] == 1
            assert 0.0 <= sp.attrs["confidence"] < 1.0


class TestDriverMetrics:
    def test_counters_and_hists(self, pn_net, pn_ev):
        mx = MetricsRegistry()
        drv = FrameDriver(pn_net, max_batch=4, salt=0, trace=Tracer(), metrics=mx)
        drv.submit(pn_ev[:6])  # launches of bucket 4 and 2, one padded lane
        drv.drain()
        assert mx.count("frames_in") == 6
        assert mx.count("frames_out") == 6
        assert mx.count("launches") == 2
        assert mx.count("bucket_4") == 1
        assert mx.count("bucket_2") == 1
        assert mx.count("padded_lanes") == 0
        n_nodes = pn_net.spec.n_nodes
        assert mx.count("entropy_words") == (4 + 2) * (N_BITS // 32) * n_nodes
        assert mx.gauges["pending"] == 0
        assert mx.hist("frame_ms").n == 6
        assert mx.hist("launch_ms").n == 2
        assert mx.hist("frame_ms").budget_ms == 0.4
        # the launch watchdog routed through the same registry
        assert mx.count("watch_steps") == 2
        assert mx.hist("watch_step_ms").n == 2

    def test_padded_lanes_counted(self, pn_net, pn_ev):
        mx = MetricsRegistry()
        drv = FrameDriver(pn_net, max_batch=8, salt=0, trace=Tracer(), metrics=mx)
        drv.submit(pn_ev[:5])  # bucket 8, 3 padded lanes
        drv.drain()
        assert mx.count("bucket_8") == 1
        assert mx.count("padded_lanes") == 3

    def test_retry_and_unreliable_counters(self, pn_net_lowbit, pn_ev):
        mx = MetricsRegistry()
        drv = FrameDriver(
            pn_net_lowbit, max_batch=8, salt=0, trace=Tracer(), metrics=mx,
            retry=RetryPolicy(min_confidence=1.0, max_retries=1),
        )
        drv.submit(pn_ev)
        drv.drain()
        assert mx.count("retry_attempt_1") == N_FRAMES
        assert mx.count("retry_launches_attempt_1") == 1
        assert mx.count("flagged_unreliable") == N_FRAMES
        # escalated program compiled once (miss), no rebuild on reuse
        assert mx.count("plan_cache_misses") == 1

    def test_trace_implies_metrics(self, pn_net):
        drv = FrameDriver(pn_net, trace=Tracer())
        assert drv.metrics is not None

    def test_untraced_driver_has_no_registry(self, pn_net):
        drv = FrameDriver(pn_net)
        assert drv.trace is None and drv.metrics is None


class TestCompileTracing:
    def test_compile_span_carries_plan_stats(self):
        tr = Tracer()
        net = compile_network(by_name("pedestrian-night"), n_bits=N_BITS, trace=tr)
        (sp,) = tr.named("compile_network")
        assert sp.done and sp.attrs["network"] == "pedestrian-night"
        assert sp.attrs["n_nodes"] == net.spec.n_nodes
        assert sp.attrs["n_bits"] == N_BITS
        assert sp.attrs["cpt_rows"] > 0
        assert sp.attrs["threshold_mask_bytes"] > 0
        assert sp.attrs["n_value_slots"] == len(net.queries)  # binary queries

    def test_trace_none_is_default(self):
        net = compile_network(by_name("pedestrian-night"), n_bits=N_BITS)
        assert net is not None  # no tracer anywhere in the default path


class TestOverhead:
    def test_tracing_overhead_within_five_percent(self):
        # Interleaved min-of-N: each rep times the traced and untraced drain
        # back-to-back so both sides see the same interference, and the min
        # over reps estimates machine capability, not scheduler luck.  The
        # workload is one production-shaped launch (the driver's default
        # max_batch, ~6ms of device work): the obs bill is ~10 spans plus a
        # per-frame stamp/observe, and it must stay within 5% of the launch
        # -- the bound the docs promise.  Best-of-3 rounds with GC paused:
        # the true bill sits near 4% here, and a single round can still be
        # poisoned by a multi-ms scheduler stall on a 2-vCPU container.
        import gc

        spec = by_name("pedestrian-night")
        net = compile_network(spec, n_bits=16384)
        ev = np.asarray(sample_evidence(spec, jax.random.PRNGKey(1), 256))

        def run_once(trace, metrics):
            drv = FrameDriver(net, max_batch=256, salt=11,
                              trace=trace, metrics=metrics)
            drv.submit(ev)
            t0 = time.perf_counter()
            drv.drain()
            return time.perf_counter() - t0

        run_once(None, None)  # warm the bucket compile cache
        ratios = []
        gc.disable()
        try:
            for _ in range(3):
                plain, traced = [], []
                for _ in range(20):
                    plain.append(run_once(None, None))
                    traced.append(run_once(Tracer(), MetricsRegistry()))
                ratios.append(min(traced) / min(plain))
                if ratios[-1] <= 1.05:
                    break
        finally:
            gc.enable()
        assert min(ratios) <= 1.05, (
            f"tracing overhead {min(ratios):.3f}x exceeds 1.05x "
            f"(rounds: {[f'{r:.3f}' for r in ratios]})"
        )
