"""Tracer: nesting, async span lifecycle, and Chrome-trace export schema."""

import json

import numpy as np
import pytest

from repro.obs import Tracer


def fake_clock():
    """Deterministic monotone clock: 1ms per reading."""
    t = [0.0]

    def clock():
        t[0] += 1e-3
        return t[0]

    return clock


class TestSyncSpans:
    def test_nesting_follows_the_with_stack(self):
        tr = Tracer(clock=fake_clock())
        with tr.span("outer"):
            with tr.span("mid"):
                with tr.span("inner"):
                    pass
            with tr.span("mid2"):
                pass
        outer, mid, inner, mid2 = tr.spans
        assert outer.parent_id is None
        assert mid.parent_id == outer.span_id
        assert inner.parent_id == mid.span_id
        assert mid2.parent_id == outer.span_id
        assert all(s.done for s in tr.spans)
        # children are contained in their parent's interval
        assert outer.t_start < mid.t_start and mid.t_end < outer.t_end

    def test_span_attrs_and_duration(self):
        tr = Tracer(clock=fake_clock())
        with tr.span("work", n=3) as sp:
            sp.attrs["extra"] = "late"
        assert sp.attrs == {"n": 3, "extra": "late"}
        assert sp.dur_ms == pytest.approx(1.0)

    def test_span_closed_even_on_exception(self):
        tr = Tracer()
        with pytest.raises(RuntimeError):
            with tr.span("boom"):
                raise RuntimeError("x")
        assert tr.spans[0].done
        assert tr.open_spans == []


class TestAsyncSpans:
    def test_begin_end_lifecycle(self):
        tr = Tracer(clock=fake_clock())
        sid = tr.begin("device", parent=None, track="device", ticket=0)
        assert not tr.get(sid).done
        assert tr.open_spans == [tr.get(sid)]
        sp = tr.end(sid, ok=True)
        assert sp.done and sp.attrs == {"ticket": 0, "ok": True}

    def test_double_end_raises(self):
        tr = Tracer()
        sid = tr.begin("x", parent=None)
        tr.end(sid)
        with pytest.raises(ValueError, match="already ended"):
            tr.end(sid)

    def test_async_span_defaults_to_enclosing_sync_parent(self):
        tr = Tracer()
        with tr.span("step") as step:
            sid = tr.begin("launch")
        assert tr.get(sid).parent_id == step.span_id

    def test_overlapping_async_spans_coexist(self):
        # the dispatch/harvest split: N launches open before any closes
        tr = Tracer(clock=fake_clock())
        sids = [tr.begin(f"device[{i}]", parent=None, track="device")
                for i in range(3)]
        assert len(tr.open_spans) == 3
        for sid in sids:
            tr.end(sid)
        starts = [tr.get(s).t_start for s in sids]
        ends = [tr.get(s).t_end for s in sids]
        assert max(starts) < min(ends)  # genuinely overlapping intervals

    def test_instant_event(self):
        tr = Tracer()
        sid = tr.event("submit", n=4)
        sp = tr.get(sid)
        assert sp.instant and sp.done and sp.t_start == sp.t_end

    def test_named_and_counts(self):
        tr = Tracer()
        with tr.span("launch[0]"):
            pass
        with tr.span("launch[1]"):
            pass
        tr.event("submit")
        assert [s.name for s in tr.named("launch")] == ["launch[0]", "launch[1]"]
        assert tr.span_counts() == {"launch[0]": 1, "launch[1]": 1, "submit": 1}


class TestChromeTraceExport:
    def _trace(self):
        tr = Tracer(clock=fake_clock())
        with tr.span("step", block=False):
            with tr.span("dispatch", bucket=np.int64(8)):
                pass
            tr.begin("device", track="device", shape=(8, 3))
        tr.event("submit", n=2)
        return tr

    def test_schema_is_valid_chrome_trace(self, tmp_path):
        tr = self._trace()
        path = tr.export_chrome_trace(str(tmp_path / "trace.json"))
        with open(path) as f:
            doc = json.load(f)  # must round-trip as strict JSON
        assert doc["displayTimeUnit"] == "ms"
        events = doc["traceEvents"]
        metas = [e for e in events if e["ph"] == "M"]
        spans = [e for e in events if e["ph"] == "X"]
        instants = [e for e in events if e["ph"] == "i"]
        assert {m["args"]["name"] for m in metas} == {"host", "device"}
        assert all(m["name"] == "thread_name" for m in metas)
        # metadata events precede payload events
        assert events[: len(metas)] == metas
        assert len(spans) == 3 and len(instants) == 1
        for e in spans:
            assert e["ts"] >= 0 and e["dur"] >= 0
            assert isinstance(e["args"]["span_id"], int)
        # numpy attrs were coerced to plain JSON types
        disp = next(e for e in spans if e["name"] == "dispatch")
        assert disp["args"]["bucket"] == 8
        dev = next(e for e in spans if e["name"] == "device")
        assert dev["args"]["shape"] == [8, 3]

    def test_parent_ids_survive_export(self):
        tr = self._trace()
        events = tr.to_chrome_trace()["traceEvents"]
        by_name = {e["name"]: e for e in events if e["ph"] != "M"}
        step_id = by_name["step"]["args"]["span_id"]
        assert by_name["dispatch"]["args"]["parent_id"] == step_id
        assert by_name["device"]["args"]["parent_id"] == step_id
        assert "parent_id" not in by_name["step"]["args"]

    def test_unfinished_spans_export_flagged_not_dropped(self):
        tr = self._trace()  # the "device" span is still open
        events = tr.to_chrome_trace()["traceEvents"]
        dev = next(e for e in events if e.get("name") == "device")
        assert dev["args"]["unfinished"] is True
        assert dev["dur"] == 0.0

    def test_timestamps_relative_to_first_span(self):
        tr = self._trace()
        events = [e for e in tr.to_chrome_trace()["traceEvents"] if e["ph"] != "M"]
        assert min(e["ts"] for e in events) == 0.0
