"""StragglerWatch telemetry: the un-started-watch fix + metrics routing."""

import pytest

from repro.distributed.fault import StragglerWatch
from repro.obs import MetricsRegistry


class TestUnstartedWatch:
    def test_step_end_without_start_raises(self):
        # previously this measured `now - now`, silently reported 0.0, and
        # poisoned the EWMA toward zero -- flagging every real step after
        with pytest.raises(RuntimeError, match="without a matching step_start"):
            StragglerWatch().step_end(0)

    def test_step_end_consumes_the_start(self):
        w = StragglerWatch()
        w.step_start()
        w.step_end(0)
        with pytest.raises(RuntimeError):
            w.step_end(1)  # second end without a fresh start

    def test_normal_cycle_still_works(self):
        w = StragglerWatch()
        for step in range(3):
            w.step_start()
            assert w.step_end(step) is False
        assert w.ewma is not None and w.flagged_steps == []


class TestMetricsRouting:
    def test_observe_routes_counters_and_histogram(self):
        mx = MetricsRegistry()
        w = StragglerWatch(threshold=3.0, metrics=mx)
        for step in range(5):
            w.observe(step, 0.010)
        assert w.observe(5, 0.100) is True   # 10x the EWMA: flagged
        assert mx.count("watch_steps") == 6
        assert mx.count("watch_slow_steps") == 1
        h = mx.hist("watch_step_ms")
        assert h.n == 6
        assert h.max_ms == pytest.approx(100.0)

    def test_step_end_feeds_metrics_too(self):
        mx = MetricsRegistry()
        w = StragglerWatch(metrics=mx)
        w.step_start()
        w.step_end(0)
        assert mx.count("watch_steps") == 1
        assert mx.hist("watch_step_ms").n == 1

    def test_no_metrics_is_the_default(self):
        w = StragglerWatch()
        assert w.metrics is None
        w.observe(0, 0.01)  # runs without a registry
