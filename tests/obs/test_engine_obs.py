"""ServeEngine telemetry: admission counters, queue-depth gauges, step spans."""

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import api
from repro.obs import MetricsRegistry, Tracer
from repro.serve import EngineConfig, Request, ServeEngine


@pytest.fixture(scope="module")
def engine_setup():
    cfg = get_smoke_config("qwen2-72b")
    params = api.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _reqs(n, tokens=2):
    return [Request(rid=i, prompt=np.arange(4), max_new_tokens=tokens)
            for i in range(n)]


def test_admission_counters_and_gauges(engine_setup):
    cfg, params = engine_setup
    mx, tr = MetricsRegistry(), Tracer()
    eng = ServeEngine(cfg, params, EngineConfig(max_batch=2, t_cache=64),
                      trace=tr, metrics=mx)
    eng.add_requests(_reqs(4))
    # 4 in, 2 slots: two admitted, two queued
    assert mx.count("requests_in") == 4
    assert mx.count("requests_admitted") == 2
    assert mx.gauges["pending_depth"] == 2
    assert mx.gauges["active_slots"] == 2
    eng.run(jax.random.PRNGKey(0), [])
    assert mx.count("requests_admitted") == 4
    assert mx.count("requests_done") == 4
    assert mx.count("tokens_out") == 4 * 2  # every request emitted its budget
    assert mx.gauges["pending_depth"] == 0
    assert mx.gauges["active_slots"] == 0
    assert mx.count("prefills") >= 1


def test_step_and_prefill_spans(engine_setup):
    cfg, params = engine_setup
    tr = Tracer()
    eng = ServeEngine(cfg, params, EngineConfig(max_batch=2, t_cache=64),
                      trace=tr, metrics=MetricsRegistry())
    eng.run(jax.random.PRNGKey(0), _reqs(2))
    assert len(tr.named("engine.prefill")) >= 1
    steps = tr.named("engine.step")
    assert len(steps) >= 2
    assert steps[0].attrs["step"] == 0
    assert all(s.done for s in tr.spans)


def test_untraced_engine_unchanged(engine_setup):
    cfg, params = engine_setup
    eng = ServeEngine(cfg, params, EngineConfig(max_batch=2, t_cache=64))
    assert eng.trace is None and eng.metrics is None
    out = eng.run(jax.random.PRNGKey(0), _reqs(2))
    assert all(r.done for r in out)
