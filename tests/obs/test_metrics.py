"""MetricsRegistry: counters, gauges, histogram namespace, CSV artifact."""

import csv

import pytest

from repro.obs import MetricsRegistry


class TestRegistry:
    def test_counters(self):
        m = MetricsRegistry()
        assert m.count("frames_in") == 0
        assert m.inc("frames_in") == 1
        assert m.inc("frames_in", 4) == 5
        assert m.count("frames_in") == 5

    def test_gauges_overwrite(self):
        m = MetricsRegistry()
        m.set_gauge("pending", 3)
        m.set_gauge("pending", 1)
        assert m.gauges["pending"] == 1.0

    def test_hist_get_or_create_applies_kwargs_once(self):
        m = MetricsRegistry()
        h = m.hist("frame_ms", budget_ms=0.4)
        assert m.hist("frame_ms", budget_ms=99.0) is h  # kwargs only on create
        assert h.budget_ms == 0.4
        m.observe("frame_ms", 0.2)
        assert h.n == 1

    def test_as_dict_snapshot(self):
        m = MetricsRegistry()
        m.inc("launches")
        m.set_gauge("in_flight", 2)
        m.observe("frame_ms", 1.5)
        d = m.as_dict()
        assert d["counters"] == {"launches": 1}
        assert d["gauges"] == {"in_flight": 2.0}
        assert d["histograms"]["frame_ms"]["n"] == 1

    def test_write_hist_csv(self, tmp_path):
        m = MetricsRegistry()
        m.observe("a_ms", 0.5)
        m.observe("a_ms", 2.0)
        m.observe("b_ms", 10.0)
        path = m.write_hist_csv(str(tmp_path / "h.csv"), extra={"run": "test"})
        with open(path, newline="") as f:
            rows = list(csv.DictReader(f))
        assert rows and set(rows[0]) == {"hist", "bin_lo_ms", "bin_hi_ms",
                                         "count", "run"}
        assert sum(int(r["count"]) for r in rows if r["hist"] == "a_ms") == 2
        assert all(r["run"] == "test" for r in rows)
        for r in rows:  # bins are sane intervals
            assert float(r["bin_lo_ms"]) < float(r["bin_hi_ms"])
