"""FrameDriver fault tolerance: injected chaos + all-or-nothing harvest.

* **injector**: seeded verdicts are a pure function of the launch identity,
  rates validate, and a zero-rate injector is bit-identical to no injector.
* **recovery**: dropped / corrupted launches re-enqueue their frames at the
  front of the queue and re-dispatch with fresh entropy; the redispatch
  budget exhausts into a flagged zero posterior, never a dropped frame.
* **regression** (exception safety): a raise while harvesting one launch --
  injected or organic -- no longer strands the other in-flight launches or
  leaves rid bookkeeping inconsistent.
"""

import jax
import numpy as np
import pytest

from repro.bayesnet import FrameDriver, by_name, compile_network
from repro.distributed.fault import LaunchFault, LaunchFaultInjector

KEY = jax.random.PRNGKey(7)


@pytest.fixture(scope="module")
def net():
    return compile_network(by_name("sensor-degradation"), 128)


def _frames(net, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, 2, size=(n, len(net.evidence)), dtype=np.int32)


class _FaultOnTickets(LaunchFaultInjector):
    """Deterministic injector: a fixed fault kind on chosen dispatch tickets."""

    def __init__(self, kind, tickets):
        super().__init__()
        self.kind = kind
        self.tickets = set(tickets)

    def draw(self, salt, ticket):
        if ticket in self.tickets:
            self.injected[self.kind] += 1
            return self.kind
        return None


# --- the injector ------------------------------------------------------------------

def test_injector_verdicts_are_pure_functions_of_identity():
    a = LaunchFaultInjector(seed=3, p_drop=0.2, p_stall=0.2, p_corrupt=0.2)
    b = LaunchFaultInjector(seed=3, p_drop=0.2, p_stall=0.2, p_corrupt=0.2)
    ids = [(s, t) for s in range(4) for t in range(16)]
    assert [a.draw(*i) for i in ids] == [b.draw(*i) for i in ids]
    # a different seed gives a different schedule
    c = LaunchFaultInjector(seed=4, p_drop=0.2, p_stall=0.2, p_corrupt=0.2)
    assert [a.draw(*i) for i in ids] != [c.draw(*i) for i in ids]


def test_injector_rate_validation():
    with pytest.raises(ValueError, match="p_drop"):
        LaunchFaultInjector(p_drop=1.5)
    with pytest.raises(ValueError, match="sum"):
        LaunchFaultInjector(p_drop=0.6, p_corrupt=0.6)


def test_zero_rate_injector_is_bit_identical(net):
    fr = _frames(net, 6)
    plain = FrameDriver(net, max_batch=4, base_key=KEY, salt=11)
    plain.submit(fr)
    ref = plain.drain()
    chaos = FrameDriver(
        net, max_batch=4, base_key=KEY, salt=11, fault=LaunchFaultInjector(seed=0)
    )
    chaos.submit(fr)
    out = chaos.drain()
    assert sorted(out) == sorted(ref)
    for rid in ref:
        np.testing.assert_array_equal(out[rid][0], ref[rid][0])
        assert out[rid][1] == ref[rid][1]
    assert chaos.launch_failures == []


# --- recovery ----------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["drop", "corrupt"])
def test_failed_launch_redispatches_and_serves_every_frame(net, kind):
    fr = _frames(net, 4)
    d = FrameDriver(
        net, max_batch=4, base_key=KEY, salt=5, fault=_FaultOnTickets(kind, {0})
    )
    rids = d.submit(fr)
    out = d.drain()
    assert sorted(out) == rids                       # every frame terminated
    assert all(np.all(np.isfinite(p)) for p, _ in out.values())
    assert len(d.launch_failures) == 1
    failure = d.launch_failures[0]
    assert failure.kind == kind and failure.ticket == 0
    assert failure.rids == tuple(rids)
    assert d.stats.launch_failures == 1
    # the re-dispatch drew fresh entropy: a clean driver's launch 0 result
    # differs from the recovered launch-1 result (same frames, new key)
    clean = FrameDriver(net, max_batch=4, base_key=KEY, salt=5)
    clean.submit(fr)
    ref = clean.drain()
    assert any(
        not np.array_equal(out[r][0], ref[r][0]) or out[r][1] != ref[r][1]
        for r in rids
    )


def test_redispatch_exhaustion_emits_flagged_zero_posterior(net):
    fr = _frames(net, 3)
    d = FrameDriver(
        net, max_batch=4, base_key=KEY, salt=6,
        fault=LaunchFaultInjector(seed=0, p_drop=1.0), max_redispatch=2,
    )
    rids = d.submit(fr)
    out = d.drain()
    assert sorted(out) == rids                       # never-drop, even at 100%
    for rid in rids:
        post, accepted = out[rid]
        assert accepted == 0 and np.all(post == 0.0)
        assert d.reports[rid].reliable is False
        assert d.reports[rid].confidence == 0.0
    # 1 initial + 2 redispatches, every one dropped
    assert len(d.launch_failures) == 3
    assert d._fail_counts == {}                      # bookkeeping cleaned up


def test_stalled_launch_still_serves(net):
    fr = _frames(net, 2)
    inj = _FaultOnTickets("stall", {0})
    inj.stall_ms = 1.0
    d = FrameDriver(net, max_batch=4, base_key=KEY, salt=8, fault=inj)
    rids = d.submit(fr)
    out = d.drain()
    assert sorted(out) == rids
    assert d.launch_failures == []                   # a stall is slow, not lost
    assert inj.injected["stall"] == 1


# --- exception-safety regression ---------------------------------------------------

def test_harvest_raise_does_not_strand_other_launches(net):
    """An organically corrupted buffer mid-harvest recovers per launch: the
    other in-flight launches harvest normally and the failed launch's frames
    re-enqueue in order (the pre-fault driver stranded everything)."""
    fr = _frames(net, 8)
    d = FrameDriver(net, max_batch=4, base_key=KEY, salt=9)
    rids = d.submit(fr)
    d.step(block=False)                              # launch A (rids 0-3)
    d.step(block=False)                              # launch B (rids 4-7)
    assert d.in_flight == 2
    # corrupt launch A's device buffer organically (no injector involved)
    d._inflight[0].post = np.full_like(np.asarray(d._inflight[0].post), np.nan)
    out = d.harvest()
    # launch B's frames came through untouched
    assert sorted(out) == rids[4:]
    assert all(np.all(np.isfinite(p)) for p, _ in out.values())
    # launch A's frames were re-enqueued at the front, original order
    assert [rid for rid, _ in d._queue] == rids[:4]
    assert len(d.launch_failures) == 1
    assert d.launch_failures[0].kind == "invalid"
    # and the fleet is fully servable afterwards
    rest = d.drain()
    assert sorted(rest) == rids[:4]
    assert all(np.all(np.isfinite(p)) for p, _ in rest.values())


def test_recovery_restores_submit_timestamps_and_metrics(net):
    from repro.obs import MetricsRegistry, Tracer

    fr = _frames(net, 4)
    tr, mx = Tracer(), MetricsRegistry()
    d = FrameDriver(
        net, max_batch=4, base_key=KEY, salt=10,
        fault=_FaultOnTickets("drop", {0}), trace=tr, metrics=mx,
    )
    rids = d.submit(fr)
    out = d.drain()
    assert sorted(out) == rids
    snap = mx.as_dict()
    assert snap["counters"]["launch_failures"] == 1
    assert snap["counters"]["launch_failures_drop"] == 1
    assert snap["counters"]["redispatched_frames"] == 4
    assert snap["counters"]["frames_out"] == 4
    # every span opened for the failed launch was closed (error-annotated)
    assert all(s.done for s in tr.spans)


def test_launch_fault_exception_carries_identity():
    e = LaunchFault("drop", 7, "gone")
    assert e.kind == "drop" and e.ticket == 7
    assert "launch 7" in str(e) and "drop" in str(e)
