"""Scenario networks: compile, batch 1024+ frames in one launch, match the
enumeration oracle within 3-sigma, and stream through the FrameDriver."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.bayesnet import (
    FrameDriver,
    SCENARIOS,
    by_name,
    compile_network,
    make_posterior_fn,
    sample_evidence,
)


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_scenario_compiles_and_runs(name):
    spec = by_name(name)
    assert 5 <= spec.n_nodes <= 12
    net = compile_network(spec, n_bits=2048)
    ev = sample_evidence(spec, jax.random.PRNGKey(1), 32)
    post, acc = net.run(jax.random.PRNGKey(0), ev)
    assert post.shape == (32, len(spec.queries))
    assert acc.shape == (32,)
    p = np.asarray(post)
    assert np.all((p >= 0) & (p <= 1))


def test_eight_node_scenario_batched_1024_frames_one_launch():
    """The acceptance-criterion run: pedestrian-night (8 nodes), 1024 evidence
    frames, n_bits=4096, one jit launch, all posteriors within 3 sigma of the
    DAC-quantised enumeration oracle."""
    spec = by_name("pedestrian-night")
    assert spec.n_nodes >= 8
    net = compile_network(spec, n_bits=4096)
    ev = sample_evidence(spec, jax.random.PRNGKey(2), 1024)
    post, acc = net.run(jax.random.PRNGKey(0), ev)       # single jitted call
    exact, _ = make_posterior_fn(spec, dac_quantize=True)(ev)
    post, exact, acc = np.asarray(post), np.asarray(exact), np.asarray(acc)
    keep = acc > 50                                       # enough accepted bits
    assert keep.mean() > 0.9, f"acceptance collapsed: {keep.mean()}"
    sigma = np.sqrt(np.clip(exact * (1 - exact), 1e-3, None) / acc[:, None])
    z = np.abs(post - exact) / sigma
    # per-frame unbiased estimates: no frame may sit outside ~3 sigma (allow
    # the expected handful of >3 outliers across 2048 comparisons)
    assert np.mean(z[keep] > 3.0) < 0.01, float(np.max(z[keep]))
    assert float(np.max(z[keep])) < 5.0


def test_intersection_three_parent_cpts_agree_with_oracle():
    """12-node network exercises the 8-leaf MUX trees (fan-in 3)."""
    spec = by_name("intersection")
    assert spec.max_fan_in() == 3
    net = compile_network(spec, n_bits=4096)
    ev = sample_evidence(spec, jax.random.PRNGKey(5), 256)
    post, acc = net.run(jax.random.PRNGKey(3), ev)
    exact, _ = make_posterior_fn(spec, dac_quantize=True)(ev)
    post, exact, acc = np.asarray(post), np.asarray(exact), np.asarray(acc)
    keep = acc > 50
    assert keep.any()
    sigma = np.sqrt(np.clip(exact * (1 - exact), 1e-3, None) / acc[:, None])
    z = (np.abs(post - exact) / sigma)[keep]
    assert np.mean(z > 3.0) < 0.02, float(np.max(z))


def test_frame_driver_continuous_batching():
    spec = by_name("sensor-degradation")
    net = compile_network(spec, n_bits=1024)
    drv = FrameDriver(net, max_batch=16)
    ev = np.asarray(sample_evidence(spec, jax.random.PRNGKey(7), 21))
    rids = drv.submit(ev[:5])
    rids += drv.submit(ev[5:])
    assert drv.pending == 21 and rids == list(range(21))
    out1 = drv.step(jax.random.PRNGKey(0))               # one padded launch
    assert len(out1) == 16 and drv.pending == 5
    out = drv.drain(jax.random.PRNGKey(1))
    assert drv.pending == 0
    out.update(out1)
    assert sorted(out) == rids
    # driver results equal a direct batched run frame-by-frame (same padding-
    # independent posteriors): check one rid against its own single-frame run
    post, acc = net.run(jax.random.PRNGKey(0), ev[:16])
    np.testing.assert_allclose(out[3][0], np.asarray(post)[3], atol=1e-6)
