"""Scenario networks: compile, batch 1024+ frames in one launch, match the
enumeration oracle within 3-sigma, and stream through the FrameDriver."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.bayesnet import (
    FrameDriver,
    SCENARIOS,
    by_name,
    compile_network,
    make_posterior_fn,
    sample_evidence,
)


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_scenario_compiles_and_runs(name):
    spec = by_name(name)
    assert 5 <= spec.n_nodes <= 12
    net = compile_network(spec, n_bits=2048)
    ev = sample_evidence(spec, jax.random.PRNGKey(1), 32)
    post, acc = net.run(jax.random.PRNGKey(0), ev)
    q_cards = tuple(spec.card(q) for q in spec.queries)
    if all(c == 2 for c in q_cards):
        assert post.shape == (32, len(spec.queries))
    else:
        assert post.shape == (32, len(spec.queries), max(q_cards))
        # per-query vectors are normalised (0/0 frames fall back to value 0)
        sums = np.asarray(post).sum(-1)
        assert np.all((np.abs(sums - 1.0) < 1e-5) | (sums == 0) | (sums == 1.0))
    assert acc.shape == (32,)
    p = np.asarray(post)
    assert np.all((p >= 0) & (p <= 1))


def test_eight_node_scenario_batched_1024_frames_one_launch():
    """The acceptance-criterion run: pedestrian-night (8 nodes), 1024 evidence
    frames, n_bits=4096, one jit launch, all posteriors within 3 sigma of the
    DAC-quantised enumeration oracle."""
    spec = by_name("pedestrian-night")
    assert spec.n_nodes >= 8
    net = compile_network(spec, n_bits=4096)
    ev = sample_evidence(spec, jax.random.PRNGKey(2), 1024)
    post, acc = net.run(jax.random.PRNGKey(0), ev)       # single jitted call
    exact, _ = make_posterior_fn(spec, dac_quantize=True)(ev)
    post, exact, acc = np.asarray(post), np.asarray(exact), np.asarray(acc)
    keep = acc > 50                                       # enough accepted bits
    assert keep.mean() > 0.9, f"acceptance collapsed: {keep.mean()}"
    sigma = np.sqrt(np.clip(exact * (1 - exact), 1e-3, None) / acc[:, None])
    z = np.abs(post - exact) / sigma
    # per-frame unbiased estimates: no frame may sit outside ~3 sigma (allow
    # the expected handful of >3 outliers across 2048 comparisons)
    assert np.mean(z[keep] > 3.0) < 0.01, float(np.max(z[keep]))
    assert float(np.max(z[keep])) < 5.0


def test_intersection_three_parent_cpts_agree_with_oracle():
    """12-node network exercises the 8-leaf MUX trees (fan-in 3)."""
    spec = by_name("intersection")
    assert spec.max_fan_in() == 3
    net = compile_network(spec, n_bits=4096)
    ev = sample_evidence(spec, jax.random.PRNGKey(5), 256)
    post, acc = net.run(jax.random.PRNGKey(3), ev)
    exact, _ = make_posterior_fn(spec, dac_quantize=True)(ev)
    post, exact, acc = np.asarray(post), np.asarray(exact), np.asarray(acc)
    keep = acc > 50
    assert keep.any()
    sigma = np.sqrt(np.clip(exact * (1 - exact), 1e-3, None) / acc[:, None])
    z = (np.abs(post - exact) / sigma)[keep]
    assert np.mean(z > 3.0) < 0.02, float(np.max(z))


def test_four_class_scenario_batched_1024_frames_one_launch():
    """The categorical acceptance run: obstacle-class (4-way classification),
    1024 evidence frames, n_bits=4096, one fused launch, every per-value
    posterior within stochastic noise of the DAC-quantised oracle."""
    spec = by_name("obstacle-class")
    assert spec.card("obstacle") == 4
    net = compile_network(spec, n_bits=4096)
    assert net.fused and net.query_cards == (4, 2)
    ev = sample_evidence(spec, jax.random.PRNGKey(2), 1024)
    post, acc = net.run(jax.random.PRNGKey(0), ev)       # single jitted call
    exact, _ = make_posterior_fn(spec, dac_quantize=True)(ev)
    post, exact, acc = np.asarray(post), np.asarray(exact), np.asarray(acc)
    assert post.shape == (1024, 2, 4)
    keep = acc > 50
    # k-ary evidence nodes span 72 joint sensor configurations, so rare
    # combinations legitimately land under the 50-bit floor more often than
    # in the binary nets -- the kept fraction is lower, not collapsed.
    assert keep.mean() > 0.7, f"acceptance collapsed: {keep.mean()}"
    sigma = np.sqrt(
        np.clip(exact * (1 - exact), 1e-3, None) / acc[:, None, None]
    )
    # tail class probabilities sit below one 8-bit DAC grid step, where the
    # discrete count noise is heavier than the normal approximation -- allow
    # the usual 2/256 grid slack before scoring sigmas (as the motif tests do)
    z = (np.clip(np.abs(post - exact) - 2 / 256, 0, None) / sigma)[keep]
    assert np.mean(z > 3.0) < 0.01, float(np.max(z))
    assert float(np.max(z)) < 5.0


def test_categorical_evidence_conditioning():
    """k-ary evidence values select the right conditional: observing the
    thermal large-warm signature should rank vehicle above pedestrian, and
    the small-warm signature the other way around."""
    spec = by_name("obstacle-class")
    net = compile_network(spec, n_bits=1 << 14)
    # (night, rgb_class, th_signature, radar_echo)
    large_warm = [0, 0, 2, 2]                 # big signature + strong echo
    small_warm = [0, 1, 1, 1]                 # small blob + ped report
    post, acc = net.run(jax.random.PRNGKey(0), np.asarray([large_warm, small_warm]))
    post, acc = np.asarray(post), np.asarray(acc)
    qi = net.queries.index("obstacle")
    assert post[0, qi, 2] > post[0, qi, 1]    # vehicle beats pedestrian
    assert post[1, qi, 1] > post[1, qi, 2]    # pedestrian beats vehicle
    exact, _ = make_posterior_fn(spec, dac_quantize=True)(
        np.asarray([large_warm, small_warm])
    )
    exact = np.asarray(exact)
    sigma = np.sqrt(
        np.clip(exact * (1 - exact), 1e-3, None) / np.maximum(acc, 1)[:, None, None]
    )
    assert float(np.max(np.abs(post - exact) / sigma)) < 5.0


def test_frame_driver_default_salt_decorrelates():
    """Two drivers built with defaults draw different joint samples (the old
    shared-PRNGKey(0) footgun); an explicit shared salt restores replay."""
    spec = by_name("sensor-degradation")
    net = compile_network(spec, n_bits=1024)
    ev = np.asarray(sample_evidence(spec, jax.random.PRNGKey(7), 4))
    outs = []
    for drv in (FrameDriver(net, max_batch=4), FrameDriver(net, max_batch=4)):
        drv.submit(ev)
        outs.append(drv.drain())              # driver-sequenced launch keys
    a, b = outs
    assert sorted(a) == sorted(b)
    assert any(not np.allclose(a[r][0], b[r][0]) for r in a), \
        "default drivers drew bit-identical joint samples"
    # explicit salt: same (base_key, salt) -> identical launches
    outs = []
    for _ in range(2):
        drv = FrameDriver(net, max_batch=4, salt=123)
        drv.submit(ev)
        outs.append(drv.drain())
    for r in outs[0]:
        np.testing.assert_array_equal(outs[0][r][0], outs[1][r][0])
        assert outs[0][r][1] == outs[1][r][1]


def test_frame_driver_categorical_posteriors():
    """The driver streams (n_q, k) posterior matrices for k-ary query sets."""
    spec = by_name("intersection-cat")
    net = compile_network(spec, n_bits=1024)
    drv = FrameDriver(net, max_batch=8, salt=0)
    ev = np.asarray(sample_evidence(spec, jax.random.PRNGKey(3), 5))
    drv.submit(ev)
    out = drv.drain(jax.random.PRNGKey(1))
    assert sorted(out) == list(range(5))
    for post, accepted in out.values():
        assert post.shape == (3, 3)           # 3 queries x max card 3
        assert accepted >= 0
        # binary queries pad their vectors with a zero third column
        assert post[1, 2] == 0.0 and post[2, 2] == 0.0


def test_frame_driver_async_matches_sync():
    """Pipelined dispatch returns bit-identical posteriors to the sync path
    for the same (base_key, salt), with submission-order rid mapping."""
    spec = by_name("pedestrian-night")
    net = compile_network(spec, n_bits=1024)
    ev = np.asarray(sample_evidence(spec, jax.random.PRNGKey(9), 21))
    sync = FrameDriver(net, max_batch=8, salt=77)
    pipe = FrameDriver(net, max_batch=8, salt=77)
    sync.submit(ev)
    pipe.submit(ev)
    out_s = sync.drain()
    out_p = pipe.drain_async()
    assert sorted(out_s) == sorted(out_p) == list(range(21))
    for rid in out_s:
        np.testing.assert_array_equal(out_s[rid][0], out_p[rid][0])
        assert out_s[rid][1] == out_p[rid][1]


def test_frame_driver_nonblocking_step_and_harvest():
    spec = by_name("sensor-degradation")
    net = compile_network(spec, n_bits=1024)
    ev = np.asarray(sample_evidence(spec, jax.random.PRNGKey(4), 12))
    drv = FrameDriver(net, max_batch=4, salt=3)
    drv.submit(ev)
    assert drv.step(block=False) == {}          # dispatched, not harvested
    assert drv.in_flight == 1 and drv.pending == 8
    drv.step(block=False)
    assert drv.in_flight == 2
    out = drv.harvest()                          # the one sync point
    assert drv.in_flight == 0 and sorted(out) == list(range(8))
    # a blocking step returns its own launch AND anything left in flight
    drv.step(block=False)
    out = drv.step()
    assert sorted(out) == list(range(8, 12)) and drv.in_flight == 0
    # drain() with an empty queue still harvests parked async launches
    drv.submit(ev[:3])
    drv.step(block=False)
    assert drv.pending == 0 and drv.in_flight == 1
    out = drv.drain()
    assert sorted(out) == [12, 13, 14] and drv.in_flight == 0


def test_frame_driver_tail_padding_buckets():
    """A 1-frame step on a wide driver launches a 1-lane batch, not
    max_batch lanes: the padded-tail entropy bill is gone."""
    spec = by_name("sensor-degradation")
    net = compile_network(spec, n_bits=1024)
    n_ev = len(net.evidence)
    drv = FrameDriver(net, max_batch=1024, salt=1)
    ev = np.asarray(sample_evidence(spec, jax.random.PRNGKey(5), 21))
    drv.submit(ev[:1])
    out = drv.step()
    assert drv.last_launch_shape == (1, n_ev)
    assert list(out) == [0]
    # 5 pending -> 8-lane bucket (pad replicates the last real frame)
    drv.submit(ev[:5])
    out = drv.step()
    assert drv.last_launch_shape == (8, n_ev)
    assert sorted(out) == [1, 2, 3, 4, 5]
    # full queue still uses the max_batch-capped bucket
    drv.submit(np.repeat(ev, 80, axis=0)[:1030])
    drv.step()
    assert drv.last_launch_shape == (1024, n_ev)


def test_frame_driver_continuous_batching():
    spec = by_name("sensor-degradation")
    net = compile_network(spec, n_bits=1024)
    drv = FrameDriver(net, max_batch=16)
    ev = np.asarray(sample_evidence(spec, jax.random.PRNGKey(7), 21))
    rids = drv.submit(ev[:5])
    rids += drv.submit(ev[5:])
    assert drv.pending == 21 and rids == list(range(21))
    out1 = drv.step(jax.random.PRNGKey(0))               # one padded launch
    assert len(out1) == 16 and drv.pending == 5
    out = drv.drain(jax.random.PRNGKey(1))
    assert drv.pending == 0
    out.update(out1)
    assert sorted(out) == rids
    # driver results equal a direct batched run frame-by-frame (same padding-
    # independent posteriors): check one rid against its own single-frame run
    post, acc = net.run(jax.random.PRNGKey(0), ev[:16])
    np.testing.assert_allclose(out[3][0], np.asarray(post)[3], atol=1e-6)
