"""Categorical (k-ary) domain correctness.

Three pillars:

* **k=2 regression**: binary networks must stay BIT-identical to the
  pre-categorical compiler -- streams, fused counts, and posteriors are pinned
  against goldens captured from the pre-refactor tree (commit 338b354).
* **k-ary correctness**: randomized mixed-cardinality DAGs (k in 2..5, fan-in
  <= 3) against the exact enumeration oracle, through both the fused sweep and
  the unfused per-node program; plus bit-exactness of the categorical
  node_mux kernel vs its jnp ref.
* **mechanism**: CDF thresholds, value bit-planes, and the categorical root
  encoder sample the documented quantised distribution.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.bayesnet import by_name, compile_network, make_posterior_fn, sweep_plan
from repro.bayesnet.compile import lower_streams
from repro.bayesnet.spec import NetworkSpec, Node
from repro.core import bitops, rng
from repro.kernels.net_sweep import SweepPlan, net_sweep
from repro.kernels.node_mux import node_mux_categorical

N_BITS = 1 << 14


# --- k=2 regression: bit-identical to the pre-categorical compiler -----------------

# Goldens captured from the pre-refactor tree (commit 338b354): pedestrian-night,
# evidence sampled with PRNGKey(42), run keys PRNGKey(0)/PRNGKey(7), n_bits=2048.
_GOLD_EV = [[1, 0, 0], [0, 0, 1], [1, 0, 0], [1, 0, 0],
            [0, 0, 0], [0, 0, 0], [1, 0, 0], [1, 0, 1]]
_GOLD_FUSED_NUMER = [[40, 8], [22, 66], [44, 13], [52, 13],
                     [14, 16], [9, 8], [56, 12], [72, 110]]
_GOLD_FUSED_DENOM = [681, 95, 705, 744, 667, 676, 741, 153]
# float32 posteriors as uint32 bit patterns (exact equality, no repr round-trip)
_GOLD_FUSED_POST_BITS = [
    [1030788702, 1010858059], [1047339784, 1060231750],
    [1031774987, 1016532707], [1032790985, 1016013769],
    [1017901615, 1019511423], [1012539731, 1010951356],
    [1033553486, 1015327226], [1055977713, 1060638051],
]
_GOLD_UNFUSED_POST_BITS = [
    [1032774204, 1002341114], [1048427529, 1060305204],
    [1030133490, 1016972696], [1031350728, 1018581126],
    [1018023432, 1017264067], [1022472319, 1016636766],
    [1029382313, 1014446218], [1056847285, 1060190996],
]
_GOLD_UNFUSED_DENOM = [688, 113, 675, 674, 707, 644, 729, 143]
# first words of three node streams from lower_streams(spec, PRNGKey(7), 256)
_GOLD_STREAMS = {
    "night": [2403081081, 563707892, 1695044603, 4068916680,
              251601518, 1716507668, 3120645670, 1669460608],
    "pedestrian": [1224744968, 16875520, 805308552, 4751488,
                   262226, 268440881, 17306624, 2960],
    "brake": [147923209, 277971204, 875563082, 557184,
              1331280, 316679426, 1082666010, 1207962512],
}


def test_binary_net_bit_identical_fused_counts_and_posterior():
    spec = by_name("pedestrian-night")
    plan = sweep_plan(spec, spec.queries, spec.evidence)
    ev = jnp.asarray(_GOLD_EV, jnp.int32)
    numer, denom = net_sweep(jax.random.PRNGKey(0), ev, plan=plan,
                             n_bits=2048, use_kernel=False)
    assert np.asarray(numer).tolist() == _GOLD_FUSED_NUMER
    assert np.asarray(denom).tolist() == _GOLD_FUSED_DENOM
    post, acc = compile_network(spec, n_bits=2048).run(jax.random.PRNGKey(0), ev)
    assert post.shape == (8, 2)                       # binary contract unchanged
    np.testing.assert_array_equal(
        np.asarray(post).view(np.uint32), np.asarray(_GOLD_FUSED_POST_BITS, np.uint32)
    )


def test_binary_net_bit_identical_unfused_streams_and_posterior():
    spec = by_name("pedestrian-night")
    streams = lower_streams(spec, jax.random.PRNGKey(7), 256)
    for name, words in _GOLD_STREAMS.items():
        assert len(streams[name]) == 1                # binary: one value plane
        assert np.asarray(streams[name][0]).tolist() == words, name
    ev = jnp.asarray(_GOLD_EV, jnp.int32)
    post, acc = compile_network(spec, n_bits=2048, fused=False).run(
        jax.random.PRNGKey(0), ev
    )
    assert np.asarray(acc).tolist() == _GOLD_UNFUSED_DENOM
    np.testing.assert_array_equal(
        np.asarray(post).view(np.uint32),
        np.asarray(_GOLD_UNFUSED_POST_BITS, np.uint32),
    )


def test_legacy_sweep_plan_form_normalises():
    """Pre-categorical (parents, scalar-thresholds) plans keep working."""
    legacy = SweepPlan(
        nodes=(((), (128,)), ((0,), (26, 230))),
        evidence=(0,),
        queries=(1,),
    )
    assert legacy.nodes == (((), 2, ((128,),)), ((0,), 2, ((26,), (230,))))
    assert legacy.n_value_slots == 1
    numer, denom = net_sweep(
        jax.random.PRNGKey(0), jnp.ones((4, 1), jnp.int32), plan=legacy,
        n_bits=1024, use_kernel=False,
    )
    assert numer.shape == (4, 1)


# --- spec validation ----------------------------------------------------------------

def test_node_categorical_constructor_and_value_probs():
    n = Node.categorical("c", (), ((0.2, 0.3, 0.5),))
    assert n.k == 3 and n.n_value_bits == 2 and not n.is_flat
    b = Node("b", (), (0.7,))
    assert b.value_probs() == ((1.0 - 0.7, 0.7),) and b.n_value_bits == 1


def test_flat_cpt_rejects_nonbinary():
    with pytest.raises(ValueError, match="binary-only"):
        Node("x", (), (0.2, 0.3), k=3)


def test_nested_row_must_sum_to_one():
    with pytest.raises(ValueError, match="sums to"):
        Node.categorical("x", (), ((0.5, 0.1, 0.1),))


def test_nested_row_length_must_match_k():
    with pytest.raises(ValueError, match="value probabilities"):
        Node("x", (), ((0.5, 0.5),), k=3)


def test_spec_validates_rows_against_parent_cardinalities():
    tri = Node.categorical("t", (), ((0.2, 0.3, 0.5),))
    with pytest.raises(ValueError, match="CPT rows"):
        NetworkSpec(name="bad", nodes=(tri, Node("c", ("t",), (0.1, 0.9))))
    ok = NetworkSpec(name="ok", nodes=(
        tri, Node("c", ("t",), ((0.9, 0.1), (0.5, 0.5), (0.2, 0.8)), k=2),
    ))
    assert ok.card("t") == 3 and ok.cards() == (3, 2) and ok.max_card() == 3


# --- CDF thresholds and value planes ------------------------------------------------

def test_cdf_thresholds_binary_matches_scalar_grid():
    for p in (0.0, 0.13, 0.5, 0.999, 1.0):
        assert rng.cdf_thresholds_int((1.0 - p, p)) == (rng.threshold_int(p),)


def test_cdf_thresholds_non_increasing_and_quantised():
    cdf = rng.cdf_thresholds_int((0.1, 0.2, 0.3, 0.4))
    assert cdf == (rng.threshold_int(0.9), rng.threshold_int(0.7), rng.threshold_int(0.4))
    assert all(a >= b for a, b in zip(cdf, cdf[1:]))


def test_encode_packed_categorical_distribution():
    probs = (0.15, 0.35, 0.30, 0.20)
    cdf = rng.cdf_thresholds_int(probs)
    planes = rng.encode_packed_categorical(jax.random.PRNGKey(5), cdf, N_BITS)
    assert planes.shape == (2, N_BITS // 32)
    vals = np.zeros(N_BITS, np.int64)
    for b in range(2):
        vals |= np.asarray(bitops.unpack_bits(planes[b], N_BITS)).astype(np.int64) << b
    bounds = (256,) + cdf + (0,)
    for v, _ in enumerate(probs):
        want = (bounds[v] - bounds[v + 1]) / 256.0
        got = (vals == v).mean()
        sigma = np.sqrt(want * (1 - want) / N_BITS)
        assert abs(got - want) < 5 * sigma, (v, got, want)


def test_value_plane_helpers_roundtrip():
    # nested levels for values 0..4 (k=5): planes must binary-encode the count
    rs = np.random.RandomState(0)
    vals = rs.randint(0, 5, size=256)
    levels = [
        bitops.pack_bits(jnp.asarray((vals >= v).astype(np.uint32)))
        for v in range(1, 5)
    ]
    planes = bitops.value_planes(levels)
    assert len(planes) == bitops.value_bits(5) == 3
    back = np.zeros(256, np.int64)
    for b, pl in enumerate(planes):
        back |= np.asarray(bitops.unpack_bits(pl, 256)).astype(np.int64) << b
    np.testing.assert_array_equal(back, vals)
    for d in range(5):
        ind = bitops.digit_indicator(planes, d)
        got = np.asarray(bitops.unpack_bits(ind & bitops.pad_mask(256), 256))
        np.testing.assert_array_equal(got, (vals == d).astype(np.uint8))


# --- categorical node_mux kernel ----------------------------------------------------

def test_node_mux_categorical_kernel_bitexact():
    cards = (4, 3, 2)                                  # k=4 node, parents k=3, k=2
    rs = np.random.RandomState(1)
    n_bits, rows, l = 1024, 8, 6
    cdf = np.stack([
        [rng.cdf_thresholds_int(tuple(r)) for r in rs.dirichlet(np.ones(4), size=l)]
        for _ in range(rows)
    ]).astype(np.uint32)
    v3 = rs.randint(0, 3, size=(rows, n_bits))
    v2 = rs.randint(0, 2, size=(rows, n_bits))
    parents = jnp.stack([
        bitops.pack_bits(jnp.asarray(v3 & 1, jnp.uint32)),
        bitops.pack_bits(jnp.asarray((v3 >> 1) & 1, jnp.uint32)),
        bitops.pack_bits(jnp.asarray(v2, jnp.uint32)),
    ])
    ref = node_mux_categorical(jax.random.PRNGKey(3), jnp.asarray(cdf), parents,
                               cards=cards, n_bits=n_bits, use_kernel=False)
    ker = node_mux_categorical(jax.random.PRNGKey(3), jnp.asarray(cdf), parents,
                               cards=cards, n_bits=n_bits, use_kernel=True,
                               interpret=True)
    assert ref.shape == (2, rows, n_bits // 32)
    assert bool(jnp.all(ref == ker))


def test_node_mux_categorical_conditional_distribution():
    """Conditional on the parents' digits, the sampled value follows the
    gathered (DAC-quantised) CPT row."""
    cards = (3, 2)
    probs = ((0.6, 0.3, 0.1), (0.1, 0.2, 0.7))
    cdf = jnp.asarray([[rng.cdf_thresholds_int(r) for r in probs]], jnp.uint32)
    parent = rng.fair_bits(jax.random.PRNGKey(2), (1, 1), N_BITS)
    planes = node_mux_categorical(jax.random.PRNGKey(9), cdf, parent,
                                  cards=cards, n_bits=N_BITS, use_kernel=False)
    vals = np.zeros(N_BITS, np.int64)
    for b in range(planes.shape[0]):
        vals |= np.asarray(bitops.unpack_bits(planes[b, 0], N_BITS)).astype(np.int64) << b
    pbits = np.asarray(bitops.unpack_bits(parent[0, 0], N_BITS)).astype(np.int64)
    for row in range(2):
        sel = pbits == row
        bounds = (256,) + rng.cdf_thresholds_int(probs[row]) + (0,)
        for v in range(3):
            want = (bounds[v] - bounds[v + 1]) / 256.0
            got = (vals[sel] == v).mean()
            sigma = np.sqrt(max(want * (1 - want), 1e-4) / sel.sum())
            assert abs(got - want) < 5 * sigma, (row, v, got, want)


# --- randomized k-ary DAGs vs the enumeration oracle --------------------------------

def _random_kary_dag(seed: int) -> NetworkSpec:
    """Random 4-7 node DAG, cardinalities 2-5, fan-in <= 3; CPT rows snapped to
    the 8-bit DAC CDF grid so the float oracle and the quantised stochastic
    path sample identical networks."""
    rs = np.random.RandomState(seed)
    n = int(rs.randint(4, 8))
    nodes = []
    cards = []
    for i in range(n):
        k = int(rs.randint(2, 6))
        m = int(min(i, rs.randint(0, 4)))
        pidx = sorted(rs.choice(i, size=m, replace=False)) if m else []
        parents = tuple(f"n{j}" for j in pidx)
        n_rows = int(np.prod([cards[j] for j in pidx])) if pidx else 1
        rows = []
        for _ in range(n_rows):
            # raw thresholds on the DAC grid, then difference into probs
            cuts = np.sort(rs.choice(np.arange(8, 249), size=k - 1, replace=False))[::-1]
            bounds = np.concatenate([[256], cuts, [0]])
            rows.append(tuple((bounds[:-1] - bounds[1:]) / 256.0))
        nodes.append(Node(f"n{i}", parents, tuple(rows), k=k))
        cards.append(k)
    names = [nd.name for nd in nodes]
    n_ev = int(rs.randint(1, 3))
    ev = tuple(str(e) for e in rs.choice(names[1:], size=min(n_ev, n - 1), replace=False))
    queries = tuple(nm for nm in names if nm not in ev)[:2]
    return NetworkSpec(name=f"kary{seed}", nodes=tuple(nodes),
                       evidence=ev, queries=queries)


def _zmax(post, exact, accepted, floor=1e-3):
    post, exact = np.asarray(post), np.asarray(exact)
    acc = np.asarray(accepted).reshape((-1,) + (1,) * (post.ndim - 1))
    sig = np.sqrt(np.clip(exact * (1 - exact), floor, None) / np.maximum(acc, 1))
    keep = np.broadcast_to(acc > 50, post.shape)
    return float(np.max(np.abs(post - exact)[keep] / sig[keep]))


@settings(deadline=None, max_examples=10)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_fused_kary_dags_match_enumeration_oracle(seed):
    spec = _random_kary_dag(seed)
    oracle = make_posterior_fn(spec)      # CPTs already on the DAC grid
    rs = np.random.RandomState(seed + 1)
    frames = jnp.asarray(
        np.stack([
            np.zeros(len(spec.evidence), np.int32),
            np.asarray([rs.randint(0, spec.card(e)) for e in spec.evidence], np.int32),
        ])
    )
    exact, _ = oracle(frames)
    net = compile_network(spec, n_bits=N_BITS, share_entropy=False, fused=True)
    post, acc = net.run(jax.random.PRNGKey(seed), frames)
    if not bool(np.any(np.asarray(acc) > 50)):
        return                            # evidence too unlikely at this n_bits
    assert _zmax(post, exact, acc) < 4.5, spec.name


@settings(deadline=None, max_examples=6)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_unfused_kary_dags_match_enumeration_oracle(seed):
    """Both entropy modes and both estimators agree with exact enumeration."""
    spec = _random_kary_dag(seed)
    oracle = make_posterior_fn(spec)
    frames = jnp.zeros((2, len(spec.evidence)), jnp.int32)
    exact, _ = oracle(frames)
    for share, estimator in ((True, "ratio"), (False, "fill")):
        net = compile_network(
            spec, n_bits=N_BITS, share_entropy=share, estimator=estimator
        )
        assert not net.fused
        post, acc = net.run(jax.random.PRNGKey(seed), frames)
        if not bool(np.any(np.asarray(acc) > 50)):
            continue
        assert _zmax(post, exact, acc) < 4.5, (spec.name, share, estimator)


def test_rows_mode_rejects_kary():
    spec = by_name("obstacle-class")
    with pytest.raises(ValueError, match="k-ary"):
        compile_network(spec, n_bits=1024, mux_mode="rows")


def test_decide_argmaxes_the_posterior():
    spec = by_name("obstacle-class")
    net = compile_network(spec, n_bits=1 << 13)
    # unambiguous frames: strong vehicle evidence vs strong nothing
    ev = np.asarray([[0, 2, 2, 2], [0, 0, 0, 0]])
    post, dec, acc = net.decide(jax.random.PRNGKey(0), ev)
    dec = np.asarray(dec)
    qi = net.queries.index("obstacle")
    assert dec.shape == (2, 2)
    assert dec[0, qi] == 2                # vehicle
    assert dec[1, qi] == 0                # none
    # the in-kernel epilogue IS the posterior argmax, and the posterior it
    # rides along with is the one `run` returns
    run_post, _ = net.run(jax.random.PRNGKey(0), ev)
    np.testing.assert_array_equal(np.asarray(post), np.asarray(run_post))
    np.testing.assert_array_equal(dec, np.argmax(np.asarray(post), axis=-1))
