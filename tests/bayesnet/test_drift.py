"""Closed-loop crossbar health: drift epochs, DriftMonitor, hot-swap.

* **epoched lowering** -- ``drift_epochs=1`` is bit-identical to the plain
  noisy compile on every scenario (the PR-8 pin); ``drift_epochs>1`` splits
  the stream across ``NoiseModel.with_cycle`` snapshots and its posteriors
  match the exact word-weighted mixture oracle within stochastic error.
* **wear model** -- ``wear_scale`` is exactly 1 at cycle 0 (the epochs=1 /
  cycle-0 equivalence satellite), grows as sqrt thereafter, and scales
  ``NoiseModel.read_cv_at``.
* **DriftMonitor** -- stationary statistics stay HEALTHY, drifting ones
  escalate HEALTHY -> DRIFTING -> RECALIBRATING, the RECALIBRATING latch
  survives healthy observations until ``reset()``, and the whole machine is
  a pure function of its observation stream (seeded-chaos replayable).
* **hot-swap** -- ``swap_net`` between launches loses and reorders nothing:
  in-flight launches harvest bit-identically to a never-swapped twin, and
  reports pin the dispatched plan's n_bits, not the swapped one's.
"""

import jax
import numpy as np
import pytest

from repro.bayesnet import (
    SCENARIOS,
    DriftMonitor,
    DriftPolicy,
    FrameDriver,
    HEALTH_DRIFTING,
    HEALTH_HEALTHY,
    HEALTH_RECALIBRATING,
    NoiseModel,
    RetryPolicy,
    by_name,
    compile_network,
    make_posterior_fn,
    sample_evidence,
)
from repro.core.device import DEFAULT_PARAMS, wear_scale
from repro.kernels.net_sweep import epoch_word_bounds

KEY = jax.random.PRNGKey(7)


# --- epoch bookkeeping -------------------------------------------------------------

def test_epoch_word_bounds_partitions_the_stream():
    for w_words in (1, 7, 32, 128):
        for epochs in (1, 2, 3, 5):
            b = epoch_word_bounds(w_words, epochs)
            assert len(b) == epochs + 1
            assert b[0] == 0 and b[-1] == w_words
            assert all(lo <= hi for lo, hi in zip(b, b[1:]))
    assert epoch_word_bounds(8, 1) == (0, 8)
    with pytest.raises(ValueError):
        epoch_word_bounds(8, 0)


def test_compile_validates_drift_epochs():
    spec = by_name("sensor-degradation")
    nm = NoiseModel(seed=1)
    with pytest.raises(ValueError):
        compile_network(spec, 128, noise=nm, drift_epochs=0)
    with pytest.raises(ValueError):
        # epochs > words: at least one word per epoch
        compile_network(spec, 128, noise=nm, drift_epochs=5)
    with pytest.raises(ValueError):
        # epoched lowering needs a noise model to advance
        compile_network(spec, 256, drift_epochs=2)


# --- wear model (endurance/OU tie-in satellite) ------------------------------------

def test_wear_scale_is_identity_at_cycle_zero():
    assert wear_scale(0.0, 3.0) == 1.0
    assert wear_scale(-1.0, 3.0) == 1.0
    assert wear_scale(3.0, 3.0) == pytest.approx(np.sqrt(2.0))


def test_read_cv_at_scales_with_wear():
    nm = NoiseModel(seed=1, wear_tau=2.0)
    assert nm.read_cv_at(0.0) == pytest.approx(nm.read_cv)
    assert nm.read_cv_at(2.0) == pytest.approx(nm.read_cv * np.sqrt(2.0))
    # default wear_tau derives from the endurance/readout device params
    assert NoiseModel().wear_tau == pytest.approx(DEFAULT_PARAMS.wear_tau_epochs)


# --- epochs=1 bit-identity pin (acceptance criterion) ------------------------------

@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_drift_epochs_one_bit_identical(name):
    spec = by_name(name)
    nm = NoiseModel(seed=5, cycle=3.0, wear_tau=2.0)
    ev = np.asarray(sample_evidence(spec, KEY, 4))
    plain = compile_network(spec, 256, noise=nm, devices=1)
    epoch1 = compile_network(spec, 256, noise=nm, drift_epochs=1, devices=1)
    p0, a0 = plain.run(KEY, ev)
    p1, a1 = epoch1.run(KEY, ev)
    assert np.array_equal(np.asarray(p0), np.asarray(p1))
    assert np.array_equal(np.asarray(a0), np.asarray(a1))


# --- epochs>1: within-launch drift vs the mixture oracle ---------------------------

def test_epoched_stream_matches_mixture_oracle():
    spec = by_name("pedestrian-night")
    nm = NoiseModel(seed=3, cycle=5.0, wear_tau=2.0)
    n_bits, epochs = 4096, 4
    net = compile_network(spec, n_bits, noise=nm, drift_epochs=epochs, devices=1)
    ev = np.asarray(sample_evidence(spec, KEY, 6))
    post, acc = net.run(KEY, ev)
    post, acc = np.asarray(post), np.asarray(acc)

    oracle = make_posterior_fn(
        spec, noise=nm, drift_epochs=epochs, n_bits=n_bits
    )
    opost, _ = oracle(ev)
    opost = np.asarray(opost)
    sigma = np.sqrt(
        np.clip(opost * (1 - opost), 1e-9, None) / np.maximum(acc, 1)[:, None]
    )
    assert np.all(np.abs(post - opost) <= 4.5 * sigma + 0.01)

    # and the epoched stream is genuinely different from the frozen one
    frozen = compile_network(spec, n_bits, noise=nm, devices=1)
    fpost, _ = frozen.run(KEY, ev)
    assert not np.array_equal(post, np.asarray(fpost))


# --- the drift detector ------------------------------------------------------------

def test_drift_monitor_stationary_stays_healthy():
    mon = DriftMonitor(DriftPolicy(warmup=8))
    rng = np.random.default_rng(0)
    for _ in range(60):
        st = mon.observe_launch(
            0.9 + 0.01 * rng.standard_normal(),
            0.5 + 0.01 * rng.standard_normal(),
        )
    assert st == HEALTH_HEALTHY and mon.alarms == 0


def test_drift_monitor_escalates_and_latches():
    mon = DriftMonitor(DriftPolicy(warmup=8, drift_h=3.0, recal_h=8.0))
    rng = np.random.default_rng(1)
    for _ in range(12):
        mon.observe_launch(0.9 + 0.005 * rng.standard_normal(), 0.5)
    assert mon.state == HEALTH_HEALTHY
    saw_drifting = False
    st = mon.state
    for i in range(120):
        st = mon.observe_launch(max(0.9 - 0.002 * i, 0.05), 0.5)
        if st == HEALTH_DRIFTING:
            saw_drifting = True
        if st == HEALTH_RECALIBRATING:
            break
    assert st == HEALTH_RECALIBRATING and saw_drifting
    # latched: healthy observations do not de-escalate until reset()
    for _ in range(20):
        st = mon.observe_launch(0.9, 0.5)
    assert st == HEALTH_RECALIBRATING
    mon.reset()
    assert mon.state == HEALTH_HEALTHY and mon.resets == 1


def test_drift_monitor_replay_deterministic():
    obs = [(0.9 - 0.004 * i, 0.5 - 0.002 * i) for i in range(50)]
    a = DriftMonitor(DriftPolicy(warmup=6))
    b = DriftMonitor(DriftPolicy(warmup=6))
    for conf, rate in obs:
        assert a.observe_launch(conf, rate) == b.observe_launch(conf, rate)
    assert a.peak_score == b.peak_score
    assert a.as_dict() == b.as_dict()


def test_drift_monitor_flip_channel_and_validation():
    mon = DriftMonitor(DriftPolicy(warmup=4, drift_h=1.0, recal_h=2.0))
    for _ in range(6):
        mon.observe_flip(0.02)
    for _ in range(30):
        st = mon.observe_flip(0.5)
        if st == HEALTH_RECALIBRATING:
            break
    assert st == HEALTH_RECALIBRATING
    with pytest.raises(ValueError):
        DriftPolicy(drift_h=5.0, recal_h=1.0)
    with pytest.raises(ValueError):
        DriftPolicy(warmup=0)


def test_driver_feeds_monitor_per_launch():
    spec = by_name("sensor-degradation")
    net = compile_network(spec, 128, devices=1)
    mon = DriftMonitor(DriftPolicy(warmup=32))
    drv = FrameDriver(net, max_batch=4, salt=3, drift=mon)
    ev = np.asarray(sample_evidence(spec, KEY, 10))
    drv.submit(ev)
    drv.drain()
    assert mon.launches == drv.launches and mon.launches >= 3


# --- hot-swap ordering guarantees (acceptance criterion) ---------------------------

def test_hot_swap_loses_nothing_and_preserves_preswap_bits():
    spec = by_name("pedestrian-night")
    net = compile_network(spec, 512, devices=1)
    ev = np.asarray(sample_evidence(spec, KEY, 12))
    ref = FrameDriver(net, max_batch=4, salt=77)
    swp = FrameDriver(net, max_batch=4, salt=77)
    ref.submit(ev[:8]); swp.submit(ev[:8])
    # two launches in flight on each driver, then swap one mid-air
    ref.step(block=False); ref.step(block=False)
    swp.step(block=False); swp.step(block=False)
    net2 = compile_network(
        spec, 512, noise=NoiseModel(seed=9, cycle=4.0, wear_tau=2.0), devices=1
    )
    swp.swap_net(net2)
    out_ref, out_swp = ref.harvest(), swp.harvest()
    assert set(out_ref) == set(out_swp)          # zero lost frames
    for rid in out_ref:
        assert np.array_equal(out_ref[rid][0], out_swp[rid][0])
        assert out_ref[rid][1] == out_swp[rid][1]
    # queued frames ride the new plan, in order, nothing dropped
    rids = swp.submit(ev[8:])
    out2 = swp.drain()
    assert sorted(out2) == sorted(rids)
    assert swp.net is net2


def test_hot_swap_reports_pin_dispatch_time_n_bits():
    spec = by_name("sensor-degradation")
    net = compile_network(spec, 256, devices=1)
    drv = FrameDriver(
        net, max_batch=8, salt=5, retry=RetryPolicy(min_confidence=0.0)
    )
    ev = np.asarray(sample_evidence(spec, KEY, 4))
    rids = drv.submit(ev)
    drv.step(block=False)
    drv.swap_net(compile_network(spec, 512, devices=1))
    out = drv.harvest()
    assert sorted(out) == sorted(rids)
    # the launch dispatched at 256 bits: its reports must say so even though
    # the driver's current net is the 512-bit swap-in
    assert all(drv.reports[r].n_bits == 256 for r in rids)


def test_hot_swap_validates_layout():
    net = compile_network(by_name("sensor-degradation"), 128, devices=1)
    other = compile_network(by_name("pedestrian-night"), 128, devices=1)
    drv = FrameDriver(net, max_batch=4, salt=1)
    with pytest.raises(ValueError):
        drv.swap_net(other)
    with pytest.raises(TypeError):
        drv.swap_net("not a network")
