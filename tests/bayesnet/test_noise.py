"""Crossbar NoiseModel correctness.

Three pillars:

* **noise=None regression**: compiling WITHOUT a noise model must stay
  BIT-identical to the pre-noise compiler -- full decide() outputs of all 7
  scenarios (and unfused run() for two) are pinned against goldens captured
  from the pre-noise tree (commit 5d45000).
* **perturbation mechanics**: perturbed rows are valid CDF rows, a pure
  function of (seed, cycle, node name), cycle re-draws only read noise,
  ``scaled(0)`` is the exact identity, stuck-at extremes pin to 0/256, and
  the default magnitudes are tied to the paper-calibrated device model.
* **noisy agreement**: under the nominal model, fused and unfused programs
  match the *perturbed-CPT* enumeration oracle within stochastic noise --
  the oracle twin keeps ground truth exact under any noise level.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.bayesnet import (
    NoiseModel,
    SCENARIOS,
    by_name,
    compile_network,
    make_posterior_fn,
    perturbed_cdf_rows,
    sample_evidence,
)
from repro.core import rng
from repro.core.device import DEFAULT_PARAMS

# --- noise=None regression: bit-identical to the pre-noise compiler ----------------

# Goldens captured from the pre-noise tree (commit 5d45000): per scenario,
# evidence = sample_evidence(spec, PRNGKey(3), 8), fused decide with
# PRNGKey(0) at n_bits=1024.  float32 posteriors as uint32 bit patterns.
_GOLD_FUSED = {
    "intersection": {
        "post_bits": [[1057609886, 0, 1047285445], [1017406289, 0, 1058451552],
                      [1029434210, 1015640861, 1058540991], [1018974820, 0, 1058796603],
                      [1058642330, 0, 1053609165], [1052490684, 0, 1053609165],
                      [1006124560, 997735952, 1058426259], [1029990088, 1016611973, 1057681850]],
        "dec": [[1, 0, 0], [0, 0, 1], [0, 0, 1], [0, 0, 1],
                [1, 0, 0], [0, 0, 0], [0, 0, 1], [0, 0, 1]],
        "acc": [13, 299, 298, 261, 5, 30, 264, 269],
    },
    "intersection-cat": {
        "post_bits": [
            [[1017759818, 1014934639, 1064744716], [1065092430, 1014934639, 0], [1042577928, 1062658430, 0]],
            [[1063828015, 1021274894, 1031951304], [1062302813, 1044000396, 0], [1061845253, 1045830637, 0]],
            [[1022901776, 1024782857, 1064234735], [1064743135, 1024782857, 0], [1046847438, 1061591052, 0]],
            [[0, 1065353216, 0], [1065353216, 0, 0], [1065353216, 0, 0]],
            [[1042983595, 1051372203, 1056964608], [1062557013, 1042983595, 0], [1059760811, 1051372203, 0]],
            [[1023822730, 1009979235, 1064619786], [1065169858, 1009979235, 0], [1046834103, 1061594386, 0]],
            [[1030811889, 0, 1064366321], [1065353216, 0, 0], [1059431846, 1052030133, 0]],
            [[1042536202, 1056293519, 1052266988], [1065017672, 1017370378, 0], [1056964608, 1056964608, 0]]],
        "dec": [[2, 0, 1], [0, 0, 0], [2, 0, 1], [1, 0, 0],
                [2, 0, 0], [2, 0, 1], [2, 0, 0], [1, 0, 0]],
        "acc": [193, 110, 165, 4, 6, 183, 17, 50],
    },
    "lane-change": {
        "post_bits": [[1002950156, 1064637115, 1063102614], [1019517862, 1052535423, 1054146036],
                      [1008422000, 1064081010, 1062808804], [1048576000, 1048576000, 1040187392],
                      [1052490684, 1059760811, 1047457519], [1014763457, 1054383498, 1054899720],
                      [1050863802, 1059252410, 0], [1001590627, 1064436428, 1063061247]],
        "dec": [[0, 1, 1], [0, 0, 0], [0, 1, 1], [0, 0, 0],
                [0, 1, 0], [0, 0, 0], [0, 1, 0], [0, 1, 1]],
        "acc": [164, 125, 211, 16, 30, 130, 22, 183],
    },
    "obstacle-class": {
        "post_bits": [
            [[1065353216, 0, 0, 0], [1065353216, 0, 0, 0]],
            [[0, 0, 1065353216, 0], [1047589105, 1061405636, 0, 0]],
            [[1064996254, 1018055745, 0, 0], [1064782077, 1024159796, 0, 0]],
            [[1064774691, 1024277963, 0, 0], [1063617642, 1037294769, 0, 0]],
            [[1064867925, 1019943809, 998729643, 0], [1064174651, 1032838694, 0, 0]],
            [[1065353216, 0, 0, 0], [1064385300, 1030508229, 0, 0]],
            [[1064011039, 0, 1025758986, 1025758986], [1062668861, 1042536202, 0, 0]],
            [[1064814498, 1018946513, 999706586, 999706586], [1064044901, 1033876696, 0, 0]]],
        "dec": [[0, 0], [2, 1], [0, 0], [0, 0], [0, 0], [0, 0], [0, 0], [0, 0]],
        "acc": [7, 17, 235, 29, 242, 208, 25, 218],
    },
    "obstacle-detection": {
        "post_bits": [
            [[1056964608, 1051372203, 1042983595, 0], [1056964608, 1056964608, 0, 0]],
            [[1023969417, 1040746633, 1059760811, 1042983595], [1052490684, 1059201570, 0, 0]],
            [[1064473512, 1018697475, 1010308867, 1016686722], [1064285004, 1031955874, 0, 0]],
            [[1061997773, 1036831949, 1036831949, 0], [1063675494, 1036831949, 0, 0]],
            [[1064640670, 1016997263, 1006438629, 1014827237], [1063863347, 1035329125, 0, 0]],
            [[1065353216, 0, 0, 0], [1064563700, 1027653825, 0, 0]],
            [[1064774691, 1015889355, 0, 1015889355], [1064485429, 1028906161, 0, 0]],
            [[1064496507, 1015771188, 1011951694, 1018055745], [1063711191, 1036546379, 0, 0]]],
        "dec": [[0, 0], [2, 1], [0, 0], [0, 0], [0, 0], [0, 0], [0, 0], [0, 0]],
        "acc": [6, 30, 267, 10, 259, 170, 58, 235],
    },
    "pedestrian-night": {
        "post_bits": [[1057776409, 1062106013], [1063339950, 1065017672],
                      [1028930141, 1014934639], [1017463209, 1002233171],
                      [1027524041, 1007069627], [1055748868, 1060976551],
                      [1048754481, 1062140558], [1056057731, 1059231799]],
        "dec": [[1, 1], [1, 1], [0, 0], [0, 0], [0, 0], [0, 1], [0, 1], [0, 1]],
        "acc": [62, 50, 386, 347, 365, 69, 47, 74],
    },
    "sensor-degradation": {
        "post_bits": [[1044809686, 1056622216], [1019255317, 1015889355],
                      [1025540199, 1022621279], [1025009864, 1016621256],
                      [1025758986, 1013706234], [1024277963, 1016730845],
                      [1021996516, 1016021799], [1027565281, 1016667930]],
        "dec": [[0, 0], [0, 0], [0, 0], [0, 0], [0, 0], [0, 0], [0, 0], [0, 0]],
        "acc": [98, 638, 638, 645, 625, 638, 629, 642],
    },
}

# Unfused run() goldens, same evidence/keys (one binary + one categorical net).
_GOLD_UNFUSED = {
    "pedestrian-night": {
        "post_bits": [[1053857716, 1060382189], [1063983647, 1065010824],
                      [1031699511, 1019339964], [1017494510, 1015942860],
                      [1029237776, 1011624312], [1059601028, 1061039075],
                      [1048576000, 1060110336], [1054951342, 1060879292]],
        "acc": [54, 49, 338, 346, 321, 70, 64, 75],
    },
    "obstacle-class": {
        "post_bits": [
            [[1065353216, 0, 0, 0], [1065353216, 0, 0, 0]],
            [[0, 0, 1065353216, 0], [1032358025, 1064234735, 0, 0]],
            [[1064882827, 1008279322, 0, 1016667930], [1064098845, 1033445146, 0, 0]],
            [[1062956471, 1032997157, 1024608549, 1024608549], [1062357285, 1043782510, 0, 0]],
            [[1065187105, 1000486851, 1000486851, 0], [1064605716, 1026981564, 0, 0]],
            [[1065353216, 0, 0, 0], [1065082616, 1015292168, 0, 0]],
            [[1064654165, 0, 0, 1026206379], [1065353216, 0, 0, 0]],
            [[1065116917, 1008326435, 999937827, 0], [1064644320, 1026363911, 0, 0]]],
        "acc": [11, 15, 214, 28, 202, 186, 24, 213],
    },
}


def _gold_ev(spec):
    return sample_evidence(spec, jax.random.PRNGKey(3), 8)


def _bits(post):
    return np.asarray(post, np.float32).view(np.uint32)


@pytest.mark.parametrize("name", sorted(_GOLD_FUSED))
def test_no_noise_fused_bit_identical_to_pre_noise_tree(name):
    spec = by_name(name)
    gold = _GOLD_FUSED[name]
    for noise in (None, NoiseModel.zero(), NoiseModel().scaled(0.0)):
        net = compile_network(spec, n_bits=1024, noise=noise)
        post, dec, acc = net.decide(jax.random.PRNGKey(0), _gold_ev(spec))
        np.testing.assert_array_equal(_bits(post), np.asarray(gold["post_bits"], np.uint32))
        np.testing.assert_array_equal(np.asarray(dec), np.asarray(gold["dec"]))
        np.testing.assert_array_equal(np.asarray(acc), np.asarray(gold["acc"]))


@pytest.mark.parametrize("name", sorted(_GOLD_UNFUSED))
def test_no_noise_unfused_bit_identical_to_pre_noise_tree(name):
    spec = by_name(name)
    gold = _GOLD_UNFUSED[name]
    net = compile_network(spec, n_bits=1024, fused=False)
    post, acc = net.run(jax.random.PRNGKey(0), _gold_ev(spec))
    np.testing.assert_array_equal(_bits(post), np.asarray(gold["post_bits"], np.uint32))
    np.testing.assert_array_equal(np.asarray(acc), np.asarray(gold["acc"]))


# --- perturbation mechanics --------------------------------------------------------

def test_model_validation():
    with pytest.raises(ValueError):
        NoiseModel(d2d_cv=-0.1)
    with pytest.raises(ValueError):
        NoiseModel(read_cv=float("nan"))
    with pytest.raises(ValueError):
        NoiseModel(ir_drop=1.0)
    with pytest.raises(ValueError):
        NoiseModel(p_stuck_on=0.7, p_stuck_off=0.7)
    with pytest.raises(TypeError):
        compile_network(by_name("sensor-degradation"), n_bits=32, noise=0.1)


def test_zero_and_scaled_models():
    assert NoiseModel.zero().is_zero
    assert NoiseModel().scaled(0.0).is_zero
    assert not NoiseModel().is_zero
    half = NoiseModel().scaled(0.5)
    assert half.d2d_cv == pytest.approx(NoiseModel().d2d_cv * 0.5)
    assert half.seed == NoiseModel().seed
    cy = NoiseModel().with_cycle(7)
    assert cy.cycle == 7 and cy.seed == NoiseModel().seed
    assert cy.d2d_cv == NoiseModel().d2d_cv


def test_default_magnitudes_tied_to_device_model():
    """The nominal NoiseModel IS the paper-calibrated device model: the d2d
    spread is Fig 1d's 8 % CV verbatim, and the read CV is the stationary
    V_th CV attenuated by the ~80 switching cycles one bit integrates."""
    m = NoiseModel()
    assert m.d2d_cv == DEFAULT_PARAMS.d2d_cv == 0.08
    assert m.read_cv == DEFAULT_PARAMS.read_cv
    assert DEFAULT_PARAMS.reads_per_bit == pytest.approx(80.0)
    assert DEFAULT_PARAMS.read_cv == pytest.approx(
        (DEFAULT_PARAMS.vth_sigma / DEFAULT_PARAMS.vth_mu) / np.sqrt(80.0)
    )
    assert NoiseModel.nominal(DEFAULT_PARAMS) == m


@pytest.mark.parametrize("name", ["intersection", "obstacle-class"])
def test_perturbed_rows_valid_and_deterministic(name):
    spec = by_name(name)
    m = NoiseModel()
    rows = perturbed_cdf_rows(spec, m)
    again = perturbed_cdf_rows(spec, m)
    assert rows == again                       # pure function of the model
    assert set(rows) == {n.name for n in spec.nodes}
    changed = 0
    for node in spec.nodes:
        clean = tuple(rng.cdf_thresholds_int(r) for r in spec.cpt_rows(node.name))
        for prow, crow in zip(rows[node.name], clean):
            assert len(prow) == len(crow) == spec.card(node.name) - 1
            assert all(0 <= t <= 256 for t in prow)
            # cumulative tails stay non-increasing (valid CDF rows)
            assert all(a >= b for a, b in zip(prow, prow[1:]))
            changed += int(prow != crow)
    assert changed > 0                          # nominal noise is material
    # a different array instance draws different devices
    assert perturbed_cdf_rows(spec, dataclasses.replace(m, seed=1)) != rows


def test_cycle_redraws_only_read_noise():
    spec = by_name("pedestrian-night")
    full = NoiseModel()
    assert perturbed_cdf_rows(spec, full) != perturbed_cdf_rows(spec, full.with_cycle(3))
    d2d_only = NoiseModel(read_cv=0.0, ir_drop=0.0, p_stuck_on=0.0, p_stuck_off=0.0)
    assert perturbed_cdf_rows(spec, d2d_only) == perturbed_cdf_rows(
        spec, d2d_only.with_cycle(3)
    )


def test_scaled_zero_returns_clean_thresholds():
    spec = by_name("lane-change")
    rows = perturbed_cdf_rows(spec, NoiseModel().scaled(0.0))
    for node in spec.nodes:
        clean = tuple(rng.cdf_thresholds_int(r) for r in spec.cpt_rows(node.name))
        assert rows[node.name] == clean


def test_stuck_at_extremes():
    spec = by_name("pedestrian-night")
    quiet = dict(d2d_cv=0.0, read_cv=0.0, ir_drop=0.0)
    all_on = perturbed_cdf_rows(spec, NoiseModel(p_stuck_on=1.0, p_stuck_off=0.0, **quiet))
    all_off = perturbed_cdf_rows(spec, NoiseModel(p_stuck_on=0.0, p_stuck_off=1.0, **quiet))
    for name in all_on:
        assert all(t == 256 for row in all_on[name] for t in row)
        assert all(t == 0 for row in all_off[name] for t in row)


# --- noisy agreement: compiled programs vs the perturbed-CPT oracle twin -----------

N_BITS = 1 << 14


def _assert_3sigma(post, exact, acc, tail=0.01, hard=6.0):
    post, exact, acc = np.asarray(post), np.asarray(exact), np.asarray(acc)
    keep = acc > 50
    assert keep.mean() > 0.5, f"acceptance collapsed: {keep.mean()}"
    extra = (np.ndim(exact) - 1) * (None,)
    sigma = np.sqrt(np.clip(exact * (1 - exact), 1e-3, None) / acc[(slice(None),) + extra])
    z = (np.clip(np.abs(post - exact) - 2 / 256, 0, None) / sigma)[keep]
    assert np.mean(z > 3.0) < tail, float(np.max(z))
    assert float(np.max(z)) < hard


@pytest.mark.parametrize("name", ["pedestrian-night", "intersection", "obstacle-class"])
def test_fused_matches_perturbed_oracle_3sigma(name):
    spec = by_name(name)
    m = NoiseModel()
    net = compile_network(spec, n_bits=N_BITS, noise=m)
    assert net.fused and net.noise == m
    ev = sample_evidence(spec, jax.random.PRNGKey(2), 256)
    post, acc = net.run(jax.random.PRNGKey(0), ev)
    exact, _ = make_posterior_fn(spec, noise=m)(ev)
    _assert_3sigma(post, exact, acc)


def test_unfused_matches_perturbed_oracle_3sigma():
    spec = by_name("pedestrian-night")
    m = NoiseModel()
    net = compile_network(spec, n_bits=N_BITS, fused=False, noise=m)
    ev = sample_evidence(spec, jax.random.PRNGKey(2), 64)
    post, acc = net.run(jax.random.PRNGKey(0), ev)
    exact, _ = make_posterior_fn(spec, noise=m)(ev)
    _assert_3sigma(post, exact, acc)


def test_noise_shifts_the_oracle():
    """The nominal model moves posteriors by much more than the DAC grid --
    agreement with the PERTURBED oracle is a real constraint, not slack."""
    spec = by_name("pedestrian-night")
    ev = sample_evidence(spec, jax.random.PRNGKey(2), 256)
    clean, _ = make_posterior_fn(spec, dac_quantize=True)(ev)
    noisy, _ = make_posterior_fn(spec, noise=NoiseModel())(ev)
    assert float(np.max(np.abs(np.asarray(clean) - np.asarray(noisy)))) > 0.02
