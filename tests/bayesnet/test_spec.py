"""Spec validation: CPT shapes, DAG checks, topological order, cardinalities."""

import pytest

from repro.bayesnet.spec import NetworkSpec, Node, chain, value_bits


def test_topo_order_respects_edges():
    spec = NetworkSpec(
        name="t",
        nodes=(
            Node("c", ("a", "b"), (0.1, 0.2, 0.3, 0.4)),
            Node("a", (), (0.5,)),
            Node("b", ("a",), (0.2, 0.8)),
        ),
    )
    order = spec.topo_order()
    assert order.index("a") < order.index("b") < order.index("c")
    assert spec.roots() == ("a",)
    assert spec.max_fan_in() == 2


def test_cpt_length_must_match_fan_in():
    with pytest.raises(ValueError, match="CPT rows"):
        Node("x", ("a", "b"), (0.1, 0.2))


def test_cpt_probabilities_bounded():
    with pytest.raises(ValueError, match="outside"):
        Node("x", (), (1.5,))


def test_unknown_parent_rejected():
    with pytest.raises(ValueError, match="unknown parent"):
        NetworkSpec(name="t", nodes=(Node("x", ("ghost",), (0.1, 0.9)),))


def test_cycle_rejected():
    with pytest.raises(ValueError, match="cycle"):
        NetworkSpec(
            name="t",
            nodes=(Node("a", ("b",), (0.1, 0.9)), Node("b", ("a",), (0.2, 0.8))),
        )


def test_duplicate_names_rejected():
    with pytest.raises(ValueError, match="duplicate"):
        NetworkSpec(name="t", nodes=(Node("a"), Node("a")))


def test_unknown_evidence_rejected():
    with pytest.raises(ValueError, match="evidence/query"):
        NetworkSpec(name="t", nodes=(Node("a"),), evidence=("b",))


def test_kary_cardinality_accessors():
    spec = NetworkSpec(
        name="k",
        nodes=(
            Node.categorical("w", (), ((0.5, 0.3, 0.2),)),
            Node("rain", ("w",), ((0.9, 0.1), (0.4, 0.6), (0.2, 0.8)), k=2),
        ),
    )
    assert spec.card("w") == 3 and spec.card("rain") == 2
    assert spec.cards() == (3, 2) and spec.cards(("rain", "w")) == (2, 3)
    assert spec.max_card() == 3
    assert spec.cpt_rows("rain") == ((0.9, 0.1), (0.4, 0.6), (0.2, 0.8))
    assert [value_bits(k) for k in (2, 3, 4, 5, 8, 9)] == [1, 2, 2, 3, 3, 4]


def test_kary_node_needs_k_mismatched_parent_rows_rejected():
    tri = Node.categorical("t", (), ((0.2, 0.3, 0.5),))
    with pytest.raises(ValueError, match="CPT rows"):
        # flat binary node declares 2 rows, but the k=3 parent needs 3
        NetworkSpec(name="bad", nodes=(tri, Node("c", ("t",), (0.1, 0.9))))


def test_mixed_flat_nested_cpt_rejected():
    with pytest.raises(ValueError, match="mixed"):
        Node("x", ("a",), ((0.5, 0.5), 0.3))


def test_chain_builder():
    spec = chain("c3", [0.3], [(0.9, 0.2), (0.8, 0.1)])
    assert spec.n_nodes == 3
    assert spec.topo_order() == ("x0", "x1", "x2")
    # cpt index 1 = parent value 1
    assert spec.node("x1").cpt == (0.2, 0.9)
