"""Calibrate-back: compensation cancels drift, fits recover the generator.

* **compensation** -- programming ``clean / error_factors`` thresholds makes
  the drifted array land within a DAC step or two of the clean thresholds,
  where the uncompensated array is tens of steps off at high wear.
* **oracle-level accuracy** -- the compensated perturbed-CPT oracle sits
  closer to the clean DAC-quantised posterior than the open-loop one.
* **hot recalibration** -- ``recalibrated_network`` is a drop-in
  ``swap_net`` target; ``recalibrate_driver`` defaults the cycle to the
  driver's launch counter; clean networks refuse (nothing to calibrate).
* **rollout fitting** -- ``fit_scene_config`` recovers the generating
  :class:`SceneConfig` from counted confusion statistics within sampling
  tolerance, and ``calibration_report`` quantifies bias/variance plus the
  per-scenario DAC deviation of the rebuilt CPTs.
"""

import jax
import numpy as np
import pytest

from repro.bayesnet import (
    FrameDriver,
    NoiseModel,
    by_name,
    calibration_report,
    compensated_program,
    compile_network,
    fit_scene_config,
    make_posterior_fn,
    perturbed_cdf_rows,
    recalibrate_driver,
    recalibrated_network,
    sample_evidence,
)
from repro.core import rng
from repro.data.detection import SceneConfig

KEY = jax.random.PRNGKey(11)
NM = NoiseModel(seed=3, cycle=20.0, wear_tau=2.0, p_stuck_on=0.0, p_stuck_off=0.0)


def _max_dev_vs_clean(spec, noise, program):
    """Max |effective - clean| DAC threshold deviation across all nodes."""
    eff = perturbed_cdf_rows(spec, noise, program=program)
    dev = 0
    for name in spec.topo_order():
        clean = [rng.cdf_thresholds_int(r) for r in spec.cpt_rows(name)]
        for crow, erow in zip(clean, eff[name]):
            for c, e in zip(crow, erow):
                dev = max(dev, abs(int(c) - int(e)))
    return dev


def test_compensated_program_cancels_predicted_drift():
    spec = by_name("obstacle-class")
    prog = compensated_program(spec, NM)
    closed = _max_dev_vs_clean(spec, NM, prog)
    open_loop = _max_dev_vs_clean(spec, NM, None)
    assert closed <= 2
    assert open_loop > 5
    assert closed < open_loop


def test_compensation_helps_at_any_cycle_for_static_terms():
    # d2d + IR are cycle-independent, so even a cycle-0 compensation beats
    # open loop at cycle 0 (the read-noise term is small there).
    spec = by_name("pedestrian-night")
    nm0 = NoiseModel(seed=5, cycle=0.0, wear_tau=2.0, p_stuck_on=0.0, p_stuck_off=0.0)
    prog = compensated_program(spec, nm0)
    assert _max_dev_vs_clean(spec, nm0, prog) <= _max_dev_vs_clean(spec, nm0, None)


def test_compensated_oracle_closer_to_clean_posterior():
    spec = by_name("obstacle-class")
    ev = np.asarray(sample_evidence(spec, KEY, 8))
    clean_fn = make_posterior_fn(spec, dac_quantize=True)
    open_fn = make_posterior_fn(spec, noise=NM)
    closed_fn = make_posterior_fn(
        spec, noise=NM, program=compensated_program(spec, NM)
    )
    ref, _ = clean_fn(ev)
    po, _ = open_fn(ev)
    pc, _ = closed_fn(ev)
    err_open = float(np.mean(np.abs(np.asarray(po) - np.asarray(ref))))
    err_closed = float(np.mean(np.abs(np.asarray(pc) - np.asarray(ref))))
    assert err_closed < err_open


def test_recalibrated_network_is_dropin_and_programmed():
    net = compile_network(
        by_name("pedestrian-night"), 512, noise=NoiseModel(seed=2, wear_tau=2.0),
        drift_epochs=2, devices=1,
    )
    recal = recalibrated_network(net, cycle=10.0)
    assert recal.evidence == net.evidence
    assert recal.query_cards == net.query_cards
    assert recal.n_bits == net.n_bits
    assert recal.drift_epochs == net.drift_epochs
    assert recal.noise.cycle == 10.0
    assert recal.program is not None and set(recal.program) == set(
        net.spec.topo_order()
    )


def test_recalibrated_network_refuses_clean_nets():
    net = compile_network(by_name("sensor-degradation"), 128, devices=1)
    with pytest.raises(ValueError):
        recalibrated_network(net, cycle=5.0)


def test_recalibrate_driver_defaults_to_launch_counter():
    spec = by_name("sensor-degradation")
    net = compile_network(
        spec, 256, noise=NoiseModel(seed=4, wear_tau=2.0), devices=1
    )
    drv = FrameDriver(net, max_batch=4, salt=13)
    ev = np.asarray(sample_evidence(spec, KEY, 8))
    drv.submit(ev)
    out1 = drv.drain()
    launches = drv.launches
    assert launches > 0
    swapped = recalibrate_driver(drv)
    assert drv.net is swapped
    assert swapped.noise.cycle == float(launches)
    drv.submit(ev)
    out2 = drv.drain()
    assert len(out2) == len(out1)   # the swapped driver still serves


def test_fit_scene_config_recovers_generator():
    ref = SceneConfig()
    fit = fit_scene_config(jax.random.PRNGKey(0), ref, n_scenes=40)
    assert abs(fit.night_fraction - ref.night_fraction) <= 0.25
    assert abs(fit.rgb_vis_day - ref.rgb_vis_day) <= 0.15
    assert abs(fit.rgb_vis_night - ref.rgb_vis_night) <= 0.20
    assert abs(fit.thermal_vis - ref.thermal_vis) <= 0.20
    assert abs(fit.strong - ref.strong) <= 0.03
    assert abs(fit.weak - ref.weak) <= 0.03
    assert fit.strong > fit.weak
    # geometry passes through untouched
    assert (fit.height, fit.width, fit.n_obstacles) == (
        ref.height, ref.width, ref.n_obstacles
    )


def test_calibration_report_structure_and_bounds():
    rep = calibration_report(
        jax.random.PRNGKey(1), n_scenes=24, repeats=2
    )
    assert set(rep["fields"]) == {
        "night_fraction", "rgb_vis_day", "rgb_vis_night",
        "thermal_vis", "strong", "weak",
    }
    for f, stats in rep["fields"].items():
        assert stats["bias"] == pytest.approx(
            stats["mean"] - stats["reference"]
        )
        assert stats["std"] >= 0.0
    assert len(rep["scenario_dac_deviation"]) == 7
    assert rep["max_dac_deviation"] == max(rep["scenario_dac_deviation"].values())
    # a sane fit never rebuilds CPTs more than a quarter of the grid away
    assert rep["max_dac_deviation"] <= 64
