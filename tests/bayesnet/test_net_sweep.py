"""Threshold-gather + fused net_sweep correctness.

The equivalence chain: gather-mode node_mux matches row-encode node_mux on
parent-conditional bit means; both kernels match their jnp refs bit-exactly;
the fused whole-network sweep matches its jnp ref bit-exactly, the unfused
compiled program statistically, and the enumeration oracle within 3 sigma on
randomized DAGs and on every scenario network.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypcompat import given, settings, st

from repro.bayesnet import (
    SCENARIOS,
    by_name,
    compile_network,
    make_posterior_fn,
    sample_evidence,
    sweep_plan,
)
from repro.bayesnet.spec import NetworkSpec, Node
from repro.core import bitops, rng
from repro.kernels.net_sweep import net_sweep
from repro.kernels.node_mux import node_mux

N_BITS = 1 << 14


# --- threshold-gather node_mux vs the row-encode baseline --------------------------

def _conditional_means(out, parents, n_bits):
    """Mean of the output bit per parent assignment (first parent = MSB)."""
    m = parents.shape[0]
    pb = np.stack([np.asarray(bitops.unpack_bits(parents[j], n_bits))[0] for j in range(m)])
    ob = np.asarray(bitops.unpack_bits(out, n_bits))[0]
    idx = np.zeros(n_bits, np.int64)
    for j in range(m):
        idx = (idx << 1) | pb[j]
    means, counts = [], []
    for row in range(1 << m):
        sel = idx == row
        means.append(ob[sel].mean())
        counts.append(sel.sum())
    return np.asarray(means), np.asarray(counts)


@pytest.mark.parametrize("mode", ["gather", "rows"])
def test_node_mux_modes_parent_conditional_bit_means(mode):
    """Both formulations sample Bernoulli(cpt[row]) conditional on the parents:
    the gather mode is distributionally identical to row-encode, with 2^m x
    less entropy."""
    m = 2
    cpt = jnp.array([[0.08, 0.35, 0.72, 0.94]])
    parents = rng.fair_bits(jax.random.PRNGKey(2), (m, 1), N_BITS)
    out = node_mux(jax.random.PRNGKey(3), cpt, parents, N_BITS, mode=mode, use_kernel=False)
    means, counts = _conditional_means(out, parents, N_BITS)
    want = np.asarray(cpt[0])
    sigma = np.sqrt(want * (1 - want) / counts)
    assert np.all(np.abs(means - want) < 4 * sigma + 2 / 256), (mode, means, want)


def test_gather_and_rows_agree_on_marginal():
    """Same key, same parents: the two modes' marginals differ only by noise."""
    cpt = jnp.broadcast_to(jnp.array([0.15, 0.55, 0.65, 0.85]), (8, 4))
    parents = rng.fair_bits(jax.random.PRNGKey(9), (2, 8), N_BITS)
    pg = bitops.decode(node_mux(jax.random.PRNGKey(4), cpt, parents, N_BITS,
                                mode="gather", use_kernel=False), N_BITS)
    pr = bitops.decode(node_mux(jax.random.PRNGKey(4), cpt, parents, N_BITS,
                                mode="rows", use_kernel=False), N_BITS)
    tol = 8 * np.sqrt(0.25 / N_BITS)
    np.testing.assert_allclose(np.asarray(pg), np.asarray(pr), atol=2 * tol)


@pytest.mark.parametrize("mode", ["gather", "rows"])
def test_node_mux_kernel_bitexact_both_modes(mode):
    r, m, n_bits = 32, 3, 1024
    cpt = jax.random.uniform(jax.random.PRNGKey(1), (r, 1 << m))
    parents = rng.fair_bits(jax.random.PRNGKey(2), (m, r), n_bits)
    ref = node_mux(jax.random.PRNGKey(3), cpt, parents, n_bits, mode=mode, use_kernel=False)
    ker = node_mux(jax.random.PRNGKey(3), cpt, parents, n_bits, mode=mode,
                   use_kernel=True, interpret=True)
    assert bool(jnp.all(ref == ker))


# --- fused net_sweep ----------------------------------------------------------------

def test_net_sweep_kernel_bitexact_vs_ref():
    """Tiled Pallas accumulation == single-tile jnp ref, counts and all."""
    spec = by_name("pedestrian-night")
    plan = sweep_plan(spec, spec.queries, spec.evidence)
    ev = sample_evidence(spec, jax.random.PRNGKey(1), 64)
    nk, dk = net_sweep(jax.random.PRNGKey(0), ev, plan=plan, n_bits=2048,
                       use_kernel=True, interpret=True)
    nr, dr = net_sweep(jax.random.PRNGKey(0), ev, plan=plan, n_bits=2048,
                       use_kernel=False)
    np.testing.assert_array_equal(np.asarray(nk), np.asarray(nr))
    np.testing.assert_array_equal(np.asarray(dk), np.asarray(dr))


def test_net_sweep_kernel_bitexact_fan_in_three():
    spec = by_name("intersection")
    plan = sweep_plan(spec, spec.queries, spec.evidence)
    ev = sample_evidence(spec, jax.random.PRNGKey(5), 16)
    nk, dk = net_sweep(jax.random.PRNGKey(3), ev, plan=plan, n_bits=1024,
                       use_kernel=True, interpret=True)
    nr, dr = net_sweep(jax.random.PRNGKey(3), ev, plan=plan, n_bits=1024,
                       use_kernel=False)
    np.testing.assert_array_equal(np.asarray(nk), np.asarray(nr))
    np.testing.assert_array_equal(np.asarray(dk), np.asarray(dr))


@pytest.mark.parametrize("name", ["obstacle-class", "intersection-cat"])
def test_net_sweep_kernel_bitexact_categorical(name):
    """Tiled Pallas accumulation == jnp ref on k-ary plans (multi-slot numer)."""
    spec = by_name(name)
    plan = sweep_plan(spec, spec.queries, spec.evidence)
    assert plan.n_value_slots > len(spec.queries)    # a real k-ary query set
    ev = sample_evidence(spec, jax.random.PRNGKey(6), 16)
    nk, dk = net_sweep(jax.random.PRNGKey(4), ev, plan=plan, n_bits=1024,
                       use_kernel=True, interpret=True)
    nr, dr = net_sweep(jax.random.PRNGKey(4), ev, plan=plan, n_bits=1024,
                       use_kernel=False)
    assert nk.shape == (16, plan.n_value_slots)
    np.testing.assert_array_equal(np.asarray(nk), np.asarray(nr))
    np.testing.assert_array_equal(np.asarray(dk), np.asarray(dr))


def _zmax(post, exact, accepted, floor=1e-3):
    """Shape-agnostic: post is (B, n_q) for binary queries, (B, n_q, k) k-ary."""
    post, exact = np.asarray(post), np.asarray(exact)
    acc = np.asarray(accepted).reshape((-1,) + (1,) * (post.ndim - 1))
    sig = np.sqrt(np.clip(exact * (1 - exact), floor, None) / np.maximum(acc, 1))
    keep = np.broadcast_to(acc > 50, post.shape)
    return float(np.max(np.abs(post - exact)[keep] / sig[keep]))


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_fused_matches_unfused_every_scenario(name):
    """The fused sweep and the per-node program are two samplers of the same
    quantised network: both must sit within stochastic noise of the oracle,
    frame by frame (binary AND categorical scenarios alike)."""
    spec = by_name(name)
    ev = sample_evidence(spec, jax.random.PRNGKey(11), 64)
    exact, _ = make_posterior_fn(spec, dac_quantize=True)(ev)
    fused = compile_network(spec, n_bits=N_BITS, share_entropy=False, fused=True)
    unfused = compile_network(spec, n_bits=N_BITS, share_entropy=False, fused=False)
    assert fused.fused and not unfused.fused
    pf, af = fused.run(jax.random.PRNGKey(0), ev)
    pu, au = unfused.run(jax.random.PRNGKey(0), ev)
    assert _zmax(pf, exact, af) < 5.0, name
    assert _zmax(pu, exact, au) < 5.0, name
    # the two estimates differ only by their independent stochastic noise
    pf, pu, exact = np.asarray(pf), np.asarray(pu), np.asarray(exact)
    lead = (-1,) + (1,) * (pf.ndim - 1)
    af_, au_ = np.asarray(af).reshape(lead), np.asarray(au).reshape(lead)
    sig = np.sqrt(
        np.clip(exact * (1 - exact), 1e-3, None)
        * (1 / np.maximum(af_, 1) + 1 / np.maximum(au_, 1))
    )
    keep = np.broadcast_to((af_ > 50) & (au_ > 50), sig.shape)
    z = np.abs(pf - pu) / sig
    assert float(np.max(z[keep])) < 5.0, name


def _random_dag(seed: int) -> NetworkSpec:
    """Random 4-7 node DAG with <=3 parents; CPTs on the 8-bit DAC grid so the
    float oracle and the quantised stochastic path sample identical networks."""
    rs = np.random.RandomState(seed)
    n = int(rs.randint(4, 8))
    nodes = []
    for i in range(n):
        k = int(min(i, rs.randint(0, 4)))
        parents = tuple(f"n{j}" for j in sorted(rs.choice(i, size=k, replace=False))) if k else ()
        cpt = tuple(rs.randint(26, 231, size=1 << len(parents)) / 256.0)
        nodes.append(Node(f"n{i}", parents, cpt))
    names = [nd.name for nd in nodes]
    n_ev = int(rs.randint(1, 3))
    ev = tuple(str(e) for e in rs.choice(names[1:], size=min(n_ev, n - 1), replace=False))
    queries = tuple(nm for nm in names if nm not in ev)[:2]
    return NetworkSpec(name=f"rand{seed}", nodes=tuple(nodes),
                       evidence=ev, queries=queries)


@settings(deadline=None, max_examples=10)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_fused_randomized_dags_match_enumeration_oracle(seed):
    """Fused posteriors agree with exact enumeration on random DAGs."""
    spec = _random_dag(seed)
    oracle = make_posterior_fn(spec)      # CPTs already on the DAC grid
    frames = jnp.stack([
        jnp.zeros((len(spec.evidence),), jnp.int32),
        jnp.ones((len(spec.evidence),), jnp.int32),
    ])
    exact, _ = oracle(frames)
    net = compile_network(spec, n_bits=N_BITS, share_entropy=False, fused=True)
    post, acc = net.run(jax.random.PRNGKey(seed), frames)
    if not bool(np.any(np.asarray(acc) > 50)):
        return                            # evidence too unlikely at this n_bits
    assert _zmax(post, exact, acc) < 4.0, spec.name


def test_deterministic_nodes_and_extreme_thresholds():
    """p=0 and p=1 nodes short-circuit (no planes) and stay exact."""
    spec = NetworkSpec(
        name="extremes",
        nodes=(
            Node("a", (), (1.0,)),
            Node("b", (), (0.0,)),
            Node("c", ("a", "b"), (0.3, 1.0, 0.25, 0.0)),
        ),
        evidence=(),
        queries=("a", "b", "c"),
    )
    net = compile_network(spec, n_bits=4096, evidence=())
    post, acc = net.run(jax.random.PRNGKey(0), jnp.zeros((2, 0), jnp.int32))
    post = np.asarray(post)
    assert np.all(np.asarray(acc) == 4096)
    np.testing.assert_allclose(post[:, 0], 1.0)           # a always fires
    np.testing.assert_allclose(post[:, 1], 0.0)           # b never fires
    # c: parents fixed at (a=1, b=0) -> row 10 -> P(c) = 0.25
    sigma = np.sqrt(0.25 * 0.75 / 4096)
    assert np.all(np.abs(post[:, 2] - 0.25) < 4 * sigma + 2 / 256)


@pytest.mark.parametrize("name", sorted(SCENARIOS))
def test_fused_decide_bit_identical_to_posterior_argmax(name):
    """The in-kernel decision epilogue == argmaxing `run`'s posterior, and the
    posterior rides along unchanged -- one launch, same numbers."""
    spec = by_name(name)
    ev = sample_evidence(spec, jax.random.PRNGKey(21), 48)
    net = compile_network(spec, n_bits=2048)
    assert net.fused
    post, acc = net.run(jax.random.PRNGKey(2), ev)
    post_d, dec, acc_d = net.decide(jax.random.PRNGKey(2), ev)
    post, dec = np.asarray(post), np.asarray(dec)
    np.testing.assert_array_equal(post, np.asarray(post_d))
    np.testing.assert_array_equal(np.asarray(acc), np.asarray(acc_d))
    if post.ndim == 2:      # binary: value 1 iff P(q=1) > 0.5, ties to 0
        want = (post > 0.5).astype(np.int32)
    else:
        want = np.argmax(post, axis=-1).astype(np.int32)
    np.testing.assert_array_equal(dec, want)


def test_fused_decide_tie_break_matches_posterior_argmax():
    """Regression: exact count ties (here accepted=3 split 1/1/1 across
    values 0/2/3) once flipped the float argmax because P(0) was computed as
    1 - sum(float slots), one ULP below the tied slots.  The count-exact
    assembler makes equal counts equal floats, so the identity holds even in
    the deep low-acceptance regime."""
    spec = by_name("obstacle-class")
    net = compile_network(spec, n_bits=512)
    ev = sample_evidence(spec, jax.random.PRNGKey(0), 64)
    post, dec, acc = net.decide(jax.random.PRNGKey(100), ev)
    post, dec = np.asarray(post), np.asarray(dec)
    np.testing.assert_array_equal(dec, np.argmax(post, axis=-1))
    # equal counts -> equal floats: the tied frame's vector is exactly uniform
    assert np.any(np.asarray(acc) < 10)     # the regime that exposed the bug


def test_fused_and_unfused_decide_agree():
    """Counts-argmax (fused) and posterior-argmax (unfused) are the same
    decision rule over the same tie-break."""
    spec = by_name("obstacle-class")
    ev = sample_evidence(spec, jax.random.PRNGKey(5), 32)
    fused = compile_network(spec, n_bits=1 << 14)
    unfused = compile_network(spec, n_bits=1 << 14, fused=False)
    _, dec_f, _ = fused.decide(jax.random.PRNGKey(1), ev)
    post_u, dec_u, _ = unfused.decide(jax.random.PRNGKey(1), ev)
    # two independent samplers: decisions agree wherever the posterior is not
    # on the decision boundary within stochastic noise; check the rule itself
    np.testing.assert_array_equal(
        np.asarray(dec_u), np.argmax(np.asarray(post_u), axis=-1)
    )
    agree = np.mean(np.asarray(dec_f) == np.asarray(dec_u))
    assert agree > 0.9, agree


def test_net_sweep_decide_kernel_bitexact_multi_word_tile():
    """The kernel's decide path is bit-exact vs the ref both when the word
    axis fits one tile (in-register epilogue) and when it is tiled (epilogue
    over the summed partials)."""
    from repro.core import rng as _rng
    from repro.kernels.net_sweep.kernel import net_sweep_pallas

    spec = by_name("intersection-cat")
    plan = sweep_plan(spec, spec.queries, spec.evidence)
    ev = sample_evidence(spec, jax.random.PRNGKey(6), 16)
    kd = _rng.seed_words(jax.random.PRNGKey(4))
    nr, dr, decr = net_sweep(jax.random.PRNGKey(4), ev, plan=plan,
                             n_bits=2048, decide=True, use_kernel=False)
    for block_w in (64, 16):     # 64 words = one tile; 16 = four tiles
        nk, dk, deck = net_sweep_pallas(
            kd, jnp.asarray(ev, jnp.int32), plan=plan, n_bits=2048,
            decide=True, block_w=block_w, interpret=True,
        )
        np.testing.assert_array_equal(np.asarray(nk), np.asarray(nr))
        np.testing.assert_array_equal(np.asarray(dk), np.asarray(dr))
        np.testing.assert_array_equal(np.asarray(deck), np.asarray(decr))


def test_sweep_tile_decide_rejects_partial_word_tiles():
    from repro.kernels.net_sweep.common import sweep_tile

    spec = by_name("pedestrian-night")
    plan = sweep_plan(spec, spec.queries, spec.evidence)
    ev = jnp.zeros((4, len(plan.evidence)), jnp.int32)
    with pytest.raises(ValueError, match="full word axis"):
        sweep_tile(plan, jnp.uint32(1), jnp.uint32(2), ev, 0, 0, 4, 8, 16, 4,
                   decide=True)


def test_fused_requires_ratio_and_independent_entropy():
    spec = by_name("sensor-degradation")
    with pytest.raises(ValueError):
        compile_network(spec, n_bits=1024, share_entropy=True, fused=True)
    with pytest.raises(ValueError):
        compile_network(spec, n_bits=1024, estimator="fill", fused=True)
    with pytest.raises(ValueError):
        compile_network(spec, n_bits=1024, mux_mode="rows", fused=True)
    # auto-resolution picks the only valid lowering in each case
    assert compile_network(spec, n_bits=1024, share_entropy=True).fused is False
    assert compile_network(spec, n_bits=1024, estimator="fill").fused is False
    # an explicit row-encode request means the unfused per-node lowering
    assert compile_network(spec, n_bits=1024, mux_mode="rows").fused is False
    assert compile_network(spec, n_bits=1024).fused is True
    # frame sharding is a fused-only feature (unfused entropy is batch-shaped)
    with pytest.raises(ValueError, match="fused"):
        compile_network(spec, n_bits=1024, share_entropy=True, devices=8)
    # devices=1 is the explicit single-device spelling, valid everywhere
    assert compile_network(spec, n_bits=1024, devices=1).n_shards == 1
