"""Sharded sweep semantics: bit-identity with the single-device program.

Two layers of evidence:

* **In-process stitching** (no extra devices needed): ``net_sweep`` with
  ``frame0`` / ``total_frames`` composes shards by hand and must reproduce
  the full-batch launch word-for-word -- the counter-entropy argument
  (DESIGN.md §11) reduced to its mechanical core.
* **Real 8-device shard_map** (subprocess, like
  ``tests/distributed/test_multidevice.py``, because jax pins the device
  count at first init): ``compile_network(devices=8)`` must match the
  single-device program bit-for-bit on every scenario -- binary and
  categorical -- and on randomized k-ary DAGs, for both ``run`` and the
  fused ``decide`` epilogue, with indivisible batches falling back cleanly.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.bayesnet import by_name, sample_evidence, sweep_plan
from repro.kernels.net_sweep import net_sweep

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..", "src"))


@pytest.mark.parametrize("name", ["pedestrian-night", "obstacle-class"])
def test_hand_stitched_shards_bit_identical(name):
    """Three 8-frame shards with global origins == one 24-frame launch."""
    spec = by_name(name)
    plan = sweep_plan(spec, spec.queries, spec.evidence)
    ev = jnp.asarray(sample_evidence(spec, jax.random.PRNGKey(1), 24))
    key = jax.random.PRNGKey(0)
    nf, df = net_sweep(key, ev, plan=plan, n_bits=1024)
    parts = [
        net_sweep(key, ev[i * 8 : (i + 1) * 8], plan=plan, n_bits=1024,
                  frame0=i * 8, total_frames=24)
        for i in range(3)
    ]
    np.testing.assert_array_equal(
        np.asarray(nf), np.concatenate([np.asarray(p[0]) for p in parts])
    )
    np.testing.assert_array_equal(
        np.asarray(df), np.concatenate([np.asarray(p[1]) for p in parts])
    )


def test_stitched_kernel_matches_ref_with_frame_origin():
    """The Pallas kernel honours the global frame origin exactly as the ref."""
    spec = by_name("intersection-cat")
    plan = sweep_plan(spec, spec.queries, spec.evidence)
    ev = jnp.asarray(sample_evidence(spec, jax.random.PRNGKey(2), 16))
    key = jax.random.PRNGKey(3)
    for f0 in (0, 8):
        nk, dk = net_sweep(key, ev[f0 : f0 + 8], plan=plan, n_bits=1024,
                           frame0=f0, total_frames=16,
                           use_kernel=True, interpret=True)
        nr, dr = net_sweep(key, ev[f0 : f0 + 8], plan=plan, n_bits=1024,
                           frame0=f0, total_frames=16, use_kernel=False)
        np.testing.assert_array_equal(np.asarray(nk), np.asarray(nr))
        np.testing.assert_array_equal(np.asarray(dk), np.asarray(dr))


SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
import numpy as np

from repro.bayesnet import (
    SCENARIOS, by_name, compile_network, sample_evidence, FrameDriver,
)
from repro.bayesnet.spec import NetworkSpec, Node
from repro.distributed import context as dctx

assert len(jax.devices()) == 8
key = jax.random.PRNGKey(0)

# --- every scenario: sharded == single-device, run AND decide, bit for bit --
for name in sorted(SCENARIOS):
    spec = by_name(name)
    ev = np.asarray(sample_evidence(spec, jax.random.PRNGKey(1), 16))
    single = compile_network(spec, n_bits=512)
    shard = compile_network(spec, n_bits=512, devices=8)
    assert shard.n_shards == 8 and shard.shard_axes == ("frames",), name
    p1, a1 = single.run(key, ev)
    p8, a8 = shard.run(key, ev)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a8))
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p8))
    pd1, d1, ad1 = single.decide(key, ev)
    pd8, d8, ad8 = shard.decide(key, ev)
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d8))
    np.testing.assert_array_equal(np.asarray(pd8), np.asarray(p1))
    np.testing.assert_array_equal(np.asarray(ad8), np.asarray(a1))
    # decisions argmax the posterior (binary: value 1 iff P > 0.5)
    post = np.asarray(p1)
    want = (post > 0.5).astype(np.int32) if post.ndim == 2 \
        else np.argmax(post, axis=-1).astype(np.int32)
    np.testing.assert_array_equal(np.asarray(d1), want)
    # indivisible batch falls back to the single-device launch
    p_odd, _ = shard.run(key, ev[:13])
    assert np.asarray(p_odd).shape[0] == 13
    print("scenario ok:", name)

# --- randomized k-ary DAGs ---------------------------------------------------
rs = np.random.RandomState(0)
for trial in range(4):
    n = int(rs.randint(4, 8))
    nodes = []
    for i in range(n):
        card = int(rs.randint(2, 5))
        m = int(min(i, rs.randint(0, 3)))
        parents = tuple(
            f"n{j}" for j in sorted(rs.choice(i, size=m, replace=False))
        ) if m else ()
        pcards = [next(nd.k for nd in nodes if nd.name == p) for p in parents]
        n_rows = int(np.prod(pcards)) if pcards else 1
        # plain floats: sharded-vs-single compares two lowerings of the SAME
        # quantised network, no oracle involved, so no DAC-grid snapping needed
        rows = tuple(tuple(rs.dirichlet(np.ones(card))) for _ in range(n_rows))
        nodes.append(Node(f"n{i}", parents, rows, k=card))
    names = [nd.name for nd in nodes]
    ev_names = tuple(str(e) for e in rs.choice(names[1:], size=2, replace=False))
    queries = tuple(nm for nm in names if nm not in ev_names)[:2]
    spec = NetworkSpec(name=f"rand{trial}", nodes=tuple(nodes),
                       evidence=ev_names, queries=queries)
    frames = np.zeros((8, len(ev_names)), np.int32)
    for c, e in enumerate(ev_names):
        frames[:, c] = rs.randint(0, spec.card(e), size=8)
    single = compile_network(spec, n_bits=512)
    shard = compile_network(spec, n_bits=512, devices=8)
    p1, a1 = single.run(jax.random.PRNGKey(trial), frames)
    p8, a8 = shard.run(jax.random.PRNGKey(trial), frames)
    np.testing.assert_array_equal(np.asarray(p1), np.asarray(p8))
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a8))
    print("random dag ok:", trial, spec.name)

# --- ambient mesh pickup + sharded FrameDriver async == sync ----------------
spec = by_name("sensor-degradation")
with dctx.mesh_context(dctx.frame_mesh(8)):
    net = compile_network(spec, n_bits=512)
assert net.n_shards == 8
ev = np.asarray(sample_evidence(spec, jax.random.PRNGKey(7), 24))
sync = FrameDriver(net, max_batch=8, salt=11); sync.submit(ev)
pipe = FrameDriver(net, max_batch=8, salt=11); pipe.submit(ev)
rs_, rp = sync.drain(), pipe.drain_async()
assert sorted(rs_) == sorted(rp) == list(range(24))
for r in rs_:
    np.testing.assert_array_equal(rs_[r][0], rp[r][0])
    assert rs_[r][1] == rp[r][1]
print("sharded driver async == sync ok")
print("ALL OK")
"""


def test_sharded_eight_devices_bit_identical():
    """The full 8-device matrix, in a subprocess with forced host devices."""
    env = dict(os.environ, PYTHONPATH=SRC)
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env,
        capture_output=True, text=True, timeout=1800,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "ALL OK" in proc.stdout
