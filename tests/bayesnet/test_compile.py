"""Compiler correctness: Fig S8 motifs against core/graph.py analytic
posteriors, randomized DAGs against the enumeration oracle, and the node_mux
kernel against its jnp reference."""

import jax
import jax.numpy as jnp
import numpy as np
from hypcompat import given, settings, st

from repro.bayesnet import compile_network, make_posterior_fn
from repro.bayesnet.spec import NetworkSpec, Node
from repro.core import graph, rng
from repro.kernels.node_mux import node_mux

N_BITS = 1 << 14


def _zmax(post, exact, accepted, floor=1e-3):
    """Largest |error| / sigma over frames with a meaningful acceptance count."""
    post, exact = np.asarray(post), np.asarray(exact)
    acc = np.asarray(accepted)[:, None]
    sig = np.sqrt(np.clip(exact * (1 - exact), floor, None) / np.maximum(acc, 1))
    keep = np.broadcast_to(acc > 50, post.shape)
    return float(np.max(np.abs(post - exact)[keep] / sig[keep]))


def test_two_parent_motif_matches_graph_analytic():
    """Fig S8b as a spec: P(A1 | B=1) from the compiled network equals the
    hardcoded motif's analytic posterior within stochastic noise."""
    cpt = ((0.10, 0.60), (0.35, 0.90))
    spec = NetworkSpec(
        name="fig-s8b",
        nodes=(
            Node("a1", (), (0.30,)),
            Node("a2", (), (0.70,)),
            Node("b", ("a1", "a2"), tuple(cpt[0]) + tuple(cpt[1])),
        ),
        evidence=("b",),
        queries=("a1",),
    )
    net = compile_network(spec, n_bits=N_BITS)
    post, acc = net.run(jax.random.PRNGKey(0), jnp.array([[1]]))
    expect = float(graph.analytic_two_parent(0.30, 0.70, jnp.asarray(cpt)))
    sigma = np.sqrt(expect * (1 - expect) / float(acc[0]))
    assert abs(float(post[0, 0]) - expect) < 3 * sigma + 2 / 256, (
        float(post[0, 0]), expect, float(acc[0])
    )


def test_one_parent_two_child_motif_matches_graph_analytic():
    """Fig S8c as a spec: P(A | B1=1, B2=1) with two likelihood children."""
    p_a, p_b1, p_b2 = 0.40, (0.85, 0.20), (0.75, 0.30)
    spec = NetworkSpec(
        name="fig-s8c",
        nodes=(
            Node("a", (), (p_a,)),
            Node("b1", ("a",), (p_b1[1], p_b1[0])),   # cpt = (P|notA, P|A)
            Node("b2", ("a",), (p_b2[1], p_b2[0])),
        ),
        evidence=("b1", "b2"),
        queries=("a",),
    )
    net = compile_network(spec, n_bits=N_BITS)
    post, acc = net.run(jax.random.PRNGKey(1), jnp.array([[1, 1]]))
    expect = float(graph.analytic_one_parent_two_child(p_a, p_b1, p_b2))
    sigma = np.sqrt(expect * (1 - expect) / float(acc[0]))
    assert abs(float(post[0, 0]) - expect) < 3 * sigma + 2 / 256


def _random_dag(seed: int) -> NetworkSpec:
    """Random 4-7 node DAG with <=3 parents; CPTs on the 8-bit DAC grid so the
    float oracle and the quantised stochastic path sample identical networks."""
    rs = np.random.RandomState(seed)
    n = int(rs.randint(4, 8))
    nodes = []
    for i in range(n):
        k = int(min(i, rs.randint(0, 4)))
        parents = tuple(f"n{j}" for j in sorted(rs.choice(i, size=k, replace=False))) if k else ()
        cpt = tuple(rs.randint(26, 231, size=1 << len(parents)) / 256.0)
        nodes.append(Node(f"n{i}", parents, cpt))
    names = [nd.name for nd in nodes]
    n_ev = int(rs.randint(1, 3))
    ev = tuple(str(e) for e in rs.choice(names[1:], size=min(n_ev, n - 1), replace=False))
    queries = tuple(nm for nm in names if nm not in ev)[:2]
    return NetworkSpec(name=f"rand{seed}", nodes=tuple(nodes),
                       evidence=ev, queries=queries)


@settings(deadline=None, max_examples=10)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_randomized_dags_match_enumeration_oracle(seed):
    """Both entropy modes and both estimators agree with exact enumeration."""
    spec = _random_dag(seed)
    oracle = make_posterior_fn(spec)      # CPTs already on the DAC grid
    frames = jnp.stack([
        jnp.zeros((len(spec.evidence),), jnp.int32),
        jnp.ones((len(spec.evidence),), jnp.int32),
    ])
    exact, _ = oracle(frames)
    for share, estimator in ((True, "ratio"), (False, "fill")):
        net = compile_network(
            spec, n_bits=N_BITS, share_entropy=share, estimator=estimator
        )
        post, acc = net.run(jax.random.PRNGKey(seed), frames)
        if not bool(np.any(np.asarray(acc) > 50)):
            continue                      # evidence too unlikely at this n_bits
        assert _zmax(post, exact, acc) < 4.0, (spec.name, share, estimator)


def test_estimators_and_entropy_modes_consistent():
    """fill vs ratio on the same compiled program differ only by stream noise.

    Pins ``share_entropy=True`` so both estimators condition the *same*
    unfused streams (the production default now lowers ratio to the fused
    sweep, whose entropy is drawn differently)."""
    spec = _random_dag(7)
    frames = jnp.zeros((4, len(spec.evidence)), jnp.int32)
    a, acc_a = compile_network(
        spec, n_bits=N_BITS, share_entropy=True, estimator="ratio"
    ).run(jax.random.PRNGKey(0), frames)
    b, acc_b = compile_network(
        spec, n_bits=N_BITS, share_entropy=True, estimator="fill"
    ).run(jax.random.PRNGKey(0), frames)
    # same entropy, same acceptance stream -> identical counts; estimates close
    np.testing.assert_array_equal(np.asarray(acc_a), np.asarray(acc_b))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0.05)


def test_node_mux_kernel_matches_ref_bitexact():
    key = jax.random.PRNGKey(3)
    r, m, n_bits = 32, 3, 1024
    cpt = jax.random.uniform(jax.random.PRNGKey(1), (r, 1 << m))
    parents = rng.fair_bits(jax.random.PRNGKey(2), (m, r), n_bits)
    ref = node_mux(key, cpt, parents, n_bits, use_kernel=False)
    ker = node_mux(key, cpt, parents, n_bits, use_kernel=True, interpret=True)
    assert bool(jnp.all(ref == ker))
    # expectation sanity: P(out) = E_parents[cpt[idx]]; fair selects -> mean cpt
    from repro.core import bitops
    p_est = np.asarray(bitops.decode(ref, n_bits))
    p_true = np.asarray(cpt.mean(-1))
    assert np.max(np.abs(p_est - p_true)) < 4 * np.sqrt(0.25 / n_bits) + 2 / 256
