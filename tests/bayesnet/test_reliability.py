"""Confidence signal, retry policy, and the retrying FrameDriver.

* **signal**: hand-computed margin z-scores and Phi values, monotonicity in
  the accepted count, zero confidence on rejected frames, flip-rate scoring.
* **driver**: confidence-gated retry escalates n_bits per attempt (lazily
  compiled, cached), exhausts its budget into a flagged-unreliable frame
  (never a drop), keeps rid -> frame mapping through the retry queue, and
  aggregates honest ReliabilityStats; ``retry=None`` stays the legacy driver.
* **watchdog**: slow dispatches land in ``stats.slow_launches``.
"""

import math

import jax
import numpy as np
import pytest

from repro.bayesnet import (
    FrameDriver,
    FrameReport,
    NoiseModel,
    ReliabilityStats,
    RetryPolicy,
    by_name,
    compile_network,
    decision_confidence,
    flip_rate,
    sample_evidence,
)
from repro.bayesnet.reliability import top2_margin_z
from repro.bayesnet.spec import NetworkSpec, Node


def _phi(z):
    return 0.5 * (1.0 + math.erf(z / math.sqrt(2.0)))


# --- the confidence signal ---------------------------------------------------------

def test_margin_z_binary_hand_computed():
    # p=0.75, acc=100: counts 75/25, z = 50 / sqrt(100) = 5.
    z = top2_margin_z(np.asarray([[0.75]]), np.asarray([100]))
    assert z.shape == (1, 1) and z[0, 0] == pytest.approx(5.0)
    # symmetric in p <-> 1-p
    z2 = top2_margin_z(np.asarray([[0.25]]), np.asarray([100]))
    assert z2[0, 0] == pytest.approx(5.0)
    conf = decision_confidence(np.asarray([[0.75]]), np.asarray([100]))
    assert conf[0] == pytest.approx(_phi(5.0))


def test_margin_z_categorical_hand_computed():
    # counts 50/30/20: top two are 50 and 30, z = 20 / sqrt(80).
    post = np.asarray([[[0.5, 0.3, 0.2]]])
    z = top2_margin_z(post, np.asarray([100]))
    assert z[0, 0] == pytest.approx(20.0 / math.sqrt(80.0))


def test_confidence_min_over_queries_and_zero_acceptance():
    # two queries: one decisive, one a coin flip -- the flip dominates.
    post = np.asarray([[0.99, 0.5], [0.99, 0.99]])
    conf = decision_confidence(post, np.asarray([200, 200]))
    assert conf[0] == pytest.approx(0.5)
    assert conf[1] > 0.99
    # rejected frame: confidence exactly 0, whatever the fallback posterior
    conf0 = decision_confidence(np.asarray([[0.5, 0.5]]), np.asarray([0]))
    assert conf0[0] == 0.0


def test_confidence_monotone_in_accepted_count():
    post = np.asarray([[0.7]])
    c = [decision_confidence(post, np.asarray([a]))[0] for a in (10, 100, 1000)]
    assert c[0] < c[1] < c[2]


def test_flip_rate():
    a = np.asarray([[0, 1], [1, 0]])
    assert flip_rate(a, a) == 0.0
    assert flip_rate(a, 1 - a) == 1.0
    assert flip_rate(a, np.asarray([[0, 1], [1, 1]])) == pytest.approx(0.25)
    with pytest.raises(ValueError):
        flip_rate(a, np.asarray([[0, 1]]))


def test_retry_policy_validation_and_escalation_ladder():
    with pytest.raises(ValueError):
        RetryPolicy(min_confidence=1.5)
    with pytest.raises(ValueError):
        RetryPolicy(max_retries=-1)
    with pytest.raises(ValueError):
        RetryPolicy(escalation=0)
    with pytest.raises(ValueError):
        RetryPolicy(max_n_bits=100)   # not a multiple of 32
    pol = RetryPolicy(escalation=4, max_n_bits=1024)
    assert [pol.n_bits_for(128, a) for a in range(4)] == [128, 512, 1024, 1024]


def test_stats_record_and_merge():
    a, b = ReliabilityStats(), ReliabilityStats()
    a.record_frame(0.95, final_attempt=0, total_bits=128, reliable=True)
    b.record_frame(0.60, final_attempt=2, total_bits=896, reliable=False)
    b.slow_launches = 1
    a.merge(b)
    assert a.frames == 2 and a.retries == 2 and a.unreliable == 1
    assert a.escalations == {0: 1, 2: 1}
    assert a.min_confidence == pytest.approx(0.60)
    assert a.mean_bits == pytest.approx(512.0)
    assert a.slow_launches == 1
    d = a.as_dict()
    assert d["frames"] == 2 and d["escalations"] == {"0": 1, "2": 1}


# --- the retrying driver -----------------------------------------------------------

# A relay network whose decision is pinned by the evidence: P(out=1 | in) is
# 0.02 / 0.98, so any surviving posterior must sit on its frame's side of 0.5
# -- which proves rid -> frame mapping survives the retry queues.
_RELAY = NetworkSpec(
    "relay",
    nodes=(Node("in", cpt=(0.5,)), Node("out", parents=("in",), cpt=(0.02, 0.98))),
    evidence=("in",), queries=("out",),
)

# A coin network: the query is a fair coin independent of the evidence, so
# confidence hovers near Phi(|z|) of a null margin and never reaches 1.0 --
# the deterministic way to exhaust any retry budget.
_COIN = NetworkSpec(
    "coin",
    nodes=(Node("flag", cpt=(0.5,)), Node("coin", parents=("flag",), cpt=(0.5, 0.5))),
    evidence=("flag",), queries=("coin",),
)

# Relay + coin: the coin query keeps the min-over-queries confidence low (so
# retries actually fire), while the relay query stays decisively mapped to
# its evidence frame through every escalation.
_RELAY_COIN = NetworkSpec(
    "relay-coin",
    nodes=(Node("in", cpt=(0.5,)),
           Node("out", parents=("in",), cpt=(0.02, 0.98)),
           Node("coin", cpt=(0.5,))),
    evidence=("in",), queries=("out", "coin"),
)


def test_retry_none_is_the_legacy_driver():
    net = compile_network(_RELAY, n_bits=256)
    d = FrameDriver(net, max_batch=8, salt=0)
    d.submit(np.zeros((4, 1), np.int32))
    out = d.drain()
    assert len(out) == 4
    assert d.reports == {} and d.stats.frames == 0
    assert d.pending_retries == 0


def test_retry_escalates_caches_nets_and_keeps_rid_mapping():
    net = compile_network(_RELAY_COIN, n_bits=64, noise=NoiseModel())
    pol = RetryPolicy(min_confidence=0.7, max_retries=3, escalation=4)
    d = FrameDriver(net, max_batch=8, salt=0, retry=pol)
    ev = np.asarray([[0], [1]] * 8, np.int32)
    rids = d.submit(ev)
    out = d.drain()
    assert sorted(out) == sorted(rids)
    for rid in rids:
        post, acc = out[rid]
        rep = d.reports[rid]
        assert isinstance(rep, FrameReport)
        assert 1 <= rep.attempts <= pol.max_retries + 1
        assert rep.n_bits == pol.n_bits_for(64, rep.attempts - 1)
        assert rep.total_bits == sum(
            pol.n_bits_for(64, a) for a in range(rep.attempts)
        )
        if rep.reliable:
            assert rep.confidence >= pol.min_confidence
            # the relay decision must match the frame that owns this rid
            assert (post[0] > 0.5) == bool(ev[rid, 0])
    # every compiled attempt level obeys the ladder
    for a, n in d._nets.items():
        assert n.n_bits == pol.n_bits_for(64, a)
    assert len(d._nets) > 1          # something actually escalated
    assert d.stats.frames == len(rids)
    assert sum(d.stats.escalations.values()) == len(rids)
    assert d.stats.retries == sum(r.attempts - 1 for r in d.reports.values())


def test_budget_exhaustion_degrades_gracefully():
    net = compile_network(_COIN, n_bits=64)
    pol = RetryPolicy(min_confidence=1.0, max_retries=2, escalation=1)
    d = FrameDriver(net, max_batch=8, salt=0, retry=pol)
    rids = d.submit(np.zeros((6, 1), np.int32))
    out = d.drain()
    assert sorted(out) == sorted(rids)            # emitted, never dropped
    for rid in rids:
        rep = d.reports[rid]
        assert rep.attempts == pol.max_retries + 1
        assert not rep.reliable
    assert d.stats.unreliable == 6
    assert d.stats.escalations == {pol.max_retries: 6}


def test_drain_async_with_retry_completes():
    net = compile_network(_RELAY_COIN, n_bits=64, noise=NoiseModel())
    pol = RetryPolicy(min_confidence=0.7, max_retries=2, escalation=4)
    d = FrameDriver(net, max_batch=8, salt=0, retry=pol)
    rids = d.submit(np.asarray([[0], [1]] * 4, np.int32))
    out = d.drain_async()
    assert sorted(out) == sorted(rids)
    assert d.pending == d.pending_retries == d.in_flight == 0
    assert d.stats.frames == len(rids)


def test_retry_reduces_low_confidence_fraction():
    """The acceptance property in miniature: at matched base n_bits, the
    retrying driver emits fewer under-threshold frames than no-retry."""
    spec = by_name("lane-change")
    ev = np.asarray(sample_evidence(spec, jax.random.PRNGKey(3), 64))
    net = compile_network(spec, n_bits=128, noise=NoiseModel())
    pol = RetryPolicy(min_confidence=0.9, max_retries=3, escalation=4)

    def low_fraction(retry):
        d = FrameDriver(net, max_batch=32, salt=0, retry=retry)
        d.submit(ev)
        out = d.drain()
        post = np.stack([out[r][0] for r in sorted(out)])
        acc = np.asarray([out[r][1] for r in sorted(out)])
        return float(np.mean(decision_confidence(post, acc) < 0.9))

    frac_no_retry = low_fraction(None)
    frac_retry = low_fraction(pol)
    assert frac_no_retry > 0.05                  # the gate has work to do
    assert frac_retry < frac_no_retry


def test_watchdog_flags_slow_dispatches():
    class AlwaysSlow:
        def step_start(self):
            pass

        def step_end(self, step):
            return True

    net = compile_network(_RELAY, n_bits=64)
    d = FrameDriver(net, max_batch=4, salt=0, watchdog=AlwaysSlow())
    d.submit(np.zeros((8, 1), np.int32))
    d.drain()
    assert d.stats.launches == 2
    assert d.stats.slow_launches == 2


def test_watchdog_default_quiet_on_uniform_launches():
    net = compile_network(_RELAY, n_bits=64)
    d = FrameDriver(net, max_batch=4, salt=0)
    d.submit(np.zeros((4, 1), np.int32))
    d.drain()
    assert d.stats.slow_launches == 0


# --- stats merge algebra + mixed drift/chaos accounting (DESIGN §15) ---------------

def _rand_stats(rng):
    s = ReliabilityStats()
    for _ in range(int(rng.integers(1, 16))):
        s.record_frame(
            float(rng.random()), int(rng.integers(0, 3)),
            int(rng.integers(32, 4096)), bool(rng.integers(0, 2)),
        )
    s.launches += int(rng.integers(0, 5))
    s.slow_launches += int(rng.integers(0, 2))
    s.launch_failures += int(rng.integers(0, 3))
    return s


def test_stats_merge_is_associative():
    import copy
    import dataclasses as dc

    rng = np.random.default_rng(0)
    for _ in range(12):
        a, b, c = _rand_stats(rng), _rand_stats(rng), _rand_stats(rng)
        left = copy.deepcopy(a)
        left.merge(b)
        left.merge(c)
        bc = copy.deepcopy(b)
        bc.merge(c)
        right = copy.deepcopy(a)
        right.merge(bc)
        dl, dr = dc.asdict(left), dc.asdict(right)
        # float summation reassociates: compare the sum to tolerance, the
        # counters exactly
        assert dl.pop("confidence_sum") == pytest.approx(
            dr.pop("confidence_sum")
        )
        assert dl == dr
        # and the identity element really is the empty stats
        ident = copy.deepcopy(a)
        ident.merge(ReliabilityStats())
        assert dc.asdict(ident) == dc.asdict(a)


def test_mixed_drift_chaos_every_frame_terminates_exactly_once():
    """Seeded chaos + a drifting noise model + auto-recalibration: the fleet
    still terminates every frame in exactly one of OK / DEGRADED /
    UNRELIABLE / REJECTED, and per-driver stats merge consistently."""
    import copy

    from repro.bayesnet import DriftPolicy
    from repro.bayesnet.reliability import TERMINAL_STATUSES
    from repro.distributed.fault import LaunchFaultInjector
    from repro.serve import BayesRouter, RouterPolicy

    r = BayesRouter(
        RouterPolicy(
            backoff_base_s=1e-4, backoff_cap_s=2e-3, breaker_cooldown_s=0.01,
        ),
        jax.random.PRNGKey(21),
        n_bits=256, max_batch=8,
        retry=RetryPolicy(max_retries=1, max_n_bits=1024),
        fault=LaunchFaultInjector(seed=5, p_drop=0.08, p_corrupt=0.08),
        drift=DriftPolicy(warmup=3, drift_h=0.5, recal_h=1.0),
    )
    name = "pedestrian-night"
    r.register(name, noise=NoiseModel(seed=7, cycle=0.0, wear_tau=1.0))
    spec = by_name(name)
    gen = np.random.default_rng(3)
    rids = []
    for _ in range(5):
        frames = gen.integers(0, 2, size=(9, len(spec.evidence)), dtype=np.int32)
        rids.extend(r.submit(name, frames))
        r.drain()
    assert sorted(r.results) == sorted(rids)           # exactly once each
    counts = r.status_counts()
    assert sum(counts.values()) == len(rids)
    assert set(counts) == set(TERMINAL_STATUSES)
    t = r.tenant(name)
    # the drifting tenant actually recalibrated under chaos, losing nothing
    assert t.recalibrations >= 1
    # per-driver stats merge associatively into the fleet view: every frame
    # that reached a driver (i.e. all but admission-time REJECTED) is
    # accounted exactly once across all rung drivers
    stats = [copy.deepcopy(d.stats) for d in t.drivers.values()]
    total = ReliabilityStats()
    for s in stats:
        total.merge(s)
    assert total.frames == len(rids) - counts["REJECTED"]
