"""Optional-`hypothesis` shim for property tests.

`hypothesis` lives in the test extra (see requirements.txt), not the runtime
deps.  When it is installed, this module re-exports the real ``given`` /
``settings`` / ``st`` unchanged.  When it is missing, each ``@given`` test
degrades to a single deterministic mid-range example instead of failing
collection (the seed repo died with ``ModuleNotFoundError`` here) -- the
full property sweep still runs wherever the extra is installed (CI).
"""

from __future__ import annotations

try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # degrade to one representative example per test
    import itertools

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A strategy reduced to a small list of representative examples."""

        def __init__(self, examples):
            self.examples = list(examples)

    class _St:
        @staticmethod
        def integers(min_value, max_value):
            mid = (min_value + max_value) // 2
            return _Strategy([mid, min_value, max_value])

        @staticmethod
        def floats(min_value, max_value):
            mid = 0.5 * (min_value + max_value)
            return _Strategy([mid, min_value, max_value])

    st = _St()

    def given(**strategies):
        """Run the test over the cartesian product of fallback examples,
        capped to keep runtime close to one hypothesis example."""

        def deco(fn):
            combos = list(itertools.islice(
                itertools.product(*(s.examples for s in strategies.values())), 3
            ))
            names = list(strategies.keys())

            # zero-arg wrapper: the strategy params must NOT appear in the
            # signature pytest inspects, or it would resolve them as fixtures
            def wrapper():
                for combo in combos:
                    fn(**dict(zip(names, combo)))

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco

    def settings(**_kwargs):
        def deco(fn):
            return fn

        return deco
