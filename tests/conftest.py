import os
import sys

# Tests run on the single real CPU device (the 512-device override is ONLY for
# repro.launch.dryrun, which sets XLA_FLAGS before importing jax in its own
# process).  Keep compilation single-threaded-ish and quiet.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# Make the optional-hypothesis shim (tests/hypcompat.py) importable from any
# test module regardless of pytest's rootdir/package resolution.
sys.path.insert(0, os.path.dirname(__file__))
