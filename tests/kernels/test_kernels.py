"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp ref oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bitops
from repro.kernels.fusion_map.kernel import fusion_map_pallas
from repro.kernels.fusion_map.ops import fusion_map
from repro.kernels.fusion_map.ref import fusion_map_ref
from repro.kernels.pand_popcount.kernel import pand_popcount_pallas
from repro.kernels.pand_popcount.ops import pand_popcount
from repro.kernels.pand_popcount.ref import pand_popcount_ref
from repro.kernels.sne_encode.kernel import sne_encode_pallas
from repro.kernels.sne_encode.ops import sne_encode
from repro.kernels.sne_encode.ref import sne_encode_ref


# --- sne_encode -------------------------------------------------------------------

@pytest.mark.parametrize("rows,n_rand,block", [(64, 32, 64), (256, 64, 64), (512, 256, 256), (1, 8, 1)])
def test_sne_encode_kernel_vs_ref(rows, n_rand, block):
    kp, kr = jax.random.split(jax.random.PRNGKey(rows * 7 + n_rand))
    p = jax.random.uniform(kp, (rows,), jnp.float32)
    rand = jax.random.bits(kr, (rows, n_rand), jnp.uint32)
    out_k = sne_encode_pallas(p, rand, block_r=block, interpret=True)
    out_r = sne_encode_ref(p, rand)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


def test_sne_encode_op_probability():
    n_bits = 4096
    p = jnp.linspace(0.05, 0.95, 64)
    words = sne_encode(jax.random.PRNGKey(0), p, n_bits)
    est = np.asarray(bitops.decode(words, n_bits))
    np.testing.assert_allclose(est, np.asarray(p), atol=0.04)


def test_sne_encode_op_matches_ref_path():
    p = jax.random.uniform(jax.random.PRNGKey(3), (128,), jnp.float32)
    a = sne_encode(jax.random.PRNGKey(1), p, 256, use_kernel=True)
    b = sne_encode(jax.random.PRNGKey(1), p, 256, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --- pand_popcount ----------------------------------------------------------------

@pytest.mark.parametrize("m,rows,n_words,block", [(2, 64, 4, 64), (3, 512, 8, 512), (4, 128, 32, 64), (2, 1, 1, 1)])
def test_pand_popcount_kernel_vs_ref(m, rows, n_words, block):
    streams = jax.random.bits(
        jax.random.PRNGKey(m * 100 + rows), (m, rows, n_words), jnp.uint32
    )
    out_k = pand_popcount_pallas(streams, block_r=block, interpret=True)
    out_r = pand_popcount_ref(streams)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))


def test_pand_popcount_semantics():
    """Fused kernel == decode(AND of streams) * n_bits."""
    n_bits = 512
    key = jax.random.PRNGKey(5)
    from repro.core import sne as core_sne

    ps = jnp.array([[0.8], [0.7]])
    streams = core_sne.encode_uncorrelated(key, ps, n_bits)  # (2, 1, n_words)
    counts = pand_popcount(streams)
    expect = bitops.popcount(streams[0, 0] & streams[1, 0])
    assert int(counts[0]) == int(expect)


# --- fusion_map -------------------------------------------------------------------

@pytest.mark.parametrize("m,rows,k,block", [(2, 64, 2, 64), (2, 256, 16, 256), (3, 512, 128, 256), (4, 1, 8, 1)])
def test_fusion_map_kernel_vs_ref(m, rows, k, block):
    kp = jax.random.PRNGKey(m * 31 + k)
    p = jax.nn.softmax(jax.random.normal(kp, (m, rows, k)), axis=-1)
    prior = jnp.full((k,), 1.0 / k, jnp.float32)
    out_k = fusion_map_pallas(p, prior, block_r=block, interpret=True)
    out_r = fusion_map_ref(p, prior)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=1e-5, atol=1e-6)


def test_fusion_map_matches_core_analytic():
    from repro.core import fusion as core_fusion

    p = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(9), (3, 40, 5)), -1)
    out = fusion_map(p)                               # (40, 5)
    expect = core_fusion.fuse_analytic(jnp.moveaxis(p, 0, -2))  # (40, 5)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-4, atol=1e-5)


def test_fusion_map_nonuniform_prior():
    p = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(4), (2, 64, 4)), -1)
    prior = jnp.array([0.6, 0.2, 0.1, 0.1])
    out = fusion_map(p, prior)
    ref = fusion_map_ref(p.reshape(2, -1, 4), prior)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(out.sum(-1)), 1.0, rtol=1e-5)


# --- end-to-end stochastic fusion through kernels ---------------------------------

def test_kernel_pipeline_matches_core_fusion():
    """sne_encode -> pand_popcount reproduces core.bayes_fusion's ratio path."""
    n_bits = 1 << 13
    p_modal = jnp.array([[0.8, 0.2], [0.7, 0.3]])  # (M, K)
    streams = sne_encode(jax.random.PRNGKey(7), p_modal, n_bits)  # (M, K, W)
    counts = pand_popcount(streams).astype(jnp.float32)           # (K,)
    fused = counts / counts.sum()
    from repro.core import fusion as core_fusion

    expect = core_fusion.fuse_analytic(jnp.moveaxis(p_modal, 0, -2))
    np.testing.assert_allclose(np.asarray(fused), np.asarray(expect), atol=0.05)
