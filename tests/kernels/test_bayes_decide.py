"""Fused Bayes decision kernel: Pallas (interpret) vs oracles, and semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.fusion import fuse_analytic
from repro.kernels.bayes_decide.kernel import bayes_decide_pallas
from repro.kernels.bayes_decide.ops import bayes_decide, bayes_decide_packed
from repro.kernels.bayes_decide.ref import bayes_decide_ref


@pytest.mark.parametrize(
    "m,rows,k,n_rand,block",
    [(2, 64, 2, 32, 64), (3, 128, 4, 64, 64), (2, 1, 8, 8, 1), (4, 256, 3, 16, 256)],
)
def test_kernel_vs_ref_bit_exact(m, rows, k, n_rand, block):
    kp, kr = jax.random.split(jax.random.PRNGKey(m * 1000 + rows + k))
    p = jax.random.uniform(kp, (m, rows, k), jnp.float32)
    rand = jax.random.bits(kr, (m, rows, k, n_rand), jnp.uint32)
    dec_k, cnt_k = bayes_decide_pallas(p, rand, block_r=block, interpret=True)
    dec_r, cnt_r = bayes_decide_ref(p, rand)
    np.testing.assert_array_equal(np.asarray(cnt_k), np.asarray(cnt_r))
    np.testing.assert_array_equal(np.asarray(dec_k), np.asarray(dec_r))


def test_fused_equals_packed_composition():
    """Same entropy stream -> the fused op and the unfused packed stages agree
    bit-for-bit, so the benchmark speedup compares identical computations."""
    key = jax.random.PRNGKey(3)
    p = jax.random.uniform(key, (2, 512, 4))
    d1, c1 = bayes_decide(jax.random.PRNGKey(7), p, 128)
    d2, c2 = bayes_decide_packed(jax.random.PRNGKey(7), p, 128)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))


def test_kernel_path_matches_fallback_path():
    key = jax.random.PRNGKey(5)
    p = jax.random.uniform(key, (2, 64, 2))
    d_k, c_k = bayes_decide(jax.random.PRNGKey(1), p, 128, use_kernel=True, interpret=True)
    d_f, c_f = bayes_decide(jax.random.PRNGKey(1), p, 128, use_kernel=False)
    np.testing.assert_array_equal(np.asarray(c_k), np.asarray(c_f))
    np.testing.assert_array_equal(np.asarray(d_k), np.asarray(d_f))


def test_counts_estimate_product_probability():
    """Class count / n_bits estimates the eq-(5) numerator product."""
    n_bits = 1 << 13
    p = jnp.array([[[0.8, 0.2]], [[0.7, 0.3]]])          # (M=2, 1, K=2)
    _, cnt = bayes_decide(jax.random.PRNGKey(0), p, n_bits)
    est = np.asarray(cnt[0], np.float32) / n_bits
    np.testing.assert_allclose(est, [0.8 * 0.7, 0.2 * 0.3], atol=0.02)


def test_decisions_match_analytic_fusion():
    """At long stream lengths the fused decisions agree with eq-(5) argmax on
    all but near-tie rows."""
    n_bits = 2048
    key = jax.random.PRNGKey(11)
    p = jax.nn.softmax(jax.random.normal(key, (2, 256, 4)) * 2.0, -1)
    dec, _ = bayes_decide(jax.random.PRNGKey(1), p, n_bits)
    ana = jnp.argmax(fuse_analytic(jnp.moveaxis(p, 0, -2)), -1)
    agree = float(jnp.mean((dec == ana).astype(jnp.float32)))
    assert agree > 0.9, agree


def test_leading_batch_shapes():
    p = jax.random.uniform(jax.random.PRNGKey(2), (3, 4, 5, 2))  # (M, B1, B2, K)
    dec, cnt = bayes_decide(jax.random.PRNGKey(8), p, 64)
    assert dec.shape == (4, 5) and cnt.shape == (4, 5, 2)
    assert int(jnp.max(cnt)) <= 64 and int(jnp.min(cnt)) >= 0
