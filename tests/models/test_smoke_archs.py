"""Per-architecture smoke tests: reduced config, one forward/train step on CPU,
shape + no-NaN assertions, plus prefill/decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import api, layers

LM_ARCHS = [a for a in ARCH_IDS if a != "paper-bayes-fusion"]


def make_batch(cfg, key, batch=2, seq=16):
    kt, ke = jax.random.split(key)
    tokens = jax.random.randint(kt, (batch, seq), 0, cfg.vocab_size)
    labels = jnp.roll(tokens, -1, axis=1)
    out = {"tokens": tokens, "labels": labels}
    if cfg.frontend == "patch":
        out["extra_embeds"] = jax.random.normal(ke, (batch, 4, cfg.d_model), jnp.float32)
    elif cfg.frontend == "frame":
        out["extra_embeds"] = jax.random.normal(
            ke, (batch, seq // cfg.enc_ratio, cfg.d_model), jnp.float32
        )
    return out


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_train_step_smoke(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = api.init(cfg, key)
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    loss, metrics = api.loss(params, cfg, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    # rough sanity: initial loss near log(vocab)
    assert 1.0 < float(loss) < 3.0 * np.log(cfg.vocab_size)

    grads = jax.grad(lambda p: api.loss(p, cfg, batch)[0])(params)
    flat = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, dtype=np.float32))) for g in flat), (
        f"{arch}: non-finite grads"
    )


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_forward_output_shape(arch):
    cfg = get_smoke_config(arch)
    params = api.init(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1), batch=2, seq=16)
    if cfg.family == "audio":
        from repro.models import encdec

        logits, _ = encdec.forward(params, cfg, batch["extra_embeds"], batch["tokens"])
        assert logits.shape == (2, 16, layers.pad_vocab(cfg.vocab_size))
    else:
        from repro.models import transformer

        logits, _ = transformer.forward(
            params, cfg, batch["tokens"], batch.get("extra_embeds")
        )
        extra = 0 if "extra_embeds" not in batch else batch["extra_embeds"].shape[1]
        assert logits.shape == (2, 16 + extra, layers.pad_vocab(cfg.vocab_size))
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_prefill_decode_consistency(arch):
    """decode_step(t) after prefill(t-1 tokens) == forward logits at position t."""
    cfg = get_smoke_config(arch)
    params = api.init(cfg, jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1), batch=2, seq=12)
    tokens = batch["tokens"]
    t_cache = 16

    pre_batch = dict(batch)
    pre_batch["tokens"] = tokens[:, :-1]
    # absolute position of the final token (prepended patch embeds shift it)
    n_extra = batch["extra_embeds"].shape[1] if cfg.frontend == "patch" else 0
    logits_pre, state = api.prefill(params, cfg, pre_batch, t_cache + n_extra)
    logits_dec, _ = api.decode(
        params, cfg, tokens[:, -1], state, jnp.int32(11 + n_extra)
    )

    # oracle: teacher-forced forward logits
    if cfg.family == "audio":
        from repro.models import encdec

        full, _ = encdec.forward(params, cfg, batch["extra_embeds"], tokens)
        expect_pre = full[:, -2]
        expect_dec = full[:, -1]
    else:
        from repro.models import transformer

        full, _ = transformer.forward(params, cfg, tokens, batch.get("extra_embeds"))
        expect_pre = full[:, -2]
        expect_dec = full[:, -1]
    np.testing.assert_allclose(
        np.asarray(logits_pre), np.asarray(expect_pre, dtype=np.float32), atol=2e-2, rtol=2e-2
    )
    # decode paths legitimately reassociate matmuls (e.g. absorbed MLA) in bf16
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(expect_dec, dtype=np.float32), atol=1e-1, rtol=1e-1
    )


def test_full_configs_construct():
    """Exact full configs build and report the published dimensions."""
    from repro.configs import get_config

    expects = {
        "qwen2-72b": (80, 8192, 64, 8, 29568, 152064),
        "starcoder2-15b": (40, 6144, 48, 4, 24576, 49152),
        "minitron-4b": (32, 3072, 24, 8, 9216, 256000),
        "phi3-mini-3.8b": (32, 3072, 32, 32, 8192, 32064),
        "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "deepseek-v3-671b": (61, 7168, 128, 128, 18432, 129280),
        "seamless-m4t-large-v2": (48, 1024, 16, 16, 8192, 256206),
    }
    for arch, (nl, d, h, kv, ff, v) in expects.items():
        cfg = get_config(arch)
        assert cfg.num_layers == nl, arch
        assert cfg.d_model == d, arch
        assert cfg.num_heads == h, arch
        assert cfg.num_kv_heads == kv, arch
        assert cfg.d_ff == ff, arch
        assert cfg.vocab_size == v, arch


def test_moe_dispatch_equivalence():
    """Sort-based capacity dispatch == dense all-experts einsum (high capacity)."""
    import dataclasses

    from repro.models import moe as moe_mod

    cfg = get_smoke_config("llama4-scout-17b-a16e")
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
    )  # no drops
    params = moe_mod.moe_init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model), jnp.float32)
    out_sort, _ = moe_mod.moe_apply(params, x, cfg)
    cfg_dense = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, impl="dense"))
    out_dense, _ = moe_mod.moe_apply(params, x, cfg_dense)
    np.testing.assert_allclose(
        np.asarray(out_sort, np.float32), np.asarray(out_dense, np.float32),
        atol=1e-4, rtol=1e-4,
    )


def test_mlstm_chunked_matches_decode_loop():
    """Chunkwise-parallel mLSTM == step-by-step recurrent decode."""
    from repro.models import xlstm as xl

    cfg = get_smoke_config("xlstm-350m")
    params = xl.mlstm_init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 20, cfg.d_model), jnp.float32) * 0.5
    out_par, state_par = xl.mlstm_apply(params, x, cfg)
    state = xl.mlstm_init_state(2, cfg)
    outs = []
    for t in range(20):
        o, state = xl.mlstm_apply(params, x[:, t : t + 1], cfg, state)
        outs.append(o)
    out_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(out_par, np.float32), np.asarray(out_seq, np.float32),
        atol=2e-3, rtol=2e-3,
    )
    np.testing.assert_allclose(
        np.asarray(state_par["C"]), np.asarray(state["C"]), atol=2e-3, rtol=2e-3
    )


def test_rglru_scan_matches_decode_loop():
    from repro.models import rglru as rg

    cfg = get_smoke_config("recurrentgemma-2b")
    params = rg.rglru_init(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, cfg.d_model), jnp.float32) * 0.5
    out_par, state_par = rg.rglru_apply(params, x, cfg, None)
    state = rg.rglru_init_state(2, cfg, dtype=jnp.float32)
    outs = []
    for t in range(12):
        o, state = rg.rglru_apply(params, x[:, t : t + 1], cfg, state)
        outs.append(o)
    out_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(out_par, np.float32), np.asarray(out_seq, np.float32),
        atol=2e-3, rtol=2e-3,
    )
