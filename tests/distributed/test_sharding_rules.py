"""Sharding-rule unit tests (no multi-device needed: specs are pure functions)."""

import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config, get_smoke_config
from repro.distributed import sharding
from repro.models import api


def fake_mesh(shape=(16, 16), axes=("data", "model")):
    # Spec computation needs no real devices: AbstractMesh takes
    # ((name, size), ...) pairs and exposes axis_names/axis_sizes/shape.
    from jax.sharding import AbstractMesh

    return AbstractMesh(tuple(zip(axes, shape)))


def test_param_specs_qwen_rules():
    mesh = fake_mesh()
    cfg = get_config("qwen2-72b")
    params = jax.eval_shape(lambda: api.init(cfg, jax.random.PRNGKey(0)))
    specs = sharding.param_specs(params, mesh)
    flat, _ = jax.tree_util.tree_flatten_with_path(specs)
    by_name = {}
    for path, spec in flat:
        name = [getattr(e, "key", None) for e in path if getattr(e, "key", None)][-1]
        by_name.setdefault(name, spec)
    # embed (V, D): vocab over model, d over data
    assert by_name["embed"] == P("model", "data")
    # wq stacked (L, D, H*hd)
    assert by_name["wq"] == P(None, "data", "model")
    assert by_name["wo"] == P(None, "model", "data")
    assert all(s is None for s in by_name["scale"])  # norms replicated


def test_param_specs_divisibility_fallback():
    """rg-2b: 10 heads not divisible by 16 -> head dim replicated, not crashed."""
    mesh = fake_mesh()
    cfg = get_config("recurrentgemma-2b")
    params = jax.eval_shape(lambda: api.init(cfg, jax.random.PRNGKey(0)))
    specs = sharding.param_specs(params, mesh)
    for path, spec in jax.tree_util.tree_flatten_with_path(specs)[0]:
        leaf = None  # just ensure all specs are valid PartitionSpecs
        assert isinstance(spec, P)
    flat_p, _ = jax.tree_util.tree_flatten_with_path(params)
    flat_s, _ = jax.tree_util.tree_flatten_with_path(specs)
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    for (pp, leaf), (sp, spec) in zip(flat_p, flat_s):
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            div = int(np.prod([sizes[a] for a in axes]))
            assert leaf.shape[dim] % div == 0, (pp, leaf.shape, spec)


@pytest.mark.parametrize("arch", ["deepseek-v3-671b", "llama4-scout-17b-a16e"])
def test_expert_leaves_ep_sharded(arch):
    mesh = fake_mesh()
    cfg = get_config(arch)
    params = jax.eval_shape(lambda: api.init(cfg, jax.random.PRNGKey(0)))
    specs = sharding.param_specs(params, mesh)
    flat, _ = jax.tree_util.tree_flatten_with_path(specs)
    found = 0
    for path, spec in flat:
        keys = [getattr(e, "key", None) for e in path]
        if "moe" in keys and "shared" not in keys and keys[-1] in ("wi", "wg", "wo"):
            # (L, E, D, F) stacked or (E, D, F): expert dim sharded over model
            edim = len(spec) - 3
            assert spec[edim] == "model", (keys, spec)
            found += 1
    assert found >= 3


def test_all_archs_specs_valid():
    mesh = fake_mesh()
    mesh3 = fake_mesh((2, 16, 16), ("pod", "data", "model"))
    for arch in ("qwen2-72b", "starcoder2-15b", "minitron-4b", "phi3-mini-3.8b",
                 "internvl2-26b", "recurrentgemma-2b", "xlstm-350m",
                 "llama4-scout-17b-a16e", "deepseek-v3-671b", "seamless-m4t-large-v2"):
        cfg = get_config(arch)
        params = jax.eval_shape(lambda c=cfg: api.init(c, jax.random.PRNGKey(0)))
        for m in (mesh, mesh3):
            specs = sharding.param_specs(params, m)
            sizes = dict(zip(m.axis_names, m.axis_sizes))
            for (pp, leaf), (sp, spec) in zip(
                jax.tree_util.tree_flatten_with_path(params)[0],
                jax.tree_util.tree_flatten_with_path(specs)[0],
            ):
                for dim, ax in enumerate(spec):
                    if ax is None:
                        continue
                    axes = ax if isinstance(ax, tuple) else (ax,)
                    div = int(np.prod([sizes[a] for a in axes]))
                    assert leaf.shape[dim] % div == 0, (arch, pp, leaf.shape, spec)
