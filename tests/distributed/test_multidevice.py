"""Multi-device semantics, run in a subprocess with 8 forced host devices.

The subprocess is required because jax locks the device count at first init
(the main pytest process runs single-device).
"""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..", "src"))

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, dataclasses
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_smoke_config
    from repro.distributed import context as dctx, sharding
    from repro.models import api
    from repro.optim import adamw

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    assert len(jax.devices()) == 8

    # --- sharded train step == single-device train step -----------------------
    cfg = get_smoke_config("qwen2-72b")
    cfg = dataclasses.replace(cfg, d_model=64, num_heads=4, num_kv_heads=4)
    params = api.init(cfg, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (8, 16), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}

    loss_plain, _ = api.loss(params, cfg, batch)

    pshard = sharding.param_shardings(params, mesh)
    params_sh = jax.device_put(params, pshard)
    batch_sh = jax.device_put(
        batch, {k: NamedSharding(mesh, P("data", None)) for k in batch}
    )
    with dctx.mesh_context(mesh):
        loss_sh, _ = jax.jit(lambda p, b: api.loss(p, cfg, b))(params_sh, batch_sh)
    np.testing.assert_allclose(float(loss_plain), float(loss_sh), rtol=2e-2)
    print("TRAIN_OK", float(loss_plain), float(loss_sh))

    # --- MoE EP via shard_map == local masked dispatch -------------------------
    from repro.models import moe as moe_mod
    mcfg = get_smoke_config("llama4-scout-17b-a16e")
    mcfg = dataclasses.replace(
        mcfg, moe=dataclasses.replace(mcfg.moe, num_experts=8, capacity_factor=8.0)
    )
    mp = moe_mod.moe_init(jax.random.PRNGKey(2), mcfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 8, mcfg.d_model), jnp.float32)
    out_local, _ = moe_mod.moe_apply(mp, x, mcfg)           # no mesh context
    with dctx.mesh_context(mesh):
        out_ep, _ = jax.jit(lambda p, xx: moe_mod.moe_apply(p, xx, mcfg))(mp, x)
    np.testing.assert_allclose(
        np.asarray(out_local), np.asarray(out_ep), atol=5e-4, rtol=5e-4
    )
    print("MOE_EP_OK")

    # --- gradient compression psum over pod axis -------------------------------
    from repro.optim import compression
    from jax.experimental.shard_map import shard_map
    g = {"w": jax.random.normal(jax.random.PRNGKey(4), (8, 32)) * 0.01}
    res = {"w": jnp.zeros((8, 32))}

    def worker(gg, rr):
        mean, new_res = compression.compressed_mean(
            jax.random.PRNGKey(0), gg, rr, "data"
        )
        return mean, new_res

    fn = shard_map(
        worker, mesh=mesh,
        in_specs=({"w": P("data", None)}, {"w": P("data", None)}),
        out_specs=({"w": P("data", None)}, {"w": P("data", None)}),
        check_rep=False,
    )
    mean, _ = fn(g, res)
    # mean over the 2-way data axis of per-shard encodings stays close to the
    # true per-shard gradients (int8 stochastic rounding, 2 shards)
    assert np.isfinite(np.asarray(mean["w"])).all()
    print("COMPRESSION_OK")
    print("ALL_OK")
    """
)


@pytest.mark.slow
def test_multidevice_semantics():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True, text=True,
        timeout=500,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr[-3000:]}"
    assert "ALL_OK" in proc.stdout
