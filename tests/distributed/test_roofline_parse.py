"""Unit tests for the roofline HLO-text collective parser."""

from repro.launch import roofline as rf

HLO = """
HloModule jit_train_step

%fused (x: bf16[16,4096,8192]) -> bf16[16,4096,8192] {
  %ag = bf16[16,4096,8192]{2,1,0} all-gather(%p0), replica_groups={{0,1}}
  %ar.1 = f32[1024,512]{1,0} all-reduce(%x1), to_apply=%add
  %ars = f32[1024,512]{1,0} all-reduce-start(%x2), to_apply=%add
  %ard = f32[1024,512]{1,0} all-reduce-done(%ars)
  %rs = bf16[8,128]{1,0} reduce-scatter(%y), dimensions={0}
  %a2a = s32[64]{0} all-to-all(%z), dimensions={0}
  %cp = u32[32,4]{1,0} collective-permute(%w), source_target_pairs={{0,1}}
  %tup = (f32[2,2]{1,0}, f32[2,2]{1,0}) all-reduce(%a, %b), to_apply=%add
}
"""


def test_shape_bytes():
    assert rf._shape_bytes("bf16[16,4096,8192]{2,1,0}") == 16 * 4096 * 8192 * 2
    assert rf._shape_bytes("f32[1024,512]") == 1024 * 512 * 4
    assert rf._shape_bytes("(f32[2,2], f32[2,2])") == 2 * (2 * 2 * 4)
    assert rf._shape_bytes("pred[7]") == 7


def test_collective_bytes_and_counts():
    total, by_kind = rf.collective_bytes(HLO)
    counts = rf.collective_counts(HLO)
    # all-reduce counted 2x (ring), -done not double counted
    ar = 2 * (1024 * 512 * 4)          # %ar.1
    ars = 2 * (1024 * 512 * 4)         # %ars (start only)
    tup = 2 * 2 * (2 * 2 * 4)          # tuple all-reduce
    assert by_kind["all-reduce"] == ar + ars + tup
    assert by_kind["all-gather"] == 16 * 4096 * 8192 * 2
    assert by_kind["reduce-scatter"] == 8 * 128 * 2
    assert by_kind["all-to-all"] == 64 * 4
    assert by_kind["collective-permute"] == 32 * 4 * 4
    assert total == sum(by_kind.values())
    assert counts["all-reduce"] == 3 and counts["all-gather"] == 1


def test_roofline_terms():
    r = rf.Roofline(
        arch="x", shape="train_4k", mesh="pod16x16", chips=256,
        flops_per_chip=197e12, bytes_per_chip=819e9,
        collective_bytes_per_chip=50e9, collective_by_kind={},
        model_flops_total=197e12 * 256 / 2,
    ).finalize()
    assert abs(r.compute_s - 1.0) < 1e-9
    assert abs(r.memory_s - 1.0) < 1e-9
    assert abs(r.collective_s - 1.0) < 1e-9
    assert r.useful_ratio == 0.5
    assert r.bottleneck in ("compute", "memory", "collective")
