"""StragglerWatch warmup: mean-seeded EWMA, no flagging during warmup.

The legacy watch seeded its EWMA with the very first observation, so a slow
first step (jit compile, cold cache) inflated the baseline and masked real
stragglers until the EWMA decayed.  ``warmup_steps`` collects the first N
observations without flagging and seeds the EWMA with their mean;
``warmup_steps=1`` is exactly the legacy behaviour.
"""

import pytest

from repro.distributed.fault import StragglerWatch


def test_warmup_collects_without_flagging_and_mean_seeds():
    w = StragglerWatch(threshold=3.0, warmup_steps=3)
    assert not w.observe(0, 10.0)       # cold outlier: not flagged
    assert w.ewma is None               # still warming up
    assert not w.observe(1, 1.0)
    assert w.ewma is None
    assert not w.observe(2, 1.0)
    assert w.ewma == pytest.approx(4.0)  # mean(10, 1, 1), not 10


def test_warmup_steps_one_is_legacy_first_obs_seed():
    w = StragglerWatch(threshold=3.0, alpha=0.2, warmup_steps=1)
    assert not w.observe(0, 2.0)
    assert w.ewma == pytest.approx(2.0)  # first observation seeds directly
    assert w.observe(1, 7.0)             # 7 > 3*2: flagged
    assert w.flagged_steps == [1]
    assert w.ewma == pytest.approx(2.0)  # flagged outliers excluded from EWMA


def test_cold_first_step_no_longer_masks_stragglers():
    # One cold step (10x), then warm steady state, then a genuine 12x
    # straggler.  Legacy seeding masks it; warmup seeding catches it.
    trace = [10.0, 1.0, 1.0, 1.0, 12.0]

    legacy = StragglerWatch(threshold=3.0, warmup_steps=1)
    for i, dt in enumerate(trace):
        legacy.observe(i, dt)
    assert legacy.flagged_steps == []    # the bug: baseline poisoned at 10

    fixed = StragglerWatch(threshold=3.0, warmup_steps=4)
    for i, dt in enumerate(trace):
        fixed.observe(i, dt)
    assert fixed.flagged_steps == [4]    # mean-seeded at 3.25; 12 > 9.75


def test_warmup_flagging_resumes_after_seed():
    w = StragglerWatch(threshold=3.0, warmup_steps=2)
    w.observe(0, 1.0)
    w.observe(1, 1.0)
    assert w.ewma == pytest.approx(1.0)
    assert w.observe(2, 5.0)
    assert w.flagged_steps == [2]


def test_min_dt_is_the_steady_state_floor():
    # min_dt excludes the seed (where a jit compile hides) and flagged
    # stragglers -- it is the optimistic launch estimate deadline admission
    # uses, so contamination here would shed healthy tenants.
    w = StragglerWatch(threshold=3.0, alpha=0.2, warmup_steps=1)
    w.observe(0, 8.0)                    # compile-sized seed
    assert w.min_dt is None              # the seed is not a steady-state obs
    w.observe(1, 0.005)
    assert w.min_dt == pytest.approx(0.005)
    w.observe(2, 30.0)                   # straggler: flagged, excluded
    assert w.flagged_steps == [2]
    assert w.min_dt == pytest.approx(0.005)
    w.observe(3, 0.003)
    assert w.min_dt == pytest.approx(0.003)


def test_warmup_steps_validation():
    with pytest.raises(ValueError, match="warmup_steps"):
        StragglerWatch(warmup_steps=0)


def test_warmup_observations_count_in_metrics():
    class Reg:
        def __init__(self):
            self.counts = {}
            self.obs = []

        def inc(self, name, n=1):
            self.counts[name] = self.counts.get(name, 0) + n

        def observe(self, name, v, **kw):
            self.obs.append((name, v))

    reg = Reg()
    w = StragglerWatch(threshold=3.0, warmup_steps=2, metrics=reg)
    w.observe(0, 1.0)
    w.observe(1, 1.0)
    w.observe(2, 9.0)
    assert reg.counts["watch_steps"] == 3
    assert reg.counts["watch_slow_steps"] == 1   # warmup never counts as slow
    assert len(reg.obs) == 3                     # but every interval is recorded
