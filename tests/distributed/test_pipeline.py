"""GPipe pipeline over the pod axis == sequential stage application."""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..", "src"))

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.distributed.pipeline import pipeline_forward, reference_forward

    mesh = jax.make_mesh((4, 2), ("pod", "data"))

    # 4 pipeline stages of a simple residual MLP block
    key = jax.random.PRNGKey(0)
    d = 16
    ks = jax.random.split(key, 4)
    stage_params = {
        "w1": jnp.stack([jax.random.normal(k, (d, 2 * d)) * 0.1 for k in ks]),
        "w2": jnp.stack([jax.random.normal(k, (2 * d, d)) * 0.1 for k in ks]),
    }

    def stage_fn(p, x):
        return x + jax.nn.gelu(x @ p["w1"]) @ p["w2"]

    m, b = 6, 4   # 6 microbatches of 4
    x = jax.random.normal(jax.random.PRNGKey(1), (m, b, d))

    out_pipe = pipeline_forward(stage_fn, stage_params, x, mesh)
    out_ref = reference_forward(stage_fn, stage_params, x)
    np.testing.assert_allclose(
        np.asarray(out_pipe), np.asarray(out_ref), atol=1e-5, rtol=1e-5
    )

    # the pipeline lowers with collective-permute on the pod axis
    hlo = jax.jit(
        lambda p, xx: pipeline_forward(stage_fn, p, xx, mesh)
    ).lower(stage_params, x).compile().as_text()
    assert "collective-permute" in hlo
    print("PIPELINE_OK")
    """
)


@pytest.mark.slow
def test_gpipe_pipeline_matches_reference():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True, text=True,
        timeout=420,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr[-3000:]}"
    assert "PIPELINE_OK" in proc.stdout
