"""Mini dry-run in a subprocess: the full lower->compile->roofline machinery on
an 8-device (2,2,2) pod/data/model mesh with smoke configs."""

import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..", "src"))

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, functools
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_smoke_config
    from repro.distributed import context as dctx, sharding
    from repro.launch import roofline as rf
    from repro.models import api, transformer
    from repro.optim import adamw

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))

    for arch in ("qwen2-72b", "llama4-scout-17b-a16e", "recurrentgemma-2b"):
        cfg = get_smoke_config(arch)
        params = jax.eval_shape(functools.partial(api.init, cfg), jax.random.PRNGKey(0))
        pshard = sharding.param_shardings(params, mesh)
        opt = jax.eval_shape(adamw.init, params)
        oshard = adamw.OptState(step=NamedSharding(mesh, P()),
                                master=pshard, m=pshard, v=pshard)
        batch = {
            "tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32),
            "labels": jax.ShapeDtypeStruct((8, 32), jnp.int32),
        }
        bshard = {k: NamedSharding(mesh, P(("pod", "data"), None)) for k in batch}
        opt_cfg = adamw.AdamWConfig()

        def train_step(p, o, b):
            (loss, _), grads = jax.value_and_grad(
                lambda pp: api.loss(pp, cfg, b), has_aux=True)(p)
            return adamw.apply(grads, o, opt_cfg)[0], loss

        with dctx.mesh_context(mesh):
            lowered = jax.jit(train_step, in_shardings=(pshard, oshard, bshard)
                              ).lower(params, opt, batch)
            compiled = lowered.compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        assert float(cost.get("flops", 0)) > 0
        cbytes, kinds = rf.collective_bytes(compiled.as_text())
        assert cbytes > 0, f"{arch}: no collectives found in partitioned HLO"
        ma = compiled.memory_analysis()
        assert ma.temp_size_in_bytes >= 0
        print(f"{arch}: flops={float(cost['flops']):.2e} coll={cbytes:.2e} "
              f"kinds={sorted(kinds)}")

        # decode step lowers too
        state = jax.eval_shape(lambda: transformer.init_decode_state(cfg, 8, 64))
        sshard = sharding.state_specs_for_cache(state, mesh)
        tok = jax.ShapeDtypeStruct((8,), jnp.int32)
        with dctx.mesh_context(mesh):
            dec = jax.jit(
                lambda p, t, s, pos: api.decode(p, cfg, t, s, pos),
                in_shardings=(pshard, NamedSharding(mesh, P(("pod", "data"))),
                              sshard, NamedSharding(mesh, P())),
            ).lower(params, tok, state, jax.ShapeDtypeStruct((), jnp.int32))
            dec.compile()
        print(f"{arch}: decode ok")
    print("MINI_DRYRUN_OK")
    """
)


@pytest.mark.slow
def test_mini_dryrun_multipod():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["JAX_PLATFORMS"] = "cpu"
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True, text=True,
        timeout=540,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr[-3000:]}"
    assert "MINI_DRYRUN_OK" in proc.stdout
