"""BayesRouter: multi-tenant serving, deadlines, chaos, degradation, breaker.

The fleet-level contracts under test:

* **bit-identity** -- with injection off, a router tenant's posteriors equal
  a standalone per-scenario FrameDriver's for the same ``(base_key, salt)``.
* **never-drop** -- under seeded launch-fault chaos across a mixed workload,
  every submitted frame terminates in exactly one of OK / DEGRADED /
  UNRELIABLE / REJECTED.
* **deadline-aware admission** -- expired/infeasible requests shed with an
  explicit REJECTED, and the pending queue dispatches in deadline order,
  not FIFO.
* **degradation & breaker** -- overload walks the n_bits ladder and flags
  DEGRADED; consecutive failures trip a per-tenant circuit breaker.
"""

import jax
import numpy as np
import pytest

from repro.bayesnet import FrameDriver, by_name, compile_network
from repro.bayesnet.reliability import TERMINAL_STATUSES
from repro.distributed.fault import LaunchFaultInjector
from repro.obs import MetricsRegistry
from repro.serve import BayesRouter, RouterPolicy, tenant_salt

KEY = jax.random.PRNGKey(42)
FAST = dict(backoff_base_s=1e-4, backoff_cap_s=2e-3, breaker_cooldown_s=0.01)


def _frames(name, n, seed=0):
    spec = by_name(name)
    rng = np.random.default_rng(seed)
    return rng.integers(0, 2, size=(n, len(spec.evidence)), dtype=np.int32)


# --- bit-identity (acceptance criterion) -------------------------------------------

def test_router_bit_identical_to_standalone_driver():
    name = "sensor-degradation"
    frames = _frames(name, 6)
    r = BayesRouter(RouterPolicy(), KEY, n_bits=128, max_batch=4)
    rids = r.submit(name, frames)
    res = r.drain()

    d = FrameDriver(
        compile_network(by_name(name), 128),
        max_batch=4, base_key=KEY, salt=tenant_salt(name),
    )
    d.submit(frames)
    ref = d.drain()
    for i, rid in enumerate(rids):
        assert res[rid].status == "OK"
        np.testing.assert_array_equal(np.asarray(res[rid].post), ref[i][0])
        assert res[rid].accepted == ref[i][1]


def test_tenant_entropy_isolation():
    # two tenants of the same spec (custom salts) draw disjoint entropy
    frames = _frames("sensor-degradation", 4)
    spec = by_name("sensor-degradation")
    r = BayesRouter(RouterPolicy(), KEY, n_bits=128, max_batch=4)
    r.register(spec, salt=1)
    import dataclasses as _dc

    spec_b = _dc.replace(spec, name="sensor-degradation-b")
    r.register(spec_b, salt=2)
    ra = r.submit("sensor-degradation", frames)
    rb = r.submit("sensor-degradation-b", frames)
    res = r.drain()
    assert any(
        not np.array_equal(np.asarray(res[a].post), np.asarray(res[b].post))
        for a, b in zip(ra, rb)
    )


# --- chaos (acceptance criterion) --------------------------------------------------

def test_chaos_every_frame_terminates_exactly_once():
    inj = LaunchFaultInjector(
        seed=3, p_drop=0.02, p_stall=0.01, p_corrupt=0.02, stall_ms=2.0
    )
    mx = MetricsRegistry()
    r = BayesRouter(
        RouterPolicy(**FAST), KEY, n_bits=64, max_batch=4, fault=inj, metrics=mx
    )
    submitted = []
    for i, name in enumerate(
        ["sensor-degradation", "pedestrian-night", "lane-change"]
    ):
        submitted += r.submit(name, _frames(name, 5, seed=i))
    out = r.drain()
    assert sorted(out) == sorted(submitted)          # zero lost frames
    assert sorted(r.results) == sorted(submitted)    # exactly one terminal each
    for res in out.values():
        assert res.status in TERMINAL_STATUSES
    assert sum(r.status_counts().values()) == len(submitted)


def test_total_device_failure_still_terminates():
    inj = LaunchFaultInjector(seed=0, p_drop=1.0)
    r = BayesRouter(
        RouterPolicy(max_redispatch=1, breaker_threshold=2, **FAST),
        KEY, n_bits=64, max_batch=4, fault=inj,
    )
    rids = r.submit("sensor-degradation", _frames("sensor-degradation", 4))
    out = r.drain()
    assert sorted(out) == rids
    for rid in rids:
        assert out[rid].status == "UNRELIABLE"       # flagged, never dropped
        assert out[rid].accepted == 0


# --- deadline-aware admission ------------------------------------------------------

def test_expired_deadline_sheds_rejected_immediately():
    r = BayesRouter(RouterPolicy(), KEY, n_bits=64, max_batch=4)
    rids = r.submit(
        "sensor-degradation", _frames("sensor-degradation", 3), deadline_ms=-1.0
    )
    for rid in rids:                                 # shed at submit, no pump
        assert r.results[rid].status == "REJECTED"
        assert r.results[rid].post is None
    assert r.pending == 0
    assert r.drain() == {rid: r.results[rid] for rid in rids}


def test_pending_queue_is_deadline_ordered_not_fifo():
    r = BayesRouter(RouterPolicy(), KEY, n_bits=64, max_batch=1)
    fr = _frames("sensor-degradation", 1)
    late = r.submit("sensor-degradation", fr, deadline_ms=60_000)[0]
    soon = r.submit("sensor-degradation", fr, deadline_ms=10_000)[0]
    r.drain()
    # the later-submitted, earlier-deadline request dispatched first
    assert r.requests[soon].dispatch_seq < r.requests[late].dispatch_seq


def test_infeasible_request_sheds_instead_of_queuing():
    r = BayesRouter(RouterPolicy(**FAST), KEY, n_bits=64, max_batch=4)
    name = r.register("sensor-degradation")
    import time

    r.tenant(name).breaker_open_until = time.perf_counter() + 30.0
    rids = r.submit(name, _frames(name, 2), deadline_ms=50.0)
    for rid in rids:                                 # cannot be served in time
        assert r.results[rid].status == "REJECTED"


# --- graceful degradation ----------------------------------------------------------

def test_overload_degrades_along_nbits_ladder():
    pol = RouterPolicy(capacity=2, max_degrade=2, min_n_bits=32, **FAST)
    r = BayesRouter(pol, KEY, n_bits=512, max_batch=4)
    name = "sensor-degradation"
    rids = r.submit(name, _frames(name, 9))
    out = r.drain()
    t = r.tenant(name)
    levels = {out[rid].degrade_level for rid in rids}
    assert max(levels) == 2                          # 9 pending // 2 capacity -> 2
    assert all(out[rid].status == "DEGRADED" for rid in rids)
    assert t.n_bits_for(1) == 128 and t.n_bits_for(2) == 32
    for level, drv in t.drivers.items():
        assert drv.net.n_bits == t.n_bits_for(level)


def test_nominal_load_never_degrades():
    r = BayesRouter(RouterPolicy(), KEY, n_bits=64, max_batch=4)
    rids = r.submit("sensor-degradation", _frames("sensor-degradation", 4))
    out = r.drain()
    assert all(out[rid].status == "OK" for rid in rids)
    assert all(out[rid].degrade_level == 0 for rid in rids)


def test_degrade_ladder_floors_and_collapses():
    pol = RouterPolicy(capacity=1, max_degrade=2, min_n_bits=128, **FAST)
    r = BayesRouter(pol, KEY, n_bits=128, max_batch=4)
    name = r.register("sensor-degradation")
    t = r.tenant(name)
    # every rung floors to the base n_bits: the "degraded" driver IS level 0
    _, eff = t.driver(2)
    assert eff == 0 and list(t.drivers) == [0]


# --- failure response --------------------------------------------------------------

class _Switchable(LaunchFaultInjector):
    """Chaos with an off switch: drop everything while ``on`` is set."""

    def __init__(self):
        super().__init__()
        self.on = True

    def draw(self, *ids):
        if self.on:
            self.injected["drop"] += 1
            return "drop"
        return None


def test_breaker_trips_then_recovers():
    inj = _Switchable()
    mx = MetricsRegistry()
    r = BayesRouter(
        RouterPolicy(breaker_threshold=2, max_redispatch=2, **FAST),
        KEY, n_bits=64, max_batch=4, fault=inj, metrics=mx,
    )
    name = "sensor-degradation"
    bad = r.submit(name, _frames(name, 3))
    out = r.drain()
    t = r.tenant(name)
    assert t.trips >= 1
    assert all(out[rid].status == "UNRELIABLE" for rid in bad)
    assert mx.count("router_breaker_trips") == t.trips
    # device heals: the half-open probe succeeds and the breaker closes
    inj.on = False
    good = r.submit(name, _frames(name, 3, seed=1))
    out = r.drain()
    assert all(out[rid].status == "OK" for rid in good)
    assert not t.breaker_open
    assert t.consecutive_failures == 0
    assert mx.count("router_breaker_closes") >= 1


def test_backoff_gates_redispatch():
    import time

    r = BayesRouter(RouterPolicy(**FAST), KEY, n_bits=64, max_batch=4)
    name = r.register("sensor-degradation")
    t = r.tenant(name)
    t.consecutive_failures = 3
    t.not_before = time.perf_counter() + 30.0
    rids = r.submit(name, _frames(name, 2), deadline_ms=120_000)
    r.pump()
    assert all(r.requests[rid].dispatch_seq == -1 for rid in rids)  # held back
    assert r.pending == 2                                           # still queued
    t.not_before = 0.0
    out = r.drain()
    assert all(out[rid].status == "OK" for rid in rids)


# --- plan cache / tenants ----------------------------------------------------------

def test_lru_evicts_idle_tenants_only_and_salts_persist():
    r = BayesRouter(
        RouterPolicy(), KEY, n_bits=64, max_batch=4, max_cached_tenants=2
    )
    r.register("sensor-degradation", salt=123)
    r.register("pedestrian-night")
    r.register("lane-change")
    assert len(r._tenants) == 2
    assert "sensor-degradation" not in r._tenants    # LRU victim
    # a tenant with frames in its driver is never evicted
    import time

    r.submit("pedestrian-night", _frames("pedestrian-night", 2))
    r._admit(time.perf_counter())                    # frames now held by the tenant
    r.register("intersection")
    assert "pedestrian-night" in r._tenants
    assert "lane-change" not in r._tenants           # the idle one went instead
    r.drain()
    # the evicted tenant's salt survives re-registration
    r.register("sensor-degradation")
    assert r.tenant("sensor-degradation").salt == 123


def test_harvest_pops_fresh_results_once():
    r = BayesRouter(RouterPolicy(), KEY, n_bits=64, max_batch=4)
    rids = r.submit("sensor-degradation", _frames("sensor-degradation", 2))
    out = r.drain()
    assert sorted(out) == rids
    assert r.harvest() == {}                         # fresh set was consumed
    assert sorted(r.results) == rids                 # accounting keeps them


def test_policy_validation():
    with pytest.raises(ValueError, match="deadline_mult"):
        RouterPolicy(deadline_mult=0)
    with pytest.raises(ValueError, match="degrade_step"):
        RouterPolicy(degrade_step=1)
    with pytest.raises(ValueError, match="breaker_threshold"):
        RouterPolicy(breaker_threshold=0)
    with pytest.raises(ValueError, match="max_cached_tenants"):
        BayesRouter(max_cached_tenants=0)


def test_metrics_and_status_accounting():
    mx = MetricsRegistry()
    r = BayesRouter(RouterPolicy(), KEY, n_bits=64, max_batch=4, metrics=mx)
    name = "sensor-degradation"
    rids = r.submit(name, _frames(name, 3))
    r.submit(name, _frames(name, 1), deadline_ms=-1.0)
    r.drain()
    assert mx.count("router_submitted") == 4
    assert mx.count("router_ok") == 3
    assert mx.count("router_rejected") == 1
    assert f"router_{name}_frame_ms" in mx.histograms
    counts = r.status_counts()
    assert counts["OK"] == 3 and counts["REJECTED"] == 1
    for rid in rids:
        assert r.results[rid].deadline_met


# --- crossbar health: retry clamp, drift monitor, recalibration (DESIGN §15) -------

def test_degraded_rung_clamps_retry_escalation():
    from repro.bayesnet.reliability import RetryPolicy

    pol = RouterPolicy(capacity=2, max_degrade=2, min_n_bits=32, **FAST)
    r = BayesRouter(
        pol, KEY, n_bits=512, max_batch=4,
        retry=RetryPolicy(
            min_confidence=0.9999, max_retries=2, escalation=4,
            max_n_bits=1 << 16,
        ),
    )
    name = "sensor-degradation"
    rids = r.submit(name, _frames(name, 9))
    r.drain()
    t = r.tenant(name)
    assert any(level > 0 for level in t.drivers)
    clamped_reports = []
    for level, drv in t.drivers.items():
        rung = t.n_bits_for(level)
        if level == 0:
            # nominal rung keeps the caller's escalation headroom
            assert drv.retry.max_n_bits == 1 << 16
            continue
        # the DEGRADED rung's ladder is clamped to its own fidelity cut
        assert drv.retry.max_n_bits == rung
        for rep in drv.reports.values():
            assert rep.n_bits <= rung
            if rep.attempts > 1:
                clamped_reports.append(rep)
    # escalated frames on a degraded rung carry the collision flag
    assert clamped_reports and all(
        rep.escalation_clamped for rep in clamped_reports
    )


def test_drift_monitor_auto_recalibrates_tenant():
    from repro.bayesnet import DriftPolicy, NoiseModel
    from repro.bayesnet.reliability import HEALTH_RECALIBRATING

    r = BayesRouter(
        RouterPolicy(**FAST), KEY, n_bits=256, max_batch=8,
        drift=DriftPolicy(warmup=2),
    )
    name = "pedestrian-night"
    r.register(name, noise=NoiseModel(seed=9, cycle=0.0, wear_tau=1.0))
    assert r.health(name) == "HEALTHY"
    rids = list(r.submit(name, _frames(name, 8)))
    r.drain()
    t = r.tenant(name)
    assert t.monitor.launches >= 1                   # the driver feeds the monitor
    # force the latch (a statistically-guaranteed trip needs thousands of
    # launches; the trip -> swap -> reset plumbing is what's under test)
    t.monitor.state = HEALTH_RECALIBRATING
    rids.extend(r.submit(name, _frames(name, 8, seed=1)))
    r.drain()
    assert t.recalibrations == 1                     # the pump recalibrated
    assert r.health(name) == "HEALTHY"               # reset after the swap
    assert sorted(r.results) == sorted(rids)         # recalibration lost nothing
    assert all(r.results[rid].status == "OK" for rid in rids)
    # the swapped-in plans are calibrate-back twins at the tenant's cycle
    assert all(d.net.program is not None for d in t.drivers.values())


def test_manual_recalibrate_and_clean_tenant_refuses():
    from repro.bayesnet import DriftPolicy, NoiseModel

    r = BayesRouter(
        RouterPolicy(**FAST), KEY, n_bits=128, max_batch=4,
        drift=DriftPolicy(warmup=64),   # detector effectively off
    )
    noisy = "lane-change"
    r.register(noisy, noise=NoiseModel(seed=4, wear_tau=2.0))
    r.submit(noisy, _frames(noisy, 4))
    r.drain()
    t = r.tenant(noisy)
    cycle = r.recalibrate(noisy)
    assert t.recalibrations == 1 and cycle == t.cycle_estimate()
    # a clean tenant has no drift to calibrate back
    clean = r.register("intersection")
    with pytest.raises(ValueError):
        r.recalibrate(clean)
    # unmonitored routers report HEALTHY rather than raising
    r2 = BayesRouter(RouterPolicy(**FAST), KEY, n_bits=128, max_batch=4)
    r2.register("intersection")
    assert r2.health("intersection") == "HEALTHY"
    assert r2.tenant("intersection").monitor is None


def test_auto_recalibrate_off_leaves_latch_visible():
    from repro.bayesnet import DriftPolicy, NoiseModel

    from repro.bayesnet.reliability import HEALTH_RECALIBRATING

    r = BayesRouter(
        RouterPolicy(**FAST), KEY, n_bits=256, max_batch=8,
        drift=DriftPolicy(warmup=2),
        auto_recalibrate=False,
    )
    name = "pedestrian-night"
    r.register(name, noise=NoiseModel(seed=9, cycle=0.0, wear_tau=1.0))
    r.submit(name, _frames(name, 8))
    r.drain()
    t = r.tenant(name)
    t.monitor.state = HEALTH_RECALIBRATING
    r.submit(name, _frames(name, 8, seed=1))
    r.drain()
    assert t.recalibrations == 0
    assert r.health(name) == "RECALIBRATING"         # latched for the operator
