"""Serving engine: batched prefill/decode, Bayes-gated emission, bayes head."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import api, bayes_head
from repro.serve import EngineConfig, Request, ServeEngine


@pytest.fixture(scope="module")
def engine_setup():
    cfg = get_smoke_config("qwen2-72b")
    params = api.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_serves_batch(engine_setup):
    cfg, params = engine_setup
    eng = ServeEngine(cfg, params, EngineConfig(max_batch=3, t_cache=64))
    reqs = [
        Request(rid=i, prompt=np.arange(4 + i) % cfg.vocab_size, max_new_tokens=6)
        for i in range(3)
    ]
    out = eng.run(jax.random.PRNGKey(1), reqs)
    for r in out:
        assert len(r.out_tokens) == 6
        assert all(0 <= t < cfg.vocab_size + 256 for t in r.out_tokens)
        assert all(0.0 <= c <= 1.0 for c in r.confidences)
        assert r.done


def test_engine_frees_slots(engine_setup):
    cfg, params = engine_setup
    eng = ServeEngine(cfg, params, EngineConfig(max_batch=2, t_cache=64))
    reqs = [Request(rid=0, prompt=np.arange(4), max_new_tokens=2),
            Request(rid=1, prompt=np.arange(5), max_new_tokens=2)]
    eng.run(jax.random.PRNGKey(0), reqs)
    assert all(s is None for s in eng.slots)


def test_pending_queue_admits_as_slots_free(engine_setup):
    """Oversubmitted requests queue (no RuntimeError) and are admitted
    mid-flight as decodes complete -- not in waves after the batch drains."""
    cfg, params = engine_setup
    eng = ServeEngine(cfg, params, EngineConfig(max_batch=2, t_cache=64))
    reqs = [
        Request(rid=0, prompt=np.arange(4), max_new_tokens=8),
        Request(rid=1, prompt=np.arange(5), max_new_tokens=2),
        Request(rid=2, prompt=np.arange(3), max_new_tokens=2),
        Request(rid=3, prompt=np.arange(4), max_new_tokens=2),
    ]
    eng.add_requests(reqs)
    assert eng.pending and len([s for s in eng.slots if s is not None]) == 2
    out = eng.run(jax.random.PRNGKey(0), [])
    del out
    for r in reqs:
        assert r.done and len(r.out_tokens) == r.max_new_tokens, r.rid
    assert not eng.pending and all(s is None for s in eng.slots)
    # rid=1 frees its slot at step 2; rid=2 must be admitted then, while rid=0
    # (8 tokens) is still decoding -- continuous batching, not wave batching.
    assert reqs[2].admit_step == 2, reqs[2].admit_step
    assert reqs[3].admit_step == 4, reqs[3].admit_step


def test_pending_queue_churn_preserves_order_and_never_starves(engine_setup):
    """Repeated overflow churn: waves of requests arriving mid-flight must be
    admitted in submission order (admit_step non-decreasing across the
    submission sequence) and every request must finish -- no starvation, no
    queue-jumping, however often the pending queue refills."""
    cfg, params = engine_setup
    eng = ServeEngine(cfg, params, EngineConfig(max_batch=2, t_cache=96))
    waves = [
        [Request(rid=10 * w + i, prompt=np.arange(3 + i), max_new_tokens=1 + (i % 3))
         for i in range(3)]
        for w in range(3)
    ]
    submitted = []
    eng.add_requests(waves[0])
    submitted += waves[0]
    logits = eng.prefill_all()
    key = jax.random.PRNGKey(4)
    steps = 0
    while any(s is not None for s in eng.slots) or eng.pending:
        if steps == 2:
            eng.add_requests(waves[1])
            submitted += waves[1]
        if steps == 4:
            eng.add_requests(waves[2])
            submitted += waves[2]
        key, sub = jax.random.split(key)
        logits, _ = eng.step(sub, logits)
        steps += 1
        assert steps < 100, "engine churn did not converge -- starvation"
    for r in submitted:
        assert r.done and len(r.out_tokens) == r.max_new_tokens, r.rid
        assert r.admit_step >= 0, f"rid {r.rid} was never admitted"
    # admission follows submission order: no later request is granted a slot
    # before an earlier one (equal steps = same admission round, still fair)
    admit_steps = [r.admit_step for r in submitted]
    assert admit_steps == sorted(admit_steps), admit_steps


def test_midflight_add_requests_gets_prefilled(engine_setup):
    """A request added while the engine is decoding must not seize a free slot
    without a cache refresh -- step() admits it with a re-prefill."""
    cfg, params = engine_setup
    eng = ServeEngine(cfg, params, EngineConfig(max_batch=2, t_cache=64))
    r0 = Request(rid=0, prompt=np.arange(4), max_new_tokens=6)
    eng.add_requests([r0])
    logits = eng.prefill_all()
    key = jax.random.PRNGKey(0)
    key, sub = jax.random.split(key)
    logits, _ = eng.step(sub, logits)
    # engine mid-flight with a free slot; late arrival must wait for step()
    late = Request(rid=1, prompt=np.arange(5), max_new_tokens=3)
    eng.add_requests([late])
    assert eng.pending and late.admit_step == -1
    while any(s is not None for s in eng.slots) or eng.pending:
        key, sub = jax.random.split(key)
        logits, _ = eng.step(sub, logits)
    # admitted at the very next step (slot was already free), fully decoded
    assert late.admit_step == 2 and late.done
    assert len(late.out_tokens) == 3 and len(r0.out_tokens) == 6


def test_bayes_gate_vs_greedy(engine_setup):
    cfg, params = engine_setup
    eng = ServeEngine(cfg, params, EngineConfig(max_batch=1, t_cache=64, bayes_gate=False))
    r = Request(rid=0, prompt=np.arange(6), max_new_tokens=4)
    eng.run(jax.random.PRNGKey(0), [r])
    assert len(r.out_tokens) == 4


def test_fuse_posteriors_sharpens():
    """Two agreeing sources -> fused confidence >= single-source confidence."""
    key = jax.random.PRNGKey(2)
    logits = jax.random.normal(key, (1, 3, 64)) * 2.0
    sources = jnp.stack([logits[0], logits[0] * 0.9], axis=0)  # agreeing views
    token, conf, fused = bayes_head.fuse_posteriors(sources, top_k=8)
    single = jax.nn.softmax(logits[0], -1).max(-1)
    # eq (5) with uniform prior sharpens agreeing posteriors
    assert float(conf[0]) >= float(single[0]) - 0.05
    np.testing.assert_allclose(np.asarray(fused.sum(-1)), 1.0, rtol=1e-5)


def test_fuse_posteriors_stochastic_matches_analytic():
    key = jax.random.PRNGKey(3)
    logits = jax.random.normal(key, (2, 4, 32)) * 1.5
    t_a, c_a, _ = bayes_head.fuse_posteriors(logits, top_k=4)
    t_s, c_s = bayes_head.fuse_posteriors_stochastic(
        jax.random.PRNGKey(9), logits, top_k=4, n_bits=1 << 13
    )
    # stochastic circuit agrees with the analytic path on the argmax decision
    # (ties between near-equal candidates may flip under stochastic sampling)
    agree = int(np.sum(np.asarray(t_a) == np.asarray(t_s)))
    assert agree >= 3, (np.asarray(t_a), np.asarray(t_s))
    np.testing.assert_allclose(np.asarray(c_a), np.asarray(c_s), atol=0.08)


def test_reliable_decision_gate():
    ok, tok = bayes_head.reliable_decision(
        jnp.array([1, 2]), jnp.array([0.9, 0.3]), threshold=0.7
    )
    assert bool(ok[0]) and not bool(ok[1])
