"""DeepSeek MTP-head × main-head Bayesian fusion (DESIGN.md §4, the closest LM
analogue of the paper's RGB+thermal fusion)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import api, bayes_head, layers, transformer


def test_mtp_head_as_second_posterior_source():
    """Fuse main-head and MTP-head posteriors of the SAME next token (eq 4)."""
    cfg = get_smoke_config("deepseek-v3-671b")
    params = api.init(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)

    # main head posterior for token t+1 given prefix ..t: forward logits at t
    h, _ = transformer.forward(params, cfg, tokens, return_hidden=True)
    unembed = params["unembed"]
    main_logits = (h[:, -2] @ unembed).astype(jnp.float32)       # predicts t_last

    # MTP head predicts t+2 from [h_t ; emb(t+1)]: use position -3 so it also
    # predicts the final token -> two conditionally-independent posteriors of
    # the same event, exactly the paper's eq (4) setting
    emb_next = params["embed"][tokens[:, -2]]
    hcat = jnp.concatenate([h[:, -3], emb_next], axis=-1)
    h2 = (hcat @ params["mtp"]["proj"])[:, None, :]
    h2, _, _ = transformer.block_apply(
        params["mtp"]["block"], h2, cfg, cfg.pattern[0], positions=jnp.arange(1)
    )
    h2 = layers.apply_norm(params["mtp"]["norm"], h2, cfg.norm)
    mtp_logits = (h2[:, 0] @ unembed).astype(jnp.float32)

    sources = jnp.stack([main_logits, mtp_logits])
    token, conf, fused = bayes_head.fuse_posteriors(sources, top_k=8)
    assert token.shape == (2,)
    assert np.all(np.asarray(conf) >= 0) and np.all(np.asarray(conf) <= 1)
    np.testing.assert_allclose(np.asarray(fused.sum(-1)), 1.0, rtol=1e-5)
    # gating returns a boolean decision per sequence
    ok, _ = bayes_head.reliable_decision(token, conf, threshold=0.2)
    assert ok.shape == (2,)
