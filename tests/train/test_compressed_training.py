"""End-to-end: training with int8 stochastic-number gradient compression (the
beyond-paper cross-pod path) converges like the uncompressed loop."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig, batch_at_step
from repro.models import api
from repro.optim import adamw, compression


def test_compressed_grads_converge():
    cfg = get_smoke_config("qwen2-72b")
    data_cfg = DataConfig(seed=3, global_batch=8, seq_len=32, vocab_size=cfg.vocab_size)
    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=20)

    def run(compressed: bool):
        params = api.init(cfg, jax.random.PRNGKey(0))
        opt = adamw.init(params)
        residual = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        losses = []
        for step in range(20):
            batch = batch_at_step(data_cfg, step)
            (loss, _), grads = jax.value_and_grad(
                lambda p: api.loss(p, cfg, batch), has_aux=True
            )(params)
            if compressed:
                # simulate the cross-pod path: encode int8 + error feedback,
                # decode (the all-reduce mean of identical replicas = identity)
                q, s, residual = compression.compress(
                    jax.random.fold_in(jax.random.PRNGKey(9), step), grads, residual
                )
                grads = compression.decompress(q, s)
            params, opt, _ = adamw.apply(grads, opt, opt_cfg)
            losses.append(float(loss))
        return losses

    base = run(False)
    comp = run(True)
    # both decrease, and compressed tracks uncompressed closely
    assert base[-1] < base[0] - 0.3
    assert comp[-1] < comp[0] - 0.3
    assert abs(comp[-1] - base[-1]) < 0.35, (base[-1], comp[-1])
