"""Training-loop integration: loss decreases, microbatching, checkpoint/restart."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig, batch_at_step, host_slice
from repro.models import api
from repro.optim import adamw
from repro.train.loop import TrainConfig, TrainLoop, make_train_step


def small_setup(tmp_path, steps=12, arch="qwen2-72b"):
    cfg = get_smoke_config(arch)
    data_cfg = DataConfig(seed=1, global_batch=8, seq_len=32, vocab_size=cfg.vocab_size)
    train_cfg = TrainConfig(
        steps=steps, ckpt_every=5, ckpt_dir=str(tmp_path / "ckpt"), microbatches=1
    )
    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=steps)
    return cfg, data_cfg, train_cfg, opt_cfg


def test_loss_decreases(tmp_path):
    cfg, data_cfg, train_cfg, opt_cfg = small_setup(tmp_path, steps=15)
    loop = TrainLoop(cfg, data_cfg, train_cfg, opt_cfg)
    _, _, history = loop.run(jax.random.PRNGKey(0))
    first = np.mean([h["loss"] for h in history[:3]])
    last = np.mean([h["loss"] for h in history[-3:]])
    assert last < first - 0.2, f"loss did not decrease: {first} -> {last}"


def test_microbatch_equivalence(tmp_path):
    """grad accumulation over 4 microbatches == single large batch step."""
    cfg, data_cfg, _, opt_cfg = small_setup(tmp_path)
    params = api.init(cfg, jax.random.PRNGKey(0))
    opt = adamw.init(params)
    batch = batch_at_step(data_cfg, 0)
    step1 = make_train_step(cfg, opt_cfg, microbatches=1)
    step4 = make_train_step(cfg, opt_cfg, microbatches=4)
    p1, _, m1 = jax.jit(step1)(params, opt, batch)
    p4, _, m4 = jax.jit(step4)(params, opt, batch)
    # losses match exactly; params match to bf16 tolerance
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]), rtol=1e-3)
    l1, l4 = jax.tree.leaves(p1), jax.tree.leaves(p4)
    for a, b in zip(l1, l4):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=2e-2
        )


def test_checkpoint_restart_resumes(tmp_path):
    """Kill at step 10, restart -> identical final state as uninterrupted run."""
    cfg, data_cfg, train_cfg, opt_cfg = small_setup(tmp_path, steps=10)
    loop = TrainLoop(cfg, data_cfg, train_cfg, opt_cfg)
    p_full, o_full, _ = loop.run(jax.random.PRNGKey(0))

    # interrupted run: first 5 steps (ckpt at 5), then a fresh loop resumes
    cfg2, data2, tc2, oc2 = small_setup(tmp_path.joinpath("b"), steps=10)
    tc5 = dataclasses.replace(tc2, steps=5)
    loop_a = TrainLoop(cfg2, data2, tc5, oc2)
    loop_a.run(jax.random.PRNGKey(0))
    loop_b = TrainLoop(cfg2, data2, tc2, oc2)
    p_res, o_res, _ = loop_b.run(jax.random.PRNGKey(0))

    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_res)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=1e-6
        )


def test_data_pipeline_determinism_and_host_slicing():
    dc = DataConfig(seed=7, global_batch=8, seq_len=16, vocab_size=128)
    b1 = batch_at_step(dc, 3)
    b2 = batch_at_step(dc, 3)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    b3 = batch_at_step(dc, 4)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))
    # host slices tile the global batch
    s0 = host_slice(b1, 0, 2)["tokens"]
    s1 = host_slice(b1, 1, 2)["tokens"]
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(s0), np.asarray(s1)]), np.asarray(b1["tokens"])
    )


def test_prefetcher():
    from repro.data.pipeline import Prefetcher

    dc = DataConfig(seed=0, global_batch=2, seq_len=8, vocab_size=64)
    pf = Prefetcher(dc, start_step=0, depth=2)
    step, batch = next(pf)
    assert step == 0 and batch["tokens"].shape == (2, 8)
    step, batch = next(pf)
    assert step == 1
    pf.close()
