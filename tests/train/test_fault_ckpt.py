"""Fault-tolerance mechanisms + checkpoint semantics."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.distributed import fault


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = {"a": jnp.arange(10, dtype=jnp.float32), "b": {"c": jnp.ones((3, 4))}}
    ck.save(5, tree, blocking=True)
    step, restored = ck.restore(tree)
    assert step == 5
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_atomic_commit_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    tree = {"x": jnp.zeros((4,))}
    for s in (1, 2, 3, 4):
        ck.save(s, tree, blocking=True)
    assert ck.available_steps() == [3, 4]       # gc keeps last 2
    # a partial (uncommitted) checkpoint is invisible
    (tmp_path / "step_9").mkdir()
    (tmp_path / "step_9" / "shard_0.npz").write_bytes(b"junk")
    assert 9 not in ck.available_steps()


def test_checkpoint_async(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = {"x": jnp.arange(1000, dtype=jnp.float32)}
    ck.save(1, tree, blocking=False)
    ck.wait()
    assert ck.latest_step() == 1


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"x": jnp.zeros((4,))}, blocking=True)
    with pytest.raises(ValueError):
        ck.restore({"x": jnp.zeros((5,))})


def test_elastic_restore_resharding(tmp_path):
    """Restore re-places arrays under new shardings (mesh change simulated)."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    ck = Checkpointer(str(tmp_path))
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    ck.save(1, tree, blocking=True)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("data",))
    shardings = {"w": NamedSharding(mesh, P("data", None))}
    _, restored = ck.restore(tree, shardings=shardings)
    assert restored["w"].sharding == shardings["w"]


def test_straggler_watch():
    watch = fault.StragglerWatch(threshold=3.0)
    for step in range(10):
        assert not watch.observe(step, 0.1)
    assert watch.observe(10, 1.0)          # 10x slower -> flagged
    assert watch.flagged_steps == [10]
    assert not watch.observe(11, 0.1)      # recovery


def test_spike_rewind():
    guard = fault.SpikeRewind(factor=3.0, patience=2)
    assert not guard.observe(2.0)
    assert not guard.observe(2.1)
    assert not guard.observe(9.0)          # first spike: patience
    assert guard.observe(9.5)              # second consecutive -> rewind
    assert not guard.observe(2.0)          # reset after rewind


def test_preemption_guard_flag():
    g = fault.PreemptionGuard(install=False)
    assert not g.requested
    g._handler(None, None)
    assert g.requested


def test_compression_error_feedback():
    """int8 stochastic compression: unbiased, error feedback shrinks residual."""
    from repro.optim import compression

    key = jax.random.PRNGKey(0)
    g = {"w": jax.random.normal(jax.random.PRNGKey(1), (256, 64)) * 0.01}
    # unbiasedness: mean of many stochastic encodings ~ g
    acc = jnp.zeros_like(g["w"])
    n = 30
    for i in range(n):
        q, s, _ = compression.compress(jax.random.fold_in(key, i), g)
        acc = acc + compression.decompress(q, s)["w"]
    np.testing.assert_allclose(
        np.asarray(acc / n), np.asarray(g["w"]), atol=2e-4
    )
    # single-shot error bounded by one quantisation step
    q, s, res = compression.compress(key, g)
    assert float(jnp.max(jnp.abs(res["w"]))) <= float(s["w"]) + 1e-7
