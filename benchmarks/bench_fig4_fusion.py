"""Fig 4 / Movie S1: RGB+thermal Bayesian fusion on synthetic FLIR-like scenes.

Measures the paper's claims: fusion recovers targets missed by single
modalities (paper: +85% vs thermal, +19% vs RGB detection chances in the video
demo) and raises decision confidence.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core import fusion
from repro.data import detection
from repro.kernels.fusion_map.ops import fusion_map


def run():
    key = jax.random.PRNGKey(0)
    cfg = detection.SceneConfig(height=64, width=64)

    n_scenes = 30
    tp_rgb, tp_th, tp_fused = [], [], []
    conf_rgb, conf_th, conf_fused = [], [], []
    for i in range(n_scenes):
        gt, p_rgb, p_th, night = detection.make_scene(jax.random.fold_in(key, i), cfg)
        p_modal = jnp.stack(
            [jnp.stack([p_rgb, 1 - p_rgb], -1), jnp.stack([p_th, 1 - p_th], -1)]
        )                                           # (2, H, W, 2)
        fused = fusion_map(p_modal.reshape(2, -1, 2))[:, 0].reshape(gt.shape)
        for p, tps, confs in ((p_rgb, tp_rgb, conf_rgb), (p_th, tp_th, conf_th),
                              (fused, tp_fused, conf_fused)):
            tp, fp, conf = detection.detection_metrics(gt, p)
            tps.append(float(tp))
            confs.append(float(conf))

    r, t, f = np.mean(tp_rgb), np.mean(tp_th), np.mean(tp_fused)
    emit("fig4b.detection_rate", 0.0,
         f"rgb={r:.2f} thermal={t:.2f} fused={f:.2f} "
         f"gain_vs_thermal=+{(f/t-1)*100:.0f}%(paper +85%) "
         f"gain_vs_rgb=+{(f/r-1)*100:.0f}%(paper +19%)")
    emit("fig4b.confidence_on_targets", 0.0,
         f"rgb={np.mean(conf_rgb):.2f} thermal={np.mean(conf_th):.2f} "
         f"fused={np.mean(conf_fused):.2f}")

    # stochastic circuit path agrees with analytic fusion (one scene)
    gt, p_rgb, p_th, _ = detection.make_scene(jax.random.fold_in(key, 999), cfg)
    sel = jnp.stack([p_rgb.reshape(-1)[:64], p_th.reshape(-1)[:64]], axis=-1)
    stoch = fusion.detection_fusion(jax.random.PRNGKey(7), sel, n_bits=1 << 12)
    analytic = fusion.fuse_analytic(
        jnp.stack([jnp.stack([sel[:, 0], 1 - sel[:, 0]], -1),
                   jnp.stack([sel[:, 1], 1 - sel[:, 1]], -1)], axis=-2)
    )[:, 0]
    emit("fig4.stochastic_vs_analytic", 0.0,
         f"mean_abs_err={float(jnp.mean(jnp.abs(stoch - analytic))):.3f}@4096bit")

    # Movie S1 scale: full-frame fused maps through the Pallas kernel (interp)
    frame = jnp.stack([
        jnp.stack([p_rgb, 1 - p_rgb], -1).reshape(-1, 2),
        jnp.stack([p_th, 1 - p_th], -1).reshape(-1, 2),
    ])
    us = timeit(lambda: fusion_map(frame), iters=3)
    emit("movieS1.frame_fusion_64x64", us,
         f"{64*64/(us/1e6)/1e6:.2f}Mpix/s (CPU interpret; TPU path is the "
         f"fusion_map kernel)")


if __name__ == "__main__":
    run()
