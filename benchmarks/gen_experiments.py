"""Generate the data-driven sections of EXPERIMENTS.md from experiments/dryrun/.

Usage: PYTHONPATH=src python -m benchmarks.gen_experiments
Writes markdown tables to experiments/generated_tables.md which EXPERIMENTS.md
references verbatim (and the final EXPERIMENTS.md inlines).
"""

from __future__ import annotations

import glob
import json
import os

HERE = os.path.dirname(os.path.abspath(__file__))
DRYRUN = os.path.join(HERE, "..", "experiments", "dryrun")
OUT = os.path.join(HERE, "..", "experiments", "generated_tables.md")

ARCH_ORDER = [
    "qwen2-72b", "starcoder2-15b", "minitron-4b", "phi3-mini-3.8b",
    "internvl2-26b", "recurrentgemma-2b", "xlstm-350m",
    "llama4-scout-17b-a16e", "deepseek-v3-671b", "seamless-m4t-large-v2",
    "paper-bayes-fusion",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load():
    cells = {}
    for path in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        d = json.load(open(path))
        key = (d.get("arch"), d.get("shape"), d.get("mesh"),
               d.get("variant", "baseline"))
        cells[key] = d
    return cells


def fmt_bytes(b):
    if b >= 1e12:
        return f"{b/1e12:.2f} TB"
    if b >= 1e9:
        return f"{b/1e9:.2f} GB"
    if b >= 1e6:
        return f"{b/1e6:.1f} MB"
    return f"{b/1e3:.0f} KB"


def dryrun_table(cells, mesh):
    lines = [
        "| arch | shape | status | bytes/device (arg+out+temp) | FLOPs/chip | collective schedule |",
        "|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            if arch == "paper-bayes-fusion" and shape != "train_4k":
                continue
            d = cells.get((arch, shape, mesh, "baseline"))
            if d is None:
                continue
            if d.get("skipped"):
                lines.append(f"| {arch} | {shape} | SKIP | — | — | {d['reason']} |")
                continue
            if not d.get("ok"):
                lines.append(f"| {arch} | {shape} | **FAIL** | — | — | {str(d.get('error'))[:60]} |")
                continue
            ma = d.get("memory_analysis", {})
            mem = (ma.get("argument_size_gb", 0) + ma.get("output_size_gb", 0)
                   + ma.get("temp_size_gb", 0))
            sched = ", ".join(
                f"{k}x{v}" for k, v in sorted(d.get("collective_counts_schedule", {}).items())
            ) or "none"
            lines.append(
                f"| {arch} | {shape} | ok ({d['compile_seconds']}s compile) | "
                f"{mem:.1f} GB | {d['flops_per_chip']:.2e} | {sched} |"
            )
    return "\n".join(lines)


def roofline_table(cells):
    lines = [
        "| arch | shape | compute (s) | memory (s) | collective (s) | bottleneck | MODEL/HLO FLOPs | peak mem/chip |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            if arch == "paper-bayes-fusion" and shape != "train_4k":
                continue
            d = cells.get((arch, shape, "pod16x16", "baseline"))
            if d is None or not d.get("ok"):
                continue
            lines.append(
                f"| {arch} | {shape} | {d['compute_s']:.3f} | {d['memory_s']:.3f} | "
                f"{d['collective_s']:.3f} | **{d['bottleneck']}** | "
                f"{d['useful_ratio']:.2f} | "
                f"{fmt_bytes(d.get('peak_memory_bytes', 0))} |"
            )
    return "\n".join(lines)


def variant_table(cells, arch, shape, variants):
    lines = [
        "| variant | compute (s) | memory (s) | collective (s) | bottleneck | temp/chip |",
        "|---|---|---|---|---|---|",
    ]
    for v in variants:
        d = cells.get((arch, shape, "pod16x16", v))
        if d is None or not d.get("ok"):
            lines.append(f"| {v} | — | — | — | (not run) | — |")
            continue
        t = d.get("memory_analysis", {}).get("temp_size_gb", 0)
        lines.append(
            f"| {v} | {d['compute_s']:.3f} | {d['memory_s']:.3f} | "
            f"{d['collective_s']:.3f} | {d['bottleneck']} | {t:.1f} GB |"
        )
    return "\n".join(lines)


def main():
    cells = load()
    parts = ["# Generated dry-run / roofline tables\n"]
    parts.append("## Dry-run, single pod (16x16 = 256 chips)\n")
    parts.append(dryrun_table(cells, "pod16x16"))
    parts.append("\n## Dry-run, multi-pod (2x16x16 = 512 chips)\n")
    parts.append(dryrun_table(cells, "pod2x16x16"))
    parts.append("\n## Roofline (single pod)\n")
    parts.append(roofline_table(cells))
    parts.append("\n## Perf variants: qwen2-72b train_4k\n")
    parts.append(variant_table(cells, "qwen2-72b", "train_4k",
                               ["baseline", "nosp", "fsdp2d", "fsdp2d+micro2",
                                "fsdp2d+qchunk1024", "fsdp2d+qchunk2048"]))
    parts.append("\n## Perf variants: deepseek-v3-671b train_4k\n")
    parts.append(variant_table(cells, "deepseek-v3-671b", "train_4k",
                               ["baseline", "micro4", "micro8"]))
    parts.append("\n## Perf variants: paper-bayes-fusion\n")
    parts.append(variant_table(cells, "paper-bayes-fusion", "train_4k",
                               ["baseline", "bits64", "rnginside", "analytic"]))
    md = "\n".join(parts) + "\n"
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        f.write(md)
    print(md)


if __name__ == "__main__":
    main()
