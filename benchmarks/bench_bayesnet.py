"""Bayesnet compiler throughput: frames/sec vs network size, entropy mode,
decision epilogue, and frame sharding.

Every scenario network is timed over a 1024-frame evidence batch in a single
jit launch, in BOTH entropy modes:

* shared entropy (``share_entropy=True``): node streams built once, every
  frame conditions the same joint sample -- the cheap-but-correlated mode.
* independent entropy (the production default): every frame draws its own
  joint sample through the fused ``net_sweep`` lowering.

The derived column of each ``_indep_`` row records the shared/indep throughput
ratio, so the cost of per-frame independence is tracked for every scenario in
every future ``BENCH_*.json`` (the committed trajectory once showed a ~70x
cliff here; the fused sweep holds it to low single digits, and CI's
bench-smoke gate fails if the pedestrian-night ratio regresses past 8x).

Two newer row families ride the same min-of-N timing:

* ``_decide_`` rows time ``CompiledNetwork.decide`` -- the sweep with its
  in-kernel decision epilogue -- against the posterior-only sweep; the
  derived column records the overhead ratio (gated ``<= 1.3x`` by
  ``check_bench``; the epilogue is a handful of argmaxes over counts that
  never leave registers, so it should be noise-level).
* ``_sharded_`` rows time the ``compile_network(devices=N)`` ``shard_map``
  launch over every visible device (run under
  ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` to get 8 CPU
  shards); the derived column records device count and the speedup vs the
  single-device independent row from the same run.  Shards are bit-identical
  to the single-device launch, so this row isolates pure execution scaling:
  on a multi-core host it approaches the device count, while a 1-2 core
  container shows mostly the smaller-working-set effect.
"""

from __future__ import annotations

import jax

from benchmarks import common

N_FRAMES = 1024
N_BITS = 4096
# binary trio + the categorical 4-class scenario (k-ary value bit-planes)
SCENARIO_NAMES = ("sensor-degradation", "pedestrian-night", "intersection",
                  "obstacle-class")


def run() -> None:
    from repro.bayesnet import by_name, compile_network, sample_evidence

    key = jax.random.PRNGKey(0)
    shared_fps = {}
    for name in SCENARIO_NAMES:
        spec = by_name(name)
        net = compile_network(spec, n_bits=N_BITS, share_entropy=True)
        ev = sample_evidence(spec, jax.random.PRNGKey(1), N_FRAMES)
        # shared launches run sub-millisecond, so min-of-25 samples only a
        # ~25ms window -- too narrow to dodge a multi-second interference
        # burst on a shared tenant.  100 iters widens the window 4x at
        # trivial cost; the slower row families keep 25 (already 100ms+).
        us = common.timeit(lambda n=net, e=ev: n.run(key, e), iters=100, stat="min")
        fps = N_FRAMES / (us / 1e6)
        shared_fps[name] = fps
        common.emit(
            f"bayesnet_{name}_batch{N_FRAMES}",
            us,
            f"{fps:,.0f} frames/s | {spec.n_nodes} nodes fan-in {spec.max_fan_in()} "
            f"n_bits {N_BITS}",
        )

    # independent entropy: every frame draws its own joint sample (fused
    # sweep).  The compiled nets and evidence batches are kept for the decide
    # and sharded row families below -- recompiling the identical program
    # three times would triple bench-smoke compile time for nothing.
    indep_nets = {}
    for name in SCENARIO_NAMES:
        spec = by_name(name)
        net = compile_network(spec, n_bits=N_BITS, share_entropy=False)
        ev = sample_evidence(spec, jax.random.PRNGKey(1), N_FRAMES)
        indep_nets[name] = (net, ev)
        us = common.timeit(lambda n=net, e=ev: n.run(key, e), iters=25, stat="min")
        fps = N_FRAMES / (us / 1e6)
        common.emit(
            f"bayesnet_{name}_indep_batch{N_FRAMES}",
            us,
            f"{fps:,.0f} frames/s | fresh entropy per frame | "
            f"shared/indep ratio {shared_fps[name] / fps:.2f}x",
        )

    # fused decide: sweep + in-kernel argmax epilogue, one launch.  Timed
    # interleaved with the posterior-only sweep so the overhead ratio
    # compares same-moment measurements (shared-tenant interference drifts
    # ~2x on minute timescales, which would otherwise swamp a few-percent
    # epilogue).
    for name in SCENARIO_NAMES:
        net, ev = indep_nets[name]
        us_sweep, us = common.timeit_pair(
            lambda n=net, e=ev: n.run(key, e),
            lambda n=net, e=ev: n.decide(key, e),
            iters=25, stat="min",
        )
        fps = N_FRAMES / (us / 1e6)
        common.emit(
            f"bayesnet_{name}_decide_batch{N_FRAMES}",
            us,
            f"{fps:,.0f} frames/s | posterior+decision one launch | "
            f"decide/sweep overhead {us / us_sweep:.2f}x",
            extra={"decide_overhead": round(us / us_sweep, 4)},
        )

    # sharded sweep: one shard_map launch over every visible device,
    # interleaved against the single-device program for the same reason
    n_dev = len(jax.devices())
    if n_dev < 2 or N_FRAMES % n_dev:
        # a non-dividing device count would silently fall back to the
        # single-device launch inside compile_network -- emitting that as a
        # "sharded" row would poison the perf trajectory with a mislabel
        print(
            f"# bayesnet sharded rows skipped: {n_dev} device(s), batch "
            f"{N_FRAMES} (need >=2 devices dividing the batch; set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=8)"
        )
        return
    for name in SCENARIO_NAMES:
        spec = by_name(name)
        single, ev = indep_nets[name]
        net = compile_network(spec, n_bits=N_BITS, devices=n_dev)
        us_single, us = common.timeit_pair(
            lambda n=single, e=ev: n.run(key, e),
            lambda n=net, e=ev: n.run(key, e),
            iters=25, stat="min",
        )
        fps = N_FRAMES / (us / 1e6)
        common.emit(
            f"bayesnet_{name}_indep_sharded_batch{N_FRAMES}",
            us,
            f"{fps:,.0f} frames/s | {n_dev} devices x {N_FRAMES // n_dev} "
            f"frames, bit-identical to single-device | "
            f"{us_single / us:.2f}x vs single-device same-moment",
            extra={"devices": n_dev,
                   "sharded_speedup": round(us_single / us, 4)},
        )


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
