"""Bayesnet compiler throughput: frames/sec vs network size and entropy mode.

Every scenario network is timed over a 1024-frame evidence batch in a single
jit launch, in BOTH entropy modes:

* shared entropy (``share_entropy=True``): node streams built once, every
  frame conditions the same joint sample -- the cheap-but-correlated mode.
* independent entropy (the production default): every frame draws its own
  joint sample through the fused ``net_sweep`` lowering.

The derived column of each ``_indep_`` row records the shared/indep throughput
ratio, so the cost of per-frame independence is tracked for every scenario in
every future ``BENCH_*.json`` (the committed trajectory once showed a ~70x
cliff here; the fused sweep holds it to low single digits, and CI's
bench-smoke gate fails if the pedestrian-night ratio regresses past 8x).
"""

from __future__ import annotations

import jax

from benchmarks import common

N_FRAMES = 1024
N_BITS = 4096
# binary trio + the categorical 4-class scenario (k-ary value bit-planes)
SCENARIO_NAMES = ("sensor-degradation", "pedestrian-night", "intersection",
                  "obstacle-class")


def run() -> None:
    from repro.bayesnet import by_name, compile_network, sample_evidence

    key = jax.random.PRNGKey(0)
    shared_fps = {}
    for name in SCENARIO_NAMES:
        spec = by_name(name)
        net = compile_network(spec, n_bits=N_BITS, share_entropy=True)
        ev = sample_evidence(spec, jax.random.PRNGKey(1), N_FRAMES)
        us = common.timeit(lambda n=net, e=ev: n.run(key, e), iters=25, stat="min")
        fps = N_FRAMES / (us / 1e6)
        shared_fps[name] = fps
        common.emit(
            f"bayesnet_{name}_batch{N_FRAMES}",
            us,
            f"{fps:,.0f} frames/s | {spec.n_nodes} nodes fan-in {spec.max_fan_in()} "
            f"n_bits {N_BITS}",
        )

    # independent entropy: every frame draws its own joint sample (fused sweep)
    for name in SCENARIO_NAMES:
        spec = by_name(name)
        net = compile_network(spec, n_bits=N_BITS, share_entropy=False)
        ev = sample_evidence(spec, jax.random.PRNGKey(1), N_FRAMES)
        us = common.timeit(lambda n=net, e=ev: n.run(key, e), iters=25, stat="min")
        fps = N_FRAMES / (us / 1e6)
        common.emit(
            f"bayesnet_{name}_indep_batch{N_FRAMES}",
            us,
            f"{fps:,.0f} frames/s | fresh entropy per frame | "
            f"shared/indep ratio {shared_fps[name] / fps:.2f}x",
        )


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
