"""Bayesnet compiler throughput: frames/sec vs network size.

Each scenario network is compiled once (shared-entropy packed program,
``estimator='ratio'``) and timed over a 1024-frame evidence batch in a single
jit launch; the derived column records frames/sec, node count and fan-in so
the BENCH_*.json trajectory tracks how scenario scale affects the hot path.
The independent-entropy mode is timed once as the costed upper bound (fresh
joint sample per frame).
"""

from __future__ import annotations

import jax

from benchmarks import common

N_FRAMES = 1024
N_BITS = 4096


def run() -> None:
    from repro.bayesnet import by_name, compile_network, sample_evidence

    key = jax.random.PRNGKey(0)
    for name in ("sensor-degradation", "pedestrian-night", "intersection"):
        spec = by_name(name)
        net = compile_network(spec, n_bits=N_BITS)
        ev = sample_evidence(spec, jax.random.PRNGKey(1), N_FRAMES)
        us = common.timeit(lambda n=net, e=ev: n.run(key, e))
        fps = N_FRAMES / (us / 1e6)
        common.emit(
            f"bayesnet_{name}_batch{N_FRAMES}",
            us,
            f"{fps:,.0f} frames/s | {spec.n_nodes} nodes fan-in {spec.max_fan_in()} "
            f"n_bits {N_BITS}",
        )

    # independent entropy: every frame draws its own joint sample
    spec = by_name("pedestrian-night")
    net = compile_network(spec, n_bits=N_BITS, share_entropy=False)
    ev = sample_evidence(spec, jax.random.PRNGKey(1), N_FRAMES)
    us = common.timeit(lambda: net.run(key, ev))
    common.emit(
        f"bayesnet_pedestrian-night_indep_batch{N_FRAMES}",
        us,
        f"{N_FRAMES / (us / 1e6):,.0f} frames/s | fresh entropy per frame",
    )


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
