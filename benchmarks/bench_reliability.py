"""Reliability sweep: decision flip-rate vs noise scale vs n_bits, and the
confidence-gated retry comparison that closes the loop.

"Timely reliable" is the paper's claim; these rows make it a *measured*
property of the compiled networks:

* ``reliability_<scenario>_flip_vs_sigma`` -- MAP-decision flip-rate against
  the clean (DAC-quantised) enumeration oracle as every crossbar non-ideality
  is scaled 0x / 0.5x / 1x / 2x of the paper-calibrated nominal
  (:class:`~repro.bayesnet.noise.NoiseModel`), at fixed ``n_bits``.  The 0x
  column isolates pure sampling flips; the growth over scale is the physics.
* ``reliability_<scenario>_flip_vs_nbits`` -- flip-rate under NOMINAL noise
  as the stream length grows 256 -> 1024 -> 4096: sampling flips average
  out, the noise-induced floor (frames whose perturbed decision boundary
  genuinely moved) stays.  The 4096-bit column is the gated "nominal
  flip-rate" of ``check_bench``.
* ``reliability_<scenario>_retry`` -- the punchline: a
  :class:`~repro.bayesnet.driver.FrameDriver` with a
  :class:`~repro.bayesnet.reliability.RetryPolicy` (confidence-gated,
  escalating n_bits) against a no-retry driver given AT LEAST the retry
  driver's *mean* per-frame bit budget as a flat stream length.  The
  reference here is the **perturbed**-oracle MAP -- the decision the noisy
  array itself would take with infinite bits -- because that is the
  component retry can actually fix: sampling flips.  (The clean-oracle gap
  that remains at 4096 bits in the ``flip_vs_nbits`` rows is the perturbed
  network's own decision-boundary shift; no amount of re-sampling, gated or
  flat, moves it -- obstacle-class demonstrates this by sitting at its
  ambiguity floor under both drivers, which is why it is measured in the
  sweep rows but not raced here.)  ``check_bench`` gates both the flip-rate
  reduction and the retry bit overhead on every retry row.

Flip-rates count every frame, including zero-acceptance ones (their
"decision" is the fallback posterior's argmax): a deployment does not get to
exclude the frames its sampler rejected, and the retry loop exists precisely
to rescue them.

Everything is seeded (evidence keys, launch keys, driver salts, the noise
model's device draws), so rows reproduce bit-for-bit on a fixed jax/CPU
stack; ``run(quick=True)`` is the CI subset (2 scenarios + 1 retry row).
"""

from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks import common

B_FRAMES = 512
B_FRAMES_QUICK = 256
SIGMA_SCALES = (0.0, 0.5, 1.0, 2.0)
SIGMA_N_BITS = 1024
NBITS_SWEEP = (256, 1024, 4096)
SCENARIO_NAMES = ("sensor-degradation", "pedestrian-night", "lane-change",
                  "intersection", "obstacle-detection", "obstacle-class",
                  "intersection-cat")
QUICK_NAMES = ("pedestrian-night", "obstacle-class")
# Retry race scenarios: the hardest ones whose flip floor has a material
# sampling component for the gate to act on (see module docstring for why
# obstacle-class, whose floor is pure decision-boundary shift, is excluded).
RETRY_NAMES = ("obstacle-detection", "lane-change", "intersection-cat")
RETRY_BASE_BITS = 256


def _flip_tag(x: float) -> str:
    return str(x).replace(".", "p")


def _ref_decisions(spec, ev):
    """Clean-oracle MAP decisions: the ideal Bayesian readout per frame."""
    from repro.bayesnet import make_posterior_fn
    from repro.bayesnet.compile import posterior_argmax

    exact, _ = make_posterior_fn(spec, dac_quantize=True)(ev)
    return np.asarray(posterior_argmax(exact))


def run(quick: bool = False) -> None:
    from repro.bayesnet import (
        FrameDriver, NoiseModel, RetryPolicy, by_name, compile_network,
        flip_rate, sample_evidence,
    )
    from repro.bayesnet.compile import posterior_argmax

    names = QUICK_NAMES if quick else SCENARIO_NAMES
    n_frames = B_FRAMES_QUICK if quick else B_FRAMES
    key = jax.random.PRNGKey(0)
    nominal = NoiseModel()

    for name in names:
        spec = by_name(name)
        ev = sample_evidence(spec, jax.random.PRNGKey(1), n_frames)
        ref = _ref_decisions(spec, ev)

        # --- flip-rate vs noise scale (fixed n_bits) -----------------------
        flips, nets = {}, {}
        for s in SIGMA_SCALES:
            noise = None if s == 0.0 else nominal.scaled(s)
            net = compile_network(spec, n_bits=SIGMA_N_BITS, noise=noise)
            nets[s] = net
            _, dec, _ = net.decide(key, ev)
            flips[s] = flip_rate(np.asarray(dec), ref)
        us = common.timeit(
            lambda n=nets[1.0], e=ev: n.decide(key, e), iters=5, stat="min"
        )
        common.emit(
            f"reliability_{name}_flip_vs_sigma",
            us,
            f"flip vs clean oracle @ {SIGMA_N_BITS} bits | "
            + " ".join(f"{s}x:{flips[s]:.3f}" for s in SIGMA_SCALES),
            extra={f"flip_sigma_{_flip_tag(s)}": round(flips[s], 4)
                   for s in SIGMA_SCALES},
        )

        # --- flip-rate vs n_bits (nominal noise) ---------------------------
        nflips = {}
        for nb in NBITS_SWEEP:
            net = nets[1.0] if nb == SIGMA_N_BITS else compile_network(
                spec, n_bits=nb, noise=nominal
            )
            _, dec, _ = net.decide(key, ev)
            nflips[nb] = flip_rate(np.asarray(dec), ref)
            if nb == max(NBITS_SWEEP):
                us = common.timeit(
                    lambda n=net, e=ev: n.decide(key, e), iters=5, stat="min"
                )
        common.emit(
            f"reliability_{name}_flip_vs_nbits",
            us,
            f"flip vs clean oracle @ nominal noise | "
            + " ".join(f"{nb}b:{nflips[nb]:.3f}" for nb in NBITS_SWEEP),
            extra={f"flip_{nb}": round(nflips[nb], 4) for nb in NBITS_SWEEP},
        )

    # --- confidence-gated retry vs flat budget on the hardest scenarios ----
    from repro.bayesnet import make_posterior_fn

    retry_names = RETRY_NAMES[:1] if quick else RETRY_NAMES
    for name in retry_names:
        spec = by_name(name)
        ev = np.asarray(sample_evidence(spec, jax.random.PRNGKey(1), n_frames))
        # perturbed-oracle MAP: the noisy array's own converged decision --
        # the sampling-flip reference retry is built to chase (docstring)
        exact, _ = make_posterior_fn(spec, noise=nominal)(ev)
        ref = np.asarray(posterior_argmax(exact))
        base = compile_network(spec, n_bits=RETRY_BASE_BITS, noise=nominal)
        pol = RetryPolicy(min_confidence=0.9, max_retries=2, escalation=4,
                          max_n_bits=1 << 14)

        def _drain(net, retry):
            d = FrameDriver(net, max_batch=n_frames, salt=0, retry=retry)
            d.submit(ev)
            t0 = time.perf_counter()
            out = d.drain()
            dt = (time.perf_counter() - t0) * 1e6
            post = np.stack([out[r][0] for r in sorted(out)])
            acc = np.asarray([out[r][1] for r in sorted(out)])
            return np.asarray(posterior_argmax(post)), acc, d.stats, dt

        dec_r, _, stats, us_retry = _drain(base, pol)
        flip_retry = flip_rate(dec_r, ref)
        # the no-retry twin gets AT LEAST the retry driver's mean per-frame
        # bit budget as a flat stream length (rounded UP to the word grid),
        # so a win here is not a bit-budget artefact
        eq_bits = int(-(-stats.mean_bits // 32) * 32)
        flat = compile_network(spec, n_bits=eq_bits, noise=nominal)
        dec_f, _, _, _ = _drain(flat, None)
        flip_flat = flip_rate(dec_f, ref)
        common.emit(
            f"reliability_{name}_retry",
            us_retry,
            f"retry {flip_retry:.3f} vs flat {flip_flat:.3f} flips @ equal "
            f"mean bits ({stats.mean_bits:.0f} vs {eq_bits}) | "
            f"retry_rate {stats.retry_rate:.2f} unreliable {stats.unreliable} "
            f"base {RETRY_BASE_BITS}b esc {pol.escalation}x",
            extra={
                "flip_retry": round(flip_retry, 4),
                "flip_noretry": round(flip_flat, 4),
                "mean_bits": round(stats.mean_bits, 1),
                "noretry_bits": eq_bits,
                "retry_overhead": round(stats.mean_bits / RETRY_BASE_BITS, 4),
                "retry_rate": round(stats.retry_rate, 4),
                "unreliable": stats.unreliable,
            },
        )


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
