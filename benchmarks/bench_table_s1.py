"""Table S1: all probabilistic logic x correlation cells, empirical vs analytic."""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit
from repro.core import logic
from repro.core.logic import Corr


def run():
    key = jax.random.PRNGKey(0)
    n = 1 << 14
    pa, pb = 0.7, 0.4
    ops = {
        "AND": (logic.prob_and, logic.expected_and),
        "OR": (logic.prob_or, logic.expected_or),
        "XOR": (logic.prob_xor, logic.expected_xor),
    }
    for opname, (op, expected) in ops.items():
        for mode in (Corr.UNCORRELATED, Corr.POSITIVE, Corr.NEGATIVE):
            _, est, _ = op(jax.random.fold_in(key, hash((opname, mode.value)) % 2**31),
                           pa, pb, n, mode)
            exp = float(expected(pa, pb, mode))
            emit(f"tableS1.{opname}[{mode.value}]", 0.0,
                 f"expect={exp:.3f} measured={float(est):.3f} "
                 f"err={abs(float(est)-exp):.3f}")
    # MUX (select uncorrelated with inputs -- the only valid configuration)
    _, est, _ = logic.prob_mux(key, 0.3, pa, pb, n)
    exp = float(logic.expected_mux(0.3, pa, pb))
    emit("tableS1.MUX[uncorr-select]", 0.0,
         f"expect={exp:.3f} measured={float(est):.3f}")


if __name__ == "__main__":
    run()
