"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit) and
writes a machine-readable ``BENCH_<timestamp>.json`` snapshot of the same rows
so the perf trajectory accumulates one artifact per run.
"""

from __future__ import annotations

import os
import sys


def main() -> None:
    from benchmarks import (
        bench_bayesnet,
        bench_drift,
        bench_fig1_device,
        bench_fig2_logic,
        bench_fig3_inference,
        bench_fig4_fusion,
        bench_latency,
        bench_reliability,
        bench_roofline,
        bench_serve,
        bench_table_s1,
        common,
    )

    out_dir = sys.argv[1] if len(sys.argv) > 1 else "."
    print("name,us_per_call,derived")
    for mod in (
        bench_fig1_device,
        bench_fig2_logic,
        bench_table_s1,
        bench_fig3_inference,
        bench_fig4_fusion,
        bench_bayesnet,
        bench_reliability,
        bench_serve,
        bench_drift,
        bench_latency,
        bench_roofline,
    ):
        print(f"# --- {mod.__name__} ---")
        mod.run()
    report = bench_drift.write_drift_report(
        os.path.join(out_dir, "drift_report.csv")
    )
    print(f"# wrote {report}")
    path = common.write_json(out_dir)
    print(f"# wrote {path}")


if __name__ == "__main__":
    main()
