"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).
"""

from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (
        bench_fig1_device,
        bench_fig2_logic,
        bench_fig3_inference,
        bench_fig4_fusion,
        bench_latency,
        bench_roofline,
        bench_table_s1,
    )

    print("name,us_per_call,derived")
    for mod in (
        bench_fig1_device,
        bench_fig2_logic,
        bench_table_s1,
        bench_fig3_inference,
        bench_fig4_fusion,
        bench_latency,
        bench_roofline,
    ):
        print(f"# --- {mod.__name__} ---")
        mod.run()


if __name__ == "__main__":
    main()
