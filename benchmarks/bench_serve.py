"""Multi-tenant serving race: mixed 7-scenario workload, nominal vs. chaos.

Two rows make the fleet-level serving tier a measured artifact:

* ``serve_mixed_nominal`` -- all 7 scenario tenants interleaved through one
  :class:`~repro.serve.router.BayesRouter` (submit in round-robin chunks,
  one ``drain``), no fault injection.  ``us_per_call`` is wall time per
  *frame* (min over rounds, the shared-tenant noise-robust estimator), so
  the derived decisions/s is the sustained mixed-workload throughput the
  trajectory gate tracks.
* ``serve_mixed_chaos5`` -- the same workload under a seeded
  :class:`~repro.distributed.fault.LaunchFaultInjector` at 5% total launch
  faults (2% dropped, 1% stalled, 2% corrupted harvests).  The row's
  structured fields carry the terminal-status census: ``lost_frames`` MUST
  be 0 (``check_bench.check_serve`` gates it -- the never-drop invariant at
  fleet scale) and ``deadline_hit_rate`` must hold its floor.

Both rows run against the same router construction (scenario-keyed plan
cache, CRC-of-name tenant salts), so the nominal row doubles as the router's
throughput baseline and the chaos row isolates the price of the failure
responses (re-dispatch, backoff, breaker) rather than of a different setup.
:func:`write_degradation_report` snapshots the per-tenant census to CSV --
the CI chaos-smoke artifact.
"""

from __future__ import annotations

import csv
import time

import jax
import numpy as np

from benchmarks import common

SCENARIO_NAMES = ("sensor-degradation", "pedestrian-night", "lane-change",
                  "intersection", "obstacle-detection", "obstacle-class",
                  "intersection-cat")
FRAMES_PER_TENANT = 24
FRAMES_PER_TENANT_QUICK = 8
ROUNDS = 3
ROUNDS_QUICK = 2
CHUNK = 4          # round-robin submission granularity (tenant interleave)
N_BITS = 1024
MAX_BATCH = 32
# Chaos launches cap at 8 lanes so the same workload takes ~4x the launches:
# at 5% per-launch fault rates the schedule actually draws faults in a bench-
# sized run instead of sailing through on a lucky handful of big launches.
MAX_BATCH_CHAOS = 8
# 5% total injected launch faults, the CI chaos rate.  Verdicts are a pure
# function of (seed, tenant salt, ticket), so the schedule replays exactly;
# seed 7 was chosen because it draws several faults of every kind inside a
# bench-sized ticket range (a seed that happens to draw nothing would make
# the chaos row a nominal row with a scarier name).
CHAOS = dict(seed=7, p_drop=0.02, p_stall=0.01, p_corrupt=0.02, stall_ms=2.0)


def _policy():
    from repro.serve import RouterPolicy

    # fast failure-response constants so a chaos drain converges in bench
    # time; admission/degradation semantics are the defaults
    return RouterPolicy(
        backoff_base_s=1e-4, backoff_cap_s=5e-3, breaker_cooldown_s=0.02
    )


def _workload(n_frames: int):
    """Per-tenant evidence batches, seeded per scenario."""
    from repro.bayesnet import by_name, sample_evidence

    return {
        name: np.asarray(
            sample_evidence(by_name(name), jax.random.PRNGKey(i + 1), n_frames)
        )
        for i, name in enumerate(SCENARIO_NAMES)
    }


def _run_round(router, workload, deadline_ms=None):
    """Submit the whole mixed workload interleaved, drain, census the round."""
    rids = []
    t0 = time.perf_counter()
    n_frames = len(next(iter(workload.values())))
    for lo in range(0, n_frames, CHUNK):
        for name, ev in workload.items():
            rids += router.submit(name, ev[lo:lo + CHUNK], deadline_ms=deadline_ms)
    router.drain()
    dt_us = (time.perf_counter() - t0) * 1e6
    census = {"OK": 0, "DEGRADED": 0, "UNRELIABLE": 0, "REJECTED": 0}
    lost = hits = 0
    for rid in rids:
        res = router.results.get(rid)
        if res is None:
            lost += 1
            continue
        census[res.status] += 1
        hits += int(res.deadline_met)
    return len(rids), census, lost, hits, dt_us


def _race(router, workload, rounds: int):
    """Warmup (compile) + timed rounds; returns aggregates over timed rounds.

    The warmup round runs with a 10-minute deadline: its job is to compile
    every tenant's plan, and on a 1-vCPU container 7 lazy compiles take tens
    of seconds -- against the default 1 s deadline the later tenants would be
    shed before ever building a plan, and the timed rounds would then pay
    the compiles the warmup exists to absorb.
    """
    _run_round(router, workload, deadline_ms=600_000)   # warmup: plans compile
    totals = {"OK": 0, "DEGRADED": 0, "UNRELIABLE": 0, "REJECTED": 0}
    n = lost = hits = 0
    per_frame_us = []
    for _ in range(rounds):
        rn, census, rl, rh, dt_us = _run_round(router, workload)
        n += rn
        lost += rl
        hits += rh
        for k, v in census.items():
            totals[k] += v
        per_frame_us.append(dt_us / rn)
    return n, totals, lost, hits, common.Timing(min(per_frame_us), per_frame_us)


def write_degradation_report(path: str, router) -> str:
    """Per-tenant terminal-status census CSV (the CI chaos-smoke artifact)."""
    from repro.obs.histogram import percentile

    by_tenant: dict = {}
    for res in router.results.values():
        row = by_tenant.setdefault(
            res.tenant,
            {"OK": 0, "DEGRADED": 0, "UNRELIABLE": 0, "REJECTED": 0,
             "deadline_hits": 0, "latencies": []},
        )
        row[res.status] += 1
        row["deadline_hits"] += int(res.deadline_met)
        row["latencies"].append(res.latency_ms)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["tenant", "frames", "ok", "degraded", "unreliable",
                    "rejected", "deadline_hit_rate", "p50_ms", "p99_ms"])
        for name in sorted(by_tenant):
            r = by_tenant[name]
            frames = sum(r[s] for s in ("OK", "DEGRADED", "UNRELIABLE",
                                        "REJECTED"))
            w.writerow([
                name, frames, r["OK"], r["DEGRADED"], r["UNRELIABLE"],
                r["REJECTED"], round(r["deadline_hits"] / max(frames, 1), 4),
                round(percentile(r["latencies"], 50.0), 3),
                round(percentile(r["latencies"], 99.0), 3),
            ])
    return path


def run(quick: bool = False, report_path: str | None = None) -> None:
    from repro.distributed.fault import LaunchFaultInjector
    from repro.serve import BayesRouter

    n_frames = FRAMES_PER_TENANT_QUICK if quick else FRAMES_PER_TENANT
    rounds = ROUNDS_QUICK if quick else ROUNDS
    workload = _workload(n_frames)
    base_key = jax.random.PRNGKey(42)

    # --- nominal: throughput baseline (rides the 30% trajectory gate) ------
    router = BayesRouter(
        _policy(), base_key, n_bits=N_BITS, max_batch=MAX_BATCH,
        max_cached_tenants=len(SCENARIO_NAMES),
    )
    n, census, lost, hits, us = _race(router, workload, rounds)
    common.emit(
        "serve_mixed_nominal",
        us,
        f"{len(SCENARIO_NAMES)} tenants x {n_frames} frames x {rounds} rounds "
        f"-> {1e6 / us:,.0f} decisions/s | "
        + " ".join(f"{k}:{v}" for k, v in census.items())
        + f" lost:{lost}",
        extra={
            "lost_frames": lost, "ok": census["OK"],
            "degraded": census["DEGRADED"],
            "unreliable": census["UNRELIABLE"],
            "rejected": census["REJECTED"],
            "deadline_hit_rate": round(hits / max(n, 1), 4),
            "tenants": len(SCENARIO_NAMES),
        },
    )

    # --- chaos: 5% seeded launch faults, never-drop invariant gated --------
    chaos_router = BayesRouter(
        _policy(), base_key, n_bits=N_BITS, max_batch=MAX_BATCH_CHAOS,
        fault=LaunchFaultInjector(**CHAOS),
        max_cached_tenants=len(SCENARIO_NAMES),
    )
    n, census, lost, hits, us = _race(chaos_router, workload, rounds)
    inj = chaos_router.fault.injected
    common.emit(
        "serve_mixed_chaos5",
        us,
        f"5% launch faults (drop:{inj['drop']} stall:{inj['stall']} "
        f"corrupt:{inj['corrupt']}) -> {1e6 / us:,.0f} decisions/s | "
        + " ".join(f"{k}:{v}" for k, v in census.items())
        + f" lost:{lost}",
        extra={
            "lost_frames": lost, "ok": census["OK"],
            "degraded": census["DEGRADED"],
            "unreliable": census["UNRELIABLE"],
            "rejected": census["REJECTED"],
            "deadline_hit_rate": round(hits / max(n, 1), 4),
            "faults_injected": sum(inj.values()),
        },
    )
    if report_path is not None:
        print(f"# wrote {write_degradation_report(report_path, chaos_router)}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
