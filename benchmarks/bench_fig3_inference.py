"""Fig 3: Bayesian inference operator -- route planning + correlation matrices."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core import correlation, graph, inference


def run():
    key = jax.random.PRNGKey(0)
    n100 = 100

    # Fig 3b route-planning case: P(A)=57%, evidence -> posterior in 61-63% band
    ests = [
        float(inference.bayes_inference(jax.random.fold_in(key, i),
                                        0.57, 0.72, 0.6, n_bits=n100).posterior_ratio)
        for i in range(100)
    ]
    theory = float(inference.analytic_posterior(0.57, 0.72, 0.6))
    emit("fig3b.route_planning@100bit", 0.0,
         f"theory={theory*100:.0f}%(paper ~61%) hw_mean={np.mean(ests)*100:.0f}% "
         f"hw_std={np.std(ests)*100:.1f}% decision=cut-in(P(A|B)>P(A))")

    # accuracy across a prior/likelihood grid at the paper's bit length
    grid_err = []
    for pa in (0.2, 0.4, 0.6, 0.8):
        for pba in (0.3, 0.6, 0.9):
            tr = [
                float(inference.bayes_inference(
                    jax.random.fold_in(key, hash((pa, pba, i)) % 2**31),
                    pa, pba, 0.5, n_bits=n100).posterior_ratio)
                for i in range(20)
            ]
            grid_err.append(abs(np.mean(tr) - float(
                inference.analytic_posterior(pa, pba, 0.5))))
    emit("fig3.grid_accuracy@100bit", 0.0,
         f"mean_abs_err={np.mean(grid_err):.3f} max={np.max(grid_err):.3f}")

    # Fig 3c/3d: pairwise correlations at the operator's key nodes
    tr = inference.bayes_inference(key, 0.57, 0.72, 0.6, n_bits=1 << 14)
    names = list(tr.streams)
    rho = correlation.correlation_matrix(tr.streams, tr.n_bits, "pearson")
    scc = correlation.correlation_matrix(tr.streams, tr.n_bits, "scc")
    iA, iN, iD = names.index("A"), names.index("numer"), names.index("denom")
    emit("fig3c.pearson", 0.0,
         f"rho(A,B|A)={float(rho[iA, names.index('B|A')]):.2f}(design 0) "
         f"rho(numer,denom)={float(rho[iN, iD]):.2f}(design >0)")
    emit("fig3d.scc", 0.0,
         f"scc(numer,denom)={float(scc[iN, iD]):.2f}(design ~1: CORDIV subset)")

    # Fig S8 graphs
    cpt = jnp.array([[0.1, 0.4], [0.6, 0.9]])
    _, pr, an = graph.two_parent_one_child(key, 0.6, 0.3, cpt, n_bits=1 << 13)
    emit("figS8b.two_parent", 0.0, f"est={float(pr):.3f} theory={float(an):.3f}")
    _, pr2, an2 = graph.one_parent_two_child(key, 0.5, (0.9, 0.2), (0.8, 0.3),
                                             n_bits=1 << 13)
    emit("figS8c.one_parent_two_child", 0.0,
         f"est={float(pr2):.3f} theory={float(an2):.3f}")

    # throughput of the jitted operator (batched: 4096 inferences at once)
    pa_v = jnp.full((4096,), 0.57)
    fn = jax.jit(lambda k: inference.bayes_inference(k, pa_v, 0.72, 0.6,
                                                     n_bits=128).posterior_ratio)
    us = timeit(fn, key)
    emit("fig3.batched_operator_4096@128bit", us,
         f"{4096 / (us / 1e6):.0f} inferences/s on 1 CPU core")


if __name__ == "__main__":
    run()
