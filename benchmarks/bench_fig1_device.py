"""Fig 1 / S4: memristor device statistics -- V_th/V_hold fits, OU stability,
endurance."""

from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, timeit
from repro.core import device


def run():
    params = device.DEFAULT_PARAMS
    key = jax.random.PRNGKey(0)

    # cycle-to-cycle stochasticity (paper: V_th 2.08 +/- 0.28 V, V_hold 0.98 +/- 0.30 V)
    path = np.asarray(device.sample_ou_path(key, 20000, params))
    us = timeit(lambda: device.sample_ou_path(key, 20000, params))
    emit("fig1.vth_cycle_stats", us,
         f"mean={path.mean():.3f}V(paper 2.08) std={path.std():.3f}V(paper 0.28)")

    # device-to-device CV (paper ~8%)
    mus = np.asarray(device.sample_devices(jax.random.PRNGKey(1), 1000))
    emit("fig1.d2d_cv", 0.0, f"cv={mus.std()/mus.mean()*100:.1f}%(paper ~8%)")

    # OU fit (Fig S4): recovered parameters
    theta, mu, sigw = device.fit_ou(path)
    emit("figS4.ou_fit", 0.0,
         f"theta={theta:.3f}(cfg {params.ou_theta}) mu={mu:.3f} sigma_w={sigw:.3f}")

    # endurance (Fig 1e): HRS/LRS separation over cycles
    hrs, lrs = device.endurance_trace(jax.random.PRNGKey(2), 100000)
    ratio = float(np.min(np.asarray(hrs)) / np.max(np.asarray(lrs)))
    emit("fig1e.endurance_1e5cycles", 0.0,
         f"min_HRS/max_LRS={ratio:.0f}(paper ~1e5 ratio; stable)")


if __name__ == "__main__":
    run()
