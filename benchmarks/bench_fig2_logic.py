"""Fig 2: SNE transfer curves + probabilistic AND/MUX hardware-test analogue."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core import bitops, logic, sne
from repro.core.logic import Corr


def run():
    key = jax.random.PRNGKey(0)

    # Fig 2b / 2c transfer curves: encoder hits the sigmoid-programmed P
    n = 1 << 14
    for v_in in (1.8, 2.24, 2.8):
        p_t = float(sne.p_from_vin(v_in))
        est = float(bitops.decode(
            sne.encode_uncorrelated(jax.random.fold_in(key, int(v_in * 100)),
                                    p_t, n), n))
        emit(f"fig2b.P_unc(Vin={v_in}V)", 0.0,
             f"target={p_t:.3f} measured={est:.3f}")
    for v_ref in (0.4, 0.57, 0.75):
        p_t = float(sne.p_from_vref(v_ref))
        est = float(bitops.decode(
            sne.encode_uncorrelated(jax.random.fold_in(key, int(v_ref * 1e3)),
                                    p_t, n), n))
        emit(f"fig2c.P_corr(Vref={v_ref}V)", 0.0,
             f"target={p_t:.3f} measured={est:.3f}")

    # Fig 2e: probabilistic AND / MUX at 100-bit (the paper's demo length)
    pa, pb, ps = 0.8, 0.6, 0.5
    for mode in (Corr.UNCORRELATED, Corr.POSITIVE, Corr.NEGATIVE):
        ests = [
            float(logic.prob_and(jax.random.fold_in(key, i), pa, pb, 100, mode)[1])
            for i in range(50)
        ]
        expect = float(logic.expected_and(pa, pb, mode))
        emit(f"fig2e.AND[{mode.value}]@100bit", 0.0,
             f"expect={expect:.3f} mean={np.mean(ests):.3f} std={np.std(ests):.3f}")
    us = timeit(
        jax.jit(lambda k: logic.prob_mux(k, ps, pa, pb, 100)[1]), key
    )
    ests = [float(logic.prob_mux(jax.random.fold_in(key, i), ps, pa, pb, 100)[1])
            for i in range(50)]
    emit("fig2e.MUX@100bit", us,
         f"expect={float(logic.expected_mux(ps,pa,pb)):.3f} mean={np.mean(ests):.3f}")

    # precision vs bit length (the paper's cost/precision trade-off note)
    for nbits in (100, 1000, 10000):
        errs = [
            abs(float(logic.prob_and(jax.random.fold_in(key, 100 + i), pa, pb,
                                     nbits, Corr.UNCORRELATED)[1]) - pa * pb)
            for i in range(20)
        ]
        emit(f"fig2.precision@{nbits}bit", 0.0, f"mean_abs_err={np.mean(errs):.4f}")


if __name__ == "__main__":
    run()
