"""Roofline report: aggregates experiments/dryrun/*.json into the EXPERIMENTS
tables (also prints a compact summary as a benchmark row)."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_cells(variant: str = "baseline"):
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        d = json.load(open(path))
        if d.get("variant", "baseline") != variant and d.get("ok"):
            continue
        d["_file"] = os.path.basename(path)
        cells.append(d)
    return cells


def run():
    cells = load_cells()
    ok = [c for c in cells if c.get("ok")]
    skipped = [c for c in cells if c.get("skipped")]
    failed = [c for c in cells if not c.get("ok") and not c.get("skipped")]
    emit("roofline.cells", 0.0,
         f"ok={len(ok)} skipped={len(skipped)} failed={len(failed)}")
    for c in ok:
        if c.get("mesh") != "pod16x16":
            continue
        emit(
            f"roofline.{c['arch']}.{c['shape']}", 0.0,
            f"compute={c['compute_s']:.2f}s memory={c['memory_s']:.2f}s "
            f"collective={c['collective_s']:.2f}s bottleneck={c['bottleneck']} "
            f"useful={c['useful_ratio']:.2f}",
        )
    for c in failed:
        emit(f"roofline.FAILED.{c.get('arch')}.{c.get('shape')}", 0.0,
             str(c.get("error", ""))[:80])


if __name__ == "__main__":
    run()
