"""Shared benchmark helpers: timing + CSV emission (name,us_per_call,derived)
plus machine-readable JSON snapshots (BENCH_<timestamp>.json) for the perf
trajectory."""

from __future__ import annotations

import json
import os
import time
from typing import Callable

import jax

ROWS = []


def timeit(
    fn: Callable, *args, warmup: int = 1, iters: int = 5, stat: str = "median"
) -> float:
    """Wall-time per call in microseconds (jax arrays blocked).

    ``stat='median'`` is the default; ``stat='min'`` reports the fastest
    observed call -- the standard noise-robust estimator when the benchmark
    shares its cores with other tenants (an interfered call can run 10-20x
    slow, which poisons a small-sample median but never the min).  The
    regression-gated bayesnet rows AND the seed-speedup latency rows
    (``bench_latency``) use it so CI compares machine capability, not
    scheduler luck.
    """
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return (times[0] if stat == "min" else times[len(times) // 2]) * 1e6


def timeit_pair(
    fn_a: Callable, fn_b: Callable, warmup: int = 1, iters: int = 5,
    stat: str = "median",
) -> tuple:
    """Time two callables with interleaved iterations; returns (us_a, us_b).

    Ratio rows (decide vs sweep, sharded vs single-device) divide the two
    numbers, and on a shared-tenant box the interference level can drift 2x
    within a minute -- timing the pair back-to-back per iteration means both
    sides see the same interference and the *ratio* stays honest even when
    the absolute numbers wobble.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn_a())
        jax.block_until_ready(fn_b())
    ta, tb = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a())
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b())
        tb.append(time.perf_counter() - t0)
    ta.sort()
    tb.sort()
    pick = (lambda t: t[0]) if stat == "min" else (lambda t: t[len(t) // 2])
    return pick(ta) * 1e6, pick(tb) * 1e6


def emit(name: str, us_per_call: float, derived: str, extra: dict | None = None):
    """Record one bench row.  ``extra`` merges additional *numeric* fields
    into the row's JSON record (e.g. ``decide_overhead``) so gates can read
    them structurally instead of parsing the human-readable derived string."""
    ROWS.append((name, us_per_call, derived, extra or {}))
    print(f"{name},{us_per_call:.2f},{derived}")


def write_json(out_dir: str = ".") -> str:
    """Snapshot all emitted rows to BENCH_<timestamp>.json; returns the path.

    Schema: {name: {"us_per_call": float, "derived": str}} plus a "_meta"
    record (timestamp, jax backend/version) so runs are comparable across the
    perf trajectory.
    """
    os.makedirs(out_dir, exist_ok=True)
    stamp = time.strftime("%Y%m%d_%H%M%S")
    path = os.path.join(out_dir, f"BENCH_{stamp}.json")
    payload = {
        "_meta": {
            "timestamp": stamp,
            "backend": jax.default_backend(),
            "jax_version": jax.__version__,
            "device_count": jax.device_count(),
            "cpu_count": os.cpu_count(),
        }
    }
    for name, us, derived, extra in ROWS:
        payload[name] = {"us_per_call": us, "derived": derived, **extra}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    return path
