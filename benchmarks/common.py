"""Shared benchmark helpers: timing + CSV emission (name,us_per_call,derived)
plus machine-readable JSON snapshots (BENCH_<timestamp>.json) for the perf
trajectory."""

from __future__ import annotations

import json
import os
import time
from typing import Callable

import jax

ROWS = []


def timeit(
    fn: Callable, *args, warmup: int = 1, iters: int = 5, stat: str = "median"
) -> float:
    """Wall-time per call in microseconds (jax arrays blocked).

    ``stat='median'`` is the default; ``stat='min'`` reports the fastest
    observed call -- the standard noise-robust estimator when the benchmark
    shares its cores with other tenants (an interfered call can run 10-20x
    slow, which poisons a small-sample median but never the min).  The
    regression-gated bayesnet rows use it so CI compares machine capability,
    not scheduler luck.
    """
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return (times[0] if stat == "min" else times[len(times) // 2]) * 1e6


def emit(name: str, us_per_call: float, derived: str):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.2f},{derived}")


def write_json(out_dir: str = ".") -> str:
    """Snapshot all emitted rows to BENCH_<timestamp>.json; returns the path.

    Schema: {name: {"us_per_call": float, "derived": str}} plus a "_meta"
    record (timestamp, jax backend/version) so runs are comparable across the
    perf trajectory.
    """
    os.makedirs(out_dir, exist_ok=True)
    stamp = time.strftime("%Y%m%d_%H%M%S")
    path = os.path.join(out_dir, f"BENCH_{stamp}.json")
    payload = {
        "_meta": {
            "timestamp": stamp,
            "backend": jax.default_backend(),
            "jax_version": jax.__version__,
        }
    }
    for name, us, derived in ROWS:
        payload[name] = {"us_per_call": us, "derived": derived}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    return path
