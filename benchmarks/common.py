"""Shared benchmark helpers: timing + CSV emission (name,us_per_call,derived)."""

from __future__ import annotations

import time
from typing import Callable

import jax

ROWS = []


def timeit(fn: Callable, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall-time per call in microseconds (jax arrays blocked)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str):
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.2f},{derived}")
