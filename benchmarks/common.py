"""Shared benchmark helpers: timing + CSV emission (name,us_per_call,derived)
plus machine-readable JSON snapshots (BENCH_<timestamp>.json) for the perf
trajectory.

``timeit`` / ``timeit_pair`` return :class:`Timing` -- a ``float`` subclass
carrying the raw per-iteration samples -- so every timed BENCH row records
``p50_us`` / ``p99_us`` next to the gate statistic.  The min/median the gates
compare is bit-for-bit the float it always was (``Timing`` IS that float);
the percentiles ride along for the latency-budget gate and for humans who
want to see the tail, not just the floor.
"""

from __future__ import annotations

import json
import os
import time
from typing import Callable

import jax

from repro.obs.histogram import percentile

ROWS = []


class Timing(float):
    """Per-call microseconds that remember their per-iteration samples.

    Arithmetic, comparison, and formatting behave exactly like the bare
    float (the chosen ``stat``), so existing gate code and derived-string
    ratios are untouched; ``samples_us`` / ``p50`` / ``p99`` expose the
    retained distribution.
    """

    def __new__(cls, value_us: float, samples_us=()):
        t = super().__new__(cls, value_us)
        t.samples_us = tuple(samples_us)
        return t

    def pct(self, q: float) -> float:
        return percentile(self.samples_us, q)

    @property
    def p50(self) -> float:
        return self.pct(50.0)

    @property
    def p90(self) -> float:
        return self.pct(90.0)

    @property
    def p99(self) -> float:
        return self.pct(99.0)


def _pick(times_sorted, stat: str) -> float:
    return times_sorted[0] if stat == "min" else times_sorted[len(times_sorted) // 2]


def timeit(
    fn: Callable, *args, warmup: int = 1, iters: int = 5, stat: str = "median"
) -> Timing:
    """Wall-time per call in microseconds (jax arrays blocked).

    ``stat='median'`` is the default; ``stat='min'`` reports the fastest
    observed call -- the standard noise-robust estimator when the benchmark
    shares its cores with other tenants (an interfered call can run 10-20x
    slow, which poisons a small-sample median but never the min).  The
    regression-gated bayesnet rows AND the seed-speedup latency rows
    (``bench_latency``) use it so CI compares machine capability, not
    scheduler luck.  The returned :class:`Timing` additionally carries every
    per-iteration sample, so rows emitted from it get ``p50_us``/``p99_us``
    fields for free.
    """
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return Timing(_pick(times, stat) * 1e6, [t * 1e6 for t in times])


def timeit_pair(
    fn_a: Callable, fn_b: Callable, warmup: int = 1, iters: int = 5,
    stat: str = "median",
) -> tuple:
    """Time two callables with interleaved iterations; returns (us_a, us_b).

    Ratio rows (decide vs sweep, sharded vs single-device) divide the two
    numbers, and on a shared-tenant box the interference level can drift 2x
    within a minute -- timing the pair back-to-back per iteration means both
    sides see the same interference and the *ratio* stays honest even when
    the absolute numbers wobble.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn_a())
        jax.block_until_ready(fn_b())
    ta, tb = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a())
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b())
        tb.append(time.perf_counter() - t0)
    ta.sort()
    tb.sort()
    return (
        Timing(_pick(ta, stat) * 1e6, [t * 1e6 for t in ta]),
        Timing(_pick(tb, stat) * 1e6, [t * 1e6 for t in tb]),
    )


def emit(name: str, us_per_call: float, derived: str, extra: dict | None = None):
    """Record one bench row.  ``extra`` merges additional *numeric* fields
    into the row's JSON record (e.g. ``decide_overhead``) so gates can read
    them structurally instead of parsing the human-readable derived string.
    A :class:`Timing` value contributes ``p50_us``/``p99_us``/``n_samples``
    automatically (explicit ``extra`` keys win)."""
    extra = dict(extra or {})
    if isinstance(us_per_call, Timing) and us_per_call.samples_us:
        extra.setdefault("p50_us", round(us_per_call.p50, 3))
        extra.setdefault("p99_us", round(us_per_call.p99, 3))
        extra.setdefault("n_samples", len(us_per_call.samples_us))
    ROWS.append((name, float(us_per_call), derived, extra))
    print(f"{name},{us_per_call:.2f},{derived}")


def write_json(out_dir: str = ".") -> str:
    """Snapshot all emitted rows to BENCH_<timestamp>.json; returns the path.

    Schema: {name: {"us_per_call": float, "derived": str}} plus a "_meta"
    record (timestamp, jax backend/version) so runs are comparable across the
    perf trajectory.  Timed rows additionally carry "p50_us"/"p99_us".
    """
    os.makedirs(out_dir, exist_ok=True)
    stamp = time.strftime("%Y%m%d_%H%M%S")
    path = os.path.join(out_dir, f"BENCH_{stamp}.json")
    payload = {
        "_meta": {
            "timestamp": stamp,
            "backend": jax.default_backend(),
            "jax_version": jax.__version__,
            "device_count": jax.device_count(),
            "cpu_count": os.cpu_count(),
        }
    }
    for name, us, derived, extra in ROWS:
        payload[name] = {"us_per_call": us, "derived": derived, **extra}
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
    return path
