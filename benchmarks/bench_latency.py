"""Timeliness claim: <0.4 ms/frame (>=2,500 fps) at 100-bit encoding, and the
TPU-mapped throughput of the packed kernels.

The decision pipeline is timed three ways over the same workload
(4096 binary decisions, 2 modalities, 128-bit streams):

* ``seed``    -- the seed composition: three separate launches
  (sne_encode kernel -> pand_popcount kernel -> argmax) with the Pallas
  kernels pinned on (interpret mode on CPU), exactly as the harness shipped.
* ``unfused`` -- the packed-domain composition (counter-based encode ->
  AND -> popcount -> argmax) as jitted jnp stages, each materialising its
  packed intermediate.
* ``fused``   -- one ``bayes_decide`` launch, nothing per-bit materialised.

The printed speedups are the tentpole's acceptance numbers.

A fourth family measures the paper's budget the way it is stated -- per
*frame*, not per batch: ``latency.frame_decide_<scenario>@128bit`` times one
fused single-frame ``CompiledNetwork.decide`` per scenario, retains every
sample, and emits p50/p99 next to the min.  The samples also feed
:class:`~repro.obs.histogram.LatencyHistogram` instances annotated with the
0.4 ms budget, exported as the ``latency_hist.csv`` artifact together with a
traced :class:`~repro.bayesnet.driver.FrameDriver` run exported as
``trace_framedriver.json`` (load it in Perfetto / chrome://tracing to see the
async launch pipeline).  ``check_bench.check_latency_budget`` gates the
p50/p99 of every frame_decide row.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import latency
from repro.kernels.bayes_decide.ops import bayes_decide, bayes_decide_packed
from repro.kernels.pand_popcount.ops import pand_popcount
from repro.kernels.sne_encode.ops import sne_encode

N_DEC = 4096
N_BITS = 128
M, K = 2, 2

# single-frame budget rows: one decision at the paper's ~100-bit operating
# point, across the binary trio + the 4-class categorical scenario
FRAME_N_BITS = 128
FRAME_ITERS = 200
FRAME_SCENARIOS = ("sensor-degradation", "pedestrian-night", "intersection",
                   "obstacle-class")


def run():
    # memristor-substrate model (the paper's own numbers)
    rep = latency.memristor_latency(n_bits=100, n_sne=5)
    emit("latency.memristor@100bit", rep.frame_latency_s * 1e6,
         f"{rep.frame_latency_s*1e3:.2f}ms/frame fps={rep.fps:.0f} "
         f"meets_paper={rep.meets_paper_claim()} "
         f"energy={rep.energy_per_decision_j*1e9:.1f}nJ/decision")
    emit("latency.reference_points", 0.0,
         f"human={latency.HUMAN_REACTION_S} ADAS_fps={latency.ADAS_FPS} "
         f"camera_fps={latency.CAMERA_FPS} edge_net_fps={latency.EDGE_NET_FPS}")

    # TPU mapping: throughput model + measured decision-pipeline timings
    model = latency.tpu_throughput_model(n_bits=N_BITS)
    emit("latency.tpu_model@128bit", 0.0, f"{model:.2e} decisions/s/core (model)")

    key = jax.random.PRNGKey(0)
    p = jax.random.uniform(key, (M, N_DEC, K))

    def decide_seed(p):
        # the composition the seed harness timed: kernel launches pinned on
        streams = sne_encode(key, p, N_BITS, use_kernel=True, interpret=True)
        counts = pand_popcount(
            streams.reshape(M, -1, N_BITS // 32), use_kernel=True, interpret=True
        ).reshape(N_DEC, K)
        return jnp.argmax(counts, -1)

    def decide_unfused(p):
        dec, _ = bayes_decide_packed(key, p, N_BITS)
        return dec

    def decide_fused(p):
        dec, _ = bayes_decide(key, p, N_BITS)
        return dec

    # min-of-N, like the bayesnet rows: a shared-tenant interference spike can
    # run 10-20x slow and poison a small-sample median, but never the min --
    # the speedup ratios below feed the committed perf trajectory, so they
    # must compare machine capability, not scheduler luck.
    us_seed = timeit(jax.jit(decide_seed), p, iters=3, stat="min")
    us_unfused = timeit(jax.jit(decide_unfused), p, warmup=2, iters=15, stat="min")
    us_fused = timeit(jax.jit(decide_fused), p, warmup=2, iters=15, stat="min")

    emit(f"latency.seed_pipeline_{N_DEC}dec@{N_BITS}bit", us_seed,
         f"{N_DEC/(us_seed/1e6):.2e} decisions/s (seed: 3 launches, interpret)")
    emit(f"latency.unfused_packed_{N_DEC}dec@{N_BITS}bit", us_unfused,
         f"{N_DEC/(us_unfused/1e6):.2e} decisions/s (packed stages, jnp)")
    emit(f"latency.packed_pipeline_{N_DEC}dec@{N_BITS}bit", us_fused,
         f"{N_DEC/(us_fused/1e6):.2e} decisions/s (fused bayes_decide; "
         f"paper hardware: 2.5e3 fps)")
    emit("latency.fused_speedup_vs_seed", us_seed / us_fused,
         f"fused is {us_seed/us_fused:.1f}x faster than the seed composition")
    emit("latency.fused_speedup_vs_unfused", us_unfused / us_fused,
         f"fused is {us_unfused/us_fused:.2f}x vs unfused packed stages "
         f"(~1x on CPU where XLA fuses both; the kernel gain shows on TPU)")

    run_frame_budget()


def run_frame_budget(artifact_dir: str = ".") -> None:
    """Per-frame budget rows + the observability artifacts.

    One fused ``decide`` launch per single evidence frame, per scenario --
    the shape of the paper's claim ("every decision inside 0.4 ms"), where
    the batched rows above measure throughput.  All per-iteration samples
    are retained, so the emitted p50/p99 are exact; ``check_bench`` gates
    them (p50 against the budget itself, p99 against budget x a documented
    container multiplier).
    """
    from repro.bayesnet import by_name, compile_network, sample_evidence
    from repro.bayesnet.driver import FrameDriver
    from repro.obs import PAPER_BUDGET_MS, MetricsRegistry, Tracer

    key = jax.random.PRNGKey(0)
    reg = MetricsRegistry()
    for name in FRAME_SCENARIOS:
        spec = by_name(name)
        net = compile_network(spec, n_bits=FRAME_N_BITS)
        ev = sample_evidence(spec, jax.random.PRNGKey(2), 1)
        us = timeit(
            lambda n=net, e=ev: n.decide(key, e),
            warmup=5, iters=FRAME_ITERS, stat="min",
        )
        h = reg.hist(f"frame_decide_{name}", budget_ms=PAPER_BUDGET_MS)
        h.observe_many([u / 1e3 for u in us.samples_us])
        emit(
            f"latency.frame_decide_{name}@{FRAME_N_BITS}bit", us,
            f"1 frame/launch, fused decide | p50 {us.p50:.0f}us "
            f"p99 {us.p99:.0f}us | {h.budget_fraction():.0%} of calls within "
            f"the paper's {PAPER_BUDGET_MS}ms budget",
            extra={"budget_ms": PAPER_BUDGET_MS,
                   "budget_fraction": round(h.budget_fraction(), 4)},
        )

    # traced FrameDriver run -> Perfetto artifact.  96 frames through a
    # 32-lane async driver = 3 pipelined launches; the exported `device`
    # spans overlap, which is the async pipeline made visible.  The driver
    # shares the registry above, so its frame_ms / launch_ms / watchdog
    # histograms land in the same latency_hist.csv.
    tr = Tracer()
    spec = by_name("pedestrian-night")
    net = compile_network(spec, n_bits=FRAME_N_BITS)
    drv = FrameDriver(net, max_batch=32, salt=0, trace=tr, metrics=reg)
    drv.submit(sample_evidence(spec, jax.random.PRNGKey(3), 96))
    drv.drain_async()
    trace_path = tr.export_chrome_trace(
        os.path.join(artifact_dir, "trace_framedriver.json")
    )
    hist_path = reg.write_hist_csv(os.path.join(artifact_dir, "latency_hist.csv"))
    emit(
        "latency.obs_artifacts", 0.0,
        f"{len(tr.spans)} spans -> {os.path.basename(trace_path)} "
        f"(chrome://tracing / Perfetto) | "
        f"{len(reg.histograms)} histograms -> {os.path.basename(hist_path)}",
        extra={"n_spans": len(tr.spans),
               "driver_launches": reg.count("launches")},
    )


if __name__ == "__main__":
    run()
