"""Timeliness claim: <0.4 ms/frame (>=2,500 fps) at 100-bit encoding, and the
TPU-mapped throughput of the packed kernels."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.core import latency
from repro.kernels.pand_popcount.ops import pand_popcount
from repro.kernels.sne_encode.ops import sne_encode


def run():
    # memristor-substrate model (the paper's own numbers)
    rep = latency.memristor_latency(n_bits=100, n_sne=5)
    emit("latency.memristor@100bit", rep.frame_latency_s * 1e6,
         f"{rep.frame_latency_s*1e3:.2f}ms/frame fps={rep.fps:.0f} "
         f"meets_paper={rep.meets_paper_claim()} "
         f"energy={rep.energy_per_decision_j*1e9:.1f}nJ/decision")
    emit("latency.reference_points", 0.0,
         f"human={latency.HUMAN_REACTION_S} ADAS_fps={latency.ADAS_FPS} "
         f"camera_fps={latency.CAMERA_FPS} edge_net_fps={latency.EDGE_NET_FPS}")

    # TPU mapping: throughput model + measured CPU-interpret lower bound
    model = latency.tpu_throughput_model(n_bits=128)
    emit("latency.tpu_model@128bit", 0.0, f"{model:.2e} decisions/s/core (model)")

    n_dec = 4096
    key = jax.random.PRNGKey(0)
    p = jax.random.uniform(key, (2, n_dec, 2))

    def decide(p):
        streams = sne_encode(key, p, 128)
        counts = pand_popcount(streams.reshape(2, -1, 4)).reshape(n_dec, 2)
        return jnp.argmax(counts, -1)

    us = timeit(jax.jit(decide), p, iters=3)
    emit("latency.packed_pipeline_4096dec@128bit", us,
         f"{n_dec/(us/1e6):.2e} decisions/s on 1 CPU core (interpret mode; "
         f"paper hardware: 2.5e3 fps)")


if __name__ == "__main__":
    run()
