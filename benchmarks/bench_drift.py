"""Closed-loop drift race: frozen-plan vs. recalibrating driver under wear.

One row per scenario makes the calibrate-back loop (DESIGN.md SS15) a
measured artifact.  Both arms serve the same evidence batch through a
:class:`~repro.bayesnet.FrameDriver` while the simulated crossbar ages
underneath them -- every launch ``i`` hot-swaps in a plan compiled against
``NoiseModel.with_cycle(i * CYCLE_STEP)``, so read noise grows with the
endurance-derived ``wear_scale`` while device-to-device spread and IR drop
stay frozen:

* **open arm** -- the thresholds programmed at install time never move; the
  drifting array walks away from them and the MAP flip-rate against the
  clean DAC-quantised oracle climbs.
* **closed arm** -- every ``RECAL_EVERY`` launches the driver swaps in a
  :func:`~repro.bayesnet.compensated_program` refit at the current cycle
  (``prog = clean / error_factors``), pulling the effective thresholds back
  to within a DAC step or two of clean.  Refitting cancels the persistent
  terms (device-to-device spread, IR drop) at *any* cycle but the
  cycle-to-cycle read realization only at the refit cycle itself -- each
  cycle draws it fresh -- so the schedule deliberately ends on a refit
  launch (``LAUNCHES`` odd, cadence-aligned): the gated number measures
  the loop right after it did its job, exactly where a tripped
  ``DriftMonitor`` leaves a live tenant, while the CSV trajectory keeps the
  honest sawtooth of the stale launches in between.

``check_bench.check_drift`` gates ``flip_closed <= flip_open`` at the final
cycle on every row (within ``DRIFT_FLIP_TOL``, two standard errors of the
final-flip estimator -- on a scenario whose array draw leaves every decision
boundary untouched the difference is pure sampling noise with mean zero) and
demands a strict, no-slack win on >=5 of the 7 scenarios when the full set
is present (quick mode runs a binary + categorical pair at underpowered
sizes and skips the flip gates).  The final-cycle flip averages
``FINAL_REPEATS`` launches at the same cycle to push the sampling floor
under the real threshold-error margins; everything is seeded, so committed
numbers reproduce bit-for-bit on a fixed jax/CPU stack.

Two more rows ride along: ``drift_hotswap`` times ``swap_net`` against a
never-swapped twin and gates the ordering guarantees (``lost_frames == 0``,
pre-swap harvests bit-identical -> ``swap_preserved == 1``), and
``drift_calibration`` times :func:`~repro.bayesnet.calibration_report` and
records the rollout-fit bias per generator field plus the worst DAC-grid
deviation of the rebuilt CPTs.  :func:`write_drift_report` snapshots the
per-launch flip trajectory to CSV -- the CI drift-smoke artifact.
"""

from __future__ import annotations

import csv
import time

import jax
import numpy as np

from benchmarks import common

SCENARIO_NAMES = ("sensor-degradation", "pedestrian-night", "lane-change",
                  "intersection", "obstacle-detection", "obstacle-class",
                  "intersection-cat")
QUICK_SCENARIOS = ("sensor-degradation", "intersection-cat")
N_BITS = 1024
N_BITS_QUICK = 512
BATCH = 128
BATCH_QUICK = 64
LAUNCHES = 7         # odd on purpose: the last launch lands on a refit
LAUNCHES_QUICK = 5
CYCLE_STEP = 2.0     # accelerated aging: cycles of wear per launch
RECAL_EVERY = 2      # closed arm refits its program every other launch
DRIFT_EPOCHS = 2     # within-launch drift: the stream spans two snapshots
FINAL_REPEATS = 8    # the gated final-cycle flip averages this many launches
# sqrt wear doubles the read CV over the 12-cycle race -- visible aging, but
# the paper's 8% d2d spread stays the dominant (and fully compensatable)
# term; cranking wear further just drowns the loop in the per-cycle read
# realization that no one-shot programming can cancel
WEAR_TAU = 4.0
# Like bench_serve's chaos seed, the array seed is scanned, not arbitrary:
# seed 4's d2d draw lands real open-loop damage on 6 of the 7 scenarios at
# the final cycle (exact-oracle flip margins 0.013-0.10; lane-change draws a
# benign array and both arms sit on the clean oracle).  A seed that happens
# to leave every decision boundary untouched would make the race a tie of
# sampling noise with a scarier name.
NOISE_SEED = 4
SALT = 17

_REPORT_ROWS: list[list] = []


def _collect(drv, rids) -> np.ndarray:
    out = drv.drain()
    return np.stack([np.asarray(out[r][0]) for r in rids])


def _race(name: str, n_bits: int, batch: int, launches: int):
    """Run both arms over the aging schedule; returns the final-cycle flips."""
    from repro.bayesnet import (
        FrameDriver,
        NoiseModel,
        by_name,
        compensated_program,
        compile_network,
        flip_rate,
        make_posterior_fn,
        posterior_argmax,
        sample_evidence,
    )

    spec = by_name(name)
    nm = NoiseModel(seed=NOISE_SEED, wear_tau=WEAR_TAU)
    ev = np.asarray(sample_evidence(spec, jax.random.PRNGKey(3), batch))
    ref = posterior_argmax(make_posterior_fn(spec, dac_quantize=True)(ev)[0])

    def plan(cycle: float, program_cycle: float | None = None):
        prog = (
            None
            if program_cycle is None
            else compensated_program(
                spec, nm.with_cycle(program_cycle),
                drift_epochs=DRIFT_EPOCHS,
            )
        )
        return compile_network(
            spec, n_bits, noise=nm.with_cycle(cycle),
            drift_epochs=DRIFT_EPOCHS, program=prog, devices=1,
        )

    drv_open = FrameDriver(plan(0.0), max_batch=batch, salt=SALT)
    drv_closed = FrameDriver(plan(0.0, 0.0), max_batch=batch, salt=SALT)
    recals, prog_cycle = 1, 0.0
    cycle = flip_open = flip_closed = 0.0
    closed_us: list[float] = []
    for i in range(launches):
        cycle = i * CYCLE_STEP
        if i > 0:
            # the array ages under both drivers; only the closed arm refits
            drv_open.swap_net(plan(cycle))
            if i % RECAL_EVERY == 0:
                prog_cycle = cycle
                recals += 1
            drv_closed.swap_net(plan(cycle, prog_cycle))
        # the final-cycle flip (the gated number) averages several launches
        # at the same cycle -- single-launch estimates bounce +/-0.01-0.02
        # from per-frame sampling alone at bench-sized n_bits
        reps = FINAL_REPEATS if i == launches - 1 else 1
        flip_open = flip_closed = 0.0
        for _ in range(reps):
            po = _collect(drv_open, drv_open.submit(ev))
            t0 = time.perf_counter()
            pc = _collect(drv_closed, drv_closed.submit(ev))
            closed_us.append((time.perf_counter() - t0) * 1e6 / batch)
            flip_open += float(flip_rate(posterior_argmax(po), ref))
            flip_closed += float(flip_rate(posterior_argmax(pc), ref))
        flip_open /= reps
        flip_closed /= reps
        _REPORT_ROWS.append(
            [name, i, cycle, round(flip_open, 4), round(flip_closed, 4),
             recals]
        )
    common.emit(
        f"drift_{name}",
        common.Timing(min(closed_us), closed_us),
        f"cycle {cycle:.0f}: flip open {flip_open:.4f} vs closed "
        f"{flip_closed:.4f} ({recals} recals)",
        extra={
            "flip_open": round(flip_open, 4),
            "flip_closed": round(flip_closed, 4),
            "final_cycle": cycle,
            "recals": recals,
            "n_bits": n_bits,
            "launches": launches,
            "wear_tau": WEAR_TAU,
        },
    )


def _hotswap(n_bits: int) -> None:
    """Swap a recalibrated plan under in-flight launches; gate the invariants."""
    from repro.bayesnet import (
        FrameDriver,
        NoiseModel,
        by_name,
        compile_network,
        recalibrated_network,
        sample_evidence,
    )

    spec = by_name("pedestrian-night")
    nm = NoiseModel(seed=NOISE_SEED, cycle=4.0, wear_tau=WEAR_TAU)
    net = compile_network(spec, n_bits, noise=nm, drift_epochs=DRIFT_EPOCHS,
                          devices=1)
    ev = np.asarray(sample_evidence(spec, jax.random.PRNGKey(5), 16))
    twin = FrameDriver(net, max_batch=4, salt=99)
    swp = FrameDriver(net, max_batch=4, salt=99)
    t_rids, s_rids = twin.submit(ev), swp.submit(ev)
    for drv in (twin, swp):
        drv.step(block=False)
        drv.step(block=False)          # two launches (8 frames) in flight
    t0 = time.perf_counter()
    swp.swap_net(recalibrated_network(net, cycle=8.0))
    swap_us = (time.perf_counter() - t0) * 1e6
    out_twin, out_swp = twin.drain(), swp.drain()
    lost = len(set(s_rids) - set(out_swp))
    pre_swap = s_rids[:8]              # frames dispatched before the swap
    preserved = int(
        lost == 0
        and all(
            np.array_equal(out_twin[t][0], out_swp[s][0])
            and out_twin[t][1] == out_swp[s][1]
            for t, s in zip(t_rids[:8], pre_swap)
        )
    )
    common.emit(
        "drift_hotswap",
        swap_us,
        f"swap under 2 in-flight launches: lost {lost}, "
        f"pre-swap bit-identical {bool(preserved)}",
        extra={"lost_frames": lost, "swap_preserved": preserved,
               "frames": len(s_rids)},
    )


def _calibration(quick: bool) -> None:
    """Time the rollout-fit report; record bias + DAC deviation numerically."""
    from repro.bayesnet import calibration_report

    n_scenes, repeats = (8, 1) if quick else (24, 2)
    t0 = time.perf_counter()
    rep = calibration_report(
        jax.random.PRNGKey(6), n_scenes=n_scenes, repeats=repeats
    )
    us = (time.perf_counter() - t0) * 1e6
    worst = max(rep["fields"].items(), key=lambda kv: abs(kv[1]["bias"]))
    common.emit(
        "drift_calibration",
        us,
        f"{n_scenes} scenes x {repeats} fits: max DAC dev "
        f"{rep['max_dac_deviation']}, worst bias {worst[0]} "
        f"{worst[1]['bias']:+.3f}",
        extra={
            "max_dac_deviation": rep["max_dac_deviation"],
            "n_scenes": n_scenes,
            **{
                f"bias_{f}": round(s["bias"], 4)
                for f, s in rep["fields"].items()
            },
        },
    )


def write_drift_report(path: str) -> str:
    """Per-launch flip trajectory CSV (the CI drift-smoke artifact)."""
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["scenario", "launch", "cycle", "flip_open",
                    "flip_closed", "recals"])
        w.writerows(_REPORT_ROWS)
    return path


def run(quick: bool = False, report_path: str | None = None) -> None:
    names = QUICK_SCENARIOS if quick else SCENARIO_NAMES
    n_bits = N_BITS_QUICK if quick else N_BITS
    batch = BATCH_QUICK if quick else BATCH
    launches = LAUNCHES_QUICK if quick else LAUNCHES
    for name in names:
        _race(name, n_bits, batch, launches)
    _hotswap(n_bits)
    _calibration(quick)
    if report_path is not None:
        print(f"# wrote {write_drift_report(report_path)}")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
