"""Bench-smoke regression gates over a freshly written ``BENCH_*.json``.

Eight gates:

* **Independent-entropy cliff**: per-frame joint samples (the production
  mode, what the physical memristor array provides for free) must stay within
  ``MAX_INDEP_RATIO`` of the shared-entropy launch for the 8-node
  pedestrian-night network.  The committed trajectory once showed ~70x here;
  the fused ``net_sweep`` lowering holds it to low double digits
  (host-dependent: 5-13x across the containers that produced the committed
  snapshots), and this gate keeps the cliff from silently regressing.
* **Trajectory regression**: every ``bayesnet_*`` scenario row present in
  both the fresh snapshot and the newest *committed* ``BENCH_*.json`` must
  stay within ``MAX_FPS_REGRESSION`` (30% frames/s) of the committed number.
  The baseline is auto-discovered next to the fresh snapshot (the snapshot
  itself is excluded), so CI compares each run against the repo's own perf
  history; rows that exist only on one side (new scenarios, retired ones) are
  skipped.  The sharded and decide rows are plain ``bayesnet_*`` rows, so
  they ride this gate with the same min-of-N >30% rule automatically.
* **Decide epilogue overhead**: for every scenario with both a
  ``_decide_`` and an ``_indep_`` row, the fused posterior+decision launch
  must stay within ``MAX_DECIDE_OVERHEAD`` of the posterior-only sweep.  The
  epilogue argmaxes counts that never leave registers; if it costs real time
  something regressed structurally (e.g. the decide path stopped fusing).
* **Nominal flip-rate**: every ``reliability_*_flip_vs_nbits`` row's
  4096-bit MAP flip-rate against the clean oracle, under the
  paper-calibrated nominal :class:`~repro.bayesnet.noise.NoiseModel`, must
  stay under ``MAX_NOMINAL_FLIP``.  The committed worst case (obstacle-class,
  whose perturbed decision boundaries genuinely move) sits near 0.09; a
  breach means either the noise model's magnitudes drifted or the sampler
  stopped averaging sampling flips out.
* **Retry wins at equal budget**: every ``reliability_*_retry`` row must
  show the confidence-gated driver at or below the no-retry driver's
  flip-rate (``flip_retry <= flip_noretry``; the flat driver is given at
  least the retry driver's mean per-frame bits, so this is a real win, not
  a budget artefact), with the retry bit overhead (mean bits / base bits)
  under ``MAX_RETRY_OVERHEAD``.  The sweep is fully seeded, so the committed
  values reproduce bit-for-bit on a fixed jax/CPU stack.
* **Serve-tier invariants**: every ``serve_*`` mixed-workload row must
  report ``lost_frames == 0`` -- under seeded 5% launch-fault chaos too, the
  fleet never-drop invariant: every submitted frame terminates in exactly
  one of OK/DEGRADED/UNRELIABLE/REJECTED -- and a ``deadline_hit_rate`` at
  or above ``MIN_DEADLINE_HIT``.  The ``serve_*`` throughput rides the same
  30% trajectory rule as the ``bayesnet_*`` rows.
* **Latency budget**: every ``latency.frame_decide_*`` row (single-frame
  fused decide, all samples retained) must hold the paper's 0.4 ms budget at
  the median (p50 <= 400 us, no fudge -- committed p50s run 50-95 us) and at
  the tail within a documented container multiplier
  (p99 <= 400 us x ``LATENCY_BUDGET_MULT``; see the constant's comment for
  why a shared 2-vCPU container cannot gate a raw sub-millisecond p99).
* **Calibrate-back loop**: every ``drift_<scenario>`` row must show the
  periodically recalibrated driver's final-cycle MAP flip-rate at or below
  the frozen-plan driver's, with strict wins on >= ``MIN_DRIFT_WINS`` of the
  7 scenarios when the full set is present (quick-mode partial sets skip the
  flip gates -- they are statistically underpowered), and the
  ``drift_hotswap`` row must report zero lost frames and bit-identical
  pre-swap harvests at any size.

Usage: ``python benchmarks/check_bench.py BENCH_<ts>.json [baseline.json]``
(CI runs it right after the bench-smoke step writes the snapshot), or call
:func:`check` with the path from the same process.
"""

from __future__ import annotations

import glob
import json
import os
import re
import subprocess
import sys

# The cliff this guards is the ~70x the per-node lowering used to pay; the
# fused sweep holds low double digits.  Re-calibrated 2026-08-07: the bench
# host changed (same commit measures 13.1x today vs the 5.0-6.4x committed
# from the old container -- shared launches got ~1.8x faster, fused indep
# ~1.45x slower), so the old 8x limit now sits below same-code hardware
# variance.  24x keeps 2x headroom over today's worst scenario while still
# catching any return of the cliff.
MAX_INDEP_RATIO = 24.0
# Fail when a scenario's frames/s drops more than 30% vs the committed
# snapshot: new_us > old_us / 0.7.  Baselines only mean anything on a
# like-for-like host: the container behind this repo was downsized from
# 2 vCPUs to 1 on 2026-08-07 (os.cpu_count() 2 -> 1; a git-stash
# experiment confirmed the *committed* code re-measures identically to
# the working tree, so the shift is hardware, not code).  The small
# multi-threaded shared-entropy launches lose the ~1.8x two-core speedup
# the morning re-calibration note below records (intersection and
# obstacle-class shared rows ~1.4x slower) while the single-core-bound
# fused rows move <~6%, so the snapshot landed with the telemetry PR
# re-baselines the trajectory on the 1-vCPU host.  When this gate fails
# on threading-sensitive rows with no plausible code cause, check the
# host before checking the diff.
MAX_FPS_REGRESSION = 0.30
# The in-kernel decide epilogue is a register-level argmax; 1.3x absorbs
# shared-tenant noise while still catching a structurally broken fusion
# (the acceptance target for a quiet machine is within 10%).
MAX_DECIDE_OVERHEAD = 1.30
# Nominal-noise 4096-bit flip-rate ceiling: the committed worst scenario
# (obstacle-class) floors near 0.09, all others sit at 0.06 or below.
MAX_NOMINAL_FLIP = 0.15
# Confidence-gated retry's mean per-frame bit bill over the base stream
# length: committed rows run 3.5-6x (min_confidence=0.9, escalation=4).
MAX_RETRY_OVERHEAD = 8.0
# The paper's timeliness claim per decision: 0.4 ms (>= 2,500 fps).
PAPER_BUDGET_US = 400.0
# Serve-tier deadline floor: with the default 1 s request deadlines the
# mixed-workload rows hold 1.0 on every committed run; 0.95 absorbs one
# multi-hundred-ms container stall per bench round without letting a
# structural deadline regression (admission mis-estimating, drain spinning)
# through.  Zero lost frames has NO tolerance: one lost frame is a bug.
MIN_DEADLINE_HIT = 0.95
# p99 container multiplier.  The budget genuinely holds on this stack -- the
# committed frame_decide rows show min 45-63 us and p50 50-95 us, 4-8x inside
# 0.4 ms -- but this repo's CI shares 2-vCPU gVisor containers whose scheduler
# preempts the bench process for multi-millisecond stalls: measured p99 runs
# 2.6-4.1 ms against a 45 us min, a ~60x spread that is entirely scheduler
# occupancy, not code.  20x bounds the p99 at 8 ms: above any stall observed
# on these containers, far below what a structural regression produces (the
# decide path falling out of fusion or back to interpret-mode kernels costs
# 100x+, and the strict p50 arm catches anything sustained).  On quiet
# hardware set REPRO_LATENCY_MULT=1 to gate the paper budget directly.
LATENCY_BUDGET_MULT = 20.0
# Closed-loop drift race: with all 7 scenario rows present, the recalibrated
# arm must strictly beat the frozen-plan arm's final-cycle flip-rate on at
# least this many (ties allowed on the rest; the <= envelope is gated on
# every row).
MIN_DRIFT_WINS = 5
# Envelope slack for the drift race: where a scenario's array draw leaves
# every decision boundary untouched (exact-oracle flips 0 on BOTH arms --
# lane-change on the committed seed), the measured difference is pure
# sampling noise with mean 0, so the <= envelope gets two standard errors of
# the per-arm final-flip estimator: 8 averaged launches x 128 frames = 1024
# frame-decisions at p ~= 0.02 -> SE ~= 0.004.  The strict-wins floor takes
# no slack: a win must be a real margin.
DRIFT_FLIP_TOL = 0.008
_SHARED = "bayesnet_pedestrian-night_batch1024"
_INDEP = "bayesnet_pedestrian-night_indep_batch1024"


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def newest_committed(path: str) -> str | None:
    """Newest *git-tracked* ``BENCH_*.json`` beside ``path`` (never ``path``).

    Only committed snapshots count as the perf-history baseline: a local
    bench run drops its (untracked) snapshot into the same directory, and
    comparing against that would let one stray local run ratchet or mask the
    gate.  Outside a git checkout every snapshot on disk is considered.
    Snapshot names embed a sortable timestamp, so lexicographic order is
    chronological order.
    """
    root = os.path.dirname(os.path.abspath(path)) or "."
    cands = [
        c for c in glob.glob(os.path.join(root, "BENCH_*.json"))
        if os.path.abspath(c) != os.path.abspath(path)
    ]
    try:
        tracked = set(
            subprocess.run(
                ["git", "-C", root, "ls-files", "--", "BENCH_*.json"],
                capture_output=True, text=True, check=True,
            ).stdout.split()
        )
        cands = [c for c in cands if os.path.basename(c) in tracked]
    except (OSError, subprocess.CalledProcessError):
        pass  # not a git checkout: fall back to everything on disk
    cands.sort()
    return cands[-1] if cands else None


def check_indep_ratio(data: dict, path: str) -> None:
    missing = [k for k in (_SHARED, _INDEP) if k not in data]
    if missing:
        raise SystemExit(f"{path}: missing bench rows {missing}")
    shared_us = float(data[_SHARED]["us_per_call"])
    indep_us = float(data[_INDEP]["us_per_call"])
    ratio = indep_us / shared_us
    print(
        f"independent-entropy gate: {indep_us:,.0f} us vs {shared_us:,.0f} us "
        f"shared -> ratio {ratio:.2f}x (limit {MAX_INDEP_RATIO:.0f}x)"
    )
    if ratio > MAX_INDEP_RATIO:
        raise SystemExit(
            f"independent-entropy cliff regressed: indep/shared ratio "
            f"{ratio:.2f}x exceeds {MAX_INDEP_RATIO:.0f}x "
            f"({_INDEP} vs {_SHARED} in {path})"
        )


def check_regression(data: dict, path: str, baseline: str | None) -> None:
    if baseline is None:
        baseline = newest_committed(path)
    if baseline is None:
        print("trajectory gate: no committed BENCH_*.json baseline, skipping")
        return
    base = _load(baseline)
    rows = sorted(
        k for k in data
        if k.startswith(("bayesnet_", "serve_")) and k in base
        and not k.startswith("_")
    )
    if not rows:
        print(f"trajectory gate: no shared bayesnet/serve rows vs {baseline}, skipping")
        return
    failed = []
    for k in rows:
        old_us = float(base[k]["us_per_call"])
        new_us = float(data[k]["us_per_call"])
        drop = 1.0 - old_us / new_us          # frames/s regression fraction
        status = "FAIL" if drop > MAX_FPS_REGRESSION else "ok"
        print(
            f"trajectory gate [{status}]: {k}: {new_us:,.0f} us vs committed "
            f"{old_us:,.0f} us ({'-' if drop > 0 else '+'}{abs(drop):.0%} frames/s)"
        )
        if drop > MAX_FPS_REGRESSION:
            failed.append(k)
    if failed:
        raise SystemExit(
            f"frames/s regressed >{MAX_FPS_REGRESSION:.0%} vs {baseline} "
            f"for {failed}"
        )


_OVERHEAD_RE = re.compile(r"overhead ([0-9.]+)x")


def check_decide_overhead(data: dict, path: str) -> None:
    """Gate the same-moment decide/sweep ratio each ``_decide_`` row records.

    The bench times the pair interleaved (``common.timeit_pair``) precisely
    so the ratio is immune to interference drift between row families --
    dividing the decide row's ``us_per_call`` by the independent row's,
    measured minutes apart, would gate scheduler luck instead.  The ratio is
    read from the row's structured ``decide_overhead`` field, with a parse of
    the derived string as fallback for snapshots from before the field.
    """
    rows = sorted(
        k for k in data if "_decide_" in k and k.startswith("bayesnet_")
    )
    if not rows:
        print("decide gate: no decide rows, skipping")
        return
    failed = []
    for row in rows:
        # structured field first (bench emits it since PR 5); regex over the
        # derived string only as a fallback for older committed snapshots
        ratio = data[row].get("decide_overhead")
        if ratio is None:
            m = _OVERHEAD_RE.search(str(data[row].get("derived", "")))
            if not m:
                print(f"decide gate: {row} has no recorded overhead ratio, skipping")
                continue
            ratio = m.group(1)
        ratio = float(ratio)
        status = "FAIL" if ratio > MAX_DECIDE_OVERHEAD else "ok"
        print(
            f"decide gate [{status}]: {row}: {ratio:.2f}x the "
            f"posterior-only sweep (limit {MAX_DECIDE_OVERHEAD:.2f}x)"
        )
        if ratio > MAX_DECIDE_OVERHEAD:
            failed.append(row)
    if failed:
        raise SystemExit(
            f"fused decide overhead exceeds {MAX_DECIDE_OVERHEAD:.2f}x the "
            f"posterior-only sweep for {failed} in {path}"
        )


def check_nominal_flip(data: dict, path: str) -> None:
    """Gate the nominal-noise flip floor of every committed sweep row."""
    rows = sorted(k for k in data if k.endswith("_flip_vs_nbits"))
    if not rows:
        print("flip-rate gate: no reliability sweep rows, skipping")
        return
    failed = []
    for row in rows:
        flips = {k: v for k, v in data[row].items() if k.startswith("flip_")}
        if not flips:
            print(f"flip-rate gate: {row} has no flip_* fields, skipping")
            continue
        # the longest-stream column is the gated floor
        top = max(flips, key=lambda k: int(k.split("_")[1]))
        rate = float(flips[top])
        status = "FAIL" if rate > MAX_NOMINAL_FLIP else "ok"
        print(
            f"flip-rate gate [{status}]: {row}: {rate:.3f} at {top.split('_')[1]} "
            f"bits (limit {MAX_NOMINAL_FLIP})"
        )
        if rate > MAX_NOMINAL_FLIP:
            failed.append(row)
    if failed:
        raise SystemExit(
            f"nominal flip-rate exceeds {MAX_NOMINAL_FLIP} for {failed} in {path}"
        )


def check_retry(data: dict, path: str) -> None:
    """Gate the retry race: gated retry beats flat at equal budget, bounded bill."""
    rows = sorted(k for k in data if k.endswith("_retry") and "reliability_" in k)
    if not rows:
        print("retry gate: no retry rows, skipping")
        return
    failed = []
    for row in rows:
        r = data[row]
        fr, fn = float(r["flip_retry"]), float(r["flip_noretry"])
        overhead = float(r["retry_overhead"])
        bad = fr > fn or overhead > MAX_RETRY_OVERHEAD
        status = "FAIL" if bad else "ok"
        print(
            f"retry gate [{status}]: {row}: retry {fr:.3f} vs flat {fn:.3f} "
            f"flips, {overhead:.1f}x bit overhead (limit {MAX_RETRY_OVERHEAD}x)"
        )
        if bad:
            failed.append(row)
    if failed:
        raise SystemExit(
            f"confidence-gated retry lost its race (flip_retry > flip_noretry "
            f"or overhead > {MAX_RETRY_OVERHEAD}x) for {failed} in {path}"
        )


def check_latency_budget(data: dict, path: str) -> None:
    """Gate the single-frame decide distribution against the 0.4 ms budget.

    Two arms per ``latency.frame_decide_*`` row: p50 must clear the budget
    itself (the honest "paper claim holds on commodity CPU" check -- the
    median is robust to the isolated scheduler stalls that poison a
    shared-container tail), p99 must clear budget x the documented
    ``LATENCY_BUDGET_MULT`` (overridable via ``REPRO_LATENCY_MULT`` for
    quiet hardware).  Percentiles are read from the structured ``p50_us`` /
    ``p99_us`` fields that every Timing-emitted row carries.
    """
    rows = sorted(k for k in data if k.startswith("latency.frame_decide_"))
    if not rows:
        print("latency-budget gate: no frame_decide rows, skipping")
        return
    mult = float(os.environ.get("REPRO_LATENCY_MULT", LATENCY_BUDGET_MULT))
    limit_p99 = PAPER_BUDGET_US * mult
    failed = []
    for row in rows:
        r = data[row]
        if "p50_us" not in r or "p99_us" not in r:
            print(f"latency-budget gate: {row} has no percentile fields, skipping")
            continue
        p50, p99 = float(r["p50_us"]), float(r["p99_us"])
        bad = p50 > PAPER_BUDGET_US or p99 > limit_p99
        status = "FAIL" if bad else "ok"
        print(
            f"latency-budget gate [{status}]: {row}: p50 {p50:,.0f} us "
            f"(paper budget {PAPER_BUDGET_US:.0f} us) | p99 {p99:,.0f} us "
            f"(limit {limit_p99:,.0f} us = budget x {mult:g} container mult)"
        )
        if bad:
            failed.append(row)
    if failed:
        raise SystemExit(
            f"single-frame decide latency broke the paper budget "
            f"(p50 > {PAPER_BUDGET_US:.0f} us or p99 > {limit_p99:,.0f} us) "
            f"for {failed} in {path}"
        )


def check_serve(data: dict, path: str) -> None:
    """Gate the serve-tier rows: zero lost frames, deadline-hit floor.

    Every ``serve_*`` row carries a structured terminal-status census
    (``bench_serve``).  ``lost_frames`` counts submitted frames that never
    reached a terminal OK/DEGRADED/UNRELIABLE/REJECTED status -- the fleet
    never-drop invariant, and the chaos row runs it under seeded 5% launch
    faults, so ANY nonzero value is a recovery-path bug, not noise.
    ``deadline_hit_rate`` must hold ``MIN_DEADLINE_HIT`` (the default 1 s
    request deadlines give ~3 orders of magnitude of headroom per frame;
    sustained misses mean admission estimates or drain convergence broke).
    """
    rows = sorted(k for k in data if k.startswith("serve_"))
    if not rows:
        print("serve gate: no serve rows, skipping")
        return
    failed = []
    for row in rows:
        r = data[row]
        if "lost_frames" not in r:
            print(f"serve gate: {row} has no status census, skipping")
            continue
        lost = int(r["lost_frames"])
        hit = float(r.get("deadline_hit_rate", 1.0))
        terminal = sum(
            int(r.get(k, 0)) for k in ("ok", "degraded", "unreliable", "rejected")
        )
        bad = lost != 0 or hit < MIN_DEADLINE_HIT
        status = "FAIL" if bad else "ok"
        print(
            f"serve gate [{status}]: {row}: {terminal} terminal frames, "
            f"{lost} lost (limit 0), deadline-hit {hit:.3f} "
            f"(floor {MIN_DEADLINE_HIT})"
        )
        if bad:
            failed.append(row)
    if failed:
        raise SystemExit(
            f"serve tier broke its invariants (lost frames or deadline-hit "
            f"< {MIN_DEADLINE_HIT}) for {failed} in {path}"
        )


def check_drift(data: dict, path: str) -> None:
    """Gate the calibrate-back rows: closed loop wins, hot-swap loses nothing.

    Every ``drift_<scenario>`` row races a frozen-plan driver against a
    periodically recalibrated one over the same aging schedule
    (``bench_drift``); at the final drift cycle the recalibrated arm's MAP
    flip-rate against the clean oracle must not exceed the open-loop arm's
    beyond the sampling floor (``flip_closed <= flip_open +
    DRIFT_FLIP_TOL``), and when the full 7-scenario set is
    present the closed loop must win *strictly* on >= ``MIN_DRIFT_WINS`` of
    them -- partial (quick-mode) sets skip the flip gates entirely, since a
    2-scenario quick race at half-width launches is statistically
    underpowered and would gate sampling luck, not the loop.  The
    ``drift_hotswap`` row has NO quick-mode exemption: ``swap_net`` under
    in-flight launches must lose zero frames and harvest the pre-swap
    launches bit-identically to a never-swapped twin, both pure ordering
    invariants of the driver, so any violation is a bug at any size.
    """
    scen = sorted(
        k for k in data
        if k.startswith("drift_") and k not in ("drift_hotswap",
                                                "drift_calibration")
    )
    if not scen and "drift_hotswap" not in data:
        print("drift gate: no drift rows, skipping")
        return
    failed = []
    full_set = len(scen) >= 7
    wins = 0
    for row in scen:
        r = data[row]
        fo, fc = float(r["flip_open"]), float(r["flip_closed"])
        wins += int(fc < fo)
        bad = full_set and fc > fo + DRIFT_FLIP_TOL
        status = "FAIL" if bad else "ok"
        gate = "" if full_set else " (partial set, not gated)"
        print(
            f"drift gate [{status}]: {row}: flip closed {fc:.4f} vs open "
            f"{fo:.4f} at cycle {r.get('final_cycle', '?')}{gate}"
        )
        if bad:
            failed.append(row)
    if full_set:
        bad = wins < MIN_DRIFT_WINS
        status = "FAIL" if bad else "ok"
        print(
            f"drift gate [{status}]: closed loop strictly wins {wins}/"
            f"{len(scen)} scenarios (floor {MIN_DRIFT_WINS})"
        )
        if bad:
            failed.append("strict_wins")
    hs = data.get("drift_hotswap")
    if hs is not None:
        lost = int(hs["lost_frames"])
        preserved = int(hs["swap_preserved"])
        bad = lost != 0 or preserved != 1
        status = "FAIL" if bad else "ok"
        print(
            f"drift gate [{status}]: drift_hotswap: {lost} lost frames "
            f"(limit 0), pre-swap bit-identical {bool(preserved)}"
        )
        if bad:
            failed.append("drift_hotswap")
    if failed:
        raise SystemExit(
            f"calibrate-back loop broke its invariants (open-loop flip beat "
            f"recalibration, or hot-swap lost/perturbed frames) for {failed} "
            f"in {path}"
        )


def check(path: str, baseline: str | None = None) -> None:
    data = _load(path)
    check_indep_ratio(data, path)
    check_decide_overhead(data, path)
    check_nominal_flip(data, path)
    check_retry(data, path)
    check_latency_budget(data, path)
    check_serve(data, path)
    check_drift(data, path)
    check_regression(data, path, baseline)


if __name__ == "__main__":
    if len(sys.argv) not in (2, 3):
        raise SystemExit("usage: check_bench.py BENCH_<timestamp>.json [baseline.json]")
    check(sys.argv[1], sys.argv[2] if len(sys.argv) == 3 else None)
