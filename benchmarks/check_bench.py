"""Bench-smoke regression gates over a freshly written ``BENCH_*.json``.

The first gate pins the independent-entropy cliff: per-frame joint samples
(the production mode, what the physical memristor array provides for free)
must stay within ``MAX_INDEP_RATIO`` of the shared-entropy launch for the
8-node pedestrian-night network.  The committed trajectory once showed ~70x
here; the fused ``net_sweep`` lowering holds it to low single digits, and this
gate keeps the cliff from silently regressing.

Usage: ``python benchmarks/check_bench.py BENCH_<ts>.json`` (CI runs it right
after the bench-smoke step writes the snapshot), or call :func:`check` with
the path from the same process.
"""

from __future__ import annotations

import json
import sys

MAX_INDEP_RATIO = 8.0
_SHARED = "bayesnet_pedestrian-night_batch1024"
_INDEP = "bayesnet_pedestrian-night_indep_batch1024"


def check(path: str) -> None:
    with open(path) as f:
        data = json.load(f)
    missing = [k for k in (_SHARED, _INDEP) if k not in data]
    if missing:
        raise SystemExit(f"{path}: missing bench rows {missing}")
    shared_us = float(data[_SHARED]["us_per_call"])
    indep_us = float(data[_INDEP]["us_per_call"])
    ratio = indep_us / shared_us
    print(
        f"independent-entropy gate: {indep_us:,.0f} us vs {shared_us:,.0f} us "
        f"shared -> ratio {ratio:.2f}x (limit {MAX_INDEP_RATIO:.0f}x)"
    )
    if ratio > MAX_INDEP_RATIO:
        raise SystemExit(
            f"independent-entropy cliff regressed: indep/shared ratio "
            f"{ratio:.2f}x exceeds {MAX_INDEP_RATIO:.0f}x "
            f"({_INDEP} vs {_SHARED} in {path})"
        )


if __name__ == "__main__":
    if len(sys.argv) != 2:
        raise SystemExit("usage: check_bench.py BENCH_<timestamp>.json")
    check(sys.argv[1])
