"""Sharded, async, atomic checkpointing with elastic (reshard-on-load) restore.

Layout:  <dir>/step_<N>/shard_<host>.npz  +  manifest.json (written LAST -- its
presence marks the checkpoint committed; partial checkpoints are ignored and
garbage-collected).  Arrays are stored whole per host here (single-host
container); the manifest records the logical shapes/dtypes + mesh metadata so a
restore may target a different mesh/topology (elastic scaling): loaded arrays
are re-placed with the *new* mesh's shardings by ``jax.device_put``.

Async: ``save()`` snapshots to host memory synchronously (cheap) and writes to
disk on a background thread, overlapping I/O with the next training steps --
the standard large-run pattern.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional, Tuple

import jax
import numpy as np

_SEP = "/"


def _flatten(tree) -> dict:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":   # npz cannot round-trip ml_dtypes
            arr = arr.astype(np.float32)
        out[key] = arr
    return out


def _unflatten_like(template, arrays: dict):
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"checkpoint leaf {key} shape {arr.shape} != expected {leaf.shape}"
            )
        if hasattr(leaf, "dtype") and arr.dtype != leaf.dtype:
            arr = arr.astype(leaf.dtype)   # restore bf16 etc. from fp32 storage
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, *, blocking: bool = False, extra: dict | None = None):
        """Snapshot ``tree`` at ``step``; disk write happens asynchronously."""
        self.wait()  # one outstanding async save at a time
        host_arrays = _flatten(tree)          # device->host copy (synchronous)
        meta = {
            "step": step,
            "time": time.time(),
            "leaves": {k: [list(v.shape), str(v.dtype)] for k, v in host_arrays.items()},
            "extra": extra or {},
        }

        def write():
            tmp = os.path.join(self.directory, f"_tmp_step_{step}")
            final = os.path.join(self.directory, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "shard_0.npz"), **host_arrays)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)             # atomic commit
            self._gc()

        if blocking:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.available_steps())
        for s in steps[: -self.keep] if len(steps) > self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"), ignore_errors=True)
        # remove aborted partials
        for name in os.listdir(self.directory):
            if name.startswith("_tmp_step_"):
                shutil.rmtree(os.path.join(self.directory, name), ignore_errors=True)

    # --------------------------------------------------------------- restore
    def available_steps(self):
        steps = []
        if not os.path.isdir(self.directory):
            return steps
        for name in os.listdir(self.directory):
            if name.startswith("step_"):
                manifest = os.path.join(self.directory, name, "manifest.json")
                if os.path.exists(manifest):   # committed only
                    steps.append(int(name.split("_")[1]))
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.available_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: int | None = None, shardings: Any = None) -> Tuple[int, Any]:
        """Load ``step`` (default latest) into the structure of ``template``.

        ``shardings``: optional pytree of NamedShardings for the *current* mesh
        -- elastic restore re-places each array accordingly.
        """
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints in {self.directory}")
        path = os.path.join(self.directory, f"step_{step}")
        with np.load(os.path.join(path, "shard_0.npz")) as z:
            arrays = {k: z[k] for k in z.files}
        tree = _unflatten_like(template, arrays)
        if shardings is not None:
            tree = jax.tree.map(
                lambda x, s: jax.device_put(x, s), tree, shardings
            )
        return step, tree
