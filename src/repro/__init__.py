"""repro: memristor-inspired stochastic-computing Bayesian decision framework on JAX.

Reproduction of Song et al., "Hardware implementation of timely reliable Bayesian
decision-making using memristors" (Adv. Electron. Mater. 2024), adapted to TPU as a
multi-pod JAX framework. See DESIGN.md.
"""

__version__ = "1.0.0"
