"""deepseek-v3-671b [moe]: 61L d_model=7168 128H d_ff(expert)=2048 vocab=129280.

MLA (q_lora 1536, kv_lora 512, nope 128, rope 64, v 128); MoE 256 routed
experts top-8 + 1 shared, sigmoid router; first 3 layers dense (d_ff 18432);
multi-token prediction head.  [arXiv:2412.19437; hf]
"""

from repro.configs.base import ModelConfig, MoEConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b",
        family="moe",
        num_layers=61,
        d_model=7168,
        num_heads=128,
        num_kv_heads=128,
        head_dim=128,
        d_ff=18432,              # dense-layer FF (used by prefix layers)
        vocab_size=129_280,
        pattern=("mla",),
        prefix_kinds=("attn_dense_prefix",) * 3,
        dense_d_ff=18432,
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_dim=128,
        qk_rope_dim=64,
        v_head_dim=128,
        rope_theta=10_000.0,
        norm="rmsnorm",
        mlp="swiglu",
        moe=MoEConfig(
            num_experts=256,
            top_k=8,
            d_ff_expert=2048,
            num_shared=1,
            capacity_factor=1.25,
            router="sigmoid",
        ),
        mtp_heads=1,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-smoke",
        family="moe",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=192,
        vocab_size=512,
        pattern=("mla",),
        prefix_kinds=("attn_dense_prefix",),
        dense_d_ff=192,
        q_lora_rank=32,
        kv_lora_rank=16,
        qk_nope_dim=16,
        qk_rope_dim=8,
        v_head_dim=16,
        norm="rmsnorm",
        mlp="swiglu",
        moe=MoEConfig(
            num_experts=4, top_k=2, d_ff_expert=64, num_shared=1,
            capacity_factor=1.5, router="sigmoid", impl="masked",
        ),
        mtp_heads=1,
    )
