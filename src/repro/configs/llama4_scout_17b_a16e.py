"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1 + 1 shared expert.

iRoPE layout: chunked local attention (8192) on 3 of 4 layers, RoPE-free global
attention every 4th.  Mostly-local -> runs long_500k (see DESIGN.md).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
"""

from repro.configs.base import ModelConfig, MoEConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-scout-17b-a16e",
        family="moe",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=202_048,
        pattern=("attn_chunk", "attn_chunk", "attn_chunk", "attn_global"),
        chunk=8192,
        rope_theta=500_000.0,
        norm="rmsnorm",
        mlp="swiglu",
        moe=MoEConfig(
            num_experts=16,
            top_k=1,
            d_ff_expert=8192,
            num_shared=1,
            capacity_factor=1.25,
            router="softmax",
        ),
        subquadratic=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama4-smoke",
        family="moe",
        num_layers=4,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=96,
        vocab_size=512,
        pattern=("attn_chunk", "attn_chunk", "attn_chunk", "attn_global"),
        chunk=16,
        norm="rmsnorm",
        mlp="swiglu",
        moe=MoEConfig(
            num_experts=4, top_k=1, d_ff_expert=96, num_shared=1,
            capacity_factor=1.5, router="softmax", impl="masked",
        ),
        subquadratic=True,
    )
