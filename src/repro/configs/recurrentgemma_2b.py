"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (MQA kv=1) d_ff=7680.

RG-LRU + local attention at 1:2 ratio (pattern rec,rec,attn_local), GeGLU MLP,
window 2048, vocab 256000.  Sub-quadratic: runs long_500k.
[arXiv:2402.19427; hf]
"""

from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        num_layers=26,
        d_model=2560,
        num_heads=10,
        num_kv_heads=1,
        head_dim=256,
        d_ff=7680,
        vocab_size=256_000,
        pattern=("rec", "rec", "attn_local"),
        prefix_kinds=("rec", "rec"),       # 26 = 2 + 8 * 3
        window=2048,
        lru_width=2560,
        conv_width=4,
        rope_theta=10_000.0,
        norm="rmsnorm",
        mlp="geglu",
        subquadratic=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-smoke",
        family="hybrid",
        num_layers=5,
        d_model=64,
        num_heads=2,
        num_kv_heads=1,
        head_dim=32,
        d_ff=128,
        vocab_size=512,
        pattern=("rec", "rec", "attn_local"),
        prefix_kinds=("rec", "rec"),
        window=16,
        lru_width=64,
        conv_width=4,
        norm="rmsnorm",
        mlp="geglu",
        subquadratic=True,
    )
