"""starcoder2-15b [dense]: 40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152.

GQA, RoPE, LayerNorm, gelu MLP, attention bias.  [arXiv:2402.19173; hf]
"""

from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b",
        family="dense",
        num_layers=40,
        d_model=6144,
        num_heads=48,
        num_kv_heads=4,
        head_dim=128,
        d_ff=24576,
        vocab_size=49152,
        qkv_bias=True,
        rope_theta=100_000.0,
        norm="layernorm",
        mlp="gelu",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=256,
        vocab_size=512,
        qkv_bias=True,
        norm="layernorm",
        mlp="gelu",
    )
