"""minitron-4b [dense]: 32L d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000.

Pruned Nemotron: squared-ReLU MLP, LayerNorm, RoPE.  [arXiv:2407.14679; hf]
"""

from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="minitron-4b",
        family="dense",
        num_layers=32,
        d_model=3072,
        num_heads=24,
        num_kv_heads=8,
        head_dim=128,
        d_ff=9216,
        vocab_size=256_000,
        rope_theta=10_000.0,
        norm="layernorm",
        mlp="relu2",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="minitron-smoke",
        family="dense",
        num_layers=2,
        d_model=48,
        num_heads=3,
        num_kv_heads=1,
        head_dim=16,
        d_ff=144,
        vocab_size=512,
        norm="layernorm",
        mlp="relu2",
    )
