"""Architecture registry: ``--arch <id>`` resolution."""

from __future__ import annotations

import importlib

ARCH_IDS = (
    "qwen2-72b",
    "starcoder2-15b",
    "minitron-4b",
    "phi3-mini-3.8b",
    "internvl2-26b",
    "recurrentgemma-2b",
    "xlstm-350m",
    "llama4-scout-17b-a16e",
    "deepseek-v3-671b",
    "seamless-m4t-large-v2",
    "paper-bayes-fusion",      # the paper's own workload as a config
)

_MODULES = {
    "qwen2-72b": "qwen2_72b",
    "starcoder2-15b": "starcoder2_15b",
    "minitron-4b": "minitron_4b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "internvl2-26b": "internvl2_26b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "xlstm-350m": "xlstm_350m",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "paper-bayes-fusion": "paper_bayes",
}


def _mod(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str):
    return _mod(arch).full_config()


def get_smoke_config(arch: str):
    return _mod(arch).smoke_config()
