"""Model / run configuration dataclasses.

One ``ModelConfig`` describes any of the assigned architectures; the per-arch
files in this package instantiate it with the exact published numbers and a
reduced ``smoke`` variant for CPU tests.  Layer stacking is expressed as a
repeating ``pattern`` of block kinds (scanned as super-blocks to keep HLO small)
plus optional unscanned ``prefix_kinds`` (e.g. deepseek's first-3 dense layers).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

# Block kinds understood by models/transformer.py
#   attn        -- full causal self-attention + MLP
#   attn_local  -- sliding-window self-attention + MLP
#   attn_chunk  -- chunked local attention + MLP (llama4 iRoPE local layers)
#   attn_global -- full attention without RoPE (llama4 iRoPE global layers)
#   mla         -- DeepSeek multi-head latent attention + (dense|moe) MLP
#   rec         -- RG-LRU recurrence block + MLP (recurrentgemma)
#   mlstm       -- xLSTM matrix-memory block
#   slstm       -- xLSTM scalar-memory block
#   enc / dec   -- encoder / decoder (cross-attention) blocks for enc-dec models


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 1
    d_ff_expert: int = 0
    num_shared: int = 0          # shared (always-on) experts
    capacity_factor: float = 1.25
    router: str = "softmax"      # "softmax" | "sigmoid" (deepseek-v3)
    impl: str = "masked"         # "masked" (EP via sharded einsum) | "dense"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads
    pattern: Tuple[str, ...] = ("attn",)
    prefix_kinds: Tuple[str, ...] = ()   # unscanned leading layers
    # attention details
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    window: int = 0              # sliding window for attn_local
    chunk: int = 0               # chunk size for attn_chunk
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    mlp: str = "swiglu"          # swiglu | geglu | gelu | relu2 | none
    # MLA (deepseek)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # MoE
    moe: Optional[MoEConfig] = None
    dense_d_ff: int = 0          # d_ff of dense prefix layers (deepseek)
    # recurrent (RG-LRU)
    conv_width: int = 4
    lru_width: int = 0           # 0 -> d_model
    # enc-dec
    enc_layers: int = 0
    dec_layers: int = 0
    enc_ratio: int = 4           # encoder frames = seq_len // enc_ratio
    # multimodal stub frontend: number of precomputed embedding positions that
    # input_specs() provides (vlm patches / audio frames); 0 = text-only.
    frontend: str = "none"       # none | patch | frame
    # extras
    mtp_heads: int = 0           # deepseek multi-token prediction depth
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # long-context policy: can this arch serve 500k-token decode?
    subquadratic: bool = False
    # execution knobs (threaded through by launchers; not architecture identity)
    q_chunk: int = 512           # query-chunked attention block (score memory)
    mlstm_chunk: int = 256       # mLSTM chunkwise-parallel block
    unroll_layers: bool = False  # python-loop layers instead of lax.scan
                                 # (dry-run flops/collective calibration only)
    seq_shard: bool = True       # SP: shard the residual stream's seq dim over
                                 # `model` at scan boundaries (activation memory)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    def param_count(self) -> float:
        """Approximate total parameter count (embeddings + blocks), for 6ND."""
        d, hd = self.d_model, self.resolved_head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)

        def mlp_params(ff: int, kind: str) -> int:
            if ff == 0 or kind == "none":
                return 0
            return d * ff * (3 if kind in ("swiglu", "geglu") else 2)

        def attn_params() -> int:
            q = d * self.num_heads * hd
            kv = 2 * d * self.num_kv_heads * hd
            o = self.num_heads * hd * d
            return q + kv + o

        def mla_params() -> int:
            ql, kvl = self.q_lora_rank, self.kv_lora_rank
            qdim = self.qk_nope_dim + self.qk_rope_dim
            return (
                d * ql
                + ql * self.num_heads * qdim
                + d * (kvl + self.qk_rope_dim)
                + kvl * self.num_heads * (self.qk_nope_dim + self.v_head_dim)
                + self.num_heads * self.v_head_dim * d
            )

        def block_params(kind: str) -> int:
            if kind in ("attn", "attn_local", "attn_chunk", "attn_global", "enc"):
                base = attn_params() + mlp_params(self.d_ff, self.mlp)
            elif kind == "dec":
                base = 2 * attn_params() + mlp_params(self.d_ff, self.mlp)
            elif kind == "mla":
                base = mla_params()
            elif kind == "rec":
                w = self.lru_width or d
                base = 2 * d * w + 2 * w * w // 1 + self.conv_width * w + mlp_params(self.d_ff, self.mlp)
            elif kind == "mlstm":
                base = 2 * d * 2 * d + 3 * d * (2 * d) // 1  # qkv+gates on 2d inner
            elif kind == "slstm":
                base = 4 * d * d + mlp_params(int(d * 8 // 3), "swiglu")
            else:
                base = 0
            if kind in ("attn", "mla") and self.moe is not None:
                e = self.moe
                base += d * e.num_experts * e.d_ff_expert * 3 // 1 * 0  # counted below
                base += (e.num_experts + e.num_shared) * mlp_params(e.d_ff_expert, self.mlp)
                base += d * e.num_experts  # router
            return base

        if self.family == "audio":
            total = emb
            total += self.enc_layers * block_params("enc")
            total += self.dec_layers * block_params("dec")
            return float(total)
        total = emb
        n_pattern = self.num_layers - len(self.prefix_kinds)
        reps = n_pattern // len(self.pattern)
        for k in self.prefix_kinds:
            if k == "attn_dense_prefix":  # deepseek dense prefix
                total += mla_params() + mlp_params(self.dense_d_ff, self.mlp)
            else:
                total += block_params(k)
        for k in self.pattern:
            total += reps * block_params(k)
        return float(total)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell of the assignment."""

    name: str                    # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}
