"""internvl2-26b [vlm]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553.

InternViT + InternLM2 backbone; per the assignment the vision frontend is a
STUB -- ``input_specs()`` provides precomputed patch embeddings that are
prepended to the text sequence.  [arXiv:2404.16821; hf]
"""

from repro.configs.base import ModelConfig

NUM_PATCH_EMBEDS = 256  # pixel-shuffled visual tokens per image (stub frontend)


def full_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b",
        family="vlm",
        num_layers=48,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=92_553,
        rope_theta=1_000_000.0,
        norm="rmsnorm",
        mlp="swiglu",
        frontend="patch",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-smoke",
        family="vlm",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        norm="rmsnorm",
        mlp="swiglu",
        frontend="patch",
    )
