from repro.configs.base import SHAPES, SHAPES_BY_NAME, ModelConfig, MoEConfig, ShapeConfig  # noqa: F401
from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config  # noqa: F401
