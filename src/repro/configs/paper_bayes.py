"""paper-bayes-fusion: the paper's own workload as a selectable config.

Large-scale RGB+thermal Bayesian fusion over per-pixel class-probability maps
(the Movie-S1 simulation): M modalities x K classes x HxW pixels per frame,
through the stochastic (SNE + AND + popcount) or analytic (eq 5) path.
This is not an LM; it has its own input_specs / step functions in
repro.launch.dryrun and its own roofline entry.
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class BayesFusionConfig:
    name: str = "paper-bayes-fusion"
    family: str = "bayes"
    modalities: int = 2
    classes: int = 16
    height: int = 1080
    width: int = 1920
    n_bits: int = 128           # stochastic-number length (paper: 100, padded to
                                # whole uint32 words for the packed TPU path)
    frames_per_batch: int = 8


def full_config() -> BayesFusionConfig:
    return BayesFusionConfig()


def smoke_config() -> BayesFusionConfig:
    return BayesFusionConfig(
        name="paper-bayes-smoke", height=32, width=32, classes=4, n_bits=64,
        frames_per_batch=2,
    )
