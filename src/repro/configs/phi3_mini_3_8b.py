"""phi3-mini-3.8b [dense]: 32L d_model=3072 32H (GQA kv=32) d_ff=8192 vocab=32064.

RoPE, SwiGLU, full MHA (kv=32), RMSNorm.  [arXiv:2404.14219; unverified]
"""

from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="phi3-mini-3.8b",
        family="dense",
        num_layers=32,
        d_model=3072,
        num_heads=32,
        num_kv_heads=32,
        head_dim=96,
        d_ff=8192,
        vocab_size=32_064,
        rope_theta=10_000.0,
        norm="rmsnorm",
        mlp="swiglu",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="phi3-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        norm="rmsnorm",
        mlp="swiglu",
    )
