"""seamless-m4t-large-v2 [audio]: enc-dec 24L+24L d_model=1024 16H d_ff=8192
vocab=256206.

The audio frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings (seq_len // enc_ratio frames) for the encoder;
the decoder is autoregressive text.  [arXiv:2308.11596; hf]
"""

from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2",
        family="audio",
        num_layers=48,
        enc_layers=24,
        dec_layers=24,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        head_dim=64,
        d_ff=8192,
        vocab_size=256_206,
        enc_ratio=4,
        rope_theta=10_000.0,
        norm="layernorm",
        mlp="gelu",
        frontend="frame",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="seamless-smoke",
        family="audio",
        num_layers=4,
        enc_layers=2,
        dec_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        enc_ratio=4,
        norm="layernorm",
        mlp="gelu",
        frontend="frame",
    )
