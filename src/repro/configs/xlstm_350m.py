"""xlstm-350m [ssm]: 24L d_model=1024 4H vocab=50304; sLSTM + mLSTM blocks.

Pattern: 3 mLSTM blocks then 1 sLSTM block (xLSTM[3:1] flavour).  d_ff=0 --
blocks carry their own inner projections.  Sub-quadratic: runs long_500k.
[arXiv:2405.04517; unverified]
"""

from repro.configs.base import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m",
        family="ssm",
        num_layers=24,
        d_model=1024,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=50_304,
        pattern=("mlstm", "mlstm", "mlstm", "slstm"),
        norm="rmsnorm",
        mlp="none",
        subquadratic=True,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-smoke",
        family="ssm",
        num_layers=4,
        d_model=64,
        num_heads=2,
        num_kv_heads=2,
        d_ff=0,
        vocab_size=512,
        pattern=("mlstm", "mlstm", "mlstm", "slstm"),
        norm="rmsnorm",
        mlp="none",
        subquadratic=True,
    )
