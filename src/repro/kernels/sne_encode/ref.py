"""Pure-jnp oracle for the SNE encode kernel.

Semantics (shared with the kernel, bit-exact): probabilities are quantised to
8 bits (the V_in programming DAC of the hardware SNE), each uint32 random word
contributes its 4 bytes as 4 independent uniform(0..255) draws, and a stream bit
is 1 iff ``byte < round(p * 256)``.  Output is packed LSB-first, 32 stream bits
per word; ``n_bits = 4 * n_rand_words = 32 * n_out_words``.
"""

from __future__ import annotations

import jax.numpy as jnp


def quantise_p(p: jnp.ndarray) -> jnp.ndarray:
    """Probability -> 8-bit threshold in [0, 256] (uint32 for comparisons)."""
    return jnp.clip(jnp.round(p * 256.0), 0.0, 256.0).astype(jnp.uint32)


def sne_encode_ref(p: jnp.ndarray, rand_words: jnp.ndarray) -> jnp.ndarray:
    """Encode probabilities into packed stochastic numbers.

    p:          (..., R) float32 target probabilities.
    rand_words: (..., R, n_rand) uint32 entropy; n_rand must be divisible by 8.
    returns:    (..., R, n_rand // 8) uint32 packed streams (n_bits = 4 * n_rand).
    """
    n_rand = rand_words.shape[-1]
    assert n_rand % 8 == 0, "n_rand must be a multiple of 8 (32 bits per out word)"
    thresh = quantise_p(p)[..., None, None]                       # (..., R, 1, 1)
    shifts = jnp.arange(4, dtype=jnp.uint32) * 8
    bytes_ = (rand_words[..., None] >> shifts) & jnp.uint32(0xFF)  # (..., n_rand, 4)
    bits = (bytes_ < thresh).astype(jnp.uint32)                    # (..., n_rand, 4)
    flat = bits.reshape(bits.shape[:-2] + (n_rand * 4,))           # n_bits
    grouped = flat.reshape(flat.shape[:-1] + (n_rand // 8, 32))
    pack_shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(grouped << pack_shifts, axis=-1).astype(jnp.uint32)
