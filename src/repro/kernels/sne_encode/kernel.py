"""Pallas TPU kernel: stochastic number encoder (threshold + bit-plane pack).

Maps the paper's SNE (memristor + comparator, Fig 2a) onto the VPU: for a block
of streams the kernel compares pre-drawn random bytes against the 8-bit
programmed threshold and packs 32 stream bits per uint32 lane word, entirely in
VMEM.  The byte comparison is the comparator; the 8-bit threshold is the V_in
programming DAC (DESIGN.md SS2).

Tiling: grid over stream rows.  Block shapes keep the trailing (lane) dimension a
multiple of 128 where shapes allow, and the whole working set
(block_r x (n_rand + n_out) words) well inside the ~16 MB v5e VMEM budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sne_kernel(p_ref, rand_ref, out_ref):
    p = p_ref[...]                       # (bR,) f32
    rand = rand_ref[...]                 # (bR, n_rand) u32
    thresh = jnp.clip(jnp.round(p * 256.0), 0.0, 256.0).astype(jnp.uint32)
    n_rand = rand.shape[-1]
    # 4 uniform bytes per random word.
    acc = jnp.zeros(rand.shape[:-1] + (n_rand // 8,), jnp.uint32)
    for byte in range(4):
        lane = (rand >> jnp.uint32(8 * byte)) & jnp.uint32(0xFF)   # (bR, n_rand)
        bits = (lane < thresh[..., None]).astype(jnp.uint32)
        # bit j of output word w is stream bit (32w + j); stream bit index of
        # (rand word r, byte b) is 4r + b -> out word w = r // 8,
        # out bit j = 4 * (r % 8) + b.
        grouped = bits.reshape(bits.shape[:-1] + (n_rand // 8, 8))
        shifts = (jnp.arange(8, dtype=jnp.uint32) * 4 + byte).astype(jnp.uint32)
        acc = acc + jnp.sum(grouped << shifts, axis=-1, dtype=jnp.uint32)
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def sne_encode_pallas(
    p: jnp.ndarray,
    rand_words: jnp.ndarray,
    *,
    block_r: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """p: (R,) f32; rand_words: (R, n_rand) u32 -> (R, n_rand // 8) u32 packed."""
    r, n_rand = rand_words.shape
    assert n_rand % 8 == 0
    n_out = n_rand // 8
    block_r = min(block_r, r)
    assert r % block_r == 0, f"rows {r} not divisible by block {block_r}"
    grid = (r // block_r,)
    return pl.pallas_call(
        _sne_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r,), lambda i: (i,)),
            pl.BlockSpec((block_r, n_rand), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, n_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, n_out), jnp.uint32),
        interpret=interpret,
    )(p, rand_words)
