"""jit'd public wrapper for the SNE encode kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.sne_encode.kernel import sne_encode_pallas
from repro.kernels.sne_encode.ref import sne_encode_ref


@functools.partial(jax.jit, static_argnames=("n_bits", "use_kernel", "interpret"))
def sne_encode(
    key: jax.Array,
    p: jnp.ndarray,
    n_bits: int = 128,
    *,
    use_kernel: bool = True,
    interpret: bool = True,
) -> jnp.ndarray:
    """Encode probabilities ``p`` (any shape) into packed stochastic numbers.

    n_bits must be a multiple of 32.  Returns ``p.shape + (n_bits // 32,)`` uint32.
    Entropy is drawn from the counter-based PRNG (the TPU stand-in for the
    memristor's stochastic V_th; see DESIGN.md SS2) -- on real TPUs this becomes
    in-kernel ``pltpu.prng_random_bits`` with identical semantics.
    """
    assert n_bits % 32 == 0, "kernel path packs whole uint32 words"
    p = jnp.asarray(p, jnp.float32)
    flat = p.reshape(-1)
    n_rand = n_bits // 4  # 4 bytes (stream bits) per random word
    rand = jax.random.bits(key, (flat.shape[0], n_rand), jnp.uint32)
    if use_kernel:
        rows = flat.shape[0]
        block = 256 if rows % 256 == 0 else (64 if rows % 64 == 0 else 1)
        out = sne_encode_pallas(flat, rand, block_r=block, interpret=interpret)
    else:
        out = sne_encode_ref(flat, rand)
    return out.reshape(p.shape + (n_bits // 32,))
