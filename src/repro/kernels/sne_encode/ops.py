"""jit'd public wrapper for the SNE encode kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import rng
from repro.kernels import backend
from repro.kernels.sne_encode.kernel import sne_encode_pallas
from repro.kernels.sne_encode.ref import sne_encode_ref


@functools.partial(jax.jit, static_argnames=("n_bits", "use_kernel", "interpret"))
def sne_encode(
    key: jax.Array,
    p: jnp.ndarray,
    n_bits: int = 128,
    *,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Encode probabilities ``p`` (any shape) into packed stochastic numbers.

    n_bits must be a multiple of 32.  Returns ``p.shape + (n_bits // 32,)`` uint32.
    Entropy is drawn from the counter-based PRNG (the TPU stand-in for the
    memristor's stochastic V_th; see DESIGN.md SS2) -- on real TPUs this becomes
    in-kernel ``pltpu.prng_random_bits`` with identical semantics.
    ``interpret=None`` auto-detects the backend (compiled on TPU/GPU,
    interpreter only as CPU fallback).
    """
    assert n_bits % 32 == 0, "kernel path packs whole uint32 words"
    interpret = backend.resolve_interpret(interpret)
    use_kernel = backend.resolve_use_kernel(use_kernel, interpret)
    p = jnp.asarray(p, jnp.float32)
    flat = p.reshape(-1)
    n_rand = n_bits // 4  # 4 bytes (stream bits) per random word
    rand = rng.counter_hash_words(key, (flat.shape[0],), n_rand)
    if use_kernel:
        block = backend.pick_block(flat.shape[0], 256)
        out = sne_encode_pallas(flat, rand, block_r=block, interpret=interpret)
    else:
        out = sne_encode_ref(flat, rand)
    return out.reshape(p.shape + (n_bits // 32,))
