from repro.kernels.sne_encode.ops import sne_encode  # noqa: F401
from repro.kernels.sne_encode.ref import sne_encode_ref  # noqa: F401
