"""jnp reference for the fused whole-network sweep (the CPU production path).

One call of the shared bit-sliced body over the full ``(B, W)`` array: every
node stream is generated in-register from counter bit-planes, conditioned, and
popcount-reduced in a single XLA fusion -- no per-node stream, no entropy
word, and no intermediate sample ever reaches HBM.  The Pallas kernel runs the
same body per tile, so the two are bit-identical.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import bitops
from repro.kernels.net_sweep.common import SweepPlan, sweep_tile


def net_sweep_ref(
    kd: jnp.ndarray, ev: jnp.ndarray, plan: SweepPlan, n_bits: int
):
    """kd (2,) u32 seed words, ev (B, n_ev) int32
    -> (numer (B, n_value_slots) i32, denom (B,) i32)."""
    b = ev.shape[0]
    w = bitops.n_words(n_bits)
    return sweep_tile(plan, kd[0], kd[1], ev, 0, 0, b, w, w, b)
