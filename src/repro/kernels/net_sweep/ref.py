"""jnp reference for the fused whole-network sweep (the CPU production path).

One call of the shared bit-sliced body over the full ``(B, W)`` array: every
node stream is generated in-register from counter bit-planes, conditioned, and
popcount-reduced in a single XLA fusion -- no per-node stream, no entropy
word, and no intermediate sample ever reaches HBM.  The Pallas kernel runs the
same body per tile, so the two are bit-identical.

``frame0`` / ``total_frames`` place this call inside a larger logical launch:
a shard of a ``shard_map`` sweep passes its global frame origin and the global
frame count, and -- because the entropy counter is a pure function of the
*global* (node, frame, word) index -- produces exactly the words the
single-device sweep would for its slice.  ``decide=True`` appends the
:func:`~repro.kernels.net_sweep.common.decide_counts` epilogue inside the same
fusion (the counts never leave registers before the argmax).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import bitops
from repro.kernels.net_sweep.common import SweepPlan, sweep_tile


def net_sweep_ref(
    kd: jnp.ndarray,
    ev: jnp.ndarray,
    plan: SweepPlan,
    n_bits: int,
    frame0=0,
    total_frames: int | None = None,
    decide: bool = False,
):
    """kd (2,) u32 seed words, ev (B, n_ev) int32
    -> (numer (B, n_value_slots) i32, denom (B,) i32[, decisions (B, n_q) i32]).
    """
    b = ev.shape[0]
    w = bitops.n_words(n_bits)
    total = b if total_frames is None else total_frames
    return sweep_tile(plan, kd[0], kd[1], ev, frame0, 0, b, w, w, total,
                      decide=decide)
