from repro.kernels.net_sweep.common import (  # noqa: F401
    SweepPlan,
    decide_counts,
    epoch_word_bounds,
)
from repro.kernels.net_sweep.ops import net_sweep  # noqa: F401
from repro.kernels.net_sweep.ref import net_sweep_ref  # noqa: F401
