"""jit'd public wrapper for the fused whole-network sweep.

``net_sweep`` lowers an entire compiled Bayesian network -- every node's
threshold-gather sample, the evidence-indicator AND, and the CORDIV popcount
fixed point -- into one backend-dispatched launch.  Entropy is generated
in-register from counter bit-planes (``rng.plane_base`` / ``rng.plane_word``),
so the ``share_entropy=False`` production mode stops writing
``B x nodes x 2**m x n_rand`` words to HBM per launch: nothing but the
evidence frames goes in and nothing but the per-frame counts comes out.

Two optional extensions make the launch span further:

* ``frame0`` / ``total_frames`` place the call inside a larger logical batch.
  The entropy counter is a pure function of the global (node, frame, word)
  index, so a shard that passes its global frame origin and the global frame
  count produces bit-identical words to the single-device sweep over its
  slice -- this is what ``compile_network(devices=...)`` wraps in
  ``shard_map``.
* ``decide=True`` appends the decision epilogue: per-query count vectors
  argmaxed in-register (``common.decide_counts``), returning
  ``(numer, denom, decisions)`` so the sense->classify->act path is one
  launch with no posterior re-encode.

Dispatch follows the other kernel ops: Pallas kernel where it compiles,
bit-exact jnp reference (the same ``sweep_tile`` body over the whole array) as
the CPU production fallback.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import rng
from repro.kernels import backend
from repro.kernels.net_sweep.common import SweepPlan
from repro.kernels.net_sweep.kernel import net_sweep_pallas
from repro.kernels.net_sweep.ref import net_sweep_ref


@functools.partial(
    jax.jit,
    static_argnames=(
        "plan", "n_bits", "total_frames", "decide", "use_kernel", "interpret",
    ),
)
def net_sweep(
    key: jax.Array,
    ev_frames: jnp.ndarray,
    *,
    plan: SweepPlan,
    n_bits: int = 4096,
    frame0=0,
    total_frames: int | None = None,
    decide: bool = False,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
):
    """Run the fused sweep: per-frame independent joint samples, conditioned.

    ev_frames: (B, n_ev) int32 evidence values (one integer in ``[0, card)``
    per evidence node), columns in ``plan.evidence`` order.  Returns
    ``(numer (B, n_value_slots) int32, denom (B,) int32)``: one CORDIV ratio
    numerator popcount per query *value* (queries in plan order, values
    ``1 .. card-1`` within a query; the value-0 count is ``denom`` minus the
    query's slots) and the accepted-bit count per frame
    (``posterior ~ numer / denom``, noise ``~ sqrt(p (1-p) / denom)``).  For
    an all-binary plan this is exactly the old one-column-per-query layout.
    With ``decide=True`` a third array ``(B, n_q) int32`` of per-query argmax
    values is appended -- bit-identical to argmaxing the posterior, computed
    from the same in-register counts.

    Every frame draws an independent joint sample (the frame index is folded
    into the entropy counters), which is what the physical memristor array
    provides for free -- the fused path makes it the cheap mode instead of a
    ``B x`` penalty.  ``frame0`` (int or traced uint32 scalar) and
    ``total_frames`` (static int) let a shard of a larger launch draw the
    global batch's entropy for its frame slice.
    """
    if n_bits % 32:
        raise ValueError("n_bits must be a multiple of 32 (packed words)")
    interpret = backend.resolve_interpret(interpret)
    use_kernel = backend.resolve_use_kernel(use_kernel, interpret)
    ev = jnp.asarray(ev_frames, jnp.int32)
    assert ev.ndim == 2 and ev.shape[1] == len(plan.evidence), (
        ev.shape, plan.evidence,
    )
    kd = rng.seed_words(key)
    if use_kernel:
        # zero-width blocks are not representable; pad the (unused) ev input
        ev_k = ev if ev.shape[1] else jnp.zeros((ev.shape[0], 1), jnp.int32)
        block_f = backend.pick_block(ev.shape[0], 128)
        block_w = backend.pick_block(n_bits // 32, 256)
        return net_sweep_pallas(
            kd, ev_k, plan=plan, n_bits=n_bits,
            frame0=frame0, total_frames=total_frames, decide=decide,
            block_f=block_f, block_w=block_w, interpret=interpret,
        )
    return net_sweep_ref(kd, ev, plan, n_bits, frame0=frame0,
                         total_frames=total_frames, decide=decide)
