"""Shared bit-sliced sweep body for the fused whole-network op.

``SweepPlan`` is the static (hashable) description of one compiled network:
per-node parent indices, cardinality, and per-row 8-bit DAC **CDF thresholds**
in topological order, plus the evidence/query node sets.  ``sweep_tile`` runs
the full topological sweep for one ``(frames x words)`` tile and returns the
popcount partials -- it is the single source of truth for the fused semantics,
called on the whole array by the jnp reference and per-tile by the Pallas
kernel, which makes the two bit-identical by construction (the kernel tests
then pin the tiling and accumulation).

Node sampling is the categorical threshold-gather formulation in bit-sliced
form: entropy arrives as 8 *bit-planes* per output word (``rng.plane_base`` /
``rng.plane_word``) -- ONE byte per stream position regardless of cardinality.
A cardinality-``k`` node carries ``k-1`` non-increasing cumulative thresholds
per CPT row (``C_v`` encodes ``P(value >= v)``); each threshold's gathered
per-plane mask words (an OR of parent-digit indicator words for every CPT row
whose threshold has that bit set -- constant-folded at trace time) feed the
borrow-chain comparator, the ``k-1`` chains share the node's 8 entropy planes,
and the sampled value ``#{v : byte < C_v}`` is re-packed as ``value_bits(k)``
bit-planes.  Planes below the lowest set threshold bit of a node can never
flip any comparison and are skipped entirely.  Binary nodes (``k=2``) collapse
to exactly the single-chain lowering -- one threshold, one plane, bit-identical
streams.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitops, rng

# np scalar (not a committed jax array): Pallas kernels cannot close over
# device constants, and np scalars fold into jaxpr literals.
_FULL = np.uint32(0xFFFFFFFF)

# Trace-time sentinel: a threshold-bit mask that is all-ones across the tile
# (every CPT row has this bit set) -- lets the borrow chain drop the AND.
_ONES = object()


def _normalize_node(entry):
    """Accept the legacy ``(parents, scalar thresholds)`` node form.

    Pre-categorical plans carried one 8-bit threshold per CPT row (binary
    nodes only); they normalise to cardinality 2 with one-level CDF rows, so
    existing plan constructions keep working unchanged.
    """
    if len(entry) == 2:
        parents, thresh = entry
        return (tuple(parents), 2, tuple((int(t),) for t in thresh))
    parents, card, rows = entry
    return (tuple(parents), int(card), tuple(tuple(int(t) for t in r) for r in rows))


def _check_rows(i: int, card: int, n_expect: int, rows) -> None:
    """Shared CDF-row validation for base and epoch rows of one node."""
    if len(rows) != n_expect:
        raise ValueError(f"node {i}: needs {n_expect} CPT rows, got {len(rows)}")
    for row in rows:
        if len(row) != card - 1:
            raise ValueError(f"node {i}: CDF row {row} needs {card - 1} thresholds")
        prev = 256
        for t in row:
            if not 0 <= t <= 256:
                raise ValueError(f"node {i}: threshold {t} outside [0, 256]")
            if t > prev:
                raise ValueError(f"node {i}: CDF thresholds {row} not non-increasing")
            prev = t


def epoch_word_bounds(w_words: int, epochs: int) -> Tuple[int, ...]:
    """Word-index partition of a launch's bit-stream into drift epochs.

    ``epochs + 1`` non-decreasing bounds: epoch ``e`` owns words
    ``[bounds[e], bounds[e+1])``.  Maximally even split, earlier epochs take
    the remainder -- a pure function of ``(w_words, epochs)`` shared by the
    sweep lowering and the analytic oracle's mixture weights so both sides
    weight each epoch by exactly the bits it emits.
    """
    if epochs < 1:
        raise ValueError(f"epochs must be >= 1, got {epochs}")
    return tuple(round(e * w_words / epochs) for e in range(epochs + 1))


@dataclasses.dataclass(frozen=True)
class SweepPlan:
    """Static lowering of a k-ary DAG network for the fused sweep.

    nodes:    per node (in topological order) a triple ``(parents, card,
              rows)``: ``parents`` are indices of earlier nodes (first parent
              = most significant mixed-radix CPT row digit), ``card`` is the
              node's cardinality, and ``rows`` holds one ``(card - 1,)`` tuple
              of non-increasing cumulative 8-bit DAC thresholds in [0, 256]
              per parent assignment (``rng.cdf_thresholds_int``).  The legacy
              binary pair form ``(parents, thresholds)`` is normalised on
              construction.
    evidence: node index per evidence frame column (values in ``[0, card)``).
    queries:  node index per posterior output; each query of cardinality k
              contributes ``k - 1`` numerator slots (values ``1 .. k-1``; the
              value-0 count is ``denom`` minus their sum).
    epochs:   within-launch drift epochs.  The word axis is split by
              :func:`epoch_word_bounds`; words of epoch ``e > 0`` compare
              against ``epoch_rows[e - 1]`` instead of the base rows --
              modelling the crossbar's read-noise snapshot advancing *during*
              one launch.  Entropy is untouched (the counter layout never
              sees epochs), so ``epochs=1`` is bit-identical to the
              pre-drift plan by construction.
    epoch_rows: ``epochs - 1`` entries, each a per-node tuple of threshold
              row tuples with the same shape as that node's base ``rows``
              (same parents, same cardinality -- only the programmed
              thresholds drift).
    """

    nodes: Tuple
    evidence: Tuple[int, ...]
    queries: Tuple[int, ...]
    epochs: int = 1
    epoch_rows: Tuple = ()

    def __post_init__(self):
        object.__setattr__(
            self, "nodes", tuple(_normalize_node(e) for e in self.nodes)
        )
        object.__setattr__(self, "evidence", tuple(self.evidence))
        object.__setattr__(self, "queries", tuple(self.queries))
        object.__setattr__(self, "epochs", int(self.epochs))
        object.__setattr__(
            self,
            "epoch_rows",
            tuple(
                tuple(tuple(tuple(int(t) for t in row) for row in node_rows)
                      for node_rows in per_epoch)
                for per_epoch in self.epoch_rows
            ),
        )
        for i, (parents, card, rows) in enumerate(self.nodes):
            if card < 2:
                raise ValueError(f"node {i}: cardinality {card} < 2")
            for p in parents:
                if not 0 <= p < i:
                    raise ValueError(f"node {i}: parent {p} not earlier in topo order")
            expect = math.prod(self.nodes[p][1] for p in parents)
            if len(rows) != expect:
                raise ValueError(
                    f"node {i}: {len(parents)} parents of cardinalities "
                    f"{tuple(self.nodes[p][1] for p in parents)} need {expect} "
                    f"CPT rows, got {len(rows)}"
                )
            _check_rows(i, card, expect, rows)
        for n in self.evidence + self.queries:
            if not 0 <= n < len(self.nodes):
                raise ValueError(f"evidence/query node {n} out of range")
        if not self.queries:
            raise ValueError("SweepPlan needs at least one query node")
        if self.epochs < 1:
            raise ValueError(f"epochs must be >= 1, got {self.epochs}")
        if len(self.epoch_rows) != self.epochs - 1:
            raise ValueError(
                f"epochs={self.epochs} needs {self.epochs - 1} epoch_rows "
                f"entries, got {len(self.epoch_rows)}"
            )
        for e, per_epoch in enumerate(self.epoch_rows):
            if len(per_epoch) != len(self.nodes):
                raise ValueError(
                    f"epoch {e + 1}: rows for {len(per_epoch)} nodes, "
                    f"plan has {len(self.nodes)}"
                )
            for i, node_rows in enumerate(per_epoch):
                _check_rows(i, self.nodes[i][1], len(self.nodes[i][2]), node_rows)

    # ------------------------------------------------------------- accessors
    def card(self, i: int) -> int:
        return self.nodes[i][1]

    @property
    def n_value_slots(self) -> int:
        """Numerator count columns: ``sum(card - 1)`` over the query nodes."""
        return sum(self.nodes[q][1] - 1 for q in self.queries)

    @property
    def query_cards(self) -> Tuple[int, ...]:
        """Cardinality per query node, in query order."""
        return tuple(self.nodes[q][1] for q in self.queries)

    @property
    def slot_offsets(self) -> Tuple[int, ...]:
        """First numerator slot column of each query (queries own contiguous
        runs of ``card - 1`` slots, in plan order)."""
        offs, off = [], 0
        for q in self.queries:
            offs.append(off)
            off += self.nodes[q][1] - 1
        return tuple(offs)

    def node_rows(self, n: int, epoch: int = 0) -> Tuple:
        """CDF rows of node ``n`` in drift epoch ``epoch`` (0 = base rows)."""
        return self.nodes[n][2] if epoch == 0 else self.epoch_rows[epoch - 1][n]


class _RowSetGather:
    """Trace-time-factored OR of CPT-row indicators for one node.

    A threshold-bit mask is the indicator of a *set* of CPT rows.  Building it
    as a flat OR of per-row AND-of-literals words costs ``O(L * m)`` ops per
    mask; factoring the set parent-by-parent (a digit ``d`` whose whole
    sub-space is selected contributes just the digit indicator) and memoising
    the recursive sub-sets -- which repeat heavily across the ``8 * (card-1)``
    masks of a k-ary node -- cuts the gate count severalfold.  Pure boolean
    restructuring: the produced words are value-identical to the flat OR, so
    binary plans stay bit-identical.
    """

    def __init__(self, streams, parents, pcards):
        self.pcards = pcards
        self.sizes = [math.prod(pcards[j:]) for j in range(len(pcards))] + [1]
        self._digits = {}
        self._sets = {}
        self._streams = streams
        self._parents = parents

    def digit(self, j, d):
        if (j, d) not in self._digits:
            self._digits[(j, d)] = bitops.digit_indicator(
                self._streams[self._parents[j]], d
            )
        return self._digits[(j, d)]

    def rows(self, selected):
        """``selected``: iterable of mixed-radix row indices -> mask word,
        ``None`` (empty) or ``_ONES`` (the full parent space)."""
        return self._gather(0, frozenset(selected))

    def _gather(self, j, sel):
        if not sel:
            return None
        if len(sel) == self.sizes[j]:
            return _ONES
        memo_key = (j, sel)
        if memo_key in self._sets:
            return self._sets[memo_key]
        sub_size = self.sizes[j + 1]
        acc = None
        for d in range(self.pcards[j]):
            sub = frozenset(r - d * sub_size for r in sel
                            if d * sub_size <= r < (d + 1) * sub_size)
            inner = self._gather(j + 1, sub)
            if inner is None:
                continue
            term = self.digit(j, d) if inner is _ONES else self.digit(j, d) & inner
            acc = term if acc is None else acc | term
        self._sets[memo_key] = acc
        return acc


def _lt_chain(plane, thresh_masks, hi, shape):
    """Bit-sliced ``byte < threshold`` borrow chain over the needed planes.

    ``plane(k)`` returns entropy bit-plane ``k`` (memoised by the caller, so
    the k-1 chains of one categorical node share the node's 8 planes).
    thresh_masks[k] is the packed mask of threshold bit ``k`` per position
    (None = bit clear everywhere, ``_ONES`` = set everywhere); ``hi`` marks
    positions whose threshold is 256 (always fires).  Planes below the lowest
    set threshold bit cannot flip a strict less-than against a zero tail and
    are never generated.
    """
    lo = 8
    for k in range(8):
        if thresh_masks[k] is not None:
            lo = k
            break
    lt = None
    eq = None
    for k in range(7, lo - 1, -1):
        r = plane(k)
        t = thresh_masks[k]
        if t is None:
            eq = ~r if eq is None else eq & ~r
        elif t is _ONES:
            c = ~r if eq is None else eq & ~r
            lt = c if lt is None else lt | c
            eq = r if eq is None else eq & r
        else:
            c = (~r & t) if eq is None else (eq & ~r & t)
            lt = c if lt is None else lt | c
            eq = ~(r ^ t) if eq is None else eq & ~(r ^ t)
    if lt is None:
        lt = jnp.zeros(shape, jnp.uint32)
    if hi is not None:
        lt = lt | (jnp.broadcast_to(_FULL, shape) if hi is _ONES else hi)
    return lt


def _level_masks(rows, level, gather, l):
    """Per-plane gathered mask words + the t=256 short-circuit for one level."""
    if gather is None:  # root: one static row
        t = rows[0][level]
        masks = [(_ONES if (t >> k) & 1 else None) for k in range(8)]
        hi = _ONES if t >= 256 else None
        return masks, hi
    masks = [
        gather.rows([r for r in range(l) if (rows[r][level] >> k) & 1])
        for k in range(8)
    ]
    hi = gather.rows([r for r in range(l) if rows[r][level] >= 256])
    return masks, hi


def _combine_epochs(per_epoch, emasks):
    """OR of per-epoch threshold-bit masks restricted to their word ranges.

    ``per_epoch[e]`` is one epoch's mask (None / ``_ONES`` / word) and
    ``emasks[e]`` the full-ones-where-epoch-``e`` word for the tile.  The
    emasks partition every tile position, so all-None stays None and
    all-``_ONES`` stays ``_ONES`` -- the static short-circuits (and with them
    the skipped-plane optimisation) survive epoching whenever the epochs
    agree on a bit.
    """
    if all(m is None for m in per_epoch):
        return None
    if all(m is _ONES for m in per_epoch):
        return _ONES
    acc = None
    for em, m in zip(emasks, per_epoch):
        if m is None:
            continue
        term = em if m is _ONES else em & m
        acc = term if acc is None else acc | term
    return acc


def _epoch_level_masks(plan, n, level, gather, l, emasks):
    """Epoch-aware :func:`_level_masks`: per-epoch rows folded under emasks.

    One ``_RowSetGather`` serves every epoch of the node (digit indicators
    and recursive row-set words are epoch-independent, so the memo is shared);
    only the selected row sets differ per epoch.
    """
    per_bits = []
    per_hi = []
    for e in range(plan.epochs):
        masks, hi = _level_masks(plan.node_rows(n, e), level, gather, l)
        per_bits.append(masks)
        per_hi.append(hi)
    masks = [
        _combine_epochs([per_bits[e][k] for e in range(plan.epochs)], emasks)
        for k in range(8)
    ]
    hi = _combine_epochs(per_hi, emasks)
    return masks, hi


def decide_counts(plan: SweepPlan, numer: jnp.ndarray, denom: jnp.ndarray):
    """Decision epilogue: per-query argmax value from the count slots.

    ``numer`` holds the per-query-value acceptance popcounts (values
    ``1 .. card-1`` per query); the value-0 count is ``denom`` minus the
    query's slots.  The argmax over the full count vector IS the argmax of
    the per-value posterior (same positive denominator, same tie-break:
    lowest value wins), so the fused decision is bit-identical to
    posterior-argmax by construction.  A frame that accepted no stream
    positions (``denom == 0``) decides value 0, matching the all-zero
    posterior convention of :func:`~repro.core.cordiv.ratio_from_counts`.

    numer (..., n_value_slots) i32, denom (...,) i32 -> (..., n_q) i32.
    """
    decs = []
    for q_card, off in zip(plan.query_cards, plan.slot_offsets):
        slots = numer[..., off : off + q_card - 1]
        c0 = denom - jnp.sum(slots, axis=-1)
        counts = jnp.concatenate([c0[..., None], slots], axis=-1)
        decs.append(jnp.argmax(counts, axis=-1).astype(jnp.int32))
    return jnp.stack(decs, axis=-1)


def sweep_tile(
    plan: SweepPlan,
    kd0,
    kd1,
    ev: jnp.ndarray,
    f0,
    w0,
    bf: int,
    bw: int,
    w_words: int,
    n_frames: int,
    decide: bool = False,
):
    """Counts for one tile: frames ``[f0, f0+bf)`` x words ``[w0, w0+bw)``.

    ev: (bf, >= n_ev) int32 evidence values for the tile's frames (one integer
    in ``[0, card)`` per evidence node).  Returns ``(numer (bf, n_value_slots)
    int32, denom (bf,) int32)`` -- popcounts of the acceptance stream and of
    each query value indicator ANDed with it, over this tile's words only
    (callers accumulate across word tiles).  Slot order: queries in plan
    order, values ``1 .. card-1`` within a query.

    The entropy counter for node ``n``, frame ``f``, word ``w`` is
    ``n * n_frames * w_words + f * w_words + w`` -- one base counter per
    output word, planes salted from it, ONE byte per stream position no
    matter the cardinality -- so tiles of any shape draw identical bits for
    identical global positions, and binary plans consume exactly the
    pre-categorical entropy layout.  ``f0`` may be a traced uint32 scalar:
    a shard of a larger launch passes its *global* frame origin (and the
    global ``n_frames``), which is all it takes for sharded output to be
    bit-identical to the single-device sweep.

    ``decide=True`` appends the :func:`decide_counts` epilogue -- per-query
    argmax straight off the in-register popcounts -- and returns
    ``(numer, denom, decisions (bf, n_q) i32)``.  Only valid when the tile
    spans the full word axis (partial-word counts cannot be argmaxed).
    """
    if decide and bw != w_words:
        raise ValueError(
            f"decide epilogue needs the full word axis in one tile "
            f"(bw={bw}, w_words={w_words}); argmax over partial counts is wrong"
        )
    fi = jax.lax.broadcasted_iota(jnp.uint32, (bf, bw), 0)
    wi = jax.lax.broadcasted_iota(jnp.uint32, (bf, bw), 1)
    pos = (jnp.asarray(f0, jnp.uint32) + fi) * jnp.uint32(w_words) \
        + jnp.asarray(w0, jnp.uint32) + wi
    emasks = None
    if plan.epochs > 1:
        # Epoch membership is a pure function of the *global* word index, so
        # any tiling (and any shard) assigns identical epochs to identical
        # positions.  Entropy is untouched: only the threshold masks switch.
        wglob = jnp.asarray(w0, jnp.uint32) + wi
        bounds = epoch_word_bounds(w_words, plan.epochs)
        emasks = [
            jnp.where(
                (wglob >= jnp.uint32(lo)) & (wglob < jnp.uint32(hi)),
                _FULL, jnp.uint32(0),
            )
            for lo, hi in zip(bounds, bounds[1:])
        ]
    streams = []        # per node: tuple of value bit-plane words
    node_buckets = []   # per node: tuple of value==v indicator words, v=1..k-1
    for n, (parents, card, rows) in enumerate(plan.nodes):
        node_off = jnp.uint32((n * n_frames * w_words) & 0xFFFFFFFF)
        base = rng.plane_base(node_off + pos, kd0)
        l = len(rows)
        if not parents:
            gather = None
        else:
            # Threshold-bit masks are factored ORs of CPT-row indicators over
            # the parents' digit indicators, first parent = most significant
            # mixed-radix digit (the spec.py / Fig S8 ordering), memoised
            # across the node's masks (see _RowSetGather).
            pcards = tuple(plan.card(p) for p in parents)
            gather = _RowSetGather(streams, parents, pcards)
        plane_cache = {}

        def plane(k, base=base):
            if k not in plane_cache:
                plane_cache[k] = rng.plane_word(base, kd1, k)
            return plane_cache[k]

        levels = []
        for v in range(card - 1):
            if emasks is None:
                masks, hi = _level_masks(rows, v, gather, l)
            else:
                masks, hi = _epoch_level_masks(plan, n, v, gather, l, emasks)
            levels.append(_lt_chain(plane, masks, hi, (bf, bw)))
        bks = bitops.nested_buckets(levels)
        streams.append(tuple(bitops.planes_from_buckets(bks)))
        node_buckets.append(tuple(bks))
    accept = None
    for col, e in enumerate(plan.evidence):
        ind = None
        for b, pl in enumerate(streams[e]):
            bit = (ev[:, col : col + 1] >> b) & 1
            term = pl ^ jnp.where(bit == 1, jnp.uint32(0), _FULL)
            ind = term if ind is None else ind & term
        accept = ind if accept is None else accept & ind
    if accept is None:
        accept = jnp.broadcast_to(_FULL, (bf, bw))
    denom = jnp.sum(jax.lax.population_count(accept).astype(jnp.int32), axis=-1)
    numer = jnp.stack(
        [
            jnp.sum(
                jax.lax.population_count(accept & bk).astype(jnp.int32), axis=-1
            )
            for q in plan.queries
            for bk in node_buckets[q]
        ],
        axis=-1,
    )
    if decide:
        return numer, denom, decide_counts(plan, numer, denom)
    return numer, denom
