"""Shared bit-sliced sweep body for the fused whole-network op.

``SweepPlan`` is the static (hashable) description of one compiled network:
per-node parent indices and 8-bit DAC thresholds in topological order, plus
the evidence/query node sets.  ``sweep_tile`` runs the full topological sweep
for one ``(frames x words)`` tile and returns the popcount partials -- it is
the single source of truth for the fused semantics, called on the whole array
by the jnp reference and per-tile by the Pallas kernel, which makes the two
bit-identical by construction (the kernel tests then pin the tiling and
accumulation).

Node sampling is the threshold-gather formulation in bit-sliced form: entropy
arrives as 8 *bit-planes* per output word (``rng.plane_base`` /
``rng.plane_word``), the parent-gathered threshold becomes 8 per-plane mask
words (an OR of parent-literal indicator words for every CPT row whose
threshold has that bit set -- constant-folded at trace time because the
thresholds are static), and ``byte < threshold`` runs as a borrow chain over
the planes.  Planes below the lowest set threshold bit of a node can never
flip the comparison and are skipped entirely, so a node costs at most
``1 + planes`` hashes per output word instead of ``2 * 8 * 2**m``.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rng

# np scalar (not a committed jax array): Pallas kernels cannot close over
# device constants, and np scalars fold into jaxpr literals.
_FULL = np.uint32(0xFFFFFFFF)

# Trace-time sentinel: a threshold-bit mask that is all-ones across the tile
# (every CPT row has this bit set) -- lets the borrow chain drop the AND.
_ONES = object()


@dataclasses.dataclass(frozen=True)
class SweepPlan:
    """Static lowering of a binary-DAG network for the fused sweep.

    nodes:    per node (in topological order) a pair ``(parents, thresh)``:
              ``parents`` are indices of earlier nodes (first parent = most
              significant CPT row bit), ``thresh`` are the ``2**m`` 8-bit DAC
              comparator thresholds in ``[0, 256]`` (``rng.threshold_from_p``).
    evidence: node index per evidence frame column.
    queries:  node index per posterior output column.
    """

    nodes: Tuple[Tuple[Tuple[int, ...], Tuple[int, ...]], ...]
    evidence: Tuple[int, ...]
    queries: Tuple[int, ...]

    def __post_init__(self):
        for i, (parents, thresh) in enumerate(self.nodes):
            if len(thresh) != 1 << len(parents):
                raise ValueError(
                    f"node {i}: {len(parents)} parents need {1 << len(parents)} "
                    f"thresholds, got {len(thresh)}"
                )
            for p in parents:
                if not 0 <= p < i:
                    raise ValueError(f"node {i}: parent {p} not earlier in topo order")
            for t in thresh:
                if not 0 <= t <= 256:
                    raise ValueError(f"node {i}: threshold {t} outside [0, 256]")
        for n in self.evidence + self.queries:
            if not 0 <= n < len(self.nodes):
                raise ValueError(f"evidence/query node {n} out of range")
        if not self.queries:
            raise ValueError("SweepPlan needs at least one query node")


def _indicator_or(indicators, selected, length):
    """OR of the selected CPT-row indicator words, constant-folded."""
    if not selected:
        return None
    if len(selected) == length:
        return _ONES
    acc = indicators[selected[0]]
    for l in selected[1:]:
        acc = acc | indicators[l]
    return acc


def _node_stream(base, kd1, thresh_masks, hi, shape):
    """Bit-sliced ``byte < threshold`` borrow chain over the needed planes.

    thresh_masks[k] is the packed mask of threshold bit ``k`` per position
    (None = bit clear everywhere, ``_ONES`` = set everywhere); ``hi`` marks
    positions whose threshold is 256 (always fires).  Planes below the lowest
    set threshold bit cannot flip a strict less-than against a zero tail and
    are never generated.
    """
    lo = 8
    for k in range(8):
        if thresh_masks[k] is not None:
            lo = k
            break
    lt = None
    eq = None
    for k in range(7, lo - 1, -1):
        r = rng.plane_word(base, kd1, k)
        t = thresh_masks[k]
        if t is None:
            eq = ~r if eq is None else eq & ~r
        elif t is _ONES:
            c = ~r if eq is None else eq & ~r
            lt = c if lt is None else lt | c
            eq = r if eq is None else eq & r
        else:
            c = (~r & t) if eq is None else (eq & ~r & t)
            lt = c if lt is None else lt | c
            eq = ~(r ^ t) if eq is None else eq & ~(r ^ t)
    if lt is None:
        lt = jnp.zeros(shape, jnp.uint32)
    if hi is not None:
        lt = lt | (jnp.broadcast_to(_FULL, shape) if hi is _ONES else hi)
    return lt


def sweep_tile(
    plan: SweepPlan,
    kd0,
    kd1,
    ev: jnp.ndarray,
    f0,
    w0,
    bf: int,
    bw: int,
    w_words: int,
    n_frames: int,
):
    """Counts for one tile: frames ``[f0, f0+bf)`` x words ``[w0, w0+bw)``.

    ev: (bf, >= n_ev) int32 evidence values for the tile's frames.
    Returns ``(numer (bf, n_q) int32, denom (bf,) int32)`` -- popcounts of the
    acceptance stream and of each query stream ANDed with it, over this tile's
    words only (callers accumulate across word tiles).

    The entropy counter for node ``n``, frame ``f``, word ``w`` is
    ``n * n_frames * w_words + f * w_words + w`` -- one base counter per
    output word, planes salted from it -- so tiles of any shape draw identical
    bits for identical global positions.
    """
    fi = jax.lax.broadcasted_iota(jnp.uint32, (bf, bw), 0)
    wi = jax.lax.broadcasted_iota(jnp.uint32, (bf, bw), 1)
    pos = (jnp.asarray(f0, jnp.uint32) + fi) * jnp.uint32(w_words) \
        + jnp.asarray(w0, jnp.uint32) + wi
    streams = []
    for n, (parents, thresh) in enumerate(plan.nodes):
        node_off = jnp.uint32((n * n_frames * w_words) & 0xFFFFFFFF)
        base = rng.plane_base(node_off + pos, kd0)
        m = len(parents)
        l = len(thresh)
        if m == 0:
            t = thresh[0]
            masks = [(_ONES if (t >> k) & 1 else None) for k in range(8)]
            hi = _ONES if t >= 256 else None
        else:
            # CPT-row indicator words: AND of parent literals, first parent =
            # most significant row bit (the spec.py / Fig S8 ordering).
            indicators = []
            for row in range(l):
                acc = None
                for j, p in enumerate(parents):
                    lit = streams[p] if (row >> (m - 1 - j)) & 1 else ~streams[p]
                    acc = lit if acc is None else acc & lit
                indicators.append(acc)
            masks = [
                _indicator_or(indicators, [r for r in range(l) if (thresh[r] >> k) & 1], l)
                for k in range(8)
            ]
            hi = _indicator_or(indicators, [r for r in range(l) if thresh[r] >= 256], l)
        streams.append(_node_stream(base, kd1, masks, hi, (bf, bw)))
    accept = None
    for col, e in enumerate(plan.evidence):
        ind = streams[e] ^ jnp.where(ev[:, col : col + 1] == 1, jnp.uint32(0), _FULL)
        accept = ind if accept is None else accept & ind
    if accept is None:
        accept = jnp.broadcast_to(_FULL, (bf, bw))
    denom = jnp.sum(jax.lax.population_count(accept).astype(jnp.int32), axis=-1)
    numer = jnp.stack(
        [
            jnp.sum(jax.lax.population_count(accept & streams[q]).astype(jnp.int32), axis=-1)
            for q in plan.queries
        ],
        axis=-1,
    )
    return numer, denom
