"""Pallas TPU kernel: the whole Bayesian network in one launch.

Grid tiles ``(frames x words)``.  Each program generates counter bit-plane
entropy in-register for its tile, runs the full topological sweep with the
per-node byte thresholds folded into plane masks (``common.sweep_tile``), ANDs
the evidence indicators, and popcounts numerator/denominator counts for its
tile -- node streams never touch HBM.  Every program writes its own partial
block (no cross-program read-modify-write, so the grid is race-free on
backends that run programs in parallel); the tiny ``(w_tiles, B, n_q + 1)``
partials are summed outside the kernel.

Sharded launches pass a global frame origin (``ctx[2]``) and the global frame
count: the entropy counters depend only on global ``(node, frame, word)``
positions, so a kernel tiling a shard produces bit-identical words to one
tiling the whole batch.  With ``decide=True`` and a single word tile (the
standard CPU/TPU block shapes cover 4096-bit streams in one tile) each program
also argmaxes its complete per-query count vectors in-register and writes the
decisions as extra output columns; multi-word-tile grids fall back to the same
``decide_counts`` epilogue over the summed partials, still inside the launch's
jit scope.

VMEM working set is ``O(n_nodes * block_f * block_w)`` words (the live node
streams) -- comfortably inside budget for every scenario network at the
standard 128 x 256 blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.net_sweep.common import SweepPlan, decide_counts, sweep_tile


def _net_sweep_kernel(
    ctx_ref, ev_ref, out_ref, *, plan, w_words, n_frames, block_f, block_w,
    decide,
):
    f = pl.program_id(0)
    w = pl.program_id(1)
    out = sweep_tile(
        plan,
        ctx_ref[0],
        ctx_ref[1],
        ev_ref[...],
        ctx_ref[2] + jnp.asarray(f * block_f, jnp.uint32),
        w * block_w,
        block_f,
        block_w,
        w_words,
        n_frames,
        decide=decide,
    )
    if decide:
        numer, denom, dec = out
        out_ref[...] = jnp.concatenate(
            [numer, denom[:, None], dec], axis=-1
        )[None]
    else:
        numer, denom = out
        out_ref[...] = jnp.concatenate([numer, denom[:, None]], axis=-1)[None]


@functools.partial(
    jax.jit,
    static_argnames=(
        "plan", "n_bits", "total_frames", "decide", "block_f", "block_w",
        "interpret",
    ),
)
def net_sweep_pallas(
    kd: jnp.ndarray,
    ev: jnp.ndarray,
    *,
    plan: SweepPlan,
    n_bits: int,
    frame0=0,
    total_frames: int | None = None,
    decide: bool = False,
    block_f: int = 128,
    block_w: int = 256,
    interpret: bool = True,
):
    """kd (2,) u32, ev (B, n_ev_padded) i32
    -> (numer (B, n_value_slots) i32, denom (B,) i32[, decisions (B, n_q) i32]).

    ``frame0`` (int or traced u32 scalar) and ``total_frames`` (static) place
    the launch inside a larger logical batch for sharded execution.
    """
    b, n_ev = ev.shape
    w_words = n_bits // 32
    n_s = plan.n_value_slots
    n_q = len(plan.queries)
    total = b if total_frames is None else total_frames
    block_f = min(block_f, b)
    block_w = min(block_w, w_words)
    assert b % block_f == 0, (b, block_f)
    assert w_words % block_w == 0, (w_words, block_w)
    n_wtiles = w_words // block_w
    grid = (b // block_f, n_wtiles)
    # in-kernel decide needs every word of a frame in one program
    decide_in_kernel = decide and n_wtiles == 1
    n_cols = n_s + 1 + (n_q if decide_in_kernel else 0)
    ctx = jnp.concatenate(
        [kd.astype(jnp.uint32),
         jnp.asarray(frame0, jnp.uint32).reshape(1)]
    )
    kernel = functools.partial(
        _net_sweep_kernel,
        plan=plan,
        w_words=w_words,
        n_frames=total,
        block_f=block_f,
        block_w=block_w,
        decide=decide_in_kernel,
    )
    partials = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((3,), lambda f, w: (0,)),
            pl.BlockSpec((block_f, n_ev), lambda f, w: (f, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_f, n_cols), lambda f, w: (w, f, 0)),
        out_shape=jax.ShapeDtypeStruct((n_wtiles, b, n_cols), jnp.int32),
        interpret=interpret,
    )(ctx, ev)
    out = jnp.sum(partials, axis=0) if n_wtiles > 1 else partials[0]
    numer, denom = out[:, :n_s], out[:, n_s]
    if not decide:
        return numer, denom
    if decide_in_kernel:
        return numer, denom, out[:, n_s + 1 :]
    return numer, denom, decide_counts(plan, numer, denom)
