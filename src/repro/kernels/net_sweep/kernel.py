"""Pallas TPU kernel: the whole Bayesian network in one launch.

Grid tiles ``(frames x words)``.  Each program generates counter bit-plane
entropy in-register for its tile, runs the full topological sweep with the
per-node byte thresholds folded into plane masks (``common.sweep_tile``), ANDs
the evidence indicators, and popcounts numerator/denominator counts for its
tile -- node streams never touch HBM.  Every program writes its own partial
block (no cross-program read-modify-write, so the grid is race-free on
backends that run programs in parallel); the tiny ``(w_tiles, B, n_q + 1)``
partials are summed outside the kernel.

VMEM working set is ``O(n_nodes * block_f * block_w)`` words (the live node
streams) -- comfortably inside budget for every scenario network at the
standard 128 x 256 blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.net_sweep.common import SweepPlan, sweep_tile


def _net_sweep_kernel(
    kd_ref, ev_ref, out_ref, *, plan, w_words, n_frames, block_f, block_w
):
    f = pl.program_id(0)
    w = pl.program_id(1)
    numer, denom = sweep_tile(
        plan,
        kd_ref[0],
        kd_ref[1],
        ev_ref[...],
        f * block_f,
        w * block_w,
        block_f,
        block_w,
        w_words,
        n_frames,
    )
    out_ref[...] = jnp.concatenate([numer, denom[:, None]], axis=-1)[None]


@functools.partial(
    jax.jit, static_argnames=("plan", "n_bits", "block_f", "block_w", "interpret")
)
def net_sweep_pallas(
    kd: jnp.ndarray,
    ev: jnp.ndarray,
    *,
    plan: SweepPlan,
    n_bits: int,
    block_f: int = 128,
    block_w: int = 256,
    interpret: bool = True,
):
    """kd (2,) u32, ev (B, n_ev_padded) i32
    -> (numer (B, n_value_slots) i32, denom (B,) i32)."""
    b, n_ev = ev.shape
    w_words = n_bits // 32
    n_s = plan.n_value_slots
    block_f = min(block_f, b)
    block_w = min(block_w, w_words)
    assert b % block_f == 0, (b, block_f)
    assert w_words % block_w == 0, (w_words, block_w)
    n_wtiles = w_words // block_w
    grid = (b // block_f, n_wtiles)
    kernel = functools.partial(
        _net_sweep_kernel,
        plan=plan,
        w_words=w_words,
        n_frames=b,
        block_f=block_f,
        block_w=block_w,
    )
    partials = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((2,), lambda f, w: (0,)),
            pl.BlockSpec((block_f, n_ev), lambda f, w: (f, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_f, n_s + 1), lambda f, w: (w, f, 0)),
        out_shape=jax.ShapeDtypeStruct((n_wtiles, b, n_s + 1), jnp.int32),
        interpret=interpret,
    )(kd, ev)
    out = jnp.sum(partials, axis=0)
    return out[:, :n_s], out[:, n_s]
