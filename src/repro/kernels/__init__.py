"""Pallas TPU kernels for the paper's compute hot spots.

Each kernel directory carries kernel.py (pl.pallas_call + BlockSpec), ops.py
(jit'd public wrapper) and ref.py (pure-jnp oracle).  The ``interpret`` flag
is auto-detected per backend (``repro.kernels.backend``): compiled on
TPU/GPU, interpreter only as the CPU fallback.
"""

from repro.kernels import backend  # noqa: F401
from repro.kernels.bayes_decide import bayes_decide, bayes_decide_packed, bayes_decide_ref  # noqa: F401
from repro.kernels.fusion_map import fusion_map, fusion_map_ref  # noqa: F401
from repro.kernels.net_sweep import SweepPlan, net_sweep, net_sweep_ref  # noqa: F401
from repro.kernels.node_mux import node_mux, node_mux_gather_ref, node_mux_ref  # noqa: F401
from repro.kernels.pand_popcount import pand_popcount, pand_popcount_ref  # noqa: F401
from repro.kernels.sne_encode import sne_encode, sne_encode_ref  # noqa: F401
