"""Pallas TPU kernels for the paper's compute hot spots.

Each kernel directory carries kernel.py (pl.pallas_call + BlockSpec), ops.py
(jit'd public wrapper) and ref.py (pure-jnp oracle).  Kernels are validated in
interpret mode on CPU; on TPU set ``interpret=False``.
"""

from repro.kernels.fusion_map import fusion_map, fusion_map_ref  # noqa: F401
from repro.kernels.pand_popcount import pand_popcount, pand_popcount_ref  # noqa: F401
from repro.kernels.sne_encode import sne_encode, sne_encode_ref  # noqa: F401
