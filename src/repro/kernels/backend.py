"""Backend dispatch shared by all Pallas kernels.

Every kernel wrapper used to hardcode ``interpret=True`` -- correct on CPU,
but it silently ran the Pallas *interpreter* on real TPU/GPU backends, turning
the kernels into demos.  This module centralises the decision:

* ``interpret=None`` (the default everywhere) -> auto-detect: compile the
  kernel on TPU/GPU, fall back to interpret mode only when the default JAX
  backend is CPU (where Mosaic cannot lower).
* ``interpret=True`` / ``False`` -> explicit override, e.g. tests that pin
  interpret mode for determinism, or benchmarks probing both paths.

Block-size choice is also shared here so the per-kernel wrappers stay thin.
"""

from __future__ import annotations

import functools

import jax


@functools.lru_cache(maxsize=1)
def default_interpret() -> bool:
    """True only when the default JAX backend cannot compile Pallas (CPU)."""
    return jax.default_backend() == "cpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve the tri-state ``interpret`` flag to a concrete bool."""
    if interpret is None:
        return default_interpret()
    return bool(interpret)


def resolve_use_kernel(use_kernel: bool | None, interpret: bool) -> bool:
    """Resolve ``use_kernel=None``: run the Pallas kernel only where it compiles.

    The interpreter exists to validate kernels against their oracles, not to
    serve traffic -- when the resolved mode is interpret (CPU fallback), the
    production default is the pure-jnp reference, which XLA fuses natively.
    """
    if use_kernel is None:
        return not interpret
    return bool(use_kernel)


def pick_block(rows: int, preferred: int) -> int:
    """Largest block size from the standard ladder that tiles ``rows`` exactly."""
    for cand in (preferred, 256, 128, 64, 32, 8):
        if cand <= rows and rows % cand == 0:
            return cand
    return 1
