"""Pallas TPU kernel: fused batched Bayes decision (encode -> AND -> popcount -> argmax).

One VMEM pass over the whole decision: the SNE byte-threshold comparison
(encode), the M-way AND across modalities (eq (5) numerator product), the
stream popcount, and the K-way argmax all happen on registers -- no packed
stream, no per-bit tensor, and no intermediate ever touches HBM.  Because the
AND-of-comparisons is consumed immediately by the count, the kernel never even
materialises the packed words the unfused pipeline ships between its three
launches (DESIGN.md SS7).

Entropy is passed in as pre-drawn counter-based uint32 words (4 uniform bytes
per word, same scheme as ``kernels/sne_encode``), keeping the kernel
deterministic and bit-exact against the jnp oracle in ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.rng import threshold_from_p


def _decide_kernel(p_ref, rand_ref, dec_ref, cnt_ref):
    p = p_ref[...]                        # (M, bR, K) f32
    rand = rand_ref[...]                  # (M, bR, K, n_rand) u32
    thresh = threshold_from_p(p)
    m = rand.shape[0]
    total = jnp.zeros(rand.shape[1:3], jnp.int32)          # (bR, K)
    for byte in range(4):
        lane = (rand >> jnp.uint32(8 * byte)) & jnp.uint32(0xFF)
        bits = lane < thresh[..., None]                    # (M, bR, K, n_rand)
        joint = bits[0]
        for i in range(1, m):
            joint = joint & bits[i]
        total = total + jnp.sum(joint.astype(jnp.int32), axis=-1)
    cnt_ref[...] = total
    # first-occurrence argmax via iota+min (lowers on Mosaic, unlike argmax)
    best = jnp.max(total, axis=-1, keepdims=True)
    idx = jax.lax.broadcasted_iota(jnp.int32, total.shape, 1)
    dec_ref[...] = jnp.min(
        jnp.where(total == best, idx, jnp.int32(total.shape[-1])), axis=-1
    )


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def bayes_decide_pallas(
    p: jnp.ndarray,
    rand_words: jnp.ndarray,
    *,
    block_r: int = 256,
    interpret: bool = False,
):
    """p: (M, R, K) f32; rand_words: (M, R, K, n_rand) u32.

    Returns (decisions (R,) int32, counts (R, K) int32).
    """
    m, r, k, n_rand = rand_words.shape
    assert p.shape == (m, r, k)
    block_r = min(block_r, r)
    assert r % block_r == 0, f"rows {r} not divisible by block {block_r}"
    grid = (r // block_r,)
    return pl.pallas_call(
        _decide_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, block_r, k), lambda i: (0, i, 0)),
            pl.BlockSpec((m, block_r, k, n_rand), lambda i: (0, i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_r,), lambda i: (i,)),
            pl.BlockSpec((block_r, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r,), jnp.int32),
            jax.ShapeDtypeStruct((r, k), jnp.int32),
        ],
        interpret=interpret,
    )(p, rand_words)
