from repro.kernels.bayes_decide.ops import bayes_decide, bayes_decide_packed  # noqa: F401
from repro.kernels.bayes_decide.ref import bayes_decide_ref  # noqa: F401
