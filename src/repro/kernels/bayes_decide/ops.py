"""jit'd public wrappers for the fused Bayes decision op.

``bayes_decide``        -- the fused single-pass kernel (or its jnp oracle).
``bayes_decide_packed`` -- the same decision composed from the packed-domain
primitives (counter-based encode -> AND -> popcount -> argmax).  It draws the
*identical* entropy words, so it is bit-exact against the fused op -- the
benchmark harness uses the pair to report the fusion speedup honestly.

This op is the *multi-modal fusion* decision layer (eq (3)): M independent
modal posteriors re-enter the stochastic domain and their AND-fused streams
are popcount-argmaxed.  A compiled network's own ``decide`` no longer routes
through here -- the fused sweep argmaxes its count slots in-register
(:func:`~repro.kernels.net_sweep.decide_counts`), which needs no re-encode
because the counts never left the kernel.  Use this op when fusing posteriors
that arrive from *separate* sources (modalities, networks, sensors), i.e.
when there are no shared counts to argmax.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import bitops, rng
from repro.kernels import backend
from repro.kernels.bayes_decide.kernel import bayes_decide_pallas


def _draw_entropy(key: jax.Array, m: int, rows: int, k: int, n_bits: int) -> jnp.ndarray:
    return rng.counter_hash_words(key, (m, rows, k), n_bits // 4)


def _decide_packed(flat_p: jnp.ndarray, rand: jnp.ndarray):
    """Packed-domain decision from pre-drawn entropy (the CPU fast path).

    Bit-exact with the Pallas kernel and with ``ref.bayes_decide_ref``; on CPU
    this formulation (SWAR popcount over packed words) is what XLA fuses best.
    """
    m = flat_p.shape[0]
    words = rng.packed_from_bytes(rand, rng.threshold_from_p(flat_p))  # (M, R, K, W)
    joint = words[0]
    for i in range(1, m):
        joint = joint & words[i]
    counts = bitops.popcount(joint)                                    # (R, K)
    return jnp.argmax(counts, axis=-1).astype(jnp.int32), counts


@functools.partial(jax.jit, static_argnames=("n_bits", "use_kernel", "interpret"))
def bayes_decide(
    key: jax.Array,
    p_modal: jnp.ndarray,
    n_bits: int = 128,
    *,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
):
    """Fused batched Bayes decision over modal posteriors.

    p_modal: (M, ..., K) single-modal class posteriors.  Each (modality,
    decision, class) stream gets independent counter-based entropy
    (conditional independence, eq (3)).  n_bits must be a multiple of 32.

    Returns (decisions (...,) int32 argmax class, counts (..., K) int32
    stream popcounts -- ``counts / counts.sum(-1)`` is the fused posterior).
    ``interpret=None`` auto-detects the backend.
    """
    assert n_bits % 32 == 0, "kernel path consumes whole uint32 entropy words"
    interpret = backend.resolve_interpret(interpret)
    use_kernel = backend.resolve_use_kernel(use_kernel, interpret)
    p = jnp.asarray(p_modal, jnp.float32)
    m, k = p.shape[0], p.shape[-1]
    flat = p.reshape(m, -1, k)
    rand = _draw_entropy(key, m, flat.shape[1], k, n_bits)
    if use_kernel:
        block = backend.pick_block(flat.shape[1], 256)
        dec, cnt = bayes_decide_pallas(flat, rand, block_r=block, interpret=interpret)
    else:
        dec, cnt = _decide_packed(flat, rand)
    return dec.reshape(p.shape[1:-1]), cnt.reshape(p.shape[1:])


@functools.partial(jax.jit, static_argnames=("n_bits",))
def bayes_decide_packed(key: jax.Array, p_modal: jnp.ndarray, n_bits: int = 128):
    """Unfused packed-domain reference: encode -> M-way AND -> popcount -> argmax.

    Bit-exact against :func:`bayes_decide` (same entropy stream), but each
    stage materialises its packed intermediate -- this is the composition the
    fused kernel collapses, kept as the speedup baseline.
    """
    assert n_bits % 32 == 0
    p = jnp.asarray(p_modal, jnp.float32)
    m, k = p.shape[0], p.shape[-1]
    flat = p.reshape(m, -1, k)
    rand = _draw_entropy(key, m, flat.shape[1], k, n_bits)
    dec, counts = _decide_packed(flat, rand)
    return dec.reshape(p.shape[1:-1]), counts.reshape(p.shape[1:])
