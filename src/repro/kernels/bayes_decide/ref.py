"""Pure-jnp oracle for the fused Bayes decision kernel.

Semantics (shared with the kernel, bit-exact): each uint32 entropy word
contributes 4 uniform bytes; stream bit = ``byte < round(p * 256)``; a
decision's class score is the popcount of the M-way AND of its modal streams;
the decision is the first-occurrence argmax over classes.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.rng import threshold_from_p


def bayes_decide_ref(p: jnp.ndarray, rand_words: jnp.ndarray):
    """p: (M, R, K) f32; rand_words: (M, R, K, n_rand) u32.

    Returns (decisions (R,) int32, counts (R, K) int32).
    """
    thresh = threshold_from_p(p)
    total = jnp.zeros(p.shape[1:], jnp.int32)
    for byte in range(4):
        lane = (rand_words >> jnp.uint32(8 * byte)) & jnp.uint32(0xFF)
        bits = lane < thresh[..., None]
        joint = jnp.all(bits, axis=0)                      # (R, K, n_rand)
        total = total + jnp.sum(joint.astype(jnp.int32), axis=-1)
    return jnp.argmax(total, axis=-1).astype(jnp.int32), total
