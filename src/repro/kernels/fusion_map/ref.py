"""Pure-jnp oracle for the analytic Bayesian fusion-map kernel (eq (5))."""

from __future__ import annotations

import jax.numpy as jnp


def fusion_map_ref(p_modal: jnp.ndarray, prior: jnp.ndarray) -> jnp.ndarray:
    """Normalized multimodal fusion over per-pixel class posteriors.

    p_modal: (M, R, K) float32 -- per-modality class posteriors for R pixels.
    prior:   (K,) float32 class prior.
    returns: (R, K) float32, rows sum to 1:
             softmax_k( sum_m log p_mk - (M-1) log prior_k ).
    """
    m = p_modal.shape[0]
    logq = jnp.sum(jnp.log(jnp.clip(p_modal, 1e-9, 1.0)), axis=0) - (
        m - 1
    ) * jnp.log(jnp.clip(prior, 1e-9, 1.0))
    logq = logq - jnp.max(logq, axis=-1, keepdims=True)
    q = jnp.exp(logq)
    return q / jnp.sum(q, axis=-1, keepdims=True)
