from repro.kernels.fusion_map.ops import fusion_map  # noqa: F401
from repro.kernels.fusion_map.ref import fusion_map_ref  # noqa: F401
