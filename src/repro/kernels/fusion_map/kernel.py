"""Pallas TPU kernel: analytic Bayesian fusion over class-probability maps.

The paper's Movie-S1 "large-scale Bayesian fusion on videos" evaluates eq (5)
per pixel over full frames.  This kernel fuses the log-product, prior division
and normalization (Fig S10 module) in one VMEM pass over pixel tiles, with the
class axis on the 128-wide lane dimension.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fusion_kernel(p_ref, logprior_ref, out_ref):
    p = p_ref[...]                                  # (M, bR, K) f32
    logp = jnp.log(jnp.clip(p, 1e-9, 1.0))
    logq = jnp.sum(logp, axis=0) - logprior_ref[...]  # (bR, K); prior term is
    # pre-scaled by (M-1) on the host side.
    logq = logq - jnp.max(logq, axis=-1, keepdims=True)
    q = jnp.exp(logq)
    out_ref[...] = q / jnp.sum(q, axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def fusion_map_pallas(
    p_modal: jnp.ndarray,
    prior: jnp.ndarray,
    *,
    block_r: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """p_modal: (M, R, K) f32, prior: (K,) f32 -> (R, K) f32 normalized fusion."""
    m, r, k = p_modal.shape
    block_r = min(block_r, r)
    assert r % block_r == 0, f"rows {r} not divisible by block {block_r}"
    logprior = (m - 1) * jnp.log(jnp.clip(prior, 1e-9, 1.0)).astype(jnp.float32)
    grid = (r // block_r,)
    return pl.pallas_call(
        _fusion_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, block_r, k), lambda i: (0, i, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_r, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, k), jnp.float32),
        interpret=interpret,
    )(p_modal, logprior)
