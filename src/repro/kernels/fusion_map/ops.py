"""jit'd public wrapper for the fusion-map kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import backend
from repro.kernels.fusion_map.kernel import fusion_map_pallas
from repro.kernels.fusion_map.ref import fusion_map_ref


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def fusion_map(
    p_modal: jnp.ndarray,
    prior: jnp.ndarray | None = None,
    *,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Analytic eq-(5) fusion over class maps.

    p_modal: (M, ..., K); prior (K,) or None (uniform).  Returns (..., K).
    ``interpret=None`` auto-detects the backend.
    """
    interpret = backend.resolve_interpret(interpret)
    use_kernel = backend.resolve_use_kernel(use_kernel, interpret)
    p_modal = jnp.asarray(p_modal, jnp.float32)
    m = p_modal.shape[0]
    k = p_modal.shape[-1]
    if prior is None:
        prior = jnp.full((k,), 1.0 / k, jnp.float32)
    flat = p_modal.reshape(m, -1, k)
    if use_kernel:
        block = backend.pick_block(flat.shape[1], 256)
        out = fusion_map_pallas(flat, prior, block_r=block, interpret=interpret)
    else:
        out = fusion_map_ref(flat, prior)
    return out.reshape(p_modal.shape[1:])
