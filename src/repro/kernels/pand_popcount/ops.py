"""jit'd public wrapper for the fused AND+popcount kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.pand_popcount.kernel import pand_popcount_pallas
from repro.kernels.pand_popcount.ref import pand_popcount_ref


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def pand_popcount(
    streams: jnp.ndarray, *, use_kernel: bool = True, interpret: bool = True
) -> jnp.ndarray:
    """Fused probabilistic-AND across modalities + popcount.

    streams: (M, ..., n_words) uint32.  Returns (...,) int32 counts.
    """
    m = streams.shape[0]
    n_words = streams.shape[-1]
    flat = streams.reshape(m, -1, n_words)
    if use_kernel:
        rows = flat.shape[1]
        block = 512 if rows % 512 == 0 else (64 if rows % 64 == 0 else 1)
        out = pand_popcount_pallas(flat, block_r=block, interpret=interpret)
    else:
        out = pand_popcount_ref(flat)
    return out.reshape(streams.shape[1:-1])
