"""jit'd public wrapper for the fused AND+popcount kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import backend
from repro.kernels.pand_popcount.kernel import pand_popcount_pallas
from repro.kernels.pand_popcount.ref import pand_popcount_ref


@functools.partial(jax.jit, static_argnames=("use_kernel", "interpret"))
def pand_popcount(
    streams: jnp.ndarray, *, use_kernel: bool | None = None, interpret: bool | None = None
) -> jnp.ndarray:
    """Fused probabilistic-AND across modalities + popcount.

    streams: (M, ..., n_words) uint32.  Returns (...,) int32 counts.
    ``interpret=None`` auto-detects the backend.
    """
    interpret = backend.resolve_interpret(interpret)
    use_kernel = backend.resolve_use_kernel(use_kernel, interpret)
    m = streams.shape[0]
    n_words = streams.shape[-1]
    flat = streams.reshape(m, -1, n_words)
    if use_kernel:
        block = backend.pick_block(flat.shape[1], 512)
        out = pand_popcount_pallas(flat, block_r=block, interpret=interpret)
    else:
        out = pand_popcount_ref(flat)
    return out.reshape(streams.shape[1:-1])
