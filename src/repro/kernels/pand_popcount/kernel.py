"""Pallas TPU kernel: fused M-way packed AND + SWAR popcount.

This is the Bayes-fusion numerator (eq (5) product) evaluated on packed
stochastic numbers: the AND chain and the popcount reduction run in one VMEM
pass, so the intermediate bitstreams never touch HBM -- the TPU analogue of the
paper's claim that the SC operator avoids pre-/post-processing circuitry.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pand_kernel(streams_ref, out_ref):
    s = streams_ref[...]                       # (M, bR, n_words) u32
    acc = s[0]
    for i in range(1, s.shape[0]):
        acc = acc & s[i]
    x = acc
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    counts = (x * jnp.uint32(0x01010101)) >> 24
    out_ref[...] = jnp.sum(counts.astype(jnp.int32), axis=-1)


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def pand_popcount_pallas(
    streams: jnp.ndarray, *, block_r: int = 512, interpret: bool = True
) -> jnp.ndarray:
    """streams: (M, R, n_words) uint32 -> (R,) int32 fused AND+popcount."""
    m, r, n_words = streams.shape
    block_r = min(block_r, r)
    assert r % block_r == 0, f"rows {r} not divisible by block {block_r}"
    grid = (r // block_r,)
    return pl.pallas_call(
        _pand_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((m, block_r, n_words), lambda i: (0, i, 0))],
        out_specs=pl.BlockSpec((block_r,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((r,), jnp.int32),
        interpret=interpret,
    )(streams)
