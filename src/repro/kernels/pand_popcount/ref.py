"""Pure-jnp oracle for the fused probabilistic-AND + popcount kernel."""

from __future__ import annotations

import jax.numpy as jnp


def _popcount_words(x: jnp.ndarray) -> jnp.ndarray:
    x = x.astype(jnp.uint32)
    x = x - ((x >> 1) & jnp.uint32(0x55555555))
    x = (x & jnp.uint32(0x33333333)) + ((x >> 2) & jnp.uint32(0x33333333))
    x = (x + (x >> 4)) & jnp.uint32(0x0F0F0F0F)
    return (x * jnp.uint32(0x01010101)) >> 24


def pand_popcount_ref(streams: jnp.ndarray) -> jnp.ndarray:
    """AND-reduce streams over the leading modality axis, then popcount.

    streams: (M, R, n_words) uint32 packed stochastic numbers.
    returns: (R,) int32 -- number of set bits in AND_m streams[m] per row
             (the Bayes-fusion numerator count, eq (5) before normalization).
    """
    acc = streams[0]
    for i in range(1, streams.shape[0]):
        acc = acc & streams[i]
    return jnp.sum(_popcount_words(acc).astype(jnp.int32), axis=-1)
