from repro.kernels.pand_popcount.ops import pand_popcount  # noqa: F401
from repro.kernels.pand_popcount.ref import pand_popcount_ref  # noqa: F401
