from repro.kernels.node_mux.ops import node_mux  # noqa: F401
from repro.kernels.node_mux.ref import node_mux_gather_ref, node_mux_ref  # noqa: F401
