from repro.kernels.node_mux.ops import node_mux, node_mux_categorical  # noqa: F401
from repro.kernels.node_mux.ref import (  # noqa: F401
    node_mux_cat_ref,
    node_mux_gather_ref,
    node_mux_ref,
)
