"""jnp references for the node-MUX sweep (the CPU production fallback).

Two formulations of the same conditional Bernoulli:

* ``node_mux_ref`` (row-encode): encode the ``2**m`` CPT rows as independent
  packed streams (byte-threshold comparators, same scheme as ``sne_encode``),
  then route each bit position through the value-select MUX tree keyed by the
  parents' bits at that position.  ``2**m`` entropy draws per stream bit.
* ``node_mux_gather_ref`` (threshold-gather): select the node's 8-bit DAC
  threshold *by the parents' bits first*, then compare a single entropy byte
  against it.  Conditional on the parents' bits at a position, the output bit
  is Bernoulli(cpt[row]) either way, and disjoint entropy per position keeps
  bits conditionally independent -- distributionally identical to row-encode
  with ``2**m`` times less entropy and no stream-wide MUX tree at all (the
  select collapses to an 8-bit threshold gather).

Both compose core packed primitives; XLA fuses them well on CPU, and the
Pallas kernels reproduce each bit-exactly from the same entropy words.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import logic, rng


def node_mux_ref(
    cpt: jnp.ndarray, rand: jnp.ndarray, parents: jnp.ndarray
) -> jnp.ndarray:
    """cpt (R, L) f32, rand (R, L, n_rand) u32, parents (m, R, W) u32 -> (R, W).

    L = 2**m; output word count W = n_rand // 8 (8 entropy words per packed
    output word).  CPT row index convention: first parent = most significant
    bit (spec.py / Fig S8 ordering).
    """
    leaves = rng.packed_from_bytes(rand, rng.threshold_from_p(cpt))  # (R, L, W)
    return logic.mux_select(parents, leaves)


def gather_thresholds(
    thresh: jnp.ndarray, parents: jnp.ndarray, byte: int
) -> jnp.ndarray:
    """Per-position threshold gather: thresh (R, L) u32, parents (m, R, W) u32
    -> (R, W, 8) u32, the selected threshold at every stream position whose
    packed-bit index is ``4 e + byte`` (entropy word ``e`` of its output word).

    The gather is a value-select tree over the *thresholds* (8-bit scalars)
    instead of over full packed streams -- the stream-wide MUX tree of the
    row-encode formulation collapses to this.  Pairing convention matches
    ``logic.mux_select``: first parent = most significant row-index bit.
    """
    m = parents.shape[0]
    shifts = (jnp.arange(8, dtype=jnp.uint32) * 4 + byte).astype(jnp.uint32)
    level = jnp.asarray(thresh, jnp.uint32)[:, None, None, :]      # (R, 1, 1, L)
    for j in range(m - 1, -1, -1):
        pbit = (parents[j][..., None] >> shifts) & jnp.uint32(1)   # (R, W, 8)
        level = jnp.where(pbit[..., None] == 1, level[..., 1::2], level[..., 0::2])
    return level[..., 0]


def node_mux_gather_ref(
    cpt: jnp.ndarray, rand: jnp.ndarray, parents: jnp.ndarray
) -> jnp.ndarray:
    """cpt (R, L) f32, rand (R, n_rand) u32, parents (m, R, W) u32 -> (R, W).

    Threshold-gather formulation: one entropy byte per stream bit regardless
    of fan-in.  Bit layout matches ``rng.packed_from_bytes`` (stream bit
    ``4 r + b`` from byte ``b`` of entropy word ``r`` lands in output word
    ``r // 8`` at bit ``4 (r % 8) + b``).
    """
    thresh = rng.threshold_from_p(cpt)                              # (R, L)
    r, n_rand = rand.shape
    w = n_rand // 8
    acc = jnp.zeros((r, w), jnp.uint32)
    for byte in range(4):
        lane = ((rand >> jnp.uint32(8 * byte)) & jnp.uint32(0xFF)).reshape(r, w, 8)
        th = gather_thresholds(thresh, parents, byte)               # (R, W, 8)
        bits = (lane < th).astype(jnp.uint32)
        shifts = (jnp.arange(8, dtype=jnp.uint32) * 4 + byte).astype(jnp.uint32)
        acc = acc | jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)
    return acc
