"""jnp references for the node-MUX sweep (the CPU production fallback).

``cat_gather_body`` / ``node_mux_cat_ref`` carry the categorical (k-ary)
gather; the two binary formulations of the same conditional Bernoulli:

* ``node_mux_ref`` (row-encode): encode the ``2**m`` CPT rows as independent
  packed streams (byte-threshold comparators, same scheme as ``sne_encode``),
  then route each bit position through the value-select MUX tree keyed by the
  parents' bits at that position.  ``2**m`` entropy draws per stream bit.
* ``node_mux_gather_ref`` (threshold-gather): select the node's 8-bit DAC
  threshold *by the parents' bits first*, then compare a single entropy byte
  against it.  Conditional on the parents' bits at a position, the output bit
  is Bernoulli(cpt[row]) either way, and disjoint entropy per position keeps
  bits conditionally independent -- distributionally identical to row-encode
  with ``2**m`` times less entropy and no stream-wide MUX tree at all (the
  select collapses to an 8-bit threshold gather).

Both compose core packed primitives; XLA fuses them well on CPU, and the
Pallas kernels reproduce each bit-exactly from the same entropy words.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import bitops, logic, rng


def node_mux_ref(
    cpt: jnp.ndarray, rand: jnp.ndarray, parents: jnp.ndarray
) -> jnp.ndarray:
    """cpt (R, L) f32, rand (R, L, n_rand) u32, parents (m, R, W) u32 -> (R, W).

    L = 2**m; output word count W = n_rand // 8 (8 entropy words per packed
    output word).  CPT row index convention: first parent = most significant
    bit (spec.py / Fig S8 ordering).
    """
    leaves = rng.packed_from_bytes(rand, rng.threshold_from_p(cpt))  # (R, L, W)
    return logic.mux_select(parents, leaves)


def gather_thresholds(
    thresh: jnp.ndarray, parents: jnp.ndarray, byte: int
) -> jnp.ndarray:
    """Per-position threshold gather: thresh (R, L) u32, parents (m, R, W) u32
    -> (R, W, 8) u32, the selected threshold at every stream position whose
    packed-bit index is ``4 e + byte`` (entropy word ``e`` of its output word).

    The gather is a value-select tree over the *thresholds* (8-bit scalars)
    instead of over full packed streams -- the stream-wide MUX tree of the
    row-encode formulation collapses to this.  Pairing convention matches
    ``logic.mux_select``: first parent = most significant row-index bit.
    """
    m = parents.shape[0]
    shifts = (jnp.arange(8, dtype=jnp.uint32) * 4 + byte).astype(jnp.uint32)
    level = jnp.asarray(thresh, jnp.uint32)[:, None, None, :]      # (R, 1, 1, L)
    for j in range(m - 1, -1, -1):
        pbit = (parents[j][..., None] >> shifts) & jnp.uint32(1)   # (R, W, 8)
        level = jnp.where(pbit[..., None] == 1, level[..., 1::2], level[..., 0::2])
    return level[..., 0]


def cat_gather_body(
    cdf: jnp.ndarray, rand: jnp.ndarray, parents: jnp.ndarray, cards: tuple
) -> jnp.ndarray:
    """Categorical threshold-gather: the shared jnp body (ref AND Pallas kernel).

    cdf     (R, L, k-1) u32 non-increasing cumulative DAC thresholds per
            mixed-radix CPT row (first parent = most significant digit).
    rand    (R, n_rand) u32 -- ONE entropy byte per stream position, exactly
            the binary gather budget: the whole categorical draw rides on the
            byte the first comparison already paid for.
    parents (P, R, W) u32 packed value bit-planes; parent ``j`` owns the
            contiguous plane block ``[sum_{i<j} vbits_i, ...)``, LSB first.
    cards   static ``(k, k_p0, .., k_pm-1)``.

    Returns (vbits, R, W) u32: the sampled value's packed bit-planes.  The
    per-position CDF row is gathered by a mixed-radix select over the parents'
    digits (the stream-wide MUX tree collapsed to ``k-1`` 8-bit scalars), the
    byte is compared against every level, and the nested level indicators are
    re-packed via ``bitops.value_planes``.
    """
    k = int(cards[0])
    pcards = tuple(int(c) for c in cards[1:])
    r, n_rand = rand.shape
    w = n_rand // 8
    vb = bitops.value_bits(k)
    offsets = []
    off = 0
    for c in pcards:
        offsets.append(off)
        off += bitops.value_bits(c)
    planes_acc = [jnp.zeros((r, w), jnp.uint32) for _ in range(vb)]
    for byte in range(4):
        lane = ((rand >> jnp.uint32(8 * byte)) & jnp.uint32(0xFF)).reshape(r, w, 8)
        shifts = (jnp.arange(8, dtype=jnp.uint32) * 4 + byte).astype(jnp.uint32)
        level = cdf[:, None, None, :, :]                  # (R, 1, 1, L, k-1)
        for j in range(len(pcards) - 1, -1, -1):
            kj = pcards[j]
            dj = jnp.zeros((r, w, 8), jnp.uint32)
            for b in range(bitops.value_bits(kj)):
                pbit = (parents[offsets[j] + b][..., None] >> shifts) & jnp.uint32(1)
                dj = dj | (pbit << jnp.uint32(b))
            lv = level.reshape(level.shape[:-2] + (level.shape[-2] // kj, kj, k - 1))
            acc = lv[..., 0, :]
            for d in range(1, kj):
                acc = jnp.where(dj[..., None, None] == jnp.uint32(d), lv[..., d, :], acc)
            level = acc
        level = level[..., 0, :]                          # (R, W, 8, k-1)
        cnt = jnp.sum((lane[..., None] < level).astype(jnp.uint32), axis=-1)
        for b in range(vb):
            bits = (cnt >> jnp.uint32(b)) & jnp.uint32(1)
            planes_acc[b] = planes_acc[b] | jnp.sum(
                bits << shifts, axis=-1, dtype=jnp.uint32
            )
    return jnp.stack(planes_acc)


def node_mux_cat_ref(
    cdf: jnp.ndarray, rand: jnp.ndarray, parents: jnp.ndarray, cards: tuple
) -> jnp.ndarray:
    """jnp reference for the categorical gather (see :func:`cat_gather_body`)."""
    return cat_gather_body(cdf, rand, parents, cards)


def node_mux_gather_ref(
    cpt: jnp.ndarray, rand: jnp.ndarray, parents: jnp.ndarray
) -> jnp.ndarray:
    """cpt (R, L) f32, rand (R, n_rand) u32, parents (m, R, W) u32 -> (R, W).

    Threshold-gather formulation: one entropy byte per stream bit regardless
    of fan-in.  Bit layout matches ``rng.packed_from_bytes`` (stream bit
    ``4 r + b`` from byte ``b`` of entropy word ``r`` lands in output word
    ``r // 8`` at bit ``4 (r % 8) + b``).
    """
    thresh = rng.threshold_from_p(cpt)                              # (R, L)
    r, n_rand = rand.shape
    w = n_rand // 8
    acc = jnp.zeros((r, w), jnp.uint32)
    for byte in range(4):
        lane = ((rand >> jnp.uint32(8 * byte)) & jnp.uint32(0xFF)).reshape(r, w, 8)
        th = gather_thresholds(thresh, parents, byte)               # (R, W, 8)
        bits = (lane < th).astype(jnp.uint32)
        shifts = (jnp.arange(8, dtype=jnp.uint32) * 4 + byte).astype(jnp.uint32)
        acc = acc | jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)
    return acc
