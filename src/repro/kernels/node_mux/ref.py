"""jnp reference for the node-MUX sweep (the CPU production fallback).

A Bayesian-network node's packed stream is: encode the ``2**m`` CPT rows as
independent packed streams (byte-threshold comparators, same scheme as
``sne_encode``), then route each bit position through the value-select MUX tree
keyed by the parents' bits at that position.  This reference composes the core
packed primitives; XLA fuses it well on CPU, and the Pallas kernel reproduces
it bit-exactly from the same entropy words.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import logic, rng


def node_mux_ref(
    cpt: jnp.ndarray, rand: jnp.ndarray, parents: jnp.ndarray
) -> jnp.ndarray:
    """cpt (R, L) f32, rand (R, L, n_rand) u32, parents (m, R, W) u32 -> (R, W).

    L = 2**m; output word count W = n_rand // 8 (8 entropy words per packed
    output word).  CPT row index convention: first parent = most significant
    bit (spec.py / Fig S8 ordering).
    """
    leaves = rng.packed_from_bytes(rand, rng.threshold_from_p(cpt))  # (R, L, W)
    return logic.mux_select(parents, leaves)
