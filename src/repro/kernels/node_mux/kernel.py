"""Pallas TPU kernels: one Bayesian-network node from pre-drawn entropy.

Three kernels: the two binary formulations (same conditional distribution,
different entropy budgets) plus the categorical gather
(``node_mux_cat_pallas``, value bit-planes from one byte vs the
parent-gathered CDF -- body shared with the jnp ref via ``cat_gather_body``):

* ``node_mux_pallas`` (row-encode): compare pre-drawn random bytes against the
  8-bit CPT thresholds (the SNE comparator, one per CPT row), pack 32 stream
  bits per uint32 lane word, and collapse the ``2**m`` leaf streams through
  the value-select MUX tree keyed by the parents' packed bits.
* ``node_mux_gather_pallas`` (threshold-gather): gather the 8-bit threshold by
  the parents' bits first, then compare one entropy byte -- ``2**m`` times
  less entropy, no stream-wide MUX tree.

Everything stays in VMEM; nothing per-leaf ever reaches HBM.  This is the
compiler's unfused inner sweep: one launch per network node per batch block.

Tiling: grid over rows (evidence frames / broadcast rows).  The working set is
``block_r * L * (n_rand + W)`` words plus the ``m * block_r * W`` parent words,
comfortably inside the ~16 MB VMEM budget for every scenario network
(L <= 8, n_bits <= 2**14).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import bitops
from repro.kernels.node_mux.ref import cat_gather_body


def _node_mux_kernel(cpt_ref, rand_ref, par_ref, out_ref):
    cpt = cpt_ref[...]                    # (bR, L) f32
    rand = rand_ref[...]                  # (bR, L, n_rand) u32
    parents = par_ref[...]                # (m, bR, W) u32
    thresh = jnp.clip(jnp.round(cpt * 256.0), 0.0, 256.0).astype(jnp.uint32)
    n_rand = rand.shape[-1]
    w = n_rand // 8
    # Encode all L leaves: 4 uniform bytes per entropy word, bit-plane packed
    # (identical layout to the sne_encode kernel).
    acc = jnp.zeros(rand.shape[:-1] + (w,), jnp.uint32)
    for byte in range(4):
        lane = (rand >> jnp.uint32(8 * byte)) & jnp.uint32(0xFF)
        bits = (lane < thresh[..., None]).astype(jnp.uint32)
        grouped = bits.reshape(bits.shape[:-1] + (w, 8))
        shifts = (jnp.arange(8, dtype=jnp.uint32) * 4 + byte).astype(jnp.uint32)
        acc = acc + jnp.sum(grouped << shifts, axis=-1, dtype=jnp.uint32)
    # Value-select MUX tree, LSB parent first (first parent = MSB of the row
    # index, matching core/logic.mux_select and the Fig S8 CPT ordering).
    m = parents.shape[0]
    level = acc                            # (bR, L, W)
    for j in range(m - 1, -1, -1):
        s = parents[j][:, None, :]         # (bR, 1, W)
        level = (s & level[:, 1::2, :]) | (~s & level[:, 0::2, :])
    out_ref[...] = level[:, 0, :]


def _node_mux_gather_kernel(cpt_ref, rand_ref, par_ref, out_ref):
    cpt = cpt_ref[...]                    # (bR, L) f32
    rand = rand_ref[...]                  # (bR, n_rand) u32
    parents = par_ref[...]                # (m, bR, W) u32
    thresh = jnp.clip(jnp.round(cpt * 256.0), 0.0, 256.0).astype(jnp.uint32)
    br, n_rand = rand.shape
    w = n_rand // 8
    m = parents.shape[0]
    # Threshold-gather: the MUX tree runs over the 8-bit thresholds, not over
    # packed streams -- one entropy byte per stream bit regardless of fan-in.
    acc = jnp.zeros((br, w), jnp.uint32)
    for byte in range(4):
        lane = ((rand >> jnp.uint32(8 * byte)) & jnp.uint32(0xFF)).reshape(br, w, 8)
        shifts = (jnp.arange(8, dtype=jnp.uint32) * 4 + byte).astype(jnp.uint32)
        level = jnp.broadcast_to(thresh[:, None, None, :], (br, 1, 1, thresh.shape[-1]))
        for j in range(m - 1, -1, -1):
            pbit = (parents[j][..., None] >> shifts) & jnp.uint32(1)
            level = jnp.where(pbit[..., None] == 1, level[..., 1::2], level[..., 0::2])
        bits = (lane < level[..., 0]).astype(jnp.uint32)
        acc = acc | jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)
    out_ref[...] = acc


def _node_mux_cat_kernel(cdf_ref, rand_ref, par_ref, out_ref, *, cards):
    out_ref[...] = cat_gather_body(
        cdf_ref[...], rand_ref[...], par_ref[...], cards
    )


@functools.partial(jax.jit, static_argnames=("cards", "block_r", "interpret"))
def node_mux_cat_pallas(
    cdf: jnp.ndarray,
    rand_words: jnp.ndarray,
    parents: jnp.ndarray,
    *,
    cards: tuple,
    block_r: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """cdf (R, L, k-1) u32, rand_words (R, n_rand) u32, parents (P, R, W) u32
    value bit-planes -> (vbits, R, W) u32 sampled value bit-planes.

    Same tiling story as the binary gather kernel: grid over rows, one byte of
    entropy per stream position, everything in VMEM.  The body is the shared
    ``cat_gather_body``, so kernel and ref are bit-identical by construction.
    """
    r, n_rand = rand_words.shape
    k = int(cards[0])
    pcards = tuple(int(c) for c in cards[1:])
    l = 1
    p = 0
    for c in pcards:
        l *= c
        p += bitops.value_bits(c)
    vb = bitops.value_bits(k)
    assert cdf.shape == (r, l, k - 1), (cdf.shape, (r, l, k - 1))
    assert n_rand % 8 == 0
    w = n_rand // 8
    assert parents.shape == (p, r, w), (parents.shape, (p, r, w))
    block_r = min(block_r, r)
    assert r % block_r == 0, f"rows {r} not divisible by block {block_r}"
    grid = (r // block_r,)
    kernel = functools.partial(_node_mux_cat_kernel, cards=cards)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, l, k - 1), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_r, n_rand), lambda i: (i, 0)),
            pl.BlockSpec((p, block_r, w), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((vb, block_r, w), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((vb, r, w), jnp.uint32),
        interpret=interpret,
    )(cdf, rand_words, parents)


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def node_mux_gather_pallas(
    cpt: jnp.ndarray,
    rand_words: jnp.ndarray,
    parents: jnp.ndarray,
    *,
    block_r: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """cpt (R, L) f32, rand_words (R, n_rand) u32, parents (m, R, W) u32
    -> (R, W) u32 packed node streams (threshold-gather formulation)."""
    r, n_rand = rand_words.shape
    l = cpt.shape[-1]
    m = parents.shape[0]
    assert l == 1 << m, (l, m)
    assert n_rand % 8 == 0
    w = n_rand // 8
    assert parents.shape == (m, r, w), (parents.shape, (m, r, w))
    block_r = min(block_r, r)
    assert r % block_r == 0, f"rows {r} not divisible by block {block_r}"
    grid = (r // block_r,)
    return pl.pallas_call(
        _node_mux_gather_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, l), lambda i: (i, 0)),
            pl.BlockSpec((block_r, n_rand), lambda i: (i, 0)),
            pl.BlockSpec((m, block_r, w), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, w), jnp.uint32),
        interpret=interpret,
    )(cpt, rand_words, parents)


@functools.partial(jax.jit, static_argnames=("block_r", "interpret"))
def node_mux_pallas(
    cpt: jnp.ndarray,
    rand_words: jnp.ndarray,
    parents: jnp.ndarray,
    *,
    block_r: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """cpt (R, L) f32, rand_words (R, L, n_rand) u32, parents (m, R, W) u32
    -> (R, W) u32 packed node streams."""
    r, l, n_rand = rand_words.shape
    m = parents.shape[0]
    assert l == 1 << m, (l, m)
    assert n_rand % 8 == 0
    w = n_rand // 8
    assert parents.shape == (m, r, w), (parents.shape, (m, r, w))
    block_r = min(block_r, r)
    assert r % block_r == 0, f"rows {r} not divisible by block {block_r}"
    grid = (r // block_r,)
    return pl.pallas_call(
        _node_mux_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_r, l), lambda i: (i, 0)),
            pl.BlockSpec((block_r, l, n_rand), lambda i: (i, 0, 0)),
            pl.BlockSpec((m, block_r, w), lambda i: (0, i, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, w), jnp.uint32),
        interpret=interpret,
    )(cpt, rand_words, parents)
