"""jit'd public wrappers for the node-MUX sweep (the bayesnet compiler's inner op).

``node_mux`` turns one binary Bayesian-network node into its packed stochastic
stream; ``node_mux_categorical`` generalises the gather mode to cardinality-k
nodes (value bit-planes sampled from one byte against the parent-gathered DAC
CDF).  The binary modes, identical in distribution:

* ``mode='gather'`` (default, production): gather the node's 8-bit DAC
  threshold by the parents' packed bits, then compare one entropy byte per
  stream bit -- ``2**m`` times less entropy than row-encode and no stream-wide
  MUX tree (the select collapses to a threshold gather).
* ``mode='rows'`` (the original formulation, kept as the statistical
  verification baseline): encode all ``2**m`` CPT rows with fresh entropy and
  MUX-select by the parents' packed streams (the n-ary Fig S8 tree).

Dispatch follows the other kernel ops: Pallas kernel where it compiles,
bit-exact jnp reference as the CPU production fallback.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import bitops, rng
from repro.kernels import backend
from repro.kernels.node_mux.kernel import (
    node_mux_cat_pallas,
    node_mux_gather_pallas,
    node_mux_pallas,
)
from repro.kernels.node_mux.ref import (
    node_mux_cat_ref,
    node_mux_gather_ref,
    node_mux_ref,
)


@functools.partial(jax.jit, static_argnames=("n_bits", "mode", "use_kernel", "interpret"))
def node_mux(
    key: jax.Array,
    cpt: jnp.ndarray,
    parents: jnp.ndarray,
    n_bits: int = 128,
    *,
    mode: str = "gather",
    use_kernel: bool | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Lower one network node to its packed stream.

    cpt:     (..., L) CPT rows P(node=1 | parent assignment), L = 2**m, row
             index with the FIRST parent as the most significant bit.
    parents: (m, ..., n_words) packed parent streams (leading dims match cpt).
    Returns (..., n_words) uint32.  n_bits must be a multiple of 32.

    ``mode='gather'`` draws ONE counter-entropy byte per stream bit and
    compares it against the parent-gathered threshold; ``mode='rows'`` draws
    fresh entropy per CPT row (one SNE per row) and MUX-selects.  Conditional
    on the parents' bits the output bit is Bernoulli(cpt[row]) either way and
    positions stay conditionally independent, so the two modes sample the
    same joint -- asserted statistically in tests.  The two modes consume
    differently-shaped entropy, so their streams are not bit-identical.
    """
    assert n_bits % 32 == 0, "kernel path consumes whole uint32 entropy words"
    if mode not in ("gather", "rows"):
        raise ValueError(f"unknown node_mux mode {mode!r}")
    interpret = backend.resolve_interpret(interpret)
    use_kernel = backend.resolve_use_kernel(use_kernel, interpret)
    cpt = jnp.asarray(cpt, jnp.float32)
    m = parents.shape[0]
    l = cpt.shape[-1]
    assert l == 1 << m, f"{l} CPT rows for {m} parents"
    lead = cpt.shape[:-1]
    w = n_bits // 32
    assert parents.shape == (m,) + lead + (w,), (parents.shape, lead)
    flat_cpt = cpt.reshape(-1, l)
    flat_par = parents.reshape(m, -1, w)
    rows = flat_cpt.shape[0]
    block = backend.pick_block(rows, 256)
    if mode == "gather":
        rand = rng.counter_hash_words(key, (rows,), n_bits // 4)
        if use_kernel:
            out = node_mux_gather_pallas(
                flat_cpt, rand, flat_par, block_r=block, interpret=interpret
            )
        else:
            out = node_mux_gather_ref(flat_cpt, rand, flat_par)
    else:
        rand = rng.counter_hash_words(key, (rows, l), n_bits // 4)
        if use_kernel:
            out = node_mux_pallas(flat_cpt, rand, flat_par, block_r=block, interpret=interpret)
        else:
            out = node_mux_ref(flat_cpt, rand, flat_par)
    return out.reshape(lead + (w,))


@functools.partial(
    jax.jit, static_argnames=("cards", "n_bits", "use_kernel", "interpret")
)
def node_mux_categorical(
    key: jax.Array,
    cdf: jnp.ndarray,
    parents: jnp.ndarray,
    *,
    cards: tuple,
    n_bits: int = 128,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Lower one cardinality-``k`` network node to its packed value bit-planes.

    cdf:     (..., L, k-1) uint32 non-increasing cumulative DAC thresholds per
             mixed-radix CPT row (``rng.cdf_thresholds_int``; L = product of
             parent cardinalities, first parent = most significant digit).
    parents: (P, ..., n_words) packed parent value bit-planes; parent ``j``
             owns the contiguous block of ``value_bits(k_j)`` planes, LSB
             first (leading dims match cdf's).
    cards:   static ``(k, k_p0, .., k_pm-1)`` -- node then parent cardinalities.
    Returns ``(value_bits(k),) + lead + (n_words,)`` uint32.

    The categorical generalisation of ``mode='gather'``: ONE counter-entropy
    byte per stream position samples the whole k-way draw against the
    parent-gathered CDF.  n_bits must be a multiple of 32.
    """
    assert n_bits % 32 == 0, "kernel path consumes whole uint32 entropy words"
    interpret = backend.resolve_interpret(interpret)
    use_kernel = backend.resolve_use_kernel(use_kernel, interpret)
    k = int(cards[0])
    pcards = tuple(int(c) for c in cards[1:])
    l = 1
    p = 0
    for c in pcards:
        l *= c
        p += bitops.value_bits(c)
    cdf = jnp.asarray(cdf, jnp.uint32)
    assert cdf.shape[-2:] == (l, k - 1), (cdf.shape, (l, k - 1))
    lead = cdf.shape[:-2]
    w = n_bits // 32
    assert parents.shape == (p,) + lead + (w,), (parents.shape, lead)
    flat_cdf = cdf.reshape((-1, l, k - 1))
    flat_par = parents.reshape(p, -1, w)
    rows = flat_cdf.shape[0]
    block = backend.pick_block(rows, 256)
    rand = rng.counter_hash_words(key, (rows,), n_bits // 4)
    if use_kernel:
        out = node_mux_cat_pallas(
            flat_cdf, rand, flat_par, cards=cards, block_r=block, interpret=interpret
        )
    else:
        out = node_mux_cat_ref(flat_cdf, rand, flat_par, cards)
    return out.reshape((out.shape[0],) + lead + (w,))
