"""Batched serving engine: prefill/decode with KV cache, continuous batching,
and the paper's Bayes decision head for timely-reliable emission.

The engine keeps a fixed pool of ``max_batch`` slots.  Requests are admitted
into free slots (continuous batching at step granularity); every engine step
decodes one token for all active slots.  When ``bayes_gate`` is on, per-slot
emission goes through ``models.bayes_head``: posteriors from the model's decision
sources (main head + temperature-perturbed ensemble source by default, MTP head
when the arch has one) are fused with eq (5) and a token is only *committed*
when fused confidence clears the threshold -- otherwise it is emitted as a
tentative token and flagged (the serving analogue of the paper's
"keep lane / change lane" reliability branch).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api, bayes_head


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 16
    out_tokens: Optional[list] = None
    confidences: Optional[list] = None
    done: bool = False


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_batch: int = 4
    t_cache: int = 128
    bayes_gate: bool = True
    confidence_threshold: float = 0.5
    ensemble_temp: float = 1.3         # second posterior source (perturbed)
    greedy: bool = True
    stochastic_gate: bool = False      # route the gate through the fused
    gate_n_bits: int = 256             # bayes_decide kernel (paper circuit)


class ServeEngine:
    def __init__(self, model_cfg, params, engine_cfg: EngineConfig):
        self.cfg = model_cfg
        self.params = params
        self.ecfg = engine_cfg
        self._decode = jax.jit(
            lambda tok, state, pos: api.decode(params, model_cfg, tok, state, pos)
        )
        self._prefill = jax.jit(
            lambda batch: api.prefill(params, model_cfg, batch, engine_cfg.t_cache)
        )
        self.slots: List[Optional[Request]] = [None] * engine_cfg.max_batch
        self.state = None
        self.pos = 0

    # ------------------------------------------------------------- admission
    def add_requests(self, requests: List[Request]):
        for r in requests:
            free = [i for i, s in enumerate(self.slots) if s is None]
            if not free:
                raise RuntimeError("no free slots (continuous batching full)")
            r.out_tokens, r.confidences = [], []
            self.slots[free[0]] = r

    def _batch_prompts(self) -> Dict[str, jnp.ndarray]:
        lens = [len(s.prompt) for s in self.slots if s is not None]
        maxlen = max(lens)
        toks = np.zeros((self.ecfg.max_batch, maxlen), np.int32)
        for i, s in enumerate(self.slots):
            if s is not None:
                toks[i, maxlen - len(s.prompt):] = s.prompt   # left-pad
        return {"tokens": jnp.asarray(toks)}

    # ---------------------------------------------------------------- serve
    def prefill_all(self):
        batch = self._batch_prompts()
        logits, self.state = self._prefill(batch)
        self.pos = batch["tokens"].shape[1]
        return logits

    def step(self, key, last_logits) -> Dict[int, tuple]:
        """One decode step for all active slots; returns {rid: (token, conf, ok)}."""
        if self.ecfg.bayes_gate:
            # two conditionally-independent posterior sources: the head itself
            # and a temperature-perturbed view (stand-in for MTP/modality heads)
            sources = jnp.stack(
                [last_logits, last_logits / self.ecfg.ensemble_temp], axis=0
            )
            if self.ecfg.stochastic_gate:
                # paper circuit end-to-end: one fused bayes_decide launch
                token, conf = bayes_head.fuse_posteriors_stochastic(
                    key, sources, top_k=8, n_bits=self.ecfg.gate_n_bits
                )
            else:
                token, conf, _ = bayes_head.fuse_posteriors(sources, top_k=8)
            ok, token = bayes_head.reliable_decision(
                token, conf, self.ecfg.confidence_threshold
            )
        else:
            token = jnp.argmax(last_logits, axis=-1)
            conf = jax.nn.softmax(last_logits, -1).max(-1)
            ok = jnp.ones_like(token, bool)
        logits, self.state = self._decode(token, self.state, jnp.int32(self.pos))
        self.pos += 1

        out = {}
        tok_np, conf_np, ok_np = np.asarray(token), np.asarray(conf), np.asarray(ok)
        for i, s in enumerate(self.slots):
            if s is None or s.done:
                continue
            s.out_tokens.append(int(tok_np[i]))
            s.confidences.append(float(conf_np[i]))
            out[s.rid] = (int(tok_np[i]), float(conf_np[i]), bool(ok_np[i]))
            if len(s.out_tokens) >= s.max_new_tokens:
                s.done = True
                self.slots[i] = None     # free the slot (continuous batching)
        return logits, out

    def run(self, key, requests: List[Request], max_steps: int | None = None):
        """Convenience driver: admit, prefill, decode until all done."""
        self.add_requests(requests)
        logits = self.prefill_all()
        steps = max_steps or max(r.max_new_tokens for r in requests)
        for t in range(steps):
            key, sub = jax.random.split(key)
            logits, _ = self.step(sub, logits)
            if all(s is None for s in self.slots):
                break
        return requests
