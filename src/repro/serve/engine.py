"""Batched serving engine: prefill/decode with KV cache, continuous batching,
and the paper's Bayes decision head for timely-reliable emission.

The engine keeps a fixed pool of ``max_batch`` slots.  Requests are admitted
into free slots (continuous batching at step granularity); every engine step
decodes one token for all active slots.  When ``bayes_gate`` is on, per-slot
emission goes through ``models.bayes_head``: posteriors from the model's decision
sources (main head + temperature-perturbed ensemble source by default, MTP head
when the arch has one) are fused with eq (5) and a token is only *committed*
when fused confidence clears the threshold -- otherwise it is emitted as a
tentative token and flagged (the serving analogue of the paper's
"keep lane / change lane" reliability branch).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import api, bayes_head


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (S,) int32
    max_new_tokens: int = 16
    out_tokens: Optional[list] = None
    confidences: Optional[list] = None
    done: bool = False
    admit_step: int = -1               # engine step at which a slot was granted


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_batch: int = 4
    t_cache: int = 128
    bayes_gate: bool = True
    confidence_threshold: float = 0.5
    ensemble_temp: float = 1.3         # second posterior source (perturbed)
    greedy: bool = True
    stochastic_gate: bool = False      # route the gate through the fused
    gate_n_bits: int = 256             # bayes_decide kernel (paper circuit)


class ServeEngine:
    def __init__(
        self, model_cfg, params, engine_cfg: EngineConfig,
        trace=None, metrics=None,
    ):
        self.cfg = model_cfg
        self.params = params
        self.ecfg = engine_cfg
        self._decode = jax.jit(
            lambda tok, state, pos: api.decode(params, model_cfg, tok, state, pos)
        )
        self._prefill = jax.jit(
            lambda batch: api.prefill(params, model_cfg, batch, engine_cfg.t_cache)
        )
        self.slots: List[Optional[Request]] = [None] * engine_cfg.max_batch
        self.pending: List[Request] = []   # admission queue (continuous batching)
        self.state = None
        self.pos = 0
        self.step_count = 0
        # optional telemetry (repro.obs); None keeps every path untouched
        self.trace = trace
        self.metrics = metrics

    def _gauge_queues(self):
        """Admission-side visibility: queue depth + slot occupancy gauges."""
        if self.metrics is not None:
            self.metrics.set_gauge("pending_depth", len(self.pending))
            self.metrics.set_gauge(
                "active_slots", sum(s is not None for s in self.slots)
            )

    # ------------------------------------------------------------- admission
    def add_requests(self, requests: List[Request]):
        """Admit into free slots; overflow waits in the pending queue.

        Queued requests are granted slots as decodes complete (``step`` calls
        ``_admit_pending`` after freeing slots) -- true continuous batching:
        submission never fails, admission happens at step granularity.
        """
        for r in requests:
            r.out_tokens, r.confidences = [], []
            self.pending.append(r)
        if self.metrics is not None:
            self.metrics.inc("requests_in", len(requests))
        if self.state is None:
            # before the first prefill, slots can be granted directly -- the
            # caller's prefill_all() encodes them.  Mid-flight, a slot grant
            # must come with a cache refresh, so step() handles admission.
            self._fill_free_slots()
        self._gauge_queues()

    def _fill_free_slots(self) -> bool:
        """Move pending requests into free slots; True if any were admitted."""
        admitted = False
        for i, s in enumerate(self.slots):
            if s is None and self.pending:
                r = self.pending.pop(0)
                r.admit_step = self.step_count
                self.slots[i] = r
                admitted = True
                if self.metrics is not None:
                    self.metrics.inc("requests_admitted")
        self._gauge_queues()
        return admitted

    def _admit_pending(self):
        """Grant freed slots to queued requests and (re)prefill the batch.

        Mid-flight admission re-encodes every active slot's prompt plus the
        tokens it has generated so far (recompute-style admission: one prefill
        refreshes the whole cache with the newcomer in place), then decoding
        continues for all slots from the refreshed logits.
        """
        if not self._fill_free_slots():
            return None
        return self.prefill_all()

    def _batch_prompts(self) -> Dict[str, jnp.ndarray]:
        # active context per slot = prompt + tokens generated so far
        ctx = [
            None if s is None else np.concatenate(
                [np.asarray(s.prompt, np.int32), np.asarray(s.out_tokens, np.int32)]
            )
            for s in self.slots
        ]
        maxlen = max(len(c) for c in ctx if c is not None)
        toks = np.zeros((self.ecfg.max_batch, maxlen), np.int32)
        for i, c in enumerate(ctx):
            if c is not None:
                toks[i, maxlen - len(c):] = c                 # left-pad
        return {"tokens": jnp.asarray(toks)}

    # ---------------------------------------------------------------- serve
    def prefill_all(self):
        batch = self._batch_prompts()
        if self.trace is not None:
            with self.trace.span("engine.prefill", tokens=batch["tokens"].shape[1]):
                logits, self.state = self._prefill(batch)
        else:
            logits, self.state = self._prefill(batch)
        self.pos = batch["tokens"].shape[1]
        if self.metrics is not None:
            self.metrics.inc("prefills")
        return logits

    def step(self, key, last_logits) -> Dict[int, tuple]:
        """One decode step for all active slots; returns {rid: (token, conf, ok)}."""
        if self.trace is None:
            return self._step_impl(key, last_logits)
        with self.trace.span(
            "engine.step", step=self.step_count, pending=len(self.pending)
        ):
            return self._step_impl(key, last_logits)

    def _step_impl(self, key, last_logits):
        if self.ecfg.bayes_gate:
            # two conditionally-independent posterior sources: the head itself
            # and a temperature-perturbed view (stand-in for MTP/modality heads)
            sources = jnp.stack(
                [last_logits, last_logits / self.ecfg.ensemble_temp], axis=0
            )
            if self.ecfg.stochastic_gate:
                # paper circuit end-to-end: one fused bayes_decide launch
                token, conf = bayes_head.fuse_posteriors_stochastic(
                    key, sources, top_k=8, n_bits=self.ecfg.gate_n_bits
                )
            else:
                token, conf, _ = bayes_head.fuse_posteriors(sources, top_k=8)
            ok, token = bayes_head.reliable_decision(
                token, conf, self.ecfg.confidence_threshold
            )
        else:
            token = jnp.argmax(last_logits, axis=-1)
            conf = jax.nn.softmax(last_logits, -1).max(-1)
            ok = jnp.ones_like(token, bool)
        logits, self.state = self._decode(token, self.state, jnp.int32(self.pos))
        self.pos += 1
        self.step_count += 1

        out = {}
        tok_np, conf_np, ok_np = np.asarray(token), np.asarray(conf), np.asarray(ok)
        for i, s in enumerate(self.slots):
            if s is None or s.done:
                continue
            s.out_tokens.append(int(tok_np[i]))
            s.confidences.append(float(conf_np[i]))
            out[s.rid] = (int(tok_np[i]), float(conf_np[i]), bool(ok_np[i]))
            if len(s.out_tokens) >= s.max_new_tokens:
                s.done = True
                self.slots[i] = None     # free the slot (continuous batching)
                if self.metrics is not None:
                    self.metrics.inc("requests_done")
        if self.metrics is not None:
            self.metrics.inc("tokens_out", len(out))
            self._gauge_queues()
        if self.pending and any(s is None for s in self.slots):
            refreshed = self._admit_pending()
            if refreshed is not None:
                logits = refreshed       # newcomers decode from the refreshed batch
        return logits, out

    def run(self, key, requests: List[Request], max_steps: int | None = None):
        """Convenience driver: admit (queueing overflow), decode until all done."""
        self.add_requests(requests)
        logits = self.prefill_all()
        active = [s for s in self.slots if s is not None] + self.pending
        steps = max_steps or sum(r.max_new_tokens for r in active)
        for t in range(steps):
            key, sub = jax.random.split(key)
            logits, _ = self.step(sub, logits)
            if all(s is None for s in self.slots) and not self.pending:
                break
        return requests
