from repro.serve.engine import EngineConfig, Request, ServeEngine  # noqa: F401
from repro.serve.router import (  # noqa: F401
    BayesRouter,
    RouterPolicy,
    RouterResult,
    tenant_salt,
)
