"""Fault-tolerant multi-tenant serving: one router, many networks (DESIGN §14).

:class:`BayesRouter` multiplexes many :class:`~repro.bayesnet.compile.
CompiledNetwork`\\ s behind one submit/harvest API.  Each *tenant* (a scenario
spec) gets a scenario-keyed plan-cache entry -- compiled lazily, LRU-evicted
only while idle -- and its own :class:`~repro.bayesnet.driver.FrameDriver`
whose entropy is isolated by the existing ``base_key``/``salt`` fold: the
tenant salt is a stable CRC of the scenario name, so a router tenant's
posteriors are *bit-identical* to a standalone per-scenario driver constructed
with the same ``(base_key, salt)`` (a gated property, not an aspiration).
Frames coalesce into the driver's power-of-two launch buckets exactly as they
would single-tenant.

The serving story is designed around things going wrong:

**Deadline-aware admission.**  Every request carries a deadline (default the
paper's 0.4 ms budget x ``RouterPolicy.deadline_mult``); the pending queue is
a deadline-ordered heap, not FIFO.  A request whose deadline cannot be met --
already expired, or the tenant's earliest dispatch time (backoff, open
breaker) plus its launch-time estimate (the driver watchdog's EWMA) lands past
it -- is shed with an explicit ``REJECTED`` status instead of silently
queued: under a hard deadline, an honest no now beats a useless yes later.

**Failure containment.**  Launch failures surface through the driver's
all-or-nothing harvest (:class:`~repro.bayesnet.driver.LaunchFailure`): the
router responds with failover re-dispatch under fresh entropy (the driver's
launch counter advanced, so a re-launch never replays the failed draw),
per-tenant capped exponential backoff, and a per-tenant circuit breaker that
trips after ``breaker_threshold`` consecutive failures.  A tripped tenant is
degraded -- its requests shed or deferred -- rather than allowed to poison
the shared queue; after ``breaker_cooldown_s`` the next batch is the
half-open probe whose outcome closes or re-trips the breaker.

**Graceful degradation.**  When the deadline-feasible queue exceeds
``capacity``, new launches are downgraded along an n_bits ladder
(``base / degrade_step^level``, floored and 32-aligned -- fewer bits = a
faster launch, the same knob :class:`~repro.bayesnet.reliability.RetryPolicy`
escalates in the other direction) and their results flagged ``DEGRADED``.

Every submitted frame therefore terminates in exactly one of
``OK | DEGRADED | UNRELIABLE | REJECTED``
(:data:`~repro.bayesnet.reliability.TERMINAL_STATUSES`) -- no frame is ever
silently dropped, extending the retry layer's never-drop invariant from the
frame to the fleet.  The invariant is CI-gated under seeded 5% launch-fault
chaos (``benchmarks/bench_serve.py``).

**Crossbar health (DESIGN §15).**  ``drift=DriftPolicy(...)`` gives every
tenant its own :class:`~repro.bayesnet.reliability.DriftMonitor`, fed by all
its ladder-rung drivers: per-launch confidence and accept-rate run through
CUSUM detectors and escalate a HEALTHY -> DRIFTING -> RECALIBRATING state
machine the router consumes alongside the circuit breaker.  When a noisy
tenant latches ``RECALIBRATING`` (and ``auto_recalibrate=True``), the next
harvest round hot-swaps a calibrate-back twin
(:func:`~repro.bayesnet.calibrate.recalibrated_network`, at the tenant's
launch-counter cycle estimate) into every rung driver between launches --
zero frames lost or reordered -- and resets the monitor.
:meth:`BayesRouter.recalibrate` is the manual trigger,
:meth:`BayesRouter.health` the state probe.

A degradation/retry interaction is also closed here: a DEGRADED tenant's
:class:`~repro.bayesnet.reliability.RetryPolicy` escalation is clamped to
the rung's n_bits (a degraded rung must not escalate its way back to full
fidelity through the retry back door); clamped frames carry
``FrameReport.escalation_clamped``.
"""

from __future__ import annotations

import dataclasses
import heapq
import time
import zlib
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple, Union

import jax
import numpy as np

from repro.bayesnet.calibrate import recalibrated_network
from repro.bayesnet.compile import CompiledNetwork, compile_network
from repro.bayesnet.driver import FrameDriver
from repro.bayesnet.noise import NoiseModel
from repro.bayesnet.reliability import (
    HEALTH_HEALTHY,
    HEALTH_RECALIBRATING,
    STATUS_DEGRADED,
    STATUS_OK,
    STATUS_REJECTED,
    STATUS_UNRELIABLE,
    TERMINAL_STATUSES,
    DriftMonitor,
    DriftPolicy,
    RetryPolicy,
)
from repro.bayesnet.scenarios import by_name
from repro.bayesnet.spec import NetworkSpec
from repro.distributed.fault import LaunchFaultInjector
from repro.obs import PAPER_BUDGET_MS, MetricsRegistry, Tracer


def tenant_salt(name: str) -> int:
    """Stable per-tenant entropy salt: CRC32 of the scenario name.

    A pure function of the name, so a router tenant and a standalone
    :class:`~repro.bayesnet.driver.FrameDriver` built with this salt (and the
    same ``base_key``) draw bit-identical launch entropy -- the router's
    bit-identity contract.
    """
    return zlib.crc32(name.encode()) & 0x7FFFFFFF


@dataclasses.dataclass(frozen=True)
class RouterPolicy:
    """Admission, degradation, and failure-response knobs.

    ``deadline_mult``: default request deadline as a multiple of the paper's
    0.4 ms budget (the default 2500x = 1 s absorbs host jitter; tighten it on
    quiet hardware).  ``capacity``: deadline-feasible queued frames above
    which new launches degrade; each further ``capacity`` frames of depth adds
    a degradation level, up to ``max_degrade``.  ``degrade_step``: n_bits
    divisor per level (floored at ``min_n_bits``, 32-aligned).
    ``breaker_threshold``: consecutive failed launches that trip a tenant's
    circuit breaker; ``breaker_cooldown_s``: how long a tripped tenant waits
    before its half-open probe.  ``backoff_base_s`` / ``backoff_cap_s``:
    capped exponential re-dispatch backoff after each failure.
    ``max_redispatch``: per-frame failed-launch budget before the frame is
    emitted flagged (:class:`~repro.bayesnet.driver.FrameDriver`'s knob).
    """

    deadline_mult: float = 2500.0
    capacity: int = 4096
    degrade_step: int = 4
    max_degrade: int = 2
    min_n_bits: int = 128
    breaker_threshold: int = 3
    breaker_cooldown_s: float = 0.25
    backoff_base_s: float = 0.002
    backoff_cap_s: float = 0.1
    max_redispatch: int = 3

    def __post_init__(self):
        if self.deadline_mult <= 0:
            raise ValueError(f"deadline_mult must be > 0, got {self.deadline_mult}")
        if self.capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {self.capacity}")
        if self.degrade_step < 2:
            raise ValueError(f"degrade_step must be >= 2, got {self.degrade_step}")
        if self.max_degrade < 0:
            raise ValueError(f"max_degrade must be >= 0, got {self.max_degrade}")
        if self.breaker_threshold < 1:
            raise ValueError(
                f"breaker_threshold must be >= 1, got {self.breaker_threshold}"
            )

    @property
    def default_deadline_s(self) -> float:
        return PAPER_BUDGET_MS * self.deadline_mult / 1e3


@dataclasses.dataclass(frozen=True)
class RouterResult:
    """One frame's terminal verdict: exactly one per submitted frame.

    ``status`` is one of :data:`~repro.bayesnet.reliability.TERMINAL_STATUSES`.
    ``post`` is ``None`` only for ``REJECTED`` (the frame never launched);
    an ``UNRELIABLE`` frame that exhausted its failover budget carries the
    zero posterior with ``accepted == 0``.  ``degrade_level`` is the n_bits
    ladder rung the frame was served at (0 = full fidelity);
    ``deadline_met`` whether the terminal verdict landed inside the request's
    deadline (always ``True`` for an admission-time ``REJECTED``: shedding
    *is* the in-deadline answer).
    """

    rid: int
    tenant: str
    status: str
    post: Optional[np.ndarray]
    accepted: int
    degrade_level: int
    latency_ms: float
    deadline_met: bool


@dataclasses.dataclass
class _Request:
    rid: int
    tenant: str
    row: np.ndarray
    deadline: float         # absolute perf_counter time
    t_submit: float
    dispatch_seq: int = -1  # global dispatch order (admission-order probe)
    level: int = 0


class _Tenant:
    """One scenario's serving state: plans per degrade level + breaker."""

    def __init__(self, router: "BayesRouter", spec: NetworkSpec, name: str,
                 salt: int, n_bits: int, noise: Optional[NoiseModel]):
        self.router = router
        self.spec = spec
        self.name = name
        self.salt = salt
        self.n_bits = n_bits
        self.noise = noise
        self.drivers: Dict[int, FrameDriver] = {}
        self.rid_map: Dict[Tuple[int, int], int] = {}  # (level, driver_rid) -> rid
        self._fail_cursor: Dict[int, int] = {}
        self.consecutive_failures = 0
        self.not_before = 0.0                  # backoff gate (abs time)
        self.breaker_open_until: Optional[float] = None
        self.trips = 0
        # one health monitor per tenant, shared by every ladder-rung driver
        self.monitor: Optional[DriftMonitor] = (
            DriftMonitor(router.drift, metrics=router.metrics, name=name)
            if router.drift is not None else None
        )
        self.recalibrations = 0

    # ------------------------------------------------------------------ plans
    def n_bits_for(self, level: int) -> int:
        p = self.router.policy
        n = self.n_bits // (p.degrade_step ** level)
        n = max(32, p.min_n_bits, (n // 32) * 32)
        return min(n, self.n_bits)

    def driver(self, level: int) -> Tuple[FrameDriver, int]:
        """The (lazily built, cached) driver for one ladder rung.

        Returns ``(driver, effective_level)``: a rung whose floored n_bits
        equals a shallower rung's collapses onto it, so "degraded" is never
        claimed without an actual fidelity cut.
        """
        while level > 0 and self.n_bits_for(level) == self.n_bits_for(level - 1):
            level -= 1
        d = self.drivers.get(level)
        if d is None:
            r = self.router
            if r.metrics is not None:
                r.metrics.inc("router_plan_compiles")
            rung = self.n_bits_for(level)
            net = compile_network(
                self.spec, rung, noise=self.noise, trace=r.trace,
            )
            retry = r.retry
            if level > 0 and retry is not None and retry.max_n_bits > rung:
                # a DEGRADED rung must not escalate past its own fidelity
                # cut: clamp the retry ladder to the rung's n_bits (frames
                # that hit the clamp carry FrameReport.escalation_clamped)
                retry = dataclasses.replace(retry, max_n_bits=rung)
            # level folds into the salt so ladder rungs draw disjoint
            # entropy; level 0 keeps the bare tenant salt -- the
            # bit-identity contract with a standalone driver
            d = FrameDriver(
                net, max_batch=r.max_batch, base_key=r.base_key,
                salt=self.salt + 7919 * level, retry=retry,
                trace=r.trace, metrics=r.metrics, fault=r.fault,
                max_redispatch=r.policy.max_redispatch,
                drift=self.monitor,
            )
            self.drivers[level] = d
            self._fail_cursor[level] = 0
        return d, level

    # ---------------------------------------------------------------- failure
    def earliest_dispatch(self, now: float) -> float:
        t = max(now, self.not_before)
        if self.breaker_open_until is not None:
            t = max(t, self.breaker_open_until)
        return t

    def launch_estimate(self) -> float:
        """Best-case launch wall time: the watchdog's steady-state floor.

        ``StragglerWatch.min_dt`` excludes the EWMA seed (where the one-off
        jit compile hides) and flagged stragglers, so this is the tenant's
        genuine capability floor -- optimistic by construction.  Admission
        sheds a request only when *even this best case* lands past its
        deadline; pessimistic estimates (the raw EWMA) were tried and shed
        healthy tenants forever after one 8-second compile seeded them.
        0.0 while cold: a tenant that has never launched is never presumed
        infeasible.
        """
        d = self.drivers.get(0)
        if d is None or d.watch.min_dt is None:
            return 0.0
        return float(d.watch.min_dt)

    @property
    def breaker_open(self) -> bool:
        return self.breaker_open_until is not None

    def idle(self) -> bool:
        return not self.rid_map and all(
            d.pending == 0 and d.in_flight == 0 and d.pending_retries == 0
            for d in self.drivers.values()
        )

    def new_failures(self) -> list:
        """Launch failures recorded by any rung's driver since the last scan."""
        out = []
        for level, d in self.drivers.items():
            cur = self._fail_cursor.get(level, 0)
            out.extend(d.launch_failures[cur:])
            self._fail_cursor[level] = len(d.launch_failures)
        return out

    # ----------------------------------------------------------------- health
    @property
    def health(self) -> str:
        """HEALTHY / DRIFTING / RECALIBRATING (HEALTHY when unmonitored)."""
        return self.monitor.state if self.monitor is not None else HEALTH_HEALTHY

    def cycle_estimate(self) -> int:
        """Crossbar wear estimate: total launches across every rung driver.

        One launch reads every device of the array once per stream position,
        so the launch count is the natural unit the noise model's ``cycle``
        axis advances in.
        """
        return sum(d.launches for d in self.drivers.values())

    def recalibrate(self, cycle: float | None = None) -> int:
        """Hot-swap a calibrate-back twin into every rung driver.

        ``cycle=None`` uses :meth:`cycle_estimate`.  Each rung's network is
        re-lowered at that cycle with a compensated program
        (:func:`~repro.bayesnet.calibrate.recalibrated_network`) and swapped
        between launches -- in-flight launches harvest against their
        original plan, so no frame is lost or reordered.  Resets the drift
        monitor (back to HEALTHY, baselines re-learned against the
        recalibrated array).  Returns the cycle used.  Raises if the tenant
        has no noise model: a clean tenant has no drift to calibrate back.
        """
        if self.noise is None:
            raise ValueError(
                f"tenant {self.name!r} has no noise model: nothing to recalibrate"
            )
        c = int(self.cycle_estimate() if cycle is None else cycle)
        for drv in self.drivers.values():
            drv.swap_net(recalibrated_network(drv.net, c))
        self.recalibrations += 1
        if self.monitor is not None:
            self.monitor.reset()
        r = self.router
        if r.metrics is not None:
            r.metrics.inc("router_recalibrations")
        if r.trace is not None:
            r.trace.event("router.recalibrate", tenant=self.name, cycle=c)
        return c


class BayesRouter:
    """Multi-tenant fault-tolerant frame router (module docstring).

    ``submit(scenario, frames, deadline_ms=...)`` -> rids;
    ``pump()`` runs one scheduling round (admit -> dispatch -> harvest);
    ``harvest()`` pops results terminal since the last call;
    ``drain()`` pumps until every submitted frame is terminal.
    ``results`` keeps every terminal :class:`RouterResult` for accounting.

    Tenants auto-register on first submit (scenario-library names or
    :class:`~repro.bayesnet.spec.NetworkSpec` objects); the plan cache holds
    ``max_cached_tenants`` compiled tenants and evicts least-recently-used
    *idle* tenants only -- a tenant with frames in flight is never evicted.
    Tenant salts persist across eviction, so a re-registered tenant keeps its
    entropy identity (its launch counter restarts, as any restart does).
    """

    def __init__(
        self,
        policy: RouterPolicy | None = None,
        base_key: jax.Array | None = None,
        *,
        n_bits: int = 4096,
        max_batch: int = 256,
        retry: RetryPolicy | None = None,
        fault: LaunchFaultInjector | None = None,
        trace: Tracer | None = None,
        metrics: MetricsRegistry | None = None,
        max_cached_tenants: int = 8,
        drift: DriftPolicy | None = None,
        auto_recalibrate: bool = True,
    ):
        if max_cached_tenants < 1:
            raise ValueError(
                f"max_cached_tenants must be >= 1, got {max_cached_tenants}"
            )
        if drift is not None and not isinstance(drift, DriftPolicy):
            raise TypeError(
                f"drift must be a DriftPolicy or None, got {type(drift)!r}"
            )
        self.policy = policy if policy is not None else RouterPolicy()
        self.base_key = (
            base_key if base_key is not None else jax.random.PRNGKey(0)
        )
        self.n_bits = int(n_bits)
        self.max_batch = int(max_batch)
        self.retry = retry
        self.fault = fault
        self.drift = drift
        self.auto_recalibrate = bool(auto_recalibrate)
        self.trace = trace
        if metrics is None and trace is not None:
            metrics = MetricsRegistry()
        self.metrics = metrics
        self.max_cached_tenants = int(max_cached_tenants)
        self._tenants: "OrderedDict[str, _Tenant]" = OrderedDict()
        self._salts: Dict[str, int] = {}       # survives eviction
        self._pending: list = []               # heap of (deadline, seq, rid)
        self._seq = 0
        self._dispatch_seq = 0
        self._next_rid = 0
        self.requests: Dict[int, _Request] = {}
        self.results: Dict[int, RouterResult] = {}
        self._fresh: Dict[int, RouterResult] = {}

    # ------------------------------------------------------------- tenants
    def register(
        self,
        scenario: Union[str, NetworkSpec],
        *,
        salt: int | None = None,
        n_bits: int | None = None,
        noise: NoiseModel | None = None,
    ) -> str:
        """Get-or-create a tenant; returns its name (LRU-touched).

        ``salt`` overrides the default CRC-of-name entropy salt (it persists
        across evictions either way).  ``n_bits`` / ``noise`` apply on first
        registration only -- a cached tenant's plans are already built.
        """
        name = scenario if isinstance(scenario, str) else scenario.name
        t = self._tenants.get(name)
        if t is not None:
            self._tenants.move_to_end(name)
            return name
        spec = by_name(scenario) if isinstance(scenario, str) else scenario
        if salt is not None:
            self._salts[name] = int(salt)
        else:
            self._salts.setdefault(name, tenant_salt(name))
        t = _Tenant(
            self, spec, name, self._salts[name],
            int(n_bits) if n_bits is not None else self.n_bits, noise,
        )
        self._tenants[name] = t
        if self.metrics is not None:
            self.metrics.inc("router_tenant_registrations")
            self.metrics.set_gauge("router_tenants", len(self._tenants))
        self._evict_idle()
        return name

    def _evict_idle(self) -> None:
        """LRU-evict idle tenants past capacity (live tenants are immune)."""
        while len(self._tenants) > self.max_cached_tenants:
            victim = next(
                (n for n, t in self._tenants.items() if t.idle()), None
            )
            if victim is None:   # everything busy: run over capacity
                return
            del self._tenants[victim]
            if self.metrics is not None:
                self.metrics.inc("router_tenant_evictions")
                self.metrics.set_gauge("router_tenants", len(self._tenants))

    def tenant(self, name: str) -> _Tenant:
        """The live tenant record (registers scenario-library names lazily)."""
        if name not in self._tenants:
            self.register(name)
        return self._tenants[name]

    # ----------------------------------------------------------- admission
    def submit(
        self,
        scenario: Union[str, NetworkSpec],
        frames,
        deadline_ms: float | None = None,
    ) -> List[int]:
        """Queue evidence frames for one tenant; returns rids.

        ``deadline_ms`` is relative to now (default
        ``policy.default_deadline_s``).  Requests that already cannot be
        scheduled inside their deadline -- expired on arrival, or the
        tenant's earliest dispatch plus its launch estimate lands past it --
        are shed immediately with ``REJECTED`` rather than silently queued.
        """
        name = self.register(scenario)
        t = self._tenants[name]
        frames = np.asarray(frames, np.int32)
        if frames.ndim == 1:
            frames = frames[None, :]
        now = time.perf_counter()
        deadline = now + (
            deadline_ms / 1e3 if deadline_ms is not None
            else self.policy.default_deadline_s
        )
        rids = []
        est = t.launch_estimate()
        for row in frames:
            rid = self._next_rid
            self._next_rid += 1
            req = _Request(rid, name, row, deadline, now)
            self.requests[rid] = req
            rids.append(rid)
            if t.earliest_dispatch(now) + est > deadline:
                self._finish(req, STATUS_REJECTED, None, 0, now)
                continue
            heapq.heappush(self._pending, (deadline, self._seq, rid))
            self._seq += 1
        if self.metrics is not None:
            self.metrics.inc("router_submitted", len(rids))
            self.metrics.set_gauge("router_pending", len(self._pending))
        if self.trace is not None:
            self.trace.event("router.submit", tenant=name, n=len(rids))
        return rids

    @property
    def pending(self) -> int:
        return len(self._pending)

    # ------------------------------------------------------------ the pump
    def pump(self) -> int:
        """One scheduling round: admit -> dispatch -> harvest.

        Returns the number of frames that reached a terminal status this
        round.  Admission walks the deadline heap in order: expired and
        infeasible requests shed as ``REJECTED``, dispatchable ones grouped
        per tenant (deadline order preserved within the group) and handed to
        the tenant's driver at the ladder rung the current queue depth
        demands; tenants inside a backoff window or an open breaker keep
        their feasible requests queued for a later round.
        """
        if self.trace is None:
            return self._pump_impl()
        with self.trace.span("router.pump", pending=len(self._pending)):
            return self._pump_impl()

    def _pump_impl(self) -> int:
        before = len(self.results)
        now = time.perf_counter()
        self._admit(now)
        self._dispatch(now)
        self._harvest_drivers()
        if self.metrics is not None:
            self.metrics.set_gauge("router_pending", len(self._pending))
        return len(self.results) - before

    def _degrade_level(self, depth: int) -> int:
        """Ladder rung for the current feasible queue depth (0 = nominal)."""
        return min(self.policy.max_degrade, depth // self.policy.capacity)

    def _admit(self, now: float) -> None:
        """Deadline-ordered admission from the heap into tenant drivers."""
        rounds: "OrderedDict[str, List[_Request]]" = OrderedDict()
        requeue: List[Tuple[float, int, int]] = []
        depth = len(self._pending)
        while self._pending:
            deadline, seq, rid = heapq.heappop(self._pending)
            req = self.requests[rid]
            if rid in self.results:
                continue
            t = self.tenant(req.tenant)
            est = t.launch_estimate()
            if deadline < now or t.earliest_dispatch(now) + est > deadline:
                # cannot be scheduled in time: shed explicitly, never queue
                self._finish(req, STATUS_REJECTED, None, 0, now)
                if self.metrics is not None:
                    self.metrics.inc(
                        "router_shed_expired" if deadline < now
                        else "router_shed_infeasible"
                    )
                continue
            if t.earliest_dispatch(now) > now:
                # feasible later (backoff / breaker cooldown): stay queued
                requeue.append((deadline, seq, rid))
                continue
            rounds.setdefault(req.tenant, []).append(req)
        for item in requeue:
            heapq.heappush(self._pending, item)
        level = self._degrade_level(depth)
        for name, reqs in rounds.items():
            t = self._tenants[name]
            probe = t.breaker_open
            drv, eff = t.driver(level)
            drv_rids = drv.submit(np.stack([r.row for r in reqs]))
            for req, dr in zip(reqs, drv_rids):
                t.rid_map[(eff, dr)] = req.rid
                req.level = eff
                req.dispatch_seq = self._dispatch_seq
                self._dispatch_seq += 1
            if probe and self.metrics is not None:
                self.metrics.inc("router_breaker_probes")

    def _dispatch(self, now: float) -> None:
        """Flush every dispatchable tenant's driver queues (async launches)."""
        for t in self._tenants.values():
            if t.earliest_dispatch(now) > now:
                continue
            for drv in t.drivers.values():
                while drv.pending or drv.pending_retries:
                    drv.step(block=False)

    def _harvest_drivers(self) -> None:
        """Harvest every tenant, map statuses, update breaker/backoff state."""
        p = self.policy
        for name, t in self._tenants.items():
            emitted = 0
            for level, drv in list(t.drivers.items()):
                if drv.in_flight == 0:
                    continue
                res = drv.harvest()
                t_now = time.perf_counter()
                for dr, (post, accepted) in res.items():
                    rid = t.rid_map.pop((level, dr), None)
                    if rid is None:
                        continue
                    req = self.requests[rid]
                    report = drv.reports.get(dr)
                    if report is not None and not report.reliable:
                        status = STATUS_UNRELIABLE
                    elif level > 0:
                        status = STATUS_DEGRADED
                    else:
                        status = STATUS_OK
                    self._finish(req, status, post, int(accepted), t_now)
                    emitted += 1
            fails = t.new_failures()
            now = time.perf_counter()
            if fails:
                t.consecutive_failures += len(fails)
                backoff = min(
                    p.backoff_cap_s,
                    p.backoff_base_s * 2 ** (t.consecutive_failures - 1),
                )
                t.not_before = now + backoff
                if (
                    t.consecutive_failures >= p.breaker_threshold
                    and not t.breaker_open
                ):
                    t.breaker_open_until = now + p.breaker_cooldown_s
                    t.trips += 1
                    if self.metrics is not None:
                        self.metrics.inc("router_breaker_trips")
                    if self.trace is not None:
                        self.trace.event(
                            "router.breaker_trip", tenant=name,
                            failures=t.consecutive_failures,
                        )
            elif emitted:
                # a clean harvest closes the loop: breaker shuts (the
                # half-open probe succeeded), backoff resets
                t.consecutive_failures = 0
                t.not_before = 0.0
                if t.breaker_open:
                    t.breaker_open_until = None
                    if self.metrics is not None:
                        self.metrics.inc("router_breaker_closes")
            if t.breaker_open and now >= t.breaker_open_until:
                # cooldown elapsed: half-open -- admission resumes, the next
                # batch is the probe (its harvest closes or re-trips above)
                pass
            if (
                self.auto_recalibrate
                and t.monitor is not None
                and t.noise is not None
                and t.monitor.state == HEALTH_RECALIBRATING
            ):
                # the detector latched: hot-swap calibrate-back twins into
                # every rung between launches (in-flight work unaffected)
                t.recalibrate()

    def _finish(
        self, req: _Request, status: str, post, accepted: int, now: float
    ) -> None:
        assert status in TERMINAL_STATUSES, status
        latency_ms = (now - req.t_submit) * 1e3
        met = status == STATUS_REJECTED or now <= req.deadline
        r = RouterResult(
            rid=req.rid, tenant=req.tenant, status=status, post=post,
            accepted=accepted, degrade_level=req.level,
            latency_ms=latency_ms, deadline_met=met,
        )
        self.results[req.rid] = r
        self._fresh[req.rid] = r
        mx = self.metrics
        if mx is not None:
            mx.inc(f"router_{status.lower()}")
            if not met:
                mx.inc("router_deadline_miss")
            if status != STATUS_REJECTED:
                mx.hist(
                    f"router_{req.tenant}_frame_ms", budget_ms=PAPER_BUDGET_MS
                ).observe(latency_ms)

    # -------------------------------------------------------------- health
    def health(self, scenario: str) -> str:
        """A tenant's drift-health state (HEALTHY when unmonitored)."""
        return self.tenant(scenario).health

    def recalibrate(self, scenario: str, cycle: float | None = None) -> int:
        """Manually hot-swap calibrate-back plans into one tenant's drivers.

        Returns the cycle the recalibration was fitted at (default the
        tenant's launch-counter estimate); see :meth:`_Tenant.recalibrate`.
        """
        return self.tenant(scenario).recalibrate(cycle)

    # ------------------------------------------------------------- results
    def harvest(self) -> Dict[int, RouterResult]:
        """Results that turned terminal since the last ``harvest`` call."""
        out = self._fresh
        self._fresh = {}
        return out

    def _live_work(self) -> bool:
        return bool(self._pending) or any(
            not t.idle() for t in self._tenants.values()
        )

    def drain(self, max_rounds: int = 100_000) -> Dict[int, RouterResult]:
        """Pump until every submitted frame is terminal; returns the fresh set.

        Backoff windows are honoured by sleeping to the earliest tenant gate
        when a round made no progress, so a drain through a failure storm
        converges instead of spinning.
        """
        out = self.harvest()
        for _ in range(max_rounds):
            if not self._live_work():
                return out
            progressed = self.pump()
            out.update(self.harvest())
            if progressed == 0 and self._live_work():
                now = time.perf_counter()
                gates = [
                    t.earliest_dispatch(now) for t in self._tenants.values()
                    if not t.idle()
                ]
                wait = min((g - now for g in gates), default=0.0)
                if wait > 0:
                    time.sleep(min(wait, 0.05))
        raise RuntimeError(
            f"router drain did not converge in {max_rounds} rounds "
            f"({len(self._pending)} pending)"
        )

    def status_counts(self) -> Dict[str, int]:
        """Terminal-status histogram over every result so far."""
        out = {s: 0 for s in TERMINAL_STATUSES}
        for r in self.results.values():
            out[r.status] += 1
        return out
