"""Counters, gauges, and latency histograms behind one registry.

The serving stack's instruments are deliberately boring: monotonically
increasing **counters** (frames in/out, launches, retry attempts per rung,
padded lanes, plan-cache hits, entropy words), point-in-time **gauges**
(pending-queue depth, active slots, in-flight launches), and
:class:`~repro.obs.histogram.LatencyHistogram` **histograms** keyed by name.
A registry is just a namespace for them -- drivers, the serve engine, the
straggler watchdog, and benchmark harnesses all write into whichever registry
they are handed, so one process-wide registry sees the whole picture and a
per-driver registry isolates one tenant.

Everything is optional-by-construction: instrumented code guards each touch
with ``if metrics is not None``, so the unobserved path never allocates.
"""

from __future__ import annotations

import csv
from typing import Dict, Optional

from repro.obs.histogram import LatencyHistogram


class MetricsRegistry:
    def __init__(self):
        self.counters: Dict[str, int] = {}
        self.gauges: Dict[str, float] = {}
        self.histograms: Dict[str, LatencyHistogram] = {}

    # -------------------------------------------------------------- counters
    def inc(self, name: str, n: int = 1) -> int:
        """Add ``n`` to counter ``name`` (created at 0); returns new value."""
        v = self.counters.get(name, 0) + n
        self.counters[name] = v
        return v

    def count(self, name: str) -> int:
        return self.counters.get(name, 0)

    # ---------------------------------------------------------------- gauges
    def set_gauge(self, name: str, value: float) -> None:
        self.gauges[name] = float(value)

    # ------------------------------------------------------------ histograms
    def hist(self, name: str, **kwargs) -> LatencyHistogram:
        """Get-or-create the named histogram (kwargs apply on first use)."""
        h = self.histograms.get(name)
        if h is None:
            h = self.histograms[name] = LatencyHistogram(**kwargs)
        return h

    def observe(self, name: str, ms: float, **kwargs) -> None:
        """Record one latency into the named histogram."""
        self.hist(name, **kwargs).observe(ms)

    # ------------------------------------------------------------- reporting
    def as_dict(self) -> dict:
        """Plain-data snapshot: counters, gauges, histogram summaries."""
        return {
            "counters": dict(sorted(self.counters.items())),
            "gauges": dict(sorted(self.gauges.items())),
            "histograms": {
                k: self.histograms[k].summary() for k in sorted(self.histograms)
            },
        }

    def write_hist_csv(self, path: str, extra: Optional[dict] = None) -> str:
        """Dump every histogram's non-empty bins as one CSV; returns ``path``.

        Columns: ``hist,bin_lo_ms,bin_hi_ms,count`` (plus any ``extra``
        key=value columns repeated on every row) -- the ``latency_hist.csv``
        artifact format the CI bench-smoke uploads.
        """
        extra = extra or {}
        with open(path, "w", newline="") as f:
            w = csv.writer(f)
            w.writerow(["hist", "bin_lo_ms", "bin_hi_ms", "count", *extra])
            for name in sorted(self.histograms):
                for lo, hi, c in self.histograms[name].rows():
                    w.writerow([name, f"{lo:.6g}", f"{hi:.6g}", c, *extra.values()])
        return path
