"""Lightweight, zero-dep telemetry for the serving path (DESIGN.md §13).

    trace.py      Tracer / Span -- nested sync spans + async (dispatch-to-
                  harvest) spans, Chrome/Perfetto JSON export, optional
                  jax.profiler TraceAnnotation passthrough
    metrics.py    MetricsRegistry -- counters / gauges / named histograms
    histogram.py  LatencyHistogram -- log-spaced streaming bins with exact
                  p50/p90/p99 while samples are retained, and the paper's
                  0.4 ms budget annotation (PAPER_BUDGET_MS)

Everything is off by default: instrumented layers take ``trace=None`` /
``metrics=None`` and the untouched path stays bit-identical (regression-
tested, not assumed).

The crossbar-health loop (DESIGN.md §15) publishes through the same
registry: each :class:`~repro.bayesnet.DriftMonitor` exports per-statistic
CUSUM gauges (``<name>_drift_score_*``, ``<name>_drift_state``) plus alarm /
reset counters, and the router adds ``router_recalibrations`` and the
driver ``net_swaps`` / ``escalation_clamped`` counters, so a dashboard can
watch a tenant walk HEALTHY -> DRIFTING -> RECALIBRATING and back.
"""

from repro.obs.histogram import (  # noqa: F401
    PAPER_BUDGET_MS,
    LatencyHistogram,
    percentile,
)
from repro.obs.metrics import MetricsRegistry  # noqa: F401
from repro.obs.trace import Span, Tracer  # noqa: F401
