"""Span tracer with JAX-async-aware timing and a Chrome/Perfetto exporter.

**Why dispatch/harvest split timing.**  Under jax's async dispatch, the host
returns from a jitted call microseconds after *enqueueing* the work; the
device (or the XLA CPU thread pool) finishes later, and the only honest
completion timestamp the host can observe is when something blocks on the
result (``block_until_ready`` / ``np.asarray`` at harvest).  Timing a launch
as ``t_after_call - t_before_call`` therefore measures queue insertion, not
inference, and timing it with a blocking call inside the loop destroys the
pipelining being measured.  The tracer's answer is *two kinds of spans*:

* **sync spans** (``with tracer.span(...)``): classic nested host-side
  regions, parented by the enclosing open span (a thread-local-free explicit
  stack -- the driver is single-threaded by design).
* **async spans** (``tracer.begin(...)`` / ``tracer.end(id)``): opened at
  dispatch, closed at harvest, on their own track.  Overlapping async spans
  in the exported trace ARE the pipeline: five in-flight launches render as
  five staggered bars, and the gap the host spends blocked shows up as the
  tail of the last one.  Nothing pretends device work finished before
  something observed that it did.

Spans are plain records (name, track, interval, parent id, attrs); export is
the Chrome trace event format (the JSON flavour Perfetto and
``chrome://tracing`` both load): one ``"X"`` complete event per finished
span, ``"i"`` instants for point events, and ``"M"`` metadata naming each
track.  ``Tracer(annotate=True)`` additionally wraps sync spans in
``jax.profiler.TraceAnnotation`` so the same region names land inside an XLA
profiler trace when one is being captured; the import is lazy and failure
degrades to plain spans (the obs layer itself never requires jax).
"""

from __future__ import annotations

import dataclasses
import json
import time
from contextlib import contextmanager
from typing import Any, Dict, List, Optional

_CURRENT = object()  # default parent sentinel: "whatever span is open"


@dataclasses.dataclass
class Span:
    """One traced region.  ``t_end is None`` while still open."""

    name: str
    span_id: int
    parent_id: Optional[int]
    track: str
    t_start: float
    t_end: Optional[float] = None
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    instant: bool = False

    @property
    def done(self) -> bool:
        return self.t_end is not None

    @property
    def dur_ms(self) -> float:
        if self.t_end is None:
            raise ValueError(f"span {self.name!r} still open")
        return (self.t_end - self.t_start) * 1e3


class Tracer:
    """Collects spans; off-path cost is one ``is None`` check at call sites.

    All instrumented layers take ``trace=None`` and skip every tracer touch
    when unset, so the traced and untraced programs execute the same jax
    computation -- bit-identity is structural, and the overhead bound is a
    regression-tested property of the *enabled* tracer.
    """

    def __init__(self, clock=time.perf_counter, annotate: bool = False):
        self._clock = clock
        self._spans: List[Span] = []
        self._stack: List[int] = []
        self._annotate = annotate
        self._annotation_cls = None  # resolved lazily on first sync span

    # -------------------------------------------------------------- recording
    def begin(
        self,
        name: str,
        parent=_CURRENT,
        track: str = "host",
        **attrs,
    ) -> int:
        """Open a span now and return its id (caller must :meth:`end` it).

        The async half of the dispatch/harvest split: the driver calls this
        at dispatch and ``end`` at harvest.  ``parent`` defaults to the
        innermost open *sync* span; pass ``parent=None`` for a root span or
        an explicit id to nest under a specific one (retry spans nest under
        the launch that flagged their frame).
        """
        pid = self._stack[-1] if parent is _CURRENT and self._stack else parent
        sp = Span(
            name=name,
            span_id=len(self._spans),
            parent_id=None if pid is _CURRENT else pid,
            track=track,
            t_start=self._clock(),
            attrs=attrs,   # **attrs is already a fresh dict; no copy needed
        )
        self._spans.append(sp)
        return sp.span_id

    def end(self, span_id: int, **attrs) -> Span:
        """Close an async span; extra attrs merge into the record."""
        sp = self._spans[span_id]
        if sp.t_end is not None:
            raise ValueError(f"span {sp.name!r} (id {span_id}) already ended")
        sp.t_end = self._clock()
        sp.attrs.update(attrs)
        return sp

    @contextmanager
    def span(self, name: str, parent=_CURRENT, track: str = "host", **attrs):
        """Nested sync span: parented by the enclosing open span."""
        sid = self.begin(name, parent=parent, track=track, **attrs)
        self._stack.append(sid)
        annotation = self._resolve_annotation(name)
        try:
            if annotation is not None:
                with annotation:
                    yield self._spans[sid]
            else:
                yield self._spans[sid]
        finally:
            self._stack.pop()
            self.end(sid)

    def event(self, name: str, track: str = "host", **attrs) -> int:
        """Zero-duration instant event (a ``ph: "i"`` mark in the export)."""
        sid = self.begin(name, track=track, **attrs)
        sp = self._spans[sid]
        sp.t_end = sp.t_start
        sp.instant = True
        return sid

    def _resolve_annotation(self, name: str):
        if not self._annotate:
            return None
        if self._annotation_cls is None:
            try:
                from jax.profiler import TraceAnnotation

                self._annotation_cls = TraceAnnotation
            except Exception:  # jax absent or too old: degrade silently
                self._annotate = False
                return None
        return self._annotation_cls(name)

    # -------------------------------------------------------------- querying
    @property
    def spans(self) -> List[Span]:
        """All spans in begin order (open ones included)."""
        return list(self._spans)

    @property
    def open_spans(self) -> List[Span]:
        return [s for s in self._spans if not s.done]

    def named(self, prefix: str) -> List[Span]:
        """Spans whose name starts with ``prefix``, in begin order."""
        return [s for s in self._spans if s.name.startswith(prefix)]

    def get(self, span_id: int) -> Span:
        return self._spans[span_id]

    def span_counts(self) -> Dict[str, int]:
        """Multiset of span names -- the async-vs-sync equality invariant:
        a sync and an async drain of the same workload must traverse the
        same launches/harvests, only on a different wall-clock schedule."""
        out: Dict[str, int] = {}
        for s in self._spans:
            out[s.name] = out.get(s.name, 0) + 1
        return out

    # ------------------------------------------------------------- exporting
    def to_chrome_trace(self) -> Dict[str, Any]:
        """Chrome trace event JSON (loadable by Perfetto / chrome://tracing).

        Tracks map to tids; timestamps are microseconds relative to the
        earliest span so traces from different processes line up at 0.
        """
        tracks: Dict[str, int] = {}
        events: List[Dict[str, Any]] = []
        t0 = min((s.t_start for s in self._spans), default=0.0)
        for s in self._spans:
            tid = tracks.setdefault(s.track, len(tracks) + 1)
            args = {k: _jsonable(v) for k, v in s.attrs.items()}
            args["span_id"] = s.span_id
            if s.parent_id is not None:
                args["parent_id"] = s.parent_id
            ev: Dict[str, Any] = {
                "name": s.name,
                "pid": 1,
                "tid": tid,
                "ts": (s.t_start - t0) * 1e6,
                "args": args,
            }
            if s.instant:
                ev.update(ph="i", s="t")
            else:
                # still-open spans export as zero-length with a marker attr
                # rather than vanishing from the artifact
                end = s.t_end if s.t_end is not None else s.t_start
                ev.update(ph="X", dur=(end - s.t_start) * 1e6)
                if s.t_end is None:
                    args["unfinished"] = True
            events.append(ev)
        meta = [
            {
                "ph": "M",
                "pid": 1,
                "tid": tid,
                "name": "thread_name",
                "args": {"name": track},
            }
            for track, tid in sorted(tracks.items(), key=lambda kv: kv[1])
        ]
        return {"traceEvents": meta + events, "displayTimeUnit": "ms"}

    def export_chrome_trace(self, path: str) -> str:
        """Write :meth:`to_chrome_trace` JSON to ``path``; returns ``path``."""
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)
        return path


def _jsonable(v):
    """Span attrs may carry numpy scalars etc.; coerce to JSON-safe types."""
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (tuple, list)):
        return [_jsonable(x) for x in v]
    try:
        return v.item()  # numpy / jax scalar
    except AttributeError:
        return repr(v)
