"""Streaming latency histograms with exact percentile extraction.

The serving path's headline number is a *tail* latency -- the paper's claim is
"every decision inside 0.4 ms", not "the average decision" -- so the histogram
keeps two representations at once:

* **log-spaced bins** (``bins_per_decade`` per decade between ``lo_ms`` and
  ``hi_ms``, plus explicit under/overflow): constant memory, streamable,
  exportable as the ``latency_hist.csv`` artifact, and the right shape for
  latencies whose interesting structure spans orders of magnitude (a 5 us
  fused launch and a 50 ms recompile belong on the same axis).
* **retained raw samples** (up to ``max_samples``): percentiles quoted against
  a budget must be *exact*, not bin-midpoint approximations -- a 0.4 ms gate
  read off a bin whose edges are 0.32/0.56 ms would be theatre.  While the
  sample buffer holds every observation (the common case: benchmark runs are
  a few thousand samples), :meth:`percentile` reproduces
  ``numpy.percentile(..., method='linear')`` exactly; past the cap it falls
  back to bin interpolation and says so via :attr:`exact`.

``budget_ms`` is an annotation, not a filter: it rides into ``summary()`` /
CSV so every exported histogram carries the paper's 0.4 ms bar next to the
measured tail (:data:`PAPER_BUDGET_MS`).

Zero dependencies (stdlib only): the histogram must be importable from
benchmark harnesses, CI smoke steps, and the driver alike.
"""

from __future__ import annotations

import math
from bisect import bisect_right
from typing import Dict, List, Optional, Sequence, Tuple

try:  # optional fast path for observe_many; the histogram never requires it
    import numpy as _np
except ImportError:  # pragma: no cover - numpy is always present in this repo
    _np = None

# The paper's timeliness claim: a decision every 0.4 ms (>= 2,500 fps).
PAPER_BUDGET_MS = 0.4

# (lo_ms, hi_ms, bins_per_decade) -> shared edges tuple (immutable, so safe)
_EDGE_CACHE: Dict[Tuple[float, float, int], Tuple[float, ...]] = {}


def percentile(samples: Sequence[float], q: float) -> float:
    """``numpy.percentile(samples, q, method='linear')`` on plain floats.

    Reimplemented (sorted copy + linear interpolation between closest ranks,
    numpy's exact formula) so the obs layer stays import-free; the test suite
    pins it against numpy on random samples.
    """
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"percentile q must be in [0, 100], got {q}")
    xs = sorted(samples)
    if not xs:
        raise ValueError("percentile of no samples")
    rank = (len(xs) - 1) * (q / 100.0)
    lo = math.floor(rank)
    hi = math.ceil(rank)
    if lo == hi:
        return float(xs[int(rank)])
    return float(xs[lo] + (xs[hi] - xs[lo]) * (rank - lo))


class LatencyHistogram:
    """Log-binned streaming histogram of millisecond latencies.

    ``observe(ms)`` is O(log n_bins); bins never reallocate.  Percentiles are
    exact (numpy-identical) while every sample fits in the retention buffer,
    bin-interpolated (with :attr:`exact` = False) after.
    """

    def __init__(
        self,
        lo_ms: float = 1e-3,
        hi_ms: float = 1e4,
        bins_per_decade: int = 8,
        budget_ms: Optional[float] = None,
        max_samples: int = 1 << 16,
    ):
        if not (0 < lo_ms < hi_ms):
            raise ValueError(f"need 0 < lo_ms < hi_ms, got {lo_ms}, {hi_ms}")
        if bins_per_decade < 1:
            raise ValueError("bins_per_decade must be >= 1")
        # edges[0] == lo_ms; the last edge lands on or just past hi_ms.  The
        # ladder is cached across instances: registries construct histograms
        # lazily inside latency-critical paths, and ~60 pow() calls per
        # construction is a measurable slice of the driver's overhead budget.
        ladder = (lo_ms, hi_ms, bins_per_decade)
        edges = _EDGE_CACHE.get(ladder)
        if edges is None:
            n = math.ceil(round(math.log10(hi_ms / lo_ms) * bins_per_decade, 9))
            edges = _EDGE_CACHE[ladder] = tuple(
                lo_ms * 10.0 ** (i / bins_per_decade) for i in range(n + 1)
            )
        self.edges: Tuple[float, ...] = edges
        # counts[0] = underflow (< lo_ms), counts[-1] = overflow (>= last edge)
        self.counts: List[int] = [0] * (len(edges) + 1)
        self.budget_ms = budget_ms
        self.max_samples = int(max_samples)
        self._edges_arr = None   # numpy copy of edges, built on first bulk use
        self._samples: List[float] = []
        self.n = 0
        self.total_ms = 0.0
        self.min_ms = math.inf
        self.max_ms = -math.inf
        self.under_budget = 0

    # ------------------------------------------------------------- recording
    def observe(self, ms: float) -> None:
        ms = float(ms)
        self.counts[bisect_right(self.edges, ms)] += 1
        self.n += 1
        self.total_ms += ms
        self.min_ms = min(self.min_ms, ms)
        self.max_ms = max(self.max_ms, ms)
        if self.budget_ms is not None and ms <= self.budget_ms:
            self.under_budget += 1
        if len(self._samples) < self.max_samples:
            self._samples.append(ms)

    def observe_many(self, ms_values: Sequence[float]) -> None:
        """Bulk :meth:`observe` -- vectorised when numpy is importable.

        The driver harvests whole launches (up to ``max_batch`` frame
        latencies at once); per-frame Python-loop observes would cost a
        measurable fraction of a sub-millisecond launch, which is exactly
        the overhead the obs layer is gated not to add.
        """
        if _np is None:
            for ms in ms_values:
                self.observe(ms)
            return
        vals = _np.asarray(ms_values, float).ravel()
        if vals.size == 0:
            return
        if self._edges_arr is None:
            self._edges_arr = _np.asarray(self.edges)
        idx = _np.searchsorted(self._edges_arr, vals, side="right")
        binc = _np.bincount(idx, minlength=len(self.counts))
        for i in _np.nonzero(binc)[0]:
            self.counts[int(i)] += int(binc[i])
        self.n += int(vals.size)
        self.total_ms += float(vals.sum())
        self.min_ms = min(self.min_ms, float(vals.min()))
        self.max_ms = max(self.max_ms, float(vals.max()))
        if self.budget_ms is not None:
            self.under_budget += int((vals <= self.budget_ms).sum())
        room = self.max_samples - len(self._samples)
        if room > 0:
            self._samples.extend(vals[:room].tolist())

    # ------------------------------------------------------------ extraction
    @property
    def exact(self) -> bool:
        """True while the retention buffer holds every observation."""
        return self.n == len(self._samples)

    @property
    def mean_ms(self) -> float:
        return self.total_ms / self.n if self.n else math.nan

    def percentile(self, q: float) -> float:
        """Latency at quantile ``q`` (exact while :attr:`exact` holds)."""
        if self.n == 0:
            raise ValueError("percentile of an empty histogram")
        if self.exact:
            return percentile(self._samples, q)
        # bin fallback: linear interpolation inside the bin holding rank q.
        # Under/overflow bins clamp to the observed extremes.
        rank = (self.n - 1) * (q / 100.0)
        seen = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if seen + c > rank:
                lo = self.min_ms if i == 0 else self.edges[i - 1]
                hi = self.max_ms if i == len(self.counts) - 1 else self.edges[i]
                lo, hi = max(lo, self.min_ms), min(hi, self.max_ms)
                return lo + (hi - lo) * ((rank - seen) / c)
            seen += c
        return self.max_ms

    @property
    def p50(self) -> float:
        return self.percentile(50.0)

    @property
    def p90(self) -> float:
        return self.percentile(90.0)

    @property
    def p99(self) -> float:
        return self.percentile(99.0)

    def budget_fraction(self) -> float:
        """Fraction of observations at or under ``budget_ms`` (nan if unset)."""
        if self.budget_ms is None or self.n == 0:
            return math.nan
        return self.under_budget / self.n

    def summary(self) -> Dict[str, float]:
        if self.n == 0:
            return {"n": 0}
        out = {
            "n": self.n,
            "mean_ms": self.mean_ms,
            "min_ms": self.min_ms,
            "max_ms": self.max_ms,
            "p50_ms": self.p50,
            "p90_ms": self.p90,
            "p99_ms": self.p99,
            "exact": self.exact,
        }
        if self.budget_ms is not None:
            out["budget_ms"] = self.budget_ms
            out["budget_fraction"] = self.budget_fraction()
        return out

    def rows(self) -> List[Tuple[float, float, int]]:
        """Non-empty ``(bin_lo_ms, bin_hi_ms, count)`` rows, CSV-ready.

        Underflow reports ``(0, lo_ms)``, overflow ``(last_edge, inf)``.
        """
        out = []
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            lo = 0.0 if i == 0 else self.edges[i - 1]
            hi = math.inf if i == len(self.counts) - 1 else self.edges[i]
            out.append((lo, hi, c))
        return out
