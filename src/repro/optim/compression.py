"""Stochastic-number gradient compression with error feedback.

Beyond-paper extension that reuses the paper's representation: a gradient tensor
is encoded as a *stochastic fixed-point number* -- int8 with Bernoulli (unbiased
stochastic) rounding, exactly an SNE quantisation of p = frac(g/scale) -- before
the cross-pod all-reduce, cutting the collective roofline term by 4x (bf16 ->
int8) at zero bias.  Residual quantisation error is fed back into the next step
(error feedback), which restores convergence to the uncompressed path.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

INT8_MAX = 127.0


def compress(key: jax.Array, grads: Any, residual: Any | None = None):
    """Encode grads (+carry residual) as (int8 tree, scales tree, new residual)."""
    leaves, treedef = jax.tree.flatten(grads)
    res_leaves = jax.tree.leaves(residual) if residual is not None else [None] * len(leaves)
    keys = jax.random.split(key, len(leaves))
    qs, scales, new_res = [], [], []
    for g, r, k in zip(leaves, res_leaves, keys):
        g = g.astype(jnp.float32)
        if r is not None:
            g = g + r
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / INT8_MAX
        x = g / scale
        lo = jnp.floor(x)
        frac = x - lo                       # in [0,1): the SNE probability
        up = jax.random.uniform(k, x.shape) < frac   # Bernoulli(p) bit
        q = jnp.clip(lo + up.astype(jnp.float32), -INT8_MAX, INT8_MAX)
        qs.append(q.astype(jnp.int8))
        scales.append(scale)
        new_res.append(g - q * scale)       # error feedback memory
    return (
        jax.tree.unflatten(treedef, qs),
        jax.tree.unflatten(treedef, scales),
        jax.tree.unflatten(treedef, new_res),
    )


def decompress(q_tree: Any, scales: Any) -> Any:
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s, q_tree, scales
    )


def compressed_mean(key: jax.Array, grads: Any, residual: Any, axis_name: str):
    """All-reduce-mean of int8-encoded grads over ``axis_name`` (inside shard_map
    / pmap contexts).  Returns (mean grads fp32, new residual)."""
    q, s, new_res = compress(key, grads, residual)
    summed = jax.tree.map(
        lambda x: jax.lax.psum(x.astype(jnp.float32), axis_name), q
    )
    n = jax.lax.psum(1, axis_name)
    mean = jax.tree.map(lambda x, sc: x * sc / n, summed, s)
    return mean, new_res
