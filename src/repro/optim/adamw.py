"""Sharded AdamW with fp32 master weights (functional, optax-free).

Optimizer state inherits each parameter's sharding (fp32 master + m + v), so
under FSDP the optimizer memory is fully sharded (ZeRO-3 equivalent).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: jnp.ndarray
    master: Any       # fp32 copy of params
    m: Any
    v: Any


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay to min_lr_ratio."""
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init(params) -> OptState:
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), master=master, m=zeros,
                    v=jax.tree.map(jnp.copy, zeros))


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def apply(grads, opt_state: OptState, cfg: AdamWConfig):
    """One AdamW update.  Returns (new_params_bf16, new_opt_state, metrics)."""
    step = opt_state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2

    m = jax.tree.map(
        lambda g, m_: b1 * m_ + (1 - b1) * g.astype(jnp.float32) * scale,
        grads, opt_state.m,
    )
    v = jax.tree.map(
        lambda g, v_: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32) * scale),
        grads, opt_state.v,
    )
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    master = jax.tree.map(
        lambda p, m_, v_: p - lr * (
            (m_ / bc1) / (jnp.sqrt(v_ / bc2) + cfg.eps) + cfg.weight_decay * p
        ),
        opt_state.master, m, v,
    )
    new_params = jax.tree.map(lambda p, mp: mp.astype(p.dtype), grads, master)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(step=step, master=master, m=m, v=v), metrics
