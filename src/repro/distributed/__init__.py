from repro.distributed import fault, sharding  # noqa: F401
