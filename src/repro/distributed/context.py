"""Ambient mesh context: lets model code apply sharding constraints / shard_map
EP without threading the mesh through every call signature.

Launchers do ``with mesh_context(mesh): jit(...).lower(...)``.  When no mesh is
active every helper is a no-op, so single-device tests run the same code path.
"""

from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: contextvars.ContextVar[Optional[Mesh]] = contextvars.ContextVar(
    "repro_mesh", default=None
)


def current_mesh() -> Optional[Mesh]:
    return _MESH.get()


@contextlib.contextmanager
def mesh_context(mesh: Mesh):
    token = _MESH.set(mesh)
    try:
        yield mesh
    finally:
        _MESH.reset(token)


def frame_mesh(devices: int | None = None) -> Mesh:
    """1-D mesh over the first ``devices`` local devices, axis ``"frames"``.

    The frame axis is the embarrassingly-parallel batch dimension of the
    bayesnet sweep (``compile_network(devices=...)`` shards over it); on a CPU
    host ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` provides the
    devices.  ``devices=None`` takes every local device.
    """
    devs = jax.devices()
    n = len(devs) if devices is None else int(devices)
    if not 1 <= n <= len(devs):
        raise ValueError(f"devices={devices} outside [1, {len(devs)}]")
    return Mesh(np.array(devs[:n]), ("frames",))


def batch_axes() -> Tuple[str, ...]:
    mesh = current_mesh()
    if mesh is None:
        return ()
    from repro.distributed import sharding as _sharding

    return _sharding.batch_axes(mesh)


def constrain(x, *spec):
    """with_sharding_constraint under the ambient mesh (no-op without one).

    Spec entries: "batch" expands to the batch axes; None / axis names pass
    through; axes not in the mesh are dropped.  Dims not divisible by their
    axis product fall back to replicated (e.g. decode's seq dim of 1).
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    sizes = dict(mesh.shape)
    resolved = []
    used: set = set()
    for dim, s in enumerate(spec):
        if s == "batch":
            ax = batch_axes()
            s = ax if len(ax) > 1 else (ax[0] if ax else None)
        if s is None:
            resolved.append(None)
            continue
        axes = s if isinstance(s, tuple) else (s,)
        # drop axes unknown to the mesh or already consumed by another dim
        axes = tuple(a for a in axes if a in sizes and a not in used)
        if not axes:
            resolved.append(None)
            continue
        total = 1
        for a in axes:
            total *= sizes[a]
        if dim < x.ndim and x.shape[dim] % total == 0:
            used.update(axes)
            resolved.append(axes if len(axes) > 1 else axes[0])
        else:
            resolved.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*resolved))
    )
