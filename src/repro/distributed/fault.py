"""Fault tolerance: preemption checkpointing, straggler watch, loss-spike rewind.

Mechanisms (all exercised by tests/train/test_fault_ckpt.py; ``StragglerWatch``
doubles as the bayesnet :class:`~repro.bayesnet.driver.FrameDriver`'s
launch-latency watchdog):

* ``PreemptionGuard`` -- SIGTERM/SIGINT sets a flag; the train loop checkpoints
  and exits cleanly at the next step boundary (standard TPU preemption flow).
* ``StragglerWatch``  -- wall-time EWMA per step; steps slower than
  ``threshold x`` the EWMA are flagged.  On a real fleet the runbook is: flag ->
  blocklist node -> restart from the last committed checkpoint with the elastic
  restore path (checkpoint/ckpt.py) on the surviving N-1 hosts.  Here the
  detection + the elastic-restore mechanics are what we can execute.
* ``SpikeRewind``     -- divergence guard: if loss exceeds ``factor x`` its EWMA
  for ``patience`` consecutive steps, signal a rewind to the last checkpoint
  (bad-node/bad-batch recovery at scale).
"""

from __future__ import annotations

import signal
import time
from typing import Optional


class PreemptionGuard:
    def __init__(self, install: bool = True):
        self.requested = False
        self._prev = {}
        if install:
            for sig in (signal.SIGTERM, signal.SIGINT):
                try:
                    self._prev[sig] = signal.signal(sig, self._handler)
                except ValueError:   # non-main thread (tests)
                    pass

    def _handler(self, signum, frame):
        self.requested = True

    def restore(self):
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)


class StragglerWatch:
    """Wall-time EWMA straggler detector.

    ``metrics`` (a :class:`~repro.obs.metrics.MetricsRegistry`) is optional:
    when set, every observed interval lands in the ``watch_step_ms``
    histogram and the ``watch_steps`` / ``watch_slow_steps`` counters, so the
    watchdog's verdicts are queryable next to the rest of the serving
    telemetry instead of living only in ``flagged_steps``.
    """

    def __init__(self, threshold: float = 3.0, alpha: float = 0.2, metrics=None):
        self.threshold = threshold
        self.alpha = alpha
        self.metrics = metrics
        self.ewma: Optional[float] = None
        self.flagged_steps: list[int] = []
        self._t0: Optional[float] = None

    def step_start(self):
        self._t0 = time.monotonic()

    def step_end(self, step: int) -> bool:
        # An un-started watch used to measure `now - now` and report a silent
        # 0.0 -- which then poisoned the EWMA toward zero and flagged every
        # real step as a straggler.  A missing step_start is a caller bug;
        # say so instead of fabricating a timing.
        if self._t0 is None:
            raise RuntimeError(
                "StragglerWatch.step_end() without a matching step_start(); "
                "an un-started watch has no interval to measure"
            )
        dt = time.monotonic() - self._t0
        self._t0 = None
        return self.observe(step, dt)

    def observe(self, step: int, dt: float) -> bool:
        """Record one interval directly (the timer-free entry point)."""
        slow = self.ewma is not None and dt > self.threshold * self.ewma
        if slow:
            self.flagged_steps.append(step)
        else:
            # EWMA excludes flagged outliers so one straggler doesn't mask
            # the next
            self.ewma = dt if self.ewma is None else (
                (1 - self.alpha) * self.ewma + self.alpha * dt
            )
        if self.metrics is not None:
            self.metrics.inc("watch_steps")
            if slow:
                self.metrics.inc("watch_slow_steps")
            self.metrics.observe("watch_step_ms", dt * 1e3)
        return slow


class SpikeRewind:
    def __init__(self, factor: float = 3.0, patience: int = 2, alpha: float = 0.1):
        self.factor = factor
        self.patience = patience
        self.alpha = alpha
        self.ewma: Optional[float] = None
        self._bad = 0

    def observe(self, loss: float) -> bool:
        """Returns True when the loop should rewind to the last checkpoint."""
        if self.ewma is None:
            self.ewma = loss
            return False
        if loss > self.factor * self.ewma:
            self._bad += 1
            if self._bad >= self.patience:
                self._bad = 0
                return True
            return False
        self._bad = 0
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * loss
        return False
