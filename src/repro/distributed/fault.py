"""Fault tolerance: preemption checkpointing, straggler watch, loss-spike
rewind, and seeded launch-level chaos injection.

Mechanisms (exercised by tests/train/test_fault_ckpt.py,
tests/distributed/test_straggler_warmup.py and the serving fault tests;
``StragglerWatch`` doubles as the bayesnet
:class:`~repro.bayesnet.driver.FrameDriver`'s launch-latency watchdog):

* ``PreemptionGuard`` -- SIGTERM/SIGINT sets a flag; the train loop checkpoints
  and exits cleanly at the next step boundary (standard TPU preemption flow).
* ``StragglerWatch``  -- wall-time EWMA per step; steps slower than
  ``threshold x`` the EWMA are flagged.  On a real fleet the runbook is: flag ->
  blocklist node -> restart from the last committed checkpoint with the elastic
  restore path (checkpoint/ckpt.py) on the surviving N-1 hosts.  Here the
  detection + the elastic-restore mechanics are what we can execute.
* ``SpikeRewind``     -- divergence guard: if loss exceeds ``factor x`` its EWMA
  for ``patience`` consecutive steps, signal a rewind to the last checkpoint
  (bad-node/bad-batch recovery at scale).
* ``LaunchFaultInjector`` -- seeded, rate-configurable chaos hook for the
  serving path: each launch draws a deterministic verdict (``None`` /
  ``"drop"`` / ``"stall"`` / ``"corrupt"``) from a counter-keyed PRNG, so a
  chaos run replays bit-for-bit and CI can gate the never-drop invariant
  under a fixed fault schedule.
"""

from __future__ import annotations

import signal
import time
from typing import Dict, Optional

import numpy as np


class PreemptionGuard:
    def __init__(self, install: bool = True):
        self.requested = False
        self._prev = {}
        if install:
            for sig in (signal.SIGTERM, signal.SIGINT):
                try:
                    self._prev[sig] = signal.signal(sig, self._handler)
                except ValueError:   # non-main thread (tests)
                    pass

    def _handler(self, signum, frame):
        self.requested = True

    def restore(self):
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)


class StragglerWatch:
    """Wall-time EWMA straggler detector.

    ``metrics`` (a :class:`~repro.obs.metrics.MetricsRegistry`) is optional:
    when set, every observed interval lands in the ``watch_step_ms``
    histogram and the ``watch_steps`` / ``watch_slow_steps`` counters, so the
    watchdog's verdicts are queryable next to the rest of the serving
    telemetry instead of living only in ``flagged_steps``.

    ``warmup_steps`` fixes the slow-first-step bug: the EWMA used to be
    seeded by the very first observation, so a slow first step (a jit
    compile, a cold cache) inflated the baseline by orders of magnitude and
    masked every later straggler until the EWMA decayed.  The first
    ``warmup_steps`` observations are never flagged and only collected; the
    EWMA is then seeded with their *mean*, so one cold outlier is averaged
    against the warm steps instead of becoming the baseline.
    ``warmup_steps=1`` is exactly the legacy behaviour (the first
    observation seeds the EWMA and is never flagged).

    ``min_dt`` tracks the fastest *steady-state* interval: the minimum over
    post-seed, non-flagged observations.  Warmup/seed observations (where a
    jit compile hides) and flagged stragglers are excluded, so it converges
    to the genuine capability floor of the step being watched -- the serve
    router uses it as an optimistic launch-time estimate for deadline
    admission (shed only what even a best-case launch cannot serve in time;
    an EWMA contaminated by one compile would shed everything forever).
    """

    def __init__(
        self,
        threshold: float = 3.0,
        alpha: float = 0.2,
        metrics=None,
        warmup_steps: int = 1,
    ):
        if warmup_steps < 1:
            raise ValueError(f"warmup_steps must be >= 1, got {warmup_steps}")
        self.threshold = threshold
        self.alpha = alpha
        self.metrics = metrics
        self.warmup_steps = int(warmup_steps)
        self.ewma: Optional[float] = None
        self.min_dt: Optional[float] = None
        self.flagged_steps: list[int] = []
        self._t0: Optional[float] = None
        self._warmup: list[float] = []

    def step_start(self):
        self._t0 = time.monotonic()

    def step_end(self, step: int) -> bool:
        # An un-started watch used to measure `now - now` and report a silent
        # 0.0 -- which then poisoned the EWMA toward zero and flagged every
        # real step as a straggler.  A missing step_start is a caller bug;
        # say so instead of fabricating a timing.
        if self._t0 is None:
            raise RuntimeError(
                "StragglerWatch.step_end() without a matching step_start(); "
                "an un-started watch has no interval to measure"
            )
        dt = time.monotonic() - self._t0
        self._t0 = None
        return self.observe(step, dt)

    def observe(self, step: int, dt: float) -> bool:
        """Record one interval directly (the timer-free entry point)."""
        if self.ewma is None:
            # warmup: collect without flagging, mean-seed once full
            self._warmup.append(dt)
            slow = False
            if len(self._warmup) >= self.warmup_steps:
                self.ewma = sum(self._warmup) / len(self._warmup)
                self._warmup = []
        elif dt > self.threshold * self.ewma:
            slow = True
            self.flagged_steps.append(step)
        else:
            # EWMA excludes flagged outliers so one straggler doesn't mask
            # the next
            slow = False
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
            self.min_dt = dt if self.min_dt is None else min(self.min_dt, dt)
        if self.metrics is not None:
            self.metrics.inc("watch_steps")
            if slow:
                self.metrics.inc("watch_slow_steps")
            self.metrics.observe("watch_step_ms", dt * 1e3)
        return slow


class CusumDetector:
    """One-sided CUSUM over a streaming statistic, with a warmup baseline.

    The classic change-point accumulator: the first ``warmup`` observations
    fix a baseline mean/std (never alarmed on), after which each observation
    is standardised, oriented (``direction=+1`` accumulates upward shifts,
    ``-1`` downward), and folded as ``S = max(0, S + z * direction - k)``.
    ``k`` is the slack in baseline sigmas -- drifts smaller than ``k`` decay
    back to zero, sustained larger shifts grow ``S`` linearly -- so callers
    compare :attr:`score` against their own thresholds (the
    :class:`~repro.bayesnet.reliability.DriftMonitor` uses two: alarm and
    escalate).  An :attr:`ewma` of the raw statistic rides along for
    telemetry.  The whole state is a pure function of the observation
    sequence -- no clocks, no RNG -- so a seeded chaos replay reproduces
    every score and alarm bit-for-bit.
    """

    def __init__(
        self,
        k: float = 0.5,
        direction: int = 1,
        warmup: int = 8,
        min_std: float = 1e-3,
        alpha: float = 0.2,
    ):
        if warmup < 1:
            raise ValueError(f"warmup must be >= 1, got {warmup}")
        if direction not in (1, -1):
            raise ValueError(f"direction must be +1 or -1, got {direction}")
        self.k = float(k)
        self.direction = int(direction)
        self.warmup = int(warmup)
        self.min_std = float(min_std)
        self.alpha = float(alpha)
        self.score = 0.0
        self.ewma: Optional[float] = None
        self.n = 0
        self.baseline_mean: Optional[float] = None
        self.baseline_std: Optional[float] = None
        self._warm: list[float] = []

    def observe(self, x: float) -> float:
        """Fold one observation; returns the updated CUSUM score."""
        x = float(x)
        self.n += 1
        self.ewma = x if self.ewma is None else (
            (1 - self.alpha) * self.ewma + self.alpha * x
        )
        if self.baseline_mean is None:
            self._warm.append(x)
            if len(self._warm) >= self.warmup:
                self.baseline_mean = float(np.mean(self._warm))
                self.baseline_std = max(float(np.std(self._warm)), self.min_std)
                self._warm = []
            return self.score
        z = (x - self.baseline_mean) / self.baseline_std
        self.score = max(0.0, self.score + z * self.direction - self.k)
        return self.score

    def reset(self, keep_baseline: bool = True) -> None:
        """Zero the accumulator; optionally restart the warmup baseline too."""
        self.score = 0.0
        if not keep_baseline:
            self.baseline_mean = None
            self.baseline_std = None
            self.ewma = None
            self.n = 0
            self._warm = []


#: fault kinds a :class:`LaunchFaultInjector` can inject, in draw order
LAUNCH_FAULTS = ("drop", "stall", "corrupt")


class LaunchFaultInjector:
    """Seeded launch-level chaos: deterministic drop/stall/corrupt verdicts.

    The serving layers ask ``draw(*ids)`` once per launch (the driver passes
    its ``(salt, ticket)`` pair) and receive ``None`` or one of
    ``LAUNCH_FAULTS``:

    * ``"drop"``    -- the launch never runs; its results never arrive
      (harvest raises :class:`LaunchFault`, the driver's recovery path
      re-enqueues the frames).
    * ``"stall"``   -- injected host-side latency of ``stall_ms`` before the
      dispatch completes, sized to trip the :class:`StragglerWatch`
      threshold (the launch itself still succeeds).
    * ``"corrupt"`` -- the harvested posterior buffer is overwritten with
      NaNs, so the driver's harvest validation must catch it (a silent
      pass-through would hand a poisoned posterior to the caller).

    Verdicts come from a PRNG keyed by ``(seed, *ids)`` -- NOT from shared
    stream state -- so the schedule is a pure function of the launch
    identity: two drivers sharing one injector cannot perturb each other's
    fault schedules, and a replay with the same salts sees the same faults.
    ``injected`` counts verdicts by kind for reporting.
    """

    def __init__(
        self,
        seed: int = 0,
        p_drop: float = 0.0,
        p_stall: float = 0.0,
        p_corrupt: float = 0.0,
        stall_ms: float = 20.0,
    ):
        for name, p in (("p_drop", p_drop), ("p_stall", p_stall),
                        ("p_corrupt", p_corrupt)):
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {p}")
        if p_drop + p_stall + p_corrupt > 1.0:
            raise ValueError(
                f"fault rates must sum to <= 1, got "
                f"{p_drop + p_stall + p_corrupt}"
            )
        self.seed = int(seed)
        self.p_drop = float(p_drop)
        self.p_stall = float(p_stall)
        self.p_corrupt = float(p_corrupt)
        self.stall_ms = float(stall_ms)
        self.injected: Dict[str, int] = {k: 0 for k in LAUNCH_FAULTS}

    def draw(self, *ids: int) -> Optional[str]:
        """Fault verdict for one launch identity; counts what it injects."""
        u = float(
            np.random.Generator(
                np.random.PCG64([self.seed, *(int(i) & 0xFFFFFFFF for i in ids)])
            ).random()
        )
        edge = 0.0
        for kind, p in (("drop", self.p_drop), ("stall", self.p_stall),
                        ("corrupt", self.p_corrupt)):
            edge += p
            if u < edge:
                self.injected[kind] += 1
                return kind
        return None


class LaunchFault(RuntimeError):
    """A launch failed to produce harvestable results (dropped / corrupted).

    ``kind`` is the failure class (one of :data:`LAUNCH_FAULTS` for injected
    faults, ``"invalid"`` for organically corrupted buffers caught by harvest
    validation); ``ticket`` the dispatch ordinal of the failed launch.
    """

    def __init__(self, kind: str, ticket: int, detail: str = ""):
        self.kind = kind
        self.ticket = ticket
        super().__init__(
            f"launch {ticket} failed ({kind})" + (f": {detail}" if detail else "")
        )


class SpikeRewind:
    def __init__(self, factor: float = 3.0, patience: int = 2, alpha: float = 0.1):
        self.factor = factor
        self.patience = patience
        self.alpha = alpha
        self.ewma: Optional[float] = None
        self._bad = 0

    def observe(self, loss: float) -> bool:
        """Returns True when the loop should rewind to the last checkpoint."""
        if self.ewma is None:
            self.ewma = loss
            return False
        if loss > self.factor * self.ewma:
            self._bad += 1
            if self._bad >= self.patience:
                self._bad = 0
                return True
            return False
        self._bad = 0
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * loss
        return False
