"""GPipe-style pipeline parallelism over a mesh axis (default: `pod`).

For topologies where the cross-pod fabric is ICI-class, the `pod` axis can run
pipeline stages instead of pure DP: layer stages are sharded over the axis,
microbatches stream through with ``lax.ppermute`` boundary transfers, and the
bubble is the standard (S-1)/(M+S-1) GPipe overhead.

The implementation is deliberately compact but real: it runs under shard_map,
moves activations with collective-permute (visible in the dry-run HLO), and is
verified against the unpipelined stack (tests/distributed/test_pipeline.py).
Forward-only here (inference / activation serving); training integration would
wrap it in jax.linearize per the standard recipe -- documented as future work.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_forward(
    stage_fn: Callable,
    stage_params,
    x: jnp.ndarray,
    mesh: Mesh,
    *,
    axis: str = "pod",
    microbatches: int | None = None,
):
    """Run ``stage_fn`` stages sharded over ``axis`` as a GPipe pipeline.

    stage_params: pytree stacked on the leading axis with size = mesh[axis]
                  (one slice per stage).
    x:            (M, B, ...) microbatched input; every stage must preserve the
                  activation shape (standard homogeneous-stage pipeline).
    Returns (M, B, ...) outputs of the final stage.
    """
    n_stages = mesh.shape[axis]
    m = x.shape[0]
    assert m >= 1
    other_axes = [a for a in mesh.axis_names if a != axis]

    def body(params_local, x_local):
        # params_local: this stage's params (leading axis stripped to size 1)
        params_stage = jax.tree.map(lambda p: p[0], params_local)
        stage = jax.lax.axis_index(axis)
        total_ticks = m + n_stages - 1
        buf = jnp.zeros_like(x_local[0])          # activation in flight
        outs = jnp.zeros_like(x_local)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (when in range)
            mb_index = jnp.clip(t, 0, m - 1)
            fresh = x_local[mb_index]
            take_fresh = jnp.logical_and(stage == 0, t < m)
            x_in = jnp.where(take_fresh, fresh, buf)
            y = stage_fn(params_stage, x_in)
            # last stage commits microbatch (t - n_stages + 1)
            out_index = jnp.clip(t - n_stages + 1, 0, m - 1)
            commit = jnp.logical_and(stage == n_stages - 1, t >= n_stages - 1)
            outs = jax.lax.dynamic_update_index_in_dim(
                outs,
                jnp.where(commit, y, outs[out_index]),
                out_index, 0,
            )
            # shift activations to the next stage
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            buf = jax.lax.ppermute(y, axis, perm)
            return buf, outs

        _, outs = jax.lax.fori_loop(0, total_ticks, tick, (buf, outs))
        # only the last stage holds committed outputs; broadcast them
        return jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)), axis
        )

    pspec = jax.tree.map(lambda _: P(axis), stage_params)
    return shard_map(
        body,
        mesh=mesh,
        in_specs=(pspec, P(*([None] * x.ndim))),
        out_specs=P(*([None] * x.ndim)),
        check_rep=False,
    )(stage_params, x)


def reference_forward(stage_fn, stage_params, x):
    """Unpipelined oracle: apply all stages sequentially to each microbatch."""
    n_stages = jax.tree.leaves(stage_params)[0].shape[0]

    def run_mb(xm):
        for s in range(n_stages):
            p = jax.tree.map(lambda q: q[s], stage_params)
            xm = stage_fn(p, xm)
        return xm

    return jax.vmap(run_mb)(x)
