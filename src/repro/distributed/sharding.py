"""Mesh-axis sharding rules (FSDP over `data`, TP/EP over `model`, DP over `pod`).

Param specs are derived from leaf names: each rule names the preferred mesh axis
for the trailing dimensions; any leading (stack/expert) dims fall back per rule.
A preferred axis is only applied when the dim is divisible by the mesh axis size
(e.g. 10 attention heads on a 16-way model axis fall back to replicated -- the
projection then shards its contracting dim instead via the `data` FSDP axis).
"""

from __future__ import annotations

from typing import Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# leaf name -> spec template for the trailing dims (applied right-aligned).
# "F" = fsdp axis ('data'), "T" = tensor axis ('model'), None = replicated.
_NAME_RULES = {
    "embed": ("T", "F"),          # (V, D)
    "unembed": ("F", "T"),        # (D, V)
    "wq": ("F", "T"),
    "wk": ("F", "T"),
    "wv": ("F", "T"),
    "wo": ("T", "F"),
    "bq": ("T",),
    "bk": ("T",),
    "bv": ("T",),
    "wi": ("F", "T"),
    "wg": ("F", "T"),
    # MLA
    "w_dq": ("F", "T"),
    "w_uq": ("T", None),
    "w_dkv": ("F", None),
    "w_ukv": (None, "T"),
    # RG-LRU / xLSTM
    "wx": ("F", "T"),
    "wy": ("F", "T"),
    "conv": (None, "T"),
    "w_input_gate": (None, "T"),
    "w_rec_gate": (None, "T"),
    "lambda_raw": ("T",),
    "w_up": ("F", "T"),
    "w_gate": ("F", "T"),
    "w_down": ("T", "F"),
    "w_i": (None, None),
    "w_f": (None, None),
    "w_z": ("F", "T"),
    "w_o": ("F", "T"),
    # MoE (trailing dims; expert dim handled by the leading-dim rule below)
    "router": ("F", None),
    "proj": ("F", "T"),
}

# leaves whose leading (first) dim is the expert axis -> shard over model (EP)
_EXPERT_LEAVES = {"wi", "wg", "wo"}

# Sharding policy knobs (set by launchers/variants before building shardings).
#   fsdp2d: drop TP; FSDP params over BOTH (data, model) axes and shard the
#   batch over both -- pure ZeRO-3 at 256-way (the SSPerf "fsdp2d" variant).
POLICY = {"fsdp2d": False}


def axis_name(mesh: Mesh, role: str):
    if POLICY["fsdp2d"]:
        if role == "F":
            return ("data", "model") if "model" in mesh.axis_names else "data"
        return None   # no TP axis in pure-FSDP mode
    if role == "F":
        return "data" if "data" in mesh.axis_names else None
    if role == "T":
        return "model" if "model" in mesh.axis_names else None
    return None


def batch_axes(mesh: Mesh):
    """Mesh axes the global batch is sharded over.

    A mesh carrying a ``"frames"`` axis (``context.frame_mesh``, the bayesnet
    sweep's frame-parallel fabric) batches over exactly that axis; the LM
    meshes batch over ``(pod,) data`` as before.
    """
    if "frames" in mesh.axis_names:
        return ("frames",)
    if POLICY["fsdp2d"]:
        return tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _leaf_name(path) -> str:
    for entry in reversed(path):
        key = getattr(entry, "key", None)
        if isinstance(key, str):
            return key
        if key is None:
            idx = getattr(entry, "idx", None)
            if idx is not None:
                continue
    return ""


def _path_has(path, name: str) -> bool:
    return any(getattr(e, "key", None) == name for e in path)


def _ax_size(sizes, ax) -> int:
    if isinstance(ax, tuple):
        out = 1
        for a in ax:
            out *= sizes[a]
        return out
    return sizes[ax]


def spec_for_leaf(path, shape: Tuple[int, ...], mesh: Mesh) -> P:
    name = _leaf_name(path)
    rule = _NAME_RULES.get(name)
    ndim = len(shape)
    spec = [None] * ndim
    sizes = dict(mesh.shape)
    if rule is not None:
        # right-align the rule on the trailing dims
        for i, role in enumerate(rule):
            dim = ndim - len(rule) + i
            if dim < 0 or role is None:
                continue
            ax = axis_name(mesh, role)
            if ax is not None and shape[dim] % _ax_size(sizes, ax) == 0:
                spec[dim] = ax
        # expert leading dim (stacked (L,) E, D, F leaves): the expert dim is
        # the dim right before the rule's trailing dims
        if name in _EXPERT_LEAVES and _path_has(path, "moe") and ndim >= 3:
            edim = ndim - len(rule) - 1
            ax = axis_name(mesh, "T")
            if edim >= 0 and ax is not None and shape[edim] % sizes[ax] == 0:
                # EP owns the model axis for expert weights: clear TP on F dim
                for i in range(ndim):
                    if spec[i] == ax:
                        spec[i] = None
                spec[edim] = ax
                # FSDP the (now TP-free) contracting dim if divisible and free
                fax = axis_name(mesh, "F")
                if fax is not None and fax not in spec and ndim - 2 >= 0 \
                        and spec[ndim - 2] is None \
                        and shape[ndim - 2] % sizes[fax] == 0:
                    spec[ndim - 2] = fax
    return P(*spec)


def param_shardings(params, mesh: Mesh):
    """NamedSharding pytree matching ``params`` (works on ShapeDtypeStructs too)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [spec_for_leaf(p, v.shape, mesh) for p, v in flat]
    return jax.tree_util.tree_unflatten(
        treedef, [NamedSharding(mesh, s) for s in specs]
    )


def param_specs(params, mesh: Mesh):
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    return jax.tree_util.tree_unflatten(
        treedef, [spec_for_leaf(p, v.shape, mesh) for p, v in flat]
    )


def batch_sharding(mesh: Mesh):
    """Inputs: tokens/labels (B, S) sharded over batch axes."""
    return NamedSharding(mesh, P(batch_axes(mesh)))


def state_specs_for_cache(state, mesh: Mesh):
    """Decode-state (KV cache / recurrent state) shardings.

    Batch dim is sharded over the batch axes.  KV-head / feature dims shard over
    `model` when divisible; otherwise, for batch=1 long-context, the sequence
    axis of k/v shards over `model` (cache too big to replicate).
    """
    sizes = dict(mesh.shape)
    baxes = batch_axes(mesh)
    bsize = int(np.prod([sizes[a] for a in baxes]))
    tsize = sizes.get("model", 1)

    # offset of the batch dim counted from the END, per leaf name (robust to an
    # optional leading stacked-layer axis): k/v are (..., B, T, KV, hd) etc.
    _BDIM_FROM_END = {
        "k": 4, "v": 4, "k_rope": 4, "latent": 3, "C": 4, "n": 3, "m": 2,
        "h": 2, "conv": 3, "c": 2,
    }

    def leaf_spec(path, v):
        name = _leaf_name(path)
        shape = v.shape
        ndim = len(shape)
        if name == "pos":
            return P()
        spec = [None] * ndim
        bdim = ndim - _BDIM_FROM_END.get(name, ndim)
        if 0 <= bdim < ndim and shape[bdim] % bsize == 0 and bsize > 1:
            spec[bdim] = baxes if len(baxes) > 1 else baxes[0]
        # kv caches: (..., T, KV, hd) or latents (..., T, R)
        if name in ("k", "v", "k_rope"):
            kv_dim, seq_dim = ndim - 2, ndim - 3
            if shape[kv_dim] % tsize == 0 and tsize > 1:
                spec[kv_dim] = "model"
            elif shape[seq_dim] % tsize == 0 and tsize > 1:
                spec[seq_dim] = "model"   # sequence-shard the cache
        elif name == "latent":
            seq_dim = ndim - 2
            if shape[seq_dim] % tsize == 0 and tsize > 1:
                spec[seq_dim] = "model"
        elif name == "C":  # mLSTM matrix memory (..., NH, DK, DV)
            if shape[-1] % tsize == 0 and tsize > 1:
                spec[-1] = "model"
        elif name in ("h", "n", "conv", "c", "m"):
            if shape[-1] % tsize == 0 and tsize > 1:
                spec[-1] = "model"
        return P(*spec)

    flat, treedef = jax.tree_util.tree_flatten_with_path(state)
    return jax.tree_util.tree_unflatten(
        treedef, [NamedSharding(mesh, leaf_spec(p, v)) for p, v in flat]
    )
