from repro.train.loop import TrainConfig, TrainLoop, make_train_step  # noqa: F401
