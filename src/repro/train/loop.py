"""Training loop: microbatched grad accumulation, sharded AdamW, fault hooks.

``make_train_step`` builds the jitted step (optionally under a mesh with full
FSDP/TP shardings); ``TrainLoop`` drives data, checkpointing, preemption,
straggler watch and loss-spike rewind.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.data.pipeline import DataConfig, batch_at_step
from repro.distributed import fault, sharding
from repro.models import api
from repro.optim import adamw


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    microbatches: int = 1            # grad-accumulation factor
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    remat: bool = True


def make_train_step(cfg, opt_cfg: adamw.AdamWConfig, microbatches: int = 1):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def loss_of(params, batch):
        return api.loss(params, cfg, batch)

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            def micro(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(loss_of, has_aux=True)(params, mb)
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), None

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mbs = jax.tree.map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:]),
                batch,
            )
            (gsum, lsum), _ = jax.lax.scan(micro, (zero, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
        else:
            (loss, _), grads = jax.value_and_grad(loss_of, has_aux=True)(params, batch)
        new_params, new_opt, om = adamw.apply(grads, opt_state, opt_cfg)
        metrics = {"loss": loss, **om}
        return new_params, new_opt, metrics

    return train_step


class TrainLoop:
    """Single-host driver with the full fault-tolerance surface."""

    def __init__(
        self,
        model_cfg,
        data_cfg: DataConfig,
        train_cfg: TrainConfig,
        opt_cfg: adamw.AdamWConfig | None = None,
        mesh=None,
    ):
        self.model_cfg = model_cfg
        self.data_cfg = data_cfg
        self.train_cfg = train_cfg
        self.opt_cfg = opt_cfg or adamw.AdamWConfig(total_steps=train_cfg.steps)
        self.mesh = mesh
        self.ckpt = Checkpointer(train_cfg.ckpt_dir)
        self.guard = fault.PreemptionGuard(install=False)
        self.straggler = fault.StragglerWatch()
        self.spike = fault.SpikeRewind()
        self.history: list[Dict[str, float]] = []

    def init_state(self, key):
        params = api.init(self.model_cfg, key)
        opt_state = adamw.init(params)
        return params, opt_state

    def run(self, key, start_step: int = 0, params=None, opt_state=None):
        if params is None:
            params, opt_state = self.init_state(key)
        step_fn = jax.jit(
            make_train_step(self.model_cfg, self.opt_cfg, self.train_cfg.microbatches)
        )
        step = start_step
        # resume from the latest committed checkpoint if present
        latest = self.ckpt.latest_step()
        if latest is not None and latest > start_step:
            latest, (params, opt_state) = self.ckpt.restore((params, opt_state), latest)
            step = latest

        while step < self.train_cfg.steps:
            self.straggler.step_start()
            batch = batch_at_step(self.data_cfg, step)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            self.straggler.step_end(step)
            self.history.append({"step": step, "loss": loss})

            if self.spike.observe(loss):
                # divergence: rewind to last committed checkpoint
                latest = self.ckpt.latest_step()
                if latest is not None:
                    latest, (params, opt_state) = self.ckpt.restore((params, opt_state))
                    step = latest
                    continue
            step += 1
            if step % self.train_cfg.ckpt_every == 0 or self.guard.requested:
                self.ckpt.save(step, (params, opt_state))
            if self.guard.requested:
                self.ckpt.wait()
                break
        self.ckpt.wait()
        return params, opt_state, self.history
