"""Deterministic, resumable synthetic data pipeline.

Every batch is a pure function of (seed, step): restart/elastic-resize needs no
iterator state -- a restored job at step N regenerates batch N exactly, and a
resharded job slices the same global batch differently.  Host-sharded loading is
modelled by ``host_slice``; a background prefetch thread keeps ``depth`` batches
ready (compute/IO overlap).
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    global_batch: int = 8
    seq_len: int = 128
    vocab_size: int = 512
    # multimodal stubs
    frontend: str = "none"       # none | patch | frame
    n_extra: int = 0             # patch count / frame count
    d_model: int = 0


def batch_at_step(cfg: DataConfig, step: int) -> Dict[str, jnp.ndarray]:
    """Global batch for ``step`` (pure function -- the resumability contract).

    Synthetic LM data with learnable structure: a shifted-window token process
    (next token depends on the previous one), so small models can overfit and
    integration tests can assert loss decreases.
    """
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    k1, k2, k3 = jax.random.split(key, 3)
    base = jax.random.randint(
        k1, (cfg.global_batch, cfg.seq_len), 0, max(cfg.vocab_size // 4, 2)
    )
    drift = jnp.cumsum(jax.random.randint(k2, (cfg.global_batch, cfg.seq_len), 0, 2), axis=1)
    tokens = (base + drift) % cfg.vocab_size
    labels = jnp.roll(tokens, -1, axis=1)
    out = {"tokens": tokens, "labels": labels}
    if cfg.frontend in ("patch", "frame") and cfg.n_extra and cfg.d_model:
        out["extra_embeds"] = (
            jax.random.normal(k3, (cfg.global_batch, cfg.n_extra, cfg.d_model), jnp.float32)
            * 0.02
        )
    return out


def host_slice(batch: Dict[str, jnp.ndarray], host_id: int, n_hosts: int):
    """The shard of the global batch this host would load (multi-host posture)."""
    def sl(x):
        per = x.shape[0] // n_hosts
        return x[host_id * per : (host_id + 1) * per]

    return {k: sl(v) for k, v in batch.items()}


class Prefetcher:
    """Background-thread prefetch of ``depth`` upcoming batches."""

    def __init__(self, cfg: DataConfig, start_step: int = 0, depth: int = 2):
        self.cfg = cfg
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            batch = jax.tree.map(np.asarray, batch_at_step(self.cfg, step))
            try:
                self._q.put((step, batch), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2.0)
