from repro.data import detection, pipeline  # noqa: F401
from repro.data.pipeline import DataConfig, Prefetcher, batch_at_step  # noqa: F401
