"""Synthetic FLIR-like RGB/thermal detection maps (the Fig 4 / Movie S1 data).

The real FLIR dataset is not available offline; we generate aligned RGB/thermal
per-pixel obstacle-probability maps with the failure modes the paper describes:
RGB misses targets at night / harsh lighting, thermal misses targets without
heat emission.  Ground truth is known, so fusion miss-rate/confidence gains are
measurable (benchmarks/bench_fig4_fusion.py).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SceneConfig:
    height: int = 64
    width: int = 64
    n_obstacles: int = 6
    night_fraction: float = 0.5     # scenes at night (RGB visibility drops)
    rgb_vis_day: float = 0.95       # P(obstacle clearly visible to RGB), day
    rgb_vis_night: float = 0.50     # ... at night (harsh lighting, low light)
    thermal_vis: float = 0.55       # P(clear heat signature) -- cold targets
    strong: float = 0.85            # detector confidence on a clear target
    weak: float = 0.52              # "insufficient evidence", NOT a confident
                                    # rejection -- the regime fusion can rescue


def make_scene(key: jax.Array, cfg: SceneConfig):
    """Returns (gt (H, W) {0,1}, p_rgb (H, W), p_thermal (H, W), night flag).

    Failure modes are independent per obstacle and per modality (the paper's
    Fig 4 setting): a missed target yields a *weak* confidence around 0.5
    (insufficient evidence), so conditionally-independent fusion (eq 5) can
    recover targets that either single modality loses.
    """
    k1, k2, k3, k4, k5, k6, k7 = jax.random.split(key, 7)
    h, w = cfg.height, cfg.width
    yy, xx = jnp.mgrid[0:h, 0:w]
    cy = jax.random.randint(k1, (cfg.n_obstacles,), 4, h - 4)
    cx = jax.random.randint(k2, (cfg.n_obstacles,), 4, w - 4)
    rad = jax.random.randint(k3, (cfg.n_obstacles,), 2, 6)
    night = jax.random.uniform(k5, ()) < cfg.night_fraction
    rgb_vis = jnp.where(night, cfg.rgb_vis_night, cfg.rgb_vis_day)
    rgb_clear = jax.random.uniform(k4, (cfg.n_obstacles,)) < rgb_vis
    th_clear = jax.random.uniform(k7, (cfg.n_obstacles,)) < cfg.thermal_vis

    dist2 = (yy[None] - cy[:, None, None]) ** 2 + (xx[None] - cx[:, None, None]) ** 2
    inside = dist2 <= (rad[:, None, None] ** 2)                 # (N, H, W)
    gt = jnp.any(inside, axis=0).astype(jnp.float32)

    rgb_strength = jnp.where(rgb_clear, cfg.strong, cfg.weak)[:, None, None]
    th_strength = jnp.where(th_clear, cfg.strong, cfg.weak)[:, None, None]
    rgb_det = jnp.max(inside * rgb_strength, axis=0)
    th_det = jnp.max(inside * th_strength, axis=0)

    noise = 0.06 * jax.random.uniform(k6, (2, h, w))
    p_rgb = jnp.clip(rgb_det * (1 - noise[0]) + noise[0] * 0.5, 0.02, 0.98)
    p_th = jnp.clip(th_det * (1 - noise[1]) + noise[1] * 0.5, 0.02, 0.98)
    # background base rate
    p_rgb = jnp.where(gt > 0, p_rgb, 0.05 + noise[0])
    p_th = jnp.where(gt > 0, p_th, 0.05 + noise[1])
    return gt, p_rgb, p_th, night


def detection_metrics(gt: jnp.ndarray, p: jnp.ndarray, thresh: float = 0.6):
    """(detection rate on gt pixels, false-positive rate, mean confidence on gt)."""
    det = (p > thresh).astype(jnp.float32)
    tp = jnp.sum(det * gt) / jnp.maximum(jnp.sum(gt), 1)
    fp = jnp.sum(det * (1 - gt)) / jnp.maximum(jnp.sum(1 - gt), 1)
    conf = jnp.sum(p * gt) / jnp.maximum(jnp.sum(gt), 1)
    return tp, fp, conf
