"""Production mesh construction.

Defined as functions (not module constants) so importing this module never
touches jax device state.  Single pod: 16 x 16 = 256 v5e chips (data x model).
Multi-pod: 2 x 16 x 16 = 512 chips with a leading `pod` axis -- only gradient
all-reduce (and optional pipeline collectives) cross the pod boundary.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(*, shape=(2, 2), axes=("data", "model")):
    """Small mesh for multi-device subprocess tests (8 host devices)."""
    return jax.make_mesh(shape, axes)


# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
