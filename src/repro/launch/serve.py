"""Serving launcher: ``python -m repro.launch.serve --arch <id> --smoke``.

Batched requests through the ServeEngine with the Bayes-gated timely-reliable
decision head (the paper's operator at the LM decision layer).
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import api
from repro.serve import EngineConfig, Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-72b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--threshold", type=float, default=0.5)
    ap.add_argument("--no-gate", action="store_true")
    ap.add_argument("--stochastic-gate", action="store_true",
                    help="gate through the fused bayes_decide kernel "
                         "(the paper's SC circuit) instead of the analytic path")
    ap.add_argument("--gate-bits", type=int, default=256)
    args = ap.parse_args()
    if args.gate_bits % 32 != 0 or args.gate_bits <= 0:
        ap.error(f"--gate-bits must be a positive multiple of 32 "
                 f"(got {args.gate_bits}); the packed pipeline consumes whole "
                 f"uint32 entropy words")

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = api.init(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(
        cfg, params,
        EngineConfig(
            max_batch=args.requests, t_cache=128,
            bayes_gate=not args.no_gate, confidence_threshold=args.threshold,
            stochastic_gate=args.stochastic_gate, gate_n_bits=args.gate_bits,
        ),
    )
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=8).astype(np.int32),
                max_new_tokens=args.new_tokens)
        for i in range(args.requests)
    ]
    engine.run(jax.random.PRNGKey(1), reqs)
    for r in reqs:
        reliable = sum(c >= args.threshold for c in r.confidences)
        print(f"req {r.rid}: {len(r.out_tokens)} tokens, "
              f"{reliable}/{len(r.confidences)} cleared the reliability gate, "
              f"mean conf {np.mean(r.confidences):.2f}")


if __name__ == "__main__":
    main()
