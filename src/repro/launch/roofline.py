"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds:

  compute    = HLO_FLOPs(total) / (chips x 197 TFLOP/s)
  memory     = HLO_bytes(total) / (chips x 819 GB/s)
  collective = collective_bytes_per_chip / 50 GB/s-per-link

HLO_FLOPs/bytes come from ``compiled.cost_analysis()`` (the partitioned module
-> per-device numbers; multiplied back to totals for reporting).  Collective
bytes are parsed from the post-SPMD HLO text: per-device bytes moved, counting
ring all-reduce as 2x payload and all-gather/reduce-scatter/all-to-all/
collective-permute as 1x (the (n-1)/n factor is folded to 1 at n >= 16).
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, Tuple

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

# collective opcodes; -start variants counted, -done skipped (same transfer)
_COLLECTIVE_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\][^\s]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Tuple[int, Dict[str, int]]:
    """Per-device collective bytes moved, by op kind."""
    by_kind: Dict[str, int] = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        shape_str, kind, _ = m.group(1), m.group(2), m.group(3)
        nbytes = _shape_bytes(shape_str)
        factor = 2 if kind == "all-reduce" else 1
        by_kind[kind] = by_kind.get(kind, 0) + factor * nbytes
    return sum(by_kind.values()), by_kind


def collective_counts(hlo_text: str) -> Dict[str, int]:
    counts: Dict[str, int] = {}
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        kind = m.group(2)
        counts[kind] = counts.get(kind, 0) + 1
    return counts


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    collective_bytes_per_chip: float
    collective_by_kind: Dict[str, int]
    model_flops_total: float
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    useful_ratio: float = 0.0
    peak_memory_bytes: float = 0.0

    def finalize(self):
        self.compute_s = self.flops_per_chip / PEAK_FLOPS_BF16
        self.memory_s = self.bytes_per_chip / HBM_BW
        self.collective_s = self.collective_bytes_per_chip / ICI_BW
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        self.bottleneck = max(terms, key=terms.get)
        hlo_total = self.flops_per_chip * self.chips
        self.useful_ratio = (
            self.model_flops_total / hlo_total if hlo_total > 0 else 0.0
        )
        return self

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def from_compiled(
    arch: str, shape: str, mesh_name: str, chips: int,
    compiled, model_flops_total: float,
) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):       # older API returns one dict per device
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    cbytes, by_kind = collective_bytes(hlo)
    mem = {}
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_size": getattr(ma, "argument_size_in_bytes", 0),
            "output_size": getattr(ma, "output_size_in_bytes", 0),
            "temp_size": getattr(ma, "temp_size_in_bytes", 0),
            "generated_code_size": getattr(ma, "generated_code_size_in_bytes", 0),
        }
    except Exception:
        pass
    peak = float(mem.get("argument_size", 0) + mem.get("output_size", 0)
                 + mem.get("temp_size", 0))
    r = Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_chip=flops, bytes_per_chip=nbytes,
        collective_bytes_per_chip=float(cbytes),
        collective_by_kind=by_kind,
        model_flops_total=model_flops_total,
        peak_memory_bytes=peak,
    ).finalize()
    return r
