"""Training launcher: ``python -m repro.launch.train --arch <id> [--smoke]``.

Single-host driver wired for the production posture: sharded params/optimizer
under the ambient mesh, deterministic resumable data, async checkpointing,
preemption guard, straggler watch, loss-spike rewind (see train/loop.py).
On this CPU container use --smoke (reduced config); full configs are exercised
via launch.dryrun.
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, get_smoke_config
from repro.data.pipeline import DataConfig
from repro.optim import adamw
from repro.train.loop import TrainConfig, TrainLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-72b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    data_cfg = DataConfig(
        seed=0, global_batch=args.global_batch, seq_len=args.seq_len,
        vocab_size=cfg.vocab_size,
        frontend=cfg.frontend,
        n_extra=(4 if cfg.frontend == "patch"
                 else args.seq_len // cfg.enc_ratio if cfg.frontend == "frame" else 0),
        d_model=cfg.d_model,
    )
    train_cfg = TrainConfig(
        steps=args.steps, ckpt_every=max(args.steps // 4, 1),
        ckpt_dir=args.ckpt_dir, microbatches=args.microbatches,
    )
    opt_cfg = adamw.AdamWConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                                total_steps=args.steps)
    loop = TrainLoop(cfg, data_cfg, train_cfg, opt_cfg)
    loop.guard.__init__(install=True)  # SIGTERM -> checkpoint + clean exit
    params, _, history = loop.run(jax.random.PRNGKey(0))
    print(f"arch={cfg.name} steps={len(history)} "
          f"first_loss={history[0]['loss']:.3f} last_loss={history[-1]['loss']:.3f}")
    if loop.straggler.flagged_steps:
        print(f"straggler-flagged steps: {loop.straggler.flagged_steps}")


if __name__ == "__main__":
    main()
