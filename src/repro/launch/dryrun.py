import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import (jax locks the device
# count at first init).  512 host devices back the 2x16x16 production mesh.

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import functools     # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np   # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import SHAPES_BY_NAME, get_config  # noqa: E402
from repro.configs.base import ShapeConfig  # noqa: E402
from repro.distributed import context as dctx  # noqa: E402
from repro.distributed import sharding  # noqa: E402
from repro.launch import roofline as rf  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import api, transformer  # noqa: E402
from repro.optim import adamw  # noqa: E402

LM_ARCHS = (
    "qwen2-72b", "starcoder2-15b", "minitron-4b", "phi3-mini-3.8b",
    "internvl2-26b", "recurrentgemma-2b", "xlstm-350m",
    "llama4-scout-17b-a16e", "deepseek-v3-671b", "seamless-m4t-large-v2",
)

# long_500k needs sub-quadratic state; skips per DESIGN.md SS4
LONG_OK = {"recurrentgemma-2b", "xlstm-350m", "llama4-scout-17b-a16e"}

N_PATCH = 256  # internvl2 stub patch embeddings


def cell_is_runnable(arch: str, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and arch not in LONG_OK and arch != "paper-bayes-fusion":
        return False, "pure full-attention arch: 512k-token cache skip (DESIGN.md SS4)"
    return True, ""


# ----------------------------------------------------------------- input specs

def input_specs(arch: str, shape: ShapeConfig, cfg) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    if arch == "paper-bayes-fusion":
        pixels = cfg.frames_per_batch * cfg.height * cfg.width
        return {
            "p_modal": jax.ShapeDtypeStruct((cfg.modalities, pixels, cfg.classes), f32),
            "rand": jax.ShapeDtypeStruct(
                (cfg.modalities, pixels, cfg.classes, cfg.n_bits // 4), jnp.uint32
            ),
        }
    if shape.kind in ("train", "prefill"):
        out = {"tokens": jax.ShapeDtypeStruct((b, _text_len(cfg, s)), i32)}
        if shape.kind == "train":
            out["labels"] = jax.ShapeDtypeStruct((b, _text_len(cfg, s)), i32)
        extra = _extra_len(cfg, s)
        if extra:
            out["extra_embeds"] = jax.ShapeDtypeStruct((b, extra, cfg.d_model), f32)
        return out
    # decode: one new token against a cache of length s
    return {"token": jax.ShapeDtypeStruct((b,), i32),
            "pos": jax.ShapeDtypeStruct((), i32)}


def _text_len(cfg, s: int) -> int:
    return s - N_PATCH if cfg.family == "vlm" else s


def _extra_len(cfg, s: int) -> int:
    if cfg.family == "vlm":
        return N_PATCH
    if cfg.family == "audio":
        return s // cfg.enc_ratio
    return 0


# --------------------------------------------------------------- step builders

def make_train_fn(cfg, microbatches: int = 1):
    opt_cfg = adamw.AdamWConfig()

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            def micro(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(
                    lambda p: api.loss(p, cfg, mb), has_aux=True
                )(params)
                return (jax.tree.map(jnp.add, g_acc, g), l_acc + l), None

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            mbs = jax.tree.map(
                lambda x: x.reshape(
                    (microbatches, x.shape[0] // microbatches) + x.shape[1:]
                ),
                batch,
            )
            if cfg.unroll_layers:   # calibration: count every microbatch
                carry = (zero, 0.0)
                for i in range(microbatches):
                    carry, _ = micro(carry, jax.tree.map(lambda x: x[i], mbs))
                gsum, lsum = carry
            else:
                (gsum, lsum), _ = jax.lax.scan(micro, (zero, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
        else:
            (loss, _), grads = jax.value_and_grad(
                lambda p: api.loss(p, cfg, batch), has_aux=True
            )(params)
        new_params, new_opt, metrics = adamw.apply(grads, opt_state, opt_cfg)
        return new_params, new_opt, metrics["grad_norm"], loss

    return train_step


def make_bayes_fn(cfg, path: str = "both", rng_inside: bool = False):
    """Movie-S1-scale fusion step (pure-jnp path of the kernels).

    path:      "both" (stochastic circuit + analytic oracle), "stochastic",
               or "analytic" (the production recommendation -- SSPerf finding).
    rng_inside: fold entropy generation into the step (in-kernel PRNG on real
               TPUs) instead of streaming pre-drawn words from HBM.
    """
    from repro.kernels.fusion_map.ref import fusion_map_ref
    from repro.kernels.pand_popcount.ref import pand_popcount_ref
    from repro.kernels.sne_encode.ref import sne_encode_ref

    prior_of = lambda p: jnp.full((p.shape[-1],), 1.0 / p.shape[-1], jnp.float32)

    if path == "analytic":
        def bayes_step(p_modal):
            analytic = fusion_map_ref(p_modal, prior_of(p_modal))
            return jnp.argmax(analytic, -1), jnp.max(analytic, -1), analytic

        return bayes_step

    def stochastic(p_modal, rand):
        m = p_modal.shape[0]
        streams = sne_encode_ref(p_modal, rand)      # (M, pixels, K, W)
        counts = pand_popcount_ref(
            streams.reshape(m, -1, streams.shape[-1])
        ).reshape(p_modal.shape[1:])                 # (pixels, K)
        cf = counts.astype(jnp.float32)
        stoch = cf / jnp.maximum(cf.sum(-1, keepdims=True), 1.0)
        out = (jnp.argmax(stoch, -1), jnp.max(stoch, -1))
        if path == "both":
            return out + (fusion_map_ref(p_modal, prior_of(p_modal)),)
        return out + (stoch,)

    if rng_inside:
        def bayes_step(p_modal):
            rand = jax.random.bits(
                jax.random.PRNGKey(0),
                p_modal.shape + (cfg.n_bits // 4,), jnp.uint32,
            )
            return stochastic(p_modal, rand)

        return bayes_step

    return stochastic


# ---------------------------------------------------------------- model flops

def model_flops(cfg, shape: ShapeConfig, params_shapes) -> float:
    """Analytic MODEL_FLOPS: 6*N*D train (2*N*D forward-only), MoE uses N_active."""
    flat, _ = jax.tree_util.tree_flatten_with_path(params_shapes)
    total = expert = embed = 0.0
    for path, leaf in flat:
        n = float(np.prod(leaf.shape))
        keys = [getattr(e, "key", None) for e in path]
        total += n
        if "moe" in keys and any(k in ("wi", "wg", "wo") for k in keys):
            expert += n
        if keys[-1] == "embed":
            embed += n
    if cfg.moe is not None:
        active = total - expert + expert * cfg.moe.top_k / cfg.moe.num_experts
    else:
        active = total
    n_eff = active - embed  # embedding gather is not a matmul
    if shape.kind == "train":
        return 6.0 * n_eff * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_eff * shape.global_batch * shape.seq_len
    tokens = shape.global_batch
    attn = 0.0
    if cfg.family != "ssm":
        hd = cfg.resolved_head_dim
        attn = 4.0 * shape.global_batch * shape.seq_len * cfg.num_heads * hd * cfg.num_layers
    return 2.0 * n_eff * tokens + attn


# -------------------------------------------------------------------- lowering

def _batch_spec(mesh, v):
    bax = sharding.batch_axes(mesh)
    return NamedSharding(mesh, P(bax) if v.ndim == 2 else P(bax, None, None))


def _batch_div(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in sharding.batch_axes(mesh)]))


def _init_state_abstract(cfg, batch: int, t_cache: int):
    if cfg.family == "audio":
        from repro.models import layers as L

        hd = cfg.resolved_head_dim
        enc_len = t_cache // cfg.enc_ratio
        return {
            "self": jax.tree.map(
                lambda z: jnp.stack([z] * cfg.dec_layers),
                L.init_kv_cache(batch, t_cache, cfg.num_kv_heads, hd),
            ),
            "cross": {
                "k": jnp.zeros((cfg.dec_layers, batch, enc_len, cfg.num_kv_heads, hd), jnp.bfloat16),
                "v": jnp.zeros((cfg.dec_layers, batch, enc_len, cfg.num_kv_heads, hd), jnp.bfloat16),
            },
        }
    return transformer.init_decode_state(cfg, batch, t_cache)


def build_lowered(cfg, shape: ShapeConfig, mesh, arch: str, microbatches: int = 1):
    """Lower the cell's step function (train/prefill/decode) under the mesh."""
    specs = input_specs(arch, shape, cfg)
    params_shapes = jax.eval_shape(functools.partial(api.init, cfg), jax.random.PRNGKey(0))
    pshard = sharding.param_shardings(params_shapes, mesh)

    with dctx.mesh_context(mesh):
        if shape.kind == "train":
            opt_shapes = jax.eval_shape(adamw.init, params_shapes)
            oshard = adamw.OptState(
                step=NamedSharding(mesh, P()), master=pshard, m=pshard, v=pshard
            )
            bshard = {k: _batch_spec(mesh, v) for k, v in specs.items()}
            lowered = jax.jit(
                make_train_fn(cfg, microbatches), in_shardings=(pshard, oshard, bshard)
            ).lower(params_shapes, opt_shapes, specs)
        elif shape.kind == "prefill":
            bshard = {k: _batch_spec(mesh, v) for k, v in specs.items()}
            fn = lambda params, batch: api.prefill(params, cfg, batch, shape.seq_len)
            lowered = jax.jit(fn, in_shardings=(pshard, bshard)).lower(
                params_shapes, specs
            )
        else:
            state_shapes = jax.eval_shape(
                lambda: _init_state_abstract(cfg, shape.global_batch, shape.seq_len)
            )
            sshard = sharding.state_specs_for_cache(state_shapes, mesh)
            tok_shard = NamedSharding(
                mesh,
                P(sharding.batch_axes(mesh))
                if shape.global_batch % _batch_div(mesh) == 0 else P(),
            )
            fn = lambda params, token, state, pos: api.decode(params, cfg, token, state, pos)
            lowered = jax.jit(
                fn, in_shardings=(pshard, tok_shard, sshard, NamedSharding(mesh, P()))
            ).lower(params_shapes, specs["token"], state_shapes, specs["pos"])
    return lowered, params_shapes


def reduced_cfg(cfg, r: int):
    """Full-width, depth-r-repetitions, unrolled config for cost calibration."""
    big = 1 << 30
    if cfg.family == "audio":
        return dataclasses.replace(
            cfg, enc_layers=r, dec_layers=r, num_layers=2 * r,
            unroll_layers=True, q_chunk=big, mlstm_chunk=big,
        )
    n = len(cfg.prefix_kinds) + r * len(cfg.pattern)
    return dataclasses.replace(
        cfg, num_layers=n, unroll_layers=True, q_chunk=big, mlstm_chunk=big,
    )


def _measure(cfg, shape, mesh, arch, microbatches: int = 1):
    """(flops, bytes, collective_bytes) per chip for one lower+compile."""
    lowered, _ = build_lowered(cfg, shape, mesh, arch, microbatches)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    cbytes, by_kind = rf.collective_bytes(compiled.as_text())
    return (
        float(cost.get("flops", 0.0)),
        float(cost.get("bytes accessed", 0.0)),
        float(cbytes),
        by_kind,
    )


def calibrate(cfg, shape, mesh, arch, microbatches: int = 1):
    """Exact per-chip (flops, bytes, collective bytes) via unrolled reps 1 & 2.

    XLA's cost analysis counts while-loop bodies once, so the production scan
    lower undercounts by ~num_layers.  Unrolled reduced-depth lowers at FULL
    width give exact fixed + per-rep terms: total = fixed + body * reps.
    """
    f1 = _measure(reduced_cfg(cfg, 1), shape, mesh, arch, microbatches)
    f2 = _measure(reduced_cfg(cfg, 2), shape, mesh, arch, microbatches)
    if cfg.family == "audio":
        reps = cfg.enc_layers  # enc and dec scale together in the reduced cfg
    else:
        reps = (cfg.num_layers - len(cfg.prefix_kinds)) // len(cfg.pattern)
    body = tuple(b2 - b1 for b1, b2 in zip(f1[:3], f2[:3]))
    fixed = tuple(b1 - bd for b1, bd in zip(f1[:3], body))
    total = tuple(fx + bd * reps for fx, bd in zip(fixed, body))
    by_kind = {
        k: (f1[3].get(k, 0) - (f2[3].get(k, 0) - f1[3].get(k, 0)))
        + (f2[3].get(k, 0) - f1[3].get(k, 0)) * reps
        for k in set(f1[3]) | set(f2[3])
    }
    return total, by_kind


def lower_cell(arch: str, shape_name: str, multi_pod: bool, variant: str = "baseline"):
    """Lower + compile one (arch x shape x mesh) cell; returns result dict."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    chips = int(np.prod(mesh.devices.shape))
    t0 = time.time()

    if arch == "paper-bayes-fusion":
        cfg, opts = apply_variant(get_config(arch), variant)
        shape = SHAPES_BY_NAME.get(shape_name, SHAPES_BY_NAME["train_4k"])
        specs = input_specs(arch, shape, cfg)
        all_axes = tuple(mesh.axis_names)
        fn = make_bayes_fn(cfg, path=opts["bayes_path"], rng_inside=opts["rng_inside"])
        with dctx.mesh_context(mesh):
            if opts["rng_inside"] or opts["bayes_path"] == "analytic":
                lowered = jax.jit(
                    fn, in_shardings=(NamedSharding(mesh, P(None, all_axes, None)),)
                ).lower(specs["p_modal"])
            else:
                lowered = jax.jit(
                    fn,
                    in_shardings=(
                        NamedSharding(mesh, P(None, all_axes, None)),
                        NamedSharding(mesh, P(None, all_axes, None, None)),
                    ),
                ).lower(specs["p_modal"], specs["rand"])
            compiled = lowered.compile()
        pixels = cfg.frames_per_batch * cfg.height * cfg.width
        mflops = 10.0 * pixels * cfg.classes * cfg.modalities
        roof = rf.from_compiled(arch, shape_name, mesh_name, chips, compiled, mflops)
        return _result(roof, compiled, t0, variant, calibrated=False)

    cfg, opts = apply_variant(get_config(arch), variant)
    shape = SHAPES_BY_NAME[shape_name]
    micro = opts["microbatches"]

    # production lower: scan-over-layers; memory analysis + collective schedule
    lowered, params_shapes = build_lowered(cfg, shape, mesh, arch, micro)
    compiled = lowered.compile()
    mflops = model_flops(cfg, shape, params_shapes)

    # calibrated roofline terms (exact flops/bytes/collectives)
    (flops, nbytes, cbytes), by_kind = calibrate(cfg, shape, mesh, arch, micro)
    roof = rf.Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        flops_per_chip=flops, bytes_per_chip=nbytes,
        collective_bytes_per_chip=cbytes, collective_by_kind=by_kind,
        model_flops_total=mflops,
    ).finalize()
    return _result(roof, compiled, t0, variant, calibrated=True)


def apply_variant(cfg, variant: str):
    """Named config variants for the SSPerf hillclimb.

    Returns (cfg, opts) where opts carries non-config knobs (microbatches,
    fsdp2d sharding policy, paper-bayes path selection).
    """
    from repro.distributed import sharding as _sh

    opts = {"microbatches": 1, "bayes_path": "both", "rng_inside": False}
    _sh.POLICY["fsdp2d"] = False
    if variant == "baseline":
        return cfg, opts
    changes = {}
    for part in variant.split("+"):
        if part == "nosp":
            changes["seq_shard"] = False
        elif part.startswith("qchunk"):
            changes["q_chunk"] = int(part[len("qchunk"):])
        elif part.startswith("mchunk"):
            changes["mlstm_chunk"] = int(part[len("mchunk"):])
        elif part == "moedense":
            changes["moe"] = dataclasses.replace(cfg.moe, impl="dense")
        elif part == "fsdp2d":
            _sh.POLICY["fsdp2d"] = True
        elif part.startswith("micro"):
            opts["microbatches"] = int(part[len("micro"):])
        elif part in ("analytic", "stochastic"):
            opts["bayes_path"] = part
        elif part.startswith("bits"):
            changes["n_bits"] = int(part[len("bits"):])
        elif part == "rnginside":
            opts["rng_inside"] = True
        else:
            raise ValueError(f"unknown variant component {part!r}")
    return dataclasses.replace(cfg, **changes), opts


def _result(roof: rf.Roofline, compiled, t0: float, variant: str, calibrated: bool) -> dict:
    mem = {}
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_size_gb": getattr(ma, "argument_size_in_bytes", 0) / 1e9,
            "output_size_gb": getattr(ma, "output_size_in_bytes", 0) / 1e9,
            "temp_size_gb": getattr(ma, "temp_size_in_bytes", 0) / 1e9,
        }
        roof.peak_memory_bytes = float(
            getattr(ma, "argument_size_in_bytes", 0)
            + getattr(ma, "output_size_in_bytes", 0)
            + getattr(ma, "temp_size_in_bytes", 0)
        )
    except Exception:
        pass
    counts = rf.collective_counts(compiled.as_text())
    return {
        "variant": variant,
        "ok": True,
        "calibrated": calibrated,
        "compile_seconds": round(time.time() - t0, 1),
        "memory_analysis": mem,
        "collective_counts_schedule": counts,
        **roof.to_dict(),
    }


# ------------------------------------------------------------------------ main

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = list(LM_ARCHS) + ["paper-bayes-fusion"] if args.arch == "all" else [args.arch]
    shapes = list(SHAPES_BY_NAME) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    os.makedirs(args.out, exist_ok=True)

    failures = 0
    for arch in archs:
        for shape_name in shapes:
            if arch == "paper-bayes-fusion" and shape_name != "train_4k":
                continue  # one canonical cell for the paper workload
            runnable, why = cell_is_runnable(arch, shape_name)
            for multi in meshes:
                mesh_name = "pod2x16x16" if multi else "pod16x16"
                tag = f"{arch}__{shape_name}__{mesh_name}"
                if args.variant != "baseline":
                    tag += f"__{args.variant}"
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    ok_prev = json.load(open(path)).get("ok", False)
                    if ok_prev:
                        print(f"[skip existing] {tag}")
                        continue
                if not runnable:
                    with open(path, "w") as f:
                        json.dump({"ok": False, "skipped": True, "reason": why,
                                   "arch": arch, "shape": shape_name,
                                   "mesh": mesh_name}, f, indent=1)
                    print(f"[skipped] {tag}: {why}")
                    continue
                try:
                    res = lower_cell(arch, shape_name, multi, args.variant)
                    with open(path, "w") as f:
                        json.dump(res, f, indent=1, default=str)
                    print(
                        f"[ok] {tag}: compile={res['compile_seconds']}s "
                        f"flops/chip={res['flops_per_chip']:.3e} "
                        f"coll={res['collective_bytes_per_chip']:.3e}B "
                        f"bottleneck={res['bottleneck']} "
                        f"temp={res['memory_analysis'].get('temp_size_gb', -1):.1f}GB",
                        flush=True,
                    )
                except Exception as e:  # noqa: BLE001
                    failures += 1
                    with open(path, "w") as f:
                        json.dump({"ok": False, "error": str(e),
                                   "trace": traceback.format_exc()[-4000:],
                                   "arch": arch, "shape": shape_name,
                                   "mesh": mesh_name}, f, indent=1)
                    print(f"[FAIL] {tag}: {str(e)[:300]}", flush=True)
    print(f"done; failures={failures}")
    return failures


if __name__ == "__main__":
    raise SystemExit(main())
