"""Mixture-of-experts with sort-based capacity dispatch (EP over the model axis).

Dispatch avoids the (T, E, C) dense one-hot tensor (infeasible at E=256): tokens
are replicated k times, sorted by expert id, truncated at per-expert capacity and
scattered into an (E, C, D) buffer.  Expert weights are sharded over the `model`
mesh axis (expert parallelism); under GSPMD the expert einsum shards over E and
the combine produces the EP collective.  ``impl="dense"`` keeps a tiny all-expert
einsum for smoke-scale correctness checks.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import layers


def moe_init(key, cfg, dtype=jnp.bfloat16):
    e = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    def stack_init(k, n, d_in, d_out):
        kk = jax.random.split(k, n)
        return jnp.stack([layers.dense_init(ki, d_in, d_out, dtype) for ki in kk])
    p = {
        "router": layers.dense_init(ks[0], d, e.num_experts, jnp.float32),
        "wi": stack_init(ks[1], e.num_experts, d, e.d_ff_expert),
        "wg": stack_init(ks[2], e.num_experts, d, e.d_ff_expert),
        "wo": stack_init(ks[3], e.num_experts, e.d_ff_expert, d),
    }
    if e.num_shared:
        p["shared"] = layers.mlp_init(ks[4], d, e.d_ff_expert * e.num_shared, cfg.mlp, dtype)
    return p


def _router_probs(logits: jnp.ndarray, kind: str, top_k: int):
    """Top-k routing weights, normalized over the selected experts."""
    if kind == "sigmoid":            # deepseek-v3 style scoring
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    top_vals, top_ids = jax.lax.top_k(scores, top_k)
    top_vals = top_vals / jnp.maximum(top_vals.sum(-1, keepdims=True), 1e-9)
    return top_vals, top_ids


def _expert_ffn(p, xe: jnp.ndarray, mlp_kind: str) -> jnp.ndarray:
    """xe: (E, C, D) -> (E, C, D) through per-expert gated MLPs."""
    h = jnp.einsum("ecd,edf->ecf", xe, p["wi"])
    if mlp_kind in ("swiglu", "geglu"):
        g = jnp.einsum("ecd,edf->ecf", xe, p["wg"])
        act = jax.nn.silu if mlp_kind == "swiglu" else jax.nn.gelu
        h = act(g) * h
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("ecf,efd->ecd", h, p["wo"])


def moe_apply(params, x: jnp.ndarray, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out, aux_loss).

    Under an ambient mesh with impl="masked" this wraps the layer in shard_map:
    tokens stay sharded over the batch axes (replicated over `model`), experts
    are sharded over `model` (EP), and the partial expert outputs are combined
    with one psum over `model` -- the Megatron-style masked-EP collective.
    """
    from repro.distributed import context as dctx

    mesh = dctx.current_mesh()
    e = cfg.moe
    if mesh is not None and e.impl == "masked" and "model" in mesh.axis_names \
            and e.num_experts % mesh.shape["model"] == 0:
        import numpy as np
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map

        bax = dctx.batch_axes()
        bsize = int(np.prod([mesh.shape[a] for a in bax])) if bax else 1
        if x.shape[0] % bsize != 0:
            bax = ()                                 # tiny batch: replicate it
        bspec = bax if len(bax) > 1 else (bax[0] if bax else None)
        # expert-stacked leaves shard over model on dim 0.  The SHARED expert
        # stays OUTSIDE the shard_map: inside it would be recomputed per model
        # shard (TPx redundant flops -- measured 10x useful-compute loss on
        # llama4; EXPERIMENTS SSPerf).  Outside, it is an ordinary TP MLP.
        ep_params = {k: v for k, v in params.items() if k != "shared"}
        expert_spec = {"wi": P("model", None, None), "wg": P("model", None, None),
                       "wo": P("model", None, None)}
        pspec = {k: expert_spec.get(k, jax.tree.map(lambda _: P(), v))
                 for k, v in ep_params.items()}
        fn = shard_map(
            lambda p, xx: _moe_local_ep(p, xx, cfg),
            mesh=mesh,
            in_specs=(pspec, P(bspec, None, None)),
            out_specs=(P(bspec, None, None), P()),
            check_rep=False,
        )
        out, aux = fn(ep_params, x)
        if e.num_shared:
            b, s, d = x.shape
            shared = layers.apply_mlp(params["shared"], x.reshape(-1, d), cfg.mlp)
            out = out + shared.reshape(b, s, d)
        return out, aux
    return _moe_local(params, x, cfg)


def _moe_local_ep(params, x, cfg):
    """shard_map body: local tokens x local experts, psum-combined over `model`."""
    out, aux = _moe_local(params, x, cfg, local_experts=True)
    out = jax.lax.psum(out, "model")
    aux = jax.lax.pmean(aux, "model")
    return out, aux


def _moe_local(params, x: jnp.ndarray, cfg, local_experts: bool = False):
    e = cfg.moe
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    logits = (xt.astype(jnp.float32)) @ params["router"]         # (T, E)
    weights, ids = _router_probs(logits, e.router, e.top_k)      # (T, k)

    # load-balancing aux loss (Switch-style): mean prob * mean assignment
    probs = jax.nn.softmax(logits, axis=-1)
    assign = jnp.zeros((t, e.num_experts), jnp.float32)
    one_hot0 = jax.nn.one_hot(ids[:, 0], e.num_experts, dtype=jnp.float32)
    assign = assign + one_hot0
    aux = jnp.mean(probs.mean(0) * assign.mean(0)) * e.num_experts * e.num_experts

    if e.impl == "dense":
        # all-experts einsum (smoke scale only)
        h = jnp.einsum("td,edf->tef", xt, params["wi"])
        if cfg.mlp in ("swiglu", "geglu"):
            g = jnp.einsum("td,edf->tef", xt, params["wg"])
            act = jax.nn.silu if cfg.mlp == "swiglu" else jax.nn.gelu
            h = act(g) * h
        out_e = jnp.einsum("tef,efd->ted", h, params["wo"])       # (T, E, D)
        gate = jnp.zeros((t, e.num_experts), out_e.dtype)
        gate = gate.at[jnp.arange(t)[:, None], ids].set(weights.astype(out_e.dtype))
        out = jnp.einsum("ted,te->td", out_e, gate)
    else:
        # sort-based capacity dispatch over the experts this shard owns
        k = e.top_k
        e_local = params["wi"].shape[0]          # = E, or E/TP inside shard_map
        if local_experts and e_local != e.num_experts:
            offset = jax.lax.axis_index("model") * e_local
            ids_here = ids - offset              # local expert ids; others -> oob
        else:
            ids_here = ids
        cap = int(e.capacity_factor * k * t / e.num_experts)
        # small-T floor (decode steps, smoke-scale prefill): below 64 assignments
        # run dropless, so keep/drop never depends on the sequence length and
        # prefill(t-1) stays bit-consistent with teacher-forced forward(t)
        cap = max(cap, min(t * k, 64))
        flat_ids = jnp.clip(ids_here.reshape(-1), -1, e_local)   # (T*k,)
        oob = (flat_ids < 0) | (flat_ids >= e_local)
        flat_ids = jnp.where(oob, e_local, flat_ids)             # overflow row
        flat_w = weights.reshape(-1).astype(x.dtype)
        tok_ix = jnp.repeat(jnp.arange(t), k)                    # source token
        order = jnp.argsort(flat_ids)                            # stable group-by
        sid = flat_ids[order]
        stok = tok_ix[order]
        sw = flat_w[order]
        # position within expert group
        grp_start = jnp.searchsorted(sid, jnp.arange(e_local + 1), side="left")
        pos_in_e = jnp.arange(t * k) - grp_start[jnp.clip(sid, 0, e_local)]
        keep = (pos_in_e < cap) & (sid < e_local)                # capacity drop
        dst_e = jnp.where(keep, sid, e_local)                    # overflow row
        dst_c = jnp.where(keep, pos_in_e % cap, 0)
        buf = jnp.zeros((e_local + 1, cap, d), x.dtype)
        buf = buf.at[dst_e, dst_c].set(xt[stok])
        out_buf = _expert_ffn(params, buf[:e_local], cfg.mlp)
        out_buf = jnp.concatenate(
            [out_buf, jnp.zeros((1, cap, d), out_buf.dtype)], axis=0
        )
        # combine: gather each (token, k) slot's expert output, weight, sum
        gathered = out_buf[dst_e, dst_c] * sw[:, None]           # (T*k, D)
        gathered = jnp.where(keep[:, None], gathered, 0)
        out = jnp.zeros((t, d), x.dtype).at[stok].add(gathered)

    if e.num_shared and "shared" in params:
        # (EP path strips the shared expert out and applies it as a TP MLP
        # outside the shard_map -- see moe_apply)
        out = out + layers.apply_mlp(params["shared"], xt, cfg.mlp)
    return out.reshape(b, s, d), aux
