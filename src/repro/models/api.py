"""Family-dispatch facade over the model zoo.

All launchers (train, serve, dryrun, tests) go through these four functions so
that decoder-only, enc-dec and multimodal-stub architectures share one calling
convention:

  init(cfg, key)                        -> params
  loss(params, cfg, batch)              -> (scalar, metrics)   [train_step]
  prefill(params, cfg, batch, t_cache)  -> (last logits, state)
  decode(params, cfg, token, state, pos)-> (logits, state)

``batch`` carries "tokens"/"labels" and, for vlm/audio stubs, "extra_embeds"
(precomputed patch/frame embeddings -- the assignment's frontend stub).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.models import encdec, transformer


def init(cfg, key):
    if cfg.family == "audio":
        return encdec.init_params(cfg, key)
    return transformer.init_params(cfg, key)


def loss(params, cfg, batch):
    if cfg.family == "audio":
        return encdec.loss_fn(params, cfg, batch)
    return transformer.loss_fn(params, cfg, batch)


def prefill(params, cfg, batch, t_cache: int):
    if cfg.family == "audio":
        return encdec.prefill(params, cfg, batch["extra_embeds"], batch["tokens"], t_cache)
    return transformer.prefill(
        params, cfg, batch["tokens"], t_cache, batch.get("extra_embeds")
    )


def decode(params, cfg, token, state, pos):
    if cfg.family == "audio":
        return encdec.decode_step(params, cfg, token, state, pos)
    return transformer.decode_step(params, cfg, token, state, pos)


def param_count(params) -> int:
    import jax

    return int(sum(x.size for x in jax.tree.leaves(params)))
