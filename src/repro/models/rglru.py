"""RG-LRU recurrence block (RecurrentGemma / Griffin).

Temporal mixing: conv1d(width 4) -> gated linear recurrent unit with
input-dependent diagonal decay, computed with ``jax.lax.associative_scan``
(training/prefill) or a single recurrent step (decode).  State is O(width),
which is what makes long_500k feasible for this family.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import layers

_C = 8.0  # RG-LRU decay sharpness constant (Griffin appendix)


def rglru_init(key, cfg, dtype=jnp.bfloat16):
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 7)
    return {
        "wx": layers.dense_init(ks[0], d, w, dtype),        # input branch
        "wy": layers.dense_init(ks[1], d, w, dtype),        # gate branch
        "conv": (jax.random.normal(ks[2], (cfg.conv_width, w), jnp.float32) * 0.02).astype(dtype),
        "w_input_gate": layers.dense_init(ks[3], w, w, dtype),
        "w_rec_gate": layers.dense_init(ks[4], w, w, dtype),
        # Lambda param: stationary decay in (0.9, 0.999)
        "lambda_raw": jnp.asarray(
            jax.random.uniform(ks[5], (w,), jnp.float32, 0.4, 0.8), jnp.float32
        ),
        "wo": layers.dense_init(ks[6], w, d, dtype),
    }


def _conv1d(x: jnp.ndarray, kernel: jnp.ndarray, state: jnp.ndarray | None):
    """Causal depthwise conv. x: (B, S, W); kernel: (cw, W); state: (B, cw-1, W)."""
    cw = kernel.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1], :] * kernel[i][None, None, :] for i in range(cw)
    )
    new_state = xp[:, -(cw - 1) :, :] if cw > 1 else jnp.zeros_like(x[:, :0])
    return out, new_state


def rglru_apply(
    params, x: jnp.ndarray, cfg, state: dict | None = None
) -> Tuple[jnp.ndarray, dict]:
    """x: (B, S, D) -> (out (B, S, D), new_state {"conv", "h"})."""
    xb = x @ params["wx"]
    gate_branch = jax.nn.gelu(x @ params["wy"])
    conv_state = None if state is None else state["conv"]
    xc, new_conv = _conv1d(xb, params["conv"], conv_state)

    i_gate = jax.nn.sigmoid(xc @ params["w_input_gate"])
    r_gate = jax.nn.sigmoid(xc @ params["w_rec_gate"])
    log_lam = -_C * jax.nn.softplus(params["lambda_raw"]) * r_gate.astype(jnp.float32)
    a = jnp.exp(log_lam)                                   # decay in (0,1)
    gated_x = (i_gate * xc).astype(jnp.float32)
    # normalized input scaling (Griffin): sqrt(1 - a^2)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-6))
    u = beta * gated_x

    h0 = None if state is None else state["h"]
    if x.shape[1] == 1 and h0 is not None:
        h = a[:, 0] * h0 + u[:, 0]
        ht = h[:, None, :]
        new_h = h
    else:
        # associative scan over the diagonal recurrence h_t = a_t h_{t-1} + u_t
        if h0 is not None:
            u = u.at[:, 0].add(a[:, 0] * h0)

        def combine(c1, c2):
            a1, u1 = c1
            a2, u2 = c2
            return a1 * a2, a2 * u1 + u2

        a_s, h_s = jax.lax.associative_scan(combine, (a, u), axis=1)
        ht = h_s
        new_h = h_s[:, -1]
    out = (ht.astype(x.dtype) * gate_branch) @ params["wo"]
    return out, {"conv": new_conv, "h": new_h}


def rglru_init_state(batch: int, cfg, dtype=jnp.bfloat16) -> dict:
    w = cfg.lru_width or cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype),
        "h": jnp.zeros((batch, w), jnp.float32),
    }
