"""Encoder-decoder model (seamless-m4t-large-v2 backbone).

Encoder consumes precomputed audio frame embeddings (the modality frontend is a
stub per the assignment); the decoder is autoregressive text with self- and
cross-attention.  Both stacks are scanned.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import context as dctx
from repro.models import layers
from repro.models.transformer import Params


def _maybe_scan(cfg, body, carry, xs):
    """lax.scan, or an unrolled python loop for dry-run calibration."""
    if cfg.unroll_layers:
        n = jax.tree.leaves(xs)[0].shape[0]
        outs = []
        for r in range(n):
            sl = jax.tree.map(lambda p: p[r], xs)
            carry, y = body(carry, sl)
            outs.append(y)
        ys = None if outs[0] is None else jax.tree.map(lambda *z: jnp.stack(z), *outs)
        return carry, ys
    return jax.lax.scan(body, carry, xs)


def _enc_block_init(key, cfg):
    ks = jax.random.split(key, 2)
    return {
        "norm1": layers.norm_init(cfg.d_model, cfg.norm),
        "attn": layers.gqa_init(ks[0], cfg),
        "norm2": layers.norm_init(cfg.d_model, cfg.norm),
        "mlp": layers.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp),
    }


def _dec_block_init(key, cfg):
    ks = jax.random.split(key, 3)
    return {
        "norm1": layers.norm_init(cfg.d_model, cfg.norm),
        "self_attn": layers.gqa_init(ks[0], cfg),
        "norm_x": layers.norm_init(cfg.d_model, cfg.norm),
        "cross_attn": layers.cross_attention_init(ks[1], cfg),
        "norm2": layers.norm_init(cfg.d_model, cfg.norm),
        "mlp": layers.mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.mlp),
    }


def init_params(cfg, key) -> Params:
    ks = jax.random.split(key, 5)
    vocab = layers.pad_vocab(cfg.vocab_size)
    ek = jax.random.split(ks[0], cfg.enc_layers)
    dk = jax.random.split(ks[1], cfg.dec_layers)
    enc_blocks = [_enc_block_init(k, cfg) for k in ek]
    dec_blocks = [_dec_block_init(k, cfg) for k in dk]
    return {
        "embed": layers.embed_init(ks[2], vocab, cfg.d_model),
        "enc_blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *enc_blocks),
        "dec_blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *dec_blocks),
        "enc_norm": layers.norm_init(cfg.d_model, cfg.norm),
        "final_norm": layers.norm_init(cfg.d_model, cfg.norm),
        "unembed": layers.dense_init(ks[3], cfg.d_model, vocab),
    }


def encode(params: Params, cfg, frames: jnp.ndarray) -> jnp.ndarray:
    """frames: (B, T_enc, d_model) precomputed frame embeddings -> encoder output."""
    frames = frames.astype(jnp.bfloat16)
    positions = jnp.arange(frames.shape[1])

    def block(x, bp):
        h = layers.apply_norm(bp["norm1"], x, cfg.norm)
        mix, _ = layers.gqa_apply(
            bp["attn"], h, cfg, kind="full_bidir", positions=positions, rope=True
        )
        x = x + mix
        h2 = layers.apply_norm(bp["norm2"], x, cfg.norm)
        x = x + layers.apply_mlp(bp["mlp"], h2, cfg.mlp)
        if cfg.seq_shard:
            x = dctx.constrain(x, "batch", "model", None)
        return x, None

    x, _ = _maybe_scan(cfg, jax.checkpoint(block), frames, params["enc_blocks"])
    return layers.apply_norm(params["enc_norm"], x, cfg.norm)


def _dec_block(bp, x, enc_out, cfg, positions, self_cache=None, cross_cache=None,
               cache_pos=None):
    h = layers.apply_norm(bp["norm1"], x, cfg.norm)
    mix, new_self = layers.gqa_apply(
        bp["self_attn"], h, cfg, kind="causal", positions=positions,
        cache=self_cache, cache_pos=cache_pos,
    )
    x = x + mix
    hx = layers.apply_norm(bp["norm_x"], x, cfg.norm)
    cross, new_cross = layers.cross_attention_apply(
        bp["cross_attn"], hx, enc_out, cfg, cache=cross_cache
    )
    x = x + cross
    h2 = layers.apply_norm(bp["norm2"], x, cfg.norm)
    return x + layers.apply_mlp(bp["mlp"], h2, cfg.mlp), new_self, new_cross


def forward(params: Params, cfg, frames: jnp.ndarray, tokens: jnp.ndarray):
    """Teacher-forced enc-dec forward -> logits (B, S_dec, vocab_padded)."""
    enc_out = encode(params, cfg, frames)
    x = params["embed"][tokens]
    positions = jnp.arange(tokens.shape[1])

    def block(x, bp):
        out, _, _ = _dec_block(bp, x, enc_out, cfg, positions)
        if cfg.seq_shard:
            out = dctx.constrain(out, "batch", "model", None)
        return out, None

    x, _ = _maybe_scan(cfg, jax.checkpoint(block), x, params["dec_blocks"])
    h = layers.apply_norm(params["final_norm"], x, cfg.norm)
    return h @ params["unembed"], jnp.float32(0.0)


def loss_fn(params: Params, cfg, batch: Dict[str, jnp.ndarray]):
    logits, aux = forward(params, cfg, batch["extra_embeds"], batch["tokens"])
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][..., None], axis=-1)[..., 0]
    loss = nll.mean()
    return loss, {"nll": loss, "aux": aux}


def prefill(params: Params, cfg, frames: jnp.ndarray, tokens: jnp.ndarray, t_cache: int):
    """Encode + teacher-forced decoder pass filling self/cross caches."""
    enc_out = encode(params, cfg, frames)
    b, s = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.arange(s)
    hd = cfg.resolved_head_dim
    state = {
        "self": jax.tree.map(
            lambda z: jnp.stack([z] * cfg.dec_layers),
            layers.init_kv_cache(b, t_cache, cfg.num_kv_heads, hd),
        ),
        "cross": {
            "k": jnp.zeros((cfg.dec_layers, b, enc_out.shape[1], cfg.num_kv_heads, hd), jnp.bfloat16),
            "v": jnp.zeros((cfg.dec_layers, b, enc_out.shape[1], cfg.num_kv_heads, hd), jnp.bfloat16),
        },
    }

    def block(x, scanned):
        bp, self_c, cross_kv = scanned
        out, new_self, new_cross = _dec_block(
            bp, x, enc_out, cfg, positions, self_cache=self_c,
            cross_cache=None, cache_pos=jnp.int32(0),
        )
        return out, (new_self, new_cross)

    x, (new_self, new_cross) = _maybe_scan(
        cfg, block, x, (params["dec_blocks"], state["self"], state["cross"])
    )
    h = layers.apply_norm(params["final_norm"], x[:, -1:], cfg.norm)
    logits = (h @ params["unembed"])[:, 0].astype(jnp.float32)
    return logits, {"self": new_self, "cross": {"k": new_cross["k"], "v": new_cross["v"]}}


def decode_step(params: Params, cfg, token: jnp.ndarray, state, pos: jnp.ndarray):
    """One decoder step against self cache + fixed cross cache."""
    x = params["embed"][token][:, None, :]
    positions = jnp.full((1,), pos, jnp.int32)

    def block(x, scanned):
        bp, self_c, cross_kv = scanned
        out, new_self, _ = _dec_block(
            bp, x, None, cfg, positions, self_cache=self_c,
            cross_cache=cross_kv, cache_pos=pos,
        )
        return out, new_self

    x, new_self = _maybe_scan(
        cfg, block, x, (params["dec_blocks"], state["self"], state["cross"])
    )
    h = layers.apply_norm(params["final_norm"], x, cfg.norm)
    logits = (h @ params["unembed"])[:, 0].astype(jnp.float32)
    return logits, {"self": new_self, "cross": state["cross"]}
