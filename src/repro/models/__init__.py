from repro.models import api, bayes_head, encdec, layers, mla, moe, rglru, transformer, xlstm  # noqa: F401
