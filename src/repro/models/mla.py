"""Multi-head Latent Attention (DeepSeek-V2/V3).

Queries and keys/values are projected through low-rank latents; the KV cache
stores only the compressed latent (kv_lora_rank) plus the shared RoPE key
(qk_rope_dim) per token -- the memory insight of MLA.  Decode reconstructs
k_nope/v from the cached latent.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import layers


def mla_init(key, cfg, dtype=jnp.bfloat16):
    d = cfg.d_model
    h = cfg.num_heads
    ks = jax.random.split(key, 8)
    return {
        "w_dq": layers.dense_init(ks[0], d, cfg.q_lora_rank, dtype),
        "q_norm": layers.norm_init(cfg.q_lora_rank, "rmsnorm"),
        "w_uq": layers.dense_init(
            ks[1], cfg.q_lora_rank, h * (cfg.qk_nope_dim + cfg.qk_rope_dim), dtype
        ),
        "w_dkv": layers.dense_init(
            ks[2], d, cfg.kv_lora_rank + cfg.qk_rope_dim, dtype
        ),
        "kv_norm": layers.norm_init(cfg.kv_lora_rank, "rmsnorm"),
        "w_ukv": layers.dense_init(
            ks[3], cfg.kv_lora_rank, h * (cfg.qk_nope_dim + cfg.v_head_dim), dtype
        ),
        "wo": layers.dense_init(ks[4], h * cfg.v_head_dim, d, dtype),
    }


def _expand_kv(params, latent: jnp.ndarray, cfg):
    """latent (B, T, kv_lora) -> k_nope (B,T,H,nope), v (B,T,H,vdim)."""
    b, t, _ = latent.shape
    h = cfg.num_heads
    kv = latent @ params["w_ukv"]
    kv = kv.reshape(b, t, h, cfg.qk_nope_dim + cfg.v_head_dim)
    return kv[..., : cfg.qk_nope_dim], kv[..., cfg.qk_nope_dim :]


def mla_apply(
    params,
    x: jnp.ndarray,
    cfg,
    *,
    positions: jnp.ndarray,
    cache: dict | None = None,
    cache_pos: jnp.ndarray | None = None,
) -> Tuple[jnp.ndarray, dict | None]:
    b, s, d = x.shape
    h = cfg.num_heads
    qd = cfg.qk_nope_dim + cfg.qk_rope_dim

    qc = layers.apply_norm(params["q_norm"], x @ params["w_dq"], "rmsnorm")
    q = (qc @ params["w_uq"]).reshape(b, s, h, qd)
    q_nope, q_rope = q[..., : cfg.qk_nope_dim], q[..., cfg.qk_nope_dim :]
    q_rope = layers.apply_rope(q_rope, positions, cfg.rope_theta)

    dkv = x @ params["w_dkv"]
    latent = layers.apply_norm(params["kv_norm"], dkv[..., : cfg.kv_lora_rank], "rmsnorm")
    k_rope = dkv[..., cfg.kv_lora_rank :].reshape(b, s, 1, cfg.qk_rope_dim)
    k_rope = layers.apply_rope(k_rope, positions, cfg.rope_theta)

    if cache is not None:
        t_cache = cache["latent"].shape[1]
        slot = cache_pos % t_cache
        clat = jax.lax.dynamic_update_slice_in_dim(
            cache["latent"], latent.astype(cache["latent"].dtype), slot, axis=1
        )
        ckr = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], k_rope.astype(cache["k_rope"].dtype), slot, axis=1
        )
        cpos = jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], positions.astype(jnp.int32), slot, axis=0
        )
        new_cache = {"latent": clat, "k_rope": ckr, "pos": cpos}
        if s == 1:
            # absorbed-weight decode: attend directly in the latent space, never
            # re-expanding the cache (the MLA decode optimization).
            w_ukv = params["w_ukv"].reshape(
                cfg.kv_lora_rank, h, cfg.qk_nope_dim + cfg.v_head_dim
            )
            w_k, w_v = w_ukv[..., : cfg.qk_nope_dim], w_ukv[..., cfg.qk_nope_dim :]
            q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, w_k)     # (B,1,H,kv_lora)
            scale = (cfg.qk_nope_dim + cfg.qk_rope_dim) ** -0.5
            scores = (
                jnp.einsum("bshr,btr->bhst", q_lat.astype(jnp.float32), clat.astype(jnp.float32))
                + jnp.einsum(
                    "bshr,btzr->bhst",
                    q_rope.astype(jnp.float32),
                    ckr.astype(jnp.float32),
                )
            ) * scale
            ok = (cpos[None, :] <= positions[:, None]) & (cpos >= 0)[None, :]
            scores = scores + jnp.where(ok, 0.0, -jnp.inf)[None, None]
            w = jax.nn.softmax(scores, axis=-1)
            ctx_lat = jnp.einsum("bhst,btr->bshr", w, clat.astype(jnp.float32))
            ctx = jnp.einsum("bshr,rhv->bshv", ctx_lat, w_v.astype(jnp.float32))
            out = ctx.reshape(b, 1, h * cfg.v_head_dim).astype(x.dtype) @ params["wo"]
            return out, new_cache
        k_nope_full, v_full = _expand_kv(params, clat, cfg)
        k_rope_full = ckr
        k_positions, k_valid = cpos, cpos >= 0
    else:
        k_nope_full, v_full = _expand_kv(params, latent, cfg)
        k_rope_full = k_rope
        k_positions, k_valid = positions, None
        new_cache = None

    # concat nope+rope parts; rope key is shared across heads (broadcast)
    k_full = jnp.concatenate(
        [
            k_nope_full,
            jnp.broadcast_to(
                k_rope_full, k_rope_full.shape[:2] + (h, cfg.qk_rope_dim)
            ),
        ],
        axis=-1,
    )
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = layers.multihead_attention(
        q_full, k_full, v_full, kind="causal",
        q_positions=positions, k_positions=k_positions, k_valid=k_valid,
        q_chunk=cfg.q_chunk,
    )
    out = out.reshape(b, s, h * cfg.v_head_dim) @ params["wo"]
    return out, new_cache


def mla_init_cache(batch: int, t_cache: int, cfg, dtype=jnp.bfloat16) -> dict:
    return {
        "latent": jnp.zeros((batch, t_cache, cfg.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, t_cache, 1, cfg.qk_rope_dim), dtype),
        "pos": jnp.full((t_cache,), -1, jnp.int32),
    }
