"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

mLSTM trains with a chunkwise-parallel linear-attention formulation (exact
w.r.t. the recurrence, sub-quadratic) and decodes with the O(d_k x d_v)
recurrent state.  sLSTM is inherently sequential (exponential-gated scalar
memory with normalizer/stabilizer state) and runs under ``lax.scan``.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import layers


# --------------------------------------------------------------------------- mLSTM

def mlstm_init(key, cfg, dtype=jnp.bfloat16):
    d = cfg.d_model
    di = 2 * d                      # up-projection factor 2 (xLSTM paper)
    ks = jax.random.split(key, 8)
    return {
        "w_up": layers.dense_init(ks[0], d, di, dtype),
        "w_gate": layers.dense_init(ks[1], d, di, dtype),
        "wq": layers.dense_init(ks[2], di, di, dtype),
        "wk": layers.dense_init(ks[3], di, di, dtype),
        "wv": layers.dense_init(ks[4], di, di, dtype),
        "w_i": layers.dense_init(ks[5], di, cfg.num_heads, jnp.float32),
        "w_f": layers.dense_init(ks[6], di, cfg.num_heads, jnp.float32),
        "w_down": layers.dense_init(ks[7], di, d, dtype),
    }


def mlstm_init_state(batch: int, cfg) -> dict:
    d = cfg.d_model
    nh = cfg.num_heads
    dh = 2 * d // nh
    return {
        "C": jnp.zeros((batch, nh, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, nh, dh), jnp.float32),
        "m": jnp.full((batch, nh), -1e30, jnp.float32),
    }


def _mlstm_chunk(carry, inputs, dh):
    """One chunk of the exact chunkwise-parallel mLSTM.

    carry: (C_hat (B,NH,DK,DV), n_hat (B,NH,DK), m (B,NH)) -- stabilized state
           (true C = C_hat * exp(m)).
    inputs: q,k,v (B,L,NH,DH), log_i/log_f (B,L,NH) for this chunk.
    """
    C_in, n_in, m_in = carry
    q, k, v, log_i, log_f = inputs
    b, l, nh, _ = q.shape
    scale = dh ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    fc = jnp.cumsum(log_f, axis=1)                               # (B, L, NH)
    # intra-chunk log weights: dmat[t, s] = fc_t - fc_s + log_i_s  (s <= t)
    dmat = fc[:, :, None, :] - fc[:, None, :, :] + log_i[:, None, :, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    dmat = jnp.where(mask[None, :, :, None], dmat, -jnp.inf)
    # carry log weight at t: fc_t + m_in
    carry_logw = fc + m_in[:, None, :]                           # (B, L, NH)
    m_t = jnp.maximum(jnp.max(dmat, axis=2), carry_logw)         # (B, L, NH)
    m_t = jnp.maximum(m_t, -1e30)
    dexp = jnp.exp(dmat - m_t[:, :, None, :])                    # (B, L, S, NH)
    cexp = jnp.exp(carry_logw - m_t)                             # (B, L, NH)

    scores = jnp.einsum("blhd,bshd->blsh", qf, kf)
    w = scores * dexp
    num = jnp.einsum("blsh,bshd->blhd", w, vf) + cexp[..., None] * jnp.einsum(
        "blhk,bhkv->blhv", qf, C_in
    )
    den = jnp.sum(w, axis=2) + cexp * jnp.einsum("blhk,bhk->blh", qf, n_in)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]

    # end-of-chunk state
    fc_last = fc[:, -1, :]                                       # (B, NH)
    logw_s = fc_last[:, None, :] - fc + log_i                    # (B, L, NH)
    m_out = jnp.maximum(jnp.max(logw_s, axis=1), fc_last + m_in)
    sexp = jnp.exp(logw_s - m_out[:, None, :])
    C_out = jnp.exp(fc_last + m_in - m_out)[..., None, None] * C_in + jnp.einsum(
        "bsh,bshk,bshv->bhkv", sexp, kf, vf
    )
    n_out = jnp.exp(fc_last + m_in - m_out)[..., None] * n_in + jnp.einsum(
        "bsh,bshk->bhk", sexp, kf
    )
    return (C_out, n_out, m_out), h


def _mlstm_chunked(q, k, v, log_i, log_f, state, chunk: int = 256):
    """Exact chunkwise mLSTM: scan over chunks, parallel within each chunk."""
    b, s, nh, dh = q.shape
    l = min(chunk, s)
    pad = (-s) % l
    if pad:
        padf = lambda x, fill=0.0: jnp.pad(
            x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2), constant_values=fill
        )
        q, k, v = padf(q), padf(k), padf(v)
        log_i = padf(log_i, -1e30)   # padding never contributes (i gate ~ 0)
        log_f = padf(log_f, 0.0)
    nc = q.shape[1] // l

    def reshape_c(x):
        return jnp.moveaxis(
            x.reshape(b, nc, l, *x.shape[2:]), 1, 0
        )  # (nc, B, L, ...)

    seq = tuple(reshape_c(x) for x in (q, k, v, log_i, log_f))
    carry0 = (state["C"], state["n"], state["m"])
    (C, n, m), hs = jax.lax.scan(
        lambda c, inp: _mlstm_chunk(c, inp, dh), carry0, seq
    )
    h = jnp.moveaxis(hs, 0, 1).reshape(b, nc * l, nh, dh)[:, :s]
    return h, {"C": C, "n": n, "m": m}


def mlstm_apply(params, x: jnp.ndarray, cfg, state: dict | None = None) -> Tuple[jnp.ndarray, dict]:
    b, s, d = x.shape
    nh = cfg.num_heads
    di = 2 * d
    dh = di // nh
    up = x @ params["w_up"]
    gate = jax.nn.silu(x @ params["w_gate"])
    q = (up @ params["wq"]).reshape(b, s, nh, dh)
    k = (up @ params["wk"]).reshape(b, s, nh, dh)
    v = (up @ params["wv"]).reshape(b, s, nh, dh)
    log_i = jax.nn.log_sigmoid(up.astype(jnp.float32) @ params["w_i"])
    log_f = jax.nn.log_sigmoid(up.astype(jnp.float32) @ params["w_f"])

    if s == 1 and state is not None:
        # recurrent decode step (exact)
        qs, ks_, vs = q[:, 0], k[:, 0], v[:, 0]
        li, lf = log_i[:, 0], log_f[:, 0]
        m_new = jnp.maximum(lf + state["m"], li)
        fgate = jnp.exp(lf + state["m"] - m_new)[..., None]
        igate = jnp.exp(li - m_new)[..., None]
        C = fgate[..., None] * state["C"] + igate[..., None] * (
            ks_[..., :, None] * vs[..., None, :]
        )
        n = fgate * state["n"] + igate * ks_
        scale = dh ** -0.5
        num = jnp.einsum("bhk,bhkv->bhv", qs.astype(jnp.float32) * scale, C)
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", qs.astype(jnp.float32) * scale, n))
        h = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
        ht = h.reshape(b, 1, di)
        new_state = {"C": C, "n": n, "m": m_new}
    else:
        if state is None:
            state = mlstm_init_state(b, cfg)
        h, new_state = _mlstm_chunked(q, k, v, log_i, log_f, state, chunk=cfg.mlstm_chunk)
        ht = h.reshape(b, s, di)
    out = (ht.astype(x.dtype) * gate) @ params["w_down"]
    return out, new_state


# --------------------------------------------------------------------------- sLSTM

def slstm_init(key, cfg, dtype=jnp.bfloat16):
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    return {
        "w_z": layers.dense_init(ks[0], d, d, dtype),
        "w_i": layers.dense_init(ks[1], d, d, jnp.float32),
        "w_f": layers.dense_init(ks[2], d, d, jnp.float32),
        "w_o": layers.dense_init(ks[3], d, d, dtype),
        "ffn": layers.mlp_init(ks[4], d, int(d * 4 // 3) * 2, "swiglu", dtype),
    }


def slstm_init_state(batch: int, cfg) -> dict:
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.zeros((batch, d), jnp.float32),
        "m": jnp.full((batch, d), -1e30, jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
    }


def slstm_apply(params, x: jnp.ndarray, cfg, state: dict | None = None) -> Tuple[jnp.ndarray, dict]:
    b, s, d = x.shape
    if state is None:
        state = slstm_init_state(b, cfg)
    z_in = jnp.tanh((x @ params["w_z"]).astype(jnp.float32))
    i_in = x.astype(jnp.float32) @ params["w_i"]
    f_in = x.astype(jnp.float32) @ params["w_f"]
    o_in = jax.nn.sigmoid((x @ params["w_o"]).astype(jnp.float32))

    def step(carry, t_in):
        c, n, m, _ = carry
        z_t, i_t, f_t, o_t = t_in
        log_f = jax.nn.log_sigmoid(f_t)
        m_new = jnp.maximum(log_f + m, i_t)
        fg = jnp.exp(log_f + m - m_new)
        ig = jnp.exp(i_t - m_new)
        c_new = fg * c + ig * z_t
        n_new = fg * n + ig
        h = o_t * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, m_new, h), h

    seq = (
        jnp.moveaxis(z_in, 1, 0),
        jnp.moveaxis(i_in, 1, 0),
        jnp.moveaxis(f_in, 1, 0),
        jnp.moveaxis(o_in, 1, 0),
    )
    (c, n, m, h_last), hs = jax.lax.scan(
        step, (state["c"], state["n"], state["m"], state["h"]), seq
    )
    ht = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    out = x + layers.apply_mlp(params["ffn"], ht, "swiglu")
    return out - x, {"c": c, "n": n, "m": m, "h": h_last}
