"""Shared neural-net layers (functional, pytree params, sharding-friendly).

Everything is pure functions over nested-dict params.  Initializers return
params; apply functions take (params, x).  Layer stacks are scanned, so params
for a stack carry a leading layer axis.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def pad_vocab(v: int, multiple: int = 256) -> int:
    """Pad vocab to a lane/shard-friendly multiple (masked out in the loss)."""
    return -(-v // multiple) * multiple


# --- initializers ------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=jnp.bfloat16) -> jnp.ndarray:
    scale = (1.0 / d_in) ** 0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.bfloat16) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# --- norms ------------------------------------------------------------------------

def norm_init(d: int, kind: str):
    if kind == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.ones((d,), jnp.float32)}


def apply_norm(params, x: jnp.ndarray, kind: str) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + 1e-6) * params["scale"] + params["bias"]
    else:  # rmsnorm
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(var + 1e-6) * params["scale"]
    return out.astype(x.dtype)


# --- RoPE -------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, hd); positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                        # (hd/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, hd/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --- MLPs -------------------------------------------------------------------------

def mlp_init(key, d: int, d_ff: int, kind: str, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {
            "wi": dense_init(ks[0], d, d_ff, dtype),
            "wg": dense_init(ks[1], d, d_ff, dtype),
            "wo": dense_init(ks[2], d_ff, d, dtype),
        }
    return {
        "wi": dense_init(ks[0], d, d_ff, dtype),
        "wo": dense_init(ks[2], d_ff, d, dtype),
    }


def apply_mlp(params, x: jnp.ndarray, kind: str) -> jnp.ndarray:
    h = x @ params["wi"]
    if kind == "swiglu":
        h = jax.nn.silu(x @ params["wg"]) * h
    elif kind == "geglu":
        h = jax.nn.gelu(x @ params["wg"]) * h
    elif kind == "gelu":
        h = jax.nn.gelu(h)
    elif kind == "relu2":
        h = jnp.square(jax.nn.relu(h))
    return h @ params["wo"]


# --- attention --------------------------------------------------------------------

def gqa_init(key, cfg, dtype=jnp.bfloat16):
    """Standard (possibly grouped-query) attention projections."""
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, h * hd, dtype),
        "wk": dense_init(ks[1], d, kv * hd, dtype),
        "wv": dense_init(ks[2], d, kv * hd, dtype),
        "wo": dense_init(ks[3], h * hd, d, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((kv * hd,), dtype)
        p["bv"] = jnp.zeros((kv * hd,), dtype)
    return p


def _mask_bias(kind: str, q_pos, k_pos, window: int, chunk: int) -> jnp.ndarray:
    """Additive mask (0 / -inf) of shape (q, k) for the given attention kind."""
    qp = q_pos[:, None]
    kp = k_pos[None, :]
    ok = kp <= qp                      # causal
    if kind == "local":
        ok &= kp > qp - window
    elif kind == "chunk":
        ok &= (kp // chunk) == (qp // chunk)
    elif kind == "full_bidir":
        ok = jnp.ones_like(ok)
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)


def multihead_attention(
    q: jnp.ndarray,            # (B, S, H, hd)
    k: jnp.ndarray,            # (B, T, KV, hd)
    v: jnp.ndarray,            # (B, T, KV, hd)
    *,
    kind: str = "causal",      # causal | local | chunk | full_bidir
    window: int = 0,
    chunk: int = 0,
    q_positions: jnp.ndarray,  # (S,) absolute positions of queries
    k_positions: jnp.ndarray,  # (T,)
    k_valid: jnp.ndarray | None = None,  # (T,) bool for cache slots
    q_chunk: int = 512,
) -> jnp.ndarray:
    """Query-chunked attention (bounded score memory) with GQA broadcast.

    KV heads are broadcast up to the full head count before the score einsum so
    the head axis stays cleanly shardable over `model` (a (kv, group) einsum
    factorization would contract over the sharded head_dim and psum per chunk).
    Per device the broadcast materialises only that device's head shard.
    """
    b, s, h, hd = q.shape
    t, kvh = k.shape[1], k.shape[2]
    group = h // kvh
    if group > 1:
        k = jnp.repeat(k, group, axis=2)
        v = jnp.repeat(v, group, axis=2)
    scale = hd ** -0.5

    def attend(q_blk, qpos_blk):
        # q_blk: (B, C, H, hd)
        scores = jnp.einsum(
            "bchd,bthd->bhct", q_blk.astype(jnp.float32), k.astype(jnp.float32)
        )
        scores *= scale
        bias = _mask_bias(kind, qpos_blk, k_positions, window, chunk)  # (C, T)
        if k_valid is not None:
            bias = bias + jnp.where(k_valid[None, :], 0.0, -jnp.inf)
        scores = scores + bias[None, None]
        # guard fully-masked rows (e.g. empty cache): softmax of all -inf
        smax = jnp.max(scores, axis=-1, keepdims=True)
        smax = jnp.maximum(smax, -1e30)
        w = jnp.exp(scores - smax)
        denom = jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-30)
        w = (w / denom).astype(v.dtype)
        return jnp.einsum("bhct,bthd->bchd", w, v)

    vd = v.shape[-1]  # value head dim may differ from hd (MLA)
    if s <= q_chunk:
        out = attend(q, q_positions)
    else:
        n_chunks = -(-s // q_chunk)
        pad = n_chunks * q_chunk - s
        qg_p = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        qpos_p = jnp.pad(q_positions, (0, pad), constant_values=0)
        qg_c = qg_p.reshape(b, n_chunks, q_chunk, h, hd).swapaxes(0, 1)
        qpos_c = qpos_p.reshape(n_chunks, q_chunk)
        out = jax.lax.map(lambda args: attend(*args), (qg_c, qpos_c))
        out = out.swapaxes(0, 1).reshape(b, n_chunks * q_chunk, h, vd)[:, :s]
    return out.reshape(b, s, h, vd)


def cache_len_for_kind(kind: str, seq_len: int, window: int, chunk: int) -> int:
    """KV-cache slots needed per layer kind (bounded for local/chunked layers)."""
    if kind == "local" and window:
        return min(seq_len, window)
    if kind == "chunk" and chunk:
        return min(seq_len, chunk)
    return seq_len


def init_kv_cache(batch: int, t_cache: int, kvh: int, hd: int, dtype=jnp.bfloat16):
    """Rolling KV cache: slot positions start at -1 (invalid)."""
    return {
        "k": jnp.zeros((batch, t_cache, kvh, hd), dtype),
        "v": jnp.zeros((batch, t_cache, kvh, hd), dtype),
        "pos": jnp.full((t_cache,), -1, jnp.int32),
    }


def gqa_apply(
    params,
    x: jnp.ndarray,
    cfg,
    *,
    kind: str,
    positions: jnp.ndarray,
    rope: bool = True,
    cache: dict | None = None,
    cache_pos: jnp.ndarray | None = None,
) -> Tuple[jnp.ndarray, dict | None]:
    """Full GQA block: proj -> rope -> (cache update) -> attention -> out proj.

    cache: rolling buffer from :func:`init_kv_cache`; new k/v are written at slot
    ``cache_pos % t_cache`` (local/chunked layers keep only a bounded window; full
    layers size t_cache = max seq so the rolling write is the identity).
    """
    b, s, d = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kvh, hd)
    v = v.reshape(b, s, kvh, hd)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    if cache is not None and s == 1:
        # decode: write k,v at the rolling slot, attend over the cache
        t_cache = cache["k"].shape[1]
        slot = cache_pos % t_cache
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), slot, axis=1
        )
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), slot, axis=1
        )
        cpos = jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], positions.astype(jnp.int32), slot, axis=0
        )
        out = multihead_attention(
            q, ck, cv, kind=kind, window=cfg.window, chunk=cfg.chunk,
            q_positions=positions, k_positions=cpos, k_valid=cpos >= 0,
            q_chunk=cfg.q_chunk,
        )
        new_cache = {"k": ck, "v": cv, "pos": cpos}
    else:
        # train / prefill: attend over the full fresh k,v
        out = multihead_attention(
            q, k, v, kind=kind, window=cfg.window, chunk=cfg.chunk,
            q_positions=positions, k_positions=positions, q_chunk=cfg.q_chunk,
        )
        if cache is not None:
            # fill the cache with the (window) tail of the prompt
            t_cache = cache["k"].shape[1]
            if s >= t_cache:
                new_cache = {
                    "k": k[:, s - t_cache :].astype(cache["k"].dtype),
                    "v": v[:, s - t_cache :].astype(cache["v"].dtype),
                    "pos": positions[s - t_cache :].astype(jnp.int32),
                }
            else:
                new_cache = {
                    "k": jax.lax.dynamic_update_slice_in_dim(
                        cache["k"], k.astype(cache["k"].dtype), 0, axis=1
                    ),
                    "v": jax.lax.dynamic_update_slice_in_dim(
                        cache["v"], v.astype(cache["v"].dtype), 0, axis=1
                    ),
                    "pos": jax.lax.dynamic_update_slice_in_dim(
                        cache["pos"], positions.astype(jnp.int32), 0, axis=0
                    ),
                }
        else:
            new_cache = None
    out = out.reshape(b, s, h * hd) @ params["wo"]
    return out, new_cache


def cross_attention_init(key, cfg, dtype=jnp.bfloat16):
    return gqa_init(key, cfg, dtype)


def cross_attention_apply(params, x, enc_out, cfg, *, cache=None):
    """Decoder cross-attention over encoder output (keys/values from enc_out)."""
    b, s, d = x.shape
    h, kvh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = (x @ params["wq"]).reshape(b, s, h, hd)
    if cache is not None and "k" in cache:
        k, v = cache["k"], cache["v"]
    else:
        t = enc_out.shape[1]
        k = (enc_out @ params["wk"]).reshape(b, t, kvh, hd)
        v = (enc_out @ params["wv"]).reshape(b, t, kvh, hd)
    t = k.shape[1]
    out = multihead_attention(
        q, k, v, kind="full_bidir",
        q_positions=jnp.arange(s), k_positions=jnp.arange(t),
        q_chunk=cfg.q_chunk,
    )
    out = out.reshape(b, s, h * hd) @ params["wo"]
    return out, {"k": k, "v": v}
