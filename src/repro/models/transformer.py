"""Decoder-only model assembly for all block kinds.

The layer stack is organised as ``prefix_kinds`` (unscanned) followed by
``lax.scan`` over repetitions of the config's ``pattern`` super-block, keeping
HLO size independent of depth.  Each block kind owns (init, apply-train,
apply-decode, init-state) entries in ``_KINDS``.

Entry points:
  init_params(cfg, key)
  forward(params, cfg, tokens, extra_embeds)          -> logits
  loss_fn(params, cfg, batch)                          -> scalar loss, metrics
  prefill(params, cfg, tokens, t_cache)                -> (last_logits, state)
  decode_step(params, cfg, token, state, pos)          -> (logits, state)
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import context as dctx
from repro.models import layers, mla, moe, rglru, xlstm

Params = Dict[str, Any]


# --------------------------------------------------------------------------- blocks

def _has_moe(cfg, kind: str) -> bool:
    return cfg.moe is not None and kind in ("attn", "attn_local", "attn_chunk", "attn_global", "mla")


def block_init(key, cfg, kind: str, *, dense_ff: int | None = None) -> Params:
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: Params = {"norm1": layers.norm_init(d, cfg.norm)}
    if kind in ("attn", "attn_local", "attn_chunk", "attn_global"):
        p["attn"] = layers.gqa_init(ks[0], cfg)
    elif kind == "mla":
        p["attn"] = mla.mla_init(ks[0], cfg)
    elif kind == "rec":
        p["rec"] = rglru.rglru_init(ks[0], cfg)
    elif kind == "mlstm":
        p["mix"] = xlstm.mlstm_init(ks[0], cfg)
        return p  # mLSTM block has no separate MLP
    elif kind == "slstm":
        p["mix"] = xlstm.slstm_init(ks[0], cfg)
        return p
    else:
        raise ValueError(kind)
    p["norm2"] = layers.norm_init(d, cfg.norm)
    if _has_moe(cfg, kind) and dense_ff is None:
        p["moe"] = moe.moe_init(ks[1], cfg)
    else:
        ff = dense_ff if dense_ff is not None else cfg.d_ff
        p["mlp"] = layers.mlp_init(ks[1], d, ff, cfg.mlp)
    return p


def block_apply(
    params: Params,
    x: jnp.ndarray,
    cfg,
    kind: str,
    *,
    positions: jnp.ndarray,
    state: Any = None,
    cache_pos: jnp.ndarray | None = None,
) -> Tuple[jnp.ndarray, Any, jnp.ndarray]:
    """Returns (x_out, new_state, aux_loss)."""
    aux = jnp.float32(0.0)
    sp = cfg.seq_shard and x.shape[1] > 1
    h = layers.apply_norm(params["norm1"], x, cfg.norm)
    if sp:
        # Megatron-style SP: residual stream lives seq-sharded; gather the full
        # sequence only at the mixer/MLP entry, reduce-scatter on the way out.
        h = dctx.constrain(h, "batch", None, None)
    if kind in ("attn", "attn_local", "attn_chunk", "attn_global"):
        akind = {"attn": "causal", "attn_local": "local", "attn_chunk": "chunk", "attn_global": "causal"}[kind]
        mix, new_state = layers.gqa_apply(
            params["attn"], h, cfg, kind=akind, positions=positions,
            rope=(kind != "attn_global"), cache=state, cache_pos=cache_pos,
        )
    elif kind == "mla":
        mix, new_state = mla.mla_apply(
            params["attn"], h, cfg, positions=positions, cache=state, cache_pos=cache_pos
        )
    elif kind == "rec":
        mix, new_state = rglru.rglru_apply(params["rec"], h, cfg, state)
    elif kind == "mlstm":
        mix, new_state = xlstm.mlstm_apply(params["mix"], h, cfg, state)
        if sp:
            mix = dctx.constrain(mix, "batch", "model", None)
        return x + mix, new_state, aux
    elif kind == "slstm":
        mix, new_state = xlstm.slstm_apply(params["mix"], h, cfg, state)
        if sp:
            mix = dctx.constrain(mix, "batch", "model", None)
        return x + mix, new_state, aux
    else:
        raise ValueError(kind)
    if sp:
        mix = dctx.constrain(mix, "batch", "model", None)   # reduce-scatter
    x = x + mix
    h2 = layers.apply_norm(params["norm2"], x, cfg.norm)
    if sp:
        h2 = dctx.constrain(h2, "batch", None, None)        # all-gather
    if "moe" in params:
        ff_out, aux = moe.moe_apply(params["moe"], h2, cfg)
    else:
        ff_out = layers.apply_mlp(params["mlp"], h2, cfg.mlp)
    if sp:
        ff_out = dctx.constrain(ff_out, "batch", "model", None)
    return x + ff_out, new_state, aux


def block_init_state(cfg, kind: str, batch: int, t_cache: int):
    """Decode-time state for one block of the given kind (None for train)."""
    if kind in ("attn", "attn_local", "attn_chunk", "attn_global"):
        tl = layers.cache_len_for_kind(
            {"attn": "causal", "attn_local": "local", "attn_chunk": "chunk", "attn_global": "causal"}[kind],
            t_cache, cfg.window, cfg.chunk,
        )
        return layers.init_kv_cache(batch, tl, cfg.num_kv_heads, cfg.resolved_head_dim)
    if kind == "mla":
        return mla.mla_init_cache(batch, t_cache, cfg)
    if kind == "rec":
        return rglru.rglru_init_state(batch, cfg)
    if kind == "mlstm":
        return xlstm.mlstm_init_state(batch, cfg)
    if kind == "slstm":
        return xlstm.slstm_init_state(batch, cfg)
    raise ValueError(kind)


# --------------------------------------------------------------------------- model

def _layer_plan(cfg) -> Tuple[Tuple[str, ...], int]:
    """(prefix kinds, number of scanned pattern repetitions)."""
    n_scanned = cfg.num_layers - len(cfg.prefix_kinds)
    assert n_scanned % len(cfg.pattern) == 0, (
        f"{cfg.name}: {n_scanned} layers not divisible by pattern {cfg.pattern}"
    )
    return cfg.prefix_kinds, n_scanned // len(cfg.pattern)


def init_params(cfg, key) -> Params:
    prefix, reps = _layer_plan(cfg)
    ks = jax.random.split(key, 5)
    vocab = layers.pad_vocab(cfg.vocab_size)
    p: Params = {
        "embed": layers.embed_init(ks[0], vocab, cfg.d_model),
        "final_norm": layers.norm_init(cfg.d_model, cfg.norm),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = layers.dense_init(ks[1], cfg.d_model, vocab)
    # unscanned prefix layers
    pk = jax.random.split(ks[2], max(len(prefix), 1))
    p["prefix"] = [
        block_init(pk[i], cfg, k if k != "attn_dense_prefix" else "mla",
                   dense_ff=cfg.dense_d_ff if k == "attn_dense_prefix" else None)
        for i, k in enumerate(prefix)
    ]
    # scanned super-blocks: stack params along leading axis per pattern position
    def one_superblock(k):
        kk = jax.random.split(k, len(cfg.pattern))
        return tuple(block_init(kk[i], cfg, kind) for i, kind in enumerate(cfg.pattern))

    sk = jax.random.split(ks[3], reps)
    per_rep = [one_superblock(k) for k in sk]
    p["blocks"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per_rep)
    if cfg.mtp_heads:
        p["mtp"] = {
            "proj": layers.dense_init(ks[4], 2 * cfg.d_model, cfg.d_model),
            "block": block_init(jax.random.fold_in(ks[4], 1), cfg, cfg.pattern[0]),
            "norm": layers.norm_init(cfg.d_model, cfg.norm),
        }
    return p


def _prefix_kind(k: str) -> str:
    return "mla" if k == "attn_dense_prefix" else k


def forward(
    params: Params,
    cfg,
    tokens: jnp.ndarray,
    extra_embeds: jnp.ndarray | None = None,
    *,
    return_hidden: bool = False,
):
    """Teacher-forced forward pass -> logits (B, S, vocab_padded)."""
    prefix, reps = _layer_plan(cfg)
    x = params["embed"][tokens]
    if extra_embeds is not None:
        # multimodal stub frontend: precomputed patch/frame embeddings prepended
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    x = dctx.constrain(x, "batch", None, None)
    s = x.shape[1]
    positions = jnp.arange(s)
    aux_total = jnp.float32(0.0)

    for pparams, kind in zip(params["prefix"], prefix):
        x, _, aux = block_apply(pparams, x, cfg, _prefix_kind(kind), positions=positions)
        aux_total += aux

    def superblock(carry, blk_params):
        x, aux_acc = carry
        aux_step = jnp.float32(0.0)
        for i, kind in enumerate(cfg.pattern):
            x, _, aux = block_apply(blk_params[i], x, cfg, kind, positions=positions)
            aux_step += aux
        if cfg.seq_shard:
            # SP: keep the scan-carry residual stream sequence-sharded over
            # `model` so saved activations are 1/TP per chip (DESIGN.md SS5)
            x = dctx.constrain(x, "batch", "model", None)
        return (x, aux_acc + aux_step), None

    if cfg.unroll_layers:
        # dry-run calibration path: every layer explicit in HLO (exact
        # cost_analysis; XLA counts while bodies once)
        for r in range(reps):
            blk = jax.tree.map(lambda p: p[r], params["blocks"])
            (x, aux_total), _ = superblock((x, aux_total), blk)
    else:
        (x, aux_total), _ = jax.lax.scan(
            jax.checkpoint(superblock), (x, aux_total), params["blocks"]
        )
    h = layers.apply_norm(params["final_norm"], x, cfg.norm)
    if return_hidden:
        return h, aux_total
    logits = h @ (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    return logits, aux_total


def loss_fn(params: Params, cfg, batch: Dict[str, jnp.ndarray]):
    """Causal LM loss (+ optional deepseek MTP auxiliary loss)."""
    tokens, labels = batch["tokens"], batch["labels"]
    extra = batch.get("extra_embeds")
    vocab = layers.pad_vocab(cfg.vocab_size)
    h, aux = forward(params, cfg, tokens, extra, return_hidden=True)
    if extra is not None:
        h = h[:, extra.shape[1]:]          # loss only over text positions
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = (h @ unembed).astype(jnp.float32)
    # keep the big logits tensor vocab-sharded over `model` (GSPMD reduces the
    # softmax across shards rather than materialising (B, S, V) per device)
    logits = dctx.constrain(logits, "batch", None, "model")
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    loss = nll.mean()
    metrics = {"nll": loss, "aux": aux}
    if cfg.mtp_heads and "mtp" in params:
        # multi-token prediction: predict t+2 from [h_t ; emb(t+1)]
        emb_next = params["embed"][tokens[:, 1:]]
        hcat = jnp.concatenate([h[:, :-1], emb_next], axis=-1)
        h2 = hcat @ params["mtp"]["proj"]
        h2, _, _ = block_apply(
            params["mtp"]["block"], h2, cfg, cfg.pattern[0],
            positions=jnp.arange(h2.shape[1]),
        )
        h2 = layers.apply_norm(params["mtp"]["norm"], h2, cfg.norm)
        logits2 = (h2 @ unembed).astype(jnp.float32)
        # position t of h2 predicts token t+2, whose label is labels[t+1]
        mtp_labels = labels[:, 1:]
        logp2 = jax.nn.log_softmax(logits2, axis=-1)
        nll2 = -jnp.take_along_axis(logp2, mtp_labels[..., None], axis=-1)[..., 0]
        mtp_loss = nll2.mean()
        metrics["mtp_nll"] = mtp_loss
        loss = loss + 0.3 * mtp_loss
    loss = loss + 0.01 * aux
    return loss, metrics


# ------------------------------------------------------------------------- serving

def init_decode_state(cfg, batch: int, t_cache: int):
    prefix, reps = _layer_plan(cfg)
    state = {
        "prefix": [
            block_init_state(cfg, _prefix_kind(k), batch, t_cache) for k in prefix
        ],
        "blocks": [],
    }
    # scanned: stack states along leading rep axis per pattern position
    per_pos = []
    for kind in cfg.pattern:
        one = block_init_state(cfg, kind, batch, t_cache)
        stacked = jax.tree.map(lambda x: jnp.stack([x] * reps), one)
        per_pos.append(stacked)
    state["blocks"] = tuple(per_pos)
    return state


def _run_stack(params, cfg, x, positions, state, cache_pos):
    """Shared prefill/decode driver over prefix + scanned blocks, with state."""
    prefix, _ = _layer_plan(cfg)
    new_prefix_states = []
    for pparams, kind, st in zip(params["prefix"], prefix, state["prefix"]):
        x, nst, _ = block_apply(
            pparams, x, cfg, _prefix_kind(kind), positions=positions,
            state=st, cache_pos=cache_pos,
        )
        new_prefix_states.append(nst)

    def superblock(carry, scanned):
        x = carry
        blk_params, blk_states = scanned
        new_states = []
        for i, kind in enumerate(cfg.pattern):
            x, nst, _ = block_apply(
                blk_params[i], x, cfg, kind, positions=positions,
                state=blk_states[i], cache_pos=cache_pos,
            )
            new_states.append(nst)
        if cfg.seq_shard and x.shape[1] > 1:
            x = dctx.constrain(x, "batch", "model", None)
        return x, tuple(new_states)

    if cfg.unroll_layers:
        _, reps = _layer_plan(cfg)
        outs = []
        for r in range(reps):
            blk = jax.tree.map(lambda p: p[r], (params["blocks"], state["blocks"]))
            x, nst = superblock(x, blk)
            outs.append(nst)
        new_block_states = jax.tree.map(lambda *xs: jnp.stack(xs), *outs)
    else:
        x, new_block_states = jax.lax.scan(
            superblock, x, (params["blocks"], state["blocks"])
        )
    return x, {"prefix": new_prefix_states, "blocks": new_block_states}


def prefill(params: Params, cfg, tokens: jnp.ndarray, t_cache: int,
            extra_embeds: jnp.ndarray | None = None):
    """Process the prompt, fill caches; returns (last-token logits, state)."""
    x = params["embed"][tokens]
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    b, s = x.shape[0], x.shape[1]
    positions = jnp.arange(s)
    state = init_decode_state(cfg, b, t_cache)
    x, state = _run_stack(params, cfg, x, positions, state, jnp.int32(0))
    h = layers.apply_norm(params["final_norm"], x[:, -1:], cfg.norm)
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = (h @ unembed)[:, 0].astype(jnp.float32)
    return logits, state


def decode_step(params: Params, cfg, token: jnp.ndarray, state, pos: jnp.ndarray):
    """One decode step: token (B,) at absolute position ``pos`` (scalar)."""
    x = params["embed"][token][:, None, :]
    positions = jnp.full((1,), pos, jnp.int32)
    x, state = _run_stack(params, cfg, x, positions, state, pos)
    h = layers.apply_norm(params["final_norm"], x, cfg.norm)
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = (h @ unembed)[:, 0].astype(jnp.float32)
    return logits, state
