"""Bayes decision head: the paper's operators at the LM decision layer.

Fuses K-way token posteriors from multiple conditionally-independent sources
(MTP head vs main head, modality branches, ensemble samples) with eq (5), and
gates emission on the fused confidence -- the LM analogue of the paper's
timely-reliable lane-change decision (DESIGN.md SS4).

Two paths, mirroring core/:
* analytic  -- float eq (5) over top-k candidate tokens (production).
* stochastic -- packed SNE streams + AND + popcount (the paper's circuit),
  available for validation and for the paper_bayes config.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.fusion import fuse_analytic
from repro.kernels.bayes_decide.ops import bayes_decide


def fuse_posteriors(
    logits_sources: jnp.ndarray, top_k: int = 8
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Fuse per-source next-token posteriors over the union top-k candidates.

    logits_sources: (M, B, V).  Returns (token (B,), confidence (B,),
    fused_topk (B, top_k)).  Candidates are the top-k of the mean logits; each
    source's posterior is restricted + renormalized over candidates, then fused
    with eq (5) under a uniform candidate prior.
    """
    m, b, v = logits_sources.shape
    mean_logits = jnp.mean(logits_sources, axis=0)
    _, cand = jax.lax.top_k(mean_logits, top_k)                  # (B, k)
    cand_logits = jnp.take_along_axis(
        logits_sources, cand[None].repeat(m, 0), axis=-1
    )                                                            # (M, B, k)
    p = jax.nn.softmax(cand_logits, axis=-1)
    p = jnp.moveaxis(p, 0, -2)                                   # (B, M, k)
    fused = fuse_analytic(p)                                     # (B, k)
    best = jnp.argmax(fused, axis=-1)
    token = jnp.take_along_axis(cand, best[:, None], axis=-1)[:, 0]
    conf = jnp.take_along_axis(fused, best[:, None], axis=-1)[:, 0]
    return token, conf, fused


def reliable_decision(
    token: jnp.ndarray, conf: jnp.ndarray, threshold: float = 0.7
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Timely-reliable gating: emit only when fused confidence clears threshold.

    Returns (accept (B,) bool, token).  Rejected positions fall back to the
    caller's policy (resample, defer to a bigger model, keep lane -- the paper's
    P(A|B) < P(A) branch).
    """
    return conf >= threshold, token


def fuse_posteriors_stochastic(
    key: jax.Array, logits_sources: jnp.ndarray, top_k: int = 8, n_bits: int = 256
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Same decision through the paper's SC circuit, via the fused kernel.

    One ``bayes_decide`` launch does encode -> M-way AND -> popcount -> argmax
    in a single pass; nothing per-bit is materialised.
    """
    m, b, v = logits_sources.shape
    mean_logits = jnp.mean(logits_sources, axis=0)
    _, cand = jax.lax.top_k(mean_logits, top_k)
    cand_logits = jnp.take_along_axis(
        logits_sources, cand[None].repeat(m, 0), axis=-1
    )
    p = jax.nn.softmax(cand_logits, axis=-1)                     # (M, B, k)
    best, counts = bayes_decide(key, p, n_bits)                  # (B,), (B, k)
    fused = counts.astype(jnp.float32) / jnp.maximum(
        counts.sum(-1, keepdims=True).astype(jnp.float32), 1.0
    )
    token = jnp.take_along_axis(cand, best[:, None], axis=-1)[:, 0]
    conf = jnp.take_along_axis(fused, best[:, None], axis=-1)[:, 0]
    return token, conf
