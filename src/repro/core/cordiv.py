"""CORDIV -- correlated stochastic divider (Chen & Hayes 2016; paper Figs S7/S9/S10).

The circuit: ``q_t = d_t ? n_t : DFF`` where the D-flip-flop holds the last quotient
bit emitted while the divisor was high.  When the numerator stream is a bitwise
subset of the denominator stream (the correlation the paper engineers by sharing
SNEs), E[q] -> P(n) / P(d).

Three implementations:

* :func:`cordiv_scan`  -- exact bit-serial circuit semantics via ``lax.scan`` (the
  flip-flop is the scan carry), one scan step per stream bit.  This is the
  faithful reproduction and the oracle for the fast path.
* :func:`cordiv_fill`  -- the word-parallel production path: the flip-flop hold
  is a last-set-bit *fill* -- each quotient bit copies ``n`` at the most recent
  position where ``d`` was high.  Within each uint32 word the fill is computed
  by SWAR jump-doubling (5 shift rounds); across words a single ``lax.scan``
  over ``n_words`` carries one held bit.  Bit-identical to ``cordiv_scan`` on
  every input, with 32x fewer sequential steps and no unpack to uint8
  (DESIGN.md SS6).
* :func:`cordiv_ratio` -- the closed-form fixed point
  ``popcount(n & d) / popcount(d)``.  For n subset-of d this equals the quantity the
  serial circuit converges to, without any sequential dependency (DESIGN.md SS2).

Tests assert scan == fill bit-for-bit, and both agree with the ratio within the
O(1/sqrt(n_bits)) stochastic tolerance.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitops


def cordiv_scan(numer: jnp.ndarray, denom: jnp.ndarray, n_bits: int):
    """Bit-serial CORDIV over packed streams.

    Returns (quotient_stream_packed, estimate).  Leading axes broadcast.
    """
    n_bits_axis = -1
    nb = bitops.unpack_bits(numer, n_bits)           # (..., n_bits) uint8
    db = bitops.unpack_bits(denom, n_bits)
    # scan over the bit axis; carry = D-flip-flop state per leading element.
    nbt = jnp.moveaxis(nb, n_bits_axis, 0)
    dbt = jnp.moveaxis(db, n_bits_axis, 0)
    init = jnp.zeros(nbt.shape[1:], jnp.uint8)

    def step(dff, nd):
        n_t, d_t = nd
        q_t = jnp.where(d_t == 1, n_t, dff)
        dff_next = jnp.where(d_t == 1, n_t, dff)
        return dff_next, q_t

    _, q = jax.lax.scan(step, init, (nbt, dbt))
    qbits = jnp.moveaxis(q, 0, n_bits_axis)
    qpacked = bitops.pack_bits(qbits)
    return qpacked, bitops.decode(qpacked, n_bits)


def _fill_last_set(m: jnp.ndarray, d: jnp.ndarray):
    """SWAR last-set-bit fill within each uint32 word, LSB-first.

    For every bit position t, propagate the value ``m`` holds at the most
    recent position <= t where ``d`` is set.  Returns (val, known): ``val`` is
    the filled word (0 at positions with no prior set bit of ``d`` in the
    word), ``known`` is the prefix-OR of ``d`` (which positions were filled).
    Jump-doubling: after the round with shift s every position within distance
    2s of its source is resolved, so 5 rounds cover the 32-bit word.
    """
    val = m.astype(jnp.uint32)
    known = d.astype(jnp.uint32)
    for s in (1, 2, 4, 8, 16):
        shifted_known = known << s
        take = shifted_known & ~known
        val = val | ((val << s) & take)
        known = known | shifted_known
    return val, known


def cordiv_fill(numer: jnp.ndarray, denom: jnp.ndarray, n_bits: int):
    """Word-parallel CORDIV: same circuit as :func:`cordiv_scan`, 32x fewer steps.

    The D-flip-flop semantics ``q_t = d_t ? n_t : q_last`` mean each quotient
    bit equals ``(n & d)`` at the last position where ``d`` was high (0 before
    the first).  That is a last-set-bit fill: SWAR inside each word, then one
    held bit carried across the ``n_words`` word boundaries by ``lax.scan``.
    Returns (quotient_stream_packed, estimate); bit-identical to
    ``cordiv_scan`` on every input.  Leading axes broadcast.
    """
    numer, denom = jnp.broadcast_arrays(numer, denom)
    m = numer & denom
    val, known = _fill_last_set(m, denom)
    vt = jnp.moveaxis(val, -1, 0)            # (n_words, ...)
    kt = jnp.moveaxis(known, -1, 0)
    dt = jnp.moveaxis(denom, -1, 0)
    init = jnp.zeros(vt.shape[1:], jnp.uint32)   # held bit from previous words

    def step(carry, xs):
        v, k, d = xs
        # positions before the first set bit of d in this word take the carry
        q = v | jnp.where(carry == 1, ~k, jnp.uint32(0))
        # bit 31 of the filled word is m at the word's last set d position
        carry_next = jnp.where(d != 0, (v >> 31) & jnp.uint32(1), carry)
        return carry_next, q

    _, q = jax.lax.scan(step, init, (vt, kt, dt))
    qpacked = jnp.moveaxis(q, 0, -1) & bitops.pad_mask(n_bits)
    return qpacked, bitops.decode(qpacked, n_bits)


def ratio_from_counts(numer_count, denom_count) -> jnp.ndarray:
    """The CORDIV fixed point from popcounts, 0 at 0/0.

    Single home of the zero-denominator convention, shared by
    :func:`cordiv_ratio` and the count-level consumers (the fused net_sweep
    lowering) so the two can never diverge.
    """
    num = jnp.asarray(numer_count, jnp.float32)
    den = jnp.asarray(denom_count, jnp.float32)
    return jnp.where(den > 0, num / jnp.maximum(den, 1.0), 0.0)


def cordiv_ratio(numer: jnp.ndarray, denom: jnp.ndarray) -> jnp.ndarray:
    """Closed-form CORDIV fixed point: popcount(n & d) / popcount(d), safe at 0/0."""
    return ratio_from_counts(bitops.popcount(numer & denom), bitops.popcount(denom))


def make_superset(key: jax.Array, numer: jnp.ndarray, p_n, p_d, n_bits: int):
    """Superset completion: build a stream d with P(d)=p_d and numer subset-of d.

    d = n OR g with g an independent stream of probability
    (p_d - p_n) / (1 - p_n); used when the denominator is known only marginally
    (e.g. P(B) given directly rather than through the MUX) so that CORDIV's
    correlation requirement still holds.
    """
    from repro.core import sne

    p_n = jnp.asarray(p_n, jnp.float32)
    p_d = jnp.asarray(p_d, jnp.float32)
    p_g = jnp.clip((p_d - p_n) / jnp.maximum(1.0 - p_n, 1e-6), 0.0, 1.0)
    g = sne.encode_uncorrelated(key, p_g, n_bits)
    return numer | g
