"""CORDIV -- correlated stochastic divider (Chen & Hayes 2016; paper Figs S7/S9/S10).

The circuit: ``q_t = d_t ? n_t : DFF`` where the D-flip-flop holds the last quotient
bit emitted while the divisor was high.  When the numerator stream is a bitwise
subset of the denominator stream (the correlation the paper engineers by sharing
SNEs), E[q] -> P(n) / P(d).

Two implementations:

* :func:`cordiv_scan`  -- exact bit-serial circuit semantics via ``lax.scan`` (the
  flip-flop is the scan carry).  This is the faithful reproduction.
* :func:`cordiv_ratio` -- the TPU production path: the closed-form fixed point
  ``popcount(n & d) / popcount(d)``.  For n subset-of d this equals the quantity the
  serial circuit converges to, without the sequential dependency (DESIGN.md SS2).

Tests assert the two agree within the O(1/sqrt(n_bits)) stochastic tolerance.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitops


def cordiv_scan(numer: jnp.ndarray, denom: jnp.ndarray, n_bits: int):
    """Bit-serial CORDIV over packed streams.

    Returns (quotient_stream_packed, estimate).  Leading axes broadcast.
    """
    n_bits_axis = -1
    nb = bitops.unpack_bits(numer, n_bits)           # (..., n_bits) uint8
    db = bitops.unpack_bits(denom, n_bits)
    # scan over the bit axis; carry = D-flip-flop state per leading element.
    nbt = jnp.moveaxis(nb, n_bits_axis, 0)
    dbt = jnp.moveaxis(db, n_bits_axis, 0)
    init = jnp.zeros(nbt.shape[1:], jnp.uint8)

    def step(dff, nd):
        n_t, d_t = nd
        q_t = jnp.where(d_t == 1, n_t, dff)
        dff_next = jnp.where(d_t == 1, n_t, dff)
        return dff_next, q_t

    _, q = jax.lax.scan(step, init, (nbt, dbt))
    qbits = jnp.moveaxis(q, 0, n_bits_axis)
    qpacked = bitops.pack_bits(qbits)
    return qpacked, bitops.decode(qpacked, n_bits)


def cordiv_ratio(numer: jnp.ndarray, denom: jnp.ndarray) -> jnp.ndarray:
    """Closed-form CORDIV fixed point: popcount(n & d) / popcount(d), safe at 0/0."""
    num = bitops.popcount(numer & denom).astype(jnp.float32)
    den = bitops.popcount(denom).astype(jnp.float32)
    return jnp.where(den > 0, num / jnp.maximum(den, 1.0), 0.0)


def make_superset(key: jax.Array, numer: jnp.ndarray, p_n, p_d, n_bits: int):
    """Superset completion: build a stream d with P(d)=p_d and numer subset-of d.

    d = n OR g with g an independent stream of probability
    (p_d - p_n) / (1 - p_n); used when the denominator is known only marginally
    (e.g. P(B) given directly rather than through the MUX) so that CORDIV's
    correlation requirement still holds.
    """
    from repro.core import sne

    p_n = jnp.asarray(p_n, jnp.float32)
    p_d = jnp.asarray(p_d, jnp.float32)
    p_g = jnp.clip((p_d - p_n) / jnp.maximum(1.0 - p_n, 1e-6), 0.0, 1.0)
    g = sne.encode_uncorrelated(key, p_g, n_bits)
    return numer | g
