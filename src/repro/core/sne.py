"""Stochastic number encoders (SNEs) -- Fig 2a / S5 of the paper.

An SNE turns a probability into a Bernoulli bitstream.  In the paper the entropy
comes from the memristor's stochastic V_th and the probability is programmed by the
pulse amplitude ``V_in`` (uncorrelated mode, Fig 2b) or the comparator reference
``V_ref`` (correlated mode, Fig 2c).  Here both modes are reproduced:

* ``encode_uncorrelated`` -- parallel SNEs: independent entropy per stream.
* ``encode_correlated``   -- one SNE, several comparator references: all streams in
  the group share the same per-bit entropy word ``u`` and are therefore maximally
  positively correlated; passing ``negate=True`` for a stream models the NOT gate on
  the comparator output (Fig S5b), yielding maximal *negative* correlation.
* ``encode_via_device``   -- drives the encoder from the OU memristor simulator so
  statistical equivalence with the calibrated device can be asserted in tests.

Streams are returned packed (see :mod:`repro.core.bitops`).  The production
encoders run entirely in the packed uint32 domain through
:mod:`repro.core.rng` -- counter-based byte entropy compared against the 8-bit
programmed threshold, no per-bit float intermediates and no ``pack_bits``
(DESIGN.md SS3).  The float-uniform construction survives only in
``encode_float_reference``, the statistical oracle used by tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bitops, rng
from repro.core.device import DEFAULT_PARAMS, MemristorParams, sample_ou_path


# --- the paper's programmed transfer curves (Fig 2b/2c) ---------------------------

def p_from_vin(v_in: jax.Array, params: MemristorParams = DEFAULT_PARAMS) -> jnp.ndarray:
    """P_uncorrelated(V_in) = sigmoid(k_unc (V_in - v0_unc))  [Fig 2b fit]."""
    return jax.nn.sigmoid(params.k_unc * (jnp.asarray(v_in, jnp.float32) - params.v0_unc))


def vin_from_p(p: jax.Array, params: MemristorParams = DEFAULT_PARAMS) -> jnp.ndarray:
    """Inverse of :func:`p_from_vin` (programming voltage for a target probability)."""
    p = jnp.clip(jnp.asarray(p, jnp.float32), 1e-6, 1.0 - 1e-6)
    return params.v0_unc + jnp.log(p / (1.0 - p)) / params.k_unc


def p_from_vref(v_ref: jax.Array, params: MemristorParams = DEFAULT_PARAMS) -> jnp.ndarray:
    """P_correlated(V_ref) = 1 - sigmoid(k_corr (V_ref - v0_corr))  [Fig 2c fit]."""
    return 1.0 - jax.nn.sigmoid(
        params.k_corr * (jnp.asarray(v_ref, jnp.float32) - params.v0_corr)
    )


def vref_from_p(p: jax.Array, params: MemristorParams = DEFAULT_PARAMS) -> jnp.ndarray:
    """Inverse of :func:`p_from_vref`."""
    p = jnp.clip(jnp.asarray(p, jnp.float32), 1e-6, 1.0 - 1e-6)
    return params.v0_corr + jnp.log((1.0 - p) / p) / params.k_corr


# --- encoders ---------------------------------------------------------------------

def encode_uncorrelated(
    key: jax.Array, p: jax.Array, n_bits: int, impl: str = "fast"
) -> jnp.ndarray:
    """Encode probabilities ``p`` (any shape) into independent packed streams.

    Output shape: ``p.shape + (n_words,)``.  Runs in the packed domain
    (counter-based byte entropy, 8-bit threshold comparator).
    ``impl='threefry'`` swaps the entropy source for ``jax.random.bits``.
    """
    return rng.encode_packed(key, p, n_bits, impl=impl)


def encode_correlated(
    key: jax.Array,
    p: jax.Array,
    n_bits: int,
    negate: jax.Array | None = None,
    impl: str = "fast",
) -> jnp.ndarray:
    """Encode ``p`` (shape ``(..., k)``) as ``k`` streams sharing one entropy source.

    All streams in the trailing axis compare the same per-bit entropy byte
    against their own threshold (one SNE, many comparator references), so
    ``bit_i = byte < t_i`` -- maximal positive correlation.  Entries where
    ``negate`` is truthy use the complementary comparator (NOT gate):
    ``bit_i = (255 - byte) < t_i`` -- maximal negative correlation with the
    non-negated streams.
    """
    return rng.encode_packed_correlated(key, p, n_bits, negate=negate, impl=impl)


def encode_float_reference(key: jax.Array, p: jax.Array, n_bits: int) -> jnp.ndarray:
    """The seed float32-uniform encoder, kept as a statistical oracle for tests.

    Draws ``(..., n_bits)`` float uniforms and packs -- 32 bits of entropy
    traffic per stream bit.  Production code should use
    :func:`encode_uncorrelated` instead.
    """
    p = jnp.asarray(p, jnp.float32)
    u = jax.random.uniform(key, p.shape + (n_bits,), dtype=jnp.float32)
    bits = u < p[..., None]
    return bitops.pack_bits(bits)


def encode_via_device(
    key: jax.Array,
    p: jax.Array,
    n_bits: int,
    params: MemristorParams = DEFAULT_PARAMS,
) -> jnp.ndarray:
    """Encode with entropy drawn from the OU memristor simulator.

    The per-bit switching threshold V_th,t follows the calibrated OU process; the
    programming voltage for target probability ``p`` is chosen so that
    P(V_th,t < V_in) = p under the stationary Gaussian.  This is the
    device-faithful path; tests assert it matches :func:`encode_uncorrelated`
    statistically.
    """
    p = jnp.asarray(p, jnp.float32)
    flat = p.reshape(-1)
    keys = jax.random.split(key, flat.shape[0])
    # Per-stream OU path of V_th; V_in from the stationary Gaussian quantile.
    from jax.scipy.stats import norm

    v_in = params.vth_mu + params.vth_sigma * norm.ppf(
        jnp.clip(flat, 1e-6, 1 - 1e-6)
    )

    def one(k, v):
        vth = sample_ou_path(k, n_bits, params)
        return (v > vth).astype(jnp.uint8)

    bits = jax.vmap(one)(keys, v_in)
    return bitops.pack_bits(bits).reshape(p.shape + (bitops.n_words(n_bits),))
