"""Hardware Bayesian fusion operator (paper Fig 4 / S9 / S10, eqs (2)-(5)).

Fuses M conditionally-independent modal posteriors over K classes:

    p(y | x_1..x_M)  proportional-to  prod_i p(y | x_i) / p(y)^(M-1)      (eq 5)

Circuit: one probabilistic AND chain per class (the numerator products), division
by the prior via CORDIV, and the Fig-S10 normalization module so the class scores
sum to one.  The normalization denominator is realised as a MUX tree (weighted
adder) over the class-numerator streams -- all selects fresh/uncorrelated -- and
the final ratio by CORDIV; both the serial-circuit and the closed-form popcount
paths are provided.

``fuse_analytic`` is the float oracle (also the eq-(5) math used at video scale in
Movie S1 and by the `fusion_map` Pallas kernel).
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import bitops, cordiv, logic, sne


def fuse_analytic(p_modal: jnp.ndarray, prior: jnp.ndarray | None = None) -> jnp.ndarray:
    """Eq (5) with normalization.

    p_modal: (..., M, K) single-modal posteriors over K classes.
    prior:   (K,) class prior; uniform if None (the paper's circuit assumption).
    Returns (..., K) normalized fused posterior.
    """
    p_modal = jnp.asarray(p_modal, jnp.float32)
    m = p_modal.shape[-2]
    k = p_modal.shape[-1]
    if prior is None:
        prior = jnp.full((k,), 1.0 / k, jnp.float32)
    prior = jnp.asarray(prior, jnp.float32)
    log_q = jnp.sum(jnp.log(jnp.clip(p_modal, 1e-9, 1.0)), axis=-2) - (
        m - 1
    ) * jnp.log(jnp.clip(prior, 1e-9, 1.0))
    q = jnp.exp(log_q - jnp.max(log_q, axis=-1, keepdims=True))
    return q / jnp.sum(q, axis=-1, keepdims=True)


def fuse_unnormalized_analytic(p_modal, prior=None) -> jnp.ndarray:
    """Eq (5) numerator  prod_i p_i / prior^(M-1)  (may exceed 1 -- Fig S10 rationale)."""
    p_modal = jnp.asarray(p_modal, jnp.float32)
    m, k = p_modal.shape[-2], p_modal.shape[-1]
    if prior is None:
        prior = jnp.full((k,), 1.0 / k, jnp.float32)
    return jnp.prod(p_modal, axis=-2) / jnp.asarray(prior, jnp.float32) ** (m - 1)


@dataclasses.dataclass
class FusionTrace:
    streams: Dict[str, jnp.ndarray]
    n_bits: int
    fused_scan: jnp.ndarray      # (..., K) serial-circuit normalized posterior
    fused_ratio: jnp.ndarray     # (..., K) closed-form normalized posterior
    fused_analytic: jnp.ndarray  # (..., K) float oracle


def bayes_fusion(
    key: jax.Array,
    p_modal: jnp.ndarray,
    n_bits: int = 100,
    prior: jnp.ndarray | None = None,
    impl: str = "fast",
) -> FusionTrace:
    """Run the hardware Bayesian fusion operator.

    p_modal: (..., M, K).  The M modal streams per class come from parallel SNEs
    (conditional independence, eq (3)); the normalization MUX tree uses fresh
    selects (Fig S6 requirement).  ``impl='threefry'`` draws every stream --
    encoders and MUX-tree selects alike -- from ``jax.random.bits``, keeping
    the whole operator reproducible against other JAX code.
    """
    p_modal = jnp.asarray(p_modal, jnp.float32)
    m, k = p_modal.shape[-2], p_modal.shape[-1]
    k_enc, k_tree = jax.random.split(key)
    # (..., M, K, n_words) independent streams -- one SNE per (modality, class).
    s_modal = sne.encode_uncorrelated(k_enc, p_modal, n_bits, impl=impl)
    # Numerator per class: AND across modalities (one-step multiplication).
    numer = s_modal[..., 0, :, :]
    for i in range(1, m):
        numer = bitops.band(numer, s_modal[..., i, :, :])   # (..., K, n_words)
    # Normalization denominator: MUX tree over class numerators -> (1/Kp) sum_j q_j.
    denom, _ = logic.mux_tree(k_tree, numer, n_bits, impl=impl)  # (..., n_words)

    # Closed-form path: q_c / sum_j q_j  (the 1/Kp scale cancels in the ratio).
    cnt_num = bitops.popcount(numer).astype(jnp.float32)    # (..., K)
    cnt_den = jnp.sum(cnt_num, axis=-1, keepdims=True)
    fused_ratio = jnp.where(cnt_den > 0, cnt_num / jnp.maximum(cnt_den, 1.0), 1.0 / k)

    # Serial-circuit path: CORDIV(numer_c, tree) with superset completion per class
    # (the tree output is not a bitwise superset; complete it, as Fig S10's
    # normalization module does with its feedback register).
    denom_sup = numer | denom[..., None, :]
    _, q_scan = cordiv.cordiv_fill(numer, denom_sup, n_bits)   # (..., K)
    z = jnp.sum(q_scan, axis=-1, keepdims=True)
    fused_scan = jnp.where(z > 0, q_scan / jnp.maximum(z, 1e-9), 1.0 / k)

    # Prior division (non-uniform priors): fold into the analytic oracle; the
    # circuit assumes uniform p(y) "for the convenience of circuit designs"
    # (paper Methods) -- we do the same for the stream paths.
    return FusionTrace(
        streams={"numer": numer, "denom": denom},
        n_bits=n_bits,
        fused_scan=fused_scan,
        fused_ratio=fused_ratio,
        fused_analytic=fuse_analytic(p_modal, prior),
    )


def detection_fusion(
    key: jax.Array,
    p_det_modal: jnp.ndarray,
    n_bits: int = 100,
) -> jnp.ndarray:
    """Binary obstacle-detection fusion (the Fig 4 use case).

    p_det_modal: (..., M) per-modality detection confidences for one candidate box;
    classes are {obstacle, background}; uniform prior.  Returns fused P(obstacle).
    """
    p = jnp.asarray(p_det_modal, jnp.float32)
    p2 = jnp.stack([p, 1.0 - p], axis=-1)           # (..., M, 2)
    tr = bayes_fusion(key, p2, n_bits=n_bits)
    return tr.fused_ratio[..., 0]
