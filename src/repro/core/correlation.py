"""Stochastic-number correlation metrics (paper Methods: Pearson rho and SCC).

Both are computed from the 2x2 contingency counts of paired bits:
a = #(1,1), b = #(1,0), c = #(0,1), d = #(0,0).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import bitops


def pair_counts(x: jnp.ndarray, y: jnp.ndarray, n_bits: int):
    """Contingency counts (a, b, c, d) of two packed streams."""
    mask = bitops.pad_mask(n_bits)
    nx = (x ^ jnp.uint32(0xFFFFFFFF)) & mask
    ny = (y ^ jnp.uint32(0xFFFFFFFF)) & mask
    a = bitops.popcount(x & y)
    b = bitops.popcount(x & ny)
    c = bitops.popcount(nx & y)
    d = bitops.popcount(nx & ny)
    return a, b, c, d


def pearson(x: jnp.ndarray, y: jnp.ndarray, n_bits: int) -> jnp.ndarray:
    """Pearson correlation rho(S_x, S_y) from the paper's Methods formula."""
    a, b, c, d = (v.astype(jnp.float32) for v in pair_counts(x, y, n_bits))
    num = a * d - b * c
    den = jnp.sqrt((a + b) * (a + c) * (b + d) * (c + d))
    return jnp.where(den > 0, num / den, 0.0).astype(jnp.float32)


def scc(x: jnp.ndarray, y: jnp.ndarray, n_bits: int) -> jnp.ndarray:
    """SC correlation (Alaghi & Hayes 2013) from the paper's Methods formula."""
    a, b, c, d = (v.astype(jnp.float32) for v in pair_counts(x, y, n_bits))
    n = a + b + c + d
    ad_bc = a * d - b * c
    den_pos = n * jnp.minimum(a + b, a + c) - (a + b) * (a + c)
    den_neg = (a + b) * (a + c) - n * jnp.maximum(a - d, 0.0)
    out = jnp.where(
        ad_bc >= 0,
        jnp.where(den_pos != 0, ad_bc / den_pos, 0.0),
        jnp.where(den_neg != 0, ad_bc / den_neg, 0.0),
    )
    return out.astype(jnp.float32)


def correlation_matrix(streams, n_bits: int, metric: str = "pearson") -> jnp.ndarray:
    """Pairwise correlation matrix over a dict/list of packed streams."""
    fn = pearson if metric == "pearson" else scc
    items = list(streams.values()) if isinstance(streams, dict) else list(streams)
    k = len(items)
    rows = []
    for i in range(k):
        rows.append(jnp.stack([fn(items[i], items[j], n_bits) for j in range(k)]))
    return jnp.stack(rows)
