"""Core library: the paper's contribution as composable JAX modules."""

from repro.core import bitops, cordiv, correlation, device, fusion, graph, inference, latency, logic, rng, sne  # noqa: F401
from repro.core.cordiv import cordiv_fill, cordiv_ratio, cordiv_scan, make_superset  # noqa: F401
from repro.core.device import DEFAULT_PARAMS, MemristorParams, wear_scale  # noqa: F401
from repro.core.fusion import bayes_fusion, detection_fusion, fuse_analytic  # noqa: F401
from repro.core.inference import analytic_posterior, bayes_inference, bayes_inference_marginal  # noqa: F401
from repro.core.logic import Corr, prob_and, prob_mux, prob_or, prob_xor  # noqa: F401
from repro.core.sne import encode_correlated, encode_uncorrelated, encode_via_device  # noqa: F401
