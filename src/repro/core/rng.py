"""Counter-based entropy for packed stochastic encoding (DESIGN.md SS2/SS3).

The hot-path encoders used to draw a full float32 ``(..., n_bits)`` uniform
tensor -- 32 bits of entropy traffic per emitted stream bit -- and then pay a
shift-reduce ``pack_bits`` to get into the packed domain.  This module is the
packed-domain replacement: entropy comes as counter-based uint32 words (the
TPU stand-in for the memristor's stochastic V_th), each word contributes its
4 bytes as 4 independent uniform(0..255) draws, and a stream bit is 1 iff
``byte < round(p * 256)``.  That is exactly the scheme the
``kernels/sne_encode`` Pallas kernel uses, so the core encoders and the
kernel stay bit-compatible.

Two generators produce the words: the default ``counter_hash_words`` (keyed
counters through two lowbias32 avalanche rounds -- the entropy-bound hot
path's fast generator) and ``jax.random.bits`` Threefry
(``random_words(..., impl='threefry')``) when reproducibility against other
JAX code matters more than speed.

Per stream bit this costs 8 bits of entropy (4x less traffic than the float
path) and the output is *born packed* -- no per-bit intermediates, no
``pack_bits`` -- which is where the ~32x hot-loop win comes from.

Probabilities are quantised to 8 bits (the V_in programming DAC of the
hardware SNE): max quantisation error 1/512, far below the O(1/sqrt(n_bits))
stochastic noise floor for every stream length used in practice.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitops

# Stream bits contributed by one uint32 entropy word (one per byte).
BITS_PER_RAND_WORD = 4
# Entropy words consumed per packed output word (32 stream bits / 4 per word).
RAND_WORDS_PER_OUT_WORD = 8


def threshold_from_p(p: jnp.ndarray) -> jnp.ndarray:
    """Probability -> 8-bit comparator threshold in [0, 256] (uint32)."""
    p = jnp.asarray(p, jnp.float32)
    return jnp.clip(jnp.round(p * 256.0), 0.0, 256.0).astype(jnp.uint32)


def threshold_int(p: float) -> int:
    """:func:`threshold_from_p` for one Python float, evaluated at trace time.

    Static lowerings (the fused sweep's :class:`SweepPlan`) bake thresholds in
    as ints; this is the same grid -- float32 ``p * 256`` is exact in numpy
    and XLA alike, so half-even rounding agrees bit-for-bit.
    """
    return int(np.clip(np.round(np.float32(p) * 256.0), 0.0, 256.0))


def cdf_thresholds_int(probs) -> tuple:
    """Per-value probabilities ``(p_0, .., p_{k-1})`` -> ``(k-1,)`` cumulative
    8-bit DAC thresholds, evaluated at trace time (Python floats in, ints out).

    Threshold ``C_v`` encodes ``P(value >= v)``: one entropy byte samples the
    whole categorical draw as ``value = #{v : byte < C_v}``.  Tail sums are
    non-increasing, so the rounded thresholds are too (enforced defensively) --
    the nesting the bit-sliced comparator chain relies on.  For k=2 the single
    threshold is exactly :func:`threshold_int` of ``P(value=1)``, which keeps
    binary nodes bit-identical to the scalar-threshold lowering.
    """
    k = len(probs)
    if k < 2:
        raise ValueError(f"need >= 2 value probabilities, got {k}")
    out = []
    prev = 256
    for v in range(1, k):
        tail = float(np.sum(np.asarray(probs[v:], np.float64)))
        t = min(threshold_int(tail), prev)
        out.append(t)
        prev = t
    return tuple(out)


def n_rand_words(n_bits: int) -> int:
    """uint32 entropy words needed for ``n_bits`` stream bits (word-padded)."""
    return bitops.n_words(n_bits) * RAND_WORDS_PER_OUT_WORD


def seed_words(key: jax.Array) -> jnp.ndarray:
    """Two uint32 seed words from a JAX PRNG key (typed or legacy uint32 pair)."""
    if jnp.issubdtype(key.dtype, jax.dtypes.prng_key):
        key = jax.random.key_data(key)
    return key.astype(jnp.uint32).reshape(-1)[:2]


_seed_words = seed_words


def _lowbias32(x: jnp.ndarray) -> jnp.ndarray:
    """Full-avalanche 32-bit integer hash (lowbias32), ~6 VPU ops per word."""
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def counter_iota(shape: tuple, offset=0) -> jnp.ndarray:
    """Row-major flattened counters of ``shape`` built from broadcasted iotas.

    Equals ``offset + arange(prod(shape)).reshape(shape)`` (mod 2^32) without
    ever materialising the flat 1-D intermediate -- each dimension contributes
    ``iota * stride`` directly at the output shape, so large-batch independent
    entropy never allocates a giant arange.  ``offset`` may be a Python int or
    a traced uint32 scalar (kernel tiles pass their global tile origin).
    """
    shape = tuple(int(d) for d in shape)
    off = jnp.asarray(offset, jnp.uint32) if not isinstance(offset, int) else \
        jnp.uint32(offset & 0xFFFFFFFF)
    if not shape:
        return off
    strides = []
    stride = 1
    for dim in reversed(shape):
        strides.append(stride)
        stride *= dim
    ctr = None
    for axis, s in enumerate(reversed(strides)):
        term = jax.lax.broadcasted_iota(jnp.uint32, shape, axis) * jnp.uint32(s & 0xFFFFFFFF)
        ctr = term if ctr is None else ctr + term
    return ctr + off


def counter_hash_words(
    key: jax.Array, shape: tuple, n_words: int, *, offset=0
) -> jnp.ndarray:
    """``shape + (n_words,)`` uint32 entropy via double-hashed counters.

    The decision hot path is entropy-bound, and Threefry's 20+ rounds dominate
    it; two rounds of the lowbias32 avalanche hash over a keyed counter give
    statistically clean stream entropy (means, pairwise correlation, and
    autocorrelation all within binomial noise at 2^14 bits -- asserted in
    tests) at a fraction of the cost.  Deterministic per key, like
    ``jax.random.bits``.  Not cryptographic -- neither is the memristor.

    ``offset`` shifts the counter block, so disjoint slices of one logical
    counter space can be drawn piecewise instead of generating (and slicing)
    the whole tensor.
    """
    kd = _seed_words(key)
    ctr = counter_iota(tuple(shape) + (n_words,), offset)
    return _lowbias32(_lowbias32(ctr ^ kd[0]) ^ kd[1])


# --- fused counter -> bit-plane entropy (the net_sweep generator) -----------------
#
# The fused whole-network sweep consumes entropy as *bit-planes*: for one packed
# output word, plane ``k`` is a uint32 word whose bit ``j`` is bit ``k`` of the
# 8-bit comparator byte at stream position ``j``.  Keeping the planes packed lets
# the byte-vs-threshold comparison run bit-sliced (a borrow chain over 8 words)
# with no byte extraction and no per-leaf packing.  Generation is two full
# lowbias32 avalanche rounds per plane word -- the same strength as
# ``counter_hash_words`` -- but the first round is shared by the 8 planes of an
# output word and the second round is salted per plane, so a 32-bit-stream word
# costs 1 + planes hashes instead of 2 x 8.

# Dense, well-spread odd salts (xxhash/murmur/splitmix finalizer constants);
# XORed into the second keyed round to separate the 8 bit-planes of one word.
PLANE_SALTS = (
    0x85EBCA6B, 0xC2B2AE35, 0x27D4EB2F, 0x165667B1,
    0x9E3779B9, 0xFF51AFD7, 0xC4CEB9FE, 0x2545F497,
)


def plane_base(ctr, kd0) -> jnp.ndarray:
    """First avalanche round over keyed counters, shared by a word's 8 planes."""
    return _lowbias32(jnp.asarray(ctr, jnp.uint32) ^ kd0)


def plane_word(base, kd1, plane: int) -> jnp.ndarray:
    """Second keyed round: one uint32 word of fair bits for bit-plane ``plane``."""
    return _lowbias32(base ^ jnp.uint32(PLANE_SALTS[plane]) ^ kd1)


def random_words(
    key: jax.Array, shape: tuple, n_bits: int, impl: str = "fast"
) -> jnp.ndarray:
    """Draw ``shape + (n_rand,)`` uint32 entropy words for ``n_bits``-bit streams.

    ``impl='fast'`` (default) uses the counter-hash generator;
    ``impl='threefry'`` uses ``jax.random.bits``.
    """
    if impl == "threefry":
        return jax.random.bits(key, tuple(shape) + (n_rand_words(n_bits),), jnp.uint32)
    return counter_hash_words(key, tuple(shape), n_rand_words(n_bits))


def packed_from_bytes(
    rand: jnp.ndarray,
    thresh: jnp.ndarray,
    flip: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Byte-threshold compare + in-register pack: the SNE comparator, packed.

    rand:   (..., n_rand) uint32 entropy, n_rand % 8 == 0.
    thresh: broadcastable to ``rand.shape[:-1]`` uint32 thresholds in [0, 256].
    flip:   optional bool mask (same broadcast) -- streams whose comparator is
            complemented (byte -> 255 - byte), the NOT-gate of the correlated
            encoder's negative mode (Fig S5b).

    Returns (..., n_rand // 8) uint32 packed streams.  Stream bit ``4r + b``
    comes from byte ``b`` of entropy word ``r``; it lands in output word
    ``r // 8`` at bit ``4 * (r % 8) + b`` (same layout as the Pallas kernel).
    """
    n_rand = rand.shape[-1]
    assert n_rand % RAND_WORDS_PER_OUT_WORD == 0
    n_out = n_rand // RAND_WORDS_PER_OUT_WORD
    thresh = jnp.asarray(thresh, jnp.uint32)[..., None]
    acc = jnp.zeros(jnp.broadcast_shapes(rand.shape[:-1], thresh.shape[:-1]) + (n_out,), jnp.uint32)
    for byte in range(BITS_PER_RAND_WORD):
        lane = (rand >> jnp.uint32(8 * byte)) & jnp.uint32(0xFF)
        if flip is not None:
            lane = jnp.where(flip[..., None], jnp.uint32(0xFF) - lane, lane)
        bits = (lane < thresh).astype(jnp.uint32)
        grouped = bits.reshape(bits.shape[:-1] + (bits.shape[-1] // 8, 8))
        shifts = (jnp.arange(8, dtype=jnp.uint32) * 4 + byte).astype(jnp.uint32)
        acc = acc | jnp.sum(grouped << shifts, axis=-1, dtype=jnp.uint32)
    return acc


def _mask_tail(words: jnp.ndarray, n_bits: int) -> jnp.ndarray:
    """Zero the pad bits when n_bits is not word-aligned (popcount invariant)."""
    if n_bits % bitops.WORD:
        return words & bitops.pad_mask(n_bits)
    return words


def encode_packed(
    key: jax.Array, p: jnp.ndarray, n_bits: int, impl: str = "fast"
) -> jnp.ndarray:
    """Independent packed Bernoulli streams: ``p.shape + (n_words,)`` uint32."""
    p = jnp.asarray(p, jnp.float32)
    rand = random_words(key, p.shape, n_bits, impl=impl)
    return _mask_tail(packed_from_bytes(rand, threshold_from_p(p)), n_bits)


def encode_packed_correlated(
    key: jax.Array,
    p: jnp.ndarray,
    n_bits: int,
    negate: jnp.ndarray | None = None,
    impl: str = "fast",
) -> jnp.ndarray:
    """Packed streams over the trailing axis of ``p`` sharing one entropy source.

    All streams in the group compare the *same* random bytes against their own
    threshold (one SNE, many comparator references): maximal positive
    correlation.  ``negate`` marks streams read through the complemented
    comparator: maximal negative correlation with the non-negated ones.
    """
    p = jnp.asarray(p, jnp.float32)
    rand = random_words(key, p.shape[:-1] + (1,), n_bits, impl=impl)
    flip = None if negate is None else jnp.asarray(negate, bool)
    return _mask_tail(packed_from_bytes(rand, threshold_from_p(p), flip), n_bits)


def encode_packed_categorical(
    key: jax.Array,
    cdf: tuple,
    n_bits: int,
    batch: int | None = None,
    impl: str = "fast",
) -> jnp.ndarray:
    """Categorical root sampling: one entropy byte -> ``value_bits(k)`` planes.

    cdf: static ``(k-1,)`` non-increasing cumulative thresholds in [0, 256]
    (:func:`cdf_thresholds_int`).  Draws the SAME entropy a binary
    :func:`encode_packed` of matching shape would (one byte per stream bit --
    the categorical draw is free after the first comparison), compares it
    against every threshold, and packs the sampled value's bit-planes.

    Returns ``(value_bits(k), n_words)`` uint32, or with a leading batch axis
    inserted after the plane axis when ``batch`` is given:
    ``(value_bits(k), batch, n_words)``.
    """
    lead = () if batch is None else (batch,)
    rand = random_words(key, lead, n_bits, impl=impl)
    levels = [
        packed_from_bytes(rand, jnp.uint32(t)) for t in cdf
    ]
    planes = bitops.value_planes(levels)
    return jnp.stack([_mask_tail(p, n_bits) for p in planes])


def fair_bits(key: jax.Array, shape: tuple, n_bits: int, impl: str = "fast") -> jnp.ndarray:
    """p = 0.5 packed streams straight from the generator (1 entropy bit/stream bit).

    MUX-tree selects are always fair coins; drawing the packed words directly
    skips even the byte comparison.  Pad bits are zeroed as usual.
    ``impl='threefry'`` draws the words from ``jax.random.bits`` instead of the
    counter-hash generator, so threefry mode stays end-to-end reproducible
    against other JAX code (the flag used to be silently unavailable here,
    which broke reproducibility for any circuit with a MUX-tree select).
    """
    if impl == "threefry":
        words = jax.random.bits(key, tuple(shape) + (bitops.n_words(n_bits),), jnp.uint32)
    else:
        words = counter_hash_words(key, tuple(shape), bitops.n_words(n_bits))
    return _mask_tail(words, n_bits)
