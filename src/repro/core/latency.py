"""Decision latency / energy model (the paper's timeliness claim).

The paper: with 100-bit stochastic numbers and < 4 us total switching per bit, the
Bayesian inference and fusion operators decide in < 0.4 ms per frame (>= 2,500 fps),
outperforming human reaction and ADAS pipelines.  Comparator/gate delays are
neglected (memristor switching is the bottleneck -- paper Fig 3 discussion).

This module reproduces those numbers from the device constants and extends the
model to the TPU mapping (bit-plane packed streams): there the bottleneck becomes
VPU bitwise throughput, and latency per decision is sub-microsecond while the
memristor path is reported alongside for the faithful comparison.
"""

from __future__ import annotations

import dataclasses

from repro.core.device import DEFAULT_PARAMS, MemristorParams

# Reference points quoted by the paper.
HUMAN_REACTION_S = (0.7, 1.5)   # paper cites 0.7-1.5 (ref 28, driver brake times;
                                # the paper text says "ms", the cited literature
                                # measures seconds -- we keep the comparison either
                                # way since the operator is faster than both)
ADAS_FPS = (30.0, 45.0)         # advanced driver-assistance systems (ref 29)
CAMERA_FPS = (10.0, 30.0)       # sensor sampling (ref 32)
EDGE_NET_FPS = 300.0            # pre-trained edge detector (ref 33)


@dataclasses.dataclass(frozen=True)
class LatencyReport:
    n_bits: int
    frame_latency_s: float
    fps: float
    energy_per_decision_j: float
    n_sne: int

    def meets_paper_claim(self) -> bool:
        """Paper claim: < 0.4 ms per frame, i.e. >= 2,500 fps at 100 bits."""
        return self.frame_latency_s < 0.4e-3 and self.fps >= 2500.0


def memristor_latency(
    n_bits: int = 100,
    n_sne: int = 5,
    mean_p: float = 0.5,
    params: MemristorParams = DEFAULT_PARAMS,
) -> LatencyReport:
    """Latency/energy of one operator decision on the memristor substrate.

    The SNEs stream bits in parallel (one memristor each); the serial dimension is
    the bit index, so frame latency = n_bits * t_bit.  Energy counts one switching
    event per emitted 1-bit per SNE (expected fraction ``mean_p``).
    """
    latency = n_bits * params.t_bit
    energy = n_sne * n_bits * mean_p * params.e_switch
    return LatencyReport(
        n_bits=n_bits,
        frame_latency_s=latency,
        fps=1.0 / latency,
        energy_per_decision_j=energy,
        n_sne=n_sne,
    )


def tpu_throughput_model(
    n_bits: int = 100,
    n_gate_ops: int = 8,
    vpu_bitops_per_s: float = 197e12 / 2 / 16,  # conservative: treat VPU lane ops
    # as ~1/16 of bf16 MAC throughput in op/s terms; one uint32 op moves 32 bits
) -> float:
    """Decisions/second of the packed TPU mapping (order-of-magnitude model).

    Each decision needs ceil(n_bits/32) words x n_gate_ops bitwise ops; popcount
    adds ~5 ops/word.  Memory traffic is negligible (streams stay in VMEM).
    """
    words = -(-n_bits // 32)
    ops = words * (n_gate_ops + 5)
    return vpu_bitops_per_s / ops
