"""Packed stochastic-number bitstream operations.

The paper streams one bit per 4 us over a wire; on TPU we pack 32 stream bits into
each uint32 lane word so the VPU processes thousands of stream-bits per cycle
(DESIGN.md SS2, "bit-plane packing").  A stochastic number of length ``n_bits`` is
stored as a uint32 array whose trailing axis has ``n_words = ceil(n_bits / 32)``
entries, LSB-first within each word.  Pad bits (beyond ``n_bits``) are always zero,
which keeps ``popcount`` exact.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

WORD = 32
_FULL = jnp.uint32(0xFFFFFFFF)


def n_words(n_bits: int) -> int:
    """Number of uint32 words needed to hold ``n_bits`` stream bits."""
    return -(-n_bits // WORD)


def pad_mask(n_bits: int) -> jnp.ndarray:
    """(n_words,) uint32 mask with ones on valid bit positions, zeros on padding."""
    nw = n_words(n_bits)
    bit_index = jnp.arange(nw * WORD, dtype=jnp.uint32).reshape(nw, WORD)
    valid = bit_index < jnp.uint32(n_bits)
    return pack_bits(valid)[..., 0]


def pack_bits(bits: jnp.ndarray) -> jnp.ndarray:
    """Pack a (..., n) bool/int array into (..., ceil(n/32)) uint32, LSB-first."""
    n = bits.shape[-1]
    nw = n_words(n)
    pad = nw * WORD - n
    if pad:
        bits = jnp.concatenate(
            [bits, jnp.zeros(bits.shape[:-1] + (pad,), dtype=bits.dtype)], axis=-1
        )
    b = bits.reshape(bits.shape[:-1] + (nw, WORD)).astype(jnp.uint32)
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    return jnp.sum(b << shifts, axis=-1).astype(jnp.uint32)


def unpack_bits(words: jnp.ndarray, n_bits: int) -> jnp.ndarray:
    """Unpack (..., n_words) uint32 into (..., n_bits) uint8 in {0, 1}."""
    shifts = jnp.arange(WORD, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    flat = bits.reshape(words.shape[:-1] + (words.shape[-1] * WORD,))
    return flat[..., :n_bits].astype(jnp.uint8)


def popcount_words(words: jnp.ndarray) -> jnp.ndarray:
    """Popcount per uint32 word (returns uint32 of same shape).

    ``lax.population_count`` lowers to the native instruction; the Pallas
    kernels keep their in-register SWAR sequence, which is bit-identical.
    """
    return jax.lax.population_count(words.astype(jnp.uint32))


def popcount(words: jnp.ndarray) -> jnp.ndarray:
    """Total number of set bits along the trailing word axis -> (...,) int32."""
    return jnp.sum(popcount_words(words).astype(jnp.int32), axis=-1)


def decode(words: jnp.ndarray, n_bits: int) -> jnp.ndarray:
    """Decode a packed stochastic number to its probability estimate in [0, 1]."""
    return popcount(words).astype(jnp.float32) / jnp.float32(n_bits)


# --- bitwise gates (correlation semantics live in how streams were encoded) -------

def band(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return a & b


def bor(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return a | b


def bxor(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return a ^ b


def bnot(a: jnp.ndarray, n_bits: int) -> jnp.ndarray:
    """Bitwise NOT restricted to the valid bit positions (padding stays zero)."""
    return (a ^ _FULL) & pad_mask(n_bits)


def bmux(select: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Per-bit 2:1 MUX: out_t = select_t ? b_t : a_t.

    With select uncorrelated from the inputs this is the paper's weighted adder:
    ``P(out) = (1 - P(s)) P(a) + P(s) P(b)`` (Table S1, Fig S6a).
    """
    return (select & b) | (~select & a)


# --- categorical value bit-planes (DESIGN.md §10) ----------------------------------
#
# A cardinality-k stochastic variable is carried as ``value_bits(k)`` packed
# words: plane ``b`` holds bit ``b`` of the sampled value at every stream
# position.  Binary variables are the k=2 special case -- one plane, identical
# to the classic packed stream -- so every bitwise gate above applies
# unchanged to each plane.

def value_bits(k: int) -> int:
    """Packed bit-planes needed to carry a cardinality-``k`` value (>= 1)."""
    if k < 2:
        raise ValueError(f"cardinality must be >= 2, got {k}")
    return (k - 1).bit_length()


def nested_buckets(levels):
    """Nested threshold indicators -> exclusive per-value bucket words.

    ``levels[v-1]`` is the packed indicator of ``value >= v`` (v = 1..k-1);
    nesting (``levels[v] subset levels[v-1]``) is guaranteed by the
    non-increasing CDF thresholds.  Returns the k-1 exclusive indicators of
    ``value == v`` for v = 1..k-1 (``value == 0`` is the complement of
    ``levels[0]``).  For k=2 this is ``levels`` itself -- zero extra gates.
    """
    k = len(levels) + 1
    return [levels[v - 1] if v == k - 1 else levels[v - 1] & ~levels[v]
            for v in range(1, k)]


def planes_from_buckets(buckets):
    """Exclusive value buckets (v = 1..k-1) -> ``value_bits(k)`` bit-planes."""
    k = len(buckets) + 1
    planes = []
    for b in range(value_bits(k)):
        sel = [buckets[v - 1] for v in range(1, k) if (v >> b) & 1]
        acc = sel[0]
        for s in sel[1:]:
            acc = acc | s
        planes.append(acc)
    return planes


def value_planes(levels):
    """Nested ``value >= v`` indicators -> binary value bit-planes."""
    return planes_from_buckets(nested_buckets(levels))


def digit_indicator(planes, d: int) -> jnp.ndarray:
    """Packed indicator of ``value == d`` from its value bit-planes.

    For a binary variable (one plane) this is the plane itself (d=1) or its
    complement (d=0) -- the classic parent literal.  NOTE: the d=0 literal of
    a single-plane variable complements pad bits too; AND the result into a
    pad-masked acceptance stream before popcounting.
    """
    acc = None
    for b, pl in enumerate(planes):
        lit = pl if (d >> b) & 1 else ~pl
        acc = lit if acc is None else acc & lit
    return acc
