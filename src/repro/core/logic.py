"""Memristor-enabled probabilistic logics (Fig 2d/2e, Table S1).

Stochastic numbers fed through ordinary Boolean gates compute probability
arithmetic; *which* arithmetic depends on the correlation between the input
streams, which the SNEs engineer (shared vs parallel entropy).  This module gives

* the analytic (Table S1) expectations, used as oracles everywhere, and
* gate-level operators that encode inputs in the requested correlation mode and
  apply the packed bitwise gate.
"""

from __future__ import annotations

import enum
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import bitops, rng, sne


class Corr(enum.Enum):
    UNCORRELATED = "uncorrelated"
    POSITIVE = "positive"
    NEGATIVE = "negative"


# --- Table S1 analytic relations --------------------------------------------------

def expected_and(pa, pb, mode: Corr) -> jnp.ndarray:
    pa, pb = jnp.asarray(pa, jnp.float32), jnp.asarray(pb, jnp.float32)
    if mode is Corr.UNCORRELATED:
        return pa * pb
    if mode is Corr.POSITIVE:
        return jnp.minimum(pa, pb)
    return jnp.maximum(pa + pb - 1.0, 0.0)


def expected_or(pa, pb, mode: Corr) -> jnp.ndarray:
    pa, pb = jnp.asarray(pa, jnp.float32), jnp.asarray(pb, jnp.float32)
    if mode is Corr.UNCORRELATED:
        return pa + pb - pa * pb
    if mode is Corr.POSITIVE:
        return jnp.maximum(pa, pb)
    return jnp.minimum(1.0, pa + pb)


def expected_xor(pa, pb, mode: Corr) -> jnp.ndarray:
    pa, pb = jnp.asarray(pa, jnp.float32), jnp.asarray(pb, jnp.float32)
    if mode is Corr.UNCORRELATED:
        return pa + pb - 2.0 * pa * pb
    if mode is Corr.POSITIVE:
        return jnp.abs(pa - pb)
    s = pa + pb
    return jnp.where(s <= 1.0, s, 2.0 - s)


def expected_mux(ps, pa, pb) -> jnp.ndarray:
    """Weighted addition; valid only when the select is uncorrelated with inputs."""
    ps = jnp.asarray(ps, jnp.float32)
    return (1.0 - ps) * jnp.asarray(pa, jnp.float32) + ps * jnp.asarray(pb, jnp.float32)


# --- encoding helpers per correlation mode ----------------------------------------

def encode_pair(
    key: jax.Array, pa, pb, n_bits: int, mode: Corr
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Encode two streams with the requested mutual correlation."""
    pa = jnp.asarray(pa, jnp.float32)
    pb = jnp.asarray(pb, jnp.float32)
    if mode is Corr.UNCORRELATED:
        ka, kb = jax.random.split(key)
        return (
            sne.encode_uncorrelated(ka, pa, n_bits),
            sne.encode_uncorrelated(kb, pb, n_bits),
        )
    stacked = jnp.stack(jnp.broadcast_arrays(pa, pb), axis=-1)
    if mode is Corr.POSITIVE:
        words = sne.encode_correlated(key, stacked, n_bits)
    else:
        neg = jnp.zeros(stacked.shape, bool).at[..., 1].set(True)
        words = sne.encode_correlated(key, stacked, n_bits, negate=neg)
    return words[..., 0, :], words[..., 1, :]


# --- gate-level operators -----------------------------------------------------------

def prob_and(key, pa, pb, n_bits: int, mode: Corr = Corr.UNCORRELATED):
    """Probabilistic AND: returns (stream_c, estimate, (stream_a, stream_b))."""
    a, b = encode_pair(key, pa, pb, n_bits, mode)
    c = bitops.band(a, b)
    return c, bitops.decode(c, n_bits), (a, b)


def prob_or(key, pa, pb, n_bits: int, mode: Corr = Corr.UNCORRELATED):
    a, b = encode_pair(key, pa, pb, n_bits, mode)
    c = bitops.bor(a, b)
    return c, bitops.decode(c, n_bits), (a, b)


def prob_xor(key, pa, pb, n_bits: int, mode: Corr = Corr.UNCORRELATED):
    a, b = encode_pair(key, pa, pb, n_bits, mode)
    c = bitops.bxor(a, b)
    return c, bitops.decode(c, n_bits), (a, b)


def prob_mux(key, ps, pa, pb, n_bits: int, mode_inputs: Corr = Corr.UNCORRELATED):
    """Probabilistic MUX (weighted adder).

    The select stream is always drawn from an independent SNE: Fig S6 shows the
    operation is corrupted if the select correlates with the inputs.  The two data
    inputs may themselves be correlated or not (``mode_inputs``) -- the MUX output
    probability is unaffected either way.
    """
    ks, kab = jax.random.split(key)
    s = sne.encode_uncorrelated(ks, ps, n_bits)
    a, b = encode_pair(kab, pa, pb, n_bits, mode_inputs)
    c = bitops.bmux(s, a, b)
    return c, bitops.decode(c, n_bits), (s, a, b)


def mux_select(selects: jnp.ndarray, leaves: jnp.ndarray) -> jnp.ndarray:
    """Value-select MUX tree: per bit position t, route ``leaves[idx(t)]_t`` out,
    where ``idx(t)`` is the binary number whose bits are the select streams' bits
    at t (``selects[0]`` is the most significant -- the Fig S8 CPT ordering
    "00, 01, 10, 11" with the first parent as the high bit).

    selects: (m, ..., n_words) packed select streams.
    leaves:  (..., L, n_words) packed data streams, L = 2**m.

    This is the n-ary generalisation of the Fig S8 motifs' MUX wiring: a node
    whose CPT row is picked by its parents' current sample.  The leaves stay
    maximally shared -- every level of the tree reuses the same packed words, so
    the numerator-subset-of-denominator discipline downstream is preserved
    (an AND of any select with the winning branch is a subset of the output).
    """
    m = selects.shape[0]
    assert leaves.shape[-2] == 1 << m, (leaves.shape, m)
    level = leaves
    for j in range(m - 1, -1, -1):
        s = selects[j][..., None, :]
        level = bitops.bmux(s, level[..., 0::2, :], level[..., 1::2, :])
    return level[..., 0, :]


def mux_tree(key, streams: jnp.ndarray, n_bits: int, impl: str = "fast") -> jnp.ndarray:
    """Balanced MUX tree over ``streams`` (..., K, n_words) with fresh uniform selects.

    Output probability = mean of the K input probabilities (i.e. (1/K) * sum) for
    K a power of two; non-powers of two are padded with zero streams, giving
    (1/K_pad) * sum -- callers must account for the scale (they do, in fusion).
    Returns (stream, K_pad).
    """
    k = streams.shape[-2]
    k_pad = 1 << (k - 1).bit_length()
    if k_pad != k:
        pad = jnp.zeros(streams.shape[:-2] + (k_pad - k, streams.shape[-1]), streams.dtype)
        streams = jnp.concatenate([streams, pad], axis=-2)
    level = streams
    while level.shape[-2] > 1:
        key, sub = jax.random.split(key)
        half = level.shape[-2] // 2
        # Fair-coin selects come straight from the packed generator (rng.fair_bits):
        # 1 entropy bit per stream bit, no comparator pass at all.
        sel = rng.fair_bits(sub, level.shape[:-2] + (half,), n_bits, impl=impl)
        level = bitops.bmux(sel, level[..., 0::2, :], level[..., 1::2, :])
    return level[..., 0, :], k_pad
