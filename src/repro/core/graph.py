"""Bayesian-network dependency structures from Fig S8.

* one-parent-one-child  (A -> B)          : 2x1 MUX        -- `repro.core.inference`
* two-parent-one-child  (A1 -> B <- A2)   : 4x1 MUX
* one-parent-two-child  (B1 <- A -> B2)   : two 2x1 MUXes

All operators keep the numerator a bitwise subset of the denominator by sharing
the parent/likelihood SNE streams, as in the paper.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import bitops, cordiv, logic, sne


def analytic_two_parent(p_a1, p_a2, cpt) -> jnp.ndarray:
    """P(A1=1 | B=1) with cpt[i, j] = P(B=1 | A1=i, A2=j)."""
    p_a1 = jnp.asarray(p_a1, jnp.float32)
    p_a2 = jnp.asarray(p_a2, jnp.float32)
    cpt = jnp.asarray(cpt, jnp.float32)
    w = jnp.stack(
        [
            (1 - p_a1) * (1 - p_a2) * cpt[0, 0],
            (1 - p_a1) * p_a2 * cpt[0, 1],
            p_a1 * (1 - p_a2) * cpt[1, 0],
            p_a1 * p_a2 * cpt[1, 1],
        ]
    )
    p_b = jnp.sum(w, axis=0)
    num = w[2] + w[3]
    return jnp.where(p_b > 0, num / jnp.maximum(p_b, 1e-9), 0.0)


def two_parent_one_child(
    key: jax.Array, p_a1, p_a2, cpt, n_bits: int = 100
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Posterior P(A1|B=1) via a 4x1 MUX (Fig S8b).

    Returns (posterior_scan, posterior_ratio, analytic).
    """
    cpt = jnp.asarray(cpt, jnp.float32)
    k1, k2, kc = jax.random.split(key, 3)
    s_a1 = sne.encode_uncorrelated(k1, jnp.asarray(p_a1, jnp.float32), n_bits)
    s_a2 = sne.encode_uncorrelated(k2, jnp.asarray(p_a2, jnp.float32), n_bits)
    kcs = jax.random.split(kc, 4)
    s_cpt = [
        sne.encode_uncorrelated(kcs[2 * i + j], cpt[i, j], n_bits)
        for i in range(2)
        for j in range(2)
    ]  # order: 00, 01, 10, 11
    # 4x1 MUX: selects are (A1, A2), A1 the high bit -- the shared n-ary tree.
    leaves = jnp.stack(s_cpt, axis=-2)
    denom = logic.mux_select(jnp.stack([s_a1, s_a2]), leaves)          # = P(B)
    hi = logic.mux_select(s_a2[None], leaves[..., 2:, :])              # A1 = 1 branch
    numer = bitops.band(s_a1, hi)                                      # = P(A1=1, B)
    _, post_scan = cordiv.cordiv_fill(numer, denom, n_bits)
    post_ratio = cordiv.cordiv_ratio(numer, denom)
    return post_scan, post_ratio, analytic_two_parent(p_a1, p_a2, cpt)


def analytic_one_parent_two_child(p_a, p_b1, p_b2) -> jnp.ndarray:
    """P(A=1 | B1=1, B2=1); p_bi = (P(Bi|A), P(Bi|notA))."""
    p_a = jnp.asarray(p_a, jnp.float32)
    num = p_a * p_b1[0] * p_b2[0]
    den = num + (1 - p_a) * p_b1[1] * p_b2[1]
    return jnp.where(den > 0, num / jnp.maximum(den, 1e-9), 0.0)


def one_parent_two_child(
    key: jax.Array, p_a, p_b1, p_b2, n_bits: int = 100
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Posterior P(A | B1, B2) via two 2x1 MUXes (Fig S8c).

    ``p_b1``/``p_b2`` are pairs (P(Bi|A), P(Bi|notA)).
    Returns (posterior_scan, posterior_ratio, analytic).
    """
    ka, k1a, k1n, k2a, k2n = jax.random.split(key, 5)
    s_a = sne.encode_uncorrelated(ka, jnp.asarray(p_a, jnp.float32), n_bits)
    s_b1a = sne.encode_uncorrelated(k1a, jnp.asarray(p_b1[0], jnp.float32), n_bits)
    s_b1n = sne.encode_uncorrelated(k1n, jnp.asarray(p_b1[1], jnp.float32), n_bits)
    s_b2a = sne.encode_uncorrelated(k2a, jnp.asarray(p_b2[0], jnp.float32), n_bits)
    s_b2n = sne.encode_uncorrelated(k2n, jnp.asarray(p_b2[1], jnp.float32), n_bits)
    numer = s_a & s_b1a & s_b2a
    denom = bitops.band(
        bitops.bmux(s_a, s_b1n, s_b1a), bitops.bmux(s_a, s_b2n, s_b2a)
    )
    _, post_scan = cordiv.cordiv_fill(numer, denom, n_bits)
    post_ratio = cordiv.cordiv_ratio(numer, denom)
    return post_scan, post_ratio, analytic_one_parent_two_child(p_a, p_b1, p_b2)
