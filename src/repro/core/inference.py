"""Hardware Bayesian inference operator (paper Fig 3 / S7, eq (1)).

Circuit (sharing SNEs exactly as the paper does to stay lightweight):

* stream A      ~ P(A)        (prior)
* stream B|A    ~ P(B|A)      (likelihood)
* stream B|notA ~ P(B|notA)
* numerator   n = A AND B|A                       -- probabilistic AND (multiplier)
* denominator d = MUX(select=A, in0=B|notA, in1=B|A)  -- weighted adder = P(B)
* posterior     = CORDIV(n, d)                    -- n is bitwise subset-of d by
                                                     construction (shared A, B|A)

The select of the MUX is the *prior* stream itself; it is uncorrelated with both
data inputs (they come from parallel SNEs), satisfying Fig S6, while making the
numerator a subset of the denominator, satisfying CORDIV.  That double role is the
paper's "maximise the sharing of the SNEs" trick.
"""

from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import bitops, cordiv, sne


def analytic_posterior(p_a, p_b_given_a, p_b_given_nota) -> jnp.ndarray:
    """Eq (1): P(A|B) = P(A)P(B|A) / (P(A)P(B|A) + P(notA)P(B|notA))."""
    p_a = jnp.asarray(p_a, jnp.float32)
    num = p_a * jnp.asarray(p_b_given_a, jnp.float32)
    den = num + (1.0 - p_a) * jnp.asarray(p_b_given_nota, jnp.float32)
    return jnp.where(den > 0, num / jnp.maximum(den, 1e-9), 0.0)


@dataclasses.dataclass
class InferenceTrace:
    """Streams at the key circuit nodes (for Fig 3b/3c/3d style reporting)."""

    streams: Dict[str, jnp.ndarray]
    n_bits: int
    posterior_scan: jnp.ndarray
    posterior_ratio: jnp.ndarray
    posterior_analytic: jnp.ndarray


def bayes_inference(
    key: jax.Array,
    p_a,
    p_b_given_a,
    p_b_given_nota,
    n_bits: int = 100,
) -> InferenceTrace:
    """Run the hardware Bayesian inference operator.  Inputs broadcast."""
    ka, kba, kbn = jax.random.split(key, 3)
    p_a = jnp.asarray(p_a, jnp.float32)
    s_a = sne.encode_uncorrelated(ka, p_a, n_bits)
    s_ba = sne.encode_uncorrelated(kba, jnp.asarray(p_b_given_a, jnp.float32), n_bits)
    s_bn = sne.encode_uncorrelated(kbn, jnp.asarray(p_b_given_nota, jnp.float32), n_bits)

    numer = bitops.band(s_a, s_ba)
    denom = bitops.bmux(s_a, s_bn, s_ba)   # select=A: P = (1-pA)*P(B|!A) + pA*P(B|A)

    # word-parallel CORDIV: bit-identical to the serial circuit, 32x fewer steps
    _, post_scan = cordiv.cordiv_fill(numer, denom, n_bits)
    post_ratio = cordiv.cordiv_ratio(numer, denom)
    return InferenceTrace(
        streams={
            "A": s_a,
            "B|A": s_ba,
            "B|!A": s_bn,
            "numer": numer,
            "denom": denom,
        },
        n_bits=n_bits,
        posterior_scan=post_scan,
        posterior_ratio=post_ratio,
        posterior_analytic=analytic_posterior(p_a, p_b_given_a, p_b_given_nota),
    )


def bayes_inference_marginal(
    key: jax.Array, p_a, p_b_given_a, p_b, n_bits: int = 100
) -> InferenceTrace:
    """Variant where the marginal P(B) is known directly (route-planning Fig 3b).

    posterior = P(A) P(B|A) / P(B); the denominator stream is built with superset
    completion so CORDIV's correlation requirement holds.
    """
    ka, kba, kd = jax.random.split(key, 3)
    p_a = jnp.asarray(p_a, jnp.float32)
    p_ba = jnp.asarray(p_b_given_a, jnp.float32)
    p_b = jnp.asarray(p_b, jnp.float32)
    s_a = sne.encode_uncorrelated(ka, p_a, n_bits)
    s_ba = sne.encode_uncorrelated(kba, p_ba, n_bits)
    numer = bitops.band(s_a, s_ba)
    denom = cordiv.make_superset(kd, numer, p_a * p_ba, p_b, n_bits)
    _, post_scan = cordiv.cordiv_fill(numer, denom, n_bits)
    post_ratio = cordiv.cordiv_ratio(numer, denom)
    analytic = jnp.where(p_b > 0, p_a * p_ba / jnp.maximum(p_b, 1e-9), 0.0)
    return InferenceTrace(
        streams={"A": s_a, "B|A": s_ba, "numer": numer, "denom": denom},
        n_bits=n_bits,
        posterior_scan=post_scan,
        posterior_ratio=post_ratio,
        posterior_analytic=jnp.clip(analytic, 0.0, 1.0),
    )
