"""Volatile memristor device model calibrated to the paper's measurements.

Fig 1 / S2 / S4 of the paper characterise solution-processed hBN filamentary
memristors:

* cycle-to-cycle threshold voltage  V_th  ~ N(2.08 V, 0.28 V)
* cycle-to-cycle hold voltage       V_hold~ N(0.98 V, 0.30 V)
* per-cycle V_th trajectory follows an Ornstein-Uhlenbeck (mean-reverting) process
* device-to-device coefficient of variation in V_th ~ 8 %
* switching time ~50 ns, relaxation ~1,100 ns (< 4 us per encoded bit),
  switching energy ~0.16 nJ, endurance > 1e6 cycles.

This module is the *simulator* side of the reproduction: it generates switching
trajectories statistically indistinguishable (by the paper's own OU fit) from the
measured devices, and it carries the timing/energy constants used by
:mod:`repro.core.latency`.  The production encoders in :mod:`repro.core.sne` may use
either this device model or a raw counter-based PRNG (DESIGN.md SS2).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class MemristorParams:
    """Calibrated constants from the paper (all SI units)."""

    vth_mu: float = 2.08          # V   (Fig 1c)
    vth_sigma: float = 0.28       # V
    vhold_mu: float = 0.98        # V
    vhold_sigma: float = 0.30     # V
    d2d_cv: float = 0.08          # device-to-device CV of V_th
    # OU process dV_t = theta * (mu - V_t) dt + sigma_w dW_t (dt = 1 cycle).
    ou_theta: float = 0.35        # mean-reversion magnitude (Fig S4 fit regime)
    t_switch: float = 50e-9       # s  (Fig S2)
    t_relax: float = 1.1e-6       # s
    t_bit: float = 4e-6           # s  -- paper: "<4 us in total per bit"
    e_switch: float = 0.16e-9     # J  (Fig S2)
    endurance_cycles: float = 1e6
    switching_ratio: float = 1e5  # HRS/LRS resistance ratio (Fig 1b)
    # Empirical SNE transfer curves (Fig 2b / 2c sigmoid fits).
    k_unc: float = 3.56
    v0_unc: float = 2.24
    k_corr: float = 11.5
    v0_corr: float = 0.57

    @property
    def ou_sigma_w(self) -> float:
        """Wiener increment scale chosen so the OU stationary std equals vth_sigma.

        For the AR(1) discretisation x' = x + theta (mu - x) + s_w eps, stationary
        variance is s_w^2 / (1 - (1 - theta)^2).
        """
        return self.vth_sigma * float(np.sqrt(1.0 - (1.0 - self.ou_theta) ** 2))

    @property
    def reads_per_bit(self) -> float:
        """Switching cycles one encoded stream bit integrates (t_bit / t_switch)."""
        return self.t_bit / self.t_switch

    @property
    def read_cv(self) -> float:
        """Effective cycle-to-cycle CV of one comparator read.

        The V_th trajectory has stationary CV ``vth_sigma / vth_mu`` per
        switching cycle, but one encoded bit integrates ``reads_per_bit``
        cycles (paper: < 4 us per bit at ~50 ns switching), so the threshold
        jitter an individual read sees is attenuated by ``sqrt(reads_per_bit)``.
        This is the calibrated cycle-to-cycle term of the crossbar
        :class:`~repro.bayesnet.noise.NoiseModel`.
        """
        return (self.vth_sigma / self.vth_mu) / float(np.sqrt(self.reads_per_bit))

    @property
    def wear_tau_epochs(self) -> float:
        """Read epochs until endurance wear doubles the read-noise *variance*.

        Two measured ingredients, no free constants:

        * The OU fit (:func:`fit_ou` / ``ou_theta``): inter-epoch V_th
          correlation decays as ``(1 - theta)^n`` over the ``reads_per_bit``
          switching cycles one read epoch spans -- ``(1 - 0.35)^80 ~ 1e-15``
          -- so successive :meth:`~repro.bayesnet.noise.NoiseModel.with_cycle`
          epochs are *independent* re-draws, which is exactly how the noise
          model re-keys them.
        * The endurance trace (Fig 1e, :func:`endurance_trace`): degradation
          accumulates as a variance random walk that reaches the fresh-device
          read variance after ``endurance_cycles`` switching events; in read
          epochs that is ``endurance_cycles / reads_per_bit``.
        """
        return self.endurance_cycles / self.reads_per_bit


def wear_scale(cycle: float, tau: float) -> float:
    """Endurance-wear multiplier on the per-read threshold CV at ``cycle``.

    Fresh-device read variance plus a linearly accumulating wear term:
    ``sqrt(1 + cycle / tau)``, with ``tau`` in read epochs
    (:attr:`MemristorParams.wear_tau_epochs`).  Exactly ``1.0`` at
    ``cycle <= 0`` so a fresh array reproduces the calibrated ``read_cv``
    bit-for-bit.
    """
    c = float(cycle)
    if c <= 0.0:
        return 1.0
    return float(np.sqrt(1.0 + c / float(tau)))


DEFAULT_PARAMS = MemristorParams()


def sample_ou_path(
    key: jax.Array,
    n: int,
    params: MemristorParams = DEFAULT_PARAMS,
    mu: float | jax.Array | None = None,
    x0: float | jax.Array | None = None,
) -> jnp.ndarray:
    """Sample an OU trajectory of per-cycle V_th values, shape (n,).

    ``mu`` may be a scalar or batched array of per-device means (device-to-device
    spread); output broadcasts accordingly to shape ``(n,) + shape(mu)``.
    """
    mu_ = jnp.asarray(params.vth_mu if mu is None else mu, dtype=jnp.float32)
    x0_ = mu_ if x0 is None else jnp.asarray(x0, dtype=jnp.float32)
    theta = jnp.float32(params.ou_theta)
    s_w = jnp.float32(params.ou_sigma_w)
    eps = jax.random.normal(key, (n,) + mu_.shape, dtype=jnp.float32)

    def step(x, e):
        x_next = x + theta * (mu_ - x) + s_w * e
        return x_next, x_next

    _, path = jax.lax.scan(step, x0_, eps)
    return path


def sample_devices(
    key: jax.Array, n_devices: int, params: MemristorParams = DEFAULT_PARAMS
) -> jnp.ndarray:
    """Per-device mean V_th values (device-to-device variation, Fig 1d)."""
    d2d_sigma = params.vth_mu * params.d2d_cv
    return params.vth_mu + d2d_sigma * jax.random.normal(
        key, (n_devices,), dtype=jnp.float32
    )


def fit_ou(path: np.ndarray) -> Tuple[float, float, float]:
    """Least-squares AR(1) fit of an OU process: returns (theta, mu, sigma_w).

    Mirrors the paper's Fig S4 stability analysis: x_{t+1} - x_t regressed on x_t.
    """
    x = np.asarray(path, dtype=np.float64)
    xt, xn = x[:-1], x[1:]
    # xn = a + b * xt + resid ; theta = 1 - b, mu = a / theta.
    b, a = np.polyfit(xt, xn, 1)
    theta = 1.0 - b
    mu = a / theta if abs(theta) > 1e-9 else float(np.mean(x))
    resid = xn - (a + b * xt)
    sigma_w = float(np.std(resid))
    return float(theta), float(mu), sigma_w


def switching_event(
    key: jax.Array,
    v_in: jax.Array,
    n_cycles: int,
    params: MemristorParams = DEFAULT_PARAMS,
    mu: float | jax.Array | None = None,
) -> jnp.ndarray:
    """Simulate ``n_cycles`` pulsed cycles: did the device switch on each pulse?

    A pulse of amplitude ``v_in`` switches the memristor iff ``v_in > V_th,t`` where
    ``V_th,t`` follows the OU trajectory.  The volatile self-reset (bias < V_hold
    between pulses) means no reset circuitry is modelled -- exactly the paper's
    "lightweight" argument.  Returns uint8 (n_cycles,) + broadcastshape.
    """
    vth = sample_ou_path(key, n_cycles, params, mu=mu)
    return (jnp.asarray(v_in, dtype=jnp.float32) > vth).astype(jnp.uint8)


def endurance_trace(
    key: jax.Array, cycles: int, params: MemristorParams = DEFAULT_PARAMS
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """HRS/LRS resistance readings over an endurance test (Fig 1e).

    Log-normal jitter around stable means; the test asserts both states stay
    separated by the paper's ~1e5 switching ratio throughout.
    """
    k1, k2 = jax.random.split(key)
    lrs = 1e4 * jnp.exp(0.05 * jax.random.normal(k1, (cycles,)))   # ~10 kOhm on-state
    hrs = lrs.mean() * params.switching_ratio * jnp.exp(
        0.08 * jax.random.normal(k2, (cycles,))
    )
    return hrs, lrs
