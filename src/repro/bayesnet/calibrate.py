"""Calibrate-back: fit CPTs from data, pre-distort thresholds, hot-recalibrate.

The closing arc of the crossbar-health loop (DESIGN §15).  The drift layers
below this module *observe* an aging array -- epoched lowering bakes
within-launch drift into the plan (:mod:`repro.bayesnet.compile`), the
:class:`~repro.bayesnet.reliability.DriftMonitor` detects it online -- and
this module *acts*:

**Compensation** (:func:`compensated_program`).  The deterministic part of
the noise model -- device-to-device lognormal spread, wear-scaled read noise,
IR droop (:meth:`~repro.bayesnet.noise.NoiseModel.error_factors`) -- is a
known multiplicative error on every programmed DAC threshold.  Dividing the
clean thresholds by the predicted factors *before* programming makes the
perturbation land back on the clean values: the programmed array then
samples (to within one DAC step of rounding) the distribution the spec
asked for.  Stuck devices are faults, not drift, and are deliberately not
compensated.  d2d and IR are cycle-independent, so compensation always
helps; the read-noise term grows with wear and only cancels at the cycle it
was fitted for -- which is exactly why recalibration must be *periodic*,
not one-shot.

**Hot recalibration** (:func:`recalibrated_network` /
:func:`recalibrate_driver`).  Re-lower the network at the current estimated
cycle with the compensated program and swap it into a live
:class:`~repro.bayesnet.driver.FrameDriver` between launches
(:meth:`~repro.bayesnet.driver.FrameDriver.swap_net`): in-flight launches
harvest against their original plan, queued frames ride the new one, zero
frames lost or reordered.  The driver's launch counter doubles as the cycle
estimate -- one launch, one read of every device.

**CPT fitting from rollouts** (:func:`fit_scene_config` /
:func:`calibration_report`).  The scenario CPTs are parameterised by a
:class:`~repro.data.detection.SceneConfig`; instead of trusting the hand-set
values, count confusion statistics over synthetic detection rollouts
(:func:`~repro.data.detection.make_scene`) and invert the generator's known
observation bias to recover the config -- per-modality visibilities from
ground-truth detection rates split by the night flag, detector
strong/weak confidences from mean probabilities on hit/missed target
pixels.  ``calibration_report`` quantifies the fit's bias/variance against
the hand-set reference and the resulting DAC-threshold deviation of every
scenario network's CPTs -- the end-to-end answer to "how wrong would the
fitted network be?".
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import numpy as np

from repro.bayesnet.compile import CompiledNetwork, compile_network
from repro.bayesnet.noise import NoiseModel
from repro.bayesnet.spec import NetworkSpec
from repro.core import rng
from repro.data.detection import SceneConfig, detection_metrics, make_scene

# make_scene blends 6% uniform noise toward 0.5 into every detector pixel:
# E[p | strength s] = s (1 - E[u]) + 0.5 E[u] with u ~ U(0, 0.06), so the
# observed mean is 0.97 s + 0.015 -- inverted exactly by the fitters below.
_NOISE_GAIN = 1.0 - 0.06 / 2.0
_NOISE_BIAS = 0.5 * 0.06 / 2.0

# SceneConfig fields the rollout fit estimates (the CPT parameterisation).
FITTED_FIELDS: Tuple[str, ...] = (
    "night_fraction", "rgb_vis_day", "rgb_vis_night",
    "thermal_vis", "strong", "weak",
)


def _debias(mean_p: float) -> float:
    """Invert the generator's noise blend: observed mean -> detector strength."""
    return float(np.clip((mean_p - _NOISE_BIAS) / _NOISE_GAIN, 0.02, 0.98))


# --------------------------------------------------------------- compensation
def compensated_program(
    spec: NetworkSpec,
    noise: NoiseModel,
    cycle: float | None = None,
    drift_epochs: int = 1,
) -> Dict[str, tuple]:
    """Pre-distorted DAC thresholds that cancel the predicted drift.

    For every node the clean CDF thresholds are divided by the noise model's
    deterministic multiplicative error at ``cycle`` (default the model's own
    cycle), rounded back to the 8-bit grid, and re-monotonised -- so after
    the hardware applies the same error, the effective thresholds land
    within one DAC step of clean.  Returns a ``name -> rows`` program dict
    for ``compile_network(program=...)`` /
    ``perturbed_cdf_rows(program=...)``.

    ``drift_epochs=E > 1`` fits the program the epoched plan will actually
    run (:mod:`repro.bayesnet.compile`): the stream spans snapshots at
    ``cycle .. cycle+E-1``, each with its own read-noise realization, but
    the hardware programs *one* conductance per threshold -- so the best
    one-shot program divides by the **geometric mean** of the per-epoch
    factors, splitting the log-mismatch evenly across epochs instead of
    zeroing the first and doubling the rest.
    """
    if noise is None:
        raise ValueError("compensated_program needs a NoiseModel")
    drift_epochs = int(drift_epochs)
    if drift_epochs < 1:
        raise ValueError(f"drift_epochs must be >= 1, got {drift_epochs}")
    nm = noise if cycle is None else noise.with_cycle(cycle)
    epoch_models = [
        nm.with_cycle(nm.cycle + e) for e in range(drift_epochs)
    ]
    order = spec.topo_order()
    program: Dict[str, tuple] = {}
    for pos, name in enumerate(order):
        clean = np.asarray(
            [rng.cdf_thresholds_int(row) for row in spec.cpt_rows(name)],
            np.float64,
        )
        if clean.size:
            log_f = np.mean(
                [
                    np.log(
                        m.error_factors(
                            name, clean.shape[0], clean.shape[1], pos,
                            len(order),
                        )
                    )
                    for m in epoch_models
                ],
                axis=0,
            )
            prog = np.clip(np.rint(clean / np.exp(log_f)), 0.0, 256.0)
            prog = np.minimum.accumulate(prog, axis=1)
        else:
            prog = clean
        program[name] = tuple(
            tuple(int(v) for v in row) for row in prog.astype(np.int64)
        )
    return program


def recalibrated_network(
    net: CompiledNetwork, cycle: float | None = None
) -> CompiledNetwork:
    """Re-lower ``net`` at ``cycle`` with a freshly compensated program.

    The returned network has the same spec / queries / evidence / stream
    length / lowering configuration as ``net`` -- it is a drop-in
    :meth:`~repro.bayesnet.driver.FrameDriver.swap_net` target -- but its
    noise model is advanced to ``cycle`` and its thresholds are programmed
    to cancel that cycle's predicted drift.
    """
    if net.noise is None:
        raise ValueError(
            "recalibrated_network needs a noisy network (net.noise is None): "
            "there is no drift to calibrate back"
        )
    nm = net.noise.with_cycle(net.noise.cycle if cycle is None else cycle)
    return compile_network(
        net.spec, net.n_bits, net.queries, net.evidence,
        share_entropy=net.share_entropy, estimator=net.estimator,
        fused=net.fused, noise=nm,
        drift_epochs=net.drift_epochs,
        program=compensated_program(
            net.spec, nm, drift_epochs=net.drift_epochs
        ),
        devices=max(net.n_shards, 1),
    )


def recalibrate_driver(driver, cycle: float | None = None) -> CompiledNetwork:
    """Recalibrate a live driver in place; returns the swapped-in network.

    ``cycle=None`` uses ``driver.launches`` as the cycle estimate (one
    launch = one read of every device in the array).  The swap happens
    between launches: zero frames lost, zero reordered (see
    :meth:`~repro.bayesnet.driver.FrameDriver.swap_net`).
    """
    c = float(driver.launches if cycle is None else cycle)
    net = recalibrated_network(driver.net, c)
    driver.swap_net(net)
    return net


# ------------------------------------------------------------ rollout fitting
def fit_scene_config(
    key: jax.Array,
    cfg: SceneConfig | None = None,
    n_scenes: int = 48,
    thresh: float = 0.6,
) -> SceneConfig:
    """Fit the CPT parameterisation from counted rollout confusion statistics.

    Generates ``n_scenes`` synthetic detection scenes from ``cfg`` (the
    data-generating truth; default hand-set) and estimates every
    :data:`FITTED_FIELDS` entry from observable statistics only:

    * ``night_fraction`` -- fraction of scenes flagged night;
    * ``rgb_vis_day`` / ``rgb_vis_night`` -- RGB ground-truth-pixel
      detection rate on day / night scenes (a clear target reads ~``strong``
      > ``thresh``, a missed one ~``weak`` < ``thresh``, so the hit rate
      *is* the visibility);
    * ``thermal_vis`` -- thermal detection rate over all scenes;
    * ``strong`` / ``weak`` -- mean detector probability on hit / missed
      target pixels, debiased through the generator's known 6% noise blend.

    Returns a :class:`~repro.data.detection.SceneConfig` with the fitted
    fields replaced (geometry fields pass through).  Accuracy vs ``cfg`` is
    quantified by :func:`calibration_report`.
    """
    cfg = cfg if cfg is not None else SceneConfig()
    if n_scenes < 2:
        raise ValueError(f"n_scenes must be >= 2, got {n_scenes}")
    day_tp, night_tp, th_tp = [], [], []
    hit_sum = hit_n = miss_sum = miss_n = 0.0
    n_night = 0
    for k in jax.random.split(key, n_scenes):
        gt, p_rgb, p_th, night = make_scene(k, cfg)
        gt = np.asarray(gt)
        p_rgb, p_th = np.asarray(p_rgb), np.asarray(p_th)
        night = bool(night)
        n_night += night
        tp_r, _, _ = detection_metrics(gt, p_rgb, thresh)
        tp_t, _, _ = detection_metrics(gt, p_th, thresh)
        (night_tp if night else day_tp).append(float(tp_r))
        th_tp.append(float(tp_t))
        for p in (p_rgb, p_th):
            on = p[gt > 0]
            hits = on[on > thresh]
            misses = on[on <= thresh]
            hit_sum += float(hits.sum()); hit_n += hits.size
            miss_sum += float(misses.sum()); miss_n += misses.size
    return dataclasses.replace(
        cfg,
        night_fraction=n_night / n_scenes,
        rgb_vis_day=(
            float(np.mean(day_tp)) if day_tp else cfg.rgb_vis_day
        ),
        rgb_vis_night=(
            float(np.mean(night_tp)) if night_tp else cfg.rgb_vis_night
        ),
        thermal_vis=float(np.mean(th_tp)) if th_tp else cfg.thermal_vis,
        strong=_debias(hit_sum / hit_n) if hit_n else cfg.strong,
        weak=_debias(miss_sum / miss_n) if miss_n else cfg.weak,
    )


def calibration_report(
    key: jax.Array,
    reference: SceneConfig | None = None,
    n_scenes: int = 48,
    repeats: int = 3,
    thresh: float = 0.6,
) -> dict:
    """Bias/variance of the rollout fit vs the hand-set CPT parameters.

    Runs ``repeats`` independent fits of ``n_scenes`` scenes each and
    reports, per fitted field, the reference value, fit mean, bias and
    spread -- plus, per scenario network, the maximum absolute 8-bit DAC
    threshold deviation between CPTs built from the mean fitted config and
    from the reference.  The scenario numbers are the end-to-end stake: a
    deviation of ``d`` DAC steps means the fitted network programs
    thresholds at most ``d/256`` of probability away from the hand-set one.
    """
    from repro.bayesnet.scenarios import SCENARIOS, by_name

    reference = reference if reference is not None else SceneConfig()
    if repeats < 1:
        raise ValueError(f"repeats must be >= 1, got {repeats}")
    fits = [
        fit_scene_config(k, reference, n_scenes, thresh)
        for k in jax.random.split(key, repeats)
    ]
    fields: Dict[str, dict] = {}
    mean_vals: Dict[str, float] = {}
    for f in FITTED_FIELDS:
        vals = np.asarray([getattr(c, f) for c in fits], np.float64)
        ref = float(getattr(reference, f))
        mean_vals[f] = float(vals.mean())
        fields[f] = {
            "reference": ref,
            "mean": float(vals.mean()),
            "bias": float(vals.mean() - ref),
            "std": float(vals.std()),
        }
    mean_cfg = dataclasses.replace(reference, **mean_vals)
    scen_dev: Dict[str, int] = {}
    for name in SCENARIOS:
        ref_spec = by_name(name, reference)
        fit_spec = by_name(name, mean_cfg)
        dev = 0
        for node in ref_spec.topo_order():
            ref_rows = [
                rng.cdf_thresholds_int(r) for r in ref_spec.cpt_rows(node)
            ]
            fit_rows = [
                rng.cdf_thresholds_int(r) for r in fit_spec.cpt_rows(node)
            ]
            for rr, fr in zip(ref_rows, fit_rows):
                for a, b in zip(rr, fr):
                    dev = max(dev, abs(int(a) - int(b)))
        scen_dev[name] = dev
    return {
        "n_scenes": int(n_scenes),
        "repeats": int(repeats),
        "fields": fields,
        "scenario_dac_deviation": scen_dev,
        "max_dac_deviation": max(scen_dev.values()) if scen_dev else 0,
    }
