"""Scenario library: driving decision networks over the paper's sensor models.

Each builder returns a :class:`~repro.bayesnet.spec.NetworkSpec` (5-12 nodes)
whose sensor CPTs are taken from the synthetic FLIR statistics in
``repro.data.detection.SceneConfig`` -- RGB visibility collapsing at night,
thermal missing cold targets, detector confidences ``strong``/``weak`` -- so
the compiled networks face exactly the failure modes the paper's fusion
operator is built to survive.  Evidence sets name the observable sensor nodes;
query sets name the latent state and the downstream decision.

The first four networks are all-binary (and stay bit-identical to the
pre-categorical compiler).  The categorical trio models the multi-class
structure the road scenes actually have -- obstacle *type* instead of
obstacle towers-of-booleans, a three-state traffic signal, class-confusion
detector reports -- exercising every k-ary path: k-ary roots, k-ary CPT
parents, k-ary evidence, and k-ary (vector-posterior) queries.

``SCENARIOS`` maps scenario id -> builder; ``by_name`` resolves one.
"""

from __future__ import annotations

from typing import Callable, Dict

from repro.bayesnet.spec import NetworkSpec, Node
from repro.data.detection import SceneConfig

_CFG = SceneConfig()


def sensor_degradation(cfg: SceneConfig = _CFG) -> NetworkSpec:
    """5 nodes: is a disagreeing sensor pair degraded, or is the world hot?"""
    return NetworkSpec(
        name="sensor-degradation",
        nodes=(
            Node("degraded", (), (0.08,)),
            Node("heat", (), (0.30,)),
            # CPT rows ordered (degraded, heat) = 00, 01, 10, 11
            Node("reading_a", ("degraded", "heat"), (0.03, cfg.strong, 0.40, cfg.weak)),
            Node("reading_b", ("degraded", "heat"), (0.05, cfg.strong, 0.45, cfg.weak)),
            Node("agree", ("reading_a", "reading_b"), (0.95, 0.10, 0.10, 0.95)),
        ),
        evidence=("reading_a", "reading_b"),
        queries=("degraded", "heat"),
    )


def pedestrian_night(cfg: SceneConfig = _CFG) -> NetworkSpec:
    """8 nodes: the Fig 4 night-pedestrian setting as a full network.

    RGB visibility drops from ``rgb_vis_day`` to ``rgb_vis_night`` after dark;
    thermal only sees warm targets; the brake decision fuses both detectors.
    """
    return NetworkSpec(
        name="pedestrian-night",
        nodes=(
            Node("night", (), (cfg.night_fraction,)),
            Node("pedestrian", (), (0.20,)),
            Node("warm", (), (0.70,)),
            # (pedestrian, night) = 00, 01, 10, 11
            Node("rgb_visible", ("pedestrian", "night"),
                 (0.02, 0.02, cfg.rgb_vis_day, cfg.rgb_vis_night)),
            # (pedestrian, warm) = 00, 01, 10, 11
            Node("th_visible", ("pedestrian", "warm"),
                 (0.03, 0.03, 0.30, cfg.strong)),
            Node("rgb_detect", ("rgb_visible",), (0.08, cfg.strong)),
            Node("th_detect", ("th_visible",), (0.08, cfg.strong)),
            # (rgb_detect, th_detect) = 00, 01, 10, 11
            Node("brake", ("rgb_detect", "th_detect"), (0.02, 0.70, 0.75, 0.98)),
        ),
        evidence=("night", "rgb_detect", "th_detect"),
        queries=("pedestrian", "brake"),
    )


def lane_change(cfg: SceneConfig = _CFG) -> NetworkSpec:
    """9 nodes: the paper's keep-lane / change-lane decision with radar+camera."""
    return NetworkSpec(
        name="lane-change",
        nodes=(
            Node("overtaker", (), (0.25,)),
            Node("night", (), (cfg.night_fraction,)),
            Node("sensor_fault", (), (0.05,)),
            Node("gap_ahead", (), (0.60,)),
            # (overtaker, sensor_fault) = 00, 01, 10, 11
            Node("radar_echo", ("overtaker", "sensor_fault"),
                 (0.06, 0.30, 0.92, cfg.weak)),
            # (overtaker, night) = 00, 01, 10, 11
            Node("camera_blob", ("overtaker", "night"),
                 (0.05, 0.08, 0.90, cfg.rgb_vis_night)),
            Node("blindspot_warn", ("radar_echo",), (0.04, 0.95)),
            # (overtaker, gap_ahead) = 00, 01, 10, 11
            Node("safe", ("overtaker", "gap_ahead"), (0.35, 0.95, 0.02, 0.15)),
            # (safe, blindspot_warn) = 00, 01, 10, 11
            Node("change_lane", ("safe", "blindspot_warn"), (0.10, 0.01, 0.90, 0.20)),
        ),
        evidence=("night", "camera_blob", "blindspot_warn", "gap_ahead"),
        queries=("overtaker", "safe", "change_lane"),
    )


def intersection(cfg: SceneConfig = _CFG) -> NetworkSpec:
    """12 nodes: right-of-way at an intersection, three-parent sensor CPTs."""
    return NetworkSpec(
        name="intersection",
        nodes=(
            Node("signal_green", (), (0.50,)),
            Node("occlusion", (), (0.30,)),
            Node("night", (), (cfg.night_fraction,)),
            Node("cross_traffic", ("signal_green",), (0.50, 0.10)),
            Node("ped_crossing", ("signal_green",), (0.15, 0.05)),
            # (cross_traffic, occlusion, night) = 000 .. 111
            Node("rgb_cross", ("cross_traffic", "occlusion", "night"),
                 (0.04, 0.04, 0.03, 0.03,
                  cfg.rgb_vis_day, cfg.rgb_vis_night, 0.40, 0.25)),
            # (cross_traffic, occlusion) = 00, 01, 10, 11
            Node("radar_cross", ("cross_traffic", "occlusion"),
                 (0.05, 0.08, 0.93, 0.60)),
            # (ped_crossing, night) = 00, 01, 10, 11
            Node("rgb_ped", ("ped_crossing", "night"),
                 (0.03, 0.03, cfg.rgb_vis_day, cfg.rgb_vis_night)),
            Node("th_ped", ("ped_crossing",), (0.06, 0.80)),
            Node("horn", ("cross_traffic",), (0.02, 0.25)),
            # (signal_green, cross_traffic, ped_crossing) = 000 .. 111
            Node("right_of_way", ("signal_green", "cross_traffic", "ped_crossing"),
                 (0.10, 0.03, 0.02, 0.01, 0.97, 0.30, 0.20, 0.05)),
            # (right_of_way, occlusion) = 00, 01, 10, 11
            Node("proceed", ("right_of_way", "occlusion"), (0.05, 0.02, 0.95, 0.60)),
        ),
        evidence=("night", "rgb_cross", "radar_cross", "rgb_ped", "th_ped", "horn"),
        queries=("cross_traffic", "ped_crossing", "proceed"),
    )


# --- categorical scenarios ---------------------------------------------------------

# Obstacle classes shared by the categorical nets (the paper's road agents).
OBSTACLE_CLASSES = ("none", "pedestrian", "vehicle", "cyclist")


def obstacle_class(cfg: SceneConfig = _CFG) -> NetworkSpec:
    """6 nodes, 4-class: *what* is ahead, not just whether something is.

    ``obstacle`` is a single cardinality-4 node; each detector reports a
    class-confusion distribution (k-ary CPT rows) instead of a bit.  RGB
    confuses cyclists with pedestrians and collapses at night; thermal sees
    warm signatures (pedestrian/cyclist small, vehicle engine large); radar
    returns echo strength by cross-section.  The net answers the full
    classification posterior plus the derived alert decision.
    """
    return NetworkSpec(
        name="obstacle-class",
        nodes=(
            # (none, pedestrian, vehicle, cyclist)
            Node.categorical("obstacle", (), ((0.55, 0.18, 0.17, 0.10),)),
            Node("night", (), (cfg.night_fraction,)),
            # rgb_class: reported class, rows = (obstacle, night) mixed-radix.
            # Day diagonals track cfg.rgb_vis_day (0.95 scaled by class
            # difficulty); night rows collapse toward "none" as visibility
            # drops to cfg.rgb_vis_night.
            Node.categorical("rgb_class", ("obstacle", "night"), (
                (0.92, 0.03, 0.03, 0.02),   # none, day
                (0.97, 0.01, 0.01, 0.01),   # none, night
                (0.06, 0.75, 0.04, 0.15),   # ped, day: cyclist confusion
                (0.52, 0.35, 0.03, 0.10),   # ped, night
                (0.04, 0.02, 0.90, 0.04),   # vehicle, day
                (0.35, 0.05, 0.50, 0.10),   # vehicle, night
                (0.08, 0.22, 0.10, 0.60),   # cyclist, day
                (0.60, 0.15, 0.05, 0.20),   # cyclist, night
            )),
            # th_signature: (cold, warm-small, warm-large) by obstacle class
            Node.categorical("th_signature", ("obstacle",), (
                (0.90, 0.07, 0.03),          # none
                (0.10, 0.75, 0.15),          # pedestrian: small warm blob
                (0.25, 0.15, 0.60),          # vehicle: large engine signature
                (0.15, 0.65, 0.20),          # cyclist
            )),
            # radar_echo: (none, weak, strong) by radar cross-section
            Node.categorical("radar_echo", ("obstacle",), (
                (0.88, 0.10, 0.02),          # none
                (0.55, 0.40, 0.05),          # pedestrian: tiny cross-section
                (0.04, 0.16, 0.80),          # vehicle
                (0.25, 0.55, 0.20),          # cyclist
            )),
            Node("alert", ("obstacle",), (
                (0.97, 0.03), (0.03, 0.97), (0.25, 0.75), (0.05, 0.95),
            ), k=2),
        ),
        evidence=("night", "rgb_class", "th_signature", "radar_echo"),
        queries=("obstacle", "alert"),
    )


def obstacle_detection(cfg: SceneConfig = _CFG) -> NetworkSpec:
    """7 nodes: the night-pedestrian net recast categorically.

    A 3-state ``light`` regime (day/dusk/night) replaces the binary night
    flag, and the 4-class ``obstacle`` replaces the pedestrian boolean tower;
    the binary detectors hang off k-ary parents (mixed-radix CPT rows), so
    this net exercises binary children of categorical causes.
    """
    nf = cfg.night_fraction
    return NetworkSpec(
        name="obstacle-detection",
        nodes=(
            # (day, dusk, night)
            Node.categorical("light", (), ((1.0 - 0.15 - nf, 0.15, nf),)),
            Node.categorical("obstacle", (), ((0.55, 0.18, 0.17, 0.10),)),
            # warm: thermal-visible signature by class
            Node("warm", ("obstacle",), (
                (0.75, 0.25), (0.05, 0.95), (0.45, 0.55), (0.10, 0.90),
            ), k=2),
            # rgb_detect rows = (obstacle, light): day / dusk / night per class
            Node("rgb_detect", ("obstacle", "light"), (
                (0.96, 0.04), (0.95, 0.05), (0.98, 0.02),     # none
                (1.0 - cfg.rgb_vis_day, cfg.rgb_vis_day),     # ped, day
                (0.45, 0.55),                                 # ped, dusk
                (1.0 - cfg.rgb_vis_night, cfg.rgb_vis_night), # ped, night
                (0.08, 0.92), (0.25, 0.75), (0.55, 0.45),     # vehicle
                (0.15, 0.85), (0.40, 0.60), (0.70, 0.30),     # cyclist
            ), k=2),
            Node("th_detect", ("obstacle", "warm"), (
                (0.95, 0.05), (0.80, 0.20),                   # none: cold/warm
                (0.90, 0.10), (1.0 - cfg.strong, cfg.strong), # pedestrian
                (0.85, 0.15), (0.20, 0.80),                   # vehicle
                (0.88, 0.12), (0.12, 0.88),                   # cyclist
            ), k=2),
            Node("radar_detect", ("obstacle",), (
                (0.94, 0.06), (0.65, 0.35), (0.07, 0.93), (0.40, 0.60),
            ), k=2),
            Node("brake", ("obstacle",), (
                (0.97, 0.03), (0.03, 0.97), (0.30, 0.70), (0.08, 0.92),
            ), k=2),
        ),
        evidence=("light", "rgb_detect", "th_detect", "radar_detect"),
        queries=("obstacle", "brake"),
    )


def intersection_cat(cfg: SceneConfig = _CFG) -> NetworkSpec:
    """10 nodes: right-of-way with a first-class 3-state traffic signal.

    The signal (red/yellow/green) is a categorical root observed through a
    class-confusion camera report (k-ary evidence of a k-ary node); the
    latent traffic/pedestrian states and the proceed decision stay binary,
    so the query set mixes a length-3 posterior with classic bits.
    """
    return NetworkSpec(
        name="intersection-cat",
        nodes=(
            # (red, yellow, green)
            Node.categorical("signal", (), ((0.45, 0.10, 0.45),)),
            Node("occlusion", (), (0.30,)),
            Node("night", (), (cfg.night_fraction,)),
            Node("cross_traffic", ("signal",), (
                (0.45, 0.55), (0.65, 0.35), (0.90, 0.10),
            ), k=2),
            Node("ped_crossing", ("signal",), (
                (0.82, 0.18), (0.90, 0.10), (0.95, 0.05),
            ), k=2),
            # rgb_signal rows = (signal, night): camera's reported light state
            Node.categorical("rgb_signal", ("signal", "night"), (
                (0.90, 0.06, 0.04), (0.80, 0.12, 0.08),   # red: day, night
                (0.10, 0.82, 0.08), (0.18, 0.68, 0.14),   # yellow
                (0.04, 0.06, 0.90), (0.10, 0.12, 0.78),   # green
            )),
            # (cross_traffic, occlusion) = 00, 01, 10, 11
            Node("radar_cross", ("cross_traffic", "occlusion"),
                 (0.05, 0.08, 0.93, 0.60)),
            Node("th_ped", ("ped_crossing",), (0.06, 0.80)),
            # (signal, cross_traffic, ped_crossing) mixed-radix, signal MSD
            Node("right_of_way", ("signal", "cross_traffic", "ped_crossing"), (
                (0.90, 0.10), (0.97, 0.03), (0.98, 0.02), (0.99, 0.01),  # red
                (0.60, 0.40), (0.90, 0.10), (0.93, 0.07), (0.97, 0.03),  # yellow
                (0.03, 0.97), (0.70, 0.30), (0.80, 0.20), (0.95, 0.05),  # green
            ), k=2),
            # (right_of_way, occlusion) = 00, 01, 10, 11
            Node("proceed", ("right_of_way", "occlusion"), (0.05, 0.02, 0.95, 0.60)),
        ),
        evidence=("night", "rgb_signal", "radar_cross", "th_ped"),
        queries=("signal", "cross_traffic", "proceed"),
    )


SCENARIOS: Dict[str, Callable[..., NetworkSpec]] = {
    "sensor-degradation": sensor_degradation,
    "pedestrian-night": pedestrian_night,
    "lane-change": lane_change,
    "intersection": intersection,
    "obstacle-class": obstacle_class,
    "obstacle-detection": obstacle_detection,
    "intersection-cat": intersection_cat,
}


def by_name(name: str, cfg: SceneConfig = _CFG) -> NetworkSpec:
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; known: {sorted(SCENARIOS)}")
    return SCENARIOS[name](cfg)
