"""Scenario library: driving decision networks over the paper's sensor models.

Each builder returns a :class:`~repro.bayesnet.spec.NetworkSpec` (5-12 binary
nodes) whose sensor CPTs are taken from the synthetic FLIR statistics in
``repro.data.detection.SceneConfig`` -- RGB visibility collapsing at night,
thermal missing cold targets, detector confidences ``strong``/``weak`` -- so
the compiled networks face exactly the failure modes the paper's fusion
operator is built to survive.  Evidence sets name the observable sensor nodes;
query sets name the latent state and the downstream decision.

``SCENARIOS`` maps scenario id -> builder; ``by_name`` resolves one.
"""

from __future__ import annotations

from typing import Callable, Dict

from repro.bayesnet.spec import NetworkSpec, Node
from repro.data.detection import SceneConfig

_CFG = SceneConfig()


def sensor_degradation(cfg: SceneConfig = _CFG) -> NetworkSpec:
    """5 nodes: is a disagreeing sensor pair degraded, or is the world hot?"""
    return NetworkSpec(
        name="sensor-degradation",
        nodes=(
            Node("degraded", (), (0.08,)),
            Node("heat", (), (0.30,)),
            # CPT rows ordered (degraded, heat) = 00, 01, 10, 11
            Node("reading_a", ("degraded", "heat"), (0.03, cfg.strong, 0.40, cfg.weak)),
            Node("reading_b", ("degraded", "heat"), (0.05, cfg.strong, 0.45, cfg.weak)),
            Node("agree", ("reading_a", "reading_b"), (0.95, 0.10, 0.10, 0.95)),
        ),
        evidence=("reading_a", "reading_b"),
        queries=("degraded", "heat"),
    )


def pedestrian_night(cfg: SceneConfig = _CFG) -> NetworkSpec:
    """8 nodes: the Fig 4 night-pedestrian setting as a full network.

    RGB visibility drops from ``rgb_vis_day`` to ``rgb_vis_night`` after dark;
    thermal only sees warm targets; the brake decision fuses both detectors.
    """
    return NetworkSpec(
        name="pedestrian-night",
        nodes=(
            Node("night", (), (cfg.night_fraction,)),
            Node("pedestrian", (), (0.20,)),
            Node("warm", (), (0.70,)),
            # (pedestrian, night) = 00, 01, 10, 11
            Node("rgb_visible", ("pedestrian", "night"),
                 (0.02, 0.02, cfg.rgb_vis_day, cfg.rgb_vis_night)),
            # (pedestrian, warm) = 00, 01, 10, 11
            Node("th_visible", ("pedestrian", "warm"),
                 (0.03, 0.03, 0.30, cfg.strong)),
            Node("rgb_detect", ("rgb_visible",), (0.08, cfg.strong)),
            Node("th_detect", ("th_visible",), (0.08, cfg.strong)),
            # (rgb_detect, th_detect) = 00, 01, 10, 11
            Node("brake", ("rgb_detect", "th_detect"), (0.02, 0.70, 0.75, 0.98)),
        ),
        evidence=("night", "rgb_detect", "th_detect"),
        queries=("pedestrian", "brake"),
    )


def lane_change(cfg: SceneConfig = _CFG) -> NetworkSpec:
    """9 nodes: the paper's keep-lane / change-lane decision with radar+camera."""
    return NetworkSpec(
        name="lane-change",
        nodes=(
            Node("overtaker", (), (0.25,)),
            Node("night", (), (cfg.night_fraction,)),
            Node("sensor_fault", (), (0.05,)),
            Node("gap_ahead", (), (0.60,)),
            # (overtaker, sensor_fault) = 00, 01, 10, 11
            Node("radar_echo", ("overtaker", "sensor_fault"),
                 (0.06, 0.30, 0.92, cfg.weak)),
            # (overtaker, night) = 00, 01, 10, 11
            Node("camera_blob", ("overtaker", "night"),
                 (0.05, 0.08, 0.90, cfg.rgb_vis_night)),
            Node("blindspot_warn", ("radar_echo",), (0.04, 0.95)),
            # (overtaker, gap_ahead) = 00, 01, 10, 11
            Node("safe", ("overtaker", "gap_ahead"), (0.35, 0.95, 0.02, 0.15)),
            # (safe, blindspot_warn) = 00, 01, 10, 11
            Node("change_lane", ("safe", "blindspot_warn"), (0.10, 0.01, 0.90, 0.20)),
        ),
        evidence=("night", "camera_blob", "blindspot_warn", "gap_ahead"),
        queries=("overtaker", "safe", "change_lane"),
    )


def intersection(cfg: SceneConfig = _CFG) -> NetworkSpec:
    """12 nodes: right-of-way at an intersection, three-parent sensor CPTs."""
    return NetworkSpec(
        name="intersection",
        nodes=(
            Node("signal_green", (), (0.50,)),
            Node("occlusion", (), (0.30,)),
            Node("night", (), (cfg.night_fraction,)),
            Node("cross_traffic", ("signal_green",), (0.50, 0.10)),
            Node("ped_crossing", ("signal_green",), (0.15, 0.05)),
            # (cross_traffic, occlusion, night) = 000 .. 111
            Node("rgb_cross", ("cross_traffic", "occlusion", "night"),
                 (0.04, 0.04, 0.03, 0.03,
                  cfg.rgb_vis_day, cfg.rgb_vis_night, 0.40, 0.25)),
            # (cross_traffic, occlusion) = 00, 01, 10, 11
            Node("radar_cross", ("cross_traffic", "occlusion"),
                 (0.05, 0.08, 0.93, 0.60)),
            # (ped_crossing, night) = 00, 01, 10, 11
            Node("rgb_ped", ("ped_crossing", "night"),
                 (0.03, 0.03, cfg.rgb_vis_day, cfg.rgb_vis_night)),
            Node("th_ped", ("ped_crossing",), (0.06, 0.80)),
            Node("horn", ("cross_traffic",), (0.02, 0.25)),
            # (signal_green, cross_traffic, ped_crossing) = 000 .. 111
            Node("right_of_way", ("signal_green", "cross_traffic", "ped_crossing"),
                 (0.10, 0.03, 0.02, 0.01, 0.97, 0.30, 0.20, 0.05)),
            # (right_of_way, occlusion) = 00, 01, 10, 11
            Node("proceed", ("right_of_way", "occlusion"), (0.05, 0.02, 0.95, 0.60)),
        ),
        evidence=("night", "rgb_cross", "radar_cross", "rgb_ped", "th_ped", "horn"),
        queries=("cross_traffic", "ped_crossing", "proceed"),
    )


SCENARIOS: Dict[str, Callable[..., NetworkSpec]] = {
    "sensor-degradation": sensor_degradation,
    "pedestrian-night": pedestrian_night,
    "lane-change": lane_change,
    "intersection": intersection,
}


def by_name(name: str, cfg: SceneConfig = _CFG) -> NetworkSpec:
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; known: {sorted(SCENARIOS)}")
    return SCENARIOS[name](cfg)
