"""Exact enumeration oracle for :class:`~repro.bayesnet.spec.NetworkSpec`.

Full-joint enumeration over the ``prod(k_i)`` mixed-radix assignments,
vectorised: the assignment grid, the per-node CPT gathers and the
evidence-consistency masks are all plain array ops, so one jit launch
evaluates *batches* of evidence frames against the whole joint at once.  For
the scenario networks this is exact, fast, and serves as the correctness
bound for the stochastic backend (compiled posteriors must match within
O(1/sqrt(n_accepted))).

``dac_quantize=True`` snaps every CPT row to the distribution the 8-bit DAC
CDF actually samples: the cumulative tail thresholds are rounded to the
``t/256`` grid (``rng.cdf_thresholds_int``) and differenced back into
per-value probabilities -- so oracle-vs-stochastic comparisons isolate the
stochastic noise from the (documented, bounded) quantisation bias.  For a
binary node this reduces to the classic ``round(p * 256) / 256``.

``noise=`` (a :class:`~repro.bayesnet.noise.NoiseModel`) makes this the
**perturbed-CPT oracle twin** of ``compile_network(noise=...)``: the same
deterministic threshold perturbation the compiler bakes into its plan is
applied here, and the enumeration runs over the *perturbed* integer
thresholds differenced back to probabilities.  The compiled program then
samples exactly the network this oracle enumerates, so 3-sigma agreement
tests keep an exact ground truth under any noise level.  The perturbation
acts on the integer DAC grid, so ``noise`` subsumes ``dac_quantize``: the
perturbed thresholds ARE the quantisation, and the flag is ignored when a
model is given.

Posterior layout mirrors the compiler: all-binary query sets keep the classic
``(B, n_q)`` array of ``P(q=1)``; any k-ary query switches to ``(B, n_q,
max_k)`` normalised per-value posteriors (zero-padded past each query's
cardinality, uniform over the query's values where the evidence is
impossible).
"""

from __future__ import annotations

import functools
import math
from typing import Callable, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.bayesnet.noise import NoiseModel, perturbed_cdf_rows
from repro.bayesnet.spec import NetworkSpec
from repro.core import rng
from repro.kernels.net_sweep.common import epoch_word_bounds

_MAX_STATES = 1 << 20


def _node_rows(
    spec: NetworkSpec,
    name: str,
    dac_quantize: bool,
    perturbed=None,
) -> np.ndarray:
    """(L, k) float32 canonical (optionally DAC-snapped / perturbed) CPT rows.

    ``perturbed`` (a name -> integer CDF rows dict from
    :func:`~repro.bayesnet.noise.perturbed_cdf_rows`) takes precedence: the
    perturbed thresholds are differenced back to per-value probabilities, the
    exact distribution the noisy compiled program samples.
    """
    rows = spec.cpt_rows(name)
    if perturbed is not None:
        snapped = []
        for prow in perturbed[name]:
            bounds = (256,) + tuple(prow) + (0,)
            snapped.append(
                tuple((bounds[v] - bounds[v + 1]) / 256.0 for v in range(len(prow) + 1))
            )
        rows = tuple(snapped)
    elif dac_quantize:
        snapped = []
        for row in rows:
            bounds = (256,) + rng.cdf_thresholds_int(row) + (0,)
            snapped.append(
                tuple((bounds[v] - bounds[v + 1]) / 256.0 for v in range(len(row)))
            )
        rows = tuple(snapped)
    return np.asarray(rows, np.float32)


def joint_table(
    spec: NetworkSpec,
    dac_quantize: bool = False,
    noise: NoiseModel | None = None,
    *,
    drift_epochs: int = 1,
    program: dict | None = None,
    n_bits: int | None = None,
):
    """Returns (states (S, N) int32, joint (S,) float32), S = prod(cards).

    Column ``j`` of ``states`` is the value of ``spec.nodes[j]`` (node 0 is
    the fastest-cycling mixed-radix digit, the k-ary generalisation of the
    old bit grid); ``joint`` is the exact probability of each assignment.
    ``noise`` enumerates the *perturbed* network (see module docstring).

    ``drift_epochs=E > 1`` is the oracle twin of the epoched sweep: the joint
    is the *mixture* ``sum_e w_e * joint_e`` of the per-epoch perturbed
    joints (epoch ``e`` at ``noise.with_cycle(noise.cycle + e)``), with
    ``w_e`` each epoch's exact share of the packed words when ``n_bits`` is
    given (:func:`~repro.kernels.net_sweep.common.epoch_word_bounds`) and
    uniform otherwise.  The sweep's count-ratio estimator sums counts across
    all epochs of the stream, so its large-``n_bits`` limit is exactly the
    posterior of this mixed joint.  ``program`` matches the compiler's
    programmed-threshold override.
    """
    drift_epochs = int(drift_epochs)
    if drift_epochs > 1 and noise is None:
        raise ValueError("drift_epochs > 1 needs a NoiseModel to advance")
    cards = spec.cards()
    total = math.prod(cards)
    if total > _MAX_STATES:
        raise ValueError(
            f"enumeration oracle capped at {_MAX_STATES} joint states, got {total}"
        )
    idx = {node.name: j for j, node in enumerate(spec.nodes)}
    s = np.arange(total, dtype=np.int64)
    cols = []
    for c in cards:
        cols.append((s % c).astype(np.int32))
        s //= c
    states = jnp.asarray(np.stack(cols, axis=-1))

    def one_epoch_joint(perturbed):
        joint = jnp.ones((total,), jnp.float32)
        for node in spec.nodes:
            cpt = jnp.asarray(_node_rows(spec, node.name, dac_quantize, perturbed))
            # Mixed-radix CPT row index: first parent is the most significant
            # digit (spec.py convention).
            row = jnp.zeros((total,), jnp.int32)
            for parent in node.parents:
                row = row * jnp.int32(spec.card(parent)) + states[:, idx[parent]]
            joint = joint * cpt[row, states[:, idx[node.name]]]
        return joint

    if noise is None and program is None:
        return states, one_epoch_joint(None)
    if drift_epochs == 1:
        return states, one_epoch_joint(
            perturbed_cdf_rows(spec, noise, program=program)
        )
    if n_bits is not None:
        bounds = epoch_word_bounds(n_bits // 32, drift_epochs)
        spans = np.diff(bounds).astype(np.float64)
        weights = spans / max(spans.sum(), 1.0)
    else:
        weights = np.full((drift_epochs,), 1.0 / drift_epochs)
    joint = jnp.zeros((total,), jnp.float32)
    for e, w_e in enumerate(weights):
        pe = perturbed_cdf_rows(
            spec, noise.with_cycle(noise.cycle + e), program=program
        )
        joint = joint + jnp.float32(w_e) * one_epoch_joint(pe)
    return states, joint


def make_posterior_fn(
    spec: NetworkSpec,
    queries: Sequence[str] | None = None,
    evidence: Sequence[str] | None = None,
    dac_quantize: bool = False,
    noise: NoiseModel | None = None,
    *,
    drift_epochs: int = 1,
    program: dict | None = None,
    n_bits: int | None = None,
) -> Callable[[jnp.ndarray], Tuple[jnp.ndarray, jnp.ndarray]]:
    """Compile the exact batched-posterior function for a spec.

    Returns ``fn(ev_frames (B, n_ev) int) -> (post, p_evidence (B,))`` with
    the posterior layout described in the module docstring, jitted and fully
    vectorised over frames.  Frames columns follow the ``evidence`` order and
    hold one value in ``[0, card)`` per node; ``p_evidence`` is the evidence
    marginal (0 where impossible; the posterior then falls back to 0.5 /
    uniform).  ``noise`` builds the perturbed-CPT oracle twin of
    ``compile_network(noise=...)`` -- exact ground truth for the noisy
    program (see module docstring).  ``drift_epochs`` / ``program`` /
    ``n_bits`` mirror the compiler's epoched calibrate-back lowering: the
    oracle becomes the exact word-weighted epoch mixture the swept stream's
    count-ratio estimator converges to (see :func:`joint_table`).
    """
    queries = tuple(queries if queries is not None else spec.queries)
    evidence = tuple(evidence if evidence is not None else spec.evidence)
    states, joint = joint_table(
        spec, dac_quantize=dac_quantize, noise=noise,
        drift_epochs=drift_epochs, program=program, n_bits=n_bits,
    )
    ev_cols = jnp.asarray([spec.index(e) for e in evidence], jnp.int32)
    q_cols = jnp.asarray([spec.index(q) for q in queries], jnp.int32)
    q_cards = tuple(spec.card(q) for q in queries)
    all_binary = all(c == 2 for c in q_cards)
    kmax = max(q_cards) if q_cards else 2

    @jax.jit
    def posterior(ev_frames: jnp.ndarray):
        ev = jnp.asarray(ev_frames, jnp.int32)
        assert ev.ndim == 2 and ev.shape[1] == len(evidence), ev.shape
        # (B, S): does assignment s agree with frame b's evidence?
        if len(evidence):
            match = jnp.all(states[None, :, ev_cols] == ev[:, None, :], axis=-1)
        else:
            match = jnp.ones((ev.shape[0], states.shape[0]), bool)
        w = match.astype(jnp.float32) * joint[None, :]            # (B, S)
        p_e = jnp.sum(w, axis=-1)                                 # (B,)
        if all_binary:
            q_on = states[:, q_cols].astype(jnp.float32)          # (S, n_q)
            num = w @ q_on                                        # (B, n_q)
            post = jnp.where(
                p_e[:, None] > 0, num / jnp.maximum(p_e[:, None], 1e-30), 0.5
            )
            return post, p_e
        posts = []
        for qi, c in enumerate(q_cards):
            onehot = (
                states[:, q_cols[qi], None] == jnp.arange(kmax, dtype=jnp.int32)
            ).astype(jnp.float32)                                 # (S, kmax)
            num = w @ onehot                                      # (B, kmax)
            fallback = jnp.asarray(
                [1.0 / c if v < c else 0.0 for v in range(kmax)], jnp.float32
            )
            posts.append(
                jnp.where(
                    p_e[:, None] > 0,
                    num / jnp.maximum(p_e[:, None], 1e-30),
                    fallback[None, :],
                )
            )
        return jnp.stack(posts, axis=1), p_e                      # (B, n_q, kmax)

    return posterior


@functools.partial(jax.jit, static_argnames=("spec", "batch"))
def _sample_joint(spec: NetworkSpec, key: jax.Array, batch: int) -> jnp.ndarray:
    """Ancestral sampling: (B, N) int32 values in declared node order."""
    idx = {node.name: j for j, node in enumerate(spec.nodes)}
    vals = [None] * spec.n_nodes
    for name in spec.topo_order():
        node = spec.node(name)
        key, sub = jax.random.split(key)
        # (L, k-1) cumulative tails: value = #{v : u < P(value >= v)} -- the
        # float twin of the DAC CDF sampler (binary: one column equal to p1).
        rows = np.asarray(spec.cpt_rows(name), np.float32)
        tails = jnp.asarray(
            np.cumsum(rows[:, ::-1], axis=-1)[:, ::-1][:, 1:], jnp.float32
        )
        row = jnp.zeros((batch,), jnp.int32)
        for parent in node.parents:
            row = row * jnp.int32(spec.card(parent)) + vals[idx[parent]]
        u = jax.random.uniform(sub, (batch,))
        vals[idx[name]] = jnp.sum(
            (u[:, None] < tails[row]).astype(jnp.int32), axis=-1
        )
    return jnp.stack(vals, axis=-1)


def sample_evidence(
    spec: NetworkSpec, key: jax.Array, batch: int,
    evidence: Sequence[str] | None = None,
) -> jnp.ndarray:
    """Draw (B, n_ev) realistic evidence frames by ancestral joint sampling.

    Frames are distributed as the network itself predicts its sensors to fire,
    so batched benchmarks exercise the acceptance rates a deployment would see.
    """
    evidence = tuple(evidence if evidence is not None else spec.evidence)
    full = _sample_joint(spec, key, batch)
    cols = jnp.asarray([spec.index(e) for e in evidence], jnp.int32)
    return full[:, cols]
