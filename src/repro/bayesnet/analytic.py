"""Exact enumeration oracle for :class:`~repro.bayesnet.spec.NetworkSpec`.

Full-joint enumeration over the ``2**N`` binary assignments, vectorised: the
assignment grid, the per-node CPT gathers and the evidence-consistency masks
are all plain array ops, so one jit launch evaluates *batches* of evidence
frames against the whole joint at once.  For the 5-12 node scenario networks
this is exact, fast, and serves as the correctness bound for the stochastic
backend (compiled posteriors must match within O(1/sqrt(n_accepted))).

``dac_quantize=True`` rounds every CPT entry to the 8-bit programming DAC grid
(k/256) before enumerating -- the exact distribution the packed-stochastic
lowering samples from -- so oracle-vs-stochastic comparisons isolate the
stochastic noise from the (documented, bounded) quantisation bias.
"""

from __future__ import annotations

import functools
from typing import Callable, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.bayesnet.spec import NetworkSpec


def _quantize(p: jnp.ndarray) -> jnp.ndarray:
    """Snap probabilities to the SNE's 8-bit DAC grid (rng.threshold_from_p)."""
    return jnp.clip(jnp.round(p * 256.0), 0.0, 256.0) / 256.0


def joint_table(spec: NetworkSpec, dac_quantize: bool = False):
    """Returns (states (2**N, N) int32, joint (2**N,) float32).

    Column ``j`` of ``states`` is the value of ``spec.nodes[j]``; ``joint`` is
    the exact probability of each assignment under the network.
    """
    n = spec.n_nodes
    if n > 20:
        raise ValueError(f"enumeration oracle capped at 20 nodes, got {n}")
    idx = {node.name: j for j, node in enumerate(spec.nodes)}
    states = (jnp.arange(1 << n, dtype=jnp.int32)[:, None] >> jnp.arange(n)) & 1
    joint = jnp.ones((1 << n,), jnp.float32)
    for node in spec.nodes:
        cpt = jnp.asarray(node.cpt, jnp.float32)
        if dac_quantize:
            cpt = _quantize(cpt)
        m = len(node.parents)
        # CPT row index: first parent is the most significant bit (spec.py).
        row = jnp.zeros((1 << n,), jnp.int32)
        for j, parent in enumerate(node.parents):
            row = row | (states[:, idx[parent]] << (m - 1 - j))
        p1 = cpt[row]
        v = states[:, idx[node.name]]
        joint = joint * jnp.where(v == 1, p1, 1.0 - p1)
    return states, joint


def make_posterior_fn(
    spec: NetworkSpec,
    queries: Sequence[str] | None = None,
    evidence: Sequence[str] | None = None,
    dac_quantize: bool = False,
) -> Callable[[jnp.ndarray], Tuple[jnp.ndarray, jnp.ndarray]]:
    """Compile the exact batched-posterior function for a spec.

    Returns ``fn(ev_frames (B, n_ev) int) -> (post (B, n_q), p_evidence (B,))``
    with ``post[b, q] = P(queries[q] = 1 | evidence = ev_frames[b])``, jitted
    and fully vectorised over frames.  Frames columns follow the ``evidence``
    order; ``p_evidence`` is the evidence marginal (0 where impossible, the
    posterior then falls back to 0.5).
    """
    queries = tuple(queries if queries is not None else spec.queries)
    evidence = tuple(evidence if evidence is not None else spec.evidence)
    states, joint = joint_table(spec, dac_quantize=dac_quantize)
    ev_cols = jnp.asarray([spec.index(e) for e in evidence], jnp.int32)
    q_cols = jnp.asarray([spec.index(q) for q in queries], jnp.int32)

    @jax.jit
    def posterior(ev_frames: jnp.ndarray):
        ev = jnp.asarray(ev_frames, jnp.int32)
        assert ev.ndim == 2 and ev.shape[1] == len(evidence), ev.shape
        # (B, 2**N): does assignment s agree with frame b's evidence?
        if len(evidence):
            match = jnp.all(states[None, :, ev_cols] == ev[:, None, :], axis=-1)
        else:
            match = jnp.ones((ev.shape[0], states.shape[0]), bool)
        w = match.astype(jnp.float32) * joint[None, :]            # (B, 2**N)
        p_e = jnp.sum(w, axis=-1)                                 # (B,)
        q_on = states[:, q_cols].astype(jnp.float32)              # (2**N, n_q)
        num = w @ q_on                                            # (B, n_q)
        post = jnp.where(p_e[:, None] > 0, num / jnp.maximum(p_e[:, None], 1e-30), 0.5)
        return post, p_e

    return posterior


@functools.partial(jax.jit, static_argnames=("spec", "batch"))
def _sample_joint(spec: NetworkSpec, key: jax.Array, batch: int) -> jnp.ndarray:
    """Ancestral sampling: (B, N) int32 samples in declared node order."""
    idx = {node.name: j for j, node in enumerate(spec.nodes)}
    vals = [None] * spec.n_nodes
    for name in spec.topo_order():
        node = spec.node(name)
        key, sub = jax.random.split(key)
        cpt = jnp.asarray(node.cpt, jnp.float32)
        m = len(node.parents)
        row = jnp.zeros((batch,), jnp.int32)
        for j, parent in enumerate(node.parents):
            row = row | (vals[idx[parent]] << (m - 1 - j))
        u = jax.random.uniform(sub, (batch,))
        vals[idx[name]] = (u < cpt[row]).astype(jnp.int32)
    return jnp.stack(vals, axis=-1)


def sample_evidence(
    spec: NetworkSpec, key: jax.Array, batch: int,
    evidence: Sequence[str] | None = None,
) -> jnp.ndarray:
    """Draw (B, n_ev) realistic evidence frames by ancestral joint sampling.

    Frames are distributed as the network itself predicts its sensors to fire,
    so batched benchmarks exercise the acceptance rates a deployment would see.
    """
    evidence = tuple(evidence if evidence is not None else spec.evidence)
    full = _sample_joint(spec, key, batch)
    cols = jnp.asarray([spec.index(e) for e in evidence], jnp.int32)
    return full[:, cols]
