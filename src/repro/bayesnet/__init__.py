"""Bayesian-network compiler: declarative DAG specs lowered to the packed
stochastic domain (DESIGN.md SS8-SS10).

    spec.py       NetworkSpec / Node -- the source language; nodes carry a
                  cardinality k (binary = the k=2 special case)
    compile.py    lowering: fused net_sweep (production; devices= shards the
                  frame axis bit-identically, decide rides an in-kernel
                  argmax epilogue) or per-node rng/node_mux/cordiv packed
                  programs (verification baseline); k-ary nodes ride value
                  bit-planes + 8-bit DAC CDFs
    analytic.py   exact mixed-radix enumeration oracle + ancestral sampling
    scenarios.py  5-12 node driving networks over data/detection statistics
                  (binary quartet + categorical trio)
    driver.py     serve-style continuous batching of evidence frames, with
                  non-blocking dispatch (step(block=False) / drain_async)
                  and power-of-two launch buckets for short tails
"""

from repro.bayesnet.analytic import make_posterior_fn, sample_evidence  # noqa: F401
from repro.bayesnet.compile import (  # noqa: F401
    CompiledNetwork,
    compile_network,
    posterior_argmax,
    sweep_plan,
)
from repro.bayesnet.driver import FrameDriver  # noqa: F401
from repro.bayesnet.scenarios import SCENARIOS, by_name  # noqa: F401
from repro.bayesnet.spec import NetworkSpec, Node  # noqa: F401
