"""Bayesian-network compiler: declarative DAG specs lowered to the packed
stochastic domain (DESIGN.md SS8-SS10).

    spec.py       NetworkSpec / Node -- the source language; nodes carry a
                  cardinality k (binary = the k=2 special case)
    compile.py    lowering: fused net_sweep (production; devices= shards the
                  frame axis bit-identically, decide rides an in-kernel
                  argmax epilogue) or per-node rng/node_mux/cordiv packed
                  programs (verification baseline); k-ary nodes ride value
                  bit-planes + 8-bit DAC CDFs; noise= perturbs every DAC
                  threshold through the crossbar non-ideality model
    noise.py      NoiseModel: plan-build-time device-to-device / read-noise /
                  IR-drop / stuck-at perturbation of the DAC thresholds
    analytic.py   exact mixed-radix enumeration oracle + ancestral sampling;
                  noise= builds the perturbed-CPT oracle twin
    reliability.py decision-margin confidence signal, RetryPolicy, and the
                  flip-rate / harvest reliability statistics
    scenarios.py  5-12 node driving networks over data/detection statistics
                  (binary quartet + categorical trio)
    driver.py     serve-style continuous batching of evidence frames, with
                  non-blocking dispatch (step(block=False) / drain_async),
                  power-of-two launch buckets for short tails,
                  confidence-gated retry with escalating n_bits (retry=),
                  online drift monitoring (drift=) and between-launch
                  hot-swap of recalibrated plans (swap_net)
    calibrate.py  calibrate-back loop: CPT fitting from synthetic detection
                  rollouts, drift-compensated threshold programming, and
                  hot recalibration of live drivers (DESIGN §15)
"""

from repro.bayesnet.analytic import make_posterior_fn, sample_evidence  # noqa: F401
from repro.bayesnet.calibrate import (  # noqa: F401
    calibration_report,
    compensated_program,
    fit_scene_config,
    recalibrate_driver,
    recalibrated_network,
)
from repro.bayesnet.compile import (  # noqa: F401
    CompiledNetwork,
    compile_network,
    posterior_argmax,
    sweep_plan,
)
from repro.bayesnet.driver import FrameDriver  # noqa: F401
from repro.bayesnet.noise import NoiseModel, perturbed_cdf_rows  # noqa: F401
from repro.bayesnet.reliability import (  # noqa: F401
    HEALTH_DRIFTING,
    HEALTH_HEALTHY,
    HEALTH_RECALIBRATING,
    HEALTH_STATES,
    DriftMonitor,
    DriftPolicy,
    FrameReport,
    ReliabilityStats,
    RetryPolicy,
    decision_confidence,
    flip_rate,
)
from repro.bayesnet.scenarios import SCENARIOS, by_name  # noqa: F401
from repro.bayesnet.spec import NetworkSpec, Node  # noqa: F401
