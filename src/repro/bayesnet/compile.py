"""Compile a :class:`~repro.bayesnet.spec.NetworkSpec` to the packed domain.

Two lowerings share the spec language:

**Fused** (production default for independent entropy): the whole network --
per-node threshold-gather sampling, evidence-indicator AND, CORDIV popcount
fixed point -- becomes ONE :func:`~repro.kernels.net_sweep.net_sweep` launch.
Entropy is generated in-register from counter bit-planes with the frame index
folded into the counters, so every frame draws an independent joint sample
(exactly what the physical memristor array provides for free) and node
streams never touch HBM.  This is what closed the former ~70x
``share_entropy=False`` cliff.

**Unfused** (one op per node; the verification baseline, and the only path
for shared entropy or the ``fill`` estimator):

* root nodes      -> independent packed Bernoulli streams (``rng.encode_packed``,
  the counter-entropy SNE).
* non-root nodes  -> the :func:`~repro.kernels.node_mux.node_mux` sweep.  The
  default ``mux_mode='gather'`` selects the node's 8-bit DAC threshold by the
  parents' packed bits and compares one entropy byte per stream bit;
  ``mux_mode='rows'`` is the original formulation (fresh entropy per CPT row
  routed through the value-select MUX tree) kept as the statistical baseline.
  Either way, at every bit position the vector of all node bits is an exact
  joint sample of the network -- the n-ary generalisation of the Fig S8
  motifs.
* queries         -> stochastic conditioning: the evidence indicator streams
  (a node stream, or its packed NOT for evidence value 0) are ANDed into the
  acceptance stream ``d``; each query's numerator is ``d AND S_q``, a bitwise
  subset of ``d`` by construction, so CORDIV's correlation discipline holds
  with no superset completion.  ``estimator='ratio'`` uses the closed-form
  ``cordiv_ratio`` popcount fixed point; ``estimator='fill'`` runs the
  word-parallel ``cordiv_fill`` flip-flop circuit (bit-faithful to the serial
  divider).

The compiled program is one jitted function.  ``share_entropy=False`` (the
default) gives every frame an independent joint sample -- independent errors
across frames, the mode a deployment should run.  ``share_entropy=True``
builds the node streams once per launch and every frame conditions the *same*
joint sample: cheaper still for huge batches, but frame errors are maximally
correlated.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.bayesnet.spec import NetworkSpec
from repro.core import bitops, cordiv, rng
from repro.kernels.net_sweep import SweepPlan, net_sweep
from repro.kernels.node_mux.ops import node_mux


def _posterior_from_counts(numer: jnp.ndarray, denom: jnp.ndarray) -> jnp.ndarray:
    """Per-frame posteriors from count arrays: numer (B, n_q), denom (B,)."""
    return cordiv.ratio_from_counts(numer, denom[:, None])


@dataclasses.dataclass(frozen=True)
class CompiledNetwork:
    """A network lowered to one jitted packed-stochastic program.

    ``run(key, ev_frames (B, n_ev) int) -> (post (B, n_q), accepted (B,))``:
    ``post[b, q]`` estimates ``P(queries[q]=1 | evidence = ev_frames[b])`` and
    ``accepted[b]`` is the number of stream bits that satisfied frame ``b``'s
    evidence -- the effective sample count, so callers can bound the noise as
    ``sigma ~ sqrt(p (1-p) / accepted)``.
    """

    spec: NetworkSpec
    queries: Tuple[str, ...]
    evidence: Tuple[str, ...]
    n_bits: int
    share_entropy: bool
    estimator: str
    fused: bool
    _run: Callable = dataclasses.field(repr=False)

    def run(self, key: jax.Array, ev_frames) -> Tuple[jnp.ndarray, jnp.ndarray]:
        ev = jnp.asarray(ev_frames, jnp.int32)
        if ev.ndim != 2 or ev.shape[1] != len(self.evidence):
            raise ValueError(
                f"evidence frames must be (B, {len(self.evidence)}), got {ev.shape}"
            )
        return self._run(key, ev)


def sweep_plan(
    spec: NetworkSpec,
    queries: Sequence[str],
    evidence: Sequence[str],
) -> SweepPlan:
    """Lower a spec to the static :class:`SweepPlan` the fused kernel consumes.

    Nodes are renumbered into topological order; thresholds are the 8-bit DAC
    comparator values (``round(p * 256)``, the same grid every other encoder
    uses), so the fused sweep samples the identical quantised network.
    """
    order = spec.topo_order()
    index = {name: i for i, name in enumerate(order)}
    nodes = []
    for name in order:
        node = spec.node(name)
        thresh = tuple(rng.threshold_int(p) for p in node.cpt)
        nodes.append((tuple(index[p] for p in node.parents), thresh))
    return SweepPlan(
        nodes=tuple(nodes),
        evidence=tuple(index[e] for e in evidence),
        queries=tuple(index[q] for q in queries),
    )


def lower_streams(
    spec: NetworkSpec,
    key: jax.Array,
    n_bits: int,
    batch: int | None = None,
    *,
    mux_mode: str = "gather",
    use_kernel: bool | None = None,
    interpret: bool | None = None,
):
    """One topological sweep: name -> packed stream ((W,) or (B, W)).

    The per-node subkey comes from ``fold_in(key, node index)``, so every node
    draws disjoint counter entropy while parents' streams are shared by all
    their children exactly once -- the correlation structure the joint sample
    requires.
    """
    order = spec.topo_order()
    streams = {}
    for i, name in enumerate(order):
        node = spec.node(name)
        sub = jax.random.fold_in(key, i)
        if not node.parents:
            p = jnp.float32(node.cpt[0])
            if batch is not None:
                p = jnp.full((batch,), p, jnp.float32)
            streams[name] = rng.encode_packed(sub, p, n_bits)
        else:
            cpt = jnp.asarray(node.cpt, jnp.float32)
            if batch is not None:
                cpt = jnp.broadcast_to(cpt, (batch,) + cpt.shape)
            parents = jnp.stack([streams[pn] for pn in node.parents])
            streams[name] = node_mux(
                sub, cpt, parents, n_bits, mode=mux_mode,
                use_kernel=use_kernel, interpret=interpret,
            )
    return streams


def compile_network(
    spec: NetworkSpec,
    n_bits: int = 4096,
    queries: Sequence[str] | None = None,
    evidence: Sequence[str] | None = None,
    *,
    share_entropy: bool = False,
    estimator: str = "ratio",
    fused: bool | None = None,
    mux_mode: str = "gather",
    use_kernel: bool | None = None,
    interpret: bool | None = None,
) -> CompiledNetwork:
    """Lower ``spec`` to a jitted, frame-batched packed-stochastic program.

    ``fused=None`` auto-selects: the one-launch ``net_sweep`` path whenever it
    applies (independent entropy + ratio estimator -- the production mode),
    the per-node unfused path otherwise.  ``fused=False`` forces the unfused
    program, the statistical verification baseline for the fused kernel.
    """
    queries = tuple(queries if queries is not None else spec.queries)
    evidence = tuple(evidence if evidence is not None else spec.evidence)
    if not queries:
        raise ValueError(f"{spec.name}: no query nodes")
    if estimator not in ("ratio", "fill"):
        raise ValueError(f"unknown estimator {estimator!r}")
    if n_bits % 32:
        raise ValueError("n_bits must be a multiple of 32 (packed words)")
    if mux_mode not in ("gather", "rows"):
        raise ValueError(f"unknown mux_mode {mux_mode!r}")
    # The fused sweep samples with threshold-gather by construction, so a
    # non-default mux_mode is an explicit request for the unfused per-node
    # lowering -- auto-resolution honours it instead of silently ignoring it.
    fusable = not share_entropy and estimator == "ratio" and mux_mode == "gather"
    if fused is None:
        fused = fusable
    elif fused and not fusable:
        raise ValueError(
            "fused lowering requires share_entropy=False, estimator='ratio' "
            f"and mux_mode='gather' (got share_entropy={share_entropy}, "
            f"estimator={estimator!r}, mux_mode={mux_mode!r})"
        )
    mask = bitops.pad_mask(n_bits)

    if fused:
        plan = sweep_plan(spec, queries, evidence)

        @jax.jit
        def _run(key, ev_frames):
            numer, denom = net_sweep(
                key, ev_frames, plan=plan, n_bits=n_bits,
                use_kernel=use_kernel, interpret=interpret,
            )
            return _posterior_from_counts(numer, denom), denom

        return CompiledNetwork(
            spec=spec, queries=queries, evidence=evidence, n_bits=n_bits,
            share_entropy=share_entropy, estimator=estimator, fused=True,
            _run=_run,
        )

    def one_frame(ev, ev_streams, q_streams):
        """ev (n_ev,), ev_streams (n_ev, W), q_streams (n_q, W)."""
        denom = jnp.broadcast_to(mask, q_streams.shape[-1:])
        for i in range(len(evidence)):
            # indicator: the node stream for e=1, its packed NOT for e=0
            ind = ev_streams[i] ^ jnp.where(ev[i] == 1, jnp.uint32(0), mask)
            denom = denom & ind
        numer = q_streams & denom[None, :]
        _, post = cordiv.cordiv_fill(numer, denom[None, :], n_bits)
        return post, bitops.popcount(denom)

    def ratio_batched(ev_frames, ev_s, q_s):
        """Straight-line batched conditioning for the ratio estimator.

        Computes ``cordiv_ratio`` -- popcount(numer) / popcount(denom) over
        the same acceptance stream ``one_frame`` builds -- with indicators
        broadcast across the frame axis instead of per-frame ``vmap``
        closures (~1.4x faster).  ev_s/q_s are (n, W) shared or (n, B, W)
        independent streams.
        """
        b = ev_frames.shape[0]
        accept = jnp.broadcast_to(mask, (b, mask.shape[0]))
        for i in range(len(evidence)):
            s = ev_s[i] if ev_s[i].ndim == 2 else ev_s[i][None, :]
            ind = s ^ jnp.where(ev_frames[:, i : i + 1] == 1, jnp.uint32(0), mask[None, :])
            accept = accept & ind
        denom = bitops.popcount(accept)
        numer = jnp.stack(
            [
                bitops.popcount(accept & (q if q.ndim == 2 else q[None, :]))
                for q in q_s
            ],
            axis=-1,
        )
        return _posterior_from_counts(numer, denom), denom

    @jax.jit
    def _run(key, ev_frames):
        b = ev_frames.shape[0]
        streams = lower_streams(
            spec, key, n_bits, batch=None if share_entropy else b,
            mux_mode=mux_mode, use_kernel=use_kernel, interpret=interpret,
        )
        ev_s = jnp.stack([streams[e] for e in evidence]) if evidence else \
            jnp.zeros((0,) + next(iter(streams.values())).shape, jnp.uint32)
        q_s = jnp.stack([streams[q] for q in queries])
        if estimator == "ratio":
            return ratio_batched(ev_frames, ev_s, q_s)
        if share_entropy:
            return jax.vmap(one_frame, in_axes=(0, None, None))(ev_frames, ev_s, q_s)
        # independent entropy: streams carry a leading frame axis
        ev_s = jnp.moveaxis(ev_s, 1, 0)                  # (B, n_ev, W)
        q_s = jnp.moveaxis(q_s, 1, 0)                    # (B, n_q, W)
        return jax.vmap(one_frame)(ev_frames, ev_s, q_s)

    return CompiledNetwork(
        spec=spec, queries=queries, evidence=evidence, n_bits=n_bits,
        share_entropy=share_entropy, estimator=estimator, fused=False,
        _run=_run,
    )
