"""Compile a :class:`~repro.bayesnet.spec.NetworkSpec` to the packed domain.

Nodes are cardinality-``k`` categorical variables carried as ``value_bits(k)``
packed bit-plane streams (binary = the one-plane ``k=2`` special case, bit
identical to the pre-categorical lowering).  Two lowerings share the spec
language:

**Fused** (production default for independent entropy): the whole network --
per-node categorical threshold-gather sampling, evidence-indicator AND, CORDIV
popcount fixed point -- becomes ONE :func:`~repro.kernels.net_sweep.net_sweep`
launch.  Entropy is generated in-register from counter bit-planes with the
frame index folded into the counters (ONE byte per stream position regardless
of cardinality), so every frame draws an independent joint sample and node
streams never touch HBM.

**Unfused** (one op per node; the verification baseline, and the only path
for shared entropy or the ``fill`` estimator):

* binary roots     -> independent packed Bernoulli streams (``rng.encode_packed``).
* k-ary roots      -> ``rng.encode_packed_categorical`` (same entropy words,
  ``k-1`` comparisons, ``value_bits(k)`` planes).
* all-binary nodes -> the :func:`~repro.kernels.node_mux.node_mux` sweep
  (``mux_mode='gather'`` default; ``mux_mode='rows'`` is the original
  formulation kept as the binary statistical baseline).
* k-ary nodes (or binary nodes with k-ary parents)
                   -> :func:`~repro.kernels.node_mux.node_mux_categorical`:
  the parents' value digits gather the row's 8-bit DAC CDF, one entropy byte
  samples the k-way draw.
* queries          -> stochastic conditioning: per-evidence-node value
  indicators (AND of plane literals) are ANDed into the acceptance stream
  ``d``; each query *value* indicator ANDed with ``d`` is a bitwise subset of
  ``d`` by construction, so CORDIV's correlation discipline holds.
  ``estimator='ratio'`` uses the closed-form popcount fixed point;
  ``estimator='fill'`` runs the word-parallel ``cordiv_fill`` flip-flop
  circuit per value slot.

Posterior contract: when every query node is binary, ``run`` returns the
classic ``(B, n_q)`` array of ``P(q=1 | evidence)`` -- bit-identical to the
pre-categorical compiler.  When any query has ``k > 2``, ``run`` returns a
``(B, n_q, max_k)`` tensor of normalised per-value posteriors (rows of
queries with smaller cardinality are zero-padded).  ``decide`` returns the
posterior AND its per-query MAP decisions from the same launch: the fused
path argmaxes the count slots in-register (``net_sweep``'s decision
epilogue), the unfused path argmaxes the assembled posterior -- identical
results by construction.

``compile_network(devices=N)`` (or an ambient ``mesh_context``) shards the
fused launch over the frame axis with ``shard_map``; the global frame index
is folded into the per-frame entropy counters, so sharded output is
bit-identical to single-device output on every scenario.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.bayesnet.noise import NoiseModel, perturbed_cdf_rows
from repro.bayesnet.spec import NetworkSpec
from repro.core import bitops, cordiv, rng
from repro.distributed import context as dist_context
from repro.distributed import sharding as dist_sharding
from repro.kernels.net_sweep import SweepPlan, net_sweep
from repro.kernels.node_mux.ops import node_mux, node_mux_categorical
from repro.obs import Tracer


def network_stats(net: "CompiledNetwork") -> dict:
    """Static plan statistics for one compiled program (span / log fodder).

    * ``n_nodes`` / ``n_edges``: DAG shape.
    * ``cpt_rows``: total CPT rows lowered (one per parent assignment per
      node) -- the crossbar row count of the modelled array.
    * ``n_thresholds``: total 8-bit DAC comparator thresholds
      (``rows x (card - 1)`` per node), the quantity the noise model perturbs.
    * ``threshold_mask_bytes``: size of the trace-time-folded comparator
      constants in the fused sweep -- each threshold contributes 8 bit-plane
      mask words of 4 bytes (:mod:`repro.kernels.net_sweep`'s borrow-chain
      literals), so this is the plan's constant footprint, the number that
      grows when a network deepens.
    * ``n_value_slots``: numerator count slots (``card - 1`` per query).
    """
    spec = net.spec
    n_edges = n_rows = n_thresholds = 0
    for name in spec.topo_order():
        node = spec.node(name)
        rows = spec.cpt_rows(name)
        n_edges += len(node.parents)
        n_rows += len(rows)
        n_thresholds += len(rows) * (spec.card(name) - 1)
    return {
        "n_nodes": spec.n_nodes,
        "n_edges": n_edges,
        "cpt_rows": n_rows,
        "n_thresholds": n_thresholds,
        "threshold_mask_bytes": n_thresholds * 8 * 4,
        "n_value_slots": sum(c - 1 for c in net.query_cards),
        "n_bits": net.n_bits,
        "fused": net.fused,
        "n_shards": net.n_shards,
    }


def _posterior_from_counts(numer: jnp.ndarray, denom: jnp.ndarray) -> jnp.ndarray:
    """Per-frame posteriors from count arrays: numer (B, n_s), denom (B,)."""
    return cordiv.ratio_from_counts(numer, denom[:, None])


def _slot_assembler(q_cards: Tuple[int, ...]) -> Callable:
    """Build the slot-probabilities -> posterior map for a query card profile.

    Slots hold ``P(q = v | e)`` for values ``1 .. k-1`` per query, in query
    order.  All-binary queries keep the classic ``(B, n_q)`` layout (the slot
    array IS the posterior, bit-identical to the pre-categorical path);
    otherwise the slots fold into ``(B, n_q, max_k)`` with
    ``P(q = 0) = 1 - sum`` and zero padding past each query's cardinality.
    Used by the ``fill`` estimator, whose slots are independent stochastic
    divisions with no underlying integer counts; the ratio paths assemble
    from counts instead (:func:`_count_assembler`).
    """
    if all(c == 2 for c in q_cards):
        return lambda slots: slots
    kmax = max(q_cards)

    def assemble(slots: jnp.ndarray) -> jnp.ndarray:
        cols = []
        off = 0
        for c in q_cards:
            v = slots[:, off : off + c - 1]
            off += c - 1
            s = jnp.sum(v, axis=-1, keepdims=True)
            p0 = jnp.clip(1.0 - s, 0.0, 1.0)
            parts = [p0, v]
            if kmax > c:
                parts.append(jnp.zeros(v.shape[:-1] + (kmax - c,), v.dtype))
            # Ratio-estimator slots are disjoint-bucket count fractions, so
            # s <= 1 exactly and the divisor is literally 1.0; the fill
            # estimator's slots are independent stochastic divisions whose
            # noise can push s past 1 -- rescale so the vector stays a
            # distribution either way.
            cols.append(jnp.concatenate(parts, axis=-1) / jnp.maximum(s, 1.0))
        return jnp.stack(cols, axis=1)

    return assemble


def _count_assembler(q_cards: Tuple[int, ...]) -> Callable:
    """Counts -> posterior map for the ratio paths (count-exact value 0).

    Same layout as :func:`_slot_assembler` -- all-binary query sets keep the
    classic ``(B, n_q)`` slot array bit-identically -- but every k-ary column
    is the correctly-rounded float32 of ``count / denom``, with the value-0
    count reconstructed in the *integer* domain (``denom - sum(slots)``), the
    SAME convention :func:`~repro.kernels.net_sweep.decide_counts` applies
    before its argmax (the two must stay in lockstep or the fused decisions
    and posterior diverge).  ``1 - sum(float slots)`` can land one ULP below
    a tied slot probability, which would flip the posterior argmax away from
    the count argmax on exact count ties; dividing the integer counts instead
    makes equal counts equal floats, so the decide epilogue's tie-break
    (lowest value) and the posterior argmax agree on every input by
    construction.  A ``denom == 0`` frame yields the all-zero vector (the
    :func:`ratio_from_counts` convention the binary path already follows).
    """
    if all(c == 2 for c in q_cards):
        return lambda numer, denom: _posterior_from_counts(numer, denom)
    kmax = max(q_cards)

    def assemble(numer: jnp.ndarray, denom: jnp.ndarray) -> jnp.ndarray:
        cols = []
        off = 0
        for c in q_cards:
            v = numer[:, off : off + c - 1]
            off += c - 1
            c0 = denom[:, None] - jnp.sum(v, axis=-1, keepdims=True)
            counts = jnp.concatenate([c0, v], axis=-1)
            p = cordiv.ratio_from_counts(counts, denom[:, None])
            if kmax > c:
                p = jnp.concatenate(
                    [p, jnp.zeros((p.shape[0], kmax - c), p.dtype)], axis=-1
                )
            cols.append(p)
        return jnp.stack(cols, axis=1)

    return assemble


def posterior_argmax(post: jnp.ndarray) -> jnp.ndarray:
    """MAP decision from a ``run`` posterior, matching the fused epilogue.

    Binary layout ``(B, n_q)``: value 1 wins iff ``P(q=1) > 0.5`` (exactly
    ``argmax([1-p, p])`` with ties to value 0).  k-ary layout
    ``(B, n_q, kmax)``: argmax over the value axis (ties to the lowest value,
    zero padding past a query's cardinality can never win).  This is the same
    tie-break :func:`~repro.kernels.net_sweep.decide_counts` applies to the
    raw counts, and the ratio-estimator posteriors (fused and unfused) are
    assembled count-exactly (:func:`_count_assembler`: equal counts -> equal
    floats), so applying this to a fused ``run`` posterior reproduces the
    in-kernel decisions bit-for-bit.  Only the ``fill`` estimator's
    posterior, which has no integer counts underneath, can land float ties
    off the count grid.
    """
    post = jnp.asarray(post)
    if post.ndim == 2:
        return (post > 0.5).astype(jnp.int32)
    return jnp.argmax(post, axis=-1).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class CompiledNetwork:
    """A network lowered to one jitted packed-stochastic program.

    ``run(key, ev_frames (B, n_ev) int) -> (post, accepted (B,))``: evidence
    values are integers in ``[0, card)`` per evidence node.  ``post`` is
    ``(B, n_q)`` of ``P(q=1 | evidence)`` when every query is binary, else
    ``(B, n_q, max(query_cards))`` of normalised per-value posteriors.
    ``accepted[b]`` is the number of stream positions that satisfied frame
    ``b``'s evidence -- the effective sample count, so callers can bound the
    noise as ``sigma ~ sqrt(p (1-p) / accepted)``.

    ``n_shards > 1`` marks the sharded fused program: one ``shard_map``
    launch spans ``n_shards`` devices over the frame axis (``shard_axes``),
    bit-identical to the single-device program for any batch the shard count
    divides (indivisible batches transparently run the single-device path).
    """

    spec: NetworkSpec
    queries: Tuple[str, ...]
    evidence: Tuple[str, ...]
    n_bits: int
    share_entropy: bool
    estimator: str
    fused: bool
    query_cards: Tuple[int, ...]
    _run: Callable = dataclasses.field(repr=False)
    _decide: Callable = dataclasses.field(repr=False)
    n_shards: int = 1
    shard_axes: Tuple[str, ...] = ()
    noise: NoiseModel | None = None
    # Within-launch drift epochs baked into the plan (1 = frozen snapshot)
    # and the programmed-threshold override the plan was lowered from
    # (calibrate-back compensation; None = clean spec thresholds).
    drift_epochs: int = 1
    program: dict | None = dataclasses.field(default=None, repr=False, compare=False)

    def _check_frames(self, ev_frames) -> jnp.ndarray:
        ev = jnp.asarray(ev_frames, jnp.int32)
        if ev.ndim != 2 or ev.shape[1] != len(self.evidence):
            raise ValueError(
                f"evidence frames must be (B, {len(self.evidence)}), got {ev.shape}"
            )
        return ev

    def run(self, key: jax.Array, ev_frames) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return self._run(key, self._check_frames(ev_frames))

    def decide(
        self, key: jax.Array, ev_frames
    ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """Posteriors AND per-query MAP decisions in the same launch.

        Fused programs run ``net_sweep(..., decide=True)``: the decision
        epilogue argmaxes the per-query count slots in-register, so the whole
        sense->classify->act path is one launch -- no posterior re-encode, no
        second kernel.  Unfused programs argmax the assembled posterior
        (:func:`posterior_argmax`); both produce the decision a MAP readout
        of ``run``'s posterior would, bit-for-bit.  Returns
        ``(post, decisions (B, n_q) int32, accepted)``.
        """
        return self._decide(key, self._check_frames(ev_frames))


def sweep_plan(
    spec: NetworkSpec,
    queries: Sequence[str],
    evidence: Sequence[str],
    noise: NoiseModel | None = None,
    *,
    drift_epochs: int = 1,
    program: dict | None = None,
) -> SweepPlan:
    """Lower a spec to the static :class:`SweepPlan` the fused kernel consumes.

    Nodes are renumbered into topological order; each CPT row becomes its
    ``card - 1`` cumulative 8-bit DAC comparator thresholds
    (``rng.cdf_thresholds_int`` -- for binary nodes exactly the old
    ``round(p * 256)`` grid), so the fused sweep samples the identical
    quantised network every other encoder does.  ``noise`` perturbs every
    threshold through the crossbar non-ideality model
    (:mod:`repro.bayesnet.noise`) before it is baked into the plan --
    ``noise=None`` produces exactly the clean plan.

    ``drift_epochs=E > 1`` models the read-noise snapshot advancing *within*
    one launch: epoch ``e`` re-perturbs the thresholds at
    ``noise.with_cycle(noise.cycle + e)`` and the sweep applies each epoch's
    rows to its share of the word axis (:func:`~repro.kernels.net_sweep.common.
    epoch_word_bounds`).  ``drift_epochs=1`` produces exactly the
    single-snapshot plan.  ``program`` overrides the programmed thresholds
    fed into the perturbation (calibrate-back compensation, see
    :func:`~repro.bayesnet.noise.perturbed_cdf_rows`).
    """
    drift_epochs = int(drift_epochs)
    if drift_epochs > 1 and noise is None:
        raise ValueError("drift_epochs > 1 needs a NoiseModel to advance")
    order = spec.topo_order()
    index = {name: i for i, name in enumerate(order)}
    perturbed = (
        perturbed_cdf_rows(spec, noise, program=program)
        if noise is not None or program is not None else None
    )
    nodes = []
    for name in order:
        node = spec.node(name)
        if perturbed is not None:
            rows = perturbed[name]
        else:
            rows = tuple(rng.cdf_thresholds_int(r) for r in spec.cpt_rows(name))
        nodes.append((tuple(index[p] for p in node.parents), spec.card(name), rows))
    epoch_rows = []
    for e in range(1, drift_epochs):
        pe = perturbed_cdf_rows(
            spec, noise.with_cycle(noise.cycle + e), program=program
        )
        epoch_rows.append(tuple(pe[name] for name in order))
    return SweepPlan(
        nodes=tuple(nodes),
        evidence=tuple(index[e] for e in evidence),
        queries=tuple(index[q] for q in queries),
        epochs=drift_epochs,
        epoch_rows=tuple(epoch_rows),
    )


def lower_streams(
    spec: NetworkSpec,
    key: jax.Array,
    n_bits: int,
    batch: int | None = None,
    *,
    mux_mode: str = "gather",
    noise: NoiseModel | None = None,
    program: dict | None = None,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
):
    """One topological sweep: name -> tuple of packed value bit-planes.

    Every entry is a ``value_bits(k)``-tuple of ``(W,)`` (or ``(B, W)``)
    packed words; a binary node's tuple holds its classic single stream.  The
    per-node subkey comes from ``fold_in(key, node index)``, so every node
    draws disjoint counter entropy while parents' planes are shared by all
    their children exactly once -- the correlation structure the joint sample
    requires.  Binary sub-networks draw entropy through exactly the
    pre-categorical code path, keeping their streams bit-identical.

    ``noise`` routes every node through the SAME perturbed integer thresholds
    the fused plan bakes in (:func:`~repro.bayesnet.noise.perturbed_cdf_rows`).
    Binary nodes feed the perturbed threshold back as ``t / 256`` -- exact in
    float32, so the encoder's ``round(p * 256)`` recovers ``t`` bit-for-bit
    and the two lowerings keep sampling the identical perturbed network.
    ``noise=None`` leaves every code path untouched.  ``program`` overrides
    the programmed thresholds fed into the perturbation (calibrate-back
    compensation); with both ``None`` nothing changes.
    """
    order = spec.topo_order()
    perturbed = (
        perturbed_cdf_rows(spec, noise, program=program)
        if noise is not None or program is not None else None
    )
    streams = {}
    for i, name in enumerate(order):
        node = spec.node(name)
        card = spec.card(name)
        pcards = tuple(spec.card(p) for p in node.parents)
        sub = jax.random.fold_in(key, i)
        if not node.parents:
            if card == 2:
                if perturbed is not None:
                    p = jnp.float32(perturbed[name][0][0] / 256.0)
                else:
                    p = jnp.float32(spec.cpt_rows(name)[0][1])
                if batch is not None:
                    p = jnp.full((batch,), p, jnp.float32)
                streams[name] = (rng.encode_packed(sub, p, n_bits),)
            else:
                if perturbed is not None:
                    cdf = perturbed[name][0]
                else:
                    cdf = rng.cdf_thresholds_int(spec.cpt_rows(name)[0])
                planes = rng.encode_packed_categorical(sub, cdf, n_bits, batch=batch)
                streams[name] = tuple(planes[b] for b in range(planes.shape[0]))
        elif card == 2 and all(c == 2 for c in pcards):
            if perturbed is not None:
                cpt = jnp.asarray(
                    tuple(r[0] / 256.0 for r in perturbed[name]), jnp.float32
                )
            else:
                cpt = jnp.asarray(
                    tuple(r[1] for r in spec.cpt_rows(name)), jnp.float32
                )
            if batch is not None:
                cpt = jnp.broadcast_to(cpt, (batch,) + cpt.shape)
            parents = jnp.stack([streams[pn][0] for pn in node.parents])
            streams[name] = (
                node_mux(
                    sub, cpt, parents, n_bits, mode=mux_mode,
                    use_kernel=use_kernel, interpret=interpret,
                ),
            )
        else:
            if perturbed is not None:
                cdf = jnp.asarray(perturbed[name], jnp.uint32)
            else:
                cdf = jnp.asarray(
                    tuple(rng.cdf_thresholds_int(r) for r in spec.cpt_rows(name)),
                    jnp.uint32,
                )
            if batch is not None:
                cdf = jnp.broadcast_to(cdf, (batch,) + cdf.shape)
            parents = jnp.stack(
                [pl for pn in node.parents for pl in streams[pn]]
            )
            planes = node_mux_categorical(
                sub, cdf, parents, cards=(card,) + pcards, n_bits=n_bits,
                use_kernel=use_kernel, interpret=interpret,
            )
            streams[name] = tuple(planes[b] for b in range(planes.shape[0]))
    return streams


def _resolve_frame_mesh(devices) -> Tuple[Mesh | None, Tuple[str, ...]]:
    """Mesh + frame-sharding axes for ``compile_network(devices=...)``.

    ``devices=N`` builds the 1-D ``frames`` mesh over the first N local
    devices; ``devices=None`` picks up the ambient
    :func:`~repro.distributed.context.current_mesh` (sharding over its
    :func:`~repro.distributed.sharding.batch_axes`) so launcher code that
    already runs under ``mesh_context`` shards for free.  Returns
    ``(None, ())`` when there is nothing to shard over (one device, no mesh,
    or no batch axis present in the mesh).
    """
    if devices is not None:
        if int(devices) == 1:
            return None, ()
        return dist_context.frame_mesh(int(devices)), ("frames",)
    mesh = dist_context.current_mesh()
    if mesh is None:
        return None, ()
    axes = tuple(
        a for a in dist_sharding.batch_axes(mesh) if a in mesh.axis_names
    )
    if not axes or math.prod(mesh.shape[a] for a in axes) <= 1:
        return None, ()
    return mesh, axes


def compile_network(
    spec: NetworkSpec,
    n_bits: int = 4096,
    queries: Sequence[str] | None = None,
    evidence: Sequence[str] | None = None,
    *,
    share_entropy: bool = False,
    estimator: str = "ratio",
    fused: bool | None = None,
    mux_mode: str = "gather",
    noise: NoiseModel | None = None,
    drift_epochs: int = 1,
    program: dict | None = None,
    devices: int | None = None,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
    trace: Tracer | None = None,
) -> CompiledNetwork:
    """Lower ``spec`` to a jitted, frame-batched packed-stochastic program.

    ``fused=None`` auto-selects: the one-launch ``net_sweep`` path whenever it
    applies (independent entropy + ratio estimator -- the production mode),
    the per-node unfused path otherwise.  ``fused=False`` forces the unfused
    program, the statistical verification baseline for the fused kernel.

    ``noise`` (a :class:`~repro.bayesnet.noise.NoiseModel`) injects crossbar
    non-idealities at plan-build time: every 8-bit DAC threshold the program
    samples against is deterministically perturbed (device-to-device lognormal
    spread, cycle-to-cycle read noise, position-dependent IR-drop, stuck-at
    faults) before lowering, in both the fused and unfused paths.
    ``noise=None`` (default) is bit-identical to a compile without the
    argument; the exact perturbed ground truth comes from the oracle twin
    ``make_posterior_fn(spec, noise=...)``.

    ``devices=N`` (fused only) wraps the sweep in one ``shard_map`` launch
    over the frame axis of an N-device mesh; with no ``devices`` argument an
    ambient :func:`~repro.distributed.context.mesh_context` mesh is picked up
    automatically.  Each shard folds its *global* frame origin into the
    entropy counters, so the sharded program is bit-identical to the
    single-device one -- replicating independent samplers is exactly how the
    physical array scales, and costs nothing in reproducibility.  Batches the
    shard count does not divide transparently fall back to the single-device
    launch (the jit is specialised per batch shape anyway).

    ``trace`` (a :class:`~repro.obs.Tracer`) records the lowering as a
    ``compile_network`` span whose attrs carry the plan statistics of
    :func:`network_stats` (nodes, edges, CPT rows, DAC thresholds,
    threshold-mask bytes, value slots).  The span's duration is the
    *lowering* time -- plan construction + jit wrapper building; XLA
    compilation itself is lazy and shows up inside the first launch's
    ``dispatch`` span instead.  ``trace=None`` changes nothing.
    """
    if trace is not None:
        with trace.span("compile_network", network=spec.name, n_bits=n_bits) as sp:
            net = compile_network(
                spec, n_bits, queries, evidence, share_entropy=share_entropy,
                estimator=estimator, fused=fused, mux_mode=mux_mode,
                noise=noise, drift_epochs=drift_epochs, program=program,
                devices=devices, use_kernel=use_kernel, interpret=interpret,
            )
            sp.attrs.update(network_stats(net))
            return net
    queries = tuple(queries if queries is not None else spec.queries)
    evidence = tuple(evidence if evidence is not None else spec.evidence)
    if not queries:
        raise ValueError(f"{spec.name}: no query nodes")
    if estimator not in ("ratio", "fill"):
        raise ValueError(f"unknown estimator {estimator!r}")
    if n_bits % 32:
        raise ValueError("n_bits must be a multiple of 32 (packed words)")
    if mux_mode not in ("gather", "rows"):
        raise ValueError(f"unknown mux_mode {mux_mode!r}")
    if mux_mode == "rows" and spec.max_card() > 2:
        raise ValueError(
            "mux_mode='rows' (the binary row-encode baseline) does not "
            "support k-ary nodes; use the default 'gather'"
        )
    if noise is not None and not isinstance(noise, NoiseModel):
        raise TypeError(f"noise must be a NoiseModel or None, got {type(noise)!r}")
    drift_epochs = int(drift_epochs)
    if drift_epochs < 1:
        raise ValueError(f"drift_epochs must be >= 1, got {drift_epochs}")
    if drift_epochs > n_bits // 32:
        raise ValueError(
            f"drift_epochs={drift_epochs} exceeds the {n_bits // 32} packed "
            f"words of n_bits={n_bits} (an epoch owns at least one word)"
        )
    if drift_epochs > 1 and noise is None:
        raise ValueError("drift_epochs > 1 needs a NoiseModel to advance")
    if program is not None:
        unknown = set(program) - set(spec.topo_order())
        if unknown:
            raise ValueError(f"program covers unknown nodes {sorted(unknown)}")
    q_cards = tuple(spec.card(q) for q in queries)
    assemble = _slot_assembler(q_cards)
    # The fused sweep samples with threshold-gather by construction, so a
    # non-default mux_mode is an explicit request for the unfused per-node
    # lowering -- auto-resolution honours it instead of silently ignoring it.
    fusable = not share_entropy and estimator == "ratio" and mux_mode == "gather"
    if fused is None:
        fused = fusable
    elif fused and not fusable:
        raise ValueError(
            "fused lowering requires share_entropy=False, estimator='ratio' "
            f"and mux_mode='gather' (got share_entropy={share_entropy}, "
            f"estimator={estimator!r}, mux_mode={mux_mode!r})"
        )
    if devices is not None and int(devices) > 1 and not fused:
        raise ValueError(
            "devices= sharding requires the fused lowering: per-node unfused "
            "programs draw batch-shaped entropy that is not bit-reproducible "
            "across shard boundaries"
        )
    if drift_epochs > 1 and not fused:
        raise ValueError(
            "drift_epochs > 1 requires the fused lowering: the per-node "
            "unfused encoders sample one threshold snapshot per stream"
        )
    mask = bitops.pad_mask(n_bits)

    if fused:
        plan = sweep_plan(
            spec, queries, evidence, noise=noise,
            drift_epochs=drift_epochs, program=program,
        )
        assemble_counts = _count_assembler(q_cards)
        mesh, shard_axes = _resolve_frame_mesh(devices)
        n_shards = (
            math.prod(mesh.shape[a] for a in shard_axes) if mesh is not None else 1
        )
        sweep_kwargs = dict(
            plan=plan, n_bits=n_bits, use_kernel=use_kernel, interpret=interpret
        )

        def launch(key, ev_frames, decide: bool):
            """One sweep launch: sharded over the frame axis when it divides.

            The per-shard body folds the shard's global frame origin into
            ``net_sweep``'s entropy counters (``frame0`` / ``total_frames``),
            which makes the sharded launch bit-identical to the single-device
            one -- asserted for every scenario in the sharding tests.
            """
            b = ev_frames.shape[0]
            if mesh is None or n_shards <= 1 or b % n_shards:
                return net_sweep(key, ev_frames, decide=decide, **sweep_kwargs)
            per_shard = b // n_shards
            ax = shard_axes if len(shard_axes) > 1 else shard_axes[0]
            bspec = P(ax)

            def body(kd, ev_local):
                idx = jnp.uint32(0)
                for a in shard_axes:
                    idx = idx * jnp.uint32(mesh.shape[a]) \
                        + jax.lax.axis_index(a).astype(jnp.uint32)
                return net_sweep(
                    kd, ev_local, frame0=idx * jnp.uint32(per_shard),
                    total_frames=b, decide=decide, **sweep_kwargs,
                )

            return shard_map(
                body, mesh=mesh, in_specs=(P(), bspec),
                out_specs=(bspec,) * (3 if decide else 2), check_rep=False,
            )(rng.seed_words(key), ev_frames)

        @jax.jit
        def _run(key, ev_frames):
            numer, denom = launch(key, ev_frames, False)
            return assemble_counts(numer, denom), denom

        @jax.jit
        def _decide(key, ev_frames):
            numer, denom, dec = launch(key, ev_frames, True)
            return assemble_counts(numer, denom), dec, denom

        return CompiledNetwork(
            spec=spec, queries=queries, evidence=evidence, n_bits=n_bits,
            share_entropy=share_entropy, estimator=estimator, fused=True,
            query_cards=q_cards, _run=_run, _decide=_decide,
            n_shards=n_shards, shard_axes=shard_axes if mesh is not None else (),
            noise=noise, drift_epochs=drift_epochs, program=program,
        )

    def slot_indicators(streams):
        """Per-query per-value (1..k-1) indicator streams, slot order."""
        slots = []
        for q, c in zip(queries, q_cards):
            pls = streams[q]
            if c == 2:
                slots.append(pls[0])
            else:
                for v in range(1, c):
                    slots.append(bitops.digit_indicator(pls, v))
        return tuple(slots)

    def one_frame(ev, ev_planes, slot_streams):
        """ev (n_ev,); ev_planes: per-evidence plane tuples; slots (n_s, W)."""
        denom = jnp.broadcast_to(mask, mask.shape)
        for i in range(len(evidence)):
            for b, s in enumerate(ev_planes[i]):
                # value indicator, plane literal at a time (binary: the node
                # stream for e=1, its packed NOT for e=0)
                term = s ^ jnp.where(((ev[i] >> b) & 1) == 1, jnp.uint32(0), mask)
                denom = denom & term
        numer = jnp.stack(slot_streams) & denom[None, :]
        _, post = cordiv.cordiv_fill(numer, denom[None, :], n_bits)
        return post, bitops.popcount(denom)

    def ratio_batched(ev_frames, ev_planes, slot_streams):
        """Straight-line batched conditioning for the ratio estimator.

        Computes the popcounts of the acceptance stream ``one_frame`` builds
        and of each slot indicator ANDed with it, with indicators broadcast
        across the frame axis instead of per-frame ``vmap`` closures.  Plane
        arrays are (W,) shared or (B, W) independent.  Returns raw counts
        ``(numer (B, n_s), denom (B,))`` so the caller can assemble the
        posterior count-exactly.
        """
        b = ev_frames.shape[0]
        accept = jnp.broadcast_to(mask, (b, mask.shape[0]))
        for i in range(len(evidence)):
            for bit, s in enumerate(ev_planes[i]):
                s = s if s.ndim == 2 else s[None, :]
                ebit = (ev_frames[:, i : i + 1] >> bit) & 1
                ind = s ^ jnp.where(ebit == 1, jnp.uint32(0), mask[None, :])
                accept = accept & ind
        denom = bitops.popcount(accept)
        numer = jnp.stack(
            [
                bitops.popcount(accept & (s if s.ndim == 2 else s[None, :]))
                for s in slot_streams
            ],
            axis=-1,
        )
        return numer, denom

    assemble_counts = _count_assembler(q_cards)

    @jax.jit
    def _run(key, ev_frames):
        b = ev_frames.shape[0]
        streams = lower_streams(
            spec, key, n_bits, batch=None if share_entropy else b,
            mux_mode=mux_mode, noise=noise, program=program,
            use_kernel=use_kernel, interpret=interpret,
        )
        ev_planes = tuple(streams[e] for e in evidence)
        slots = slot_indicators(streams)
        if estimator == "ratio":
            # count-exact assembly, like the fused path: equal counts give
            # equal floats, so posterior_argmax ties break on the lowest
            # value here too (the fill path has no counts to assemble from)
            numer, denom = ratio_batched(ev_frames, ev_planes, slots)
            return assemble_counts(numer, denom), denom
        if share_entropy:
            post, denom = jax.vmap(one_frame, in_axes=(0, None, None))(
                ev_frames, ev_planes, slots
            )
        else:
            # independent entropy: every plane carries a leading frame axis
            post, denom = jax.vmap(one_frame)(ev_frames, ev_planes, slots)
        return assemble(post), denom

    @jax.jit
    def _decide(key, ev_frames):
        post, denom = _run(key, ev_frames)
        return post, posterior_argmax(post), denom

    return CompiledNetwork(
        spec=spec, queries=queries, evidence=evidence, n_bits=n_bits,
        share_entropy=share_entropy, estimator=estimator, fused=False,
        query_cards=q_cards, _run=_run, _decide=_decide, noise=noise,
        program=program,
    )
