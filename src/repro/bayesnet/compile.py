"""Compile a :class:`~repro.bayesnet.spec.NetworkSpec` to the packed domain.

Nodes are cardinality-``k`` categorical variables carried as ``value_bits(k)``
packed bit-plane streams (binary = the one-plane ``k=2`` special case, bit
identical to the pre-categorical lowering).  Two lowerings share the spec
language:

**Fused** (production default for independent entropy): the whole network --
per-node categorical threshold-gather sampling, evidence-indicator AND, CORDIV
popcount fixed point -- becomes ONE :func:`~repro.kernels.net_sweep.net_sweep`
launch.  Entropy is generated in-register from counter bit-planes with the
frame index folded into the counters (ONE byte per stream position regardless
of cardinality), so every frame draws an independent joint sample and node
streams never touch HBM.

**Unfused** (one op per node; the verification baseline, and the only path
for shared entropy or the ``fill`` estimator):

* binary roots     -> independent packed Bernoulli streams (``rng.encode_packed``).
* k-ary roots      -> ``rng.encode_packed_categorical`` (same entropy words,
  ``k-1`` comparisons, ``value_bits(k)`` planes).
* all-binary nodes -> the :func:`~repro.kernels.node_mux.node_mux` sweep
  (``mux_mode='gather'`` default; ``mux_mode='rows'`` is the original
  formulation kept as the binary statistical baseline).
* k-ary nodes (or binary nodes with k-ary parents)
                   -> :func:`~repro.kernels.node_mux.node_mux_categorical`:
  the parents' value digits gather the row's 8-bit DAC CDF, one entropy byte
  samples the k-way draw.
* queries          -> stochastic conditioning: per-evidence-node value
  indicators (AND of plane literals) are ANDed into the acceptance stream
  ``d``; each query *value* indicator ANDed with ``d`` is a bitwise subset of
  ``d`` by construction, so CORDIV's correlation discipline holds.
  ``estimator='ratio'`` uses the closed-form popcount fixed point;
  ``estimator='fill'`` runs the word-parallel ``cordiv_fill`` flip-flop
  circuit per value slot.

Posterior contract: when every query node is binary, ``run`` returns the
classic ``(B, n_q)`` array of ``P(q=1 | evidence)`` -- bit-identical to the
pre-categorical compiler.  When any query has ``k > 2``, ``run`` returns a
``(B, n_q, max_k)`` tensor of normalised per-value posteriors (rows of
queries with smaller cardinality are zero-padded).  ``decide`` reduces either
form to per-query argmax values through the fused ``bayes_decide`` op.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.bayesnet.spec import NetworkSpec
from repro.core import bitops, cordiv, rng
from repro.kernels.bayes_decide import bayes_decide
from repro.kernels.net_sweep import SweepPlan, net_sweep
from repro.kernels.node_mux.ops import node_mux, node_mux_categorical


def _posterior_from_counts(numer: jnp.ndarray, denom: jnp.ndarray) -> jnp.ndarray:
    """Per-frame posteriors from count arrays: numer (B, n_s), denom (B,)."""
    return cordiv.ratio_from_counts(numer, denom[:, None])


def _slot_assembler(q_cards: Tuple[int, ...]) -> Callable:
    """Build the slot-probabilities -> posterior map for a query card profile.

    Slots hold ``P(q = v | e)`` for values ``1 .. k-1`` per query, in query
    order.  All-binary queries keep the classic ``(B, n_q)`` layout (the slot
    array IS the posterior, bit-identical to the pre-categorical path);
    otherwise the slots fold into ``(B, n_q, max_k)`` with
    ``P(q = 0) = 1 - sum`` and zero padding past each query's cardinality.
    """
    if all(c == 2 for c in q_cards):
        return lambda slots: slots
    kmax = max(q_cards)

    def assemble(slots: jnp.ndarray) -> jnp.ndarray:
        cols = []
        off = 0
        for c in q_cards:
            v = slots[:, off : off + c - 1]
            off += c - 1
            s = jnp.sum(v, axis=-1, keepdims=True)
            p0 = jnp.clip(1.0 - s, 0.0, 1.0)
            parts = [p0, v]
            if kmax > c:
                parts.append(jnp.zeros(v.shape[:-1] + (kmax - c,), v.dtype))
            # Ratio-estimator slots are disjoint-bucket count fractions, so
            # s <= 1 exactly and the divisor is literally 1.0; the fill
            # estimator's slots are independent stochastic divisions whose
            # noise can push s past 1 -- rescale so the vector stays a
            # distribution either way.
            cols.append(jnp.concatenate(parts, axis=-1) / jnp.maximum(s, 1.0))
        return jnp.stack(cols, axis=1)

    return assemble


@dataclasses.dataclass(frozen=True)
class CompiledNetwork:
    """A network lowered to one jitted packed-stochastic program.

    ``run(key, ev_frames (B, n_ev) int) -> (post, accepted (B,))``: evidence
    values are integers in ``[0, card)`` per evidence node.  ``post`` is
    ``(B, n_q)`` of ``P(q=1 | evidence)`` when every query is binary, else
    ``(B, n_q, max(query_cards))`` of normalised per-value posteriors.
    ``accepted[b]`` is the number of stream positions that satisfied frame
    ``b``'s evidence -- the effective sample count, so callers can bound the
    noise as ``sigma ~ sqrt(p (1-p) / accepted)``.
    """

    spec: NetworkSpec
    queries: Tuple[str, ...]
    evidence: Tuple[str, ...]
    n_bits: int
    share_entropy: bool
    estimator: str
    fused: bool
    query_cards: Tuple[int, ...]
    _run: Callable = dataclasses.field(repr=False)

    def run(self, key: jax.Array, ev_frames) -> Tuple[jnp.ndarray, jnp.ndarray]:
        ev = jnp.asarray(ev_frames, jnp.int32)
        if ev.ndim != 2 or ev.shape[1] != len(self.evidence):
            raise ValueError(
                f"evidence frames must be (B, {len(self.evidence)}), got {ev.shape}"
            )
        return self._run(key, ev)

    def decide(
        self, key: jax.Array, ev_frames, decide_bits: int = 256
    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Per-frame argmax value for every query via the fused decision op.

        Runs the compiled program, re-encodes each query's posterior vector as
        packed streams, and lets :func:`~repro.kernels.bayes_decide` take the
        popcount argmax -- the stochastic decision layer the paper's output
        stage implements.  Returns ``(decisions (B, n_q) int32, accepted)``.
        """
        post, accepted = self.run(key, ev_frames)
        if post.ndim == 2:  # all-binary queries: (B, n_q) -> per-value vectors
            post = jnp.stack([1.0 - post, post], axis=-1)
        dec, _ = bayes_decide(
            jax.random.fold_in(key, 0x5EED), post[None], n_bits=decide_bits
        )
        return dec, accepted


def sweep_plan(
    spec: NetworkSpec,
    queries: Sequence[str],
    evidence: Sequence[str],
) -> SweepPlan:
    """Lower a spec to the static :class:`SweepPlan` the fused kernel consumes.

    Nodes are renumbered into topological order; each CPT row becomes its
    ``card - 1`` cumulative 8-bit DAC comparator thresholds
    (``rng.cdf_thresholds_int`` -- for binary nodes exactly the old
    ``round(p * 256)`` grid), so the fused sweep samples the identical
    quantised network every other encoder does.
    """
    order = spec.topo_order()
    index = {name: i for i, name in enumerate(order)}
    nodes = []
    for name in order:
        node = spec.node(name)
        rows = tuple(rng.cdf_thresholds_int(r) for r in spec.cpt_rows(name))
        nodes.append((tuple(index[p] for p in node.parents), spec.card(name), rows))
    return SweepPlan(
        nodes=tuple(nodes),
        evidence=tuple(index[e] for e in evidence),
        queries=tuple(index[q] for q in queries),
    )


def lower_streams(
    spec: NetworkSpec,
    key: jax.Array,
    n_bits: int,
    batch: int | None = None,
    *,
    mux_mode: str = "gather",
    use_kernel: bool | None = None,
    interpret: bool | None = None,
):
    """One topological sweep: name -> tuple of packed value bit-planes.

    Every entry is a ``value_bits(k)``-tuple of ``(W,)`` (or ``(B, W)``)
    packed words; a binary node's tuple holds its classic single stream.  The
    per-node subkey comes from ``fold_in(key, node index)``, so every node
    draws disjoint counter entropy while parents' planes are shared by all
    their children exactly once -- the correlation structure the joint sample
    requires.  Binary sub-networks draw entropy through exactly the
    pre-categorical code path, keeping their streams bit-identical.
    """
    order = spec.topo_order()
    streams = {}
    for i, name in enumerate(order):
        node = spec.node(name)
        card = spec.card(name)
        pcards = tuple(spec.card(p) for p in node.parents)
        sub = jax.random.fold_in(key, i)
        if not node.parents:
            if card == 2:
                p = jnp.float32(spec.cpt_rows(name)[0][1])
                if batch is not None:
                    p = jnp.full((batch,), p, jnp.float32)
                streams[name] = (rng.encode_packed(sub, p, n_bits),)
            else:
                cdf = rng.cdf_thresholds_int(spec.cpt_rows(name)[0])
                planes = rng.encode_packed_categorical(sub, cdf, n_bits, batch=batch)
                streams[name] = tuple(planes[b] for b in range(planes.shape[0]))
        elif card == 2 and all(c == 2 for c in pcards):
            cpt = jnp.asarray(
                tuple(r[1] for r in spec.cpt_rows(name)), jnp.float32
            )
            if batch is not None:
                cpt = jnp.broadcast_to(cpt, (batch,) + cpt.shape)
            parents = jnp.stack([streams[pn][0] for pn in node.parents])
            streams[name] = (
                node_mux(
                    sub, cpt, parents, n_bits, mode=mux_mode,
                    use_kernel=use_kernel, interpret=interpret,
                ),
            )
        else:
            cdf = jnp.asarray(
                tuple(rng.cdf_thresholds_int(r) for r in spec.cpt_rows(name)),
                jnp.uint32,
            )
            if batch is not None:
                cdf = jnp.broadcast_to(cdf, (batch,) + cdf.shape)
            parents = jnp.stack(
                [pl for pn in node.parents for pl in streams[pn]]
            )
            planes = node_mux_categorical(
                sub, cdf, parents, cards=(card,) + pcards, n_bits=n_bits,
                use_kernel=use_kernel, interpret=interpret,
            )
            streams[name] = tuple(planes[b] for b in range(planes.shape[0]))
    return streams


def compile_network(
    spec: NetworkSpec,
    n_bits: int = 4096,
    queries: Sequence[str] | None = None,
    evidence: Sequence[str] | None = None,
    *,
    share_entropy: bool = False,
    estimator: str = "ratio",
    fused: bool | None = None,
    mux_mode: str = "gather",
    use_kernel: bool | None = None,
    interpret: bool | None = None,
) -> CompiledNetwork:
    """Lower ``spec`` to a jitted, frame-batched packed-stochastic program.

    ``fused=None`` auto-selects: the one-launch ``net_sweep`` path whenever it
    applies (independent entropy + ratio estimator -- the production mode),
    the per-node unfused path otherwise.  ``fused=False`` forces the unfused
    program, the statistical verification baseline for the fused kernel.
    """
    queries = tuple(queries if queries is not None else spec.queries)
    evidence = tuple(evidence if evidence is not None else spec.evidence)
    if not queries:
        raise ValueError(f"{spec.name}: no query nodes")
    if estimator not in ("ratio", "fill"):
        raise ValueError(f"unknown estimator {estimator!r}")
    if n_bits % 32:
        raise ValueError("n_bits must be a multiple of 32 (packed words)")
    if mux_mode not in ("gather", "rows"):
        raise ValueError(f"unknown mux_mode {mux_mode!r}")
    if mux_mode == "rows" and spec.max_card() > 2:
        raise ValueError(
            "mux_mode='rows' (the binary row-encode baseline) does not "
            "support k-ary nodes; use the default 'gather'"
        )
    q_cards = tuple(spec.card(q) for q in queries)
    assemble = _slot_assembler(q_cards)
    # The fused sweep samples with threshold-gather by construction, so a
    # non-default mux_mode is an explicit request for the unfused per-node
    # lowering -- auto-resolution honours it instead of silently ignoring it.
    fusable = not share_entropy and estimator == "ratio" and mux_mode == "gather"
    if fused is None:
        fused = fusable
    elif fused and not fusable:
        raise ValueError(
            "fused lowering requires share_entropy=False, estimator='ratio' "
            f"and mux_mode='gather' (got share_entropy={share_entropy}, "
            f"estimator={estimator!r}, mux_mode={mux_mode!r})"
        )
    mask = bitops.pad_mask(n_bits)

    if fused:
        plan = sweep_plan(spec, queries, evidence)

        @jax.jit
        def _run(key, ev_frames):
            numer, denom = net_sweep(
                key, ev_frames, plan=plan, n_bits=n_bits,
                use_kernel=use_kernel, interpret=interpret,
            )
            return assemble(_posterior_from_counts(numer, denom)), denom

        return CompiledNetwork(
            spec=spec, queries=queries, evidence=evidence, n_bits=n_bits,
            share_entropy=share_entropy, estimator=estimator, fused=True,
            query_cards=q_cards, _run=_run,
        )

    def slot_indicators(streams):
        """Per-query per-value (1..k-1) indicator streams, slot order."""
        slots = []
        for q, c in zip(queries, q_cards):
            pls = streams[q]
            if c == 2:
                slots.append(pls[0])
            else:
                for v in range(1, c):
                    slots.append(bitops.digit_indicator(pls, v))
        return tuple(slots)

    def one_frame(ev, ev_planes, slot_streams):
        """ev (n_ev,); ev_planes: per-evidence plane tuples; slots (n_s, W)."""
        denom = jnp.broadcast_to(mask, mask.shape)
        for i in range(len(evidence)):
            for b, s in enumerate(ev_planes[i]):
                # value indicator, plane literal at a time (binary: the node
                # stream for e=1, its packed NOT for e=0)
                term = s ^ jnp.where(((ev[i] >> b) & 1) == 1, jnp.uint32(0), mask)
                denom = denom & term
        numer = jnp.stack(slot_streams) & denom[None, :]
        _, post = cordiv.cordiv_fill(numer, denom[None, :], n_bits)
        return post, bitops.popcount(denom)

    def ratio_batched(ev_frames, ev_planes, slot_streams):
        """Straight-line batched conditioning for the ratio estimator.

        Computes ``cordiv_ratio`` -- popcount(numer) / popcount(denom) over
        the same acceptance stream ``one_frame`` builds -- with indicators
        broadcast across the frame axis instead of per-frame ``vmap``
        closures.  Plane arrays are (W,) shared or (B, W) independent.
        """
        b = ev_frames.shape[0]
        accept = jnp.broadcast_to(mask, (b, mask.shape[0]))
        for i in range(len(evidence)):
            for bit, s in enumerate(ev_planes[i]):
                s = s if s.ndim == 2 else s[None, :]
                ebit = (ev_frames[:, i : i + 1] >> bit) & 1
                ind = s ^ jnp.where(ebit == 1, jnp.uint32(0), mask[None, :])
                accept = accept & ind
        denom = bitops.popcount(accept)
        numer = jnp.stack(
            [
                bitops.popcount(accept & (s if s.ndim == 2 else s[None, :]))
                for s in slot_streams
            ],
            axis=-1,
        )
        return _posterior_from_counts(numer, denom), denom

    @jax.jit
    def _run(key, ev_frames):
        b = ev_frames.shape[0]
        streams = lower_streams(
            spec, key, n_bits, batch=None if share_entropy else b,
            mux_mode=mux_mode, use_kernel=use_kernel, interpret=interpret,
        )
        ev_planes = tuple(streams[e] for e in evidence)
        slots = slot_indicators(streams)
        if estimator == "ratio":
            post, denom = ratio_batched(ev_frames, ev_planes, slots)
            return assemble(post), denom
        if share_entropy:
            post, denom = jax.vmap(one_frame, in_axes=(0, None, None))(
                ev_frames, ev_planes, slots
            )
        else:
            # independent entropy: every plane carries a leading frame axis
            post, denom = jax.vmap(one_frame)(ev_frames, ev_planes, slots)
        return assemble(post), denom

    return CompiledNetwork(
        spec=spec, queries=queries, evidence=evidence, n_bits=n_bits,
        share_entropy=share_entropy, estimator=estimator, fused=False,
        query_cards=q_cards, _run=_run,
    )
