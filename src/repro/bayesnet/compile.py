"""Compile a :class:`~repro.bayesnet.spec.NetworkSpec` to the packed domain.

Lowering (one pass over the topological order):

* root nodes      -> independent packed Bernoulli streams (``rng.encode_packed``,
  the counter-entropy SNE).
* non-root nodes  -> the :func:`~repro.kernels.node_mux.node_mux` sweep: the
  ``2**m`` CPT rows are encoded with fresh entropy and routed through the
  value-select MUX tree keyed by the parents' packed streams.  At every bit
  position the vector of all node bits is then an exact joint sample of the
  network -- the n-ary generalisation of the Fig S8 motifs.
* queries         -> stochastic conditioning: the evidence indicator streams
  (a node stream, or its packed NOT for evidence value 0) are ANDed into the
  acceptance stream ``d``; each query's numerator is ``d AND S_q``, a bitwise
  subset of ``d`` by construction, so CORDIV's correlation discipline holds
  with no superset completion.  ``estimator='ratio'`` uses the closed-form
  ``cordiv_ratio`` popcount fixed point (the production path);
  ``estimator='fill'`` runs the word-parallel ``cordiv_fill`` flip-flop
  circuit (bit-faithful to the serial divider).

The compiled program is one jitted function, ``vmap``-batched over evidence
frames.  With ``share_entropy=True`` (default) the node streams are built once
per launch and every frame conditions the *same* joint sample -- per-frame
posteriors stay unbiased and thousands of frames cost little more than one.
``share_entropy=False`` folds the frame index into the entropy counters so
every frame gets an independent joint sample (independent errors across
frames, ~B x the encode work).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.bayesnet.spec import NetworkSpec
from repro.core import bitops, cordiv, rng
from repro.kernels.node_mux.ops import node_mux


@dataclasses.dataclass(frozen=True)
class CompiledNetwork:
    """A network lowered to one jitted packed-stochastic program.

    ``run(key, ev_frames (B, n_ev) int) -> (post (B, n_q), accepted (B,))``:
    ``post[b, q]`` estimates ``P(queries[q]=1 | evidence = ev_frames[b])`` and
    ``accepted[b]`` is the number of stream bits that satisfied frame ``b``'s
    evidence -- the effective sample count, so callers can bound the noise as
    ``sigma ~ sqrt(p (1-p) / accepted)``.
    """

    spec: NetworkSpec
    queries: Tuple[str, ...]
    evidence: Tuple[str, ...]
    n_bits: int
    share_entropy: bool
    estimator: str
    _run: Callable = dataclasses.field(repr=False)

    def run(self, key: jax.Array, ev_frames) -> Tuple[jnp.ndarray, jnp.ndarray]:
        ev = jnp.asarray(ev_frames, jnp.int32)
        if ev.ndim != 2 or ev.shape[1] != len(self.evidence):
            raise ValueError(
                f"evidence frames must be (B, {len(self.evidence)}), got {ev.shape}"
            )
        return self._run(key, ev)


def lower_streams(
    spec: NetworkSpec,
    key: jax.Array,
    n_bits: int,
    batch: int | None = None,
    *,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
):
    """One topological sweep: name -> packed stream ((W,) or (B, W)).

    The per-node subkey comes from ``fold_in(key, node index)``, so every CPT
    row of every node draws disjoint counter entropy while parents' streams are
    shared by all their children exactly once -- the correlation structure the
    joint sample requires.
    """
    order = spec.topo_order()
    streams = {}
    for i, name in enumerate(order):
        node = spec.node(name)
        sub = jax.random.fold_in(key, i)
        if not node.parents:
            p = jnp.float32(node.cpt[0])
            if batch is not None:
                p = jnp.full((batch,), p, jnp.float32)
            streams[name] = rng.encode_packed(sub, p, n_bits)
        else:
            cpt = jnp.asarray(node.cpt, jnp.float32)
            if batch is not None:
                cpt = jnp.broadcast_to(cpt, (batch,) + cpt.shape)
            parents = jnp.stack([streams[pn] for pn in node.parents])
            streams[name] = node_mux(
                sub, cpt, parents, n_bits,
                use_kernel=use_kernel, interpret=interpret,
            )
    return streams


def compile_network(
    spec: NetworkSpec,
    n_bits: int = 4096,
    queries: Sequence[str] | None = None,
    evidence: Sequence[str] | None = None,
    *,
    share_entropy: bool = True,
    estimator: str = "ratio",
    use_kernel: bool | None = None,
    interpret: bool | None = None,
) -> CompiledNetwork:
    """Lower ``spec`` to a jitted, frame-batched packed-stochastic program."""
    queries = tuple(queries if queries is not None else spec.queries)
    evidence = tuple(evidence if evidence is not None else spec.evidence)
    if not queries:
        raise ValueError(f"{spec.name}: no query nodes")
    if estimator not in ("ratio", "fill"):
        raise ValueError(f"unknown estimator {estimator!r}")
    if n_bits % 32:
        raise ValueError("n_bits must be a multiple of 32 (packed words)")
    mask = bitops.pad_mask(n_bits)

    def one_frame(ev, ev_streams, q_streams):
        """ev (n_ev,), ev_streams (n_ev, W), q_streams (n_q, W)."""
        denom = jnp.broadcast_to(mask, q_streams.shape[-1:])
        for i in range(len(evidence)):
            # indicator: the node stream for e=1, its packed NOT for e=0
            ind = ev_streams[i] ^ jnp.where(ev[i] == 1, jnp.uint32(0), mask)
            denom = denom & ind
        numer = q_streams & denom[None, :]
        if estimator == "fill":
            _, post = cordiv.cordiv_fill(numer, denom[None, :], n_bits)
        else:
            post = cordiv.cordiv_ratio(numer, denom[None, :])
        return post, bitops.popcount(denom)

    @jax.jit
    def _run(key, ev_frames):
        b = ev_frames.shape[0]
        streams = lower_streams(
            spec, key, n_bits, batch=None if share_entropy else b,
            use_kernel=use_kernel, interpret=interpret,
        )
        ev_s = jnp.stack([streams[e] for e in evidence]) if evidence else \
            jnp.zeros((0,) + next(iter(streams.values())).shape, jnp.uint32)
        q_s = jnp.stack([streams[q] for q in queries])
        if share_entropy:
            return jax.vmap(one_frame, in_axes=(0, None, None))(ev_frames, ev_s, q_s)
        # independent entropy: streams carry a leading frame axis
        ev_s = jnp.moveaxis(ev_s, 1, 0)                  # (B, n_ev, W)
        q_s = jnp.moveaxis(q_s, 1, 0)                    # (B, n_q, W)
        return jax.vmap(one_frame)(ev_frames, ev_s, q_s)

    return CompiledNetwork(
        spec=spec, queries=queries, evidence=evidence, n_bits=n_bits,
        share_entropy=share_entropy, estimator=estimator, _run=_run,
    )
