"""Crossbar non-ideality model: plan-build-time perturbation of DAC thresholds.

The compiled networks are mathematically exact today: every CPT row becomes
its 8-bit cumulative DAC thresholds and the comparator fires on *exactly*
those integers.  The physical crossbar does not work like that -- each
threshold is a programmed conductance read through a resistive line, and the
paper's own device characterisation (:mod:`repro.core.device`) quantifies how
far reality sits from the integer grid.  This module makes that spread a
first-class compile input: a :class:`NoiseModel` deterministically perturbs
the integer CDF thresholds of every (node, CPT row, level) "device" at
plan-build time, so the SAME perturbed network flows into the fused
``net_sweep`` plan, the unfused per-node lowering, and the enumeration oracle
(:func:`repro.bayesnet.analytic.make_posterior_fn` with ``noise=``) -- which
keeps 3-sigma agreement tests exact under noise.

Four non-ideality terms, applied in the conductance (multiplicative) domain
then snapped back to the integer grid:

* **device-to-device spread** -- lognormal conductance factor with CV
  ``d2d_cv`` (paper Fig 1d: ~8 %), seeded per device from the model's
  ``seed``; the factor is a property of the *device*, so it does not change
  with ``cycle``.
* **cycle-to-cycle read noise** -- lognormal factor with CV ``read_cv``
  (derived in :class:`~repro.core.device.MemristorParams.read_cv` from the
  paper's V_th trajectory: stationary CV attenuated by the ~80 switching
  cycles one encoded bit integrates).  Seeded per (device, ``cycle``): the
  perturbation is a *frozen snapshot* of one read epoch, which is what lets
  the oracle twin enumerate the perturbed network exactly; re-draw with
  :meth:`NoiseModel.with_cycle` to model drift across launches.
* **line-resistance IR drop** -- deterministic position-dependent droop: the
  further a device sits along the word/bit lines, the more of the programming
  voltage the line eats, scaling its effective threshold down by up to
  ``ir_drop`` at the far corner of the array (node index = wordline, flat
  row x level index = bitline).
* **stuck-at faults** -- with probability ``p_stuck_on`` / ``p_stuck_off``
  per device, the threshold pins to 256 (always fires) / 0 (never fires),
  the endurance-tail failure mode (paper: > 1e6 cycles, so the nominal
  budget is small but non-zero).

All randomness comes from a dependency-free numpy lowbias32 hash keyed by
``(seed, cycle, crc32(node name), device index)`` -- no global RNG state, no
jax tracing, bit-stable across platforms -- and node identity is the node
*name*, so the same device draws the same fault regardless of which path
(fused plan, unfused streams, oracle) asks.  Perturbed rows are re-clipped to
``[0, 256]`` and re-monotonised (non-increasing cummin) so they remain valid
CDF rows for the bit-sliced comparator.

``NoiseModel()`` is the paper-nominal model; ``NoiseModel.zero()`` (or
``scaled(0.0)``) perturbs nothing and returns the clean thresholds exactly.
"""

from __future__ import annotations

import dataclasses
import math
import zlib
from typing import Dict, Tuple

import numpy as np

from repro.bayesnet.spec import NetworkSpec
from repro.core import rng
from repro.core.device import DEFAULT_PARAMS, MemristorParams, wear_scale

_U32 = np.uint32


def _lowbias32(x: np.ndarray) -> np.ndarray:
    """Full-avalanche 32-bit hash (numpy twin of :func:`repro.core.rng._lowbias32`)."""
    x = x.astype(np.uint32, copy=True)
    with np.errstate(over="ignore"):
        x ^= x >> _U32(16)
        x *= _U32(0x7FEB352D)
        x ^= x >> _U32(15)
        x *= _U32(0x846CA68B)
        x ^= x >> _U32(16)
    return x


def _fold(*words: int) -> int:
    """Chain ints into one 32-bit key (order-sensitive, avalanche per step)."""
    h = np.zeros((), np.uint32)
    for w in words:
        h = _lowbias32(h ^ _U32(w & 0xFFFFFFFF))[()]
    return int(h)


def _uniforms(key: int, counters: np.ndarray) -> np.ndarray:
    """Deterministic uniform(0, 1) draws, one per counter (never exactly 0)."""
    h = _lowbias32(counters.astype(np.uint32) ^ _U32(key & 0xFFFFFFFF))
    return (h.astype(np.float64) + 0.5) / 2.0**32


def _normals(key: int, counters: np.ndarray) -> np.ndarray:
    """Deterministic standard normals via Box-Muller over two hashed streams."""
    u1 = _uniforms(key, counters)
    u2 = _uniforms(key ^ 0x9E3779B9, counters)
    return np.sqrt(-2.0 * np.log(u1)) * np.cos(2.0 * np.pi * u2)


@dataclasses.dataclass(frozen=True)
class NoiseModel:
    """Deterministic crossbar non-ideality model (hashable compile input).

    Defaults are the paper-calibrated nominal values: ``d2d_cv`` comes
    straight from :data:`~repro.core.device.DEFAULT_PARAMS` (the 8 %
    device-to-device V_th CV of Fig 1d) and ``read_cv`` from its derived
    per-read attenuation -- a test pins both so the calibration cannot
    silently drift from the device model.  ``seed`` selects the fabricated
    array instance (which devices are weak/stuck); ``cycle`` selects the
    read-noise epoch within that instance.
    """

    d2d_cv: float = DEFAULT_PARAMS.d2d_cv
    read_cv: float = DEFAULT_PARAMS.read_cv
    ir_drop: float = 0.02
    p_stuck_on: float = 5e-4
    p_stuck_off: float = 5e-4
    seed: int = 0
    cycle: int = 0
    # Endurance-wear time constant in read epochs: the effective read CV at
    # epoch c is read_cv * wear_scale(c, wear_tau) -- derived, not ad hoc
    # (:attr:`~repro.core.device.MemristorParams.wear_tau_epochs`).
    wear_tau: float = DEFAULT_PARAMS.wear_tau_epochs

    def __post_init__(self):
        for f in ("d2d_cv", "read_cv", "ir_drop", "p_stuck_on", "p_stuck_off"):
            v = float(getattr(self, f))
            if not 0.0 <= v or not math.isfinite(v):
                raise ValueError(f"NoiseModel.{f} must be finite and >= 0, got {v}")
            object.__setattr__(self, f, v)
        if self.ir_drop >= 1.0:
            raise ValueError(f"ir_drop {self.ir_drop} >= 1 inverts thresholds")
        if self.p_stuck_on + self.p_stuck_off > 1.0:
            raise ValueError("p_stuck_on + p_stuck_off > 1")
        wt = float(self.wear_tau)
        if not wt > 0.0 or not math.isfinite(wt):
            raise ValueError(f"NoiseModel.wear_tau must be finite and > 0, got {wt}")
        object.__setattr__(self, "wear_tau", wt)
        object.__setattr__(self, "seed", int(self.seed))
        object.__setattr__(self, "cycle", int(self.cycle))

    # ---------------------------------------------------------- constructors
    @classmethod
    def nominal(
        cls, params: MemristorParams = DEFAULT_PARAMS, seed: int = 0, cycle: int = 0
    ) -> "NoiseModel":
        """Paper-calibrated model from a device-parameter set."""
        return cls(d2d_cv=params.d2d_cv, read_cv=params.read_cv,
                   seed=seed, cycle=cycle, wear_tau=params.wear_tau_epochs)

    @classmethod
    def zero(cls, seed: int = 0) -> "NoiseModel":
        """The identity model: perturbs nothing, thresholds stay exact."""
        return cls(d2d_cv=0.0, read_cv=0.0, ir_drop=0.0,
                   p_stuck_on=0.0, p_stuck_off=0.0, seed=seed)

    def scaled(self, m: float) -> "NoiseModel":
        """Every non-ideality magnitude scaled by ``m`` (sweep axis helper)."""
        m = float(m)
        return dataclasses.replace(
            self, d2d_cv=self.d2d_cv * m, read_cv=self.read_cv * m,
            ir_drop=self.ir_drop * m, p_stuck_on=self.p_stuck_on * m,
            p_stuck_off=self.p_stuck_off * m,
        )

    def with_cycle(self, cycle: int) -> "NoiseModel":
        """Same array instance, fresh read-noise epoch (d2d/stuck unchanged)."""
        return dataclasses.replace(self, cycle=int(cycle))

    @property
    def is_zero(self) -> bool:
        return (self.d2d_cv == 0.0 and self.read_cv == 0.0 and self.ir_drop == 0.0
                and self.p_stuck_on == 0.0 and self.p_stuck_off == 0.0)

    def read_cv_at(self, cycle: int | None = None) -> float:
        """Effective read CV at ``cycle`` (default: this model's own cycle).

        The calibrated fresh-device ``read_cv`` grows with endurance wear as
        ``wear_scale(cycle, wear_tau)`` (:mod:`repro.core.device`): exactly
        ``read_cv`` at cycle 0, doubling in variance every ``wear_tau``
        epochs.  This is the only cycle-dependent *magnitude* in the model --
        the d2d spread, IR droop, and stuck map are properties of the array,
        not of the epoch.
        """
        c = self.cycle if cycle is None else int(cycle)
        return self.read_cv * wear_scale(c, self.wear_tau)

    # ------------------------------------------------------------ perturbation
    def error_factors(
        self, name: str, l: int, k1: int, node_pos: int, n_nodes: int
    ) -> np.ndarray:
        """The ``(l, k1)`` multiplicative conductance error of one node's array.

        The deterministic part of the perturbation -- d2d lognormal x
        wear-scaled read lognormal x IR droop -- BEFORE grid rounding, stuck
        faults, and re-monotonisation.  Exposed separately so calibrate-back
        (:mod:`repro.bayesnet.calibrate`) can divide it out of the programmed
        thresholds: ``perturb_rows(rows / factors) ~ rows`` up to one DAC step
        plus the stuck devices nothing can compensate.
        """
        f = np.ones((l, k1), np.float64)
        if l * k1 == 0:
            return f
        dev = np.arange(l * k1, dtype=np.uint32).reshape(l, k1)
        nh = zlib.crc32(name.encode("utf-8"))
        if self.d2d_cv > 0.0:
            sg = math.sqrt(math.log1p(self.d2d_cv**2))
            dev_key = _fold(self.seed, nh, 0x0D2D)
            f = f * np.exp(sg * _normals(dev_key, dev) - 0.5 * sg * sg)
        rc = self.read_cv_at()
        if rc > 0.0:
            sr = math.sqrt(math.log1p(rc**2))
            read_key = _fold(self.seed, nh, 0x0C2C, self.cycle)
            f = f * np.exp(sr * _normals(read_key, dev) - 0.5 * sr * sr)
        if self.ir_drop > 0.0:
            # Word/bit-line voltage divider: devices further down either line
            # see less of the programming voltage; linear droop per axis,
            # worst case (far corner) = 1 - ir_drop.
            word = (node_pos + 1) / max(n_nodes, 1)
            bit = (dev.astype(np.float64) + 1.0) / float(l * k1)
            f = f * (1.0 - self.ir_drop * 0.5 * (word + bit))
        return f

    def perturb_rows(
        self,
        name: str,
        clean_rows: Tuple[Tuple[int, ...], ...],
        node_pos: int,
        n_nodes: int,
    ) -> Tuple[Tuple[int, ...], ...]:
        """Perturb one node's integer CDF rows; returns valid CDF rows.

        ``clean_rows``: ``(L, card-1)`` cumulative thresholds in ``[0, 256]``
        (:func:`repro.core.rng.cdf_thresholds_int` output).  Each threshold is
        one physical device at wordline ``node_pos`` (of ``n_nodes``) and
        bitline ``row * (card-1) + level``; its perturbed value is a pure
        function of ``(seed, cycle, name, device index)``.
        """
        t = np.asarray(clean_rows, np.float64)
        if t.size == 0:
            return tuple(tuple(r) for r in clean_rows)
        if self.is_zero:
            return tuple(tuple(int(x) for x in row) for row in clean_rows)
        l, k1 = t.shape
        out = t * self.error_factors(name, l, k1, node_pos, n_nodes)
        out = np.clip(np.rint(out), 0.0, 256.0)
        if self.p_stuck_on > 0.0 or self.p_stuck_off > 0.0:
            dev = np.arange(l * k1, dtype=np.uint32).reshape(l, k1)
            stuck_key = _fold(self.seed, zlib.crc32(name.encode("utf-8")), 0x057C)
            u = _uniforms(stuck_key, dev)
            out = np.where(u < self.p_stuck_on, 256.0, out)
            out = np.where(
                (u >= self.p_stuck_on) & (u < self.p_stuck_on + self.p_stuck_off),
                0.0, out,
            )
        # Re-monotonise: cumulative tails must be non-increasing for the
        # nested comparator chains (a stuck-on device saturates every deeper
        # level's ceiling; a stuck-off one floors the shallower levels' tail).
        out = np.minimum.accumulate(out, axis=1)
        return tuple(tuple(int(x) for x in row) for row in out)


def _sanitize_rows(rows) -> Tuple[Tuple[int, ...], ...]:
    """Clip to the DAC grid and re-monotonise programmed rows (no noise)."""
    t = np.asarray(rows, np.float64)
    if t.size == 0:
        return tuple(tuple(int(x) for x in r) for r in rows)
    t = np.minimum.accumulate(np.clip(np.rint(t), 0.0, 256.0), axis=1)
    return tuple(tuple(int(x) for x in row) for row in t)


def perturbed_cdf_rows(
    spec: NetworkSpec,
    noise: NoiseModel | None,
    program: Dict[str, Tuple[Tuple[int, ...], ...]] | None = None,
) -> Dict[str, Tuple[Tuple[int, ...], ...]]:
    """Perturbed integer CDF rows for every node of ``spec``, keyed by name.

    The single source of truth consumed by all three backends: the fused
    :func:`~repro.bayesnet.compile.sweep_plan`, the unfused
    :func:`~repro.bayesnet.compile.lower_streams`, and the oracle twin
    (:func:`~repro.bayesnet.analytic.make_posterior_fn` with ``noise=``).
    Wordline positions follow topological order (the fused plan's node
    numbering), but the random draws key on the node *name*, so any caller
    iterating in any order sees the identical perturbed array.

    ``program`` optionally overrides the *programmed* thresholds of named
    nodes before perturbation -- the calibrate-back hook: a compensated
    program divides the deterministic error factors out so the perturbed
    array lands back on the intended grid.  Nodes absent from ``program``
    use the clean spec thresholds; with ``noise=None`` the programmed rows
    are returned as-is (clipped / re-monotonised).
    """
    order = spec.topo_order()
    out: Dict[str, Tuple[Tuple[int, ...], ...]] = {}
    for pos, name in enumerate(order):
        if program is not None and name in program:
            base = tuple(tuple(int(t) for t in r) for r in program[name])
        else:
            base = tuple(rng.cdf_thresholds_int(r) for r in spec.cpt_rows(name))
        if noise is None:
            out[name] = _sanitize_rows(base)
        else:
            out[name] = noise.perturb_rows(name, base, pos, len(order))
    return out
