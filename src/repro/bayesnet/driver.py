"""Streaming frame driver: serve-style batching for compiled networks.

Mirrors the LM serving engine's admission discipline on the bayesnet side:
frames are submitted at any time into a pending queue, and every ``step``
packs up to ``max_batch`` of them, runs the compiled program once, and
returns per-request posteriors.  Launch shapes are drawn from a small ladder
of power-of-two *buckets* (1, 2, 4, ... max_batch): a short batch pads up to
the nearest bucket by repeating its last real frame instead of always paying
the full ``max_batch`` lanes, so a 1-frame step on a 1024-lane driver costs
one frame's entropy, not ~1024x.  Padded lanes are dropped at harvest; each
bucket compiles once and is reused for every launch of that shape.

With the fused independent-entropy default (``compile_network``'s production
mode) every frame in a launch carries its own joint sample, so batch-mates
never share errors.  The driver also sequences launch keys itself: pass
``key=None`` to ``step`` / ``drain`` and each launch folds a monotonically
increasing launch counter into the driver's base key, so successive launches
draw disjoint entropy without the caller threading PRNG state.

**Async mode.**  ``step(block=False)`` dispatches the launch and returns
immediately with its ticket: jax's async dispatch runs the device work while
the driver packs and dispatches the next batch, and nothing calls
``block_until_ready`` until ``harvest()`` converts the posteriors to host
arrays.  ``drain_async`` pipelines the whole queue this way -- every launch
in flight back-to-back, one synchronisation at the end.  The launch-counter
key sequencing makes this safe: tickets are assigned at dispatch in
submission order, so async results map to rids exactly as sync results do,
and a sync and an async driver with the same ``(base_key, salt)`` return
bit-identical posteriors.

Every driver additionally folds a ``salt`` into its base key.  ``salt=None``
(the default) takes the next value of a process-wide driver counter, so two
drivers constructed with defaults -- the footgun the old ``PRNGKey(0)``
default base key armed -- no longer draw bit-identical joint samples per
launch index.  Pass an explicit ``salt`` (a driver id) to make a driver's key
sequence reproducible across processes/restarts: drivers with the same
``(base_key, salt)`` replay the same launches, drivers differing in either
draw disjoint entropy.

**Confidence-gated retry.**  ``retry=RetryPolicy(...)`` makes reliability a
measured, acted-on property: every harvested frame gets a decision-margin
confidence (:func:`~repro.bayesnet.reliability.decision_confidence`), and
frames below ``min_confidence`` are re-queued for a fresh launch -- new
entropy via the launch counter, ``escalation``-times longer bitstream per
attempt (escalated programs compile lazily, once per attempt level, and are
cached like buckets).  After ``max_retries`` the frame is emitted anyway with
``reliable=False`` -- graceful degradation, never a dropped frame.  Results
keep the legacy ``{rid: (post, accepted)}`` shape; per-frame verdicts land in
``driver.reports[rid]`` (:class:`~repro.bayesnet.reliability.FrameReport`)
and aggregates in ``driver.stats``
(:class:`~repro.bayesnet.reliability.ReliabilityStats`).  With retry enabled
a ``step`` may dispatch several launches (one per pending attempt level plus
the main batch); an explicit ``key`` is folded with the launch index within
the step.  ``retry=None`` (default) is behaviour-identical to the
pre-reliability driver.

**Launch watchdog.**  Every dispatch's wall time feeds a
:class:`~repro.distributed.fault.StragglerWatch` EWMA (the train-loop
straggler detector, reused verbatim): dispatches slower than ``threshold x``
the running mean -- a recompile for a new bucket shape, a contended device,
host-side stalls -- are counted in ``stats.slow_launches``.  Under async
dispatch the wall time covers trace/compile + enqueue, which is exactly the
host-side latency a serving deployment cares about.

**Telemetry.**  ``trace=Tracer()`` / ``metrics=MetricsRegistry()``
(:mod:`repro.obs`) light up the whole serving path with zero behaviour
change -- the traced driver's posteriors are bit-identical to the untraced
one's (a regression-tested property, like the <=5% overhead bound).  Each
launch becomes a span tree honouring jax's async dispatch: a ``launch[n]``
parent span from dispatch to harvest, ``pack`` and ``dispatch`` sync child
spans for the host-side work, a ``device`` child opened when the dispatch
call returns and closed only when :meth:`harvest` first blocks on the result
(overlapping ``device`` spans in the exported trace ARE the async pipeline),
and a ``harvest`` child for host-side conversion + confidence gating.
Retried frames get ``retry[rid]`` spans nested under the launch that flagged
them, covering the wait until their re-launch's verdict.  The registry
counts frames in/out, launches, per-bucket launch shapes, padded lanes,
retry attempts per rung, flagged-unreliable emissions, escalated-plan cache
hits/misses, and entropy words generated, and feeds ``frame_ms`` (enqueue ->
emit, annotated with the paper's 0.4 ms budget) and ``launch_ms``
(dispatch -> harvest) histograms; the watchdog writes into the same registry.
``trace=None`` (default) leaves every hot path untouched.
"""

from __future__ import annotations

import itertools
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.bayesnet.compile import CompiledNetwork, compile_network
from repro.bayesnet.reliability import (
    FrameReport,
    ReliabilityStats,
    RetryPolicy,
    decision_confidence,
)
from repro.distributed.fault import StragglerWatch
from repro.obs import PAPER_BUDGET_MS, MetricsRegistry, Tracer

# Process-wide source of default driver salts (one per construction).
_DRIVER_IDS = itertools.count()


class FrameDriver:
    def __init__(
        self,
        net: CompiledNetwork,
        max_batch: int = 256,
        base_key: jax.Array | None = None,
        salt: int | None = None,
        retry: RetryPolicy | None = None,
        watchdog: StragglerWatch | None = None,
        trace: Tracer | None = None,
        metrics: MetricsRegistry | None = None,
    ):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        if retry is not None and not isinstance(retry, RetryPolicy):
            raise TypeError(f"retry must be a RetryPolicy or None, got {type(retry)!r}")
        self.net = net
        self.max_batch = int(max_batch)
        self.retry = retry
        self._queue: deque = deque()
        self._next_rid = 0
        self.salt = next(_DRIVER_IDS) if salt is None else int(salt)
        base = base_key if base_key is not None else jax.random.PRNGKey(0)
        self._base_key = jax.random.fold_in(base, self.salt)
        self._launches = 0
        self._dispatches = 0
        # dispatched-but-unharvested launches, in dispatch order:
        # (ticket, taken (rid, row, attempt, bits_before) tuples,
        #  attempt level, device posteriors, device accepted counts,
        #  launch span id | None, device span id | None,
        #  dispatch wall-clock | None)
        self._inflight: deque = deque()
        self.last_launch_shape: Optional[Tuple[int, int]] = None
        # --- telemetry (inert when both are None) ---
        self.trace = trace
        if metrics is None and trace is not None:
            metrics = MetricsRegistry()   # spans without counters are half a story
        self.metrics = metrics
        self._t_submit: Dict[int, float] = {}     # rid -> enqueue wall-clock
        self._retry_spans: Dict[int, int] = {}    # rid -> open retry span id
        # --- reliability layer (inert when retry is None) ---
        self._nets: Dict[int, CompiledNetwork] = {0: net}
        self._retry_q: deque = deque()   # (rid, row, attempt, bits_before)
        self.reports: Dict[int, FrameReport] = {}
        self.stats = ReliabilityStats()
        self.watch = (
            watchdog if watchdog is not None else StragglerWatch(metrics=metrics)
        )

    # ------------------------------------------------------------- admission
    def submit(self, frames) -> List[int]:
        """Queue evidence frames ((n_ev,) each, or an (N, n_ev) array); returns rids."""
        frames = np.asarray(frames, np.int32)
        if frames.ndim == 1:
            frames = frames[None, :]
        assert frames.shape[1] == len(self.net.evidence), frames.shape
        rids = []
        for row in frames:
            rid = self._next_rid
            self._next_rid += 1
            self._queue.append((rid, row))
            rids.append(rid)
        if self.metrics is not None:
            now = time.perf_counter()
            for rid in rids:
                self._t_submit[rid] = now
            self.metrics.inc("frames_in", len(rids))
            self.metrics.set_gauge("pending", len(self._queue))
        if self.trace is not None:
            self.trace.event("submit", n=len(rids))
        return rids

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def pending_retries(self) -> int:
        """Frames awaiting a confidence-gated re-launch."""
        return len(self._retry_q)

    @property
    def in_flight(self) -> int:
        """Dispatched launches whose results have not been harvested yet."""
        return len(self._inflight)

    # ----------------------------------------------------------------- serve
    def _next_key(self) -> jax.Array:
        key = jax.random.fold_in(self._base_key, self._launches)
        self._launches += 1
        return key

    def _bucket(self, n_real: int) -> int:
        """Smallest power-of-two launch shape >= n_real (capped at max_batch).

        Padding to a bucket instead of to ``max_batch`` is the tail fix: the
        padded lanes still replicate the last real frame (one static shape
        per bucket), but a nearly-empty step skips the entropy planes of
        every lane above its bucket because those lanes are simply not in
        the launch.
        """
        b = 1
        while b < n_real:
            b <<= 1
        return min(b, self.max_batch)

    def _net_for(self, attempt: int) -> CompiledNetwork:
        """The (lazily compiled, cached) program for one retry attempt level.

        Attempt ``a`` runs ``escalation^a x`` the base stream length, capped
        at the policy's ``max_n_bits``; the escalated program reuses the base
        network's full lowering configuration (queries, evidence, estimator,
        entropy mode, noise model) on a single device -- retry batches are
        short tails, not the place for shard_map.
        """
        cached = attempt in self._nets
        if self.metrics is not None:
            self.metrics.inc("plan_cache_hits" if cached else "plan_cache_misses")
        if not cached:
            assert self.retry is not None
            n_bits = self.retry.n_bits_for(self.net.n_bits, attempt)
            self._nets[attempt] = compile_network(
                self.net.spec, n_bits, self.net.queries, self.net.evidence,
                share_entropy=self.net.share_entropy,
                estimator=self.net.estimator, fused=self.net.fused,
                noise=self.net.noise, devices=1, trace=self.trace,
            )
        return self._nets[attempt]

    def _pack(self, taken: list) -> Tuple[np.ndarray, int]:
        """Stack the taken frames and pad up to their power-of-two bucket."""
        ev = np.stack([row for _, row, _, _ in taken])
        n_real = ev.shape[0]
        bucket = self._bucket(n_real)
        if n_real < bucket:
            pad = np.repeat(ev[-1:], bucket - n_real, axis=0)
            ev = np.concatenate([ev, pad], axis=0)
        return ev, n_real

    def _launch(self, key: jax.Array | None, taken: list, attempt: int) -> int:
        """Pack one batch at one attempt level, launch it, park the results."""
        tr, mx = self.trace, self.metrics
        lspan = dspan = t_dispatch = None
        if tr is not None:
            lspan = tr.begin(
                f"launch[{self._dispatches}]", track="launch",
                attempt=attempt, n_real=len(taken),
            )
        if key is None:
            key = self._next_key()
        if tr is not None:
            with tr.span("pack", parent=lspan):
                ev, n_real = self._pack(taken)
        else:
            ev, n_real = self._pack(taken)
        self.last_launch_shape = ev.shape
        net = self.net if attempt == 0 else self._net_for(attempt)
        if mx is not None:
            t_dispatch = time.perf_counter()
        self.watch.step_start()
        if tr is not None:
            # host-side dispatch only: under async dispatch net.run returns
            # as soon as the work is enqueued, so this span is trace/compile
            # lookup + enqueue -- the device interval is the `device` span
            with tr.span("dispatch", parent=lspan, bucket=ev.shape[0]):
                post, accepted = net.run(key, ev)
        else:
            post, accepted = net.run(key, ev)
        ticket = self._dispatches
        self._dispatches += 1
        if self.watch.step_end(ticket):
            self.stats.slow_launches += 1
        self.stats.launches += 1
        if tr is not None:
            dspan = tr.begin("device", parent=lspan, track="device", ticket=ticket)
        if mx is not None:
            mx.inc("launches")
            mx.inc(f"bucket_{ev.shape[0]}")
            mx.inc("padded_lanes", ev.shape[0] - n_real)
            mx.inc(
                "entropy_words",
                ev.shape[0] * (net.n_bits // 32) * net.spec.n_nodes,
            )
            if attempt > 0:
                mx.inc(f"retry_launches_attempt_{attempt}")
            mx.set_gauge("in_flight", len(self._inflight) + 1)
            mx.set_gauge("pending", len(self._queue))
        self._inflight.append(
            (ticket, taken, attempt, post, accepted, lspan, dspan, t_dispatch)
        )
        return ticket

    def _dispatch(self, key: jax.Array | None) -> int:
        """Pack one main-queue batch (attempt 0), launch it (async)."""
        taken = [
            (rid, row, 0, 0)
            for rid, row in (
                self._queue.popleft()
                for _ in range(min(self.max_batch, len(self._queue)))
            )
        ]
        return self._launch(key, taken, 0)

    def _dispatch_retries(self, key: jax.Array | None) -> int:
        """Launch one batch from the retry queue (head's attempt level)."""
        attempt = self._retry_q[0][2]
        taken, rest = [], deque()
        while self._retry_q:
            item = self._retry_q.popleft()
            if item[2] == attempt and len(taken) < self.max_batch:
                taken.append(item)
            else:
                rest.append(item)
        self._retry_q = rest
        return self._launch(key, taken, attempt)

    def harvest(self) -> Dict[int, Tuple[np.ndarray, int]]:
        """Block on every in-flight launch and return {rid: (post, accepted)}.

        The single synchronisation point of the async mode: device arrays are
        converted to host arrays here (masking the padded lanes out -- only
        real rids appear), in dispatch order, so result mapping follows
        submission order exactly as in the sync path.  With a retry policy,
        under-confidence frames with budget left are re-queued instead of
        returned (dispatch them with the next ``step``/``drain``); emitted
        frames additionally gain a ``reports[rid]`` entry and roll into
        ``stats``.
        """
        out: Dict[int, Tuple[np.ndarray, int]] = {}
        tr, mx = self.trace, self.metrics
        while self._inflight:
            ticket, taken, attempt, post, accepted, lspan, dspan, t_disp = (
                self._inflight.popleft()
            )
            hspan = None
            if tr is not None:
                hspan = tr.begin("harvest", parent=lspan, ticket=ticket)
            post, accepted = np.asarray(post), np.asarray(accepted)
            if tr is not None:
                # first observable point at which this launch's device work
                # is complete: the host just blocked on its arrays
                tr.end(dspan)
            t_now = time.perf_counter() if mx is not None else None
            emitted: List[int] = []
            if self.retry is None:
                for i, (rid, _, _, _) in enumerate(taken):
                    out[rid] = (post[i], int(accepted[i]))
                    emitted.append(rid)
            else:
                n_real = len(taken)
                conf = decision_confidence(post[:n_real], accepted[:n_real])
                n_bits = (self.net if attempt == 0 else self._nets[attempt]).n_bits
                for i, (rid, row, _, bits_before) in enumerate(taken):
                    total = bits_before + n_bits
                    ok = bool(conf[i] >= self.retry.min_confidence)
                    if tr is not None and rid in self._retry_spans:
                        # this launch carried the frame's retry attempt: close
                        # the span opened when it was flagged
                        tr.end(self._retry_spans.pop(rid), confidence=float(conf[i]))
                    if not ok and attempt < self.retry.max_retries:
                        self._retry_q.append((rid, row, attempt + 1, total))
                        if tr is not None:
                            self._retry_spans[rid] = tr.begin(
                                f"retry[{rid}]", parent=lspan, track="retry",
                                attempt=attempt + 1, confidence=float(conf[i]),
                            )
                        if mx is not None:
                            mx.inc(f"retry_attempt_{attempt + 1}")
                        continue
                    out[rid] = (post[i], int(accepted[i]))
                    emitted.append(rid)
                    self.reports[rid] = FrameReport(
                        confidence=float(conf[i]), attempts=attempt + 1,
                        n_bits=n_bits, total_bits=total, reliable=ok,
                    )
                    self.stats.record_frame(float(conf[i]), attempt, total, ok)
                    if mx is not None and not ok:
                        mx.inc("flagged_unreliable")
            if mx is not None:
                mx.inc("frames_out", len(emitted))
                if t_disp is not None:
                    mx.observe(
                        "launch_ms", (t_now - t_disp) * 1e3,
                        budget_ms=PAPER_BUDGET_MS,
                    )
                # one dict pop per frame (C-speed map, single lookup), with
                # the arithmetic vectorised: harvest bookkeeping is on the
                # <=5% overhead budget
                waits = [
                    t for t in map(self._t_submit.pop, emitted,
                                   itertools.repeat(None))
                    if t is not None
                ]
                if waits:
                    mx.hist("frame_ms", budget_ms=PAPER_BUDGET_MS).observe_many(
                        (t_now - np.asarray(waits)) * 1e3
                    )
            if tr is not None:
                tr.end(hspan, emitted=len(emitted))
                tr.end(lspan, ticket=ticket)
        return out

    def step(
        self, key: jax.Array | None = None, block: bool = True
    ) -> Dict[int, Tuple[np.ndarray, int]]:
        """Run one round of batched launches over the queued frames.

        ``block=True`` (default) harvests immediately and returns
        {rid: (posteriors (n_q,), accepted bit count)} for this round (plus
        any still-unharvested async launches).  ``block=False`` only
        *dispatches* -- the jit launch's device work proceeds asynchronously
        while the caller packs more frames -- and returns ``{}``; collect
        results later with :meth:`harvest`.  ``key=None`` uses the driver's
        own launch-counter key sequence.

        Without a retry policy a round is exactly one launch (one batch off
        the queue).  With one, pending retry batches launch first (one per
        attempt level present, escalated programs), then the main batch; an
        explicit ``key`` covers them all by folding the within-step launch
        index (launch 0 uses ``key`` itself, so the no-retry case is
        unchanged).
        """
        if self.trace is None:
            return self._step_impl(key, block)
        with self.trace.span("step", block=block):
            return self._step_impl(key, block)

    def _step_impl(
        self, key: jax.Array | None, block: bool
    ) -> Dict[int, Tuple[np.ndarray, int]]:
        if not self._queue and not self._retry_q:
            return self.harvest() if block else {}
        n = 0

        def sub():
            nonlocal n
            k = None if key is None else (
                key if n == 0 else jax.random.fold_in(key, n)
            )
            n += 1
            return k

        while self._retry_q:
            self._dispatch_retries(sub())
        if self._queue:
            self._dispatch(sub())
        return self.harvest() if block else {}

    def drain(self, key: jax.Array | None = None) -> Dict[int, Tuple[np.ndarray, int]]:
        """Step until the queue (and any retry backlog) is empty.

        Returns all results keyed by rid.  Any launches previously dispatched
        with ``step(block=False)`` are harvested too, so ``drain`` is always
        the "collect everything" call -- even when the queue itself is
        already empty.
        """
        out: Dict[int, Tuple[np.ndarray, int]] = {}
        while self._queue or self._retry_q:
            if key is None:
                sub = None
            else:
                key, sub = jax.random.split(key)
            out.update(self.step(sub))
        out.update(self.harvest())
        return out

    def drain_async(
        self, key: jax.Array | None = None
    ) -> Dict[int, Tuple[np.ndarray, int]]:
        """Pipeline the whole queue: dispatch every launch, then harvest.

        Each launch is dispatched while its predecessors' device work is
        still in flight; ``block_until_ready`` happens once per harvest
        round, after everything dispatchable is in the air.  Key sequencing
        and rid mapping are identical to :meth:`drain`, so without a retry
        policy the posteriors are bit-identical to the sync path for the same
        ``(base_key, salt)``.  With a retry policy each harvest may re-queue
        under-confidence frames, which pipeline through further rounds until
        none remain; retry-round launch *grouping* differs from ``drain``'s
        (retries batch up across the whole round, and launch keys are drawn
        in a different order), so sync and async posteriors agree only for
        frames that never retried.
        """
        out: Dict[int, Tuple[np.ndarray, int]] = {}
        while self._queue or self._retry_q or self._inflight:
            while self._queue or self._retry_q:
                if key is None:
                    sub = None
                else:
                    key, sub = jax.random.split(key)
                self.step(sub, block=False)
            out.update(self.harvest())
        return out
