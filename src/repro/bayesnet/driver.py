"""Streaming frame driver: serve-style batching for compiled networks.

Mirrors the LM serving engine's admission discipline on the bayesnet side:
frames are submitted at any time into a pending queue, and every ``step``
packs up to ``max_batch`` of them -- padding the tail with the last real frame
so the jit launch keeps one static shape -- runs the compiled program once,
and returns per-request posteriors.  One compile, one launch shape, arbitrary
arrival pattern: the continuous-batching contract.

With the fused independent-entropy default (``compile_network``'s production
mode) every frame in a launch carries its own joint sample, so batch-mates
never share errors -- the padding frames simply burn a little extra entropy.
The driver also sequences launch keys itself: pass ``key=None`` to ``step`` /
``drain`` and each launch folds a monotonically increasing launch counter into
the driver's base key, so successive launches draw disjoint entropy without
the caller threading PRNG state.

Every driver additionally folds a ``salt`` into its base key.  ``salt=None``
(the default) takes the next value of a process-wide driver counter, so two
drivers constructed with defaults -- the footgun the old ``PRNGKey(0)``
default base key armed -- no longer draw bit-identical joint samples per
launch index.  Pass an explicit ``salt`` (a driver id) to make a driver's key
sequence reproducible across processes/restarts: drivers with the same
``(base_key, salt)`` replay the same launches, drivers differing in either
draw disjoint entropy.
"""

from __future__ import annotations

import itertools
from collections import deque
from typing import Dict, List, Tuple

import jax
import numpy as np

from repro.bayesnet.compile import CompiledNetwork

# Process-wide source of default driver salts (one per construction).
_DRIVER_IDS = itertools.count()


class FrameDriver:
    def __init__(
        self,
        net: CompiledNetwork,
        max_batch: int = 256,
        base_key: jax.Array | None = None,
        salt: int | None = None,
    ):
        self.net = net
        self.max_batch = int(max_batch)
        self._queue: deque = deque()
        self._next_rid = 0
        self.salt = next(_DRIVER_IDS) if salt is None else int(salt)
        base = base_key if base_key is not None else jax.random.PRNGKey(0)
        self._base_key = jax.random.fold_in(base, self.salt)
        self._launches = 0

    # ------------------------------------------------------------- admission
    def submit(self, frames) -> List[int]:
        """Queue evidence frames ((n_ev,) each, or an (N, n_ev) array); returns rids."""
        frames = np.asarray(frames, np.int32)
        if frames.ndim == 1:
            frames = frames[None, :]
        assert frames.shape[1] == len(self.net.evidence), frames.shape
        rids = []
        for row in frames:
            rid = self._next_rid
            self._next_rid += 1
            self._queue.append((rid, row))
            rids.append(rid)
        return rids

    @property
    def pending(self) -> int:
        return len(self._queue)

    # ----------------------------------------------------------------- serve
    def _next_key(self) -> jax.Array:
        key = jax.random.fold_in(self._base_key, self._launches)
        self._launches += 1
        return key

    def step(self, key: jax.Array | None = None) -> Dict[int, Tuple[np.ndarray, int]]:
        """Run one batched launch over up to ``max_batch`` queued frames.

        Returns {rid: (posteriors (n_q,), accepted bit count)}.  The launch
        shape is always (max_batch, n_ev): short batches are padded by
        repeating the final frame, and the padded rows' results are dropped.
        ``key=None`` uses the driver's own launch-counter key sequence.
        """
        if not self._queue:
            return {}
        if key is None:
            key = self._next_key()
        taken = [self._queue.popleft() for _ in range(min(self.max_batch, len(self._queue)))]
        ev = np.stack([row for _, row in taken])
        n_real = ev.shape[0]
        if n_real < self.max_batch:
            pad = np.repeat(ev[-1:], self.max_batch - n_real, axis=0)
            ev = np.concatenate([ev, pad], axis=0)
        post, accepted = self.net.run(key, ev)
        post, accepted = np.asarray(post), np.asarray(accepted)
        return {
            rid: (post[i], int(accepted[i]))
            for i, (rid, _) in enumerate(taken)
        }

    def drain(self, key: jax.Array | None = None) -> Dict[int, Tuple[np.ndarray, int]]:
        """Step until the queue is empty; returns all results keyed by rid."""
        out: Dict[int, Tuple[np.ndarray, int]] = {}
        while self._queue:
            if key is None:
                sub = None
            else:
                key, sub = jax.random.split(key)
            out.update(self.step(sub))
        return out
