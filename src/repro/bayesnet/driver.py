"""Streaming frame driver: serve-style batching for compiled networks.

Mirrors the LM serving engine's admission discipline on the bayesnet side:
frames are submitted at any time into a pending queue, and every ``step``
packs up to ``max_batch`` of them, runs the compiled program once, and
returns per-request posteriors.  Launch shapes are drawn from a small ladder
of power-of-two *buckets* (1, 2, 4, ... max_batch): a short batch pads up to
the nearest bucket by repeating its last real frame instead of always paying
the full ``max_batch`` lanes, so a 1-frame step on a 1024-lane driver costs
one frame's entropy, not ~1024x.  Padded lanes are dropped at harvest; each
bucket compiles once and is reused for every launch of that shape.

With the fused independent-entropy default (``compile_network``'s production
mode) every frame in a launch carries its own joint sample, so batch-mates
never share errors.  The driver also sequences launch keys itself: pass
``key=None`` to ``step`` / ``drain`` and each launch folds a monotonically
increasing launch counter into the driver's base key, so successive launches
draw disjoint entropy without the caller threading PRNG state.

**Async mode.**  ``step(block=False)`` dispatches the launch and returns
immediately with its ticket: jax's async dispatch runs the device work while
the driver packs and dispatches the next batch, and nothing calls
``block_until_ready`` until ``harvest()`` converts the posteriors to host
arrays.  ``drain_async`` pipelines the whole queue this way -- every launch
in flight back-to-back, one synchronisation at the end.  The launch-counter
key sequencing makes this safe: tickets are assigned at dispatch in
submission order, so async results map to rids exactly as sync results do,
and a sync and an async driver with the same ``(base_key, salt)`` return
bit-identical posteriors.

Every driver additionally folds a ``salt`` into its base key.  ``salt=None``
(the default) takes the next value of a process-wide driver counter, so two
drivers constructed with defaults -- the footgun the old ``PRNGKey(0)``
default base key armed -- no longer draw bit-identical joint samples per
launch index.  Pass an explicit ``salt`` (a driver id) to make a driver's key
sequence reproducible across processes/restarts: drivers with the same
``(base_key, salt)`` replay the same launches, drivers differing in either
draw disjoint entropy.
"""

from __future__ import annotations

import itertools
from collections import deque
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.bayesnet.compile import CompiledNetwork

# Process-wide source of default driver salts (one per construction).
_DRIVER_IDS = itertools.count()


class FrameDriver:
    def __init__(
        self,
        net: CompiledNetwork,
        max_batch: int = 256,
        base_key: jax.Array | None = None,
        salt: int | None = None,
    ):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.net = net
        self.max_batch = int(max_batch)
        self._queue: deque = deque()
        self._next_rid = 0
        self.salt = next(_DRIVER_IDS) if salt is None else int(salt)
        base = base_key if base_key is not None else jax.random.PRNGKey(0)
        self._base_key = jax.random.fold_in(base, self.salt)
        self._launches = 0
        self._dispatches = 0
        # dispatched-but-unharvested launches, in dispatch order:
        # (ticket, taken rids, device posteriors, device accepted counts)
        self._inflight: deque = deque()
        self.last_launch_shape: Optional[Tuple[int, int]] = None

    # ------------------------------------------------------------- admission
    def submit(self, frames) -> List[int]:
        """Queue evidence frames ((n_ev,) each, or an (N, n_ev) array); returns rids."""
        frames = np.asarray(frames, np.int32)
        if frames.ndim == 1:
            frames = frames[None, :]
        assert frames.shape[1] == len(self.net.evidence), frames.shape
        rids = []
        for row in frames:
            rid = self._next_rid
            self._next_rid += 1
            self._queue.append((rid, row))
            rids.append(rid)
        return rids

    @property
    def pending(self) -> int:
        return len(self._queue)

    @property
    def in_flight(self) -> int:
        """Dispatched launches whose results have not been harvested yet."""
        return len(self._inflight)

    # ----------------------------------------------------------------- serve
    def _next_key(self) -> jax.Array:
        key = jax.random.fold_in(self._base_key, self._launches)
        self._launches += 1
        return key

    def _bucket(self, n_real: int) -> int:
        """Smallest power-of-two launch shape >= n_real (capped at max_batch).

        Padding to a bucket instead of to ``max_batch`` is the tail fix: the
        padded lanes still replicate the last real frame (one static shape
        per bucket), but a nearly-empty step skips the entropy planes of
        every lane above its bucket because those lanes are simply not in
        the launch.
        """
        b = 1
        while b < n_real:
            b <<= 1
        return min(b, self.max_batch)

    def _dispatch(self, key: jax.Array | None) -> int:
        """Pack one batch, launch it (async), park the device results."""
        if key is None:
            key = self._next_key()
        taken = [
            self._queue.popleft()
            for _ in range(min(self.max_batch, len(self._queue)))
        ]
        ev = np.stack([row for _, row in taken])
        n_real = ev.shape[0]
        bucket = self._bucket(n_real)
        if n_real < bucket:
            pad = np.repeat(ev[-1:], bucket - n_real, axis=0)
            ev = np.concatenate([ev, pad], axis=0)
        self.last_launch_shape = ev.shape
        post, accepted = self.net.run(key, ev)
        ticket = self._dispatches
        self._dispatches += 1
        self._inflight.append((ticket, [rid for rid, _ in taken], post, accepted))
        return ticket

    def harvest(self) -> Dict[int, Tuple[np.ndarray, int]]:
        """Block on every in-flight launch and return {rid: (post, accepted)}.

        The single synchronisation point of the async mode: device arrays are
        converted to host arrays here (masking the padded lanes out -- only
        real rids appear), in dispatch order, so result mapping follows
        submission order exactly as in the sync path.
        """
        out: Dict[int, Tuple[np.ndarray, int]] = {}
        while self._inflight:
            _, rids, post, accepted = self._inflight.popleft()
            post, accepted = np.asarray(post), np.asarray(accepted)
            for i, rid in enumerate(rids):
                out[rid] = (post[i], int(accepted[i]))
        return out

    def step(
        self, key: jax.Array | None = None, block: bool = True
    ) -> Dict[int, Tuple[np.ndarray, int]]:
        """Run one batched launch over up to ``max_batch`` queued frames.

        ``block=True`` (default) harvests immediately and returns
        {rid: (posteriors (n_q,), accepted bit count)} for this launch (plus
        any still-unharvested async launches).  ``block=False`` only
        *dispatches* -- the jit launch's device work proceeds asynchronously
        while the caller packs more frames -- and returns ``{}``; collect
        results later with :meth:`harvest`.  ``key=None`` uses the driver's
        own launch-counter key sequence.
        """
        if not self._queue:
            return self.harvest() if block else {}
        self._dispatch(key)
        return self.harvest() if block else {}

    def drain(self, key: jax.Array | None = None) -> Dict[int, Tuple[np.ndarray, int]]:
        """Step until the queue is empty; returns all results keyed by rid.

        Any launches previously dispatched with ``step(block=False)`` are
        harvested too, so ``drain`` is always the "collect everything"
        call -- even when the queue itself is already empty.
        """
        out: Dict[int, Tuple[np.ndarray, int]] = {}
        while self._queue:
            if key is None:
                sub = None
            else:
                key, sub = jax.random.split(key)
            out.update(self.step(sub))
        out.update(self.harvest())
        return out

    def drain_async(
        self, key: jax.Array | None = None
    ) -> Dict[int, Tuple[np.ndarray, int]]:
        """Pipeline the whole queue: dispatch every launch, then one harvest.

        Each launch is dispatched while its predecessors' device work is
        still in flight; ``block_until_ready`` happens once, inside the
        final :meth:`harvest`.  Key sequencing and rid mapping are identical
        to :meth:`drain`, so the posteriors are bit-identical to the sync
        path for the same ``(base_key, salt)``.
        """
        while self._queue:
            if key is None:
                sub = None
            else:
                key, sub = jax.random.split(key)
            self.step(sub, block=False)
        return self.harvest()
